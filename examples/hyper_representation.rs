//! Hyper-representation learning (paper §6.2): a 3-layer MLP on
//! MNIST-shaped data where the *backbone* (~85k params) is the upper-level
//! variable and the classification *head* (~650 params) the lower-level
//! one.  Demonstrates the reference-point compression against the naive
//! error-feedback variant C²DFB(nc) — the paper's Fig. 3 story.
//!
//! ```bash
//! cargo run --release --example hyper_representation [-- rounds]
//! ```

use c2dfb::config::{Algorithm, ExperimentConfig};
use c2dfb::coordinator::{summarize, write_runs, Runner};
use c2dfb::data::partition::Partition;
use c2dfb::runtime::ArtifactRegistry;

fn main() -> anyhow::Result<()> {
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);
    let reg = ArtifactRegistry::open_default()?;

    let base = ExperimentConfig {
        name: "example_hyperrep".into(),
        preset: "hyperrep".into(),
        nodes: 10,
        rounds,
        inner_steps: 10,
        eta_out: 0.02,
        eta_in: 0.05,
        gamma_out: 0.3,
        gamma_in: 0.3,
        lambda: 10.0,
        compressor: "topk:0.3".into(),
        partition: Partition::Heterogeneous { h: 0.8 },
        eval_every: (rounds / 20).max(1),
        data_noise: 0.15,
        ..Default::default()
    };

    let mut runs = Vec::new();
    for algo in [Algorithm::C2dfb, Algorithm::C2dfbNc] {
        let mut cfg = base.clone();
        cfg.algorithm = algo;
        println!("--- {} ---", algo.name());
        let m = Runner::new(&cfg).registry(&reg).run()?;
        println!("{}", summarize(&m));
        runs.push(m);
    }

    println!("\nloss vs communication (MB) — reference-point vs naive:");
    println!("{:>10} {:>14} {:>14}", "comm(MB)", "c2dfb", "c2dfb_nc");
    let n = runs[0].trace.len().min(runs[1].trace.len());
    for i in 0..n {
        println!(
            "{:>10.1} {:>14.4} {:>14.4}",
            runs[0].trace[i].comm_mb, runs[0].trace[i].loss, runs[1].trace[i].loss
        );
    }
    write_runs("runs", "example_hyperrep", &runs)?;
    println!("\ntraces written to runs/example_hyperrep/");
    Ok(())
}
