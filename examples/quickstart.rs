//! Quickstart: run C²DFB on the tiny coefficient-tuning preset over a
//! 6-node ring with top-k compression, and print the learning curve.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use c2dfb::config::ExperimentConfig;
use c2dfb::coordinator::{summarize, Runner};
use c2dfb::data::partition::Partition;
use c2dfb::runtime::ArtifactRegistry;

fn main() -> anyhow::Result<()> {
    // 1. Open the AOT artifacts (built once by `make artifacts`; Python is
    //    never on this path).
    let reg = ArtifactRegistry::open_default()?;

    // 2. Describe the experiment: the paper's Algorithm 1+2 with the
    //    Appendix C.1 shape — 15 inner steps, λ = 10, top-20% compression —
    //    on a heterogeneous (h = 0.8) split.
    let cfg = ExperimentConfig {
        name: "quickstart".into(),
        preset: "coeff_tiny".into(),
        nodes: 6,
        rounds: 30,
        inner_steps: 10,
        eta_out: 0.2,
        eta_in: 0.2,
        lambda: 10.0,
        compressor: "topk:0.2".into(),
        partition: Partition::Heterogeneous { h: 0.8 },
        eval_every: 3,
        ..Default::default()
    };

    // 3. Run. All compute goes through the PJRT-loaded Pallas/JAX
    //    artifacts; all communication through the simulated gossip network
    //    with exact byte accounting.
    let metrics = Runner::new(&cfg).registry(&reg).run()?;

    println!("\nround  comm(MB)  loss     accuracy");
    for p in &metrics.trace {
        println!(
            "{:5}  {:8.3}  {:7.4}  {:7.3}",
            p.round, p.comm_mb, p.loss, p.accuracy
        );
    }
    println!("\n{}", summarize(&metrics));
    Ok(())
}
