//! End-to-end full-stack validation driver (DESIGN.md §5, EXPERIMENTS.md).
//!
//! Exercises every layer on a real small workload: generates the synthetic
//! MNIST-like corpus, partitions it heterogeneously over a 10-node
//! Erdős–Rényi network, and trains the 85k-parameter hyper-representation
//! bilevel problem with C²DFB for a few hundred outer rounds — all model
//! compute flowing through the AOT-compiled Pallas/JAX artifacts via PJRT,
//! all communication through the gossip simulator with exact byte
//! accounting.  Logs the loss/accuracy curve and the communication ledger
//! to `runs/e2e/`.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train [-- rounds]
//! ```

use c2dfb::config::{Algorithm, ExperimentConfig};
use c2dfb::coordinator::{summarize, Runner};
use c2dfb::data::partition::Partition;
use c2dfb::runtime::ArtifactRegistry;
use c2dfb::topology::Topology;

fn main() -> anyhow::Result<()> {
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let reg = ArtifactRegistry::open_default()?;

    let cfg = ExperimentConfig {
        name: "e2e".into(),
        preset: "hyperrep".into(),
        algorithm: Algorithm::C2dfb,
        nodes: 10,
        topology: Topology::ErdosRenyi { p_milli: 400, seed: 42 },
        partition: Partition::Heterogeneous { h: 0.8 },
        rounds,
        inner_steps: 10,
        eta_out: 0.02,
        eta_in: 0.05,
        gamma_out: 0.3,
        gamma_in: 0.3,
        lambda: 10.0,
        compressor: "topk:0.3".into(),
        eval_every: (rounds / 50).max(1),
        data_noise: 0.25,
        out_dir: "runs".into(),
        ..Default::default()
    };

    println!(
        "e2e: C²DFB, hyper-representation (dx=85k backbone / dy=650 head), \
         m=10 ER(0.4), het 0.8, top-k 30%, {rounds} rounds\n"
    );
    let metrics = Runner::new(&cfg).registry(&reg).run()?;

    println!("round  comm(MB)   sim-t(s)  wall(s)   loss      acc     ‖∇ψ̂‖");
    for p in &metrics.trace {
        println!(
            "{:5}  {:9.2}  {:8.3}  {:7.1}  {:8.4}  {:6.3}  {:9.3e}",
            p.round, p.comm_mb, p.sim_time_s, p.wall_time_s, p.loss, p.accuracy, p.grad_norm
        );
    }
    println!("\n{}", summarize(&metrics));
    let dir = std::path::Path::new("runs").join("e2e");
    metrics.write_to(&dir)?;
    println!("trace written to {}", dir.display());

    // Hard success criteria: the stack must have LEARNED, not just run.
    let first = metrics.trace.first().unwrap();
    let last = metrics.trace.last().unwrap();
    assert!(last.loss < first.loss * 0.5, "loss did not halve: {} -> {}", first.loss, last.loss);
    assert!(last.accuracy > 0.8, "final accuracy too low: {}", last.accuracy);
    println!(
        "\nE2E OK: loss {:.4} -> {:.4}, accuracy {:.3} -> {:.3}, {:.1} MB total traffic",
        first.loss, last.loss, first.accuracy, last.accuracy, last.comm_mb
    );
    Ok(())
}
