//! Coefficient tuning (paper §6.1) at full scale: the 20-Newsgroups-style
//! bilevel problem — per-feature exponential regularization weights tuned
//! at the upper level, a linear classifier trained at the lower level —
//! comparing C²DFB against the second-order baselines on a ring with
//! heterogeneous data.
//!
//! ```bash
//! cargo run --release --example coefficient_tuning [-- rounds]
//! ```

use c2dfb::config::{Algorithm, ExperimentConfig};
use c2dfb::coordinator::{summarize, write_runs, Runner};
use c2dfb::data::partition::Partition;
use c2dfb::runtime::ArtifactRegistry;

fn main() -> anyhow::Result<()> {
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);
    let reg = ArtifactRegistry::open_default()?;

    let base = ExperimentConfig {
        name: "example_coeff".into(),
        preset: "coeff".into(),
        nodes: 10,
        rounds,
        inner_steps: 15,
        eta_out: 0.5,
        eta_in: 0.2,
        gamma_out: 0.5,
        gamma_in: 0.5,
        lambda: 10.0,
        compressor: "topk:0.2".into(),
        partition: Partition::Heterogeneous { h: 0.8 },
        eval_every: (rounds / 20).max(1),
        target_accuracy: Some(0.7),
        ..Default::default()
    };

    let mut runs = Vec::new();
    for algo in [Algorithm::C2dfb, Algorithm::Madsbo, Algorithm::Mdbo] {
        let mut cfg = base.clone();
        cfg.algorithm = algo;
        if algo == Algorithm::Madsbo {
            cfg.eta_out = 1.0; // moving average damps the effective step
            cfg.eta_in = 0.1;
        }
        if algo == Algorithm::Mdbo {
            cfg.eta_in = 0.1;
        }
        println!("--- {} ---", algo.name());
        let m = Runner::new(&cfg).registry(&reg).run()?;
        println!("{}", summarize(&m));
        if let Some(p) = m.time_to_accuracy(0.7) {
            println!(
                "    reached 70% accuracy after {:.2} MB / {} rounds / {:.1}s wall",
                p.comm_mb, p.round, p.wall_time_s
            );
        } else {
            println!("    did NOT reach 70% accuracy in {rounds} rounds");
        }
        runs.push(m);
    }
    write_runs("runs", "example_coeff", &runs)?;
    println!("\ntraces written to runs/example_coeff/");
    Ok(())
}
