//! Convergence-theory tests: the paper's Theorems/Lemmas checked on the
//! analytic bilevel quadratic, plus cross-algorithm sanity (all methods
//! find the same hyper-optimum; C²DFB does it with less communication).

use c2dfb::collective::Network;
use c2dfb::compress::{Identity, TopK};
use c2dfb::config::{Algorithm, ExperimentConfig};
use c2dfb::coordinator::Runner;
use c2dfb::linalg;
use c2dfb::metrics::RunMetrics;
use c2dfb::optim::{run_inner, InnerConfig, InnerState};
use c2dfb::tasks::{BilevelTask, QuadraticTask};
use c2dfb::topology::{Graph, Topology};
use c2dfb::util::rng::Rng;

fn run_with_task(task: &QuadraticTask, cfg: &ExperimentConfig) -> anyhow::Result<RunMetrics> {
    Runner::new(cfg).task(task).run()
}

/// The analytic hyper-minimum (GD on the closed-form hypergradient).
fn psi_min(task: &QuadraticTask) -> (Vec<f32>, f64) {
    let mut x = task.init_x(&mut Rng::new(5));
    for _ in 0..8000 {
        let g = task.hypergrad_analytic(&x);
        for k in 0..x.len() {
            x[k] -= 0.2 * g[k];
        }
    }
    let v = task.psi(&x);
    (x, v)
}

/// Theorem 1 — linear inner-loop convergence to 1·ỹ* under compression:
/// the log-error decreases ~linearly in K (checked at three K values).
#[test]
fn theorem1_inner_linear_rate_under_compression() {
    let m = 8;
    let dim = 12;
    let task: QuadraticTask = QuadraticTask::generate(m, dim, 1.0, 7);
    let mut rng_master = Rng::new(3);
    let x = task.init_x(&mut rng_master);
    let xs: Vec<Vec<f32>> = vec![x; m];

    let errs: Vec<f64> = [30usize, 60, 120]
        .iter()
        .map(|&k_steps| {
            let mut net = Network::new(Graph::build(Topology::Ring, m));
            let mut rng = Rng::new(11);
            let mut state = InnerState::new(&net, dim);
            let mut zs = vec![vec![0.0f32; dim]; m];
            let cfg = InnerConfig { eta: 0.2, gamma: 0.6, k_steps };
            let xs_ref = &xs;
            run_inner(
                &cfg,
                &mut net,
                &TopK::new(0.3),
                &mut rng,
                &mut state,
                &mut zs,
                |i, z| task.inner_z_grad(i, &xs_ref[i], z).unwrap(),
            );
            // ỹ* for identical x across nodes is y*(x).
            let opt = task.y_star(&xs[0]);
            zs.iter()
                .map(|z| {
                    z.iter()
                        .zip(&opt)
                        .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
                        .sum::<f64>()
                })
                .sum()
        })
        .collect();
    assert!(errs[1] < errs[0] * 0.2, "K=60: {} vs K=30: {}", errs[1], errs[0]);
    assert!(errs[2] < errs[1] * 0.2 || errs[2] < 1e-9, "K=120: {} vs K=60: {}", errs[2], errs[1]);
}

/// Lemma 1/3 of the penalty method: the quality of the final point improves
/// as λ grows (bias ∝ 1/λ), at fixed budget.
#[test]
fn penalty_bias_shrinks_with_lambda() {
    let task: QuadraticTask = QuadraticTask::generate(6, 8, 0.6, 13);
    let (_, psi_star) = psi_min(&task);
    let mut last_excess = f64::INFINITY;
    for lambda in [2.0, 8.0, 32.0] {
        let cfg = ExperimentConfig {
            algorithm: Algorithm::C2dfb,
            nodes: 6,
            rounds: 400,
            inner_steps: 25,
            eta_out: 0.3,
            eta_in: 0.4,
            gamma_out: 0.8,
            gamma_in: 0.6,
            lambda,
            compressor: "topk:0.5".into(),
            eval_every: 50,
            ..Default::default()
        };
        let m = run_with_task(&task, &cfg).unwrap();
        let excess = (m.final_point().unwrap().loss - psi_star).abs();
        assert!(
            excess < last_excess * 1.5 + 1e-4,
            "λ={lambda}: excess {excess} vs previous {last_excess}"
        );
        last_excess = excess;
    }
    assert!(last_excess < 0.05, "λ=32 excess too large: {last_excess}");
}

/// All four algorithms drive the loss towards the same hyper-minimum on an
/// easy quadratic — the cross-validation that the baselines are faithful.
#[test]
fn all_algorithms_reach_same_optimum() {
    let task: QuadraticTask = QuadraticTask::generate(5, 6, 0.5, 17);
    let (_, psi_star) = psi_min(&task);
    for (algo, rounds, eta_out, eta_in, comp) in [
        (Algorithm::C2dfb, 300, 0.3, 0.3, "topk:0.5"),
        // The naive variant accumulates compression error and diverges at
        // these (aggressive) settings — the paper's Fig. 3 point.  It is
        // cross-validated dense here; its behaviour *under* compression is
        // exercised by the fig3 harness and the integration tests.
        (Algorithm::C2dfbNc, 300, 0.3, 0.3, "none"),
        (Algorithm::Madsbo, 800, 0.8, 0.3, "topk:0.5"),
        (Algorithm::Mdbo, 800, 0.4, 0.3, "topk:0.5"),
    ] {
        let cfg = ExperimentConfig {
            algorithm: algo,
            nodes: 5,
            rounds,
            inner_steps: 20,
            eta_out,
            eta_in,
            gamma_out: 0.8,
            gamma_in: 0.6,
            lambda: 40.0,
            compressor: comp.into(),
            eval_every: 100,
            ..Default::default()
        };
        let m = run_with_task(&task, &cfg).unwrap();
        let first_excess = m.trace.first().unwrap().loss - psi_star;
        let excess = m.final_point().unwrap().loss - psi_star;
        assert!(
            excess.abs() < 0.25 * first_excess.abs() + 0.05,
            "{}: excess {excess:.4} (start {first_excess:.4}, ψ* {psi_star:.4})",
            algo.name()
        );
    }
}

/// C²DFB needs (much) less communication than MDBO to reach the same loss
/// threshold — the Table 1 phenomenon on the analytic task.
#[test]
fn c2dfb_beats_mdbo_on_comm_to_threshold() {
    let task: QuadraticTask = QuadraticTask::generate(6, 32, 1.0, 19);
    let (_, psi_star) = psi_min(&task);
    let threshold = {
        // Halfway (in log scale) between start and optimum.
        let start = {
            let mut rng = Rng::new(42 ^ 0xA1607);
            let x0 = task.init_x(&mut rng);
            task.psi(&x0)
        };
        psi_star + (start - psi_star) * 0.25
    };
    let run = |algo: Algorithm, eta_out: f64| {
        let cfg = ExperimentConfig {
            algorithm: algo,
            nodes: 6,
            rounds: 600,
            inner_steps: 15,
            eta_out,
            eta_in: 0.3,
            gamma_out: 0.8,
            gamma_in: 0.6,
            lambda: 40.0,
            compressor: "topk:0.2".into(),
            eval_every: 5,
            ..Default::default()
        };
        run_with_task(&task, &cfg).unwrap()
    };
    let c = run(Algorithm::C2dfb, 0.3);
    let b = run(Algorithm::Mdbo, 0.4);
    let c_mb = c.comm_to_loss(threshold).map(|p| p.comm_mb);
    let b_mb = b.comm_to_loss(threshold).map(|p| p.comm_mb);
    let c_mb = c_mb.expect("C²DFB never reached the threshold");
    match b_mb {
        None => {} // MDBO never got there at this budget: stronger win.
        Some(b_mb) => assert!(
            c_mb < b_mb,
            "C²DFB {c_mb:.3} MB vs MDBO {b_mb:.3} MB to loss {threshold:.3}"
        ),
    }
}

/// Tighter compression (smaller δ) still converges, only slower — the
/// Fig. 5(middle) sensitivity shape.
#[test]
fn compression_ratio_sensitivity_shape() {
    let task: QuadraticTask = QuadraticTask::generate(6, 16, 0.8, 23);
    let mut final_losses = Vec::new();
    for ratio in ["0.05", "0.2", "1.0"] {
        let cfg = ExperimentConfig {
            algorithm: Algorithm::C2dfb,
            nodes: 6,
            rounds: 120,
            inner_steps: 10,
            eta_out: 0.3,
            // Theorem 1 prescribes η_in ∝ δ_c: the 5% ratio needs the
            // smallest step, so use a step safe for all three ratios.
            eta_in: 0.05,
            gamma_out: 0.8,
            gamma_in: 0.5,
            lambda: 30.0,
            compressor: format!("topk:{ratio}"),
            eval_every: 20,
            ..Default::default()
        };
        let m = run_with_task(&task, &cfg).unwrap();
        final_losses.push(m.final_point().unwrap().loss);
    }
    // All converge (finite, decreasing from the start), and the dense run
    // is no worse than the most aggressive compression.
    assert!(final_losses.iter().all(|l| l.is_finite()));
    assert!(final_losses[2] <= final_losses[0] * 1.5 + 0.05);
}

/// With Q = identity the reference-point protocol and textbook
/// uncompressed gradient tracking share the same fixed point (consensus at
/// ỹ*): the refpoint machinery adds no asymptotic bias.
#[test]
fn refpoint_protocol_fixed_point_matches_dense_tracking() {
    let m = 5;
    let dim = 10;
    let task: QuadraticTask = QuadraticTask::generate(m, dim, 0.7, 29);
    let x = task.init_x(&mut Rng::new(1));
    let xs: Vec<Vec<f32>> = vec![x; m];
    let opt = task.y_star(&xs[0]);

    // Protocol A: reference-point inner loop with Q = identity.
    let mut net = Network::new(Graph::build(Topology::Ring, m));
    let mut rng = Rng::new(2);
    let mut state = InnerState::new(&net, dim);
    let mut d_ref = vec![vec![0.0f32; dim]; m];
    let cfg = InnerConfig { eta: 0.2, gamma: 0.5, k_steps: 250 };
    let xs_ref = &xs;
    run_inner(&cfg, &mut net, &Identity, &mut rng, &mut state, &mut d_ref, |i, z| {
        task.inner_z_grad(i, &xs_ref[i], z).unwrap()
    });

    // Protocol B: textbook uncompressed gradient tracking (no refpoints).
    let mut d = vec![vec![0.0f32; dim]; m];
    let w = c2dfb::topology::MixingMatrix::metropolis(&Graph::build(Topology::Ring, m));
    let mut s: Vec<Vec<f32>> =
        (0..m).map(|i| task.inner_z_grad(i, &xs[i], &d[i]).unwrap()).collect();
    let mut prev: Vec<Vec<f32>> = s.clone();
    for _k in 0..250 {
        let mixed = w.mix(0.5, &d);
        for i in 0..m {
            d[i] = mixed[i].iter().zip(&s[i]).map(|(a, b)| a - 0.2 * b).collect();
        }
        let smixed = w.mix(0.5, &s);
        for i in 0..m {
            let g = task.inner_z_grad(i, &xs[i], &d[i]).unwrap();
            s[i] = smixed[i]
                .iter()
                .zip(&g)
                .zip(&prev[i])
                .map(|((sv, gn), go)| sv + gn - go)
                .collect();
            prev[i] = g;
        }
    }

    for protocol in [&d_ref, &d] {
        assert!(linalg::consensus_err_sq(protocol) < 1e-8);
        for node in protocol {
            for (a, b) in node.iter().zip(&opt) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        }
    }
}
