//! Sweep-orchestrator acceptance tests: the same grid at parallelism 1,
//! 2 and max yields bit-identical per-cell RunMetrics and identical
//! aggregate report bytes; a failing cell is reported per-cell without
//! aborting its siblings; and the refactored harnesses produce identical
//! results at any `--jobs`.

use c2dfb::config::{Algorithm, ExperimentConfig};
use c2dfb::coordinator::sweep::{self, Cell, SweepSpec, TaskRef};
use c2dfb::coordinator::experiments;
use c2dfb::sim::NetMode;
use c2dfb::tasks::QuadraticTask;

/// The acceptance criterion behind `c2dfb sweep --tiny`: one multi-axis
/// grid (2 algos × 2 tasks × 2 topologies × 2 engines), executed at
/// three pool widths, must agree bit-for-bit — per-cell metrics AND the
/// aggregated CSV/JSON report bytes.
#[test]
fn same_grid_bit_identical_at_parallelism_1_2_and_max() {
    let run_at = |jobs: usize| {
        let mut spec = SweepSpec::tiny();
        spec.jobs = jobs;
        sweep::run(&spec, false).expect("sweep run")
    };
    let (g1, o1) = run_at(1);
    assert_eq!(g1.cells.len(), 16);
    assert!(o1.iter().all(|o| o.result.is_ok()), "tiny grid must be clean");
    for jobs in [2, 0] {
        let (g, o) = run_at(jobs);
        assert_eq!(
            sweep::diff_outcomes(&o1, &o),
            None,
            "per-cell results must be bit-identical at jobs={jobs}"
        );
        assert_eq!(
            sweep::report_csv(&g1.cells, &o1),
            sweep::report_csv(&g.cells, &o),
            "CSV report bytes must be identical at jobs={jobs}"
        );
        assert_eq!(
            sweep::report_json(&g1.cells, &o1).to_string(),
            sweep::report_json(&g.cells, &o).to_string(),
            "JSON report bytes must be identical at jobs={jobs}"
        );
    }
}

/// The scale/width axes (`dtypes`, `sampling_rates`, `generators`) ride
/// the same bit-identity contract as every other axis: a grid mixing
/// default and non-default values of all three runs clean and yields
/// identical per-cell metrics and report bytes at parallelism 1, 2 and
/// max — f64 cells run through the same pool as f32 ones.
#[test]
fn scale_axes_grid_bit_identical_across_jobs() {
    let run_at = |jobs: usize| {
        let mut spec = SweepSpec::tiny();
        spec.algos = vec![Algorithm::C2dfb]; // sampling rates < 1 are c2dfb-only
        spec.tasks = vec!["quadratic".into()];
        spec.topologies = vec!["ring".into()]; // generator transport needs a
        spec.engines = vec![NetMode::Sync]; // generator topology + sync engine
        spec.dtypes = vec!["default".into(), "f64".into()];
        spec.sampling_rates = vec!["default".into(), "0.5".into()];
        spec.generators = vec!["default".into(), "on".into()];
        spec.jobs = jobs;
        sweep::run(&spec, false).expect("sweep run")
    };
    let (g1, o1) = run_at(1);
    assert_eq!(g1.cells.len(), 8, "2 dtypes x 2 rates x 2 generator modes");
    assert!(o1.iter().all(|o| o.result.is_ok()), "scale-axes grid must be clean");
    // Each f64 cell has an f32 twin differing only in the `+f64` id
    // segment, and must pay strictly more wire bytes on the same problem
    // (wider scalars, whatever the calibrated compressor kind).
    let bytes_of = |id: &str| {
        g1.cells
            .iter()
            .zip(&o1)
            .find(|(c, _)| c.id == id)
            .and_then(|(_, o)| o.metrics().map(|m| m.ledger.total_bytes))
            .unwrap_or_else(|| panic!("no metrics for cell {id}"))
    };
    let mut pairs = 0;
    for c in &g1.cells {
        if let Some(pos) = c.id.find("+f64") {
            let twin = format!("{}{}", &c.id[..pos], &c.id[pos + 4..]);
            let (b64, b32) = (bytes_of(&c.id), bytes_of(&twin));
            assert!(b64 > b32, "{}: f64 bytes {b64} not above f32 twin's {b32}", c.id);
            pairs += 1;
        }
    }
    assert_eq!(pairs, 4, "every non-default dtype cell pairs with a default twin");
    for jobs in [2, 0] {
        let (g, o) = run_at(jobs);
        assert_eq!(
            sweep::diff_outcomes(&o1, &o),
            None,
            "per-cell results must be bit-identical at jobs={jobs}"
        );
        assert_eq!(
            sweep::report_csv(&g1.cells, &o1),
            sweep::report_csv(&g.cells, &o),
            "CSV report bytes must be identical at jobs={jobs}"
        );
        assert_eq!(
            sweep::report_json(&g1.cells, &o1).to_string(),
            sweep::report_json(&g.cells, &o).to_string(),
            "JSON report bytes must be identical at jobs={jobs}"
        );
    }
}

/// Error isolation: a cell with an invalid config fails alone; every
/// sibling (before and after it in declaration order) completes, and the
/// report carries the per-cell error.
#[test]
fn failing_cell_does_not_abort_siblings() {
    let task: QuadraticTask = QuadraticTask::generate(4, 6, 0.5, 11);
    let mut cells = Vec::new();
    for (i, comp) in ["topk:0.5", "qsgd:0", "topk:0.5"].iter().enumerate() {
        let cfg = ExperimentConfig {
            algorithm: Algorithm::C2dfb,
            nodes: 4,
            rounds: 2,
            inner_steps: 3,
            eta_out: 0.1,
            eta_in: 0.2,
            eval_every: 1,
            compressor: comp.to_string(),
            ..ExperimentConfig::default()
        };
        cells.push(Cell { id: format!("cell{i}+{comp}"), cfg, task: TaskRef::Shared(0) });
    }
    let outcomes = sweep::run_cells(&cells, &[&task], None, 3, false);
    assert_eq!(outcomes.len(), 3);
    assert!(outcomes[0].result.is_ok(), "{:?}", outcomes[0].result.as_ref().err());
    assert!(outcomes[1].result.is_err(), "qsgd:0 must fail validation");
    assert!(outcomes[2].result.is_ok(), "sibling after the failure must still run");
    let csv = sweep::report_csv(&cells, &outcomes);
    let rows: Vec<&str> = csv.lines().collect();
    assert_eq!(rows.len(), 4, "header + one row per cell");
    assert!(rows[2].contains("error"));
    assert!(rows[1].contains(",ok,") && rows[3].contains(",ok,"));
}

/// A registry-lane cell without a registry is a per-cell error, and a
/// shared-lane cell pointing past the task table is too — never a panic,
/// never an abort of the other cells.
#[test]
fn bad_task_references_are_per_cell_errors() {
    let task: QuadraticTask = QuadraticTask::generate(4, 6, 0.5, 12);
    let ok_cfg = ExperimentConfig {
        nodes: 4,
        rounds: 2,
        inner_steps: 3,
        eta_out: 0.1,
        eta_in: 0.2,
        eval_every: 1,
        ..ExperimentConfig::default()
    };
    let cells = vec![
        Cell { id: "good".into(), cfg: ok_cfg.clone(), task: TaskRef::Shared(0) },
        Cell { id: "no-registry".into(), cfg: ok_cfg.clone(), task: TaskRef::Registry },
        Cell { id: "out-of-range".into(), cfg: ok_cfg, task: TaskRef::Shared(7) },
    ];
    let outcomes = sweep::run_cells(&cells, &[&task], None, 2, false);
    assert!(outcomes[0].result.is_ok());
    assert!(outcomes[1].result.as_ref().unwrap_err().contains("registry"));
    assert!(outcomes[2].result.as_ref().unwrap_err().contains("out of range"));
}

/// The divergence guard stays armed on the parallel lane: a cell driven
/// into non-finite losses stops with `observer_abort` instead of burning
/// its whole round budget, and its siblings are unaffected.
#[test]
fn divergence_guard_fires_inside_parallel_cells() {
    let task: QuadraticTask = QuadraticTask::generate(4, 6, 0.5, 13);
    let mut diverging = ExperimentConfig {
        nodes: 4,
        rounds: 50,
        inner_steps: 5,
        eta_out: 1e6, // far past the stability edge
        eta_in: 1e6,
        eval_every: 1,
        ..ExperimentConfig::default()
    };
    diverging.algorithm = Algorithm::C2dfb;
    let sane = ExperimentConfig {
        nodes: 4,
        rounds: 3,
        inner_steps: 3,
        eta_out: 0.1,
        eta_in: 0.2,
        eval_every: 1,
        ..ExperimentConfig::default()
    };
    let cells = vec![
        Cell { id: "diverging".into(), cfg: diverging, task: TaskRef::Shared(0) },
        Cell { id: "sane".into(), cfg: sane, task: TaskRef::Shared(0) },
    ];
    let outcomes = sweep::run_cells(&cells, &[&task], None, 2, false);
    let m = outcomes[0].metrics().expect("aborted runs still return metrics");
    assert_eq!(
        m.stop_reason,
        Some(c2dfb::metrics::StopReason::Observer),
        "guard must abort the diverging cell"
    );
    assert!(m.trace.len() < 50, "abort must fire well before the round cap");
    assert!(outcomes[1].result.is_ok());
}

/// Cell seeds follow the published derivation contract and the task
/// table is shared: every cell of a (task, partition) group points at
/// one task instance, so comparisons run on identical data.
#[test]
fn expansion_shares_tasks_and_derives_seeds() {
    let spec = SweepSpec::tiny();
    let grid = sweep::expand(&spec).unwrap();
    // 16 cells over 2 (task, partition) groups -> exactly 2 instances.
    assert_eq!(grid.tasks.len(), 2);
    for c in &grid.cells {
        assert_eq!(c.cfg.seed, sweep::derive_seed(spec.base.seed, &c.id));
        match c.task {
            TaskRef::Shared(i) => assert!(i < grid.tasks.len()),
            TaskRef::Registry => panic!("native sweeps never use the registry lane"),
        }
    }
    // Editing an axis (dropping one topology) leaves surviving cells'
    // seeds untouched — the contract that makes grids extendable.
    let mut smaller = spec.clone();
    smaller.topologies = vec!["ring".into()];
    let sgrid = sweep::expand(&smaller).unwrap();
    for sc in &sgrid.cells {
        let twin = grid.cells.iter().find(|c| c.id == sc.id).expect("subset");
        assert_eq!(twin.cfg.seed, sc.cfg.seed);
    }
}

/// The refactored `budget` harness (now a grid declaration over the
/// sweep engine) returns identical trajectories at any --jobs.
#[test]
fn budget_harness_identical_across_jobs() {
    let dir = std::env::temp_dir().join("c2dfb_sweep_budget_jobs");
    let _ = std::fs::remove_dir_all(&dir);
    let opts = |jobs: usize, sub: &str| experiments::HarnessOpts {
        rounds: 40,
        out_dir: dir.join(sub).to_str().unwrap().to_string(),
        seed: 42,
        jobs,
        ..Default::default()
    };
    let serial = experiments::budget(&opts(1, "serial"), 0.4, true).unwrap();
    let parallel = experiments::budget(&opts(4, "parallel"), 0.4, true).unwrap();
    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.ledger.total_bytes, b.ledger.total_bytes, "{}", a.algo);
        assert_eq!(a.stop_reason, b.stop_reason, "{}", a.algo);
        let la: Vec<u64> = a.trace.iter().map(|p| p.loss.to_bits()).collect();
        let lb: Vec<u64> = b.trace.iter().map(|p| p.loss.to_bits()).collect();
        assert_eq!(la, lb, "{}", a.algo);
    }
    // The aggregated report landed next to the traces in both runs.
    assert!(dir.join("serial/budget/report.csv").exists());
    assert!(dir.join("parallel/budget/report.json").exists());
}
