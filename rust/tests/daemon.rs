//! End-to-end tests for the `c2dfb serve` daemon: in-process
//! [`daemon::spawn`] on ephemeral ports (`127.0.0.1:0`), driven through
//! the real TCP line protocol ([`daemon::Client`]) and raw HTTP/1.1
//! requests.  The acceptance criteria from the daemon PR live here:
//! resubmitted grids are fully cache-served with zero new cell
//! executions, and daemon report bytes are identical to a batch
//! `c2dfb sweep` of the same body.

// Test deadlines legitimately read the wall clock (clippy.toml bans it
// in deterministic code; see docs/LINT.md R1).
#![allow(clippy::disallowed_methods)]

use c2dfb::coordinator::sweep::{self, ExecOpts, SweepSpec};
use c2dfb::daemon::{self, Client, Job, JobState, ServeOpts, SubmitError};
use c2dfb::obs::Console;
use c2dfb::util::json::Json;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

const TINY_BODY: &str = r#"{"sweep": {"tiny": true}}"#;
const DEADLINE: Duration = Duration::from_secs(120);

/// Poll a job to a terminal state (the executor runs on its own thread).
fn wait_state(job: &Arc<Job>) -> JobState {
    let t0 = Instant::now();
    loop {
        let s = job.state();
        if s.terminal() {
            return s;
        }
        assert!(
            t0.elapsed() < DEADLINE,
            "timed out waiting for job {} (still {:?})",
            job.id,
            s
        );
        std::thread::sleep(Duration::from_millis(25));
    }
}

/// One blocking HTTP/1.1 request; the server closes after responding, so
/// read-to-EOF captures the full response.
fn http_req(addr: SocketAddr, method: &str, path: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).expect("send request");
    let mut out = String::new();
    s.read_to_string(&mut out).expect("read response");
    out
}

fn http_body(resp: &str) -> &str {
    resp.split_once("\r\n\r\n").expect("header separator").1
}

/// Concurrent submissions through the real TCP protocol all complete,
/// each with an intact full-grid report (per-job error isolation).
#[test]
fn concurrent_tcp_submissions_all_complete() {
    let opts = ServeOpts { tcp: Some("127.0.0.1:0".into()), ..ServeOpts::default() };
    let handle = daemon::spawn(opts).expect("spawn daemon");
    let addr = handle.tcp_addr.expect("tcp bound").to_string();

    let threads: Vec<_> = (0..4)
        .map(|k| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let c = Client::new(&addr);
                let st = c.submit(TINY_BODY, k as i64, false).expect("submit");
                st.get("id").and_then(Json::as_usize).expect("id") as u64
            })
        })
        .collect();
    let ids: Vec<u64> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    assert_eq!(ids.len(), 4);

    let c = Client::new(&addr);
    let quiet = Console::quiet();
    let ncells = sweep::expand(&SweepSpec::tiny()).unwrap().cells.len();
    for id in &ids {
        let fin = c.wait(*id, DEADLINE, &quiet).expect("wait");
        assert_eq!(fin.get("state").and_then(Json::as_str), Some("done"), "{fin:?}");
        let csv = String::from_utf8(c.report(*id, "csv").expect("report")).unwrap();
        assert_eq!(
            csv.lines().count(),
            ncells + 1,
            "job {id}: header + one row per cell"
        );
    }
    handle.shutdown_join(false);
}

/// The headline cache contract: resubmitting an identical grid returns
/// byte-identical reports with every cell served from the cache — zero
/// new cell executions (and therefore zero new oracle calls).
#[test]
fn resubmitted_grid_is_cache_served_and_byte_identical() {
    let handle = daemon::spawn(ServeOpts::default()).expect("spawn daemon");
    let d = handle.daemon.clone();

    let a = d.submit(TINY_BODY, 0, false).expect("submit a");
    assert_eq!(wait_state(&a), JobState::Done);
    let misses = d.counters.cache_misses.load(Ordering::Relaxed);
    let run = d.counters.cells_run.load(Ordering::Relaxed);
    assert!(misses > 0, "first run must populate the cache");
    assert_eq!(run, misses, "every miss ran exactly once");
    let (csv_a, json_a) =
        a.with_progress(|st| (st.report_csv.clone().unwrap(), st.report_json.clone().unwrap()));

    let b = d.submit(TINY_BODY, 0, false).expect("submit b");
    assert_eq!(wait_state(&b), JobState::Done);
    assert_eq!(
        d.counters.cache_misses.load(Ordering::Relaxed),
        misses,
        "resubmission must not miss the cache"
    );
    assert_eq!(
        d.counters.cells_run.load(Ordering::Relaxed),
        run,
        "resubmission must execute zero cells"
    );
    b.with_progress(|st| {
        assert_eq!(st.cells_cached, st.cells_total, "fully cache-served");
        assert_eq!(st.report_csv.as_deref(), Some(csv_a.as_str()), "CSV bytes differ");
        assert_eq!(st.report_json.as_deref(), Some(json_a.as_str()), "JSON bytes differ");
    });
    handle.shutdown_join(false);
}

/// Daemon reports are bit-identical to what a batch `c2dfb sweep` of the
/// same body writes: same grid expansion, same derived seeds, same
/// report rendering.
#[test]
fn daemon_report_bytes_match_batch_sweep() {
    let eopts = ExecOpts {
        jobs: 0,
        console: Console::quiet(),
        trace: false,
        profile: false,
    };
    let (grid, outcomes) = sweep::run_with(&SweepSpec::tiny(), &eopts).expect("batch sweep");
    let batch_csv = sweep::report_csv(&grid.cells, &outcomes);
    let batch_json = sweep::report_json(&grid.cells, &outcomes).to_string() + "\n";

    let handle = daemon::spawn(ServeOpts::default()).expect("spawn daemon");
    let job = handle.daemon.submit(TINY_BODY, 0, false).expect("submit");
    assert_eq!(wait_state(&job), JobState::Done);
    job.with_progress(|st| {
        assert_eq!(st.report_csv.as_deref(), Some(batch_csv.as_str()), "CSV differs");
        assert_eq!(st.report_json.as_deref(), Some(batch_json.as_str()), "JSON differs");
    });
    handle.shutdown_join(false);
}

/// Cancelling one job leaves its siblings untouched: the cancelled job
/// ends `cancelled` with a closed event log, the sibling completes with
/// a full report.
#[test]
fn cancelling_one_job_leaves_siblings_untouched() {
    let opts = ServeOpts { start_paused: true, ..ServeOpts::default() };
    let handle = daemon::spawn(opts).expect("spawn daemon");
    let d = &handle.daemon;

    let a = d.submit(TINY_BODY, 0, false).expect("submit a");
    let b = d.submit(TINY_BODY, 0, false).expect("submit b");
    d.cancel(a.id).expect("cancel a");
    assert_eq!(a.state(), JobState::Cancelled);

    d.pause(false);
    assert_eq!(wait_state(&b), JobState::Done);
    assert_eq!(a.state(), JobState::Cancelled, "sibling completion must not revive a");
    b.with_progress(|st| {
        assert_eq!(st.cells_done, st.cells_total);
        assert!(st.report_csv.is_some());
    });
    let (lines, _, closed) = a.events.snapshot_from(0);
    assert!(closed, "cancelled job's event log must close");
    assert!(
        lines.iter().any(|l| l.contains("job_done") && l.contains("cancelled")),
        "terminal event missing: {lines:?}"
    );
    handle.shutdown_join(false);
}

/// Drain shutdown finishes every queued job before stopping, and refuses
/// new submissions the moment it begins.
#[test]
fn drain_shutdown_finishes_queued_jobs() {
    let opts = ServeOpts { start_paused: true, ..ServeOpts::default() };
    let handle = daemon::spawn(opts).expect("spawn daemon");
    let a = handle.daemon.submit(TINY_BODY, 0, false).expect("submit a");
    let b = handle.daemon.submit(TINY_BODY, 3, false).expect("submit b");

    handle.daemon.begin_shutdown(false);
    assert!(
        matches!(handle.daemon.submit(TINY_BODY, 0, false), Err(SubmitError::ShuttingDown)),
        "drain mode must refuse new work"
    );
    let d = handle.daemon.clone();
    handle.join();

    assert!(d.stopped());
    assert_eq!(a.state(), JobState::Done, "drain must finish queued jobs");
    assert_eq!(b.state(), JobState::Done, "drain must finish queued jobs");
}

/// The HTTP surface end-to-end: health probe, submission, queue
/// backpressure as 429, artifact serving, SSE event replay, and a
/// `/metrics` document that passes the strict exposition validator both
/// before and after cells have run.
#[test]
fn http_surface_backpressure_artifacts_and_valid_metrics() {
    let opts = ServeOpts {
        http: Some("127.0.0.1:0".into()),
        queue_cap: 1,
        start_paused: true,
        ..ServeOpts::default()
    };
    let handle = daemon::spawn(opts).expect("spawn daemon");
    let addr = handle.http_addr.expect("http bound");

    assert!(http_req(addr, "GET", "/healthz", "").starts_with("HTTP/1.1 200"));

    let r1 = http_req(addr, "POST", "/jobs?priority=2", TINY_BODY);
    assert!(r1.starts_with("HTTP/1.1 201"), "submit: {r1}");
    let r2 = http_req(addr, "POST", "/jobs", TINY_BODY);
    assert!(r2.starts_with("HTTP/1.1 429"), "backpressure: {r2}");

    // Artifacts do not exist yet: 409 while queued.
    let early = http_req(addr, "GET", "/jobs/1/report.csv", "");
    assert!(early.starts_with("HTTP/1.1 409"), "{early}");

    // Metrics must validate even before anything has run.
    let m = http_req(addr, "GET", "/metrics", "");
    assert!(m.starts_with("HTTP/1.1 200"));
    daemon::validate_exposition(http_body(&m)).expect("pre-run exposition invalid");

    handle.daemon.pause(false);
    let job = handle.daemon.job(1).expect("job 1 exists");
    assert_eq!(wait_state(&job), JobState::Done);

    let csv = http_req(addr, "GET", "/jobs/1/report.csv", "");
    assert!(csv.starts_with("HTTP/1.1 200"), "{csv}");
    let expected = job.with_progress(|st| st.report_csv.clone().unwrap());
    assert_eq!(http_body(&csv), expected, "HTTP artifact differs from stored report");

    // SSE replay: the log is closed, so the stream drains and ends.
    let sse = http_req(addr, "GET", "/jobs/1/events", "");
    assert!(sse.contains("Content-Type: text/event-stream"), "{sse}");
    assert!(sse.contains("data: "), "{sse}");
    assert!(sse.contains("job_done"), "{sse}");

    let m2 = http_req(addr, "GET", "/metrics", "");
    let body2 = http_body(&m2);
    let samples = daemon::validate_exposition(body2).expect("post-run exposition invalid");
    assert!(samples >= 16, "expected full family set, got {samples} samples");
    assert!(
        body2.contains("c2dfb_daemon_jobs_completed_total 1"),
        "completion counter missing:\n{body2}"
    );
    assert!(body2.contains("c2dfb_daemon_cells_run_total"), "{body2}");

    handle.shutdown_join(false);
}
