//! End-to-end dtype acceptance (docs/DTYPE.md): the f64 lane runs the
//! same experiment as the f32 lane on exactly-widened data, tracks it
//! within a roundoff envelope, and pays double the scalar wire bytes —
//! the whole point of keeping f32 the default payload width.

use c2dfb::config::{Algorithm, ExperimentConfig};
use c2dfb::coordinator::Runner;
use c2dfb::linalg::Dtype;
use c2dfb::metrics::RunMetrics;
use c2dfb::tasks::QuadraticTask;
use c2dfb::util::prop::{check, ensure, Gen};

fn cfg(nodes: usize, rounds: usize, seed: u64, dtype: Dtype) -> ExperimentConfig {
    ExperimentConfig {
        algorithm: Algorithm::C2dfb,
        nodes,
        rounds,
        inner_steps: 3,
        eta_out: 0.1,
        eta_in: 0.2,
        eval_every: 1,
        // Dense payloads: every message bills 8 + S::BYTES * len, which
        // makes the f32/f64 byte relation exact rather than approximate.
        compressor: "none".into(),
        seed,
        dtype,
        ..ExperimentConfig::default()
    }
}

/// Run the same quadratic experiment at both payload widths.  The f64
/// task is the exact widening of the f32 task (same generator streams),
/// so the two runs differ only in arithmetic precision.
fn run_both(nodes: usize, dim: usize, rounds: usize, seed: u64) -> (RunMetrics, RunMetrics) {
    let t32: QuadraticTask = QuadraticTask::generate(nodes, dim, 0.8, seed);
    let t64: QuadraticTask<f64> = QuadraticTask::generate(nodes, dim, 0.8, seed);
    let m32 = Runner::new(&cfg(nodes, rounds, seed, Dtype::F32))
        .task(&t32)
        .run()
        .expect("f32 run");
    let m64 = Runner::new(&cfg(nodes, rounds, seed, Dtype::F64))
        .task_f64(&t64)
        .run()
        .expect("f64 run");
    (m32, m64)
}

/// ISSUE acceptance: an f32 run reports ~half the CommLedger payload
/// bytes of its f64 twin.  With dense payloads the relation is exact:
/// each copy bills `8 + S::BYTES * len`, the schedules are identical, so
/// `bytes_f64 = 2 * bytes_f32 - 8 * messages` and the message counts
/// match copy-for-copy.
#[test]
fn f32_run_pays_half_the_scalar_bytes_of_f64() {
    let (m32, m64) = run_both(4, 16, 3, 17);
    assert!(m32.ledger.total_bytes > 0, "the f32 run must actually communicate");
    assert_eq!(m32.ledger.messages, m64.ledger.messages, "same copy schedule");
    assert_eq!(
        m64.ledger.total_bytes + 8 * m64.ledger.messages,
        2 * m32.ledger.total_bytes,
        "f64 scalar bytes must be exactly double (f32 {} vs f64 {})",
        m32.ledger.total_bytes,
        m64.ledger.total_bytes
    );
    // And the headline ratio the ISSUE quotes: roughly half.
    let ratio = m64.ledger.total_bytes as f64 / m32.ledger.total_bytes as f64;
    assert!((1.8..=2.0).contains(&ratio), "byte ratio {ratio} not ~2");
}

/// Tolerance envelope: over random quadratic instances, every evaluated
/// f32 loss stays inside a relative roundoff envelope of the f64 loss at
/// the same round.  The envelope (1e-3 relative) is orders of magnitude
/// above honest f32 roundoff for these sizes, so a real divergence — a
/// kernel widening where it shouldn't, a dtype-dependent code path — is
/// caught, while legitimate rounding never trips it.
#[test]
fn prop_f32_losses_track_f64_within_roundoff_envelope() {
    check("dtype-envelope", 10, |g: &mut Gen| {
        let nodes = g.usize_in(3, 6);
        let dim = g.usize_in(4, 16);
        let seed = g.rng.next_u64();
        let (m32, m64) = run_both(nodes, dim, 3, seed);
        ensure(
            m32.trace.len() == m64.trace.len(),
            format!("trace lengths diverge: {} vs {}", m32.trace.len(), m64.trace.len()),
        )?;
        ensure(!m32.trace.is_empty(), "empty trace")?;
        for (a, b) in m32.trace.iter().zip(&m64.trace) {
            ensure(
                a.loss.is_finite() && b.loss.is_finite(),
                format!("non-finite loss ({} / {})", a.loss, b.loss),
            )?;
            let tol = 1e-3 * (1.0 + b.loss.abs());
            ensure(
                (a.loss - b.loss).abs() <= tol,
                format!(
                    "f32 loss {} leaves the f64 envelope {} ± {tol} (nodes {nodes}, dim {dim}, seed {seed})",
                    a.loss, b.loss
                ),
            )?;
        }
        Ok(())
    });
}
