//! Hot-path refactor equivalence: the zero-allocation inner loop must be
//! **bit-identical** to the original allocating formulation.
//!
//! `reference_inner` / `reference_inner_naive` below transcribe the
//! pre-refactor protocol verbatim (fresh `Vec`s per residual/message/
//! gradient batch, the Arc-based `exchange`, weights read after the
//! exchange — safe here: static graphs only).  Any numerical or
//! accounting drift introduced by buffer reuse, `compress_into`, the
//! borrowing exchange or the `NodeBlock` layout shows up as a bitwise
//! mismatch.  Together with the golden fixtures (which pin the same
//! trajectories across releases), this is the proof the rewrite changed
//! performance, not semantics.

use c2dfb::collective::{Network, Transport};
use c2dfb::compress::{parse, Compressor};
use c2dfb::linalg::{kernels, Scalar};
use c2dfb::optim::{run_inner, run_inner_naive, InnerConfig, InnerState, RefPoint};
use c2dfb::topology::{Graph, Topology};
use c2dfb::util::rng::Rng;

struct Quad {
    a: Vec<f32>,
    c: Vec<Vec<f32>>,
}

impl Quad {
    fn build(m: usize, dim: usize, seed: u64) -> Quad {
        let mut rng = Rng::new(seed);
        Quad {
            a: (0..m).map(|_| rng.uniform_in(0.5, 2.0)).collect(),
            c: (0..m)
                .map(|_| {
                    let mut v = vec![0.0f32; dim];
                    rng.fill_normal(&mut v, 0.0, 1.0);
                    v
                })
                .collect(),
        }
    }

    fn grad(&self, i: usize, z: &[f32]) -> Vec<f32> {
        z.iter()
            .zip(&self.c[i])
            .map(|(x, c)| self.a[i] * (x - c))
            .collect()
    }
}

/// Pre-refactor per-node inner state (plain vectors).
struct RefState {
    d_ref: Vec<RefPoint>,
    s: Vec<Vec<f32>>,
    s_ref: Vec<RefPoint>,
    prev_grad: Vec<Vec<f32>>,
    err_d: Vec<Vec<f32>>,
    err_s: Vec<Vec<f32>>,
}

impl RefState {
    fn new(net: &Network, dim: usize) -> RefState {
        let m = net.m();
        let mk = || {
            (0..m)
                .map(|i| RefPoint::new(dim, 1.0 - Transport::mixing(net).weight(i, i)))
                .collect::<Vec<_>>()
        };
        RefState {
            d_ref: mk(),
            s: vec![vec![0.0; dim]; m],
            s_ref: mk(),
            prev_grad: vec![vec![0.0; dim]; m],
            err_d: vec![vec![0.0; dim]; m],
            err_s: vec![vec![0.0; dim]; m],
        }
    }

    fn bootstrap(&mut self, q: &Quad, d: &[Vec<f32>]) {
        let g: Vec<Vec<f32>> = d.iter().enumerate().map(|(i, di)| q.grad(i, di)).collect();
        self.prev_grad = g.clone();
        self.s = g;
    }
}

/// The original (allocating) reference-point protocol, verbatim.
fn reference_inner(
    cfg: &InnerConfig,
    net: &mut Network,
    compressor: &dyn Compressor,
    rng: &mut Rng,
    state: &mut RefState,
    d: &mut [Vec<f32>],
    q: &Quad,
) {
    let m = net.m();
    let eta = cfg.eta as f32;
    let gamma = cfg.gamma as f32;
    for _k in 0..cfg.k_steps {
        for i in 0..m {
            state.d_ref[i].add_mix_term(gamma, &mut d[i]);
            for (dk, sk) in d[i].iter_mut().zip(&state.s[i]) {
                *dk -= eta * sk;
            }
        }
        let msgs: Vec<_> = (0..m)
            .map(|i| compressor.compress(&state.d_ref[i].residual(&d[i]), rng))
            .collect();
        for i in 0..m {
            state.d_ref[i].apply_own(&msgs[i]);
        }
        let inbox = net.exchange(msgs);
        for (i, arrived) in inbox.into_iter().enumerate() {
            for (j, qmsg) in arrived {
                let wij = Transport::mixing(net).weight(i, j);
                state.d_ref[i].apply_neighbor(wij, qmsg.as_ref());
            }
        }
        for i in 0..m {
            state.s_ref[i].add_mix_term(gamma, &mut state.s[i]);
        }
        let g_new: Vec<Vec<f32>> = d.iter().enumerate().map(|(i, di)| q.grad(i, di)).collect();
        for i in 0..m {
            for ((sk, gn), go) in state.s[i]
                .iter_mut()
                .zip(&g_new[i])
                .zip(&state.prev_grad[i])
            {
                *sk += gn - go;
            }
        }
        state.prev_grad = g_new;
        let msgs: Vec<_> = (0..m)
            .map(|i| compressor.compress(&state.s_ref[i].residual(&state.s[i]), rng))
            .collect();
        for i in 0..m {
            state.s_ref[i].apply_own(&msgs[i]);
        }
        let inbox = net.exchange(msgs);
        for (i, arrived) in inbox.into_iter().enumerate() {
            for (j, qmsg) in arrived {
                let wij = Transport::mixing(net).weight(i, j);
                state.s_ref[i].apply_neighbor(wij, qmsg.as_ref());
            }
        }
    }
}

/// The original (allocating) naive error-feedback protocol, verbatim.
fn reference_inner_naive(
    cfg: &InnerConfig,
    net: &mut Network,
    compressor: &dyn Compressor,
    rng: &mut Rng,
    state: &mut RefState,
    d: &mut [Vec<f32>],
    q: &Quad,
) {
    let m = net.m();
    let eta = cfg.eta as f32;
    let gamma = cfg.gamma as f32;
    for _k in 0..cfg.k_steps {
        let mut msgs = Vec::with_capacity(m);
        for i in 0..m {
            let mut carry: Vec<f32> = d[i]
                .iter()
                .zip(&state.err_d[i])
                .map(|(a, e)| a + e)
                .collect();
            let qm = compressor.compress(&carry, rng);
            let dense = qm.to_dense();
            for (c, qv) in carry.iter_mut().zip(&dense) {
                *c -= qv;
            }
            state.err_d[i] = carry;
            msgs.push(qm);
        }
        let own: Vec<Vec<f32>> = msgs.iter().map(|qm| qm.to_dense()).collect();
        let inbox = net.exchange(msgs);
        for (i, arrived) in inbox.into_iter().enumerate() {
            for (sender, _qm) in arrived {
                let w = (gamma as f64 * Transport::mixing(net).weight(i, sender)) as f32;
                let qd = &own[sender];
                for k in 0..d[i].len() {
                    d[i][k] += w * (qd[k] - own[i][k]);
                }
            }
            for (dk, sk) in d[i].iter_mut().zip(&state.s[i]) {
                *dk -= eta * sk;
            }
        }
        let mut smsgs = Vec::with_capacity(m);
        for i in 0..m {
            let mut carry: Vec<f32> = state.s[i]
                .iter()
                .zip(&state.err_s[i])
                .map(|(a, e)| a + e)
                .collect();
            let qm = compressor.compress(&carry, rng);
            let dense = qm.to_dense();
            for (c, qv) in carry.iter_mut().zip(&dense) {
                *c -= qv;
            }
            state.err_s[i] = carry;
            smsgs.push(qm);
        }
        let own: Vec<Vec<f32>> = smsgs.iter().map(|qm| qm.to_dense()).collect();
        let inbox = net.exchange(smsgs);
        for (i, arrived) in inbox.into_iter().enumerate() {
            for (sender, _qm) in arrived {
                let w = (gamma as f64 * Transport::mixing(net).weight(i, sender)) as f32;
                let qd = &own[sender];
                for k in 0..state.s[i].len() {
                    state.s[i][k] += w * (qd[k] - own[i][k]);
                }
            }
        }
        let g_new: Vec<Vec<f32>> = d.iter().enumerate().map(|(i, di)| q.grad(i, di)).collect();
        for i in 0..m {
            for ((sk, gn), go) in state.s[i]
                .iter_mut()
                .zip(&g_new[i])
                .zip(&state.prev_grad[i])
            {
                *sk += gn - go;
            }
        }
        state.prev_grad = g_new;
    }
}

fn init_d(m: usize, dim: usize) -> Vec<Vec<f32>> {
    (0..m)
        .map(|i| (0..dim).map(|k| (i * 3 + k) as f32 * 0.05).collect())
        .collect()
}

fn bits(rows: &[Vec<f32>]) -> Vec<Vec<u32>> {
    rows.iter()
        .map(|r| r.iter().map(|x| x.to_bits()).collect())
        .collect()
}

#[test]
fn rewritten_inner_loop_is_bit_identical_to_reference() {
    let m = 6;
    let dim = 37; // odd, exercises tie/threshold paths
    let q = Quad::build(m, dim, 11);
    for (spec, topo) in [
        ("topk:0.2", Topology::Ring),
        ("topk:0.5", Topology::TwoHopRing),
        ("randk:0.3", Topology::Ring),
        ("qsgd:16", Topology::Ring),
        ("none", Topology::Exponential),
    ] {
        let comp = parse(spec).unwrap();
        let cfg = InnerConfig { eta: 0.12, gamma: 0.55, k_steps: 25 };

        let mut net_new = Network::new(Graph::build(topo, m));
        let mut rng_new = Rng::new(77);
        let mut st_new = InnerState::new(&net_new, dim);
        let mut d_new = init_d(m, dim);
        run_inner(&cfg, &mut net_new, comp.as_ref(), &mut rng_new, &mut st_new, &mut d_new, |i, z| {
            q.grad(i, z)
        });

        let mut net_ref = Network::new(Graph::build(topo, m));
        let mut rng_ref = Rng::new(77);
        let mut st_ref = RefState::new(&net_ref, dim);
        st_ref.bootstrap(&q, &init_d(m, dim));
        let mut d_ref = init_d(m, dim);
        reference_inner(
            &cfg,
            &mut net_ref,
            comp.as_ref(),
            &mut rng_ref,
            &mut st_ref,
            &mut d_ref,
            &q,
        );

        assert_eq!(bits(&d_new), bits(&d_ref), "{spec}: iterates diverged");
        assert_eq!(bits(&st_new.s.to_vecs()), bits(&st_ref.s), "{spec}: trackers diverged");
        for i in 0..m {
            assert_eq!(
                st_new.d_ref[i].hat.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                st_ref.d_ref[i].hat.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "{spec}: d̂ diverged at node {i}"
            );
        }
        assert_eq!(
            net_new.ledger.total_bytes, net_ref.ledger.total_bytes,
            "{spec}: byte accounting diverged"
        );
        assert_eq!(net_new.ledger.messages, net_ref.ledger.messages);
        assert_eq!(net_new.ledger.gossip_rounds, net_ref.ledger.gossip_rounds);
        // Both RNGs consumed exactly the same draw sequence.
        assert_eq!(rng_new.next_u64(), rng_ref.next_u64(), "{spec}: rng drift");
    }
}

#[test]
fn rewritten_naive_loop_is_bit_identical_to_reference() {
    let m = 5;
    let dim = 23;
    let q = Quad::build(m, dim, 13);
    for spec in ["topk:0.3", "qsgd:8", "none"] {
        let comp = parse(spec).unwrap();
        let cfg = InnerConfig { eta: 0.1, gamma: 0.5, k_steps: 20 };

        let mut net_new = Network::new(Graph::build(Topology::Ring, m));
        let mut rng_new = Rng::new(5);
        let mut st_new = InnerState::new(&net_new, dim);
        let mut d_new = init_d(m, dim);
        run_inner_naive(
            &cfg,
            &mut net_new,
            comp.as_ref(),
            &mut rng_new,
            &mut st_new,
            &mut d_new,
            |i, z| q.grad(i, z),
        );

        let mut net_ref = Network::new(Graph::build(Topology::Ring, m));
        let mut rng_ref = Rng::new(5);
        let mut st_ref = RefState::new(&net_ref, dim);
        st_ref.bootstrap(&q, &init_d(m, dim));
        let mut d_ref = init_d(m, dim);
        reference_inner_naive(
            &cfg,
            &mut net_ref,
            comp.as_ref(),
            &mut rng_ref,
            &mut st_ref,
            &mut d_ref,
            &q,
        );

        assert_eq!(bits(&d_new), bits(&d_ref), "{spec}: iterates diverged");
        assert_eq!(bits(&st_new.s.to_vecs()), bits(&st_ref.s), "{spec}: trackers diverged");
        assert_eq!(net_new.ledger.total_bytes, net_ref.ledger.total_bytes);
        assert_eq!(rng_new.next_u64(), rng_ref.next_u64(), "{spec}: rng drift");
    }
}

/// Every slice kernel in `linalg::kernels` equals the textbook inline
/// formulation bit-for-bit, at both dtypes: per-element loops for the
/// elementwise ops, strict left-to-right f64 folds for the reductions.
/// The chunked zip layout inside the kernels is a compiler hint for the
/// autovectorizer, never a numeric change — this test is the proof.
fn kernels_vs_inline<S: Scalar>(seed: u64) {
    let mut rng = Rng::new(seed);
    // Odd length: exercises the chunk-remainder path in zip2/zip3.
    let n = 37;
    let mut draw = |n: usize| -> Vec<S> {
        (0..n)
            .map(|_| S::from_f64(rng.normal_f32(0.0, 1.0) as f64))
            .collect()
    };
    let a = draw(n);
    let b = draw(n);
    let c = draw(n);
    let alpha = S::from_f64(0.37);
    let w = S::from_f64(-0.61);
    let eq = |x: &[S], y: &[S], what: &str| {
        assert_eq!(x.len(), y.len(), "{}: {what} length", S::NAME);
        for (k, (u, v)) in x.iter().zip(y).enumerate() {
            assert_eq!(
                u.to_f64().to_bits(),
                v.to_f64().to_bits(),
                "{}: {what} diverges at [{k}] ({u:?} vs {v:?})",
                S::NAME
            );
        }
    };

    // axpy: y += alpha * x
    let (mut yk, mut yi) = (b.clone(), b.clone());
    kernels::axpy(alpha, &a, &mut yk);
    for (y, &x) in yi.iter_mut().zip(&a) {
        *y += alpha * x;
    }
    eq(&yk, &yi, "axpy");

    // scale: x *= alpha
    let (mut xk, mut xi) = (a.clone(), a.clone());
    kernels::scale(alpha, &mut xk);
    for x in xi.iter_mut() {
        *x *= alpha;
    }
    eq(&xk, &xi, "scale");

    // sub / sub_assign / add_assign
    let mut ok = vec![S::ZERO; n];
    kernels::sub(&a, &b, &mut ok);
    let oi: Vec<S> = a.iter().zip(&b).map(|(&x, &y)| x - y).collect();
    eq(&ok, &oi, "sub");
    let (mut sk, mut si) = (a.clone(), a.clone());
    kernels::sub_assign(&mut sk, &b);
    for (x, &y) in si.iter_mut().zip(&b) {
        *x -= y;
    }
    eq(&sk, &si, "sub_assign");
    let (mut ak, mut ai) = (a.clone(), a.clone());
    kernels::add_assign(&mut ak, &b);
    for (x, &y) in ai.iter_mut().zip(&b) {
        *x += y;
    }
    eq(&ak, &ai, "add_assign");

    // descent: x -= eta * g
    let (mut dk, mut di) = (a.clone(), a.clone());
    kernels::descent(alpha, &b, &mut dk);
    for (x, &g) in di.iter_mut().zip(&b) {
        *x -= alpha * g;
    }
    eq(&dk, &di, "descent");

    // weighted_diff_add: out += w * (a - b)
    let (mut gk, mut gi) = (c.clone(), c.clone());
    kernels::weighted_diff_add(w, &a, &b, &mut gk);
    for ((o, &x), &y) in gi.iter_mut().zip(&a).zip(&b) {
        *o += w * (x - y);
    }
    eq(&gk, &gi, "weighted_diff_add");

    // add_diff: s += new - old
    let (mut tk, mut ti) = (c.clone(), c.clone());
    kernels::add_diff(&a, &b, &mut tk);
    for ((s, &new), &old) in ti.iter_mut().zip(&a).zip(&b) {
        *s += new - old;
    }
    eq(&tk, &ti, "add_diff");

    // ref_mix_term: out += gamma * (hat_w - sw * hat)
    let (mut rk, mut ri) = (c.clone(), c.clone());
    kernels::ref_mix_term(alpha, w, &a, &b, &mut rk);
    for ((o, &hw), &h) in ri.iter_mut().zip(&a).zip(&b) {
        *o += alpha * (hw - w * h);
    }
    eq(&rk, &ri, "ref_mix_term");

    // ema_diff: u = (1-theta)*u + theta*(a - b)
    let (mut uk, mut ui) = (c.clone(), c.clone());
    kernels::ema_diff(alpha, &a, &b, &mut uk);
    let omt = S::ONE - alpha;
    for ((u, &x), &y) in ui.iter_mut().zip(&a).zip(&b) {
        *u = omt * *u + alpha * (x - y);
    }
    eq(&uk, &ui, "ema_diff");

    // dense_add_scaled: target += w * v
    let (mut pk, mut pi) = (c.clone(), c.clone());
    kernels::dense_add_scaled(w, &a, &mut pk);
    for (t, &x) in pi.iter_mut().zip(&a) {
        *t += w * x;
    }
    eq(&pk, &pi, "dense_add_scaled");

    // scatter_add_scaled over an in-range strictly increasing index set
    let idx: Vec<u32> = (0..12).map(|j| j * 3 + 1).collect();
    let val = &a[..idx.len()];
    let (mut qk, mut qi) = (c.clone(), c.clone());
    kernels::scatter_add_scaled(w, &idx, val, &mut qk);
    for (&i, &x) in idx.iter().zip(val) {
        qi[i as usize] += w * x;
    }
    eq(&qk, &qi, "scatter_add_scaled");

    // dequant_add: target += codes[i] * scale
    let codes: Vec<i16> = (0..n).map(|_| rng.next_u64() as i16).collect();
    let (mut zk, mut zi) = (c.clone(), c.clone());
    kernels::dequant_add(alpha, &codes, &mut zk);
    for (t, &cd) in zi.iter_mut().zip(&codes) {
        *t += S::from_i16(cd) * alpha;
    }
    eq(&zk, &zi, "dequant_add");

    // Reductions: strict left-to-right f64 folds, bit-compared as f64.
    let dot_inline: f64 = a.iter().zip(&b).map(|(x, y)| x.to_f64() * y.to_f64()).sum();
    assert_eq!(kernels::dot(&a, &b).to_bits(), dot_inline.to_bits(), "{}: dot", S::NAME);
    let nsq_inline: f64 = a.iter().map(|x| x.to_f64() * x.to_f64()).sum();
    assert_eq!(kernels::norm2_sq(&a).to_bits(), nsq_inline.to_bits(), "{}: norm2_sq", S::NAME);
    let dsq_inline: f64 = a
        .iter()
        .zip(&b)
        .map(|(x, y)| (x.to_f64() - y.to_f64()).powi(2))
        .sum();
    assert_eq!(kernels::dist_sq(&a, &b).to_bits(), dsq_inline.to_bits(), "{}: dist_sq", S::NAME);
}

#[test]
fn kernels_match_inline_formulation_bitwise_at_both_dtypes() {
    kernels_vs_inline::<f32>(31);
    kernels_vs_inline::<f64>(32);
}
