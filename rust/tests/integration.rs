//! Integration tests over the REAL stack: AOT artifacts → PJRT runtime →
//! tasks → algorithms.  Requires `make artifacts` (the tiny presets) and
//! a `--features pjrt` build; the default offline build compiles this
//! file to nothing.

#![cfg(feature = "pjrt")]
// Test-side timing printout only (docs/LINT.md R1).
#![allow(clippy::disallowed_methods)]

use c2dfb::config::{Algorithm, ExperimentConfig};
use c2dfb::coordinator::{build_task, Runner};
use c2dfb::data::partition::Partition;
use c2dfb::runtime::{Arg, ArtifactRegistry};
use c2dfb::tasks::BilevelTask;
use c2dfb::topology::Topology;
use c2dfb::util::rng::Rng;

fn run_with_registry(
    reg: &ArtifactRegistry,
    cfg: &ExperimentConfig,
) -> anyhow::Result<c2dfb::metrics::RunMetrics> {
    Runner::new(cfg).registry(reg).run()
}

fn registry() -> ArtifactRegistry {
    ArtifactRegistry::open_default().expect("run `make artifacts` first")
}

#[test]
fn demo_affine_roundtrip() {
    let reg = registry();
    let oracle = reg.load("demo.affine").unwrap();
    let a: Vec<f32> = (0..64).map(|i| i as f32).collect();
    let b: Vec<f32> = (0..64).map(|i| (i % 8 == i / 8) as u8 as f32).collect(); // identity
    let out = oracle.call(&[Arg::Host(&a), Arg::Host(&b)]).unwrap();
    assert_eq!(out.len(), 1);
    // a @ I + 1 == a + 1
    for (got, want) in out[0].iter().zip(&a) {
        assert!((got - (want + 1.0)).abs() < 1e-5);
    }
}

#[test]
fn oracle_rejects_wrong_shapes() {
    let reg = registry();
    let oracle = reg.load("demo.affine").unwrap();
    let a = vec![0.0f32; 64];
    let short = vec![0.0f32; 5];
    assert!(oracle.call(&[Arg::Host(&a), Arg::Host(&short)]).is_err());
    assert!(oracle.call(&[Arg::Host(&a)]).is_err());
}

#[test]
fn registry_caches_compilations() {
    let reg = registry();
    let t0 = std::time::Instant::now();
    let _o1 = reg.load("coeff_tiny.eval").unwrap();
    let first = t0.elapsed();
    let t1 = std::time::Instant::now();
    let _o2 = reg.load("coeff_tiny.eval").unwrap();
    let second = t1.elapsed();
    assert!(second < first / 5, "cache miss? {first:?} vs {second:?}");
}

#[test]
fn unknown_artifact_is_a_clean_error() {
    let reg = registry();
    let err = match reg.load("coeff_tiny.not_a_thing") {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected error"),
    };
    assert!(err.contains("not in manifest"), "{err}");
}

/// The fully first-order hypergradient identity (paper Eq. 4) holds through
/// the REAL artifacts for the coeff task (closed form of ∇x g).
#[test]
fn coeff_tiny_hypergrad_consistency() {
    let reg = registry();
    let task = build_task(
        &reg,
        &ExperimentConfig {
            preset: "coeff_tiny".into(),
            nodes: 4,
            ..Default::default()
        },
    )
    .unwrap();
    let mut rng = Rng::new(3);
    let dx = task.dx();
    let x: Vec<f32> = (0..dx).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let y: Vec<f32> = (0..task.dy()).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let z: Vec<f32> = (0..task.dy()).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let lam = 4.0f32;
    let u = task.hypergrad(0, &x, &y, &z, lam).unwrap();
    // Closed form for coeff: u = λ exp(x) ⊙ (Σ_c y² − Σ_c z²).
    let c = task.dy() / dx;
    for f in 0..dx {
        let ry: f32 = (0..c).map(|j| y[f * c + j] * y[f * c + j]).sum();
        let rz: f32 = (0..c).map(|j| z[f * c + j] * z[f * c + j]).sum();
        let want = lam * x[f].exp() * (ry - rz);
        assert!(
            (u[f] - want).abs() < 1e-3 * (1.0 + want.abs()),
            "coord {f}: {} vs {want}",
            u[f]
        );
    }
}

/// Pallas and jnp artifact variants agree through PJRT end to end.
#[test]
fn pallas_vs_jnp_variants_agree_through_runtime() {
    let reg = registry();
    if !reg.has_preset("coeff_jnp") {
        eprintln!("skipping: coeff_jnp preset not built");
        return;
    }
    let mk = |preset: &str| {
        build_task(
            &reg,
            &ExperimentConfig { preset: preset.into(), nodes: 3, seed: 99, ..Default::default() },
        )
        .unwrap()
    };
    let tp = mk("coeff");
    let tj = mk("coeff_jnp");
    let mut rng = Rng::new(7);
    let x: Vec<f32> = (0..tp.dx()).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let y: Vec<f32> = (0..tp.dy()).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    // Same seed ⇒ identical data shards ⇒ oracle outputs must agree.
    let gp = tp.inner_z_grad(0, &x, &y).unwrap();
    let gj = tj.inner_z_grad(0, &x, &y).unwrap();
    let diff: f64 = gp
        .iter()
        .zip(&gj)
        .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    let norm: f64 = gj.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
    assert!(diff < 1e-3 * (1.0 + norm), "pallas vs jnp grad diff {diff} (norm {norm})");
}

#[test]
fn c2dfb_learns_on_tiny_coeff_end_to_end() {
    let reg = registry();
    let cfg = ExperimentConfig {
        preset: "coeff_tiny".into(),
        algorithm: Algorithm::C2dfb,
        nodes: 6,
        rounds: 25,
        inner_steps: 10,
        eta_out: 0.2,
        eta_in: 0.2,
        eval_every: 5,
        partition: Partition::Heterogeneous { h: 0.8 },
        ..Default::default()
    };
    let m = run_with_registry(&reg, &cfg).unwrap();
    let first = m.trace.first().unwrap();
    let last = m.trace.last().unwrap();
    assert!(
        last.accuracy > first.accuracy + 0.2,
        "acc {} -> {}",
        first.accuracy,
        last.accuracy
    );
    assert!(last.loss.is_finite());
    assert!(m.ledger.total_bytes > 0);
    assert_eq!(m.oracles.second_order, 0);
}

#[test]
fn all_algorithms_run_on_tiny_hyperrep() {
    let reg = registry();
    for algo in [Algorithm::C2dfb, Algorithm::C2dfbNc, Algorithm::Madsbo, Algorithm::Mdbo] {
        let cfg = ExperimentConfig {
            preset: "hyperrep_tiny".into(),
            algorithm: algo,
            nodes: 4,
            rounds: 4,
            inner_steps: 5,
            eta_out: 0.05,
            eta_in: 0.05,
            gamma_out: 0.3,
            gamma_in: 0.3,
            eval_every: 2,
            compressor: "topk:0.3".into(),
            ..Default::default()
        };
        let m =
            run_with_registry(&reg, &cfg).unwrap_or_else(|e| panic!("{}: {e:?}", algo.name()));
        assert!(m.final_point().unwrap().loss.is_finite(), "{} diverged", algo.name());
    }
}

#[test]
fn topologies_and_compressors_matrix_smoke() {
    let reg = registry();
    for topo in ["ring", "2hop", "er:0.5", "complete", "star"] {
        for comp in ["topk:0.2", "randk:0.3", "qsgd:16", "none"] {
            let cfg = ExperimentConfig {
                preset: "coeff_tiny".into(),
                nodes: 5,
                rounds: 2,
                inner_steps: 3,
                eta_out: 0.1,
                eta_in: 0.1,
                topology: Topology::parse(topo, 1).unwrap(),
                compressor: comp.into(),
                eval_every: 2,
                ..Default::default()
            };
            let m = run_with_registry(&reg, &cfg)
                .unwrap_or_else(|e| panic!("{topo}/{comp}: {e:?}"));
            assert!(m.final_point().unwrap().loss.is_finite(), "{topo}/{comp}");
        }
    }
}

/// Compression must reduce inner-loop bytes on the real task.
#[test]
fn compressed_run_sends_fewer_bytes_than_dense() {
    let reg = registry();
    let base = ExperimentConfig {
        preset: "coeff_tiny".into(),
        nodes: 5,
        rounds: 3,
        inner_steps: 5,
        eta_out: 0.1,
        eta_in: 0.1,
        eval_every: 3,
        ..Default::default()
    };
    let mut dense_cfg = base.clone();
    dense_cfg.compressor = "none".into();
    let dense = run_with_registry(&reg, &dense_cfg).unwrap();
    let mut topk_cfg = base;
    topk_cfg.compressor = "topk:0.1".into();
    let topk = run_with_registry(&reg, &topk_cfg).unwrap();
    assert!(
        topk.ledger.total_bytes * 2 < dense.ledger.total_bytes,
        "{} vs {}",
        topk.ledger.total_bytes,
        dense.ledger.total_bytes
    );
}

/// Determinism: identical config ⇒ identical traces (bytes and losses).
#[test]
fn runs_are_deterministic() {
    let reg = registry();
    let cfg = ExperimentConfig {
        preset: "coeff_tiny".into(),
        nodes: 4,
        rounds: 5,
        inner_steps: 4,
        eta_out: 0.1,
        eta_in: 0.1,
        eval_every: 2,
        seed: 1234,
        ..Default::default()
    };
    let a = run_with_registry(&reg, &cfg).unwrap();
    let b = run_with_registry(&reg, &cfg).unwrap();
    assert_eq!(a.ledger.total_bytes, b.ledger.total_bytes);
    for (pa, pb) in a.trace.iter().zip(&b.trace) {
        assert_eq!(pa.loss.to_bits(), pb.loss.to_bits(), "round {}", pa.round);
        assert_eq!(pa.accuracy.to_bits(), pb.accuracy.to_bits());
    }
}

/// Heterogeneous split changes the data each node sees but the stack stays
/// stable and still learns.
#[test]
fn heterogeneous_vs_iid_both_learn() {
    let reg = registry();
    for part in [Partition::Iid, Partition::Heterogeneous { h: 0.8 }] {
        let cfg = ExperimentConfig {
            preset: "coeff_tiny".into(),
            nodes: 6,
            rounds: 20,
            inner_steps: 8,
            eta_out: 0.2,
            eta_in: 0.2,
            partition: part,
            eval_every: 5,
            ..Default::default()
        };
        let m = run_with_registry(&reg, &cfg).unwrap();
        let last = m.trace.last().unwrap();
        assert!(last.accuracy > 0.5, "{}: acc {}", part.name(), last.accuracy);
    }
}
