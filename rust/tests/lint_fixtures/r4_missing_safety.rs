// R4 bad fixture: an unsafe block with no SAFETY argument.
pub fn read(p: *const u8) -> u8 {
    unsafe { *p }
}
