// R2 bad fixture: an unordered map in a deterministic module.
pub fn sum(m: &std::collections::HashMap<u32, f32>) -> f32 {
    m.values().sum()
}
