// R5 bad fixture: a nondeterministic RNG source.
pub fn roll() -> u64 {
    let r = thread_rng();
    let _ = r;
    0
}
