// R6 bad fixture: a wall-clock key literal at a trace emit site.
pub fn emit(out: &mut String) {
    out.push_str("\"wall_time_s\":");
}
