// R3 bad fixture: a panicking slice index on untrusted bytes.
pub fn first(b: &[u8]) -> u8 {
    b[0]
}
