// R1 bad fixture: a wall-clock read in replayable code (docs/LINT.md).
pub fn stamp_now() -> u64 {
    let t0 = std::time::Instant::now();
    let _ = t0;
    0
}
