//! Adversarial-transport tests: the compressed inner loop against
//! transports that violate, bend, or stress the [`Transport`] delivery
//! contract.  The required behavior (docs/SCALE.md) is *resync or fail
//! loudly, never silent divergence*:
//!
//! * duplicated or out-of-order delivery — a contract violation that
//!   would silently corrupt the reference-point accumulators — must
//!   panic with a diagnostic, not fold;
//! * a graph-epoch bump observed mid-exchange (cross-epoch reordering)
//!   must drop the in-flight round and resync the reference points;
//! * asymmetric partitions and total blackouts are *legal* hostile
//!   regimes: runs stay finite, deterministic, and locally progressing;
//! * a crashed (masked-out) node neither sends nor steps while dark and
//!   rejoins seamlessly because passive folding kept its reference
//!   points in sync.
//!
//! Every wrapper delegates real accounting to the synchronous
//! [`Network`] and then tampers with what the algorithm sees.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

use c2dfb::collective::{Inbox, Network, Transport};
use c2dfb::compress::{parse, Compressed};
use c2dfb::metrics::CommLedger;
use c2dfb::optim::{run_inner, InnerConfig, InnerState};
use c2dfb::topology::{Graph, Topology};
use c2dfb::util::rng::Rng;

/// What a [`HostileNet`] does to each receiver's delivered-sender list
/// after the honest exchange has run (bytes already paid).
#[derive(Clone, Copy)]
enum Tamper {
    /// Deliver honestly.
    None,
    /// Hand `receiver` the first delivered sender twice.
    DuplicateFirst { receiver: usize },
    /// Hand `receiver` its senders in descending order.
    ReverseOrder { receiver: usize },
    /// Silently eat every message from `from` to `to` (one direction
    /// only — the reverse link stays up).
    DropDirected { from: usize, to: usize },
    /// Total blackout: every list empty, every inbox empty.
    DropAll,
}

/// A transport that performs honest synchronous exchanges and then
/// tampers with the delivery report; optionally bumps its graph epoch on
/// every `bump_every`-th exchange to simulate a topology switch racing
/// the in-flight messages.
struct HostileNet {
    inner: Network,
    tamper: Tamper,
    epoch: u64,
    bump_every: usize,
    exchanges: usize,
}

impl HostileNet {
    fn new(m: usize, tamper: Tamper) -> HostileNet {
        HostileNet {
            inner: Network::new(Graph::build(Topology::Ring, m)),
            tamper,
            epoch: 0,
            bump_every: 0,
            exchanges: 0,
        }
    }

    fn tamper_delivered(&self, delivered: &mut [Vec<usize>]) {
        match self.tamper {
            Tamper::None => {}
            Tamper::DuplicateFirst { receiver } => {
                if let Some(&first) = delivered[receiver].first() {
                    delivered[receiver].insert(0, first);
                }
            }
            Tamper::ReverseOrder { receiver } => delivered[receiver].reverse(),
            Tamper::DropDirected { from, to } => delivered[to].retain(|&s| s != from),
            Tamper::DropAll => {
                for list in delivered.iter_mut() {
                    list.clear();
                }
            }
        }
    }

    fn tick_epoch(&mut self) {
        self.exchanges += 1;
        if self.bump_every > 0 && self.exchanges % self.bump_every == 0 {
            self.epoch += 1;
        }
    }
}

impl Transport for HostileNet {
    fn m(&self) -> usize {
        self.inner.m()
    }

    fn weight(&self, i: usize, j: usize) -> f64 {
        Transport::weight(&self.inner, i, j)
    }

    fn ledger(&self) -> &CommLedger {
        Transport::ledger(&self.inner)
    }

    fn set_active(&mut self, mask: Option<Arc<Vec<bool>>>) {
        self.inner.set_active(mask)
    }

    fn active(&self) -> Option<&[bool]> {
        Transport::active(&self.inner)
    }

    fn exchange(&mut self, msgs: Vec<Compressed>) -> Inbox<Compressed> {
        let mut inbox = self.inner.exchange(msgs);
        if matches!(self.tamper, Tamper::DropAll) {
            for ib in inbox.iter_mut() {
                ib.clear();
            }
        }
        self.tick_epoch();
        inbox
    }

    fn exchange_dense(&mut self, vecs: &[Vec<f32>]) -> Inbox<Vec<f32>> {
        let mut inbox = self.inner.exchange_dense(vecs);
        if matches!(self.tamper, Tamper::DropAll) {
            for ib in inbox.iter_mut() {
                ib.clear();
            }
        }
        self.tick_epoch();
        inbox
    }

    fn exchange_indices(&mut self, bytes: &[usize], delivered: &mut Vec<Vec<usize>>) {
        self.inner.exchange_indices(bytes, delivered);
        self.tamper_delivered(delivered);
        self.tick_epoch();
    }

    fn graph_epoch(&self) -> u64 {
        self.epoch
    }
}

const M: usize = 6;
const DIM: usize = 8;

fn targets(seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..M).map(|_| (0..DIM).map(|_| rng.normal_f32(0.0, 1.0)).collect()).collect()
}

/// Run `steps` inner steps of the refpoint protocol (quadratic oracle
/// ∇r_i(d) = d − t_i) over `net`, returning the final per-node iterates.
fn run_protocol<T: Transport>(net: &mut T, steps: usize, seed: u64) -> Vec<Vec<f32>> {
    let cfg = InnerConfig { eta: 0.3, gamma: 0.6, k_steps: steps };
    let q = parse("topk:0.5").unwrap();
    let mut rng = Rng::new(seed ^ 0xAD5E);
    let mut state = InnerState::new(net, DIM);
    let t = targets(seed);
    let mut d: Vec<Vec<f32>> = vec![vec![0.0; DIM]; M];
    run_inner(&cfg, net, q.as_ref(), &mut rng, &mut state, &mut d, |i, di| {
        di.iter().zip(&t[i]).map(|(x, ti)| x - ti).collect()
    });
    d
}

fn bits(rows: &[Vec<f32>]) -> Vec<Vec<u32>> {
    rows.iter().map(|r| r.iter().map(|x| x.to_bits()).collect()).collect()
}

fn all_finite(rows: &[Vec<f32>]) -> bool {
    rows.iter().all(|r| r.iter().all(|x| x.is_finite()))
}

/// Duplicated delivery must panic with the contract diagnostic — folding
/// the same residual twice would corrupt the accumulators silently.
#[test]
fn duplicated_delivery_fails_loudly() {
    let err = catch_unwind(AssertUnwindSafe(|| {
        let mut net = HostileNet::new(M, Tamper::DuplicateFirst { receiver: 2 });
        run_protocol(&mut net, 4, 7);
    }))
    .expect_err("a duplicating transport must not be folded silently");
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(
        msg.contains("transport contract violated"),
        "panic lacked the contract diagnostic: {msg:?}"
    );
}

/// Out-of-order delivery is the same contract violation and must be
/// refused just as loudly.
#[test]
fn out_of_order_delivery_fails_loudly() {
    let err = catch_unwind(AssertUnwindSafe(|| {
        let mut net = HostileNet::new(M, Tamper::ReverseOrder { receiver: 0 });
        run_protocol(&mut net, 4, 7);
    }))
    .expect_err("an order-scrambling transport must not be folded silently");
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(
        msg.contains("transport contract violated"),
        "panic lacked the contract diagnostic: {msg:?}"
    );
}

/// Cross-epoch reordering: when the graph epoch ticks while messages are
/// in flight, the round is dropped and the reference points resync.  The
/// run must complete, stay finite, be deterministic, and actually differ
/// from the clean run (the dropped rounds are observable, not papered
/// over).
#[test]
fn epoch_flap_mid_exchange_resyncs_and_stays_finite() {
    let clean = {
        let mut net = HostileNet::new(M, Tamper::None);
        run_protocol(&mut net, 6, 11)
    };
    let run_flapping = || {
        let mut net = HostileNet::new(M, Tamper::None);
        net.bump_every = 3; // every 3rd exchange lands in a new epoch
        run_protocol(&mut net, 6, 11)
    };
    let a = run_flapping();
    let b = run_flapping();
    assert!(all_finite(&a), "epoch flapping produced non-finite iterates");
    assert_eq!(bits(&a), bits(&b), "dropped-round handling must be deterministic");
    assert_ne!(
        bits(&a),
        bits(&clean),
        "flapped run should visibly drop rounds, not silently equal the clean run"
    );
}

/// An asymmetric partition (0 → 1 dead, 1 → 0 alive) is a legal hostile
/// regime: ascending delivery is preserved, so the run completes finite
/// and deterministic, and the fault visibly bends the trajectory.
#[test]
fn asymmetric_partition_is_finite_and_deterministic() {
    let clean = {
        let mut net = HostileNet::new(M, Tamper::None);
        run_protocol(&mut net, 6, 13)
    };
    let run_cut = || {
        let mut net = HostileNet::new(M, Tamper::DropDirected { from: 0, to: 1 });
        run_protocol(&mut net, 6, 13)
    };
    let a = run_cut();
    let b = run_cut();
    assert!(all_finite(&a), "asymmetric partition produced non-finite iterates");
    assert_eq!(bits(&a), bits(&b), "partitioned run must be deterministic");
    assert_ne!(bits(&a), bits(&clean), "a dead link must be observable in the iterates");
}

/// Total blackout: every node pays its sends but nothing arrives.  The
/// run degrades to damped local descent (the uncoupled mix term
/// `−γ·sw·d̂` pulls toward the reference origin, so nodes settle at a
/// biased point between 0 and their local target) — finite,
/// deterministic, strictly closer to the local targets than the start,
/// and the ledger still charges the senders.
#[test]
fn zero_delivery_degrades_to_local_descent() {
    let mut net = HostileNet::new(M, Tamper::DropAll);
    let d = run_protocol(&mut net, 8, 17);
    assert!(all_finite(&d), "blackout produced non-finite iterates");
    assert!(net.ledger().total_bytes > 0, "senders must still pay under a blackout");
    let t = targets(17);
    for i in 0..M {
        let dist_sq: f64 = d[i]
            .iter()
            .zip(&t[i])
            .map(|(x, ti)| (*x as f64 - *ti as f64).powi(2))
            .sum();
        let init_sq: f64 = t[i].iter().map(|ti| (*ti as f64).powi(2)).sum();
        assert!(
            dist_sq < 0.8 * init_sq.max(1e-6),
            "node {i} made no local progress: {dist_sq} vs initial {init_sq}"
        );
        assert!(
            d[i].iter().any(|&x| x != 0.0),
            "node {i} never moved — blackout should not freeze local descent"
        );
    }
    // And the blackout run is bit-reproducible.
    let mut net2 = HostileNet::new(M, Tamper::DropAll);
    let d2 = run_protocol(&mut net2, 8, 17);
    assert_eq!(bits(&d), bits(&d2));
}

/// Crash and rejoin via the sampling mask: a dark node neither sends nor
/// steps (its iterate is frozen exactly), and after rejoining, the run
/// continues finite and deterministic — passive folding kept its
/// reference points consistent, so no resync is needed.
#[test]
fn crashed_node_freezes_then_rejoins_cleanly() {
    let crashed = 2usize;
    let run_with_crash = || {
        let mut net = Network::new(Graph::build(Topology::Ring, M));
        let cfg = InnerConfig { eta: 0.3, gamma: 0.6, k_steps: 3 };
        let q = parse("topk:0.5").unwrap();
        let mut rng = Rng::new(0xC0FFEE);
        let mut state = InnerState::new(&net, DIM);
        let t = targets(19);
        let mut d: Vec<Vec<f32>> = vec![vec![0.0; DIM]; M];
        let mut run_k = |net: &mut Network, state: &mut InnerState, d: &mut [Vec<f32>], rng: &mut Rng| {
            run_inner(&cfg, net, q.as_ref(), rng, state, d, |i, di| {
                di.iter().zip(&t[i]).map(|(x, ti)| x - ti).collect()
            });
        };
        // Healthy warm-up.
        run_k(&mut net, &mut state, &mut d, &mut rng);
        // Crash: node `crashed` goes dark for a stretch.
        let mut mask = vec![true; M];
        mask[crashed] = false;
        net.set_active(Some(Arc::new(mask)));
        let frozen = d[crashed].clone();
        run_k(&mut net, &mut state, &mut d, &mut rng);
        assert_eq!(
            bits(&[frozen]),
            bits(&[d[crashed].clone()]),
            "a dark node's iterate must be frozen exactly"
        );
        // Rejoin: full participation again.
        net.set_active(None);
        run_k(&mut net, &mut state, &mut d, &mut rng);
        d
    };
    let a = run_with_crash();
    let b = run_with_crash();
    assert!(all_finite(&a), "crash/rejoin produced non-finite iterates");
    assert_eq!(bits(&a), bits(&b), "crash/rejoin must be deterministic");
}
