//! Telemetry acceptance tests (docs/OBS.md): the deterministic JSONL
//! trace is byte-identical at any pool width; attaching the trace and
//! profiler sinks never perturbs a run (no RNG consumption, no comm-
//! ledger mutation); every produced line validates against the schema;
//! and the `c2dfb trace` summary has a per-phase row for each
//! algorithm × phase pair a run exercised.

use c2dfb::config::{Algorithm, ExperimentConfig};
use c2dfb::coordinator::sweep::{self, Cell, ExecOpts, SweepSpec, TaskRef};
use c2dfb::obs::{self, Console};
use c2dfb::tasks::QuadraticTask;

fn exec(trace: bool, profile: bool, jobs: usize) -> ExecOpts {
    ExecOpts { jobs, console: Console::quiet(), trace, profile }
}

/// The tentpole determinism contract: the same grid traced at
/// parallelism 1, 2 and max produces byte-identical JSONL — per cell
/// (checked by `diff_outcomes`) and for the concatenated file.
#[test]
fn traces_byte_identical_at_parallelism_1_2_and_max() {
    let spec = SweepSpec::tiny();
    let grid = sweep::expand(&spec).expect("tiny grid expands");
    let tasks = grid.slots();
    let o1 = sweep::run_cells_slots(&grid.cells, &tasks, None, &exec(true, false, 1));
    assert!(o1.iter().all(|o| o.result.is_ok()), "tiny grid must be clean");
    assert!(
        o1.iter().all(|o| o.trace.as_ref().is_some_and(|t| !t.is_empty())),
        "every traced cell must produce a JSONL chunk"
    );
    let t1 = sweep::concat_traces(&o1);
    let lines = obs::validate_trace(&t1).expect("trace must validate line-by-line");
    assert!(lines > grid.cells.len(), "at least one line per cell plus spans");
    for jobs in [2, 0] {
        let o = sweep::run_cells_slots(&grid.cells, &tasks, None, &exec(true, false, jobs));
        assert_eq!(
            sweep::diff_outcomes(&o1, &o),
            None,
            "per-cell results AND trace chunks must be bit-identical at jobs={jobs}"
        );
        assert_eq!(
            t1,
            sweep::concat_traces(&o),
            "concatenated trace bytes must be identical at jobs={jobs}"
        );
    }
}

/// Observer-effect guard: runs with both sinks attached are bit-identical
/// to untraced runs — tracing consumes no RNG and never touches the
/// communication ledger.
#[test]
fn tracing_never_perturbs_results() {
    let spec = SweepSpec::tiny();
    let grid = sweep::expand(&spec).expect("tiny grid expands");
    let tasks = grid.slots();
    let plain = sweep::run_cells_slots(&grid.cells, &tasks, None, &exec(false, false, 2));
    let traced = sweep::run_cells_slots(&grid.cells, &tasks, None, &exec(true, true, 2));
    for (a, b) in plain.iter().zip(&traced) {
        assert!(a.trace.is_none() && a.profile.is_none());
        assert!(b.trace.is_some(), "{}: trace sink was requested", b.id);
        assert!(b.profile.is_some(), "{}: profiler was requested", b.id);
        let (ma, mb) = (a.metrics().unwrap(), b.metrics().unwrap());
        assert_eq!(ma.ledger.total_bytes, mb.ledger.total_bytes, "{}", a.id);
        assert_eq!(ma.ledger.messages, mb.ledger.messages, "{}", a.id);
        assert_eq!(ma.ledger.gossip_rounds, mb.ledger.gossip_rounds, "{}", a.id);
        assert_eq!(ma.oracles.first_order, mb.oracles.first_order, "{}", a.id);
        assert_eq!(ma.oracles.second_order, mb.oracles.second_order, "{}", a.id);
        let la: Vec<u64> = ma.trace.iter().map(|p| p.loss.to_bits()).collect();
        let lb: Vec<u64> = mb.trace.iter().map(|p| p.loss.to_bits()).collect();
        assert_eq!(la, lb, "{}: traced losses must be bit-identical", a.id);
    }
}

/// `c2dfb trace` renders a per-phase cost row for every algorithm ×
/// phase pair the runs exercised: C²DFB's scoped inner loops, MADSBO's
/// HVP sub-solver, MDBO's Neumann series.
#[test]
fn summary_covers_every_algorithm_phase_pair() {
    let task: QuadraticTask = QuadraticTask::generate(4, 6, 0.5, 21);
    let mut cells = Vec::new();
    for algo in [Algorithm::C2dfb, Algorithm::Madsbo, Algorithm::Mdbo] {
        let cfg = ExperimentConfig {
            algorithm: algo,
            nodes: 4,
            rounds: 3,
            inner_steps: 3,
            eta_out: 0.1,
            eta_in: 0.2,
            eval_every: 1,
            ..ExperimentConfig::default()
        };
        cells.push(Cell {
            id: format!("obs+{}", algo.name()),
            cfg,
            task: TaskRef::Shared(0),
        });
    }
    let outcomes = sweep::run_cells_with(&cells, &[&task], None, &exec(true, false, 1));
    assert!(outcomes.iter().all(|o| o.result.is_ok()));
    let text = sweep::concat_traces(&outcomes);
    let s = obs::summarize(&text).expect("trace must summarize");
    assert_eq!(s.runs, 3);
    assert!(s.evals > 0);
    let pairs = s.phase_pairs();
    let has = |algo: &str, scope: &str, phase: &str| {
        pairs.iter().any(|(a, s, p)| a == algo && s == scope && p == phase)
    };
    // C²DFB: outer mixing + hypergradient, and both scoped inner loops
    // paying compression and exchanges.
    for scope in ["inner_y", "inner_z"] {
        for phase in ["mix", "compress", "exchange", "grad", "tracker"] {
            assert!(has("c2dfb", scope, phase), "missing c2dfb/{scope}/{phase}");
        }
    }
    for phase in ["mix", "hypergrad", "eval"] {
        assert!(has("c2dfb", "outer", phase), "missing c2dfb/outer/{phase}");
    }
    // Baselines: coarse second-order sections attributed to their phases.
    for phase in ["lower", "hvp", "hypergrad", "mix"] {
        assert!(has("madsbo", "outer", phase), "missing madsbo/outer/{phase}");
    }
    for phase in ["lower", "neumann", "hypergrad", "mix"] {
        assert!(has("mdbo", "outer", phase), "missing mdbo/outer/{phase}");
    }
    let rendered = s.render();
    for needle in ["c2dfb", "madsbo", "mdbo", "hvp", "neumann", "per-node sent bytes"] {
        assert!(rendered.contains(needle), "summary table missing {needle:?}");
    }
}

/// The deterministic sink never carries wall-clock data, even when the
/// profiler runs alongside it in the same cells.
#[test]
fn profiled_trace_stays_wall_clock_free() {
    let task: QuadraticTask = QuadraticTask::generate(4, 6, 0.5, 22);
    let cfg = ExperimentConfig {
        algorithm: Algorithm::C2dfb,
        nodes: 4,
        rounds: 2,
        inner_steps: 3,
        eta_out: 0.1,
        eta_in: 0.2,
        eval_every: 1,
        ..ExperimentConfig::default()
    };
    let cells = vec![Cell { id: "prof".into(), cfg, task: TaskRef::Shared(0) }];
    let outcomes = sweep::run_cells_with(&cells, &[&task], None, &exec(true, true, 1));
    let trace = outcomes[0].trace.as_ref().expect("trace requested");
    assert!(!trace.contains("wall"), "profiler data leaked into the trace");
    obs::validate_trace(trace).expect("trace validates with profiler attached");
    let profile = outcomes[0].profile.as_ref().expect("profile requested");
    assert!(profile.contains("nondeterministic"));
    assert!(profile.contains("inner_y/"), "profile must attribute inner-loop phases");
}
