//! Property-based tests on the coordinator invariants, via the in-repo
//! `util::prop` harness (proptest is not available offline).  Each property
//! runs over a deterministic seed sequence; failures print a replayable
//! seed.

use c2dfb::compress::{parse, Compressor};
use c2dfb::data::partition::Partition;
use c2dfb::data::newsgroups_like;
use c2dfb::linalg;
use c2dfb::optim::RefPoint;
use c2dfb::topology::{Graph, MixingMatrix, Topology};
use c2dfb::util::prop::{check, ensure, ensure_close, Gen};
use c2dfb::util::rng::Rng;

fn random_topology(g: &mut Gen) -> (Topology, usize) {
    let m = g.usize_in(3, 16);
    let t = match g.usize_in(0, 4) {
        0 => Topology::Ring,
        1 => Topology::TwoHopRing,
        2 => Topology::Complete,
        3 => Topology::Star,
        _ => Topology::ErdosRenyi { p_milli: 300 + g.usize_in(0, 500) as u32, seed: g.rng.next_u64() },
    };
    (t, m)
}

fn random_compressor(g: &mut Gen) -> Box<dyn Compressor> {
    let spec = match g.usize_in(0, 3) {
        0 => format!("topk:{}", [0.05, 0.1, 0.3, 0.7][g.usize_in(0, 3)]),
        1 => format!("randk:{}", [0.1, 0.25, 0.5][g.usize_in(0, 2)]),
        2 => format!("qsgd:{}", [4, 8, 16, 64][g.usize_in(0, 3)]),
        _ => "none".to_string(),
    };
    parse(&spec).unwrap()
}

/// Metropolis mixing matrices are symmetric doubly stochastic with a
/// positive spectral gap on every random connected topology.
#[test]
fn prop_mixing_matrix_valid() {
    check("mixing-valid", 60, |g| {
        let (t, m) = random_topology(g);
        let w = MixingMatrix::metropolis(&Graph::build(t, m));
        ensure(
            w.matrix().doubly_stochastic_defect() < 1e-9,
            format!("{t:?} m={m}: not doubly stochastic"),
        )?;
        ensure(w.matrix().is_symmetric(1e-9), "not symmetric")?;
        ensure(
            w.spectral_gap > 0.0 && w.spectral_gap <= 1.0 + 1e-9,
            format!("bad gap {}", w.spectral_gap),
        )
    });
}

/// Gossip mixing preserves the node average for any γ (Eq. 7 foundation).
#[test]
fn prop_mix_preserves_mean() {
    check("mix-mean", 60, |g| {
        let (t, m) = random_topology(g);
        let w = MixingMatrix::metropolis(&Graph::build(t, m));
        let d = g.usize_in(1, 40);
        let rows: Vec<Vec<f32>> = (0..m).map(|_| g.vec_normal(d, 2.0)).collect();
        let gamma = g.f32_in(0.05, 1.0) as f64;
        let mixed = w.mix(gamma, &rows);
        let m0 = linalg::mean_rows(&rows);
        let m1 = linalg::mean_rows(&mixed);
        for k in 0..d {
            ensure_close(m0[k] as f64, m1[k] as f64, 1e-4, "mean shifted")?;
        }
        Ok(())
    });
}

/// Every compressor satisfies the contractive bound in (empirical)
/// expectation: E‖Q(v) − v‖² ≤ (1 − δ)‖v‖².
#[test]
fn prop_compressors_contractive() {
    check("contractive", 40, |g| {
        let q = random_compressor(g);
        let d = g.usize_in(8, 600);
        let v = g.vec_normal(d, 1.0);
        let v_norm = linalg::norm2_sq(&v);
        if v_norm == 0.0 {
            return Ok(());
        }
        let trials = 30;
        let mut err = 0.0;
        for _ in 0..trials {
            let c = q.compress(&v, &mut g.rng);
            err += c
                .to_dense()
                .iter()
                .zip(&v)
                .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
                .sum::<f64>();
        }
        err /= trials as f64;
        // QSGD's δ is quoted for a representative d; allow slack for the
        // per-d constant, keep the bound tight for top-k/rand-k.
        let slack = if q.name().starts_with("qsgd") { 1.5 } else { 1.02 };
        ensure(
            err <= (1.0 - q.delta()).max(0.0) * v_norm * slack + 1e-9,
            format!("{}: err {err} vs bound {}", q.name(), (1.0 - q.delta()) * v_norm),
        )
    });
}

/// Message encode→decode identity: every densification path of a
/// [`c2dfb::compress::Compressed`] message — `to_dense`, `write_dense`
/// into a dirty buffer, `add_dense` onto zeros, `add_scaled_into(1.0)`
/// onto zeros — reconstructs the same vector, and the dense/identity
/// encoding round-trips the input verbatim.
#[test]
fn prop_message_densify_paths_agree() {
    check("message-roundtrip", 60, |g| {
        let q = random_compressor(g);
        let d = g.usize_in(1, 400);
        let v = g.vec_normal(d, 1.5);
        let c = q.compress(&v, &mut g.rng);
        ensure(c.dim == d, "dim lost in compression")?;
        ensure(c.wire_bytes() > 8, "empty wire message")?;

        let dense = c.to_dense();
        let mut written = g.vec_normal(d, 9.0); // dirty buffer
        c.decompress_into(&mut written);
        let mut added = vec![0.0f32; d];
        c.add_into(&mut added);
        let mut scaled = vec![0.0f32; d];
        c.add_scaled_into(1.0, &mut scaled);
        for k in 0..d {
            ensure(
                dense[k] == written[k] && dense[k] == added[k] && dense[k] == scaled[k],
                format!(
                    "{}: densify paths disagree at {k}: {} / {} / {} / {}",
                    q.name(),
                    dense[k],
                    written[k],
                    added[k],
                    scaled[k]
                ),
            )?;
        }
        // The dense (identity) encoding is a bit-exact round-trip.
        let id = parse("none").unwrap();
        let c2 = id.compress(&v, &mut g.rng);
        ensure(c2.to_dense() == v, "identity encode→decode altered the vector")
    });
}

/// Hot-path buffer reuse: `compress_into` into an arbitrarily dirty
/// reused slot (previously holding a different vector compressed by a
/// different compressor) produces exactly the message a fresh `compress`
/// produces — same payload, same wire bytes, same RNG consumption — for
/// every compressor.
#[test]
fn prop_compress_into_dirty_buffer_equals_fresh_compress() {
    check("compress-into-reuse", 60, |g| {
        let q = random_compressor(g);
        let d = g.usize_in(1, 400);
        let v = g.vec_normal(d, 1.0);
        // Fresh encode with a cloned RNG stream.
        let mut rng_fresh = Rng::new(g.rng.next_u64());
        let mut rng_reuse = rng_fresh.clone();
        let fresh = q.compress(&v, &mut rng_fresh);
        // Dirty the slot: different vector, different compressor family.
        let other = g.vec_normal(g.usize_in(1, 300), 2.0);
        let dirt = random_compressor(g);
        let mut slot = dirt.compress(&other, &mut g.rng);
        q.compress_into(&v, &mut slot, &mut rng_reuse);
        ensure(slot == fresh, format!("{}: reused slot differs from fresh", q.name()))?;
        ensure(
            slot.wire_bytes() == fresh.wire_bytes(),
            format!("{}: wire bytes differ", q.name()),
        )?;
        ensure(
            rng_fresh.next_u64() == rng_reuse.next_u64(),
            format!("{}: rng consumption differs", q.name()),
        )
    });
}

/// Re-encoding an already-compressed message is the identity for the
/// deterministic sparsifier: top-k(decode(top-k(v))) == top-k(v), so the
/// wire format is a fixed point of the compressor (no error accumulates
/// from encode→decode→encode cycles).
#[test]
fn prop_topk_reencode_is_fixed_point() {
    check("topk-fixed-point", 40, |g| {
        let d = g.usize_in(2, 300);
        let ratio = [0.1, 0.3, 0.6][g.usize_in(0, 2)];
        let q = parse(&format!("topk:{ratio}")).unwrap();
        let v = g.vec_normal(d, 1.0);
        let once = q.compress(&v, &mut g.rng).to_dense();
        let twice = q.compress(&once, &mut g.rng).to_dense();
        ensure(
            once == twice,
            "top-k is not idempotent on its own reconstruction",
        )
    });
}

/// Compressed-residual error norms are monotone in the compression ratio:
/// keeping more coordinates never hurts — exactly for the deterministic
/// top-k, in empirical expectation for rand-k.
#[test]
fn prop_compression_error_monotone_in_ratio() {
    let err_of = |dense: &[f32], v: &[f32]| -> f64 {
        dense
            .iter()
            .zip(v)
            .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
            .sum()
    };
    check("ratio-monotone", 40, |g| {
        let d = g.usize_in(8, 400);
        let v = g.vec_normal(d, 1.0);
        let ratios = [0.05, 0.15, 0.4, 0.8, 1.0];
        // Top-k: deterministic, so monotonicity must hold exactly.
        let mut last = f64::INFINITY;
        for r in ratios {
            let q = parse(&format!("topk:{r}")).unwrap();
            let e = err_of(&q.compress(&v, &mut g.rng).to_dense(), &v);
            ensure(
                e <= last + 1e-9,
                format!("topk error not monotone at ratio {r}: {e} > {last}"),
            )?;
            last = e;
        }
        // Rand-k: monotone in expectation; average a few trials and allow
        // sampling slack.
        let mut last = f64::INFINITY;
        for r in ratios {
            let q = parse(&format!("randk:{r}")).unwrap();
            let trials = 25;
            let mut acc = 0.0;
            for _ in 0..trials {
                acc += err_of(&q.compress(&v, &mut g.rng).to_dense(), &v);
            }
            let e = acc / trials as f64;
            ensure(
                e <= last * 1.25 + 1e-9,
                format!("randk mean error not monotone-ish at ratio {r}: {e} > {last}"),
            )?;
            last = e;
        }
        // Full-ratio compression is lossless for both.
        let full = parse("topk:1.0").unwrap().compress(&v, &mut g.rng).to_dense();
        ensure(full == v, "ratio 1.0 must be lossless")
    });
}

/// Compression round-trips are exact on the kept coordinates for sparse
/// compressors (top-k keeps the largest magnitudes verbatim).
#[test]
fn prop_topk_kept_coords_exact() {
    check("topk-exact", 40, |g| {
        let d = g.usize_in(4, 300);
        let ratio = [0.1, 0.3, 0.6][g.usize_in(0, 2)];
        let q = parse(&format!("topk:{ratio}")).unwrap();
        let v = g.vec_normal(d, 1.0);
        let dense = q.compress(&v, &mut g.rng).to_dense();
        for k in 0..d {
            ensure(
                dense[k] == 0.0 || dense[k] == v[k],
                format!("coord {k} altered: {} vs {}", dense[k], v[k]),
            )?;
        }
        Ok(())
    });
}

/// The reference-point invariant: hat_w_i ≡ Σ_j w_ij hat_j after arbitrary
/// message sequences with any compressor (the paper's key bookkeeping).
#[test]
fn prop_refpoint_accumulator_invariant() {
    check("refpoint-invariant", 25, |g| {
        let (t, m) = random_topology(g);
        let w = MixingMatrix::metropolis(&Graph::build(t, m));
        let d = g.usize_in(2, 50);
        let q = random_compressor(g);
        let mut states: Vec<RefPoint> =
            (0..m).map(|i| RefPoint::new(d, 1.0 - w.weight(i, i))).collect();
        let mut vecs: Vec<Vec<f32>> = (0..m).map(|_| g.vec_normal(d, 1.0)).collect();
        for _step in 0..6 {
            for v in vecs.iter_mut() {
                for x in v.iter_mut() {
                    *x += g.rng.normal_f32(0.0, 0.2);
                }
            }
            let msgs: Vec<_> = (0..m)
                .map(|i| q.compress(&states[i].residual(&vecs[i]), &mut g.rng))
                .collect();
            for i in 0..m {
                states[i].apply_own(&msgs[i]);
            }
            for i in 0..m {
                for &(j, wij) in w.neighbors(i) {
                    states[i].apply_neighbor(wij, &msgs[j]);
                }
            }
            for i in 0..m {
                for k in 0..d {
                    let direct: f64 = w
                        .neighbors(i)
                        .iter()
                        .map(|&(j, wij)| wij * states[j].hat[k] as f64)
                        .sum();
                    ensure_close(
                        states[i].hat_w[k] as f64,
                        direct,
                        5e-4,
                        "accumulator drifted",
                    )?;
                }
            }
        }
        Ok(())
    });
}

/// Partitioners conserve rows and heterogeneity increases skew
/// monotonically in h.
#[test]
fn prop_partition_conserves_and_skews() {
    check("partition", 25, |g| {
        let classes = g.usize_in(2, 8);
        let n = classes * g.usize_in(20, 60);
        let m = g.usize_in(2, 8);
        let ds = newsgroups_like(n, 24, classes, 0.3, g.rng.next_u64());
        let mut rng = Rng::new(g.rng.next_u64());
        let iid = Partition::Iid.split(&ds, m, &mut rng);
        ensure(iid.iter().map(|s| s.n).sum::<usize>() == n, "iid lost rows")?;
        let h1 = Partition::Heterogeneous { h: 0.4 }.split(&ds, m, &mut rng);
        let h2 = Partition::Heterogeneous { h: 0.9 }.split(&ds, m, &mut rng);
        ensure(h1.iter().map(|s| s.n).sum::<usize>() == n, "het lost rows")?;
        let s0 = c2dfb::data::partition::skew(&iid, classes);
        let s1 = c2dfb::data::partition::skew(&h1, classes);
        let s2 = c2dfb::data::partition::skew(&h2, classes);
        ensure(
            s0 <= s1 + 0.12 && s1 <= s2 + 0.12,
            format!("skew not monotone: {s0:.3} {s1:.3} {s2:.3}"),
        )
    });
}

/// JSON round-trips arbitrary structured values built from the generator.
#[test]
fn prop_json_roundtrip() {
    use c2dfb::util::json::Json;

    fn random_json(g: &mut Gen, depth: usize) -> Json {
        match if depth == 0 { g.usize_in(0, 2) } else { g.usize_in(0, 4) } {
            0 => Json::Num((g.f32_in(-1e6, 1e6) as f64 * 100.0).round() / 100.0),
            1 => Json::Bool(g.bool()),
            2 => {
                let n = g.usize_in(0, 12);
                Json::Str((0..n).map(|_| *g.choose(&['a', 'β', '"', '\\', '\n', 'z'])).collect())
            }
            3 => Json::Arr((0..g.usize_in(0, 4)).map(|_| random_json(g, depth - 1)).collect()),
            _ => Json::Obj(
                (0..g.usize_in(0, 4))
                    .map(|i| (format!("k{i}"), random_json(g, depth - 1)))
                    .collect(),
            ),
        }
    }

    check("json-roundtrip", 80, |g| {
        let v = random_json(g, 3);
        let text = v.to_string();
        let re = Json::parse(&text).map_err(|e| format!("{e} in {text}"))?;
        ensure(re == v, format!("roundtrip mismatch: {text}"))
    });
}

/// The dense tracker invariant (Proposition 4) under random topologies,
/// gammas, and gradient sequences.
#[test]
fn prop_tracker_mean_invariant() {
    use c2dfb::collective::Network;
    use c2dfb::optim::DenseTracker;

    check("tracker-mean", 25, |g| {
        let (t, m) = random_topology(g);
        let mut net = Network::new(Graph::build(t, m));
        let d = g.usize_in(1, 30);
        let u0: Vec<Vec<f32>> = (0..m).map(|_| g.vec_normal(d, 1.0)).collect();
        let mut tr = DenseTracker::new(u0);
        let gamma = g.f32_in(0.1, 1.0) as f64;
        for _ in 0..5 {
            let u: Vec<Vec<f32>> = (0..m).map(|_| g.vec_normal(d, 1.0)).collect();
            tr.update(&mut net, gamma, &u);
            let mu = linalg::mean_rows(&u);
            let ms = tr.mean();
            for k in 0..d {
                ensure_close(mu[k] as f64, ms[k] as f64, 1e-4, "tracker mean")?;
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Binary wire codec (`compress::Payload::encode`/`decode`) — the daemon's
// untrusted-input boundary.  The decoder's contract is: arbitrary bytes
// never panic, never over-read, never allocate attacker-sized buffers;
// valid encodings round-trip exactly.
// ---------------------------------------------------------------------------

use c2dfb::compress::Payload;

/// A random canonical payload: dense, sparse (narrow or wide indices,
/// strictly increasing), or quantized with an in-range header.
fn random_payload(g: &mut Gen) -> Payload {
    match g.usize_in(0, 2) {
        0 => Payload::Dense(g.vec_normal(g.usize_in(0, 48), 1.0)),
        1 => {
            let n = g.usize_in(0, 16);
            let wide = g.bool();
            let mut cur: u32 = if wide { 65_536 } else { 0 };
            let mut idx = Vec::with_capacity(n);
            for _ in 0..n {
                cur += g.usize_in(0, 9) as u32;
                idx.push(cur);
                cur += 1;
            }
            let val = g.vec_normal(n, 1.0);
            Payload::Sparse { idx, val }
        }
        _ => Payload::Quantized {
            norm: g.f32_in(0.0, 100.0),
            levels: g.usize_in(1, 32_767) as u32,
            codes: (0..g.usize_in(0, 48)).map(|_| g.rng.next_u64() as i16).collect(),
        },
    }
}

/// The smallest dimension a payload legitimately fits
/// (`decode_for_dim`'s accept side).
fn fitting_dim(p: &Payload) -> usize {
    match p {
        Payload::Dense(v) => v.len(),
        Payload::Sparse { idx, .. } => idx.last().map_or(0, |&m| m as usize) + 1,
        Payload::Quantized { codes, .. } => codes.len(),
    }
}

/// Canonical payloads round-trip the wire bit-exactly: `encoded_len` is
/// the true length, `decode(encode(p)) == p`, `decode_for_dim` accepts
/// the payload's own dimension and rejects a dimension it cannot fit.
#[test]
fn prop_wire_codec_roundtrip() {
    check("wire-roundtrip", 80, |g| {
        let p = random_payload(g);
        let mut bytes = Vec::new();
        p.encode(&mut bytes);
        ensure(
            bytes.len() == p.encoded_len(),
            format!("encoded_len {} vs actual {}", p.encoded_len(), bytes.len()),
        )?;
        let back = Payload::<f32>::decode(&bytes)
            .map_err(|e| format!("decode of a valid encoding failed: {e}"))?;
        ensure(back == p, "encode→decode altered the payload")?;
        let dim = fitting_dim(&p);
        Payload::<f32>::decode_for_dim(&bytes, dim)
            .map_err(|e| format!("rejected at its own dim {dim}: {e}"))?;
        // A dimension the payload cannot fit must be rejected: one short
        // of the dense/quantized length, or the max sparse index itself.
        let too_small = match &p {
            Payload::Dense(v) if !v.is_empty() => Some(v.len() - 1),
            Payload::Quantized { codes, .. } if !codes.is_empty() => Some(codes.len() - 1),
            Payload::Sparse { idx, .. } => idx.last().map(|&m| m as usize),
            _ => None,
        };
        if let Some(bad) = too_small {
            ensure(
                Payload::<f32>::decode_for_dim(&bytes, bad).is_err(),
                format!("dim {bad} accepted a payload needing {dim}"),
            )?;
        }
        Ok(())
    });
}

/// Arbitrary byte strings never panic the decoder — at either dtype.
/// When hostile bytes happen to decode, the result must be a canonical
/// payload: re-encoding it and decoding again is a bit-exact fixed point
/// (compared on encoded bytes, so NaN payload values cannot fake a
/// mismatch).
#[test]
fn prop_wire_decode_survives_random_bytes() {
    check("wire-hostile", 200, |g| {
        let n = g.usize_in(0, 64);
        let mut bytes: Vec<u8> = (0..n).map(|_| g.rng.next_u64() as u8).collect();
        // Bias half the cases onto real tags (both dtype blocks, plus the
        // first out-of-range value) so every decode arm is hit.
        if !bytes.is_empty() && g.bool() {
            bytes[0] = g.usize_in(0, 8) as u8;
        }
        if let Ok(p) = Payload::<f32>::decode(&bytes) {
            let mut re = Vec::new();
            p.encode(&mut re);
            let p2 = Payload::<f32>::decode(&re)
                .map_err(|e| format!("re-encoding not decodable: {e}"))?;
            let mut re2 = Vec::new();
            p2.encode(&mut re2);
            ensure(re == re2, "decode→encode→decode is not a fixed point")?;
        }
        if let Ok(p) = Payload::<f64>::decode(&bytes) {
            let mut re = Vec::new();
            p.encode(&mut re);
            let p2 = Payload::<f64>::decode(&re)
                .map_err(|e| format!("f64 re-encoding not decodable: {e}"))?;
            let mut re2 = Vec::new();
            p2.encode(&mut re2);
            ensure(re == re2, "f64 decode→encode→decode is not a fixed point")?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Scale subsystem (docs/SCALE.md): generator topologies, the calendar
// event queue, and the strided consensus estimator.
// ---------------------------------------------------------------------------

use c2dfb::metrics::ConsensusEstimator;
use c2dfb::sim::event::{EventQueue, HeapEventQueue};
use c2dfb::topology::{GenTopology, Neighborhood};

/// A random generator-capable topology with an m it accepts.
fn random_gen_topology(g: &mut Gen) -> (Topology, usize) {
    match g.usize_in(0, 3) {
        0 => (Topology::Ring, g.usize_in(3, 90)),
        1 => (Topology::Exponential, g.usize_in(3, 90)),
        2 => (Topology::Torus, g.usize_in(4, 90)),
        _ => {
            let k = 2 * g.usize_in(1, 4) as u32; // 2, 4, 6, 8
            // Circulant feasibility: offset 1 plus k/2 − 1 distinct offsets
            // in [2, (m−1)/2] needs m ≥ k + 2 or so; stay well above.
            let m = g.usize_in(k as usize + 3, 90);
            (Topology::RandomRegular { k, seed: g.rng.next_u64() }, m)
        }
    }
}

/// Every generator topology is a valid gossip graph at any (m, seed):
/// sorted self-loop-free neighbor lists, symmetric edges, degree
/// consistent with the advertised `degree(i)`, connected, and a
/// symmetric Metropolis weight function whose rows sum to 1.
#[test]
fn prop_generator_topologies_are_valid_graphs() {
    check("gen-valid", 60, |g| {
        let (t, m) = random_gen_topology(g);
        let gt = GenTopology::new(t, m).map_err(|e| format!("{t:?} m={m}: {e}"))?;
        ensure(gt.node_count() == m, "node count")?;
        let mut nbrs = Vec::new();
        let mut back = Vec::new();
        let mut seen = vec![false; m];
        let mut frontier = vec![0usize];
        seen[0] = true;
        let mut reached = 1usize;
        while let Some(i) = frontier.pop() {
            gt.neighbors_into(i, &mut nbrs);
            for &j in &nbrs {
                if !seen[j] {
                    seen[j] = true;
                    reached += 1;
                    frontier.push(j);
                }
            }
        }
        ensure(reached == m, format!("{t:?} m={m}: only {reached}/{m} reachable"))?;
        for i in 0..m {
            gt.neighbors_into(i, &mut nbrs);
            ensure(
                nbrs.windows(2).all(|w| w[0] < w[1]),
                format!("{t:?} m={m}: node {i} neighbors not sorted-unique"),
            )?;
            ensure(!nbrs.contains(&i), format!("{t:?} m={m}: self-loop at {i}"))?;
            ensure(
                nbrs.len() == gt.degree(i),
                format!("{t:?} m={m}: node {i} degree {} vs list {}", gt.degree(i), nbrs.len()),
            )?;
            let mut row_sum = gt.mix_weight(i, i);
            for &j in &nbrs {
                gt.neighbors_into(j, &mut back);
                ensure(
                    back.binary_search(&i).is_ok(),
                    format!("{t:?} m={m}: edge {i}->{j} not symmetric"),
                )?;
                let w = gt.mix_weight(i, j);
                ensure(w > 0.0, format!("{t:?} m={m}: non-positive edge weight"))?;
                ensure(
                    w.to_bits() == gt.mix_weight(j, i).to_bits(),
                    format!("{t:?} m={m}: weight ({i},{j}) not symmetric"),
                )?;
                row_sum += w;
            }
            ensure_close(row_sum, 1.0, 1e-9, &format!("{t:?} m={m}: row {i} sum"))?;
        }
        Ok(())
    });
}

/// The O(1) calendar queue pops the exact sequence the binary heap pops
/// on any random stream — interleaved pushes/pops, duplicate times, and
/// times far beyond the initial bucket horizon included.  Equal
/// timestamps break ties by insertion order in both queues.
#[test]
fn prop_calendar_queue_matches_heap_order() {
    check("calendar-vs-heap", 80, |g| {
        let mut cal: EventQueue<u32> = EventQueue::new();
        let mut heap: HeapEventQueue<u32> = HeapEventQueue::new();
        let ops = g.usize_in(1, 200);
        // A small palette of times forces plenty of exact ties.
        let palette: Vec<f64> = (0..g.usize_in(2, 12))
            .map(|_| g.f32_in(0.0, 50.0) as f64 * if g.bool() { 1.0 } else { 1e4 })
            .collect();
        let mut next_id = 0u32;
        for _ in 0..ops {
            if g.bool() || cal.is_empty() {
                let t = *g.choose(&palette);
                cal.push(t, next_id);
                heap.push(t, next_id);
                next_id += 1;
            } else {
                let a = cal.pop();
                let b = heap.pop();
                ensure(
                    a.map(|(t, v)| (t.to_bits(), v)) == b.map(|(t, v)| (t.to_bits(), v)),
                    format!("mid-stream pop diverged: {a:?} vs {b:?}"),
                )?;
            }
            ensure(cal.len() == heap.len(), "length drifted")?;
            ensure(
                cal.peek_time().map(f64::to_bits) == heap.peek_time().map(f64::to_bits),
                "peek_time drifted",
            )?;
        }
        while let Some(b) = heap.pop() {
            let a = cal.pop();
            ensure(
                a.map(|(t, v)| (t.to_bits(), v)) == Some((b.0.to_bits(), b.1)),
                format!("drain pop diverged: {a:?} vs {b:?}"),
            )?;
        }
        ensure(cal.pop().is_none(), "calendar queue had extra events")
    });
}

/// The strided consensus estimator degrades gracefully: stride 1 is
/// bit-exact, the lazy row-fill entry point matches the materialized
/// entry point bitwise for every variant, and on a consensus-reached
/// state every stride reports exactly zero.
#[test]
fn prop_strided_estimator_converges_to_exact() {
    check("estimator-strides", 60, |g| {
        let m = g.usize_in(2, 120);
        let d = g.usize_in(1, 24);
        let rows: Vec<Vec<f32>> = (0..m).map(|_| g.vec_normal(d, 1.5)).collect();
        let exact = linalg::consensus_err_sq(&rows);
        let variants = [
            ConsensusEstimator::Exact,
            ConsensusEstimator::Strided { stride: 1 },
            ConsensusEstimator::Strided { stride: g.usize_in(2, 16) },
            ConsensusEstimator::Auto { threshold: g.usize_in(1, 150) },
        ];
        for est in variants {
            let direct = est.estimate(&rows);
            let lazy = est.estimate_sampled(m, d, |i, out| out.copy_from_slice(&rows[i]));
            ensure(
                direct.to_bits() == lazy.to_bits(),
                format!("{}: lazy {lazy} vs materialized {direct}", est.name()),
            )?;
            if est.stride_for(m) == 1 {
                ensure(
                    direct.to_bits() == exact.to_bits(),
                    format!("{}: stride 1 not bit-exact", est.name()),
                )?;
            } else {
                ensure(direct.is_finite() && direct >= 0.0, "strided estimate not finite")?;
            }
        }
        // Consensus reached ⇒ every estimator reports exactly zero.
        let same: Vec<Vec<f32>> = (0..m).map(|_| rows[0].clone()).collect();
        for est in variants {
            ensure(
                est.estimate(&same) == 0.0,
                format!("{}: nonzero on consensus state", est.name()),
            )?;
        }
        Ok(())
    });
}

/// Every strict prefix of a valid encoding fails cleanly (the count field
/// pins the exact payload length), and flipping a single byte never
/// panics — if the mutant still decodes, it is itself canonical.
#[test]
fn prop_wire_truncation_and_mutation_are_clean() {
    check("wire-truncate", 60, |g| {
        let p = random_payload(g);
        let mut bytes = Vec::new();
        p.encode(&mut bytes);
        for cut in 0..bytes.len() {
            ensure(
                Payload::<f32>::decode(&bytes[..cut]).is_err(),
                format!("strict prefix {cut}/{} decoded", bytes.len()),
            )?;
        }
        if !bytes.is_empty() {
            let at = g.usize_in(0, bytes.len() - 1);
            bytes[at] ^= (g.rng.next_u64() as u8) | 1;
            if let Ok(m) = Payload::<f32>::decode(&bytes) {
                let mut re = Vec::new();
                m.encode(&mut re);
                ensure(
                    Payload::<f32>::decode(&re).is_ok(),
                    "mutated payload decoded but its re-encoding does not",
                )?;
            }
        }
        Ok(())
    });
}

/// The payload's f64 twin: same structure, every scalar widened.  Exact
/// widening keeps the two encodings comparable field-for-field.
fn widen_payload(p: &Payload) -> Payload<f64> {
    match p {
        Payload::Dense(v) => Payload::Dense(v.iter().map(|&x| x as f64).collect()),
        Payload::Sparse { idx, val } => Payload::Sparse {
            idx: idx.clone(),
            val: val.iter().map(|&x| x as f64).collect(),
        },
        Payload::Quantized { norm, levels, codes } => Payload::Quantized {
            norm: *norm as f64,
            levels: *levels,
            codes: codes.clone(),
        },
    }
}

/// The wire dtype tag is enforced both ways: f32 encodings use tags
/// 0..=3 and never decode under the f64 contract, f64 encodings use
/// 4..=7 and never decode under the f32 contract (clean "dtype mismatch"
/// errors, not panics or misreads), tags outside both blocks are
/// rejected by name at either dtype, and the f64 block round-trips and
/// bills its length as exactly as the historical f32 one.
#[test]
fn prop_wire_dtype_tag_is_enforced() {
    check("wire-dtype", 80, |g| {
        let p32 = random_payload(g);
        let p64 = widen_payload(&p32);
        let (mut b32, mut b64) = (Vec::new(), Vec::new());
        p32.encode(&mut b32);
        p64.encode(&mut b64);
        ensure(b32[0] < 4, format!("f32 tag {} outside 0..=3", b32[0]))?;
        ensure(
            (4..8).contains(&b64[0]),
            format!("f64 tag {} outside 4..=7", b64[0]),
        )?;
        ensure(
            b64.len() == p64.encoded_len(),
            format!("f64 encoded_len {} vs actual {}", p64.encoded_len(), b64.len()),
        )?;
        // Everything but the tag and the scalar width matches: an f64
        // dense/sparse body is the f32 body with each value re-widened,
        // so the count fields must agree byte-for-byte.
        ensure(b32[1..5] == b64[1..5], "count fields diverge across dtypes")?;
        // Wrong-dtype decodes fail clean, and say why.
        match Payload::<f64>::decode(&b32) {
            Ok(_) => return Err("f32 bytes decoded under the f64 contract".into()),
            Err(e) => ensure(
                e.contains("dtype mismatch"),
                format!("unhelpful cross-dtype error: {e}"),
            )?,
        }
        match Payload::<f32>::decode(&b64) {
            Ok(_) => return Err("f64 bytes decoded under the f32 contract".into()),
            Err(e) => ensure(
                e.contains("dtype mismatch"),
                format!("unhelpful cross-dtype error: {e}"),
            )?,
        }
        // Right-dtype decode round-trips bit-exactly.
        let back = Payload::<f64>::decode(&b64)
            .map_err(|e| format!("f64 decode of a valid encoding failed: {e}"))?;
        ensure(back == p64, "f64 encode→decode altered the payload")?;
        // Every strict prefix of the f64 encoding fails clean too.
        for cut in 0..b64.len() {
            ensure(
                Payload::<f64>::decode(&b64[..cut]).is_err(),
                format!("f64 strict prefix {cut}/{} decoded", b64.len()),
            )?;
        }
        // A tag outside both dtype blocks is unknown to both decoders.
        let junk = 8 + (g.rng.next_u64() % 248) as u8;
        b64[0] = junk;
        for (what, err) in [
            ("f32", Payload::<f32>::decode(&b64).err()),
            ("f64", Payload::<f64>::decode(&b64).err()),
        ] {
            let e = err.ok_or(format!("{what} decoder accepted junk tag {junk}"))?;
            ensure(
                e.contains("unknown payload tag"),
                format!("unhelpful junk-tag error at {what}: {e}"),
            )?;
        }
        Ok(())
    });
}
