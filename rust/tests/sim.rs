//! Sim-subsystem acceptance tests: benign-network equivalence with the
//! synchronous engine, bit-reproducibility across thread counts,
//! drop-rate accounting, straggler virtual-time ordering, and the
//! `netsweep` harness end-to-end.

use c2dfb::collective::Transport;
use c2dfb::config::{Algorithm, ExperimentConfig};
use c2dfb::coordinator::{experiments, Runner};
use c2dfb::metrics::RunMetrics;
use c2dfb::sim::{NetConfig, NetMode, SimNetwork};
use c2dfb::tasks::QuadraticTask;
use c2dfb::topology::{Graph, Topology};

fn run_with_task(task: &QuadraticTask, cfg: &ExperimentConfig) -> anyhow::Result<RunMetrics> {
    Runner::new(cfg).task(task).run()
}

fn run_with_task_shared(
    task: &QuadraticTask,
    cfg: &ExperimentConfig,
) -> anyhow::Result<RunMetrics> {
    Runner::new(cfg).shared_task(task).run()
}

fn quad_cfg(algo: Algorithm) -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        algorithm: algo,
        nodes: 6,
        rounds: 8,
        inner_steps: 8,
        eta_out: 0.2,
        eta_in: 0.3,
        gamma_out: 0.8,
        gamma_in: 0.6,
        lambda: 50.0,
        compressor: "topk:0.5".into(),
        eval_every: 2,
        ..ExperimentConfig::default()
    };
    if algo == Algorithm::Madsbo || algo == Algorithm::Mdbo {
        cfg.eta_out = 0.4;
    }
    cfg
}

fn trace_bits(m: &RunMetrics) -> Vec<(usize, u64, u64)> {
    m.trace
        .iter()
        .map(|p| (p.round, p.loss.to_bits(), p.grad_norm.to_bits()))
        .collect()
}

/// Acceptance criterion: with drop_rate = 0, zero jitter and no
/// stragglers, the event engine reproduces the synchronous engine's
/// RunMetrics — bytes, rounds, messages and the full loss trace — exactly,
/// for every algorithm.
#[test]
fn event_engine_reproduces_sync_engine_exactly() {
    for algo in [
        Algorithm::C2dfb,
        Algorithm::C2dfbNc,
        Algorithm::Madsbo,
        Algorithm::Mdbo,
    ] {
        let task: QuadraticTask = QuadraticTask::generate(6, 10, 0.8, 91);
        let cfg_sync = quad_cfg(algo);
        let mut cfg_sim = quad_cfg(algo);
        cfg_sim.network.mode = NetMode::Event;

        let a = run_with_task(&task, &cfg_sync).expect(algo.name());
        let b = run_with_task(&task, &cfg_sim).expect(algo.name());

        assert_eq!(a.ledger.total_bytes, b.ledger.total_bytes, "{}", algo.name());
        assert_eq!(a.ledger.gossip_rounds, b.ledger.gossip_rounds, "{}", algo.name());
        assert_eq!(a.ledger.messages, b.ledger.messages, "{}", algo.name());
        assert_eq!(b.ledger.dropped_messages, 0, "{}", algo.name());
        assert_eq!(trace_bits(&a), trace_bits(&b), "{} trajectory diverged", algo.name());
        // Same message sizes on a ring every round ⇒ same virtual time.
        assert!(
            (a.ledger.network_time_s - b.ledger.network_time_s).abs()
                < 1e-9 * a.ledger.network_time_s.max(1.0),
            "{}: {} vs {}",
            algo.name(),
            a.ledger.network_time_s,
            b.ledger.network_time_s
        );
    }
}

/// Same seed ⇒ identical RunMetrics at any thread-pool width, even with
/// drops and jitter in play (transport randomness lives in per-sender
/// streams, compute fans out with node-ordered reductions).
#[test]
fn runs_are_bit_identical_across_thread_counts() {
    let task: QuadraticTask = QuadraticTask::generate(6, 12, 0.8, 92);
    let run_at = |threads: usize| {
        let mut cfg = quad_cfg(Algorithm::C2dfb);
        cfg.network.mode = NetMode::Event;
        cfg.network.drop_rate = 0.1;
        cfg.network.jitter_s = 2e-4;
        cfg.network.threads = threads;
        run_with_task_shared(&task, &cfg).unwrap()
    };
    let reference = run_at(1);
    for threads in [2, 4, 8] {
        let m = run_at(threads);
        assert_eq!(trace_bits(&reference), trace_bits(&m), "{threads} threads");
        assert_eq!(reference.ledger.total_bytes, m.ledger.total_bytes);
        assert_eq!(
            reference.ledger.dropped_messages,
            m.ledger.dropped_messages,
            "drop realization must not depend on thread count"
        );
        assert_eq!(
            reference.oracles.first_order, m.oracles.first_order,
            "oracle accounting must not depend on thread count"
        );
    }
}

/// Ledger invariant under loss: sent = delivered + dropped, with the
/// empirical drop rate near the configured one, and dropped messages
/// surfacing in the trace/CSV.
#[test]
fn drop_rate_accounting_is_exact() {
    let cfg = NetConfig {
        mode: NetMode::Event,
        drop_rate: 0.2,
        ..NetConfig::default()
    };
    let mut net = SimNetwork::new(Graph::build(Topology::TwoHopRing, 8), cfg, 5).unwrap();
    let rows: Vec<Vec<f32>> = (0..8).map(|i| vec![i as f32; 16]).collect();
    let mut delivered = 0u64;
    for _ in 0..100 {
        delivered += net
            .exchange_dense(&rows)
            .iter()
            .map(|ib| ib.len() as u64)
            .sum::<u64>();
    }
    assert_eq!(delivered + net.ledger.dropped_messages, net.ledger.messages);
    let rate = net.ledger.dropped_messages as f64 / net.ledger.messages as f64;
    assert!((0.15..0.25).contains(&rate), "empirical drop rate {rate}");

    // End-to-end: the trace carries the cumulative dropped counter.
    let task: QuadraticTask = QuadraticTask::generate(6, 8, 0.5, 93);
    let mut ecfg = quad_cfg(Algorithm::C2dfb);
    ecfg.network.mode = NetMode::Event;
    ecfg.network.drop_rate = 0.1;
    let m = run_with_task(&task, &ecfg).unwrap();
    assert!(m.ledger.dropped_messages > 0);
    assert_eq!(m.trace.last().unwrap().dropped_msgs, m.ledger.dropped_messages);
    let csv = m.to_csv();
    assert!(csv
        .lines()
        .next()
        .unwrap()
        .ends_with(",dropped,stop_reason"));
}

/// Straggler ordering in virtual time: the event log is time-sorted, the
/// straggler's copies arrive after every healthy node's, and the run's
/// virtual time grows by ~the straggler delay per gossip round.
#[test]
fn straggler_virtual_time_ordering() {
    let delay = 0.25;
    let cfg = NetConfig {
        mode: NetMode::Event,
        straggler_frac: 0.15, // 1 of 8
        straggler_delay_s: delay,
        ..NetConfig::default()
    };
    let mut net = SimNetwork::new(Graph::build(Topology::Ring, 8), cfg, 17).unwrap();
    let lag = net.stragglers();
    assert_eq!(lag.len(), 2); // ceil(0.15 * 8)
    let rows: Vec<Vec<f32>> = (0..8).map(|i| vec![i as f32; 4]).collect();
    let rounds = 5;
    for _ in 0..rounds {
        net.exchange_dense(&rows);
        let times: Vec<f64> = net.last_events.iter().map(|a| a.t_s).collect();
        assert!(
            times.windows(2).all(|w| w[0] <= w[1]),
            "event log must be sorted by virtual time"
        );
        // Every arrival from a straggler postdates every arrival from a
        // non-straggler whose clock isn't already dragged by one.
        let first_straggler_arrival = net
            .last_events
            .iter()
            .find(|a| lag.contains(&a.sender))
            .map(|a| a.t_s)
            .unwrap();
        assert!(first_straggler_arrival >= delay);
    }
    // Virtual time accumulated ≥ rounds × delay (the lag re-applies every
    // round and propagates to neighbours' clocks).
    assert!(
        net.ledger.network_time_s >= rounds as f64 * delay,
        "virtual time {} after {rounds} rounds",
        net.ledger.network_time_s
    );

    // Sanity at the run level: stragglers inflate virtual time, not bytes.
    let task: QuadraticTask = QuadraticTask::generate(6, 8, 0.5, 94);
    let mut benign = quad_cfg(Algorithm::C2dfb);
    benign.network.mode = NetMode::Event;
    let mut slow = benign.clone();
    slow.network.straggler_frac = 0.2;
    slow.network.straggler_delay_s = 0.1;
    let a = run_with_task(&task, &benign).unwrap();
    let b = run_with_task(&task, &slow).unwrap();
    assert_eq!(a.ledger.total_bytes, b.ledger.total_bytes);
    assert!(b.ledger.network_time_s > a.ledger.network_time_s * 10.0);
}

/// Time-varying topology: a schedule switch changes message fan-out (and
/// therefore bytes) mid-run, and the dense baselines keep converging.
#[test]
fn topology_schedule_changes_cost_profile() {
    let task: QuadraticTask = QuadraticTask::generate(6, 8, 0.5, 95);
    let mut stat = quad_cfg(Algorithm::Mdbo);
    stat.network.mode = NetMode::Event;
    let mut dyn_cfg = stat.clone();
    dyn_cfg
        .network
        .parse_schedule("20:complete", dyn_cfg.seed)
        .unwrap();
    let a = run_with_task(&task, &stat).unwrap();
    let b = run_with_task(&task, &dyn_cfg).unwrap();
    // Complete graph from gossip round 20 on ⇒ strictly more messages.
    assert!(b.ledger.messages > a.ledger.messages);
    assert!(b.final_point().unwrap().loss.is_finite());
}

/// The compressed inner loop resyncs its reference points when the graph
/// epoch changes: C²DFB stays stable and keeps improving across a
/// topology switch (rather than silently mixing with a stale matrix).
#[test]
fn c2dfb_resyncs_reference_points_across_topology_switch() {
    let task: QuadraticTask = QuadraticTask::generate(6, 8, 0.5, 96);
    let mut cfg = quad_cfg(Algorithm::C2dfb);
    cfg.rounds = 40;
    cfg.eval_every = 10;
    cfg.network.mode = NetMode::Event;
    // c2dfb pays (2 + 4K) gossip rounds per outer round; switch a few
    // outer rounds in, then again later.
    cfg.network
        .parse_schedule("150:2hop,600:complete", cfg.seed)
        .unwrap();
    let m = run_with_task(&task, &cfg).unwrap();
    let first = m.trace.first().unwrap();
    let last = m.final_point().unwrap();
    assert!(last.loss.is_finite());
    assert!(last.grad_norm.is_finite());
    assert!(
        last.grad_norm < first.grad_norm * 0.5,
        "hypergrad {} -> {} across topology switches",
        first.grad_norm,
        last.grad_norm
    );
}

/// `c2dfb netsweep --tiny` end-to-end (the CLI calls exactly this),
/// including its internal sync ≡ ideal-sim assertion.
#[test]
fn netsweep_tiny_completes() {
    let dir = std::env::temp_dir().join("c2dfb_netsweep_tiny");
    let _ = std::fs::remove_dir_all(&dir);
    let opts = experiments::HarnessOpts {
        rounds: 4,
        out_dir: dir.to_str().unwrap().to_string(),
        seed: 42,
        ..Default::default()
    };
    let runs = experiments::netsweep(&opts, true).expect("netsweep failed");
    assert_eq!(runs.len(), 6 * 3); // 6 regimes × 3 algorithms
    assert!(runs.iter().all(|r| !r.trace.is_empty()));
    // Traces landed on disk, plus the sweep engine's aggregated report.
    let n_files = std::fs::read_dir(dir.join("netsweep")).unwrap().count();
    assert_eq!(n_files, 6 * 3 * 2 + 2); // csv + json each, + report.{csv,json}
    assert!(dir.join("netsweep/report.csv").exists());
    assert!(dir.join("netsweep/report.json").exists());
}

/// Regression for the zero-delivery panic path: a full run under total
/// message loss (`drop_rate = 1.0`) completes cleanly — every inbox is
/// empty every round, the nodes fall back to their own state, and the
/// driver still records a finite trace and a `rounds` stop.
#[test]
fn total_loss_run_completes_without_panicking() {
    let task: QuadraticTask = QuadraticTask::generate(4, 6, 0.5, 97);
    let mut cfg = quad_cfg(Algorithm::C2dfb);
    cfg.nodes = 4;
    cfg.rounds = 3;
    cfg.inner_steps = 3;
    cfg.eval_every = 1;
    cfg.network.mode = NetMode::Event;
    cfg.network.drop_rate = 1.0;
    let m = run_with_task(&task, &cfg).unwrap();
    assert_eq!(m.ledger.dropped_messages, m.ledger.messages);
    assert!(m.ledger.messages > 0);
    assert!(m.final_point().unwrap().loss.is_finite());
}
