//! Runner / budgeted-stopping acceptance tests: every `StopCondition`
//! fires within one eval interval and records its reason, budget-stopped
//! runs are bit-identical prefixes of fixed-round runs (across serial vs
//! `NodePool` and sync vs benign-sim engines), observers see every trace
//! point and can abort, and the `budget` harness runs end-to-end.

use c2dfb::algorithms::RunObserver;
use c2dfb::config::{Algorithm, ExperimentConfig};
use c2dfb::coordinator::{experiments, Runner};
use c2dfb::data::partition::Partition;
use c2dfb::metrics::{RunMetrics, StopReason, TracePoint};
use c2dfb::sim::NetMode;
use c2dfb::tasks::{LogRegTask, QuadraticTask};

fn quad_cfg(rounds: usize, eval_every: usize) -> ExperimentConfig {
    ExperimentConfig {
        algorithm: Algorithm::C2dfb,
        nodes: 6,
        rounds,
        inner_steps: 8,
        eta_out: 0.2,
        eta_in: 0.3,
        gamma_out: 0.8,
        gamma_in: 0.6,
        lambda: 50.0,
        compressor: "topk:0.5".into(),
        eval_every,
        ..ExperimentConfig::default()
    }
}

fn task() -> QuadraticTask {
    QuadraticTask::generate(6, 10, 0.8, 101)
}

fn run(task: &QuadraticTask, cfg: &ExperimentConfig) -> RunMetrics {
    Runner::new(cfg).task(task).run().unwrap()
}

fn trace_bits(m: &RunMetrics) -> Vec<(usize, u64, u64)> {
    m.trace
        .iter()
        .map(|p| (p.round, p.loss.to_bits(), p.grad_norm.to_bits()))
        .collect()
}

#[test]
fn fixed_round_run_records_rounds_reason() {
    let t = task();
    let m = run(&t, &quad_cfg(6, 2));
    assert_eq!(m.stop_reason, Some(StopReason::Rounds));
    assert_eq!(m.trace.last().unwrap().round, 6);
}

#[test]
fn target_accuracy_records_reason() {
    let t = task();
    let mut cfg = quad_cfg(50, 1);
    cfg.target_accuracy = Some(0.0); // any accuracy qualifies at round 0
    let m = run(&t, &cfg);
    assert_eq!(m.stop_reason, Some(StopReason::TargetAccuracy));
    assert_eq!(m.trace.len(), 1);
}

/// Communication budget: fires at the FIRST eval point where the ledger
/// crosses the budget (one eval interval), and the stopped run is a
/// bit-identical prefix of the fixed-round trace.
#[test]
fn comm_budget_stops_within_one_eval_interval_and_is_a_prefix() {
    let t = task();
    let full = run(&t, &quad_cfg(12, 2));
    // Budget strictly between the comm totals at rounds 4 and 6: the
    // first eval point at or past the budget is round 6.
    let c4 = full.trace.iter().find(|p| p.round == 4).unwrap().comm_mb;
    let c6 = full.trace.iter().find(|p| p.round == 6).unwrap().comm_mb;
    assert!(c4 < c6);
    let mut cfg = quad_cfg(12, 2);
    cfg.stop.comm_mb = Some((c4 + c6) / 2.0);

    let stopped = run(&t, &cfg);
    assert_eq!(stopped.stop_reason, Some(StopReason::CommBudget));
    let last = stopped.trace.last().unwrap();
    assert_eq!(last.round, 6, "budget must fire at the first eval past it");
    assert!(last.comm_mb >= cfg.stop.comm_mb.unwrap());

    // Bit-identical prefix of the fixed-round run.
    let full_bits = trace_bits(&full);
    let stop_bits = trace_bits(&stopped);
    assert_eq!(stop_bits, full_bits[..stop_bits.len()]);
}

#[test]
fn first_order_oracle_budget_stops_early_with_reason() {
    let t = task();
    let full = run(&t, &quad_cfg(10, 1));
    let total = full.oracles.first_order;
    let mut cfg = quad_cfg(10, 1);
    cfg.stop.first_order = Some(total / 2);
    let m = run(&t, &cfg);
    assert_eq!(m.stop_reason, Some(StopReason::FirstOrderOracles));
    assert!(m.oracles.first_order >= total / 2);
    assert!(m.trace.len() < full.trace.len());

    // A 1-call budget is already exhausted by init's hypergradient batch.
    cfg.stop.first_order = Some(1);
    let m = run(&t, &cfg);
    assert_eq!(m.stop_reason, Some(StopReason::FirstOrderOracles));
    assert_eq!(m.trace.len(), 1);
}

#[test]
fn sim_time_budget_stops_with_reason() {
    let t = task();
    let full = run(&t, &quad_cfg(8, 1));
    let s3 = full.trace.iter().find(|p| p.round == 3).unwrap().sim_time_s;
    let s4 = full.trace.iter().find(|p| p.round == 4).unwrap().sim_time_s;
    assert!(s3 < s4);
    let mut cfg = quad_cfg(8, 1);
    cfg.stop.sim_secs = Some((s3 + s4) / 2.0);
    let m = run(&t, &cfg);
    assert_eq!(m.stop_reason, Some(StopReason::SimTime));
    assert_eq!(m.trace.last().unwrap().round, 4);
}

#[test]
fn wall_clock_budget_stops_with_reason() {
    let t = task();
    let mut cfg = quad_cfg(1000, 1);
    cfg.stop.wall_secs = Some(1e-9); // elapses before the first eval
    let m = run(&t, &cfg);
    assert_eq!(m.stop_reason, Some(StopReason::WallClock));
    assert_eq!(m.trace.len(), 1);
}

/// Budget-stopped runs must not depend on the execution mode: serial vs
/// `NodePool` and sync vs benign event engine all produce the same trace
/// bits, bytes and stop reason.
#[test]
fn budget_stop_is_bit_identical_across_engines_and_threads() {
    let t = task();
    let mut cfg = quad_cfg(20, 2);
    // Pick a budget that binds strictly inside the run.
    let probe = run(&t, &quad_cfg(20, 2));
    let mid = probe.trace[probe.trace.len() / 2].comm_mb;
    cfg.stop.comm_mb = Some(mid * 0.99 + probe.trace.last().unwrap().comm_mb * 0.01);

    let serial = Runner::new(&cfg).task(&t).run().unwrap();
    assert_eq!(serial.stop_reason, Some(StopReason::CommBudget));

    let mut pooled_cfg = cfg.clone();
    pooled_cfg.network.threads = 3;
    let pooled = Runner::new(&pooled_cfg).shared_task(&t).run().unwrap();

    let mut sim_cfg = cfg.clone();
    sim_cfg.network.mode = NetMode::Event;
    let sim = Runner::new(&sim_cfg).task(&t).run().unwrap();

    for other in [&pooled, &sim] {
        assert_eq!(trace_bits(&serial), trace_bits(other));
        assert_eq!(serial.ledger.total_bytes, other.ledger.total_bytes);
        assert_eq!(serial.stop_reason, other.stop_reason);
        assert_eq!(serial.oracles.first_order, other.oracles.first_order);
    }
}

fn logreg_cfg(rounds: usize, eval_every: usize) -> ExperimentConfig {
    ExperimentConfig {
        algorithm: Algorithm::C2dfb,
        nodes: 4,
        rounds,
        inner_steps: 5,
        eta_out: 0.2,
        eta_in: 0.3,
        gamma_out: 0.8,
        gamma_in: 0.6,
        lambda: 10.0,
        compressor: "topk:0.5".into(),
        eval_every,
        ..ExperimentConfig::default()
    }
}

fn logreg_task() -> LogRegTask {
    LogRegTask::generate(4, 12, 3, 24, 12, Partition::Dirichlet { alpha: 0.5 }, 0.4, 31)
}

/// The stop contract holds on the native logreg task too, not just the
/// analytic quadratic: a communication budget fires at the first eval
/// point past it, and the stopped run is a bit-identical prefix of the
/// fixed-round trace.
#[test]
fn comm_budget_on_logreg_fires_within_one_interval_and_is_a_prefix() {
    let t = logreg_task();
    let full = Runner::new(&logreg_cfg(8, 2)).shared_task(&t).run().unwrap();
    let c2 = full.trace.iter().find(|p| p.round == 2).unwrap().comm_mb;
    let c4 = full.trace.iter().find(|p| p.round == 4).unwrap().comm_mb;
    assert!(c2 < c4, "ledger must grow between evals: {c2} vs {c4}");
    let mut cfg = logreg_cfg(8, 2);
    cfg.stop.comm_mb = Some((c2 + c4) / 2.0);

    let stopped = Runner::new(&cfg).shared_task(&t).run().unwrap();
    assert_eq!(stopped.stop_reason, Some(StopReason::CommBudget));
    let last = stopped.trace.last().unwrap();
    assert_eq!(last.round, 4, "budget must fire at the first eval past it");
    assert!(last.comm_mb >= cfg.stop.comm_mb.unwrap());
    let full_bits = trace_bits(&full);
    let stop_bits = trace_bits(&stopped);
    assert_eq!(stop_bits, full_bits[..stop_bits.len()], "prefix invariant");
    assert!(last.loss.is_finite());
}

#[test]
fn first_order_oracle_budget_on_logreg_stops_with_prefix() {
    let t = logreg_task();
    let full = Runner::new(&logreg_cfg(6, 1)).shared_task(&t).run().unwrap();
    let total = full.oracles.first_order;
    assert!(total > 0);
    let mut cfg = logreg_cfg(6, 1);
    cfg.stop.first_order = Some(total / 2);
    let m = Runner::new(&cfg).shared_task(&t).run().unwrap();
    assert_eq!(m.stop_reason, Some(StopReason::FirstOrderOracles));
    assert!(m.oracles.first_order >= total / 2);
    assert!(m.trace.len() < full.trace.len());
    let full_bits = trace_bits(&full);
    let stop_bits = trace_bits(&m);
    assert_eq!(stop_bits, full_bits[..stop_bits.len()], "prefix invariant");

    // A 1-call budget is exhausted by init's hypergradient batch already.
    cfg.stop.first_order = Some(1);
    let m = Runner::new(&cfg).shared_task(&t).run().unwrap();
    assert_eq!(m.stop_reason, Some(StopReason::FirstOrderOracles));
    assert_eq!(m.trace.len(), 1);
}

/// Budget-stopped logreg runs are engine-independent like the quadratic
/// ones: sync and benign-sim produce the same bits, bytes and reason.
#[test]
fn logreg_budget_stop_is_engine_independent() {
    let t = logreg_task();
    let probe = Runner::new(&logreg_cfg(6, 1)).shared_task(&t).run().unwrap();
    let mid = probe.trace[probe.trace.len() / 2].comm_mb;
    let mut cfg = logreg_cfg(6, 1);
    cfg.stop.comm_mb = Some(mid * 0.99 + probe.trace.last().unwrap().comm_mb * 0.01);

    let sync = Runner::new(&cfg).shared_task(&t).run().unwrap();
    assert_eq!(sync.stop_reason, Some(StopReason::CommBudget));
    let mut sim_cfg = cfg.clone();
    sim_cfg.network.mode = NetMode::Event;
    let sim = Runner::new(&sim_cfg).shared_task(&t).run().unwrap();
    assert_eq!(trace_bits(&sync), trace_bits(&sim));
    assert_eq!(sync.ledger.total_bytes, sim.ledger.total_bytes);
    assert_eq!(sync.stop_reason, sim.stop_reason);
    assert_eq!(sync.oracles.first_order, sim.oracles.first_order);
}

struct Counting {
    seen: Vec<usize>,
    abort_after: Option<usize>,
}

impl RunObserver for Counting {
    fn on_trace(&mut self, _algo: &str, p: &TracePoint) -> bool {
        self.seen.push(p.round);
        match self.abort_after {
            Some(n) => self.seen.len() < n,
            None => true,
        }
    }
}

#[test]
fn observer_sees_every_trace_point_and_can_abort() {
    let t = task();
    let cfg = quad_cfg(6, 2);

    let mut obs = Counting { seen: Vec::new(), abort_after: None };
    let m = Runner::new(&cfg).task(&t).observer(&mut obs).run().unwrap();
    let rounds: Vec<usize> = m.trace.iter().map(|p| p.round).collect();
    assert_eq!(obs.seen, rounds, "observer must see every recorded point");
    assert_eq!(m.stop_reason, Some(StopReason::Rounds));

    let mut obs = Counting { seen: Vec::new(), abort_after: Some(2) };
    let m = Runner::new(&cfg).task(&t).observer(&mut obs).run().unwrap();
    assert_eq!(m.stop_reason, Some(StopReason::Observer));
    assert_eq!(m.trace.len(), 2);
}

/// `c2dfb budget --tiny --task logreg` end-to-end: the equal-communication
/// harness also runs on the native logreg task, and every algorithm stops
/// on the budget with a finite loss.
#[test]
fn budget_harness_on_logreg_completes() {
    let dir = std::env::temp_dir().join("c2dfb_budget_logreg");
    let _ = std::fs::remove_dir_all(&dir);
    let opts = experiments::HarnessOpts {
        rounds: 300,
        out_dir: dir.to_str().unwrap().to_string(),
        seed: 42,
        ..Default::default()
    };
    let budget_mb = 0.3;
    let runs = experiments::budget_on(&opts, budget_mb, true, "logreg")
        .expect("budget harness on logreg failed");
    assert_eq!(runs.len(), 4);
    for m in &runs {
        assert_eq!(
            m.stop_reason,
            Some(StopReason::CommBudget),
            "{} should stop on the communication budget",
            m.algo
        );
        assert!(m.ledger.total_mb() >= budget_mb, "{}", m.algo);
        assert!(m.final_point().unwrap().loss.is_finite(), "{}", m.algo);
    }
    // Unknown task specs are rejected loudly.
    assert!(experiments::budget_on(&opts, budget_mb, true, "bogus").is_err());
}

/// `c2dfb budget --tiny` end-to-end: all four algorithms stop on the
/// communication budget and record it.
#[test]
fn budget_harness_tiny_completes() {
    let dir = std::env::temp_dir().join("c2dfb_budget_tiny");
    let _ = std::fs::remove_dir_all(&dir);
    let opts = experiments::HarnessOpts {
        rounds: 200,
        out_dir: dir.to_str().unwrap().to_string(),
        seed: 42,
        ..Default::default()
    };
    let budget_mb = 0.3;
    let runs = experiments::budget(&opts, budget_mb, true).expect("budget harness failed");
    assert_eq!(runs.len(), 4);
    for m in &runs {
        assert_eq!(
            m.stop_reason,
            Some(StopReason::CommBudget),
            "{} should stop on the communication budget",
            m.algo
        );
        assert!(m.ledger.total_mb() >= budget_mb, "{}", m.algo);
        assert!(m.final_point().unwrap().loss.is_finite(), "{}", m.algo);
    }
    // No second-order oracle calls for the fully first-order methods,
    // even under budgeted stopping.
    for m in &runs {
        if m.algo.starts_with("c2dfb") {
            assert_eq!(m.oracles.second_order, 0, "{}", m.algo);
        }
    }
    let n_files = std::fs::read_dir(dir.join("budget")).unwrap().count();
    // csv + json per algorithm, plus the sweep engine's report.{csv,json}.
    assert_eq!(n_files, 4 * 2 + 2);
}
