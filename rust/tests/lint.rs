//! `c2dfb lint` self-tests (ISSUE: the pass must be self-testing).
//!
//! Three contracts pinned here:
//! 1. each committed bad fixture under `tests/lint_fixtures/` triggers
//!    exactly its rule, at the expected line;
//! 2. the full `src/` tree passes clean under the shipped `lint.toml`
//!    (every pre-existing violation is fixed or allowlisted-with-reason);
//! 3. the JSON report schema and the allowlist semantics are stable.
//!
//! cargo runs integration tests with cwd = the crate root (`rust/`), so
//! `lint.toml`, `src/`, and `tests/lint_fixtures/` resolve directly.

use c2dfb::analysis::{self, lint_source, LintConfig};
use c2dfb::util::json::Json;

fn shipped_config() -> LintConfig {
    LintConfig::load(std::path::Path::new("lint.toml")).expect("rust/lint.toml parses")
}

/// (fixture file, rule that must fire, line it must fire on)
const FIXTURES: [(&str, &str, u32); 6] = [
    ("tests/lint_fixtures/r1_wall_clock.rs", "R1", 3),
    ("tests/lint_fixtures/r2_unordered_iteration.rs", "R2", 2),
    ("tests/lint_fixtures/r3_panicky_decode.rs", "R3", 3),
    ("tests/lint_fixtures/r4_missing_safety.rs", "R4", 3),
    ("tests/lint_fixtures/r5_foreign_rng.rs", "R5", 3),
    ("tests/lint_fixtures/r6_wall_key.rs", "R6", 3),
];

#[test]
fn each_bad_fixture_triggers_exactly_its_rule() {
    let cfg = shipped_config();
    for (path, rule, line) in FIXTURES {
        let src = std::fs::read_to_string(path).expect(path);
        let findings = lint_source(path, &src, &cfg);
        assert_eq!(findings.len(), 1, "{path}: expected exactly one finding, got {findings:?}");
        assert_eq!(findings[0].rule, rule, "{path}: wrong rule: {findings:?}");
        assert_eq!(findings[0].line, line, "{path}: wrong line: {findings:?}");
    }
}

#[test]
fn full_src_tree_is_clean_under_shipped_policy() {
    let cfg = shipped_config();
    let report = analysis::lint_tree(&["src".to_string()], &cfg).expect("scan src/");
    assert!(
        report.findings.is_empty(),
        "src/ must lint clean; fix the code or allowlist-with-reason in lint.toml:\n{}",
        report.render_text()
    );
    assert!(
        report.files.len() > 30,
        "suspiciously few files scanned ({}); did the walk break?",
        report.files.len()
    );
    // Every shipped allow entry must still be load-bearing: a stale entry
    // means the violation it excused was fixed, so delete the entry.
    assert!(
        report.unused_allows.is_empty(),
        "stale lint.toml allow entries: {:?}",
        report.unused_allows
    );
}

#[test]
fn allowlist_round_trip() {
    let src = "fn t() { let t0 = std::time::Instant::now(); }";
    // Entry present => suppressed.
    let with = LintConfig::from_toml_str(
        "[R1]\nallow1 = \"src/wall.rs -- test: wall-clock on purpose\"\n",
    )
    .unwrap();
    assert!(lint_source("src/wall.rs", src, &with).is_empty());
    // Entry removed => fires again.
    let without = LintConfig::default_config();
    let findings = lint_source("src/wall.rs", src, &without);
    assert_eq!(findings.len(), 1);
    assert_eq!(findings[0].rule, "R1");
    // A reason-less entry is rejected outright.
    assert!(LintConfig::from_toml_str("[R1]\nallow1 = \"src/wall.rs\"\n").is_err());
}

#[test]
fn json_report_schema_is_stable() {
    let cfg = shipped_config();
    let report = analysis::lint_tree(
        &["tests/lint_fixtures/r1_wall_clock.rs".to_string()],
        &cfg,
    )
    .expect("scan fixture");
    let text = report.to_json().to_string();
    let j = Json::parse(&text).expect("lint JSON output parses");
    assert_eq!(j.get("version").and_then(Json::as_f64), Some(1.0));
    assert_eq!(j.get("files_scanned").and_then(Json::as_usize), Some(1));
    assert!(j.get("allow_used").is_some());
    assert!(j.get("allow_unused").and_then(Json::as_arr).is_some());
    let findings = j.get("findings").and_then(Json::as_arr).expect("findings array");
    assert_eq!(findings.len(), 1);
    let f = &findings[0];
    assert_eq!(f.get("rule").and_then(Json::as_str), Some("R1"));
    assert_eq!(f.get("line").and_then(Json::as_usize), Some(3));
    assert!(f.get("path").and_then(Json::as_str).is_some());
    assert!(f.get("message").and_then(Json::as_str).is_some());
}

#[test]
fn rules_never_fire_inside_literals_or_comments() {
    let cfg = LintConfig::default_config();
    // Every banned name, spelled inside strings, raw strings, and
    // comments — none may produce a finding.
    let src = r####"
// Instant::now() HashMap thread_rng unsafe x.unwrap()
/* SystemTime rand::random b[0] panic!("no") */
pub fn t() -> &'static str {
    let s = r#"Instant HashMap "wall_s": thread_rng"#;
    let _ = s;
    "Instant SystemTime .elapsed() unwrap expect"
}
"####;
    let findings = lint_source("src/t.rs", src, &cfg);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn scoped_rules_stay_in_scope() {
    let cfg = shipped_config();
    // Indexing is an R3 finding only on the hostile-input paths; the
    // same code elsewhere in the tree is not R3's business.
    let src = "pub fn first(b: &[u8]) -> u8 { b[0] }";
    assert_eq!(lint_source("src/compress/message.rs", src, &cfg).len(), 1);
    assert!(lint_source("src/topology/mod.rs", src, &cfg).is_empty());
    // Wall-key literals are R6 findings only at the obs emit sites.
    let src = "pub fn emit(o: &mut String) { o.push_str(\"\\\"wall_s\\\":\"); }";
    assert_eq!(lint_source("src/obs/mod.rs", src, &cfg).len(), 1);
    assert!(lint_source("src/metrics/mod.rs", src, &cfg).is_empty());
}

#[test]
fn unused_allow_entries_are_reported() {
    let cfg = LintConfig::from_toml_str(
        "[R1]\nallow1 = \"src/never_matches_anything.rs -- stale on purpose\"\n",
    )
    .unwrap();
    let report = analysis::lint_tree(
        &["tests/lint_fixtures/r4_missing_safety.rs".to_string()],
        &cfg,
    )
    .expect("scan fixture");
    assert_eq!(report.unused_allows.len(), 1);
    assert!(report.render_text().contains("stale allowlist entry"));
}
