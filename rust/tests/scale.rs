//! Scale-subsystem equivalence suite: the million-node machinery
//! (generator topologies, per-round node sampling, the strided consensus
//! estimator) must be **bitwise invisible** at small m, where we can
//! afford to run the materialized / unsampled / exact reference next to
//! it.  Every test here compares full trajectories by `f64::to_bits`,
//! not tolerances — the 48-scenario golden matrix stays byte-stable only
//! if these paths are exactly equal, not merely close.
//!
//! Layers covered (see docs/SCALE.md):
//!
//! * edge contract — `GenTopology` neighbor sets and Metropolis weights
//!   vs `Graph` + `MixingMatrix` at m ∈ 4..=64;
//! * driver — C²DFB / C²DFB(nc) runs with `scale.generator = true`
//!   bitwise equal to materialized runs, with and without sampling;
//! * engines — generator-capable topologies on the benign event engine
//!   reproduce the synchronous engine (materialized path);
//! * sampling — `sampling.rate = 1.0` is the identity, rates < 1 are
//!   deterministic and strictly cheaper;
//! * sweep — a generator + sampling grid is byte-identical at
//!   jobs ∈ {1, 2, max}.

use c2dfb::config::{Algorithm, ExperimentConfig};
use c2dfb::coordinator::{sweep, sweep::SweepSpec, Runner};
use c2dfb::metrics::RunMetrics;
use c2dfb::sim::NetMode;
use c2dfb::tasks::QuadraticTask;
use c2dfb::topology::{GenTopology, Graph, MixingMatrix, Neighborhood, Topology};

/// The generator-capable topology set (everything `GenTopology::supports`
/// accepts), at an m each variant is happy with.
fn gen_topologies() -> Vec<Topology> {
    vec![
        Topology::Ring,
        Topology::Exponential,
        Topology::Torus,
        Topology::RandomRegular { k: 4, seed: 23 },
    ]
}

fn quad_cfg(algo: Algorithm, m: usize, topology: Topology) -> ExperimentConfig {
    ExperimentConfig {
        algorithm: algo,
        nodes: m,
        topology,
        rounds: 4,
        inner_steps: 4,
        eta_out: 0.2,
        eta_in: 0.3,
        gamma_out: 0.8,
        gamma_in: 0.6,
        lambda: 50.0,
        compressor: "topk:0.5".into(),
        eval_every: 1,
        ..ExperimentConfig::default()
    }
}

fn run(task: &QuadraticTask, cfg: &ExperimentConfig) -> RunMetrics {
    Runner::new(cfg).task(task).run().expect("run")
}

fn trace_bits(m: &RunMetrics) -> Vec<(usize, u64, u64)> {
    m.trace
        .iter()
        .map(|p| (p.round, p.loss.to_bits(), p.grad_norm.to_bits()))
        .collect()
}

/// Bitwise run equality: trajectory, bytes, messages, virtual time.
fn assert_runs_identical(a: &RunMetrics, b: &RunMetrics, what: &str) {
    assert_eq!(trace_bits(a), trace_bits(b), "{what}: trajectory diverged");
    assert_eq!(a.ledger.total_bytes, b.ledger.total_bytes, "{what}: bytes");
    assert_eq!(a.ledger.messages, b.ledger.messages, "{what}: messages");
    assert_eq!(a.ledger.gossip_rounds, b.ledger.gossip_rounds, "{what}: rounds");
    assert_eq!(
        a.ledger.network_time_s.to_bits(),
        b.ledger.network_time_s.to_bits(),
        "{what}: virtual time"
    );
}

// ---------------------------------------------------------------------------
// Edge contract: generator vs materialized adjacency + mixing weights.
// ---------------------------------------------------------------------------

/// For every generator-capable topology and a spread of node counts in
/// 4..=64 (including awkward odd / prime m), the generator's neighbor
/// sets and Metropolis weights match `Graph::build` +
/// `MixingMatrix::metropolis` bitwise at every (i, j).
#[test]
fn generator_edge_contract_matches_materialized() {
    let cases: Vec<(Topology, Vec<usize>)> = vec![
        (Topology::Ring, vec![4, 5, 7, 16, 33, 64]),
        (Topology::Exponential, vec![4, 5, 9, 16, 33, 64]),
        (Topology::Torus, vec![4, 6, 9, 12, 16, 35, 64]),
        // Circulant rreg needs m > k; start above that floor.
        (Topology::RandomRegular { k: 4, seed: 23 }, vec![7, 11, 16, 33, 64]),
    ];
    for (topology, ms) in cases {
        for m in ms {
            let g = GenTopology::new(topology, m)
                .unwrap_or_else(|e| panic!("{}/{m}: {e}", topology.name()));
            let graph = Graph::build(topology, m);
            let mixing = MixingMatrix::metropolis(&graph);
            assert_eq!(g.node_count(), m);
            let mut nbrs = Vec::new();
            for i in 0..m {
                g.neighbors_into(i, &mut nbrs);
                assert_eq!(
                    nbrs,
                    graph.neighbors(i),
                    "{}/{m}: neighbor set of node {i}",
                    topology.name()
                );
                assert_eq!(
                    g.degree(i),
                    graph.degree(i),
                    "{}/{m}: degree of node {i}",
                    topology.name()
                );
                for j in 0..m {
                    assert_eq!(
                        g.mix_weight(i, j).to_bits(),
                        mixing.weight(i, j).to_bits(),
                        "{}/{m}: weight ({i}, {j})",
                        topology.name()
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Driver: generator transport ≡ materialized transport, all topologies,
// several node counts, with and without sampling.
// ---------------------------------------------------------------------------

/// Full C²DFB / C²DFB(nc) runs with the generator transport reproduce
/// the materialized transport bitwise across m ∈ {5, 16, 64} — the
/// range where both paths are affordable.  (m = 5 is skipped for the
/// torus/rreg variants that want more nodes; each m uses a task sized
/// to it.)
#[test]
fn generator_runs_match_materialized_across_node_counts() {
    for algo in [Algorithm::C2dfb, Algorithm::C2dfbNc] {
        for topology in gen_topologies() {
            for m in [5usize, 16, 64] {
                if GenTopology::new(topology, m).is_err() {
                    continue; // e.g. rreg:4 below its m floor
                }
                let task: QuadraticTask = QuadraticTask::generate(m, 6, 0.7, 90 + m as u64);
                let mut cfg = quad_cfg(algo, m, topology);
                let reference = run(&task, &cfg);
                cfg.scale.generator = true;
                let generated = run(&task, &cfg);
                assert_runs_identical(
                    &reference,
                    &generated,
                    &format!("{} {} m={m}", algo.name(), topology.name()),
                );
            }
        }
    }
}

/// The generator transport stays bitwise identical under per-round node
/// sampling — the interaction the million-node path actually runs
/// (implicit topology AND a sparse active set in the same round).
#[test]
fn generator_matches_materialized_under_sampling() {
    for algo in [Algorithm::C2dfb, Algorithm::C2dfbNc] {
        for topology in gen_topologies() {
            let m = 12;
            let task: QuadraticTask = QuadraticTask::generate(m, 6, 0.7, 131);
            let mut cfg = quad_cfg(algo, m, topology);
            cfg.sampling.rate = 0.5;
            let reference = run(&task, &cfg);
            cfg.scale.generator = true;
            let generated = run(&task, &cfg);
            assert_runs_identical(
                &reference,
                &generated,
                &format!("{} {} sampled", algo.name(), topology.name()),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Engines: the generator-capable topologies on the benign event engine.
// ---------------------------------------------------------------------------

/// Torus and random-regular circulants (the topologies this PR adds to
/// the generator set) reproduce the synchronous engine exactly on a
/// benign event-engine run, like the seed's ring/exp tests.
#[test]
fn new_generator_topologies_match_on_benign_event_engine() {
    for topology in [Topology::Torus, Topology::RandomRegular { k: 4, seed: 23 }] {
        for algo in [Algorithm::C2dfb, Algorithm::Madsbo] {
            let m = 9;
            let task: QuadraticTask = QuadraticTask::generate(m, 8, 0.8, 77);
            let cfg_sync = quad_cfg(algo, m, topology);
            let mut cfg_sim = quad_cfg(algo, m, topology);
            cfg_sim.network.mode = NetMode::Event;
            let a = run(&task, &cfg_sync);
            let b = run(&task, &cfg_sim);
            assert_eq!(trace_bits(&a), trace_bits(&b), "{} {}", algo.name(), topology.name());
            assert_eq!(a.ledger.total_bytes, b.ledger.total_bytes);
            assert_eq!(a.ledger.messages, b.ledger.messages);
            assert_eq!(b.ledger.dropped_messages, 0);
        }
    }
}

// ---------------------------------------------------------------------------
// Sampling: rate = 1.0 is the identity; rates < 1 are deterministic and
// strictly cheaper.
// ---------------------------------------------------------------------------

/// `sampling.rate = 1.0` must be bit-identical to a config that never
/// mentions sampling — no RNG consumed, no ledger drift.
#[test]
fn sampling_rate_one_is_the_identity() {
    for algo in [Algorithm::C2dfb, Algorithm::C2dfbNc, Algorithm::Madsbo] {
        let m = 8;
        let task: QuadraticTask = QuadraticTask::generate(m, 8, 0.8, 55);
        let cfg_default = quad_cfg(algo, m, Topology::Ring);
        let mut cfg_explicit = quad_cfg(algo, m, Topology::Ring);
        cfg_explicit.sampling.rate = 1.0;
        let a = run(&task, &cfg_default);
        let b = run(&task, &cfg_explicit);
        assert_runs_identical(&a, &b, &format!("{} rate=1.0", algo.name()));
    }
}

/// Sampled runs are deterministic (same seed ⇒ same bits) and pay
/// strictly fewer gossip bytes than the full-participation run.
#[test]
fn sampled_runs_are_deterministic_and_cheaper() {
    for algo in [Algorithm::C2dfb, Algorithm::C2dfbNc] {
        let m = 16;
        let task: QuadraticTask = QuadraticTask::generate(m, 6, 0.7, 201);
        let mut cfg = quad_cfg(algo, m, Topology::Exponential);
        let full = run(&task, &cfg);
        cfg.sampling.rate = 0.5;
        let s1 = run(&task, &cfg);
        let s2 = run(&task, &cfg);
        assert_runs_identical(&s1, &s2, &format!("{} sampled repeat", algo.name()));
        assert!(
            s1.ledger.total_bytes < full.ledger.total_bytes,
            "{}: sampled bytes {} !< full bytes {}",
            algo.name(),
            s1.ledger.total_bytes,
            full.ledger.total_bytes
        );
    }
}

// ---------------------------------------------------------------------------
// Sweep: generator + sampling grid, byte-identical at any job count.
// ---------------------------------------------------------------------------

/// A sweep over the generator transport with sampling enabled produces
/// byte-identical CSV/JSON reports at jobs ∈ {1, 2, max} — scale
/// features must not leak nondeterminism into the grid.
#[test]
fn generator_sampling_sweep_is_job_count_invariant() {
    let mut spec = SweepSpec::tiny();
    spec.algos = vec![Algorithm::C2dfb, Algorithm::C2dfbNc];
    spec.topologies = vec!["ring".into(), "exp".into()];
    spec.engines = vec![NetMode::Sync];
    spec.base.nodes = 6;
    spec.base.scale.generator = true;
    spec.base.sampling.rate = 0.75;

    let run_at = |jobs: usize| {
        let mut s = spec.clone();
        s.jobs = jobs;
        sweep::run(&s, false).expect("sweep run")
    };
    let (grid, reference) = run_at(1);
    assert!(!reference.is_empty(), "sweep produced no cells");
    // The scale tables must survive grid expansion (calibration included):
    // a cell silently running dense/unsampled would make this test vacuous.
    for c in &grid.cells {
        assert!(c.cfg.scale.generator, "cell {} lost scale.generator", c.id);
        assert_eq!(c.cfg.sampling.rate, 0.75, "cell {} lost sampling.rate", c.id);
    }
    assert!(
        reference.iter().all(|o| o.result.is_ok()),
        "generator + sampling grid must be clean"
    );
    let ref_csv = sweep::report_csv(&grid.cells, &reference);
    let ref_json = sweep::report_json(&grid.cells, &reference).to_string();
    for jobs in [2usize, 0] {
        let (g, outcomes) = run_at(jobs);
        assert_eq!(
            sweep::diff_outcomes(&reference, &outcomes),
            None,
            "jobs={jobs}: outcomes diverged from serial run"
        );
        assert_eq!(ref_csv, sweep::report_csv(&g.cells, &outcomes), "jobs={jobs}: csv");
        assert_eq!(
            ref_json,
            sweep::report_json(&g.cells, &outcomes).to_string(),
            "jobs={jobs}: json"
        );
    }
}
