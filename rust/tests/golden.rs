//! Golden-trace acceptance tests: the full 4 algorithms × 3 tasks ×
//! 2 topologies × 2 engines matrix replays against the committed fixtures
//! under `rust/goldens/` (exact bytes/oracle counts, 1e-9-relative
//! losses), blessing is byte-identical across repeated runs, and the
//! benign-sim fixtures agree with their sync twins.

use c2dfb::goldens::{self, Engine, TaskKind};

/// Replay against the committed fixtures.  On a checkout that has never
/// been blessed (no toolchain ran here yet) the fixtures are bootstrapped
/// in place — commit them; every later run then enforces them.
#[test]
fn full_matrix_replays_against_committed_fixtures() {
    let dir = goldens::default_dir();
    let report = goldens::replay(&dir, 1).expect("replay failed to run");
    for p in &report.bootstrapped {
        eprintln!(
            "NOTE: bootstrapped golden fixture {} — commit it to pin behavior",
            p.display()
        );
    }
    assert!(
        report.ok(),
        "golden-trace drift ({} mismatches):\n  {}",
        report.mismatches.len(),
        report.mismatches.join("\n  ")
    );
    if report.bootstrapped.is_empty() {
        assert_eq!(report.checked, 48, "matrix must cover all 48 scenarios");
    }
}

/// Blessing twice into different directories — serially the first time,
/// on a 4-worker sweep pool the second — produces byte-identical files:
/// the whole pipeline (data generation, partitioning, algorithms,
/// transports, serialization) is deterministic at any parallelism.
#[test]
fn bless_is_byte_identical_across_runs() {
    let base = std::env::temp_dir().join("c2dfb_goldens_determinism");
    let (d1, d2) = (base.join("a"), base.join("b"));
    for d in [&d1, &d2] {
        let _ = std::fs::remove_dir_all(d);
    }
    let w1 = goldens::bless(&d1, 1).expect("first bless");
    let w2 = goldens::bless(&d2, 4).expect("second bless");
    assert_eq!(w1.len(), 3);
    assert_eq!(w2.len(), 3);
    for (a, b) in w1.iter().zip(&w2) {
        let ba = std::fs::read(a).unwrap();
        let bb = std::fs::read(b).unwrap();
        assert_eq!(
            ba,
            bb,
            "bless must be deterministic: {} differs from {}",
            a.display(),
            b.display()
        );
        assert!(!ba.is_empty());
    }
}

/// A freshly blessed directory replays clean against itself (the diff
/// logic's tolerances accept the serialization round-trip).
#[test]
fn fresh_bless_replays_clean() {
    let dir = std::env::temp_dir().join("c2dfb_goldens_selfcheck");
    let _ = std::fs::remove_dir_all(&dir);
    goldens::bless(&dir, 1).expect("bless");
    let report = goldens::replay(&dir, 2).expect("replay");
    assert!(report.bootstrapped.is_empty());
    assert_eq!(report.checked, 48);
    assert!(report.ok(), "self-replay drift: {:?}", report.mismatches);
}

/// The benign event engine must reproduce the synchronous engine exactly —
/// per scenario pair, same byte totals and bit-identical losses.  This
/// pins PR1's equivalence guarantee inside the golden matrix itself.
#[test]
fn sync_and_benign_sim_scenarios_agree() {
    for task in TaskKind::ALL {
        let t = task.build();
        for s in goldens::matrix().into_iter().filter(|s| {
            s.task == task && s.engine == Engine::Sync
        }) {
            let mut twin = s;
            twin.engine = Engine::BenignSim;
            let a = goldens::run_scenario(t.as_ref(), &s).unwrap();
            let b = goldens::run_scenario(t.as_ref(), &twin).unwrap();
            assert_eq!(
                a.ledger.total_bytes,
                b.ledger.total_bytes,
                "{}: sync vs benign-sim bytes",
                s.id()
            );
            let la: Vec<u64> = a.trace.iter().map(|p| p.loss.to_bits()).collect();
            let lb: Vec<u64> = b.trace.iter().map(|p| p.loss.to_bits()).collect();
            assert_eq!(la, lb, "{}: sync vs benign-sim loss bits", s.id());
        }
    }
}

/// Corrupting a fixture field is caught by replay (the harness actually
/// bites): flip one loss value beyond tolerance and expect a mismatch.
#[test]
fn replay_detects_injected_drift() {
    use c2dfb::util::json::Json;

    let dir = std::env::temp_dir().join("c2dfb_goldens_drift");
    let _ = std::fs::remove_dir_all(&dir);
    goldens::bless(&dir, 1).expect("bless");
    let path = dir.join("quadratic.json");
    let text = std::fs::read_to_string(&path).unwrap();
    let mut doc = Json::parse(&text).unwrap();
    // Mutate the first scenario's first trace loss by 1% (≫ 1e-9).
    if let Json::Obj(top) = &mut doc {
        let scenarios = top.get_mut("scenarios").unwrap();
        if let Json::Obj(scn) = scenarios {
            let first = scn.values_mut().next().unwrap();
            if let Json::Obj(run) = first {
                if let Json::Arr(trace) = run.get_mut("trace").unwrap() {
                    if let Json::Obj(point) = &mut trace[0] {
                        let loss = point.get_mut("loss").unwrap();
                        let v = loss.as_f64().unwrap();
                        *loss = Json::num(v * 1.01 + 0.01);
                    }
                }
            }
        }
    }
    std::fs::write(&path, doc.to_string() + "\n").unwrap();
    let report = goldens::replay(&dir, 2).expect("replay");
    assert!(
        !report.ok(),
        "injected drift must be detected by the replay diff"
    );
    assert!(
        report.mismatches.iter().any(|m| m.contains("loss")),
        "mismatch should name the drifted field: {:?}",
        report.mismatches
    );
}
