//! Offline stand-in for the PJRT backend (default build, no `pjrt`
//! feature).  The manifest is still parsed — artifact listing, shape
//! queries and `preset_dim` work — but executing an oracle reports that
//! the backend is unavailable.  Everything that doesn't need artifacts
//! (the analytic tasks, the sim engine, `c2dfb netsweep`) runs unchanged.

use super::manifest::{EntrySpec, Manifest};
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};
use std::rc::Rc;

const NO_PJRT: &str = "built without the `pjrt` feature — PJRT-backed oracles are unavailable \
(rebuild with `cargo build --features pjrt`); analytic tasks and `c2dfb netsweep` work without it";

/// A staged (device-resident) input buffer.  Never constructed in the stub.
pub struct Staged {
    pub len: usize,
}

/// One argument to an oracle call.
pub enum Arg<'a> {
    /// Host data, uploaded at call time.
    Host(&'a [f32]),
    /// Scalar (f32[] in the artifact signature).
    Scalar(f32),
    /// Pre-staged device buffer (zero upload on the hot path).
    Staged(&'a Staged),
}

/// Manifest entry without a compiled executable behind it.
pub struct Oracle {
    pub name: String,
    pub spec: EntrySpec,
}

impl Oracle {
    pub fn call(&self, _args: &[Arg]) -> Result<Vec<Vec<f32>>> {
        bail!("{}: {NO_PJRT}", self.name)
    }

    pub fn stage(&self, _data: &[f32], _shape: &[usize]) -> Result<Staged> {
        bail!("{}: {NO_PJRT}", self.name)
    }
}

/// Manifest-only registry: `open`/`preset_dim`/`has_preset` work, `load`
/// fails with a clear message.
pub struct ArtifactRegistry {
    pub root: PathBuf,
    pub manifest: Manifest,
}

impl ArtifactRegistry {
    pub fn open(root: &Path) -> Result<ArtifactRegistry> {
        let manifest_path = root.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "cannot read {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let manifest = Manifest::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        Ok(ArtifactRegistry { root: root.to_path_buf(), manifest })
    }

    /// Default repo location (env `C2DFB_ARTIFACTS` overrides).
    pub fn open_default() -> Result<ArtifactRegistry> {
        Self::open(&super::default_root())
    }

    /// Look the key up (so unknown artifacts still error precisely), then
    /// report the missing backend.
    pub fn load(&self, key: &str) -> Result<Rc<Oracle>> {
        if !self.manifest.entries.contains_key(key) {
            bail!(
                "artifact {key:?} not in manifest ({} entries)",
                self.manifest.entries.len()
            );
        }
        bail!("artifact {key:?}: {NO_PJRT}")
    }

    /// Preset metadata (dims) recorded by the AOT pipeline.
    pub fn preset_dim(&self, preset: &str, dim: &str) -> Result<usize> {
        self.manifest
            .preset_dims
            .get(preset)
            .and_then(|d| d.get(dim))
            .copied()
            .ok_or_else(|| anyhow!("preset {preset:?} has no dim {dim:?}"))
    }

    pub fn has_preset(&self, preset: &str) -> bool {
        self.manifest.preset_dims.contains_key(preset)
    }
}
