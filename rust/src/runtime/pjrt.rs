//! The real PJRT backend (cargo feature `pjrt`), wrapping the `xla` crate.

// Oracle cache: String-keyed get/insert only, never iterated, so hash
// order can't leak into results (lint.toml R2 allow1).
#![allow(clippy::disallowed_types)]

use super::manifest::{EntrySpec, Manifest};
use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// A staged (device-resident) input buffer.
pub struct Staged {
    buf: xla::PjRtBuffer,
    pub len: usize,
}

/// One argument to an oracle call.
pub enum Arg<'a> {
    /// Host data, uploaded at call time.
    Host(&'a [f32]),
    /// Scalar (f32[] in the artifact signature).
    Scalar(f32),
    /// Pre-staged device buffer (zero upload on the hot path).
    Staged(&'a Staged),
}

/// A compiled artifact plus its manifest spec.
pub struct Oracle {
    pub name: String,
    pub spec: EntrySpec,
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    n_outputs: usize,
}

impl Oracle {
    /// Execute with the given args; returns one flat f32 vector per output.
    pub fn call(&self, args: &[Arg]) -> Result<Vec<Vec<f32>>> {
        if args.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} args, got {}",
                self.name,
                self.spec.inputs.len(),
                args.len()
            );
        }
        // First pass: upload host/scalar args (owned buffers).
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(args.len());
        let mut owned_slots: Vec<Option<usize>> = Vec::with_capacity(args.len());
        for (i, (arg, spec)) in args.iter().zip(&self.spec.inputs).enumerate() {
            match arg {
                Arg::Host(data) => {
                    if data.len() != spec.elements() {
                        bail!(
                            "{}: arg {i} has {} elements, artifact expects {:?}",
                            self.name,
                            data.len(),
                            spec.shape
                        );
                    }
                    let buf = self
                        .client
                        .buffer_from_host_buffer::<f32>(data, &spec.shape, None)
                        .map_err(|e| anyhow!("{}: upload arg {i}: {e:?}", self.name))?;
                    bufs.push(buf);
                    owned_slots.push(Some(bufs.len() - 1));
                }
                Arg::Scalar(v) => {
                    if !spec.shape.is_empty() {
                        bail!("{}: arg {i} is not scalar in the artifact", self.name);
                    }
                    let buf = self
                        .client
                        .buffer_from_host_buffer::<f32>(std::slice::from_ref(v), &[], None)
                        .map_err(|e| anyhow!("{}: upload scalar {i}: {e:?}", self.name))?;
                    bufs.push(buf);
                    owned_slots.push(Some(bufs.len() - 1));
                }
                Arg::Staged(s) => {
                    if s.len != spec.elements() {
                        bail!(
                            "{}: staged arg {i} has {} elements, artifact expects {:?}",
                            self.name,
                            s.len,
                            spec.shape
                        );
                    }
                    owned_slots.push(None);
                }
            }
        }
        // Second pass: build the borrowed, ordered argument list.
        let mut ordered: Vec<&xla::PjRtBuffer> = Vec::with_capacity(args.len());
        for (arg, slot) in args.iter().zip(&owned_slots) {
            match (arg, slot) {
                (Arg::Staged(s), None) => ordered.push(&s.buf),
                (_, Some(ix)) => ordered.push(&bufs[*ix]),
                _ => unreachable!(),
            }
        }
        let outs = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&ordered)
            .map_err(|e| anyhow!("{}: execute: {e:?}", self.name))?;
        // AOT lowers with return_tuple=True: one tuple buffer out.
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: download: {e:?}", self.name))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("{}: untuple: {e:?}", self.name))?;
        if parts.len() != self.n_outputs {
            bail!(
                "{}: expected {} outputs, got {}",
                self.name,
                self.n_outputs,
                parts.len()
            );
        }
        let mut result = Vec::with_capacity(parts.len());
        for (p, ospec) in parts.into_iter().zip(&self.spec.outputs) {
            let v = p
                .to_vec::<f32>()
                .map_err(|e| anyhow!("{}: output to_vec: {e:?}", self.name))?;
            if v.len() != ospec.elements() {
                bail!(
                    "{}: output has {} elements, manifest says {:?}",
                    self.name,
                    v.len(),
                    ospec.shape
                );
            }
            result.push(v);
        }
        Ok(result)
    }

    /// Upload a tensor once; reuse across calls via [`Arg::Staged`].
    pub fn stage(&self, data: &[f32], shape: &[usize]) -> Result<Staged> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("stage: {} elements vs shape {:?}", data.len(), shape);
        }
        let buf = self
            .client
            .buffer_from_host_buffer::<f32>(data, shape, None)
            .map_err(|e| anyhow!("stage: {e:?}"))?;
        Ok(Staged { buf, len: data.len() })
    }
}

/// Lazily-compiling registry over the AOT manifest.
pub struct ArtifactRegistry {
    pub root: PathBuf,
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<Oracle>>>,
}

impl ArtifactRegistry {
    /// Open `root/manifest.json` and create the CPU PJRT client.
    pub fn open(root: &Path) -> Result<ArtifactRegistry> {
        let manifest_path = root.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "cannot read {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let manifest = Manifest::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(ArtifactRegistry {
            root: root.to_path_buf(),
            manifest,
            client,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Default repo location (env `C2DFB_ARTIFACTS` overrides).
    pub fn open_default() -> Result<ArtifactRegistry> {
        Self::open(&super::default_root())
    }

    /// Load (compile-once) an oracle by manifest key, e.g. "coeff.inner_y".
    pub fn load(&self, key: &str) -> Result<Rc<Oracle>> {
        if let Some(o) = self.cache.borrow().get(key) {
            return Ok(o.clone());
        }
        let spec = self
            .manifest
            .entries
            .get(key)
            .ok_or_else(|| {
                anyhow!(
                    "artifact {key:?} not in manifest ({} entries)",
                    self.manifest.entries.len()
                )
            })?
            .clone();
        let path = self.root.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("{key}: parse HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("{key}: XLA compile: {e:?}"))?;
        let oracle = Rc::new(Oracle {
            name: key.to_string(),
            n_outputs: spec.outputs.len(),
            spec,
            exe,
            client: self.client.clone(),
        });
        self.cache.borrow_mut().insert(key.to_string(), oracle.clone());
        Ok(oracle)
    }

    /// Preset metadata (dims) recorded by the AOT pipeline.
    pub fn preset_dim(&self, preset: &str, dim: &str) -> Result<usize> {
        self.manifest
            .preset_dims
            .get(preset)
            .and_then(|d| d.get(dim))
            .copied()
            .ok_or_else(|| anyhow!("preset {preset:?} has no dim {dim:?}"))
    }

    pub fn has_preset(&self, preset: &str) -> bool {
        self.manifest.preset_dims.contains_key(preset)
    }
}
