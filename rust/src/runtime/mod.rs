//! PJRT runtime: load AOT HLO-text artifacts and execute them from Rust.
//!
//! Two interchangeable backends behind the same API:
//!
//! * With the `pjrt` cargo feature (`--features pjrt`), [`pjrt`] wraps the
//!   `xla` crate (PJRT C API): `PjRtClient::cpu()` →
//!   `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//!   Interchange is HLO **text** (see python/compile/aot.py for why).
//! * Without it (the default, offline build), [`stub`] still reads the
//!   manifest — so artifact listing and shape queries work — but every
//!   oracle call reports that the backend is unavailable.  The analytic
//!   tasks, the sim subsystem, and `c2dfb netsweep` never touch PJRT and
//!   work in both builds.
//!
//! Design points:
//! * [`ArtifactRegistry`] reads `artifacts/manifest.json` (shapes/dtypes per
//!   entry) and lazily compiles executables, caching them by entry name.
//! * [`Oracle::call`] marshals flat `f32` slices; static per-node inputs
//!   (data shards) should be staged once as device buffers via
//!   [`Oracle::stage`] and passed as [`Arg::Staged`] — the hot path then
//!   only uploads the (much smaller) parameter vectors.

pub mod manifest;

pub use manifest::{EntrySpec, Manifest, TensorSpec};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{Arg, ArtifactRegistry, Oracle, Staged};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Arg, ArtifactRegistry, Oracle, Staged};

use std::path::PathBuf;

/// Default artifacts root: env `C2DFB_ARTIFACTS` overrides; otherwise walk
/// up from the CWD so tests/benches work from any target dir.
pub(crate) fn default_root() -> PathBuf {
    std::env::var("C2DFB_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
            loop {
                let cand = dir.join("artifacts");
                if cand.join("manifest.json").exists() {
                    return cand;
                }
                if !dir.pop() {
                    return PathBuf::from("artifacts");
                }
            }
        })
}
