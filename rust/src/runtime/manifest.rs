//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).

use crate::util::json::Json;
use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec, String> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or("tensor spec missing shape")?
            .iter()
            .map(|d| d.as_usize().ok_or("bad dim"))
            .collect::<Result<Vec<_>, _>>()?;
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or("tensor spec missing dtype")?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub kernels: String,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: BTreeMap<String, EntrySpec>,
    /// preset name → dim name → value (e.g. "coeff" → "dx" → 2000).
    pub preset_dims: BTreeMap<String, BTreeMap<String, usize>>,
    /// preset name → kernel backend ("pallas" | "jnp").
    pub preset_kernels: BTreeMap<String, String>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest, String> {
        let root = Json::parse(text)?;
        let mut m = Manifest::default();
        let entries = root
            .get("entries")
            .and_then(Json::as_obj)
            .ok_or("manifest missing entries")?;
        for (key, e) in entries {
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .ok_or(format!("{key}: missing file"))?
                .to_string();
            let parse_list = |name: &str| -> Result<Vec<TensorSpec>, String> {
                e.get(name)
                    .and_then(Json::as_arr)
                    .ok_or(format!("{key}: missing {name}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            m.entries.insert(
                key.clone(),
                EntrySpec {
                    file,
                    inputs: parse_list("inputs")?,
                    outputs: parse_list("outputs")?,
                    kernels: e
                        .get("kernels")
                        .and_then(Json::as_str)
                        .unwrap_or("unknown")
                        .to_string(),
                },
            );
        }
        if let Some(presets) = root.get("presets").and_then(Json::as_obj) {
            for (name, p) in presets {
                let mut dims = BTreeMap::new();
                if let Some(d) = p.get("dims").and_then(Json::as_obj) {
                    for (k, v) in d {
                        if let Some(n) = v.as_usize() {
                            dims.insert(k.clone(), n);
                        }
                    }
                }
                m.preset_dims.insert(name.clone(), dims);
                if let Some(k) = p.get("kernels").and_then(Json::as_str) {
                    m.preset_kernels.insert(name.clone(), k.to_string());
                }
            }
        }
        Ok(m)
    }

    /// All entry keys under a preset prefix (e.g. "coeff.").
    pub fn preset_entries(&self, preset: &str) -> Vec<&str> {
        let prefix = format!("{preset}.");
        self.entries
            .keys()
            .filter(|k| k.starts_with(&prefix))
            .map(String::as_str)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "entries": {
        "demo.affine": {
          "file": "demo/affine.hlo.txt",
          "inputs": [{"shape": [8, 8], "dtype": "float32"},
                     {"shape": [8, 8], "dtype": "float32"}],
          "outputs": [{"shape": [8, 8], "dtype": "float32"}],
          "kernels": "jnp"
        },
        "coeff.hyper": {
          "file": "coeff/hyper.hlo.txt",
          "inputs": [{"shape": [2000], "dtype": "float32"},
                     {"shape": [20000], "dtype": "float32"},
                     {"shape": [20000], "dtype": "float32"},
                     {"shape": [], "dtype": "float32"}],
          "outputs": [{"shape": [2000], "dtype": "float32"}],
          "kernels": "pallas"
        }
      },
      "presets": {
        "coeff": {"task": "coeff", "kernels": "pallas",
                  "dims": {"dx": 2000, "dy": 20000, "features": 2000}}
      }
    }"#;

    #[test]
    fn parses_entries_and_presets() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = &m.entries["coeff.hyper"];
        assert_eq!(e.inputs.len(), 4);
        assert_eq!(e.inputs[3].shape, Vec::<usize>::new());
        assert_eq!(e.inputs[3].elements(), 1);
        assert_eq!(e.outputs[0].elements(), 2000);
        assert_eq!(m.preset_dims["coeff"]["dy"], 20000);
        assert_eq!(m.preset_kernels["coeff"], "pallas");
    }

    #[test]
    fn preset_entry_listing() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.preset_entries("coeff"), vec!["coeff.hyper"]);
        assert_eq!(m.preset_entries("demo"), vec!["demo.affine"]);
        assert!(m.preset_entries("nope").is_empty());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"entries": {"x": {"file": "f"}}}"#).is_err());
    }
}
