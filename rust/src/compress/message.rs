//! Compressed-message payload encodings and their exact wire sizes —
//! plus the real binary wire codec ([`Payload::encode`] /
//! [`Payload::decode`]) the `c2dfb serve` daemon lineage needs before any
//! byte from an untrusted client may reach the gossip fold.
//!
//! Payloads are generic over the wire [`Scalar`] `S`: the first byte of
//! every encoding is `payload kind + S::WIRE_OFFSET`, so the tag doubles
//! as a dtype tag — f32 payloads use tags 0..=3 (the historical,
//! golden-pinned format, byte-identical to the pre-dtype codec), f64
//! payloads use 4..=7.  A decoder instantiated at one dtype rejects the
//! other dtype's tags with a clean `Err` ("dtype mismatch"), never by
//! misreading lengths: the count/body arithmetic below never runs before
//! the tag has pinned the element width.
//!
//! The decode path treats its input as hostile: truncated payloads,
//! oversized counts, inconsistent lengths, out-of-range indices,
//! non-finite headers and wrong-dtype or unknown tags all return a clean
//! `Err` — never a panic, never an over-read, never an attacker-sized
//! allocation (see [`MAX_WIRE_COORDS`]).  `tests/proptests.rs` feeds it
//! random byte strings and mutated valid encodings to hold that line.

// Toolchain-native twin of lint rule R3 (panic-free decode); `c2dfb
// lint` enforces the same contract lexically.  docs/LINT.md.
#![warn(clippy::unwrap_used, clippy::expect_used, clippy::panic)]

use crate::linalg::kernels;
use crate::linalg::scalar::{Dtype, Scalar};

/// The on-the-wire representation of a compressed vector.  The byte counts
/// model a straightforward binary encoding; no actual serialization happens
/// in the in-process simulator, but the sizes feed the communication-volume
/// ledger, which is the paper's headline metric.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload<S: Scalar = f32> {
    /// Raw scalar values (`S::BYTES` B/coord).
    Dense(Vec<S>),
    /// Coordinate list: index + scalar value.  Indices are modeled at the
    /// narrowest width that covers the max index (u16 below 65536, u32
    /// above), as a real wire encoder would emit.
    Sparse { idx: Vec<u32>, val: Vec<S> },
    /// QSGD: one scalar norm + i16 signed level codes (2 B/coord).
    Quantized { norm: S, levels: u32, codes: Vec<i16> },
}

/// Coarse payload classification, used by the telemetry layer's
/// per-compressor encode counters ([`crate::obs`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PayloadKind {
    Dense,
    Sparse,
    Quantized,
}

impl PayloadKind {
    /// Wire-tag base of this kind (the dtype offset is added on top).
    fn tag_base(self) -> u8 {
        match self {
            PayloadKind::Dense => TAG_DENSE,
            PayloadKind::Sparse => TAG_SPARSE16,
            PayloadKind::Quantized => TAG_QUANTIZED,
        }
    }
}

impl<S: Scalar> Payload<S> {
    pub fn kind(&self) -> PayloadKind {
        match self {
            Payload::Dense(_) => PayloadKind::Dense,
            Payload::Sparse { .. } => PayloadKind::Sparse,
            Payload::Quantized { .. } => PayloadKind::Quantized,
        }
    }

    pub fn payload_bytes(&self) -> usize {
        match self {
            Payload::Dense(v) => S::BYTES * v.len(),
            Payload::Sparse { idx, val } => {
                // Width from the MAX index, not the last: the encoding must
                // bill correctly even if a producer ever emits indices out
                // of order (the canonical encoders sort, but the byte model
                // must not under-bill if that invariant slips).
                let max = idx.iter().copied().max().unwrap_or(0);
                let idx_width = if max < 65_536 { 2 } else { 4 };
                idx_width * idx.len() + S::BYTES * val.len()
            }
            Payload::Quantized { codes, .. } => S::BYTES + 4 + 2 * codes.len(),
        }
    }

    /// Reuse `self` as a `Dense` payload, returning its cleared value
    /// buffer (allocation-free once the variant and capacity are warm).
    pub(crate) fn reuse_dense(&mut self) -> &mut Vec<S> {
        if !matches!(self, Payload::Dense(_)) {
            *self = Payload::Dense(Vec::new());
        }
        match self {
            Payload::Dense(v) => {
                v.clear();
                v
            }
            _ => unreachable!(),
        }
    }

    /// Reuse `self` as a `Sparse` payload, returning its cleared index and
    /// value buffers.
    pub(crate) fn reuse_sparse(&mut self) -> (&mut Vec<u32>, &mut Vec<S>) {
        if !matches!(self, Payload::Sparse { .. }) {
            *self = Payload::Sparse { idx: Vec::new(), val: Vec::new() };
        }
        match self {
            Payload::Sparse { idx, val } => {
                idx.clear();
                val.clear();
                (idx, val)
            }
            _ => unreachable!(),
        }
    }

    /// Reuse `self` as a `Quantized` payload with the given header fields,
    /// returning its cleared code buffer.
    pub(crate) fn reuse_quantized(&mut self, norm: S, levels: u32) -> &mut Vec<i16> {
        if !matches!(self, Payload::Quantized { .. }) {
            *self = Payload::Quantized { norm, levels, codes: Vec::new() };
        }
        match self {
            Payload::Quantized { norm: n, levels: l, codes } => {
                *n = norm;
                *l = levels;
                codes.clear();
                codes
            }
            _ => unreachable!(),
        }
    }

    /// Number of degrees of freedom actually transmitted.
    pub fn nnz(&self) -> usize {
        match self {
            Payload::Dense(v) => v.len(),
            Payload::Sparse { idx, .. } => idx.len(),
            Payload::Quantized { codes, .. } => codes.len(),
        }
    }

    pub fn write_dense(&self, out: &mut [S]) {
        match self {
            Payload::Dense(v) => {
                // zip, not copy_from_slice: a decoded dense payload may
                // claim a different dim than the receiver's buffer, and
                // copy_from_slice panics on mismatch (R3).
                debug_assert_eq!(v.len(), out.len(), "dense payload dim mismatch");
                out.fill(S::ZERO);
                for (o, &x) in out.iter_mut().zip(v) {
                    *o = x;
                }
            }
            Payload::Sparse { idx, val } => {
                out.fill(S::ZERO);
                kernels::scatter_write(idx, val, out);
            }
            Payload::Quantized { norm, levels, codes } => {
                let scale = *norm / S::from_u32(*levels);
                kernels::dequant_write(scale, codes, out);
            }
        }
    }

    pub fn add_dense(&self, target: &mut [S]) {
        self.add_scaled_dense(S::ONE, target);
    }

    pub fn add_scaled_dense(&self, w: S, target: &mut [S]) {
        match self {
            Payload::Dense(v) => kernels::dense_add_scaled(w, v, target),
            Payload::Sparse { idx, val } => kernels::scatter_add_scaled(w, idx, val, target),
            Payload::Quantized { norm, levels, codes } => {
                let scale = w * *norm / S::from_u32(*levels);
                kernels::dequant_add(scale, codes, target);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// wire codec
// ---------------------------------------------------------------------------

/// Hard cap on the coordinate count any single decoded payload may claim.
/// A 4-byte length field can demand a 16 GiB allocation before the first
/// value byte is read; rejecting counts above this bound keeps a hostile
/// header from becoming a memory bomb.  2²⁴ coordinates (64 MiB of f32s,
/// 128 MiB of f64s) comfortably covers every dimension this repo
/// simulates.
pub const MAX_WIRE_COORDS: u32 = 1 << 24;

/// Wire-tag kind bases (the first byte of every encoded payload is
/// `base + Scalar::WIRE_OFFSET`: f32 → 0..=3, f64 → 4..=7).
const TAG_DENSE: u8 = 0;
const TAG_SPARSE16: u8 = 1;
const TAG_SPARSE32: u8 = 2;
const TAG_QUANTIZED: u8 = 3;
/// Number of kind tags per dtype block.
const TAG_KINDS: u8 = 4;
/// First tag value outside any dtype block (f32 0..=3, f64 4..=7).
const TAG_LIMIT: u8 = 2 * TAG_KINDS;

/// Bounds-checked little-endian reader over untrusted bytes.  Every read
/// goes through [`Reader::take`], so an over-read is impossible by
/// construction: the only failure mode is a clean `Err`.
struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        // checked_add + get: no arithmetic here can wrap and no slice
        // indexing can panic, whatever n a hostile header claims.
        let end = self
            .i
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| {
                format!(
                    "truncated payload: wanted {n} bytes at offset {}, have {}",
                    self.i,
                    self.b.len().saturating_sub(self.i)
                )
            })?;
        let s = self
            .b
            .get(self.i..end)
            .ok_or_else(|| "reader range out of bounds".to_string())?;
        self.i = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        self.take(1)?
            .first()
            .copied()
            .ok_or_else(|| "empty u8 read".to_string())
    }

    fn u16(&mut self) -> Result<u16, String> {
        let s: [u8; 2] = self
            .take(2)?
            .try_into()
            .map_err(|_| "short u16 read".to_string())?;
        Ok(u16::from_le_bytes(s))
    }

    fn u32(&mut self) -> Result<u32, String> {
        let s: [u8; 4] = self
            .take(4)?
            .try_into()
            .map_err(|_| "short u32 read".to_string())?;
        Ok(u32::from_le_bytes(s))
    }

    fn scalar<S: Scalar>(&mut self) -> Result<S, String> {
        let s = self.take(S::BYTES)?;
        S::read_le(s).ok_or_else(|| "short scalar read".to_string())
    }

    fn i16(&mut self) -> Result<i16, String> {
        let s: [u8; 2] = self
            .take(2)?
            .try_into()
            .map_err(|_| "short i16 read".to_string())?;
        Ok(i16::from_le_bytes(s))
    }

    fn done(&self) -> Result<(), String> {
        if self.i != self.b.len() {
            return Err(format!(
                "{} trailing bytes after payload end",
                self.b.len() - self.i
            ));
        }
        Ok(())
    }
}

/// Validate a decoded element count against both the global cap and the
/// bytes actually present (`elem_bytes` per element still unread).
fn checked_count(n: u32, remaining: usize, elem_bytes: usize) -> Result<usize, String> {
    if n > MAX_WIRE_COORDS {
        return Err(format!("count {n} exceeds MAX_WIRE_COORDS ({MAX_WIRE_COORDS})"));
    }
    let need = (n as usize).checked_mul(elem_bytes).ok_or("count overflow")?;
    if need > remaining {
        return Err(format!(
            "inconsistent length: count {n} needs {need} bytes, only {remaining} remain"
        ));
    }
    Ok(n as usize)
}

impl<S: Scalar> Payload<S> {
    /// Serialize into `out` (appended; caller clears for reuse).  The
    /// format is little-endian and mirrors [`payload_bytes`]'s cost
    /// model: `tag u8 · count u32 · body`, with sparse indices at the
    /// narrowest width covering the max index, exactly as billed.  The
    /// tag carries the dtype (`kind + S::WIRE_OFFSET`); the f32 encoding
    /// is byte-identical to the historical untagged-dtype format.
    ///
    /// [`payload_bytes`]: Payload::payload_bytes
    pub fn encode(&self, out: &mut Vec<u8>) {
        match self {
            Payload::Dense(v) => {
                out.push(TAG_DENSE + S::WIRE_OFFSET);
                out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                for x in v {
                    x.write_le(out);
                }
            }
            Payload::Sparse { idx, val } => {
                let max = idx.iter().copied().max().unwrap_or(0);
                let wide = max >= 65_536;
                out.push(if wide { TAG_SPARSE32 } else { TAG_SPARSE16 } + S::WIRE_OFFSET);
                out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
                for &i in idx {
                    if wide {
                        out.extend_from_slice(&i.to_le_bytes());
                    } else {
                        out.extend_from_slice(&(i as u16).to_le_bytes());
                    }
                }
                for x in val {
                    x.write_le(out);
                }
            }
            Payload::Quantized { norm, levels, codes } => {
                out.push(TAG_QUANTIZED + S::WIRE_OFFSET);
                out.extend_from_slice(&(codes.len() as u32).to_le_bytes());
                norm.write_le(out);
                out.extend_from_slice(&levels.to_le_bytes());
                for &c in codes {
                    out.extend_from_slice(&c.to_le_bytes());
                }
            }
        }
    }

    /// Exact length [`encode`](Payload::encode) will append.
    pub fn encoded_len(&self) -> usize {
        match self {
            Payload::Dense(v) => 1 + 4 + S::BYTES * v.len(),
            Payload::Sparse { idx, val } => {
                let max = idx.iter().copied().max().unwrap_or(0);
                let w = if max >= 65_536 { 4 } else { 2 };
                1 + 4 + w * idx.len() + S::BYTES * val.len()
            }
            Payload::Quantized { codes, .. } => 1 + 4 + S::BYTES + 4 + 2 * codes.len(),
        }
    }

    /// Decode an untrusted byte string at this dtype.  Structural
    /// failures — unknown tag, a tag of the *other* dtype ("dtype
    /// mismatch": an f32 payload must not decode into an f64 contract or
    /// vice versa), truncation, counts that disagree with the bytes
    /// present, trailing garbage, a count above [`MAX_WIRE_COORDS`],
    /// unsorted or duplicate sparse indices, a quantized header with
    /// `levels` outside `1..=32767` or a non-finite norm — all return
    /// `Err`.  Dimension agreement is the caller's contract: use
    /// [`decode_for_dim`](Payload::decode_for_dim) before folding a
    /// payload into `d`-length state.
    pub fn decode(bytes: &[u8]) -> Result<Payload<S>, String> {
        let mut r = Reader { b: bytes, i: 0 };
        let tag = r.u8().map_err(|_| "empty payload".to_string())?;
        if tag >= TAG_LIMIT {
            return Err(format!("unknown payload tag {tag}"));
        }
        // The tag pins the wire dtype before any length arithmetic runs:
        // a wrong-dtype payload is rejected here, never misread with the
        // wrong element width.
        let wire_dtype = if tag < TAG_KINDS { Dtype::F32 } else { Dtype::F64 };
        if wire_dtype != S::DTYPE {
            return Err(format!(
                "payload dtype mismatch: wire carries {wire_dtype}, decoder expects {}",
                S::NAME
            ));
        }
        let kind = tag - S::WIRE_OFFSET;
        let n_raw = r.u32()?;
        let remaining = bytes.len() - r.i;
        let p = match kind {
            TAG_DENSE => {
                let n = checked_count(n_raw, remaining, S::BYTES)?;
                let mut v = Vec::with_capacity(n);
                for _ in 0..n {
                    v.push(r.scalar::<S>()?);
                }
                Payload::Dense(v)
            }
            TAG_SPARSE16 | TAG_SPARSE32 => {
                let wide = kind == TAG_SPARSE32;
                let iw = if wide { 4 } else { 2 };
                let n = checked_count(n_raw, remaining, iw + S::BYTES)?;
                let mut idx = Vec::with_capacity(n);
                for _ in 0..n {
                    let i = if wide { r.u32()? } else { r.u16()? as u32 };
                    if let Some(&prev) = idx.last() {
                        if i <= prev {
                            return Err(format!(
                                "sparse indices not strictly increasing ({prev} then {i})"
                            ));
                        }
                    }
                    idx.push(i);
                }
                // A canonical encoder uses the narrow tag whenever the max
                // index fits u16; a wide tag on narrow indices would let a
                // peer bill 4 B/index for traffic the ledger models at 2 B.
                if wide && idx.last().is_some_and(|&m| m < 65_536) {
                    return Err("non-canonical width: u32 indices all fit u16".into());
                }
                let mut val = Vec::with_capacity(n);
                for _ in 0..n {
                    val.push(r.scalar::<S>()?);
                }
                Payload::Sparse { idx, val }
            }
            TAG_QUANTIZED => {
                let norm = r.scalar::<S>()?;
                let levels = r.u32()?;
                if !norm.is_finite() {
                    return Err("quantized norm is not finite".into());
                }
                if levels == 0 || levels > 32_767 {
                    return Err(format!("quantized levels {levels} outside 1..=32767"));
                }
                let n = checked_count(n_raw, bytes.len() - r.i, 2)?;
                let mut codes = Vec::with_capacity(n);
                for _ in 0..n {
                    codes.push(r.i16()?);
                }
                Payload::Quantized { norm, levels, codes }
            }
            other => return Err(format!("unknown payload kind {other}")),
        };
        r.done()?;
        Ok(p)
    }

    /// [`decode`](Payload::decode) plus the dimension contract: every
    /// index/coordinate count must fit a `dim`-length vector, so the
    /// result is safe to pass to [`write_dense`](Payload::write_dense) /
    /// [`add_dense`](Payload::add_dense) with `dim`-length buffers.
    pub fn decode_for_dim(bytes: &[u8], dim: usize) -> Result<Payload<S>, String> {
        let p = Payload::<S>::decode(bytes)?;
        let ok = match &p {
            Payload::Dense(v) => v.len() == dim,
            Payload::Sparse { idx, .. } => {
                idx.len() <= dim && idx.last().map_or(true, |&m| (m as usize) < dim)
            }
            Payload::Quantized { codes, .. } => codes.len() == dim,
        };
        if !ok {
            return Err(format!("payload does not fit dimension {dim}"));
        }
        Ok(p)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used, clippy::panic)]
mod tests {
    use super::*;

    #[test]
    fn byte_accounting() {
        assert_eq!(Payload::Dense(vec![0.0f32; 10]).payload_bytes(), 40);
        // Doubled per-coordinate cost at f64.
        assert_eq!(Payload::Dense(vec![0.0f64; 10]).payload_bytes(), 80);
        // u16 indices below 65536.
        assert_eq!(
            Payload::Sparse { idx: vec![1, 3], val: vec![1.0f32, 2.0] }.payload_bytes(),
            12
        );
        // u32 indices once any index exceeds the u16 range.
        assert_eq!(
            Payload::Sparse { idx: vec![1, 70_000], val: vec![1.0f32, 2.0] }.payload_bytes(),
            16
        );
        // Width follows the MAX index even when indices are unsorted (an
        // early wide index must not be under-billed at u16 width).
        assert_eq!(
            Payload::Sparse { idx: vec![70_000, 1], val: vec![1.0f32, 2.0] }.payload_bytes(),
            16
        );
        assert_eq!(
            Payload::Quantized { norm: 1.0f32, levels: 4, codes: vec![0; 10] }.payload_bytes(),
            28
        );
        // f64 quantized pays only for the wider norm header.
        assert_eq!(
            Payload::Quantized { norm: 1.0f64, levels: 4, codes: vec![0; 10] }.payload_bytes(),
            32
        );
    }

    #[test]
    fn sparse_write_and_add() {
        let p = Payload::Sparse { idx: vec![0, 2], val: vec![5.0f32, -1.0] };
        let mut d = vec![9.0f32; 3];
        p.write_dense(&mut d);
        assert_eq!(d, vec![5.0, 0.0, -1.0]);
        let mut t = vec![1.0f32; 3];
        p.add_scaled_dense(2.0, &mut t);
        assert_eq!(t, vec![11.0, 1.0, -1.0]);
    }

    #[test]
    fn reuse_helpers_switch_variant_and_clear() {
        let mut p = Payload::Dense(vec![1.0f32, 2.0]);
        {
            let (idx, val) = p.reuse_sparse();
            assert!(idx.is_empty() && val.is_empty());
            idx.push(3);
            val.push(9.0);
        }
        assert_eq!(p, Payload::Sparse { idx: vec![3], val: vec![9.0] });
        {
            let codes = p.reuse_quantized(2.0, 4);
            assert!(codes.is_empty());
            codes.push(1);
        }
        assert_eq!(p, Payload::Quantized { norm: 2.0, levels: 4, codes: vec![1] });
        let v = p.reuse_dense();
        assert!(v.is_empty());
    }

    #[test]
    fn quantized_roundtrip_scale() {
        let p = Payload::Quantized { norm: 8.0f32, levels: 4, codes: vec![4, -2, 0] };
        let mut d = vec![0.0f32; 3];
        p.write_dense(&mut d);
        assert_eq!(d, vec![8.0, -4.0, 0.0]);
    }

    fn enc<S: Scalar>(p: &Payload<S>) -> Vec<u8> {
        let mut b = Vec::new();
        p.encode(&mut b);
        assert_eq!(b.len(), p.encoded_len());
        b
    }

    #[test]
    fn wire_roundtrip_all_variants() {
        let cases = vec![
            Payload::Dense(vec![1.0f32, -2.5, 0.0]),
            Payload::Dense(vec![]),
            Payload::Sparse { idx: vec![0, 3, 9], val: vec![1.0, 2.0, -3.0] },
            Payload::Sparse { idx: vec![5, 70_000], val: vec![0.5, 0.25] },
            Payload::Quantized { norm: 2.0, levels: 4, codes: vec![1, -4, 0] },
        ];
        for p in cases {
            let b = enc(&p);
            assert_eq!(Payload::<f32>::decode(&b).unwrap(), p, "roundtrip failed");
        }
    }

    #[test]
    fn wire_roundtrip_f64_variants() {
        let cases = vec![
            Payload::Dense(vec![1.0f64, -2.5, 1e300]),
            Payload::Sparse { idx: vec![0, 3, 70_001], val: vec![1.0f64, 2.0, -3.0] },
            Payload::Quantized { norm: 2.0f64, levels: 4, codes: vec![1, -4, 0] },
        ];
        for p in cases {
            let b = enc(&p);
            assert!(b[0] >= 4 && b[0] < 8, "f64 tags live in 4..=7, got {}", b[0]);
            assert_eq!(Payload::<f64>::decode(&b).unwrap(), p, "f64 roundtrip failed");
        }
    }

    #[test]
    fn f32_encoding_is_the_historical_format() {
        // The dtype tag must not move a single byte of the f32 format the
        // goldens and the sweep byte-identity suite pin: tag 0..=3, then
        // count u32, then the body.
        let p = Payload::Dense(vec![1.0f32, -2.5]);
        let b = enc(&p);
        let mut want = vec![0u8];
        want.extend_from_slice(&2u32.to_le_bytes());
        want.extend_from_slice(&1.0f32.to_bits().to_le_bytes());
        want.extend_from_slice(&(-2.5f32).to_bits().to_le_bytes());
        assert_eq!(b, want);
    }

    #[test]
    fn decode_rejects_wrong_dtype_tag() {
        // An f32 payload must not decode into an f64 contract: the f64
        // decoder sees tag 0 and stops at the tag, before any length
        // arithmetic could misread the 4-byte values as 8-byte ones.
        let f32_bytes = enc(&Payload::Dense(vec![1.0f32, 2.0]));
        let err = Payload::<f64>::decode(&f32_bytes).unwrap_err();
        assert!(err.contains("dtype mismatch"), "unhelpful error: {err}");
        // And symmetrically.
        let f64_bytes = enc(&Payload::Dense(vec![1.0f64, 2.0]));
        let err = Payload::<f32>::decode(&f64_bytes).unwrap_err();
        assert!(err.contains("dtype mismatch"), "unhelpful error: {err}");
        // decode_for_dim inherits the rejection.
        assert!(Payload::<f64>::decode_for_dim(&f32_bytes, 2).is_err());
        // Tags beyond both dtype blocks are unknown, not mismatched.
        let err = Payload::<f32>::decode(&[9, 0, 0, 0, 0]).unwrap_err();
        assert!(err.contains("unknown payload tag"), "{err}");
    }

    #[test]
    fn wire_width_matches_billing() {
        // The encoded body (minus tag + count header) costs exactly what
        // payload_bytes bills, so the ledger and the wire cannot drift.
        for p in [
            Payload::Dense(vec![1.0f32; 7]),
            Payload::Sparse { idx: vec![1, 2, 65_536], val: vec![1.0f32; 3] },
            Payload::Sparse { idx: vec![1, 2, 3], val: vec![1.0f32; 3] },
        ] {
            assert_eq!(enc(&p).len() - 5, p.payload_bytes());
        }
        // Quantized ships one extra u32 count the cost model folds into
        // its 8-byte header allowance.
        let q = Payload::Quantized { norm: 1.0f32, levels: 4, codes: vec![0; 5] };
        assert_eq!(enc(&q).len(), 1 + 4 + q.payload_bytes());
        // The identity holds at f64 too.
        let d64 = Payload::Dense(vec![1.0f64; 7]);
        assert_eq!(enc(&d64).len() - 5, d64.payload_bytes());
    }

    #[test]
    fn decode_rejects_structural_garbage() {
        // Empty, unknown tag, truncated header.
        assert!(Payload::<f32>::decode(&[]).is_err());
        assert!(Payload::<f32>::decode(&[9, 0, 0, 0, 0]).is_err());
        assert!(Payload::<f32>::decode(&[0, 1]).is_err());
        // Count disagrees with the bytes present (both directions).
        let mut b = enc(&Payload::Dense(vec![1.0f32, 2.0]));
        b[1] = 3; // claims 3 coords, carries 2
        assert!(Payload::<f32>::decode(&b).is_err());
        let mut b = enc(&Payload::Dense(vec![1.0f32, 2.0]));
        b[1] = 1; // claims 1 coord → 4 trailing bytes
        assert!(Payload::<f32>::decode(&b).is_err());
        // Oversized count: a 16 GiB allocation request must die at the
        // header, not at the allocator.
        let mut b = vec![0u8];
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(Payload::<f32>::decode(&b)
            .unwrap_err()
            .contains("MAX_WIRE_COORDS"));
        // Every truncation of a valid encoding fails cleanly — both dtypes.
        let full = enc(&Payload::Sparse {
            idx: vec![2, 7, 70_000],
            val: vec![1.0f32, 2.0, 3.0],
        });
        for cut in 0..full.len() {
            assert!(Payload::<f32>::decode(&full[..cut]).is_err(), "cut at {cut} decoded");
        }
        let full = enc(&Payload::Sparse {
            idx: vec![2, 7, 70_000],
            val: vec![1.0f64, 2.0, 3.0],
        });
        for cut in 0..full.len() {
            assert!(Payload::<f64>::decode(&full[..cut]).is_err(), "cut at {cut} decoded");
        }
    }

    #[test]
    fn decode_rejects_non_canonical_sparse() {
        // Unsorted and duplicate indices.
        let mut b = vec![1u8]; // f32 sparse16 tag
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&7u16.to_le_bytes());
        b.extend_from_slice(&3u16.to_le_bytes());
        b.extend_from_slice(&1.0f32.to_bits().to_le_bytes());
        b.extend_from_slice(&2.0f32.to_bits().to_le_bytes());
        assert!(Payload::<f32>::decode(&b)
            .unwrap_err()
            .contains("strictly increasing"));
        // Wide tag on indices that all fit u16 (billing inflation).
        let mut b = vec![2u8]; // f32 sparse32 tag
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&3u32.to_le_bytes());
        b.extend_from_slice(&1.0f32.to_bits().to_le_bytes());
        assert!(Payload::<f32>::decode(&b)
            .unwrap_err()
            .contains("non-canonical"));
    }

    #[test]
    fn decode_rejects_bad_quantized_header() {
        let good = Payload::Quantized { norm: 1.0f32, levels: 4, codes: vec![1, 2] };
        let b = enc(&good);
        // levels = 0 and levels > i16 code range.
        let mut z = b.clone();
        z[9..13].copy_from_slice(&0u32.to_le_bytes());
        assert!(Payload::<f32>::decode(&z).is_err());
        let mut big = b.clone();
        big[9..13].copy_from_slice(&40_000u32.to_le_bytes());
        assert!(Payload::<f32>::decode(&big).is_err());
        // Non-finite norm (a NaN scale would poison every fold).
        let mut nan = b;
        nan[5..9].copy_from_slice(&f32::NAN.to_bits().to_le_bytes());
        assert!(Payload::<f32>::decode(&nan).unwrap_err().contains("finite"));
    }

    #[test]
    fn decode_for_dim_enforces_fit() {
        let d = enc(&Payload::Dense(vec![1.0f32, 2.0, 3.0]));
        assert!(Payload::<f32>::decode_for_dim(&d, 3).is_ok());
        assert!(Payload::<f32>::decode_for_dim(&d, 4).is_err());
        let s = enc(&Payload::Sparse { idx: vec![0, 5], val: vec![1.0f32, 2.0] });
        assert!(Payload::<f32>::decode_for_dim(&s, 6).is_ok());
        // Index 5 out of range for dim 5 — write_dense would have panicked.
        assert!(Payload::<f32>::decode_for_dim(&s, 5).is_err());
        let q = enc(&Payload::Quantized { norm: 1.0f32, levels: 2, codes: vec![0, 1] });
        assert!(Payload::<f32>::decode_for_dim(&q, 2).is_ok());
        assert!(Payload::<f32>::decode_for_dim(&q, 3).is_err());
    }
}
