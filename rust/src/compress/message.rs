//! Compressed-message payload encodings and their exact wire sizes.

/// The on-the-wire representation of a compressed vector.  The byte counts
/// model a straightforward binary encoding; no actual serialization happens
/// in the in-process simulator, but the sizes feed the communication-volume
/// ledger, which is the paper's headline metric.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// Raw f32 values (4 B/coord).
    Dense(Vec<f32>),
    /// Coordinate list: index + f32 value.  Indices are modeled at the
    /// narrowest width that covers the max index (u16 below 65536, u32
    /// above), as a real wire encoder would emit.
    Sparse { idx: Vec<u32>, val: Vec<f32> },
    /// QSGD: one f32 norm + i16 signed level codes (2 B/coord).
    Quantized { norm: f32, levels: u32, codes: Vec<i16> },
}

/// Coarse payload classification, used by the telemetry layer's
/// per-compressor encode counters ([`crate::obs`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PayloadKind {
    Dense,
    Sparse,
    Quantized,
}

impl Payload {
    pub fn kind(&self) -> PayloadKind {
        match self {
            Payload::Dense(_) => PayloadKind::Dense,
            Payload::Sparse { .. } => PayloadKind::Sparse,
            Payload::Quantized { .. } => PayloadKind::Quantized,
        }
    }

    pub fn payload_bytes(&self) -> usize {
        match self {
            Payload::Dense(v) => 4 * v.len(),
            Payload::Sparse { idx, val } => {
                // Width from the MAX index, not the last: the encoding must
                // bill correctly even if a producer ever emits indices out
                // of order (the canonical encoders sort, but the byte model
                // must not under-bill if that invariant slips).
                let max = idx.iter().copied().max().unwrap_or(0);
                let idx_width = if max < 65_536 { 2 } else { 4 };
                idx_width * idx.len() + 4 * val.len()
            }
            Payload::Quantized { codes, .. } => 4 + 4 + 2 * codes.len(),
        }
    }

    /// Reuse `self` as a `Dense` payload, returning its cleared value
    /// buffer (allocation-free once the variant and capacity are warm).
    pub(crate) fn reuse_dense(&mut self) -> &mut Vec<f32> {
        if !matches!(self, Payload::Dense(_)) {
            *self = Payload::Dense(Vec::new());
        }
        match self {
            Payload::Dense(v) => {
                v.clear();
                v
            }
            _ => unreachable!(),
        }
    }

    /// Reuse `self` as a `Sparse` payload, returning its cleared index and
    /// value buffers.
    pub(crate) fn reuse_sparse(&mut self) -> (&mut Vec<u32>, &mut Vec<f32>) {
        if !matches!(self, Payload::Sparse { .. }) {
            *self = Payload::Sparse { idx: Vec::new(), val: Vec::new() };
        }
        match self {
            Payload::Sparse { idx, val } => {
                idx.clear();
                val.clear();
                (idx, val)
            }
            _ => unreachable!(),
        }
    }

    /// Reuse `self` as a `Quantized` payload with the given header fields,
    /// returning its cleared code buffer.
    pub(crate) fn reuse_quantized(&mut self, norm: f32, levels: u32) -> &mut Vec<i16> {
        if !matches!(self, Payload::Quantized { .. }) {
            *self = Payload::Quantized { norm, levels, codes: Vec::new() };
        }
        match self {
            Payload::Quantized { norm: n, levels: l, codes } => {
                *n = norm;
                *l = levels;
                codes.clear();
                codes
            }
            _ => unreachable!(),
        }
    }

    /// Number of degrees of freedom actually transmitted.
    pub fn nnz(&self) -> usize {
        match self {
            Payload::Dense(v) => v.len(),
            Payload::Sparse { idx, .. } => idx.len(),
            Payload::Quantized { codes, .. } => codes.len(),
        }
    }

    pub fn write_dense(&self, out: &mut [f32]) {
        match self {
            Payload::Dense(v) => out.copy_from_slice(v),
            Payload::Sparse { idx, val } => {
                out.fill(0.0);
                for (&i, &x) in idx.iter().zip(val) {
                    out[i as usize] = x;
                }
            }
            Payload::Quantized { norm, levels, codes } => {
                let scale = norm / *levels as f32;
                for (o, &c) in out.iter_mut().zip(codes) {
                    *o = c as f32 * scale;
                }
            }
        }
    }

    pub fn add_dense(&self, target: &mut [f32]) {
        self.add_scaled_dense(1.0, target);
    }

    pub fn add_scaled_dense(&self, w: f32, target: &mut [f32]) {
        match self {
            Payload::Dense(v) => {
                for (t, &x) in target.iter_mut().zip(v) {
                    *t += w * x;
                }
            }
            Payload::Sparse { idx, val } => {
                for (&i, &x) in idx.iter().zip(val) {
                    target[i as usize] += w * x;
                }
            }
            Payload::Quantized { norm, levels, codes } => {
                let scale = w * norm / *levels as f32;
                for (t, &c) in target.iter_mut().zip(codes) {
                    *t += c as f32 * scale;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_accounting() {
        assert_eq!(Payload::Dense(vec![0.0; 10]).payload_bytes(), 40);
        // u16 indices below 65536.
        assert_eq!(
            Payload::Sparse { idx: vec![1, 3], val: vec![1.0, 2.0] }.payload_bytes(),
            12
        );
        // u32 indices once any index exceeds the u16 range.
        assert_eq!(
            Payload::Sparse { idx: vec![1, 70_000], val: vec![1.0, 2.0] }.payload_bytes(),
            16
        );
        // Width follows the MAX index even when indices are unsorted (an
        // early wide index must not be under-billed at u16 width).
        assert_eq!(
            Payload::Sparse { idx: vec![70_000, 1], val: vec![1.0, 2.0] }.payload_bytes(),
            16
        );
        assert_eq!(
            Payload::Quantized { norm: 1.0, levels: 4, codes: vec![0; 10] }.payload_bytes(),
            28
        );
    }

    #[test]
    fn sparse_write_and_add() {
        let p = Payload::Sparse { idx: vec![0, 2], val: vec![5.0, -1.0] };
        let mut d = vec![9.0f32; 3];
        p.write_dense(&mut d);
        assert_eq!(d, vec![5.0, 0.0, -1.0]);
        let mut t = vec![1.0f32; 3];
        p.add_scaled_dense(2.0, &mut t);
        assert_eq!(t, vec![11.0, 1.0, -1.0]);
    }

    #[test]
    fn reuse_helpers_switch_variant_and_clear() {
        let mut p = Payload::Dense(vec![1.0, 2.0]);
        {
            let (idx, val) = p.reuse_sparse();
            assert!(idx.is_empty() && val.is_empty());
            idx.push(3);
            val.push(9.0);
        }
        assert_eq!(p, Payload::Sparse { idx: vec![3], val: vec![9.0] });
        {
            let codes = p.reuse_quantized(2.0, 4);
            assert!(codes.is_empty());
            codes.push(1);
        }
        assert_eq!(p, Payload::Quantized { norm: 2.0, levels: 4, codes: vec![1] });
        let v = p.reuse_dense();
        assert!(v.is_empty());
    }

    #[test]
    fn quantized_roundtrip_scale() {
        let p = Payload::Quantized { norm: 8.0, levels: 4, codes: vec![4, -2, 0] };
        let mut d = vec![0.0f32; 3];
        p.write_dense(&mut d);
        assert_eq!(d, vec![8.0, -4.0, 0.0]);
    }
}
