//! Contractive compressors (Definition 2) with exact wire-size accounting.
//!
//! The C²DFB inner loop transmits `Q(d^{k+1} − d̂^k)` — a compressed
//! residual — so compressors are on the communication hot path.  All
//! implementations satisfy the contractive property
//! `E‖Q(v) − v‖² ≤ (1 − δ) ‖v‖²` with a known δ:
//!
//! * [`TopK`] — biased, keeps the k largest-magnitude coords, δ = k/d.
//! * [`RandK`] — unbiased after 1/q rescaling in expectation; used here in
//!   its contractive (non-rescaled) form with δ = k/d.
//! * [`Qsgd`] — stochastic uniform quantization to `levels` buckets per
//!   sign, transmitted as (norm, signs, level indices).
//! * [`Identity`] — δ = 1 (no compression), the "dense" baseline.
//!
//! Wire size is modeled exactly from the encoding (indices u32, values at
//! the run's [`Scalar`] width, bit-packed levels for QSGD) — this is what
//! the paper's communication-volume plots integrate.  Everything is
//! generic over the payload scalar `S` (default `f32`, the historical
//! wire type; `f64` doubles per-coordinate value bytes); the dense
//! selection/quantization passes live in [`crate::linalg::kernels`].

use crate::linalg::kernels;
use crate::linalg::scalar::Scalar;
use crate::util::rng::Rng;

mod message;
pub use message::{Payload, PayloadKind, MAX_WIRE_COORDS};

/// A compressed vector plus its exact serialized size.
///
/// Also the reusable output slot of [`Compressor::compress_into`]: the
/// payload buffers and two private scratch fields (quickselect magnitudes,
/// rand-k index samples) persist across calls, so re-encoding into an old
/// message is allocation-free in steady state.  The scratch never reaches
/// the wire and is excluded from equality.
#[derive(Clone, Debug)]
pub struct Compressed<S: Scalar = f32> {
    pub dim: usize,
    pub payload: Payload<S>,
    scratch: Vec<S>,
    scratch_idx: Vec<usize>,
}

impl<S: Scalar> PartialEq for Compressed<S> {
    fn eq(&self, other: &Self) -> bool {
        self.dim == other.dim && self.payload == other.payload
    }
}

impl<S: Scalar> Compressed<S> {
    pub fn new(dim: usize, payload: Payload<S>) -> Compressed<S> {
        Compressed { dim, payload, scratch: Vec::new(), scratch_idx: Vec::new() }
    }

    /// An empty slot for [`Compressor::compress_into`] to fill.
    pub fn empty() -> Compressed<S> {
        Compressed::new(0, Payload::Dense(Vec::new()))
    }

    /// Exact bytes on the wire for this message (payload + 8-byte header).
    pub fn wire_bytes(&self) -> usize {
        8 + self.payload.payload_bytes()
    }

    /// Densify into `out` (must be zeroed or will be overwritten).
    pub fn decompress_into(&self, out: &mut [S]) {
        assert_eq!(out.len(), self.dim);
        self.payload.write_dense(out);
    }

    pub fn to_dense(&self) -> Vec<S> {
        let mut out = vec![S::ZERO; self.dim];
        self.decompress_into(&mut out);
        out
    }

    /// `target += decompress(self)` without materializing.
    pub fn add_into(&self, target: &mut [S]) {
        assert_eq!(target.len(), self.dim);
        self.payload.add_dense(target);
    }

    /// `target += weight * decompress(self)`.
    pub fn add_scaled_into(&self, weight: S, target: &mut [S]) {
        assert_eq!(target.len(), self.dim);
        self.payload.add_scaled_dense(weight, target);
    }

    /// Payload classification for the telemetry encode counters.
    pub fn payload_kind(&self) -> PayloadKind {
        self.payload.kind()
    }
}

/// A contractive compression operator Q (Definition 2), generic over the
/// payload scalar.
pub trait Compressor<S: Scalar = f32>: Send + Sync {
    fn name(&self) -> String;
    /// The contraction constant δ ∈ (0, 1].
    fn delta(&self) -> f64;

    /// Compress `v` into `out`, reusing `out`'s payload and scratch
    /// buffers (the inner-loop hot path; allocation-free in steady state).
    /// `out` is fully overwritten — its previous contents, variant and dim
    /// are irrelevant.  Equal RNG state ⇒ output identical to
    /// [`Compressor::compress`], which is defined in terms of this method.
    fn compress_into(&self, v: &[S], out: &mut Compressed<S>, rng: &mut Rng);

    /// Allocating convenience wrapper around
    /// [`Compressor::compress_into`].
    fn compress(&self, v: &[S], rng: &mut Rng) -> Compressed<S> {
        let mut out = Compressed::empty();
        self.compress_into(v, &mut out, rng);
        out
    }
}

/// Parse "topk:0.2" | "randk:0.3" | "qsgd:16" | "none".
pub fn parse<S: Scalar>(spec: &str) -> Result<Box<dyn Compressor<S>>, String> {
    let (kind, arg) = match spec.split_once(':') {
        Some((k, a)) => (k, Some(a)),
        None => (spec, None),
    };
    match kind {
        "none" | "identity" | "dense" => Ok(Box::new(Identity)),
        "topk" => {
            let r: f64 = arg.ok_or("topk needs a ratio, e.g. topk:0.2")?.parse().map_err(|_| "bad topk ratio")?;
            Ok(Box::new(TopK::new(r)))
        }
        "randk" => {
            let r: f64 = arg.ok_or("randk needs a ratio")?.parse().map_err(|_| "bad randk ratio")?;
            Ok(Box::new(RandK::new(r)))
        }
        "qsgd" => {
            let l: u32 = arg.ok_or("qsgd needs a level count, e.g. qsgd:16")?.parse().map_err(|_| "bad qsgd levels")?;
            if l == 0 || l > Qsgd::MAX_LEVELS {
                return Err(format!(
                    "qsgd levels must be in 1..={} (i16 code range), got {l}",
                    Qsgd::MAX_LEVELS
                ));
            }
            Ok(Box::new(Qsgd::new(l)))
        }
        _ => Err(format!("unknown compressor: {spec}")),
    }
}

// ---------------------------------------------------------------------------

/// No-op compressor, δ = 1.
#[derive(Clone, Copy, Debug)]
pub struct Identity;

impl<S: Scalar> Compressor<S> for Identity {
    fn name(&self) -> String {
        "none".into()
    }

    fn delta(&self) -> f64 {
        1.0
    }

    fn compress_into(&self, v: &[S], out: &mut Compressed<S>, _rng: &mut Rng) {
        out.dim = v.len();
        out.payload.reuse_dense().extend_from_slice(v);
    }
}

/// Keep the k = ⌈ratio·d⌉ largest-magnitude coordinates (biased, δ = k/d).
#[derive(Clone, Copy, Debug)]
pub struct TopK {
    pub ratio: f64,
}

impl TopK {
    pub fn new(ratio: f64) -> TopK {
        assert!(ratio > 0.0 && ratio <= 1.0, "topk ratio must be in (0,1]");
        TopK { ratio }
    }

    fn k(&self, d: usize) -> usize {
        ((self.ratio * d as f64).ceil() as usize).clamp(1, d)
    }
}

impl<S: Scalar> Compressor<S> for TopK {
    fn name(&self) -> String {
        format!("topk:{}", self.ratio)
    }

    fn delta(&self) -> f64 {
        self.ratio
    }

    fn compress_into(&self, v: &[S], out: &mut Compressed<S>, _rng: &mut Rng) {
        let d = v.len();
        let k = self.k(d);
        out.dim = d;
        // Non-finite coordinates break the quickselect ordering (its
        // comparisons are not a total order under NaN), which can corrupt
        // the threshold or drop entries.  Fall back deterministically to
        // the dense encoding: nothing is silently lost, and the run-level
        // divergence guard sees the non-finite values unfiltered.
        if k == d || v.iter().any(|x| !x.is_finite()) {
            out.payload.reuse_dense().extend_from_slice(v);
            return;
        }
        let scratch = &mut out.scratch;
        let (idx, val) = out.payload.reuse_sparse();
        kernels::topk_select(v, k, scratch, idx, val);
    }
}

/// Keep k uniformly random coordinates (contractive with δ = k/d).
#[derive(Clone, Copy, Debug)]
pub struct RandK {
    pub ratio: f64,
}

impl RandK {
    pub fn new(ratio: f64) -> RandK {
        assert!(ratio > 0.0 && ratio <= 1.0, "randk ratio must be in (0,1]");
        RandK { ratio }
    }
}

impl<S: Scalar> Compressor<S> for RandK {
    fn name(&self) -> String {
        format!("randk:{}", self.ratio)
    }

    fn delta(&self) -> f64 {
        self.ratio
    }

    fn compress_into(&self, v: &[S], out: &mut Compressed<S>, rng: &mut Rng) {
        let d = v.len();
        let k = ((self.ratio * d as f64).ceil() as usize).clamp(1, d);
        out.dim = d;
        if k == d {
            out.payload.reuse_dense().extend_from_slice(v);
            return;
        }
        // Canonically sorted ascending (sample_indices_into sorts), so the
        // wire width model and re-encode fixed points see the same order
        // top-k emits.
        rng.sample_indices_into(d, k, &mut out.scratch_idx);
        let (idx, val) = out.payload.reuse_sparse();
        idx.extend(out.scratch_idx.iter().map(|&i| i as u32));
        val.extend(out.scratch_idx.iter().map(|&i| v[i]));
    }
}

/// QSGD-style stochastic uniform quantization with `levels` buckets.
/// Unbiased; contractive after the Proposition-1 rescale with
/// δ = 1/(1 + min(d/levels², √d/levels)).
#[derive(Clone, Copy, Debug)]
pub struct Qsgd {
    pub levels: u32,
}

impl Qsgd {
    /// Largest representable level count: codes are `level · sign` stored
    /// as `i16`, so levels beyond `i16::MAX` would silently saturate.
    pub const MAX_LEVELS: u32 = i16::MAX as u32;

    pub fn new(levels: u32) -> Qsgd {
        assert!(levels >= 1, "need at least 1 level");
        assert!(
            levels <= Qsgd::MAX_LEVELS,
            "qsgd levels {levels} exceed the i16 code range (max {})",
            Qsgd::MAX_LEVELS
        );
        Qsgd { levels }
    }

    fn omega(&self, d: usize) -> f64 {
        let s = self.levels as f64;
        let d = d as f64;
        (d / (s * s)).min(d.sqrt() / s)
    }
}

impl<S: Scalar> Compressor<S> for Qsgd {
    fn name(&self) -> String {
        format!("qsgd:{}", self.levels)
    }

    fn delta(&self) -> f64 {
        // Variance bound E‖Q(v)−v‖² ≤ ω‖v‖² with ω = min(d/s², √d/s); for a
        // representative d = 10⁴.  The per-call contraction is recomputed
        // from the actual d when it matters (tests use this method's bound).
        1.0 / (1.0 + self.omega(10_000))
    }

    fn compress_into(&self, v: &[S], out: &mut Compressed<S>, rng: &mut Rng) {
        let d = v.len();
        let norm = S::from_f64(kernels::norm2(v));
        out.dim = d;
        if norm == S::ZERO {
            let codes = out.payload.reuse_quantized(S::ZERO, self.levels);
            codes.resize(d, 0);
            return;
        }
        let codes = out.payload.reuse_quantized(norm, self.levels);
        kernels::qsgd_quantize(v, norm, self.levels, codes, rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg;

    fn rngv(seed: u64, d: usize) -> (Rng, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0; d];
        rng.fill_normal(&mut v, 0.0, 1.0);
        (rng, v)
    }

    #[test]
    fn identity_roundtrip() {
        let (mut rng, v) = rngv(1, 100);
        let c = Compressor::<f32>::compress(&Identity, &v, &mut rng);
        assert_eq!(c.to_dense(), v);
        assert_eq!(c.wire_bytes(), 8 + 400);
    }

    #[test]
    fn identity_f64_doubles_value_bytes() {
        let mut rng = Rng::new(1);
        let v: Vec<f64> = (0..100).map(|i| i as f64 * 0.25 - 10.0).collect();
        let c = Compressor::<f64>::compress(&Identity, &v, &mut rng);
        assert_eq!(c.to_dense(), v);
        assert_eq!(c.wire_bytes(), 8 + 800);
    }

    #[test]
    fn topk_keeps_largest() {
        let mut rng = Rng::new(2);
        let v = vec![0.1f32, -5.0, 0.2, 3.0, -0.05];
        let c = TopK::new(0.4).compress(&v, &mut rng); // k = 2
        let dense = c.to_dense();
        assert_eq!(dense[1], -5.0);
        assert_eq!(dense[3], 3.0);
        assert_eq!(dense[0], 0.0);
        assert_eq!(dense[2], 0.0);
        assert_eq!(dense[4], 0.0);
    }

    #[test]
    fn topk_contraction_bound() {
        let (mut rng, v) = rngv(3, 500);
        let q = TopK::new(0.2);
        let c = q.compress(&v, &mut rng);
        let err: f64 = c
            .to_dense()
            .iter()
            .zip(&v)
            .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
            .sum();
        let bound = (1.0 - Compressor::<f32>::delta(&q)) * linalg::norm2_sq(&v);
        assert!(err <= bound + 1e-6, "{err} > {bound}");
    }

    #[test]
    fn topk_wire_smaller_than_dense() {
        let (mut rng, v) = rngv(4, 1000);
        let dense = Compressor::<f32>::compress(&Identity, &v, &mut rng).wire_bytes();
        let sparse = TopK::new(0.1).compress(&v, &mut rng).wire_bytes();
        assert!(sparse < dense / 4, "{sparse} vs {dense}");
    }

    #[test]
    fn topk_exact_k_when_ties() {
        let mut rng = Rng::new(5);
        let v = vec![1.0f32; 10]; // all tied
        let c = TopK::new(0.3).compress(&v, &mut rng);
        if let Payload::Sparse { idx, val } = &c.payload {
            assert_eq!(idx.len(), 3);
            assert!(val.iter().all(|&x| x == 1.0));
            assert!(idx.windows(2).all(|w| w[0] < w[1]));
        } else {
            panic!("expected sparse");
        }
    }

    #[test]
    fn randk_contraction_in_expectation() {
        let (mut rng, v) = rngv(6, 400);
        let q = RandK::new(0.25);
        let trials = 200;
        let mut err_sum = 0.0;
        for _ in 0..trials {
            let c = q.compress(&v, &mut rng);
            err_sum += c
                .to_dense()
                .iter()
                .zip(&v)
                .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
                .sum::<f64>();
        }
        let avg = err_sum / trials as f64;
        let bound = (1.0 - Compressor::<f32>::delta(&q)) * linalg::norm2_sq(&v);
        assert!(avg <= bound * 1.05, "{avg} > {bound}");
    }

    #[test]
    fn qsgd_unbiased_and_bounded() {
        let (mut rng, v) = rngv(7, 256);
        let q = Qsgd::new(16);
        let trials = 300;
        let mut mean = vec![0.0f64; v.len()];
        for _ in 0..trials {
            let c = q.compress(&v, &mut rng);
            for (m, x) in mean.iter_mut().zip(c.to_dense()) {
                *m += x as f64;
            }
        }
        for m in mean.iter_mut() {
            *m /= trials as f64;
        }
        // Unbiasedness: mean reconstruction ≈ v.
        let diff: f64 = mean.iter().zip(&v).map(|(a, b)| (a - *b as f64).powi(2)).sum();
        let rel = diff / linalg::norm2_sq(&v);
        assert!(rel < 0.01, "bias {rel}");
    }

    #[test]
    fn qsgd_wire_bytes_small() {
        let (mut rng, v) = rngv(8, 1000);
        let c = Qsgd::new(16).compress(&v, &mut rng);
        // 2 bytes/coord (i16 codes) + norm + header ≪ 4 bytes/coord dense.
        assert!(c.wire_bytes() < 8 + 4 + 2 * 1000 + 16);
        assert!(c.wire_bytes() > 1000);
    }

    #[test]
    fn qsgd_zero_vector() {
        let mut rng = Rng::new(9);
        let v = vec![0.0f32; 32];
        let c = Qsgd::new(8).compress(&v, &mut rng);
        assert_eq!(c.to_dense(), v);
    }

    #[test]
    fn qsgd_f64_same_draw_sequence_as_f32() {
        // The quantize pass draws one Bernoulli per coordinate in index
        // order for both dtypes — the RNG advance must not depend on S.
        let (_, v) = rngv(12, 128);
        let v64: Vec<f64> = v.iter().map(|&x| x as f64).collect();
        let mut rng_a = Rng::new(77);
        let mut rng_b = Rng::new(77);
        let _ = Compressor::<f32>::compress(&Qsgd::new(8), &v, &mut rng_a);
        let _ = Compressor::<f64>::compress(&Qsgd::new(8), &v64, &mut rng_b);
        assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "rng divergence across dtypes");
    }

    #[test]
    fn add_scaled_into_matches_dense_math() {
        let (mut rng, v) = rngv(10, 64);
        let c = TopK::new(0.5).compress(&v, &mut rng);
        let mut target = vec![1.0f32; 64];
        c.add_scaled_into(0.5, &mut target);
        let dense = c.to_dense();
        for i in 0..64 {
            assert!((target[i] - (1.0 + 0.5 * dense[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn parse_specs() {
        assert_eq!(parse::<f32>("topk:0.2").unwrap().name(), "topk:0.2");
        assert_eq!(parse::<f32>("randk:0.5").unwrap().name(), "randk:0.5");
        assert_eq!(parse::<f32>("qsgd:16").unwrap().name(), "qsgd:16");
        assert_eq!(parse::<f32>("none").unwrap().name(), "none");
        assert!(parse::<f32>("bogus").is_err());
        assert!(parse::<f32>("topk").is_err());
        // The same spec grammar parses at f64.
        assert_eq!(parse::<f64>("topk:0.2").unwrap().name(), "topk:0.2");
    }

    #[test]
    fn parse_rejects_qsgd_level_overflow() {
        // (level · sign) is stored as i16: levels beyond 32767 would
        // silently saturate, so the spec is rejected with a clear error.
        assert_eq!(parse::<f32>("qsgd:32767").unwrap().name(), "qsgd:32767");
        let err = parse::<f32>("qsgd:32768").unwrap_err();
        assert!(err.contains("i16"), "unhelpful error: {err}");
        assert!(parse::<f32>("qsgd:40000").is_err());
        assert!(parse::<f32>("qsgd:0").is_err());
    }

    #[test]
    #[should_panic(expected = "i16 code range")]
    fn qsgd_constructor_rejects_overflow() {
        Qsgd::new(40_000);
    }

    #[test]
    fn topk_nan_input_falls_back_to_dense() {
        let mut rng = Rng::new(21);
        let mut v = vec![1.0f32, -2.0, 3.0, -4.0, 5.0, -6.0, 7.0, -8.0, 9.0, -10.0];
        v[3] = f32::NAN;
        let c = TopK::new(0.2).compress(&v, &mut rng);
        // Deterministic fallback: the full vector travels dense, so the
        // divergence guard downstream sees the NaN unfiltered.
        match &c.payload {
            Payload::Dense(dense) => {
                assert_eq!(dense.len(), v.len());
                assert!(dense[3].is_nan());
                for (i, x) in v.iter().enumerate() {
                    if i != 3 {
                        assert_eq!(dense[i], *x);
                    }
                }
            }
            p => panic!("expected dense fallback, got {p:?}"),
        }
        assert_eq!(c.wire_bytes(), 8 + 4 * v.len());
        // Infinities take the same fallback.
        v[3] = f32::INFINITY;
        let c = TopK::new(0.2).compress(&v, &mut rng);
        assert!(matches!(c.payload, Payload::Dense(_)));
    }

    #[test]
    fn randk_indices_sorted_and_billed_at_u32_width_beyond_u16_range() {
        // Regression for the wire-size accounting: at d > 65536 the width
        // must come from the max index, and rand-k indices stay canonical
        // (ascending) like top-k's.
        let d = 70_000;
        let mut rng = Rng::new(33);
        let mut v = vec![0.0f32; d];
        rng.fill_normal(&mut v, 0.0, 1.0);
        let c = RandK::new(0.01).compress(&v, &mut rng);
        let Payload::Sparse { idx, val } = &c.payload else {
            panic!("expected sparse");
        };
        assert_eq!(idx.len(), 700);
        assert!(idx.windows(2).all(|w| w[0] < w[1]), "indices not sorted");
        let max = *idx.last().unwrap();
        assert!(max >= 65_536, "seed must sample a wide index (got max {max})");
        assert_eq!(c.wire_bytes(), 8 + 4 * idx.len() + 4 * val.len());
    }

    #[test]
    fn compress_into_reuses_dirty_buffers_identically() {
        let (_, v) = rngv(40, 257);
        let (_, w) = rngv(41, 64);
        for spec in ["none", "topk:0.1", "randk:0.25", "qsgd:8"] {
            let q = parse::<f32>(spec).unwrap();
            let mut rng_a = Rng::new(99);
            let mut rng_b = rng_a.clone();
            let fresh = q.compress(&v, &mut rng_a);
            // Dirty the slot with a different vector and different
            // compressors first, then re-encode v into it.
            let mut slot = parse::<f32>("qsgd:4").unwrap().compress(&w, &mut Rng::new(1));
            parse::<f32>("topk:0.5")
                .unwrap()
                .compress_into(&w, &mut slot, &mut Rng::new(2));
            q.compress_into(&v, &mut slot, &mut rng_b);
            assert_eq!(slot, fresh, "{spec}: dirty-buffer reuse changed the message");
            assert_eq!(slot.wire_bytes(), fresh.wire_bytes());
            // Both RNGs consumed the same draws.
            assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "{spec}: rng divergence");
        }
    }

    #[test]
    fn quickselect_matches_sort() {
        let mut rng = Rng::new(11);
        for _ in 0..50 {
            let n = 1 + rng.below(200);
            let mut v: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let k = rng.below(n);
            let got = kernels::quickselect_desc(&mut v.clone(), k);
            v.sort_by(|a, b| b.partial_cmp(a).unwrap());
            assert_eq!(got, v[k]);
        }
    }
}
