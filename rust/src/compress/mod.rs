//! Contractive compressors (Definition 2) with exact wire-size accounting.
//!
//! The C²DFB inner loop transmits `Q(d^{k+1} − d̂^k)` — a compressed
//! residual — so compressors are on the communication hot path.  All
//! implementations satisfy the contractive property
//! `E‖Q(v) − v‖² ≤ (1 − δ) ‖v‖²` with a known δ:
//!
//! * [`TopK`] — biased, keeps the k largest-magnitude coords, δ = k/d.
//! * [`RandK`] — unbiased after 1/q rescaling in expectation; used here in
//!   its contractive (non-rescaled) form with δ = k/d.
//! * [`Qsgd`] — stochastic uniform quantization to `levels` buckets per
//!   sign, transmitted as (norm, signs, level indices).
//! * [`Identity`] — δ = 1 (no compression), the "dense" baseline.
//!
//! Wire size is modeled exactly from the encoding (indices u32, values
//! f32, bit-packed levels for QSGD) — this is what the paper's
//! communication-volume plots integrate.

use crate::util::rng::Rng;

mod message;
pub use message::Payload;

/// A compressed vector plus its exact serialized size.
#[derive(Clone, Debug)]
pub struct Compressed {
    pub dim: usize,
    pub payload: Payload,
}

impl Compressed {
    /// Exact bytes on the wire for this message (payload + 8-byte header).
    pub fn wire_bytes(&self) -> usize {
        8 + self.payload.payload_bytes()
    }

    /// Densify into `out` (must be zeroed or will be overwritten).
    pub fn decompress_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.dim);
        self.payload.write_dense(out);
    }

    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.dim];
        self.decompress_into(&mut out);
        out
    }

    /// `target += decompress(self)` without materializing.
    pub fn add_into(&self, target: &mut [f32]) {
        assert_eq!(target.len(), self.dim);
        self.payload.add_dense(target);
    }

    /// `target += weight * decompress(self)`.
    pub fn add_scaled_into(&self, weight: f32, target: &mut [f32]) {
        assert_eq!(target.len(), self.dim);
        self.payload.add_scaled_dense(weight, target);
    }
}

/// A contractive compression operator Q (Definition 2).
pub trait Compressor: Send + Sync {
    fn name(&self) -> String;
    /// The contraction constant δ ∈ (0, 1].
    fn delta(&self) -> f64;
    fn compress(&self, v: &[f32], rng: &mut Rng) -> Compressed;
}

/// Parse "topk:0.2" | "randk:0.3" | "qsgd:16" | "none".
pub fn parse(spec: &str) -> Result<Box<dyn Compressor>, String> {
    let (kind, arg) = match spec.split_once(':') {
        Some((k, a)) => (k, Some(a)),
        None => (spec, None),
    };
    match kind {
        "none" | "identity" | "dense" => Ok(Box::new(Identity)),
        "topk" => {
            let r: f64 = arg.ok_or("topk needs a ratio, e.g. topk:0.2")?.parse().map_err(|_| "bad topk ratio")?;
            Ok(Box::new(TopK::new(r)))
        }
        "randk" => {
            let r: f64 = arg.ok_or("randk needs a ratio")?.parse().map_err(|_| "bad randk ratio")?;
            Ok(Box::new(RandK::new(r)))
        }
        "qsgd" => {
            let l: u32 = arg.ok_or("qsgd needs a level count, e.g. qsgd:16")?.parse().map_err(|_| "bad qsgd levels")?;
            Ok(Box::new(Qsgd::new(l)))
        }
        _ => Err(format!("unknown compressor: {spec}")),
    }
}

// ---------------------------------------------------------------------------

/// No-op compressor, δ = 1.
#[derive(Clone, Copy, Debug)]
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> String {
        "none".into()
    }

    fn delta(&self) -> f64 {
        1.0
    }

    fn compress(&self, v: &[f32], _rng: &mut Rng) -> Compressed {
        Compressed { dim: v.len(), payload: Payload::Dense(v.to_vec()) }
    }
}

/// Keep the k = ⌈ratio·d⌉ largest-magnitude coordinates (biased, δ = k/d).
#[derive(Clone, Copy, Debug)]
pub struct TopK {
    pub ratio: f64,
}

impl TopK {
    pub fn new(ratio: f64) -> TopK {
        assert!(ratio > 0.0 && ratio <= 1.0, "topk ratio must be in (0,1]");
        TopK { ratio }
    }

    fn k(&self, d: usize) -> usize {
        ((self.ratio * d as f64).ceil() as usize).clamp(1, d)
    }
}

impl Compressor for TopK {
    fn name(&self) -> String {
        format!("topk:{}", self.ratio)
    }

    fn delta(&self) -> f64 {
        self.ratio
    }

    fn compress(&self, v: &[f32], _rng: &mut Rng) -> Compressed {
        let d = v.len();
        let k = self.k(d);
        if k == d {
            return Compressed { dim: d, payload: Payload::Dense(v.to_vec()) };
        }
        // Quickselect on |v| for the threshold, then gather ≥ threshold in
        // index order (ties broken by first-come, capped at k).
        let mut mags: Vec<f32> = v.iter().map(|x| x.abs()).collect();
        let thresh = quickselect_desc(&mut mags, k - 1);
        let mut idx = Vec::with_capacity(k);
        let mut val = Vec::with_capacity(k);
        for (i, &x) in v.iter().enumerate() {
            if x.abs() > thresh {
                idx.push(i as u32);
                val.push(x);
            }
        }
        // Fill remaining slots with values exactly at the threshold.
        if idx.len() < k {
            for (i, &x) in v.iter().enumerate() {
                if x.abs() == thresh {
                    idx.push(i as u32);
                    val.push(x);
                    if idx.len() == k {
                        break;
                    }
                }
            }
            // Keep index order canonical.
            let mut pairs: Vec<(u32, f32)> = idx.into_iter().zip(val).collect();
            pairs.sort_unstable_by_key(|p| p.0);
            idx = pairs.iter().map(|p| p.0).collect();
            val = pairs.iter().map(|p| p.1).collect();
        }
        Compressed { dim: d, payload: Payload::Sparse { idx, val } }
    }
}

/// k-th largest value (0-based) of `xs` by magnitude-descending order.
fn quickselect_desc(xs: &mut [f32], k: usize) -> f32 {
    let n = xs.len();
    assert!(k < n);
    let (mut lo, mut hi) = (0usize, n - 1);
    loop {
        if lo == hi {
            return xs[lo];
        }
        // Median-of-three pivot for adversarial orderings.
        let mid = lo + (hi - lo) / 2;
        let (a, b, c) = (xs[lo], xs[mid], xs[hi]);
        let pivot = if (a >= b) == (b >= c) { b } else if (b >= a) == (a >= c) { a } else { c };
        let (mut i, mut j) = (lo, hi);
        while i <= j {
            while xs[i] > pivot {
                i += 1;
            }
            while xs[j] < pivot {
                j -= 1;
            }
            if i <= j {
                xs.swap(i, j);
                i += 1;
                if j == 0 {
                    break;
                }
                j -= 1;
            }
        }
        if k <= j {
            hi = j;
        } else if k >= i {
            lo = i;
        } else {
            return xs[k];
        }
    }
}

/// Keep k uniformly random coordinates (contractive with δ = k/d).
#[derive(Clone, Copy, Debug)]
pub struct RandK {
    pub ratio: f64,
}

impl RandK {
    pub fn new(ratio: f64) -> RandK {
        assert!(ratio > 0.0 && ratio <= 1.0, "randk ratio must be in (0,1]");
        RandK { ratio }
    }
}

impl Compressor for RandK {
    fn name(&self) -> String {
        format!("randk:{}", self.ratio)
    }

    fn delta(&self) -> f64 {
        self.ratio
    }

    fn compress(&self, v: &[f32], rng: &mut Rng) -> Compressed {
        let d = v.len();
        let k = ((self.ratio * d as f64).ceil() as usize).clamp(1, d);
        if k == d {
            return Compressed { dim: d, payload: Payload::Dense(v.to_vec()) };
        }
        let indices = rng.sample_indices(d, k);
        let idx: Vec<u32> = indices.iter().map(|&i| i as u32).collect();
        let val: Vec<f32> = indices.iter().map(|&i| v[i]).collect();
        Compressed { dim: d, payload: Payload::Sparse { idx, val } }
    }
}

/// QSGD-style stochastic uniform quantization with `levels` buckets.
/// Unbiased; contractive after the Proposition-1 rescale with
/// δ = 1/(1 + min(d/levels², √d/levels)).
#[derive(Clone, Copy, Debug)]
pub struct Qsgd {
    pub levels: u32,
}

impl Qsgd {
    pub fn new(levels: u32) -> Qsgd {
        assert!(levels >= 1, "need at least 1 level");
        Qsgd { levels }
    }

    fn omega(&self, d: usize) -> f64 {
        let s = self.levels as f64;
        let d = d as f64;
        (d / (s * s)).min(d.sqrt() / s)
    }
}

impl Compressor for Qsgd {
    fn name(&self) -> String {
        format!("qsgd:{}", self.levels)
    }

    fn delta(&self) -> f64 {
        // Variance bound E‖Q(v)−v‖² ≤ ω‖v‖² with ω = min(d/s², √d/s); for a
        // representative d = 10⁴.  The per-call contraction is recomputed
        // from the actual d when it matters (tests use this method's bound).
        1.0 / (1.0 + self.omega(10_000))
    }

    fn compress(&self, v: &[f32], rng: &mut Rng) -> Compressed {
        let d = v.len();
        let norm = crate::linalg::norm2(v) as f32;
        if norm == 0.0 {
            return Compressed {
                dim: d,
                payload: Payload::Quantized { norm: 0.0, levels: self.levels, codes: vec![0; d] },
            };
        }
        let s = self.levels as f32;
        let mut codes = Vec::with_capacity(d);
        for &x in v {
            let u = x.abs() / norm * s; // in [0, s]
            let lo = u.floor();
            let level = lo + if rng.bernoulli((u - lo) as f64) { 1.0 } else { 0.0 };
            // Signed code in [−s, s]; stored as i16.
            let code = (level * x.signum()) as i16;
            codes.push(code);
        }
        Compressed { dim: d, payload: Payload::Quantized { norm, levels: self.levels, codes } }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg;

    fn rngv(seed: u64, d: usize) -> (Rng, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0; d];
        rng.fill_normal(&mut v, 0.0, 1.0);
        (rng, v)
    }

    #[test]
    fn identity_roundtrip() {
        let (mut rng, v) = rngv(1, 100);
        let c = Identity.compress(&v, &mut rng);
        assert_eq!(c.to_dense(), v);
        assert_eq!(c.wire_bytes(), 8 + 400);
    }

    #[test]
    fn topk_keeps_largest() {
        let mut rng = Rng::new(2);
        let v = vec![0.1, -5.0, 0.2, 3.0, -0.05];
        let c = TopK::new(0.4).compress(&v, &mut rng); // k = 2
        let dense = c.to_dense();
        assert_eq!(dense[1], -5.0);
        assert_eq!(dense[3], 3.0);
        assert_eq!(dense[0], 0.0);
        assert_eq!(dense[2], 0.0);
        assert_eq!(dense[4], 0.0);
    }

    #[test]
    fn topk_contraction_bound() {
        let (mut rng, v) = rngv(3, 500);
        let q = TopK::new(0.2);
        let c = q.compress(&v, &mut rng);
        let err: f64 = c
            .to_dense()
            .iter()
            .zip(&v)
            .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
            .sum();
        let bound = (1.0 - q.delta()) * linalg::norm2_sq(&v);
        assert!(err <= bound + 1e-6, "{err} > {bound}");
    }

    #[test]
    fn topk_wire_smaller_than_dense() {
        let (mut rng, v) = rngv(4, 1000);
        let dense = Identity.compress(&v, &mut rng).wire_bytes();
        let sparse = TopK::new(0.1).compress(&v, &mut rng).wire_bytes();
        assert!(sparse < dense / 4, "{sparse} vs {dense}");
    }

    #[test]
    fn topk_exact_k_when_ties() {
        let mut rng = Rng::new(5);
        let v = vec![1.0f32; 10]; // all tied
        let c = TopK::new(0.3).compress(&v, &mut rng);
        if let Payload::Sparse { idx, val } = &c.payload {
            assert_eq!(idx.len(), 3);
            assert!(val.iter().all(|&x| x == 1.0));
            assert!(idx.windows(2).all(|w| w[0] < w[1]));
        } else {
            panic!("expected sparse");
        }
    }

    #[test]
    fn randk_contraction_in_expectation() {
        let (mut rng, v) = rngv(6, 400);
        let q = RandK::new(0.25);
        let trials = 200;
        let mut err_sum = 0.0;
        for _ in 0..trials {
            let c = q.compress(&v, &mut rng);
            err_sum += c
                .to_dense()
                .iter()
                .zip(&v)
                .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
                .sum::<f64>();
        }
        let avg = err_sum / trials as f64;
        let bound = (1.0 - q.delta()) * linalg::norm2_sq(&v);
        assert!(avg <= bound * 1.05, "{avg} > {bound}");
    }

    #[test]
    fn qsgd_unbiased_and_bounded() {
        let (mut rng, v) = rngv(7, 256);
        let q = Qsgd::new(16);
        let trials = 300;
        let mut mean = vec![0.0f64; v.len()];
        for _ in 0..trials {
            let c = q.compress(&v, &mut rng);
            for (m, x) in mean.iter_mut().zip(c.to_dense()) {
                *m += x as f64;
            }
        }
        for m in mean.iter_mut() {
            *m /= trials as f64;
        }
        // Unbiasedness: mean reconstruction ≈ v.
        let diff: f64 = mean.iter().zip(&v).map(|(a, b)| (a - *b as f64).powi(2)).sum();
        let rel = diff / linalg::norm2_sq(&v);
        assert!(rel < 0.01, "bias {rel}");
    }

    #[test]
    fn qsgd_wire_bytes_small() {
        let (mut rng, v) = rngv(8, 1000);
        let c = Qsgd::new(16).compress(&v, &mut rng);
        // 2 bytes/coord (i16 codes) + norm + header ≪ 4 bytes/coord dense.
        assert!(c.wire_bytes() < 8 + 4 + 2 * 1000 + 16);
        assert!(c.wire_bytes() > 1000);
    }

    #[test]
    fn qsgd_zero_vector() {
        let mut rng = Rng::new(9);
        let v = vec![0.0f32; 32];
        let c = Qsgd::new(8).compress(&v, &mut rng);
        assert_eq!(c.to_dense(), v);
    }

    #[test]
    fn add_scaled_into_matches_dense_math() {
        let (mut rng, v) = rngv(10, 64);
        let c = TopK::new(0.5).compress(&v, &mut rng);
        let mut target = vec![1.0f32; 64];
        c.add_scaled_into(0.5, &mut target);
        let dense = c.to_dense();
        for i in 0..64 {
            assert!((target[i] - (1.0 + 0.5 * dense[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn parse_specs() {
        assert_eq!(parse("topk:0.2").unwrap().name(), "topk:0.2");
        assert_eq!(parse("randk:0.5").unwrap().name(), "randk:0.5");
        assert_eq!(parse("qsgd:16").unwrap().name(), "qsgd:16");
        assert_eq!(parse("none").unwrap().name(), "none");
        assert!(parse("bogus").is_err());
        assert!(parse("topk").is_err());
    }

    #[test]
    fn quickselect_matches_sort() {
        let mut rng = Rng::new(11);
        for _ in 0..50 {
            let n = 1 + rng.below(200);
            let mut v: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let k = rng.below(n);
            let got = quickselect_desc(&mut v.clone(), k);
            v.sort_by(|a, b| b.partial_cmp(a).unwrap());
            assert_eq!(got, v[k]);
        }
    }
}
