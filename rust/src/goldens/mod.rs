//! Golden-trace regression fixtures: deterministic pinned trajectories for
//! every algorithm on every native task.
//!
//! The scenario matrix is {C²DFB, C²DFB(nc), MADSBO, MDBO} ×
//! {quadratic, logreg, hyperrep} × {ring, exponential} × {sync,
//! benign-sim} — 48 short runs, a few rounds each, every one seeded so a
//! `(code, fixture)` pair either agrees bit-for-bit-modulo-tolerance or
//! the build fails.  This is the safety net performance PRs diff against:
//! a refactor that changes any trajectory, byte count or oracle count
//! shows up as fixture drift.
//!
//! * [`bless`] regenerates the fixtures under `rust/goldens/*.json` (one
//!   file per task).  Blessing is deterministic: a second bless produces
//!   byte-identical files (CI proves this on every push).
//! * [`replay`] re-runs the matrix and diffs against the committed
//!   fixtures with per-field tolerances — **exact** for communication
//!   bytes, message/round counts, oracle counts and stop reasons,
//!   **1e-9 relative** for losses, gradient norms and consensus errors
//!   (floating-point results may legitimately be re-associated by future
//!   compiler versions; byte counts may not drift, ever).
//! * Missing fixture files are bootstrapped on first replay (written and
//!   reported, not failed) so a fresh clone without a toolchain-blessed
//!   checkout can still self-initialize; commit the generated files.
//!
//! CLI: `c2dfb goldens [--bless] [--dir D]`; test: `tests/golden.rs`.

use crate::config::{Algorithm, ExperimentConfig};
use crate::coordinator::Runner;
use crate::data::partition::Partition;
use crate::metrics::RunMetrics;
use crate::sim::NetMode;
use crate::tasks::{BilevelTask, HyperRepTask, LogRegTask, QuadraticTask};
use crate::topology::Topology;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Fixture format version; bump when the schema changes (forces re-bless).
pub const FORMAT: u64 = 1;

/// Relative tolerance for float trace fields (loss, grad norm, consensus).
pub const REL_TOL: f64 = 1e-9;

/// Which native task a scenario runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    Quadratic,
    Logreg,
    Hyperrep,
}

impl TaskKind {
    pub const ALL: [TaskKind; 3] = [TaskKind::Quadratic, TaskKind::Logreg, TaskKind::Hyperrep];

    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::Quadratic => "quadratic",
            TaskKind::Logreg => "logreg",
            TaskKind::Hyperrep => "hyperrep",
        }
    }

    /// Build the task instance (fixed generation seeds: the fixtures pin
    /// these exact datasets).
    pub fn build(&self) -> Box<dyn BilevelTask + Sync> {
        match self {
            TaskKind::Quadratic => Box::new(QuadraticTask::<f32>::generate(4, 8, 0.8, 11)),
            TaskKind::Logreg => Box::new(LogRegTask::<f32>::generate(
                4,
                12,
                3,
                24,
                12,
                Partition::Dirichlet { alpha: 0.5 },
                0.4,
                11,
            )),
            TaskKind::Hyperrep => Box::new(HyperRepTask::<f32>::generate(
                4,
                12,
                4,
                3,
                20,
                10,
                Partition::Dirichlet { alpha: 0.5 },
                0.3,
                13,
            )),
        }
    }
}

/// Which transport engine a scenario uses.  `BenignSim` is the event
/// engine with the default (lossless, jitter-free) link model — its
/// fixtures double as a pinned record of the sync ≡ benign-sim
/// equivalence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    Sync,
    BenignSim,
}

impl Engine {
    pub fn name(&self) -> &'static str {
        match self {
            Engine::Sync => "sync",
            Engine::BenignSim => "sim",
        }
    }
}

/// One cell of the golden matrix.
#[derive(Clone, Copy, Debug)]
pub struct Scenario {
    pub algo: Algorithm,
    pub task: TaskKind,
    pub topology: Topology,
    pub engine: Engine,
}

impl Scenario {
    /// Key inside the per-task fixture file, e.g. `c2dfb_ring_sync`.
    pub fn id(&self) -> String {
        format!(
            "{}_{}_{}",
            self.algo.name(),
            self.topology.name(),
            self.engine.name()
        )
    }
}

/// The full 4×3×2×2 matrix in a deterministic order.
pub fn matrix() -> Vec<Scenario> {
    let algos = [
        Algorithm::C2dfb,
        Algorithm::C2dfbNc,
        Algorithm::Madsbo,
        Algorithm::Mdbo,
    ];
    let topologies = [Topology::Ring, Topology::Exponential];
    let engines = [Engine::Sync, Engine::BenignSim];
    let mut out = Vec::with_capacity(48);
    for task in TaskKind::ALL {
        for algo in algos {
            for topology in topologies {
                for engine in engines {
                    out.push(Scenario { algo, task, topology, engine });
                }
            }
        }
    }
    out
}

/// The run configuration for a scenario: a few rounds, eval every round,
/// per-task step sizes known to stay finite.  Everything here is part of
/// the fixture contract — changing any value invalidates the fixtures
/// (re-bless and review the diff).
pub fn config_for(s: &Scenario) -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        name: "goldens".into(),
        algorithm: s.algo,
        nodes: 4,
        topology: s.topology,
        rounds: 3,
        eval_every: 1,
        seed: 42,
        compressor: "topk:0.5".into(),
        gamma_out: 0.8,
        gamma_in: 0.6,
        ..ExperimentConfig::default()
    };
    match s.task {
        TaskKind::Quadratic => {
            cfg.inner_steps = 8;
            cfg.eta_out = 0.3;
            cfg.eta_in = 0.4;
            cfg.lambda = 50.0;
        }
        TaskKind::Logreg => {
            cfg.inner_steps = 5;
            cfg.eta_out = 0.2;
            cfg.eta_in = 0.3;
            cfg.lambda = 10.0;
        }
        TaskKind::Hyperrep => {
            cfg.inner_steps = 5;
            cfg.eta_out = 0.05;
            cfg.eta_in = 0.05;
            cfg.lambda = 10.0;
        }
    }
    if s.engine == Engine::BenignSim {
        cfg.network.mode = NetMode::Event;
    }
    cfg
}

/// Run one scenario against an already-built task.  Attaches the same
/// divergence guard the sweep pool uses for `bless`/`replay`
/// ([`crate::coordinator::sweep::HarnessObserver`]), so this path and
/// the pooled fixture pipeline record identical traces for any scenario
/// — including a hypothetical diverging one, which both would truncate
/// with `stop_reason = observer_abort` (and which replay would then
/// flag as drift against a healthy fixture).
pub fn run_scenario(task: &(dyn BilevelTask + Sync), s: &Scenario) -> Result<RunMetrics> {
    let cfg = config_for(s);
    let mut guard = crate::coordinator::sweep::HarnessObserver::default();
    Runner::new(&cfg)
        .shared_task(task)
        .observer(&mut guard)
        .run()
        .with_context(|| format!("golden scenario {} ({})", s.id(), s.task.name()))
}

/// Default fixture directory: `<crate root>/goldens`.
pub fn default_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("goldens")
}

fn fixture_path(dir: &Path, task: TaskKind) -> PathBuf {
    dir.join(format!("{}.json", task.name()))
}

/// Serialize one run into its fixture record.  Wall-clock fields are
/// deliberately excluded (non-deterministic); everything here must be a
/// pure function of (code, config, seed).
fn run_json(s: &Scenario, m: &RunMetrics) -> Json {
    let trace: Vec<Json> = m
        .trace
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("round", Json::num(p.round as f64)),
                ("comm_mb", Json::num(p.comm_mb)),
                ("loss", Json::num(p.loss)),
                ("grad_norm", Json::num(p.grad_norm)),
                ("consensus", Json::num(p.consensus_err)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("algo", Json::str(s.algo.name())),
        ("topology", Json::str(s.topology.name())),
        ("engine", Json::str(s.engine.name())),
        ("total_bytes", Json::num(m.ledger.total_bytes as f64)),
        ("messages", Json::num(m.ledger.messages as f64)),
        ("gossip_rounds", Json::num(m.ledger.gossip_rounds as f64)),
        ("first_order", Json::num(m.oracles.first_order as f64)),
        ("second_order", Json::num(m.oracles.second_order as f64)),
        ("evals", Json::num(m.oracles.evals as f64)),
        (
            "stop_reason",
            Json::str(m.stop_reason.map_or("none", |r| r.name())),
        ),
        ("trace", Json::Arr(trace)),
    ])
}

/// Run every scenario of one task kind and assemble the fixture document.
/// The scenarios execute as cells on the sweep orchestrator's
/// work-stealing pool (`jobs` workers; 0 = all cores), so replay and
/// bless exercise — and are therefore proven against — the same
/// determinism-under-parallelism contract as every other sweep: the
/// assembled document is byte-identical at any `jobs`.
fn fixture_for(task: TaskKind, jobs: usize) -> Result<Json> {
    use crate::coordinator::sweep::{self, Cell, TaskRef};
    let t = task.build();
    let scenarios: Vec<Scenario> = matrix().into_iter().filter(|s| s.task == task).collect();
    let cells: Vec<Cell> = scenarios
        .iter()
        .map(|s| Cell { id: s.id(), cfg: config_for(s), task: TaskRef::Shared(0) })
        .collect();
    let outcomes = sweep::run_cells(&cells, &[t.as_ref()], None, jobs, false);
    let mut out = Vec::new();
    for (s, o) in scenarios.iter().zip(outcomes) {
        let m = o.result.map_err(|e| {
            anyhow::anyhow!("golden scenario {} ({}): {e}", s.id(), s.task.name())
        })?;
        out.push((s.id(), run_json(s, &m)));
    }
    Ok(Json::obj(vec![
        ("format", Json::num(FORMAT as f64)),
        ("task", Json::str(task.name())),
        (
            "scenarios",
            Json::Obj(out.into_iter().collect()),
        ),
    ]))
}

/// Regenerate all fixture files under `dir`.  Deterministic: a second
/// bless writes byte-identical files, at any `jobs` (0 = all cores).
pub fn bless(dir: &Path, jobs: usize) -> Result<Vec<PathBuf>> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating fixture dir {}", dir.display()))?;
    let mut written = Vec::new();
    for task in TaskKind::ALL {
        let doc = fixture_for(task, jobs)?;
        let path = fixture_path(dir, task);
        std::fs::write(&path, doc.to_string() + "\n")
            .with_context(|| format!("writing {}", path.display()))?;
        written.push(path);
    }
    Ok(written)
}

/// Outcome of a replay: which files were checked, which were freshly
/// bootstrapped (absent before), and every field-level mismatch found.
#[derive(Debug, Default)]
pub struct ReplayReport {
    pub checked: usize,
    pub bootstrapped: Vec<PathBuf>,
    pub mismatches: Vec<String>,
}

impl ReplayReport {
    pub fn ok(&self) -> bool {
        self.mismatches.is_empty()
    }
}

/// Fixture numbers may be `null` (JSON has no NaN literal — the baselines
/// report a NaN grad norm at round 0).
fn num_or_nan(v: Option<&Json>) -> f64 {
    match v {
        Some(Json::Null) | None => f64::NAN,
        Some(j) => j.as_f64().unwrap_or(f64::NAN),
    }
}

fn close_rel(a: f64, b: f64) -> bool {
    // JSON has no NaN/Inf literal: every non-finite value is blessed as
    // `null` and parses back as NaN, so all non-finite values are one
    // equivalence class on replay (NaN vs Inf cannot be distinguished
    // after a round-trip).
    if !a.is_finite() || !b.is_finite() {
        return !a.is_finite() && !b.is_finite();
    }
    (a - b).abs() <= REL_TOL * (1.0f64).max(a.abs()).max(b.abs())
}

/// Diff one scenario's expected fixture record against a fresh run.
fn diff_run(id: &str, expected: &Json, actual: &Json, out: &mut Vec<String>) {
    // Exact integer counters and strings.
    for key in [
        "total_bytes",
        "messages",
        "gossip_rounds",
        "first_order",
        "second_order",
        "evals",
    ] {
        let e = num_or_nan(expected.get(key));
        let a = num_or_nan(actual.get(key));
        if e != a {
            out.push(format!("{id}: {key} expected {e}, got {a} (exact field)"));
        }
    }
    for key in ["stop_reason", "algo", "topology", "engine"] {
        let e = expected.get(key).and_then(Json::as_str);
        let a = actual.get(key).and_then(Json::as_str);
        if e != a {
            out.push(format!("{id}: {key} expected {e:?}, got {a:?}"));
        }
    }
    let empty: Vec<Json> = Vec::new();
    let etr = expected.get("trace").and_then(Json::as_arr).unwrap_or(&empty);
    let atr = actual.get("trace").and_then(Json::as_arr).unwrap_or(&empty);
    if etr.len() != atr.len() {
        out.push(format!(
            "{id}: trace length expected {}, got {}",
            etr.len(),
            atr.len()
        ));
        return;
    }
    for (i, (e, a)) in etr.iter().zip(atr).enumerate() {
        // Round index and comm bytes are exact; losses are tolerance-based.
        for key in ["round", "comm_mb"] {
            let ev = num_or_nan(e.get(key));
            let av = num_or_nan(a.get(key));
            if ev != av {
                out.push(format!(
                    "{id}[{i}]: {key} expected {ev}, got {av} (exact field)"
                ));
            }
        }
        for key in ["loss", "grad_norm", "consensus"] {
            let ev = num_or_nan(e.get(key));
            let av = num_or_nan(a.get(key));
            if !close_rel(ev, av) {
                out.push(format!(
                    "{id}[{i}]: {key} expected {ev}, got {av} (rel tol {REL_TOL})"
                ));
            }
        }
    }
}

/// Replay the full matrix against the fixtures under `dir`, running the
/// scenario re-runs on the sweep pool (`jobs` workers; 0 = all cores —
/// the results are bit-identical at any width).  Absent fixture files
/// are bootstrapped (written from the current code) and reported;
/// present files are diffed field by field.
pub fn replay(dir: &Path, jobs: usize) -> Result<ReplayReport> {
    let mut report = ReplayReport::default();
    for task in TaskKind::ALL {
        let path = fixture_path(dir, task);
        if !path.exists() {
            let actual = fixture_for(task, jobs)?;
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating fixture dir {}", dir.display()))?;
            std::fs::write(&path, actual.to_string() + "\n")
                .with_context(|| format!("bootstrapping {}", path.display()))?;
            report.bootstrapped.push(path);
            continue;
        }
        // Parse and format-check the fixture BEFORE paying for the 16
        // scenario re-runs, so corrupt/stale files fail fast.
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let expected = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let fmt = num_or_nan(expected.get("format"));
        if fmt != FORMAT as f64 {
            bail!(
                "{}: fixture format {fmt} != supported {FORMAT}; re-bless with `c2dfb goldens --bless`",
                path.display()
            );
        }
        let actual = fixture_for(task, jobs)?;
        let empty = std::collections::BTreeMap::new();
        let escn = expected
            .get("scenarios")
            .and_then(Json::as_obj)
            .unwrap_or(&empty);
        let ascn = actual
            .get("scenarios")
            .and_then(Json::as_obj)
            .expect("fixture_for always emits scenarios");
        for (id, a) in ascn {
            match escn.get(id) {
                None => report
                    .mismatches
                    .push(format!("{}: scenario {id} missing from fixture", task.name())),
                Some(e) => diff_run(id, e, a, &mut report.mismatches),
            }
            report.checked += 1;
        }
        for id in escn.keys() {
            if !ascn.contains_key(id) {
                report.mismatches.push(format!(
                    "{}: fixture scenario {id} no longer produced",
                    task.name()
                ));
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_is_full_and_ids_unique() {
        let m = matrix();
        assert_eq!(m.len(), 48, "4 algos × 3 tasks × 2 topologies × 2 engines");
        let mut ids: Vec<String> =
            m.iter().map(|s| format!("{}/{}", s.task.name(), s.id())).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 48, "scenario ids must be unique");
    }

    #[test]
    fn configs_validate() {
        for s in matrix() {
            config_for(&s).validate().unwrap_or_else(|e| {
                panic!("invalid golden config for {}: {e}", s.id());
            });
        }
    }

    #[test]
    fn close_rel_handles_nonfinite_and_scale() {
        assert!(close_rel(f64::NAN, f64::NAN));
        assert!(!close_rel(f64::NAN, 1.0));
        // Inf blesses as null and replays as NaN: one equivalence class.
        assert!(close_rel(f64::INFINITY, f64::NAN));
        assert!(close_rel(f64::NEG_INFINITY, f64::INFINITY));
        assert!(!close_rel(f64::INFINITY, 1.0));
        assert!(close_rel(1.0, 1.0 + 1e-12));
        assert!(!close_rel(1.0, 1.0 + 1e-6));
        assert!(close_rel(1e12, 1e12 * (1.0 + 1e-10)));
    }

    #[test]
    fn run_json_excludes_wall_clock_and_roundtrips() {
        let s = Scenario {
            algo: Algorithm::C2dfb,
            task: TaskKind::Quadratic,
            topology: Topology::Ring,
            engine: Engine::Sync,
        };
        let t = TaskKind::Quadratic.build();
        let m = run_scenario(t.as_ref(), &s).unwrap();
        let j = run_json(&s, &m);
        let text = j.to_string();
        assert!(!text.contains("wall"), "wall-clock must not enter fixtures");
        let re = Json::parse(&text).unwrap();
        assert_eq!(re, j, "fixture records must round-trip through JSON");
        // And a self-diff is clean.
        let mut out = Vec::new();
        diff_run("self", &j, &re, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }
}
