//! Consensus-distance evaluation at scale.
//!
//! [`crate::linalg::consensus_err_sq`] is the exact Σ_i ‖x_i − x̄‖² — an
//! O(m·d) pass the driver runs at every eval point.  At millions of nodes
//! that pass costs more than the round it measures, so
//! [`ConsensusEstimator`] subsamples above a node-count threshold: every
//! `stride`-th row is measured against the subset's own mean and the
//! subset sum is scaled by m / |subset|.  The strided rows are spread
//! evenly across node ids, so block-structured disagreement (e.g. a torus
//! quadrant lagging) is still seen.
//!
//! Contract pinned by tests here and in `tests/proptests.rs`:
//! * `exact` and `strided:1` call the SAME function — bitwise-equal
//!   results, not merely close ones.
//! * `auto` is exact at or below its threshold, so every config that
//!   existed before this knob (m ≤ 4096) keeps byte-stable traces.
//! * As the stride shrinks toward 1 the estimate converges to exact.

use crate::linalg::{self, Scalar};

/// Node count at or below which `auto` stays exact.  Every golden config
/// sits far under this, so the default estimator never perturbs them.
pub const AUTO_EXACT_THRESHOLD: usize = 4096;

/// How to evaluate the consensus distance Σ_i ‖x_i − x̄‖².
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConsensusEstimator {
    /// Exact at or below `threshold` nodes; above it, strided with the
    /// stride chosen to sample ≈ `threshold` rows.
    Auto { threshold: usize },
    /// Always the full evaluation.
    Exact,
    /// Sample rows 0, stride, 2·stride, …; scale the subset sum by
    /// m / |subset|.  `strided:1` is the exact path (bitwise).
    Strided { stride: usize },
}

impl Default for ConsensusEstimator {
    fn default() -> Self {
        ConsensusEstimator::Auto { threshold: AUTO_EXACT_THRESHOLD }
    }
}

impl ConsensusEstimator {
    /// Parse "auto", "auto:THRESHOLD", "exact", or "strided:K".
    pub fn parse(s: &str) -> Result<ConsensusEstimator, String> {
        if s == "auto" {
            return Ok(ConsensusEstimator::default());
        }
        if s == "exact" {
            return Ok(ConsensusEstimator::Exact);
        }
        if let Some(t) = s.strip_prefix("auto:") {
            let threshold: usize = t
                .parse()
                .map_err(|_| format!("bad auto threshold: {s}"))?;
            if threshold == 0 {
                return Err("auto threshold must be >= 1".into());
            }
            return Ok(ConsensusEstimator::Auto { threshold });
        }
        if let Some(t) = s.strip_prefix("strided:") {
            let stride: usize = t.parse().map_err(|_| format!("bad stride: {s}"))?;
            if stride == 0 {
                return Err("stride must be >= 1".into());
            }
            return Ok(ConsensusEstimator::Strided { stride });
        }
        Err(format!(
            "unknown consensus estimator: {s} (want auto, auto:N, exact, strided:K)"
        ))
    }

    pub fn name(&self) -> String {
        match self {
            ConsensusEstimator::Auto { threshold } if *threshold == AUTO_EXACT_THRESHOLD => {
                "auto".into()
            }
            ConsensusEstimator::Auto { threshold } => format!("auto:{threshold}"),
            ConsensusEstimator::Exact => "exact".into(),
            ConsensusEstimator::Strided { stride } => format!("strided:{stride}"),
        }
    }

    /// Evaluate (or estimate) Σ_i ‖x_i − x̄‖² over the stacked rows.
    /// Generic over the payload [`Scalar`]; the reduction itself is always
    /// f64, so at `S = f32` this is byte-for-byte the historical path.
    pub fn estimate<S: Scalar>(&self, rows: &[Vec<S>]) -> f64 {
        let m = rows.len();
        match *self {
            ConsensusEstimator::Exact => linalg::consensus_err_sq(rows),
            ConsensusEstimator::Auto { threshold } => {
                if m <= threshold {
                    linalg::consensus_err_sq(rows)
                } else {
                    strided_err_sq(rows, m.div_ceil(threshold))
                }
            }
            ConsensusEstimator::Strided { stride } => strided_err_sq(rows, stride),
        }
    }

    /// The row stride this estimator uses at `m` nodes (1 = exact).
    pub fn stride_for(&self, m: usize) -> usize {
        match *self {
            ConsensusEstimator::Exact => 1,
            ConsensusEstimator::Auto { threshold } => {
                if m <= threshold {
                    1
                } else {
                    m.div_ceil(threshold)
                }
            }
            ConsensusEstimator::Strided { stride } => stride,
        }
    }

    /// [`estimate`](Self::estimate) from lazily-derived rows: `fill(i,
    /// row)` writes node i's d-dimensional row.  Only the sampled subset
    /// is materialized — O((m / stride)·d) memory — which is what lets
    /// the sparse scale engine ([`crate::sim::scale`]) report consensus
    /// at m = 10⁶ without holding m rows.  For every variant the result
    /// is bitwise identical to `estimate` on fully materialized rows:
    /// stride 1 materializes everything and calls the same exact
    /// function; stride > 1 picks the same subset and runs the same f64
    /// reduction.
    pub fn estimate_sampled<S: Scalar>(
        &self,
        m: usize,
        d: usize,
        mut fill: impl FnMut(usize, &mut [S]),
    ) -> f64 {
        let stride = self.stride_for(m);
        let mut rows: Vec<Vec<S>> = Vec::with_capacity(m.div_ceil(stride));
        for i in (0..m).step_by(stride) {
            let mut r = vec![S::ZERO; d];
            fill(i, &mut r);
            rows.push(r);
        }
        if stride == 1 {
            linalg::consensus_err_sq(&rows)
        } else {
            subset_scaled_err_sq(&rows, m)
        }
    }
}

/// Strided estimate: subset = rows {0, stride, 2·stride, …}, measured
/// against the subset mean, scaled by m / |subset|.  `stride == 1` is
/// exactly `linalg::consensus_err_sq` — same call, same bits.
fn strided_err_sq<S: Scalar>(rows: &[Vec<S>], stride: usize) -> f64 {
    assert!(stride >= 1, "stride must be >= 1");
    if stride == 1 {
        return linalg::consensus_err_sq(rows);
    }
    let picked: Vec<&Vec<S>> = rows.iter().step_by(stride).collect();
    subset_scaled_err_sq(&picked, rows.len())
}

/// The shared strided reduction: subset rows against the subset's own
/// f64 mean, subset sum scaled by m / |subset|.  One implementation so
/// the materialized ([`strided_err_sq`]) and lazy
/// ([`ConsensusEstimator::estimate_sampled`]) paths agree bitwise.
fn subset_scaled_err_sq<S: Scalar, R: AsRef<[S]>>(picked: &[R], m: usize) -> f64 {
    let n = picked.len();
    let d = picked[0].as_ref().len();
    let mut mean = vec![0.0f64; d];
    for r in picked {
        for (s, x) in mean.iter_mut().zip(r.as_ref()) {
            *s += x.to_f64();
        }
    }
    for s in &mut mean {
        *s /= n as f64;
    }
    let sum: f64 = picked
        .iter()
        .map(|r| {
            r.as_ref()
                .iter()
                .zip(&mean)
                .map(|(a, b)| (a.to_f64() - b).powi(2))
                .sum::<f64>()
        })
        .sum();
    sum * (m as f64 / n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_rows(m: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..m)
            .map(|_| (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect()
    }

    #[test]
    fn parse_and_name_roundtrip() {
        for s in ["auto", "exact", "strided:7", "auto:128"] {
            let e = ConsensusEstimator::parse(s).unwrap();
            assert_eq!(e.name(), s);
        }
        assert_eq!(
            ConsensusEstimator::parse("auto").unwrap(),
            ConsensusEstimator::Auto { threshold: AUTO_EXACT_THRESHOLD }
        );
        for bad in ["strided:0", "auto:0", "strided:x", "bogus"] {
            assert!(ConsensusEstimator::parse(bad).is_err(), "{bad}");
        }
    }

    /// stride 1 and exact are the SAME code path — bitwise equal.
    #[test]
    fn stride_one_is_bitwise_exact() {
        let rows = rand_rows(37, 9, 3);
        let exact = ConsensusEstimator::Exact.estimate(&rows);
        let s1 = ConsensusEstimator::Strided { stride: 1 }.estimate(&rows);
        assert_eq!(exact.to_bits(), s1.to_bits());
    }

    /// Auto below the threshold is the exact path, bitwise.
    #[test]
    fn auto_is_exact_below_threshold() {
        let rows = rand_rows(64, 5, 4);
        let exact = ConsensusEstimator::Exact.estimate(&rows);
        let auto = ConsensusEstimator::default().estimate(&rows);
        assert_eq!(exact.to_bits(), auto.to_bits());
    }

    /// Shrinking the stride converges monotonically-in-error toward exact
    /// on smooth disagreement fields.
    #[test]
    fn strided_converges_to_exact() {
        let m = 1200;
        let rows: Vec<Vec<f32>> = (0..m)
            .map(|i| {
                let t = i as f32 / m as f32;
                vec![t.sin(), (2.0 * t).cos(), t]
            })
            .collect();
        let exact = ConsensusEstimator::Exact.estimate(&rows);
        assert!(exact > 0.0);
        for (stride, bound) in [(64usize, 0.25), (16, 0.10), (4, 0.03)] {
            let est = ConsensusEstimator::Strided { stride }.estimate(&rows);
            let rel = (est - exact).abs() / exact;
            assert!(rel < bound, "stride {stride}: rel err {rel} >= {bound}");
        }
        let s1 = ConsensusEstimator::Strided { stride: 1 }.estimate(&rows);
        assert_eq!(s1.to_bits(), exact.to_bits(), "stride 1 must recover exact");
    }

    /// Perfect consensus is reported as exactly zero at any stride.
    #[test]
    fn zero_on_consensus_rows() {
        let rows = vec![vec![1.5f32, -0.5]; 500];
        for e in [
            ConsensusEstimator::Exact,
            ConsensusEstimator::Strided { stride: 17 },
            ConsensusEstimator::Auto { threshold: 10 },
        ] {
            assert_eq!(e.estimate(&rows), 0.0);
        }
    }

    /// The lazy entry point materializes only the sampled subset yet
    /// returns the exact bits of the materialized evaluation, for every
    /// variant on both sides of the auto threshold.
    #[test]
    fn estimate_sampled_is_bitwise_identical_to_estimate() {
        for (m, d) in [(50usize, 3usize), (700, 4)] {
            let rows = rand_rows(m, d, 11);
            for est in [
                ConsensusEstimator::Exact,
                ConsensusEstimator::Auto { threshold: 100 },
                ConsensusEstimator::Strided { stride: 1 },
                ConsensusEstimator::Strided { stride: 13 },
            ] {
                let dense = est.estimate(&rows);
                let lazy =
                    est.estimate_sampled(m, d, |i, out| out.copy_from_slice(&rows[i]));
                assert_eq!(
                    dense.to_bits(),
                    lazy.to_bits(),
                    "{} at m={m}: dense {dense} vs lazy {lazy}",
                    est.name()
                );
            }
        }
    }

    #[test]
    fn stride_for_matches_variant_semantics() {
        let auto = ConsensusEstimator::Auto { threshold: 100 };
        assert_eq!(auto.stride_for(100), 1);
        assert_eq!(auto.stride_for(101), 2);
        assert_eq!(auto.stride_for(1_000_000), 10_000);
        assert_eq!(ConsensusEstimator::Exact.stride_for(1_000_000), 1);
        assert_eq!(ConsensusEstimator::Strided { stride: 7 }.stride_for(10), 7);
    }

    /// Above its threshold, auto switches to a stride targeting ~threshold
    /// sampled rows and stays within a reasonable band of exact on
    /// homogeneous random data.
    #[test]
    fn auto_estimates_above_threshold() {
        let rows = rand_rows(2000, 4, 9);
        let exact = ConsensusEstimator::Exact.estimate(&rows);
        let est = ConsensusEstimator::Auto { threshold: 250 }.estimate(&rows);
        let rel = (est - exact).abs() / exact;
        assert!(rel < 0.2, "auto estimate off by {rel}");
    }
}
