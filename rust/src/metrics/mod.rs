//! Run metrics: communication ledger, oracle counters, traces, CSV/JSON out.
//!
//! The paper's plots are test accuracy/loss against (a) cumulative
//! communication volume in MB, (b) wall-clock time, and (c) round index —
//! so the ledger records exact bytes per round (from the compressor's wire
//! model), a modeled network time (latency + bytes/bandwidth per gossip
//! round, the in-process simulator has no real network), and real compute
//! time.

use std::fmt::Write as _;
use std::time::Instant;

use crate::util::json::Json;

pub mod consensus;

pub use consensus::{ConsensusEstimator, AUTO_EXACT_THRESHOLD};

/// Simple network cost model used to convert bytes into simulated seconds.
/// Defaults approximate the paper's LAN testbed: 1 ms latency, 1 Gbit/s.
#[derive(Clone, Copy, Debug)]
pub struct TimeModel {
    pub latency_s: f64,
    pub bandwidth_bytes_per_s: f64,
}

impl Default for TimeModel {
    fn default() -> Self {
        TimeModel { latency_s: 1e-3, bandwidth_bytes_per_s: 125e6 }
    }
}

impl TimeModel {
    /// Time for one synchronous gossip round in which the busiest node
    /// sends `max_node_bytes` (nodes transmit to neighbours in parallel).
    pub fn round_time(&self, max_node_bytes: usize) -> f64 {
        self.latency_s + max_node_bytes as f64 / self.bandwidth_bytes_per_s
    }
}

/// Per-run communication ledger.
#[derive(Clone, Debug, Default)]
pub struct CommLedger {
    /// Total application bytes sent by all nodes (paid even for messages
    /// the transport later loses — they left the NIC).
    pub total_bytes: u64,
    /// Number of gossip exchanges (a "communication round" in the plots).
    pub gossip_rounds: u64,
    /// Total virtual network seconds: the synchronous engine accumulates a
    /// per-round cost model, the event engine reports its furthest node
    /// clock.
    pub network_time_s: f64,
    /// Messages sent.
    pub messages: u64,
    /// Messages lost in transit (event engine's drop injection; always 0
    /// on the synchronous engine).
    pub dropped_messages: u64,
}

impl CommLedger {
    /// Record one synchronous gossip exchange.  `per_node_bytes[i]` is the
    /// bytes node i transmitted to EACH neighbour; `fanout[i]` its degree.
    pub fn record_round(
        &mut self,
        per_node_bytes: &[usize],
        fanout: &[usize],
        tm: &TimeModel,
    ) {
        self.record_round_active(per_node_bytes, fanout, None, tm);
    }

    /// [`record_round`](CommLedger::record_round) under a per-round node
    /// sampling mask: inactive senders transmit nothing and pay nothing
    /// (no bytes, no messages, and they don't bound the round time).
    /// `active: None` is the unmasked path, bit-identical to
    /// `record_round`.
    pub fn record_round_active(
        &mut self,
        per_node_bytes: &[usize],
        fanout: &[usize],
        active: Option<&[bool]>,
        tm: &TimeModel,
    ) {
        let mut max_node = 0usize;
        for (i, (b, f)) in per_node_bytes.iter().zip(fanout).enumerate() {
            if let Some(mask) = active {
                if !mask[i] {
                    continue;
                }
            }
            let node_total = b * f;
            self.total_bytes += node_total as u64;
            self.messages += *f as u64;
            max_node = max_node.max(node_total);
        }
        self.gossip_rounds += 1;
        self.network_time_s += tm.round_time(max_node);
    }

    pub fn total_mb(&self) -> f64 {
        self.total_bytes as f64 / 1e6
    }
}

/// Oracle-call counters — the paper's computation-efficiency metric.
#[derive(Clone, Debug, Default)]
pub struct OracleCounter {
    pub first_order: u64,
    pub second_order: u64, // HVP / JVP calls (baselines only)
    pub evals: u64,
}

/// Why a run ended — recorded in [`RunMetrics`] and the CSV/JSON outputs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// The configured round cap was reached.
    Rounds,
    /// The communication budget (MB) was exhausted.
    CommBudget,
    /// The first-order oracle budget was exhausted.
    FirstOrderOracles,
    /// The target test accuracy was reached.
    TargetAccuracy,
    /// The wall-clock limit elapsed.
    WallClock,
    /// The virtual (simulated) network-time limit elapsed.
    SimTime,
    /// A [`RunObserver`](crate::algorithms::RunObserver) aborted the run.
    Observer,
}

impl StopReason {
    pub fn name(&self) -> &'static str {
        match self {
            StopReason::Rounds => "rounds",
            StopReason::CommBudget => "comm_budget",
            StopReason::FirstOrderOracles => "first_order_oracles",
            StopReason::TargetAccuracy => "target_accuracy",
            StopReason::WallClock => "wall_clock",
            StopReason::SimTime => "sim_time",
            StopReason::Observer => "observer_abort",
        }
    }
}

/// A budgeted stopping rule, evaluated by the runner against the live
/// [`CommLedger`]/[`OracleCounter`] mirror at every evaluation point — so
/// a condition fires within one `eval_every` interval of becoming true,
/// and a budget-stopped run is a bit-identical prefix of the fixed-round
/// trace.  Built from the config by
/// [`ExperimentConfig::stop_conditions`](crate::config::ExperimentConfig::stop_conditions).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StopCondition {
    /// Stop after this many outer rounds (the classic `rounds` cap).
    Rounds(usize),
    /// Stop once total communication reaches this many megabytes.
    CommBudgetMb(f64),
    /// Stop once this many first-order oracle calls have been paid.
    FirstOrderOracles(u64),
    /// Stop once consensus test accuracy reaches this value.
    TargetAccuracy(f64),
    /// Stop once this much real wall-clock time has elapsed.
    WallClockSecs(f64),
    /// Stop once the transport's virtual network time reaches this value.
    SimTimeSecs(f64),
}

impl StopCondition {
    /// The reason recorded when this condition fires.
    pub fn reason(&self) -> StopReason {
        match self {
            StopCondition::Rounds(_) => StopReason::Rounds,
            StopCondition::CommBudgetMb(_) => StopReason::CommBudget,
            StopCondition::FirstOrderOracles(_) => StopReason::FirstOrderOracles,
            StopCondition::TargetAccuracy(_) => StopReason::TargetAccuracy,
            StopCondition::WallClockSecs(_) => StopReason::WallClock,
            StopCondition::SimTimeSecs(_) => StopReason::SimTime,
        }
    }

    /// Whether the condition holds at `round` given the run's live
    /// counters.  The caller (the runner) must have synced the ledger
    /// mirror first.
    pub fn triggered(&self, round: usize, m: &RunMetrics) -> bool {
        match *self {
            StopCondition::Rounds(n) => round >= n,
            StopCondition::CommBudgetMb(mb) => m.ledger.total_mb() >= mb,
            StopCondition::FirstOrderOracles(n) => m.oracles.first_order >= n,
            StopCondition::TargetAccuracy(a) => {
                m.trace.last().is_some_and(|p| p.accuracy >= a)
            }
            StopCondition::WallClockSecs(s) => m.wall_time_s() >= s,
            StopCondition::SimTimeSecs(s) => m.ledger.network_time_s >= s,
        }
    }
}

/// A single evaluation record along a run.
#[derive(Clone, Debug)]
pub struct TracePoint {
    pub round: usize,
    pub comm_mb: f64,
    pub sim_time_s: f64,
    pub wall_time_s: f64,
    pub loss: f64,
    pub accuracy: f64,
    pub grad_norm: f64,
    pub consensus_err: f64,
    /// Cumulative messages lost by this point (event engine).
    pub dropped_msgs: u64,
}

/// Full metrics for one experiment run.  `Clone` exists for the daemon's
/// completed-cell cache: every deterministic field round-trips exactly
/// (the `started` instant is wall-clock and excluded from all reports).
#[derive(Clone)]
pub struct RunMetrics {
    pub algo: String,
    pub label: String,
    pub ledger: CommLedger,
    pub oracles: OracleCounter,
    pub trace: Vec<TracePoint>,
    pub time_model: TimeModel,
    /// Why the run ended (set by the runner; `None` on a run that was
    /// never driven to a stop).
    pub stop_reason: Option<StopReason>,
    started: Instant,
}

impl RunMetrics {
    // Wall-clock stop budget: documented nondeterministic, rejected on
    // sweep axes (lint.toml R1 allow3).
    #[allow(clippy::disallowed_methods)]
    pub fn new(algo: &str, label: &str) -> RunMetrics {
        RunMetrics {
            algo: algo.into(),
            label: label.into(),
            ledger: CommLedger::default(),
            oracles: OracleCounter::default(),
            trace: Vec::new(),
            time_model: TimeModel::default(),
            stop_reason: None,
            started: Instant::now(),
        }
    }

    pub fn wall_time_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    #[allow(clippy::too_many_arguments)]
    pub fn record_eval(
        &mut self,
        round: usize,
        loss: f64,
        accuracy: f64,
        grad_norm: f64,
        consensus_err: f64,
    ) {
        self.trace.push(TracePoint {
            round,
            comm_mb: self.ledger.total_mb(),
            sim_time_s: self.ledger.network_time_s,
            wall_time_s: self.wall_time_s(),
            loss,
            accuracy,
            grad_norm,
            consensus_err,
            dropped_msgs: self.ledger.dropped_messages,
        });
    }

    /// First trace point reaching `acc` test accuracy, if any.
    pub fn time_to_accuracy(&self, acc: f64) -> Option<&TracePoint> {
        self.trace.iter().find(|p| p.accuracy >= acc)
    }

    /// First trace point with loss at or below `loss`, if any.
    pub fn comm_to_loss(&self, loss: f64) -> Option<&TracePoint> {
        self.trace.iter().find(|p| p.loss <= loss)
    }

    pub fn final_point(&self) -> Option<&TracePoint> {
        self.trace.last()
    }

    pub fn to_csv(&self) -> String {
        // New columns append at the END: tools/fill_experiments.py indexes
        // the earlier columns positionally.  `stop_reason` is a run-level
        // fact repeated per row so sliced/filtered traces keep it.
        let mut out = String::from(
            "round,comm_mb,sim_time_s,wall_time_s,loss,accuracy,grad_norm,consensus_err,dropped,stop_reason\n",
        );
        let stop = self.stop_reason.map_or("", |r| r.name());
        for p in &self.trace {
            let _ = writeln!(
                out,
                "{},{:.6},{:.6},{:.3},{:.6},{:.4},{:.6e},{:.6e},{},{}",
                p.round, p.comm_mb, p.sim_time_s, p.wall_time_s, p.loss, p.accuracy,
                p.grad_norm, p.consensus_err, p.dropped_msgs, stop
            );
        }
        out
    }

    pub fn summary_json(&self) -> Json {
        let last = self.trace.last();
        Json::obj(vec![
            ("algo", Json::str(&self.algo)),
            ("label", Json::str(&self.label)),
            ("comm_mb", Json::num(self.ledger.total_mb())),
            ("gossip_rounds", Json::num(self.ledger.gossip_rounds as f64)),
            ("messages", Json::num(self.ledger.messages as f64)),
            ("dropped_messages", Json::num(self.ledger.dropped_messages as f64)),
            ("network_time_s", Json::num(self.ledger.network_time_s)),
            ("wall_time_s", Json::num(self.wall_time_s())),
            ("first_order_calls", Json::num(self.oracles.first_order as f64)),
            ("second_order_calls", Json::num(self.oracles.second_order as f64)),
            ("final_loss", Json::num(last.map(|p| p.loss).unwrap_or(f64::NAN))),
            ("final_accuracy", Json::num(last.map(|p| p.accuracy).unwrap_or(f64::NAN))),
            ("stop_reason", Json::str(self.stop_reason.map_or("none", |r| r.name()))),
        ])
    }

    /// Prometheus text-exposition snapshot of this run — the metrics
    /// surface the future `c2dfb serve` daemon will scrape.  Counters
    /// carry a `_total` suffix; every sample is labeled
    /// `{algo, label}`.  Wall-clock time is intentionally absent: the
    /// exposition covers the same deterministic counters as the trace
    /// sink, so scraping a finished run is reproducible.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let lbl = format!(
            "{{algo={:?},label={:?}}}",
            self.algo,
            self.label.replace(['\n', '"'], "_")
        );
        // One HELP/TYPE header per metric name, then its samples — strict
        // exposition-format parsers reject repeated TYPE lines.
        fn family(
            out: &mut String,
            lbl: &str,
            name: &str,
            help: &str,
            kind: &str,
            samples: &[(Option<&str>, f64)],
        ) {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} {kind}");
            for (extra, v) in samples {
                let l = match extra {
                    Some(e) => format!("{},{e}}}", lbl.trim_end_matches('}')),
                    None => lbl.to_string(),
                };
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    let _ = writeln!(out, "{name}{l} {}", *v as i64);
                } else {
                    let _ = writeln!(out, "{name}{l} {v}");
                }
            }
        }
        let one = |v: f64| vec![(None, v)];
        family(
            &mut out,
            &lbl,
            "c2dfb_comm_bytes_total",
            "Application bytes sent by all nodes.",
            "counter",
            &one(self.ledger.total_bytes as f64),
        );
        family(
            &mut out,
            &lbl,
            "c2dfb_messages_total",
            "Messages sent.",
            "counter",
            &one(self.ledger.messages as f64),
        );
        family(
            &mut out,
            &lbl,
            "c2dfb_dropped_messages_total",
            "Messages lost in transit (event engine).",
            "counter",
            &one(self.ledger.dropped_messages as f64),
        );
        family(
            &mut out,
            &lbl,
            "c2dfb_gossip_rounds_total",
            "Paid gossip exchanges.",
            "counter",
            &one(self.ledger.gossip_rounds as f64),
        );
        family(
            &mut out,
            &lbl,
            "c2dfb_oracle_calls_total",
            "Oracle calls by differentiation order.",
            "counter",
            &[
                (Some("order=\"first\""), self.oracles.first_order as f64),
                (Some("order=\"second\""), self.oracles.second_order as f64),
            ],
        );
        family(
            &mut out,
            &lbl,
            "c2dfb_evals_total",
            "Consensus evaluations.",
            "counter",
            &one(self.oracles.evals as f64),
        );
        family(
            &mut out,
            &lbl,
            "c2dfb_sim_time_seconds",
            "Virtual network time.",
            "gauge",
            &one(self.ledger.network_time_s),
        );
        family(
            &mut out,
            &lbl,
            "c2dfb_rounds",
            "Last evaluated outer round.",
            "gauge",
            &one(self.trace.last().map_or(0.0, |p| p.round as f64)),
        );
        if let Some(p) = self.trace.last() {
            family(
                &mut out,
                &lbl,
                "c2dfb_loss",
                "Consensus loss at the last evaluation.",
                "gauge",
                &one(p.loss),
            );
            family(
                &mut out,
                &lbl,
                "c2dfb_grad_norm",
                "Hypergradient norm at the last evaluation.",
                "gauge",
                &one(p.grad_norm),
            );
            family(
                &mut out,
                &lbl,
                "c2dfb_consensus_err",
                "Consensus error at the last evaluation.",
                "gauge",
                &one(p.consensus_err),
            );
            family(
                &mut out,
                &lbl,
                "c2dfb_accuracy",
                "Consensus accuracy at the last evaluation.",
                "gauge",
                &one(p.accuracy),
            );
        }
        let reason = format!("reason={:?}", self.stop_reason.map_or("none", |r| r.name()));
        family(
            &mut out,
            &lbl,
            "c2dfb_stop_reason",
            "1 for the reason the run stopped.",
            "gauge",
            &[(Some(reason.as_str()), 1.0)],
        );
        out
    }

    /// Write trace CSV + summary JSON under `dir` (created if needed).
    pub fn write_to(&self, dir: &std::path::Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        let stem = format!("{}_{}", self.algo, self.label.replace([' ', '/'], "_"));
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())?;
        std::fs::write(
            dir.join(format!("{stem}.json")),
            self.summary_json().to_string(),
        )?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accumulates() {
        let mut l = CommLedger::default();
        let tm = TimeModel::default();
        l.record_round(&[100, 200], &[2, 3], &tm);
        assert_eq!(l.total_bytes, 100 * 2 + 200 * 3);
        assert_eq!(l.messages, 5);
        assert_eq!(l.gossip_rounds, 1);
        assert!(l.network_time_s > tm.latency_s);
    }

    #[test]
    fn masked_ledger_charges_active_senders_only() {
        let tm = TimeModel::default();
        let mut all = CommLedger::default();
        all.record_round_active(&[100, 200, 300], &[2, 3, 1], None, &tm);
        let mut full = CommLedger::default();
        full.record_round(&[100, 200, 300], &[2, 3, 1], &tm);
        // None mask is bit-identical to the unmasked call.
        assert_eq!(all.total_bytes, full.total_bytes);
        assert_eq!(all.messages, full.messages);
        assert_eq!(all.network_time_s.to_bits(), full.network_time_s.to_bits());

        let mut masked = CommLedger::default();
        masked.record_round_active(&[100, 200, 300], &[2, 3, 1], Some(&[true, false, true]), &tm);
        assert_eq!(masked.total_bytes, 100 * 2 + 300);
        assert_eq!(masked.messages, 3);
        assert_eq!(masked.gossip_rounds, 1);
        // Node 1 (the busiest) was inactive, so it doesn't bound the
        // round time.
        assert!(masked.network_time_s < full.network_time_s);
    }

    #[test]
    fn time_model_round_time() {
        let tm = TimeModel { latency_s: 0.001, bandwidth_bytes_per_s: 1000.0 };
        assert!((tm.round_time(2000) - 2.001).abs() < 1e-9);
    }

    #[test]
    fn trace_and_thresholds() {
        let mut m = RunMetrics::new("c2dfb", "test");
        m.record_eval(0, 2.0, 0.3, 1.0, 0.1);
        m.ledger.total_bytes = 5_000_000;
        m.record_eval(10, 1.0, 0.75, 0.5, 0.05);
        let p = m.time_to_accuracy(0.7).unwrap();
        assert_eq!(p.round, 10);
        assert!((p.comm_mb - 5.0).abs() < 1e-9);
        assert!(m.time_to_accuracy(0.9).is_none());
        assert_eq!(m.comm_to_loss(1.5).unwrap().round, 10);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut m = RunMetrics::new("a", "b");
        m.record_eval(0, 1.0, 0.5, 0.0, 0.0);
        let csv = m.to_csv();
        assert!(csv.starts_with("round,"));
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn summary_json_parses() {
        let m = RunMetrics::new("c2dfb", "ring");
        let j = m.summary_json().to_string();
        let v = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(v.get("algo").unwrap().as_str(), Some("c2dfb"));
        assert_eq!(v.get("stop_reason").unwrap().as_str(), Some("none"));
    }

    #[test]
    fn stop_reason_lands_in_csv_and_json() {
        let mut m = RunMetrics::new("c2dfb", "b");
        m.record_eval(0, 1.0, 0.5, 0.0, 0.0);
        m.stop_reason = Some(StopReason::CommBudget);
        let csv = m.to_csv();
        assert!(csv.lines().next().unwrap().ends_with(",stop_reason"));
        assert!(csv.lines().nth(1).unwrap().ends_with(",comm_budget"));
        let j = m.summary_json().to_string();
        let v = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(v.get("stop_reason").unwrap().as_str(), Some("comm_budget"));
    }

    #[test]
    fn stop_conditions_trigger_on_live_counters() {
        let mut m = RunMetrics::new("a", "b");
        m.ledger.total_bytes = 3_000_000;
        m.ledger.network_time_s = 1.5;
        m.oracles.first_order = 100;
        m.record_eval(7, 1.0, 0.8, 0.1, 0.0);

        assert!(StopCondition::Rounds(7).triggered(7, &m));
        assert!(!StopCondition::Rounds(8).triggered(7, &m));
        assert!(StopCondition::CommBudgetMb(3.0).triggered(7, &m));
        assert!(!StopCondition::CommBudgetMb(3.1).triggered(7, &m));
        assert!(StopCondition::FirstOrderOracles(100).triggered(7, &m));
        assert!(!StopCondition::FirstOrderOracles(101).triggered(7, &m));
        assert!(StopCondition::TargetAccuracy(0.8).triggered(7, &m));
        assert!(!StopCondition::TargetAccuracy(0.81).triggered(7, &m));
        assert!(StopCondition::SimTimeSecs(1.5).triggered(7, &m));
        assert!(!StopCondition::SimTimeSecs(2.0).triggered(7, &m));
        // Wall clock: zero always fires, an hour never (in a test).
        assert!(StopCondition::WallClockSecs(0.0).triggered(7, &m));
        assert!(!StopCondition::WallClockSecs(3600.0).triggered(7, &m));
        // TargetAccuracy needs a trace point.
        let empty = RunMetrics::new("a", "b");
        assert!(!StopCondition::TargetAccuracy(0.0).triggered(0, &empty));
    }

    #[test]
    fn prometheus_exposition_shape() {
        let mut m = RunMetrics::new("c2dfb", "ring");
        m.ledger.total_bytes = 1234;
        m.ledger.messages = 10;
        m.oracles.first_order = 40;
        m.oracles.second_order = 2;
        m.record_eval(5, 0.25, 0.9, 0.125, 0.0);
        m.stop_reason = Some(StopReason::Rounds);
        let text = m.render_prometheus();
        assert!(text.contains("# TYPE c2dfb_comm_bytes_total counter"));
        assert!(text.contains("c2dfb_comm_bytes_total{algo=\"c2dfb\",label=\"ring\"} 1234"));
        assert!(text
            .contains("c2dfb_oracle_calls_total{algo=\"c2dfb\",label=\"ring\",order=\"first\"} 40"));
        assert!(text
            .contains("c2dfb_oracle_calls_total{algo=\"c2dfb\",label=\"ring\",order=\"second\"} 2"));
        assert!(text.contains("c2dfb_stop_reason{algo=\"c2dfb\",label=\"ring\",reason=\"rounds\"} 1"));
        assert!(text.contains("c2dfb_rounds{algo=\"c2dfb\",label=\"ring\"} 5"));
        assert!(text.contains("c2dfb_accuracy{algo=\"c2dfb\",label=\"ring\"} 0.9"));
        // One TYPE header per family, even multi-sample ones.
        assert_eq!(text.matches("# TYPE c2dfb_oracle_calls_total").count(), 1);
        // The exposition is deterministic: no wall-clock samples.
        assert!(!text.contains("wall"));
    }

    #[test]
    fn stop_reason_names_are_stable() {
        for (r, n) in [
            (StopReason::Rounds, "rounds"),
            (StopReason::CommBudget, "comm_budget"),
            (StopReason::FirstOrderOracles, "first_order_oracles"),
            (StopReason::TargetAccuracy, "target_accuracy"),
            (StopReason::WallClock, "wall_clock"),
            (StopReason::SimTime, "sim_time"),
            (StopReason::Observer, "observer_abort"),
        ] {
            assert_eq!(r.name(), n);
        }
        assert_eq!(StopCondition::CommBudgetMb(1.0).reason(), StopReason::CommBudget);
        assert_eq!(StopCondition::Rounds(1).reason(), StopReason::Rounds);
    }
}
