//! Run metrics: communication ledger, oracle counters, traces, CSV/JSON out.
//!
//! The paper's plots are test accuracy/loss against (a) cumulative
//! communication volume in MB, (b) wall-clock time, and (c) round index —
//! so the ledger records exact bytes per round (from the compressor's wire
//! model), a modeled network time (latency + bytes/bandwidth per gossip
//! round, the in-process simulator has no real network), and real compute
//! time.

use std::fmt::Write as _;
use std::time::Instant;

use crate::util::json::Json;

/// Simple network cost model used to convert bytes into simulated seconds.
/// Defaults approximate the paper's LAN testbed: 1 ms latency, 1 Gbit/s.
#[derive(Clone, Copy, Debug)]
pub struct TimeModel {
    pub latency_s: f64,
    pub bandwidth_bytes_per_s: f64,
}

impl Default for TimeModel {
    fn default() -> Self {
        TimeModel { latency_s: 1e-3, bandwidth_bytes_per_s: 125e6 }
    }
}

impl TimeModel {
    /// Time for one synchronous gossip round in which the busiest node
    /// sends `max_node_bytes` (nodes transmit to neighbours in parallel).
    pub fn round_time(&self, max_node_bytes: usize) -> f64 {
        self.latency_s + max_node_bytes as f64 / self.bandwidth_bytes_per_s
    }
}

/// Per-run communication ledger.
#[derive(Clone, Debug, Default)]
pub struct CommLedger {
    /// Total application bytes sent by all nodes (paid even for messages
    /// the transport later loses — they left the NIC).
    pub total_bytes: u64,
    /// Number of gossip exchanges (a "communication round" in the plots).
    pub gossip_rounds: u64,
    /// Total virtual network seconds: the synchronous engine accumulates a
    /// per-round cost model, the event engine reports its furthest node
    /// clock.
    pub network_time_s: f64,
    /// Messages sent.
    pub messages: u64,
    /// Messages lost in transit (event engine's drop injection; always 0
    /// on the synchronous engine).
    pub dropped_messages: u64,
}

impl CommLedger {
    /// Record one synchronous gossip exchange.  `per_node_bytes[i]` is the
    /// bytes node i transmitted to EACH neighbour; `fanout[i]` its degree.
    pub fn record_round(
        &mut self,
        per_node_bytes: &[usize],
        fanout: &[usize],
        tm: &TimeModel,
    ) {
        let mut max_node = 0usize;
        for (b, f) in per_node_bytes.iter().zip(fanout) {
            let node_total = b * f;
            self.total_bytes += node_total as u64;
            self.messages += *f as u64;
            max_node = max_node.max(node_total);
        }
        self.gossip_rounds += 1;
        self.network_time_s += tm.round_time(max_node);
    }

    pub fn total_mb(&self) -> f64 {
        self.total_bytes as f64 / 1e6
    }
}

/// Oracle-call counters — the paper's computation-efficiency metric.
#[derive(Clone, Debug, Default)]
pub struct OracleCounter {
    pub first_order: u64,
    pub second_order: u64, // HVP / JVP calls (baselines only)
    pub evals: u64,
}

/// A single evaluation record along a run.
#[derive(Clone, Debug)]
pub struct TracePoint {
    pub round: usize,
    pub comm_mb: f64,
    pub sim_time_s: f64,
    pub wall_time_s: f64,
    pub loss: f64,
    pub accuracy: f64,
    pub grad_norm: f64,
    pub consensus_err: f64,
    /// Cumulative messages lost by this point (event engine).
    pub dropped_msgs: u64,
}

/// Full metrics for one experiment run.
pub struct RunMetrics {
    pub algo: String,
    pub label: String,
    pub ledger: CommLedger,
    pub oracles: OracleCounter,
    pub trace: Vec<TracePoint>,
    pub time_model: TimeModel,
    started: Instant,
}

impl RunMetrics {
    pub fn new(algo: &str, label: &str) -> RunMetrics {
        RunMetrics {
            algo: algo.into(),
            label: label.into(),
            ledger: CommLedger::default(),
            oracles: OracleCounter::default(),
            trace: Vec::new(),
            time_model: TimeModel::default(),
            started: Instant::now(),
        }
    }

    pub fn wall_time_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    #[allow(clippy::too_many_arguments)]
    pub fn record_eval(
        &mut self,
        round: usize,
        loss: f64,
        accuracy: f64,
        grad_norm: f64,
        consensus_err: f64,
    ) {
        self.trace.push(TracePoint {
            round,
            comm_mb: self.ledger.total_mb(),
            sim_time_s: self.ledger.network_time_s,
            wall_time_s: self.wall_time_s(),
            loss,
            accuracy,
            grad_norm,
            consensus_err,
            dropped_msgs: self.ledger.dropped_messages,
        });
    }

    /// First trace point reaching `acc` test accuracy, if any.
    pub fn time_to_accuracy(&self, acc: f64) -> Option<&TracePoint> {
        self.trace.iter().find(|p| p.accuracy >= acc)
    }

    /// First trace point with loss at or below `loss`, if any.
    pub fn comm_to_loss(&self, loss: f64) -> Option<&TracePoint> {
        self.trace.iter().find(|p| p.loss <= loss)
    }

    pub fn final_point(&self) -> Option<&TracePoint> {
        self.trace.last()
    }

    pub fn to_csv(&self) -> String {
        // `dropped` stays LAST: tools/fill_experiments.py indexes columns
        // positionally.
        let mut out = String::from(
            "round,comm_mb,sim_time_s,wall_time_s,loss,accuracy,grad_norm,consensus_err,dropped\n",
        );
        for p in &self.trace {
            let _ = writeln!(
                out,
                "{},{:.6},{:.6},{:.3},{:.6},{:.4},{:.6e},{:.6e},{}",
                p.round, p.comm_mb, p.sim_time_s, p.wall_time_s, p.loss, p.accuracy,
                p.grad_norm, p.consensus_err, p.dropped_msgs
            );
        }
        out
    }

    pub fn summary_json(&self) -> Json {
        let last = self.trace.last();
        Json::obj(vec![
            ("algo", Json::str(&self.algo)),
            ("label", Json::str(&self.label)),
            ("comm_mb", Json::num(self.ledger.total_mb())),
            ("gossip_rounds", Json::num(self.ledger.gossip_rounds as f64)),
            ("messages", Json::num(self.ledger.messages as f64)),
            ("dropped_messages", Json::num(self.ledger.dropped_messages as f64)),
            ("network_time_s", Json::num(self.ledger.network_time_s)),
            ("wall_time_s", Json::num(self.wall_time_s())),
            ("first_order_calls", Json::num(self.oracles.first_order as f64)),
            ("second_order_calls", Json::num(self.oracles.second_order as f64)),
            ("final_loss", Json::num(last.map(|p| p.loss).unwrap_or(f64::NAN))),
            ("final_accuracy", Json::num(last.map(|p| p.accuracy).unwrap_or(f64::NAN))),
        ])
    }

    /// Write trace CSV + summary JSON under `dir` (created if needed).
    pub fn write_to(&self, dir: &std::path::Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        let stem = format!("{}_{}", self.algo, self.label.replace([' ', '/'], "_"));
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())?;
        std::fs::write(
            dir.join(format!("{stem}.json")),
            self.summary_json().to_string(),
        )?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accumulates() {
        let mut l = CommLedger::default();
        let tm = TimeModel::default();
        l.record_round(&[100, 200], &[2, 3], &tm);
        assert_eq!(l.total_bytes, 100 * 2 + 200 * 3);
        assert_eq!(l.messages, 5);
        assert_eq!(l.gossip_rounds, 1);
        assert!(l.network_time_s > tm.latency_s);
    }

    #[test]
    fn time_model_round_time() {
        let tm = TimeModel { latency_s: 0.001, bandwidth_bytes_per_s: 1000.0 };
        assert!((tm.round_time(2000) - 2.001).abs() < 1e-9);
    }

    #[test]
    fn trace_and_thresholds() {
        let mut m = RunMetrics::new("c2dfb", "test");
        m.record_eval(0, 2.0, 0.3, 1.0, 0.1);
        m.ledger.total_bytes = 5_000_000;
        m.record_eval(10, 1.0, 0.75, 0.5, 0.05);
        let p = m.time_to_accuracy(0.7).unwrap();
        assert_eq!(p.round, 10);
        assert!((p.comm_mb - 5.0).abs() < 1e-9);
        assert!(m.time_to_accuracy(0.9).is_none());
        assert_eq!(m.comm_to_loss(1.5).unwrap().round, 10);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut m = RunMetrics::new("a", "b");
        m.record_eval(0, 1.0, 0.5, 0.0, 0.0);
        let csv = m.to_csv();
        assert!(csv.starts_with("round,"));
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn summary_json_parses() {
        let m = RunMetrics::new("c2dfb", "ring");
        let j = m.summary_json().to_string();
        let v = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(v.get("algo").unwrap().as_str(), Some("c2dfb"));
    }
}
