//! Scoped thread-pool execution of per-node compute.
//!
//! The algorithms are bulk-synchronous: between gossip exchanges every
//! node evaluates local oracles (gradients, hypergradients, HVPs) that
//! depend only on that node's state.  [`NodePool::map`] fans those
//! evaluations out over a scoped thread pool with channel-based result
//! passing and returns results **in node order**, so the reduction that
//! follows sees exactly the serial order — runs are bit-reproducible
//! regardless of thread count (asserted by `tests/sim.rs`).
//!
//! Randomized per-node work should use [`NodePool::map_rng`], which derives
//! an independent, seed-stable RNG stream per node (splitmix-seeded, as in
//! [`Rng::split`]) instead of sharing one generator — again making the
//! draw sequence a function of (seed, node), never of scheduling.

use crate::util::rng::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// A fixed-width scoped thread pool for per-node work.  `threads == 1`
/// (the default everywhere) short-circuits to a plain serial loop.
#[derive(Clone, Copy, Debug)]
pub struct NodePool {
    threads: usize,
}

impl NodePool {
    /// `threads = 0` and `1` both mean serial.
    pub fn new(threads: usize) -> NodePool {
        NodePool { threads: threads.max(1) }
    }

    pub fn serial() -> NodePool {
        NodePool { threads: 1 }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Evaluate `f(0), …, f(n−1)` — concurrently when the pool has more
    /// than one thread — and return the results indexed by node.
    pub fn map<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        if self.threads == 1 || n <= 1 {
            return (0..n).map(f).collect();
        }
        let workers = self.threads.min(n);
        let next = AtomicUsize::new(0);
        let (tx, rx) = mpsc::channel::<(usize, R)>();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let tx = tx.clone();
                let next = &next;
                let f = &f;
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    if tx.send((i, f(i))).is_err() {
                        break;
                    }
                });
            }
        });
        drop(tx); // all worker clones are gone; close our end too
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter()
            .map(|r| r.expect("NodePool worker dropped a node result"))
            .collect()
    }

    /// Like [`map`](NodePool::map), but hands each node an independent RNG
    /// stream derived from `(base_seed, node)` — identical draws whether
    /// the pool runs 1 thread or 16.
    pub fn map_rng<R, F>(&self, n: usize, base_seed: u64, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, &mut Rng) -> R + Sync,
    {
        self.map(n, |i| {
            let mut rng = node_stream(base_seed, i);
            f(i, &mut rng)
        })
    }
}

/// The per-node RNG stream for `(base_seed, node)`.
pub fn node_stream(base_seed: u64, node: usize) -> Rng {
    Rng::new(base_seed ^ (node as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_returns_results_in_node_order() {
        for threads in [1, 2, 4, 7] {
            let pool = NodePool::new(threads);
            let out = pool.map(13, |i| i * i);
            assert_eq!(out, (0..13).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn map_identical_across_thread_counts() {
        let serial = NodePool::serial().map(32, |i| (i as f64).sqrt().to_bits());
        for threads in [2, 3, 8] {
            let par = NodePool::new(threads).map(32, |i| (i as f64).sqrt().to_bits());
            assert_eq!(serial, par);
        }
    }

    #[test]
    fn map_rng_streams_stable_and_independent() {
        let a = NodePool::new(4).map_rng(8, 42, |_, rng| rng.next_u64());
        let b = NodePool::serial().map_rng(8, 42, |_, rng| rng.next_u64());
        assert_eq!(a, b, "per-node streams must not depend on thread count");
        // Streams differ across nodes and seeds.
        assert_ne!(a[0], a[1]);
        let c = NodePool::serial().map_rng(8, 43, |_, rng| rng.next_u64());
        assert_ne!(a, c);
    }

    #[test]
    fn zero_threads_means_serial() {
        assert_eq!(NodePool::new(0).threads(), 1);
    }

    #[test]
    fn empty_and_single_inputs() {
        let pool = NodePool::new(4);
        assert_eq!(pool.map(0, |i| i), Vec::<usize>::new());
        assert_eq!(pool.map(1, |i| i + 1), vec![1]);
    }
}
