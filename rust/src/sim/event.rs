//! Deterministic discrete-event queue keyed by virtual time.
//!
//! A thin min-heap with two guarantees the engine leans on:
//!
//! * **Total order on `f64` times** via `total_cmp` (no NaN surprises —
//!   NaN times are rejected at push).
//! * **Deterministic tie-breaking**: events at equal times pop in
//!   insertion order (a monotone sequence number), so a run is a pure
//!   function of its inputs regardless of heap internals.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

struct Entry<T> {
    time_s: f64,
    seq: u64,
    item: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl<T> Eq for Entry<T> {}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time_s
            .total_cmp(&other.time_s)
            .then(self.seq.cmp(&other.seq))
    }
}

/// Min-heap of `(virtual time, payload)` events.
pub struct EventQueue<T> {
    heap: BinaryHeap<Reverse<Entry<T>>>,
    seq: u64,
}

impl<T> EventQueue<T> {
    pub fn new() -> EventQueue<T> {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    /// Schedule `item` at `time_s` (virtual seconds, must be finite).
    pub fn push(&mut self, time_s: f64, item: T) {
        assert!(time_s.is_finite(), "event time must be finite, got {time_s}");
        self.heap.push(Reverse(Entry { time_s, seq: self.seq, item }));
        self.seq += 1;
    }

    /// Pop the earliest event (FIFO among equal times).
    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|Reverse(e)| (e.time_s, e.item))
    }

    /// Virtual time of the next event without popping it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|Reverse(e)| e.time_s)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        EventQueue::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, x)| x)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push(1.0, i);
        }
        q.push(0.5, 999);
        assert_eq!(q.pop(), Some((0.5, 999)));
        for i in 0..100 {
            assert_eq!(q.pop(), Some((1.0, i)));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn peek_does_not_pop() {
        let mut q = EventQueue::new();
        q.push(2.5, ());
        assert_eq!(q.peek_time(), Some(2.5));
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop(), Some((2.5, ())));
        assert_eq!(q.peek_time(), None);
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan_times() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }
}
