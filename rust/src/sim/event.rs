//! Deterministic discrete-event queues keyed by virtual time.
//!
//! Two implementations with **identical observable ordering**:
//!
//! * [`HeapEventQueue`] — the reference binary heap, O(log n) per op.
//! * [`EventQueue`] — a calendar (bucketed) queue, amortized O(1) per op
//!   at 10⁶+ in-flight events; the engine's default since the scale
//!   work.
//!
//! Both provide the two guarantees the engine leans on:
//!
//! * **Total order on `f64` times** via `total_cmp` (no NaN surprises —
//!   NaN times are rejected at push).
//! * **Deterministic tie-breaking**: events at equal times pop in
//!   insertion order (a monotone sequence number), so a run is a pure
//!   function of its inputs regardless of queue internals.
//!
//! ## The same-timestamp tie contract (pinned — do not weaken)
//!
//! `SimNetwork::simulate_core` pushes one event per message copy,
//! iterating senders in ascending node id and, per sender, neighbors in
//! ascending id.  Combined with insertion-order tie-breaking this means
//! **messages that arrive at the same virtual instant pop in (sender id,
//! push sequence) order** — exactly the ascending-sender inbox order the
//! synchronous engine uses, which is why a benign sim config reproduces
//! the synchronous trajectories bit-for-bit (float reductions fold in
//! the same order).  The goldens encode this order; a queue that
//! reorders equal-time events is a correctness bug, not a scheduling
//! choice.  `tie_contract_*` tests below and `tests/proptests.rs`
//! (random streams, heap vs calendar) pin it.

use std::cmp::{Ordering, Reverse};
use std::collections::{BinaryHeap, VecDeque};

struct Entry<T> {
    time_s: f64,
    seq: u64,
    item: T,
}

impl<T> Entry<T> {
    #[inline]
    fn key_cmp(&self, other: &Self) -> Ordering {
        self.time_s
            .total_cmp(&other.time_s)
            .then(self.seq.cmp(&other.seq))
    }
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl<T> Eq for Entry<T> {}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        self.key_cmp(other)
    }
}

/// Reference min-heap of `(virtual time, payload)` events.  Kept (and
/// kept public) as the ordering oracle for the calendar queue: the
/// property suite replays random streams through both and requires
/// identical pop sequences, including same-timestamp ties.
pub struct HeapEventQueue<T> {
    heap: BinaryHeap<Reverse<Entry<T>>>,
    seq: u64,
}

impl<T> HeapEventQueue<T> {
    pub fn new() -> HeapEventQueue<T> {
        HeapEventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    /// Schedule `item` at `time_s` (virtual seconds, must be finite).
    pub fn push(&mut self, time_s: f64, item: T) {
        assert!(time_s.is_finite(), "event time must be finite, got {time_s}");
        self.heap.push(Reverse(Entry { time_s, seq: self.seq, item }));
        self.seq += 1;
    }

    /// Pop the earliest event (FIFO among equal times).
    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|Reverse(e)| (e.time_s, e.item))
    }

    /// Virtual time of the next event without popping it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|Reverse(e)| e.time_s)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl<T> Default for HeapEventQueue<T> {
    fn default() -> Self {
        HeapEventQueue::new()
    }
}

/// Calendar queue (Brown 1988): events hash into `width`-second day
/// buckets on a circular year; dequeue walks days in order.  Amortized
/// O(1) push/pop when event times spread across buckets, and never worse
/// than O(n) in degenerate distributions (every event in one bucket pops
/// front-of-deque in O(1); the pathological case is *inserting* before
/// many earlier-pushed later-time events in one bucket).
///
/// Each bucket is kept sorted ascending by `(time total_cmp, seq)`, so
/// the pop order — including the same-timestamp tie contract above — is
/// exactly [`HeapEventQueue`]'s.  The bulk-arrival pattern the engine
/// produces (a gossip round schedules many copies at identical or
/// near-identical times, in seq order) inserts at the bucket tail in
/// O(1).
///
/// Bucket count and width adapt on resize: the count tracks the live
/// event count (×2 / ÷2 thresholds), the width spans the observed time
/// range so one "year" covers the queue and an average day holds O(1)
/// events.  A width floor of `max_abs_time / 1e15` keeps every
/// `time / width` day index well inside `i64` (and its rounding error
/// below half a day, so an event lands at most one day off its true
/// position — `scan_min` checks the neighboring day to compensate).
pub struct EventQueue<T> {
    buckets: Vec<VecDeque<Entry<T>>>,
    /// Day length in virtual seconds.
    width: f64,
    /// Virtual day index (`floor(time / width)`) below which all days
    /// have been drained; `i64::MIN` sentinel when unknown (empty).
    cur_day: i64,
    len: usize,
    seq: u64,
}

const MIN_BUCKETS: usize = 4;

impl<T> EventQueue<T> {
    pub fn new() -> EventQueue<T> {
        EventQueue {
            buckets: (0..MIN_BUCKETS).map(|_| VecDeque::new()).collect(),
            width: 1.0,
            cur_day: i64::MIN,
            len: 0,
            seq: 0,
        }
    }

    /// Virtual day index of `time_s` under the current width.  Rounding
    /// in the division can misplace an event by at most one day (see the
    /// type docs); `scan_min` compensates.
    #[inline]
    fn day_of(&self, time_s: f64) -> i64 {
        (time_s / self.width).floor() as i64
    }

    #[inline]
    fn bucket_of_day(&self, day: i64) -> usize {
        day.rem_euclid(self.buckets.len() as i64) as usize
    }

    /// Schedule `item` at `time_s` (virtual seconds, must be finite).
    pub fn push(&mut self, time_s: f64, item: T) {
        assert!(time_s.is_finite(), "event time must be finite, got {time_s}");
        let entry = Entry { time_s, seq: self.seq, item };
        self.seq += 1;
        let day = self.day_of(time_s);
        if day < self.cur_day || self.len == 0 {
            self.cur_day = day;
        }
        let bucket = self.bucket_of_day(day);
        Self::insert_sorted(&mut self.buckets[bucket], entry);
        self.len += 1;
        if self.len > 2 * self.buckets.len() {
            self.resize();
        }
    }

    /// Insert preserving ascending `(time, seq)` order.  New entries
    /// carry the largest seq so far, so among equal times the insertion
    /// point is always the tail of the equal-time run — `partition_point`
    /// with a `!= Greater` predicate lands exactly there.
    fn insert_sorted(bucket: &mut VecDeque<Entry<T>>, entry: Entry<T>) {
        let pos = bucket.partition_point(|e| e.key_cmp(&entry) != Ordering::Greater);
        if pos == bucket.len() {
            bucket.push_back(entry);
        } else {
            bucket.insert(pos, entry);
        }
    }

    /// Bucket index holding the global minimum entry, or None if empty.
    ///
    /// Walks days from `cur_day`; the first day whose bucket front lives
    /// in that day is the candidate.  Because an event's computed day can
    /// be off by one from its time (float division), the next day's
    /// front is compared too and the smaller key wins.  If a whole year
    /// passes with no match (sparse far-future events), falls back to a
    /// direct scan of all bucket fronts.
    fn scan_min(&self) -> Option<usize> {
        if self.len == 0 {
            return None;
        }
        let n = self.buckets.len();
        let mut day = self.cur_day;
        for _ in 0..n {
            let b = self.bucket_of_day(day);
            if let Some(front) = self.buckets[b].front() {
                if self.day_of(front.time_s) == day {
                    // Candidate found; the true min may sit one day over.
                    let nb = self.bucket_of_day(day + 1);
                    if nb != b {
                        if let Some(next) = self.buckets[nb].front() {
                            if next.key_cmp(front) == Ordering::Less {
                                return Some(nb);
                            }
                        }
                    }
                    return Some(b);
                }
            }
            day += 1;
        }
        // Direct search: compare every bucket front.
        let mut best: Option<usize> = None;
        for (b, q) in self.buckets.iter().enumerate() {
            if let Some(front) = q.front() {
                match best {
                    None => best = Some(b),
                    Some(bb) => {
                        if front.key_cmp(self.buckets[bb].front().unwrap()) == Ordering::Less {
                            best = Some(b);
                        }
                    }
                }
            }
        }
        best
    }

    /// Pop the earliest event (FIFO among equal times).
    pub fn pop(&mut self) -> Option<(f64, T)> {
        let b = self.scan_min()?;
        let entry = self.buckets[b].pop_front().unwrap();
        self.len -= 1;
        if self.len == 0 {
            self.cur_day = i64::MIN;
        } else {
            self.cur_day = self.day_of(entry.time_s);
        }
        if self.len >= MIN_BUCKETS && self.len < self.buckets.len() / 2 {
            self.resize();
        }
        Some((entry.time_s, entry.item))
    }

    /// Virtual time of the next event without popping it.
    pub fn peek_time(&self) -> Option<f64> {
        self.scan_min()
            .map(|b| self.buckets[b].front().unwrap().time_s)
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Rebuild with a bucket count tracking `len` and a width spanning
    /// the live time range.  O(n log n) for the global sort, amortized
    /// against the pushes/pops that moved `len` past a threshold.
    fn resize(&mut self) {
        let mut entries: Vec<Entry<T>> = Vec::with_capacity(self.len);
        for q in self.buckets.iter_mut() {
            entries.extend(q.drain(..));
        }
        entries.sort_unstable_by(|a, b| a.key_cmp(b));
        let n = self.len.next_power_of_two().max(MIN_BUCKETS);
        self.buckets = (0..n).map(|_| VecDeque::new()).collect();
        let (mut lo, mut hi, mut max_abs) = (f64::INFINITY, f64::NEG_INFINITY, 0.0f64);
        for e in &entries {
            lo = lo.min(e.time_s);
            hi = hi.max(e.time_s);
            max_abs = max_abs.max(e.time_s.abs());
        }
        let span = if entries.is_empty() { 0.0 } else { hi - lo };
        // ~4 days per span so a year (n days) comfortably covers it;
        // floors keep day indices finite and within i64 (see type docs).
        let mut w = span * 4.0 / entries.len().max(1) as f64;
        w = w.max(max_abs / 1e15).max(f64::MIN_POSITIVE);
        if !w.is_finite() || w == 0.0 {
            w = 1.0;
        }
        self.width = w;
        self.cur_day = i64::MIN;
        for e in entries {
            let day = self.day_of(e.time_s);
            if self.cur_day == i64::MIN || day < self.cur_day {
                self.cur_day = day;
            }
            let b = self.bucket_of_day(day);
            // Entries arrive globally sorted, so per-bucket order is
            // already ascending — append.
            self.buckets[b].push_back(e);
        }
        if self.len == 0 {
            self.cur_day = i64::MIN;
        }
    }
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        EventQueue::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, x)| x)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push(1.0, i);
        }
        q.push(0.5, 999);
        assert_eq!(q.pop(), Some((0.5, 999)));
        for i in 0..100 {
            assert_eq!(q.pop(), Some((1.0, i)));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn heap_ties_break_by_insertion_order() {
        let mut q = HeapEventQueue::new();
        for i in 0..100 {
            q.push(1.0, i);
        }
        q.push(0.5, 999);
        assert_eq!(q.pop(), Some((0.5, 999)));
        for i in 0..100 {
            assert_eq!(q.pop(), Some((1.0, i)));
        }
        assert!(q.is_empty());
    }

    /// The pinned contract: copies pushed in ascending (sender, neighbor)
    /// order with equal arrival times pop in exactly that push order —
    /// on BOTH queue implementations.  `SimNetwork`'s inbox assembly
    /// (and so the benign-sim ≡ sync bit-identity) depends on this.
    #[test]
    fn tie_contract_sender_then_sequence_order() {
        let t = 1.0 + 1e-3; // one latency hop, like a benign round
        let mut heap = HeapEventQueue::new();
        let mut cal = EventQueue::new();
        let mut pushed = Vec::new();
        for sender in 0..8u32 {
            for neighbor in [1u32, 3, 5] {
                heap.push(t, (sender, neighbor));
                cal.push(t, (sender, neighbor));
                pushed.push((sender, neighbor));
            }
        }
        let hv: Vec<_> = std::iter::from_fn(|| heap.pop().map(|(_, x)| x)).collect();
        let cv: Vec<_> = std::iter::from_fn(|| cal.pop().map(|(_, x)| x)).collect();
        assert_eq!(hv, pushed, "heap must preserve (sender, seq) push order on ties");
        assert_eq!(cv, pushed, "calendar must preserve (sender, seq) push order on ties");
    }

    #[test]
    fn calendar_matches_heap_on_random_interleaved_streams() {
        let mut rng = Rng::new(0xCA1E);
        for case in 0..20 {
            let mut heap = HeapEventQueue::new();
            let mut cal = EventQueue::new();
            let n = 50 + case * 37;
            let mut id = 0u64;
            for _ in 0..n {
                // Mix pushes and interleaved pops, heavy on ties.
                let t = (rng.below(16) as f64) * 0.25;
                heap.push(t, id);
                cal.push(t, id);
                id += 1;
                if rng.bernoulli(0.3) {
                    assert_eq!(heap.pop(), cal.pop());
                }
            }
            while !heap.is_empty() {
                assert_eq!(heap.peek_time(), cal.peek_time());
                assert_eq!(heap.pop(), cal.pop());
            }
            assert!(cal.is_empty());
        }
    }

    #[test]
    fn peek_does_not_pop() {
        let mut q = EventQueue::new();
        q.push(2.5, ());
        assert_eq!(q.peek_time(), Some(2.5));
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop(), Some((2.5, ())));
        assert_eq!(q.peek_time(), None);
    }

    #[test]
    fn survives_resize_cycles_and_wide_time_ranges() {
        let mut heap = HeapEventQueue::new();
        let mut cal = EventQueue::new();
        let mut rng = Rng::new(7);
        for i in 0..4096u64 {
            let t = match i % 4 {
                0 => rng.uniform() * 1e-6,
                1 => rng.uniform() * 1e6,
                2 => 42.0, // massive tie pile-up in one day
                _ => rng.uniform(),
            };
            heap.push(t, i);
            cal.push(t, i);
        }
        // Drain half, refill, drain all — exercises shrink and grow.
        for _ in 0..2048 {
            assert_eq!(heap.pop(), cal.pop());
        }
        for i in 0..512u64 {
            let t = rng.uniform() * 100.0;
            heap.push(t, 10_000 + i);
            cal.push(t, 10_000 + i);
        }
        while !heap.is_empty() {
            assert_eq!(heap.pop(), cal.pop());
        }
        assert!(cal.is_empty());
    }

    #[test]
    fn push_earlier_than_current_scan_position() {
        let mut q = EventQueue::new();
        q.push(10.0, "late");
        q.push(20.0, "later");
        assert_eq!(q.pop(), Some((10.0, "late")));
        // Now schedule before the drained region — must still pop first.
        q.push(1.0, "early");
        assert_eq!(q.pop(), Some((1.0, "early")));
        assert_eq!(q.pop(), Some((20.0, "later")));
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan_times() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn heap_rejects_nan_times() {
        let mut q = HeapEventQueue::new();
        q.push(f64::NAN, ());
    }
}
