//! Event-driven gossip transport: [`SimNetwork`].
//!
//! One `exchange` = one bulk-synchronous gossip round, simulated message
//! by message:
//!
//! 1. Every sender starts transmitting at its own virtual clock (plus its
//!    straggler delay, if it is one), serializing its per-neighbour copies
//!    through one NIC at `bandwidth` bytes/s.
//! 2. Each copy arrives `latency + U[0, jitter)` after it leaves the NIC,
//!    or is lost with probability `drop_rate`.  Jitter and drops are drawn
//!    from per-sender RNG streams in neighbour order, so the realization
//!    depends only on `(seed, round, sender, edge)` — never on event
//!    interleaving or thread count.
//! 3. Arrivals drain through the [`EventQueue`](super::event::EventQueue)
//!    in virtual-time order; each receiver's clock advances to the latest
//!    of its own send completion and its delivered arrivals (a *local*
//!    barrier — a straggler delays its neighbours this round, their
//!    neighbours next round, one hop per round, like a real deployment).
//!
//! With zero jitter, zero drops and no stragglers every message is
//! delivered, inboxes match the synchronous [`Network`]'s exactly (both
//! are sorted by sender), and ledger bytes/rounds/messages are identical —
//! so algorithm trajectories are bit-for-bit the same (asserted by
//! `tests/sim.rs`).

use super::event::EventQueue;
use super::{NetConfig, NetMode};
use crate::collective::{clear_delivered, dense_wire_bytes, Inbox, Transport};
use crate::compress::Compressed;
use crate::linalg::scalar::Scalar;
use crate::metrics::CommLedger;
use crate::topology::{Graph, MixingMatrix, Topology};
use crate::util::rng::Rng;
use std::sync::Arc;

/// One scheduled message copy in flight (event-queue payload).
struct Flight {
    sender: usize,
    receiver: usize,
    dropped: bool,
}

/// One simulated message delivery (or loss), for tests and tracing.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Arrival {
    /// Virtual arrival time (s); for dropped messages, when it *would*
    /// have arrived.
    pub t_s: f64,
    pub sender: usize,
    pub receiver: usize,
    pub bytes: usize,
    pub dropped: bool,
}

/// Discrete-event transport with per-link latency/bandwidth/jitter, loss,
/// stragglers and a topology schedule.  Implements [`Transport`], so every
/// algorithm runs on it unmodified.
pub struct SimNetwork {
    pub graph: Graph,
    pub mixing: MixingMatrix,
    pub ledger: CommLedger,
    cfg: NetConfig,
    degrees: Vec<usize>,
    /// Per-node virtual clocks (s): when the node can next transmit.
    clock: Vec<f64>,
    /// Per-sender RNG streams for jitter and drops.
    streams: Vec<Rng>,
    /// Extra pre-send delay per node per round (stragglers; 0 otherwise).
    straggle: Vec<f64>,
    /// Gossip rounds completed (drives the topology schedule).
    round: u64,
    sched_next: usize,
    /// Bumped on every topology switch (see [`Transport::graph_epoch`]).
    epoch: u64,
    /// Arrival log of the most recent exchange, in event order.
    pub last_events: Vec<Arrival>,
    /// Reused event queue (its heap storage persists across rounds).
    queue: EventQueue<Flight>,
    /// Reused per-node scratch: send-completion times during an exchange,
    /// swapped into `clock` afterwards.
    done: Vec<f64>,
    /// Per-round sampling mask ([`Transport::set_active`]): inactive
    /// senders transmit nothing, pay nothing, and consume no jitter/drop
    /// draws that round (their streams stay aligned for the round they
    /// rejoin).
    active: Option<Arc<Vec<bool>>>,
}

impl SimNetwork {
    /// Build over an initial graph.  `seed` controls jitter/drop draws and
    /// the straggler choice; it is independent of the algorithms' seeds.
    ///
    /// Errors (instead of panicking) on an invalid `[network]` config or a
    /// config whose mode is `sync` — so a bad CLI flag surfaces as a clean
    /// `anyhow` error through [`crate::coordinator::build_sim_network`]
    /// and the [`Runner`](crate::coordinator::Runner), never as a panic.
    pub fn new(graph: Graph, cfg: NetConfig, seed: u64) -> Result<SimNetwork, String> {
        if cfg.mode != NetMode::Event {
            return Err(
                "SimNetwork built from a config with mode = sync; set network mode = \"sim\""
                    .into(),
            );
        }
        cfg.validate()?;
        let m = graph.m;
        let mixing = MixingMatrix::metropolis(&graph);
        let degrees = (0..m).map(|i| graph.degree(i)).collect();
        let mut root = Rng::new(seed ^ 0x5157_0C0D);
        let streams = (0..m).map(|i| root.split(i as u64)).collect();
        let mut straggle = vec![0.0; m];
        let k = (cfg.straggler_frac * m as f64).ceil() as usize;
        if k > 0 && cfg.straggler_delay_s > 0.0 {
            for i in root.sample_indices(m, k.min(m)) {
                straggle[i] = cfg.straggler_delay_s;
            }
        }
        let mut schedule = cfg.topology_schedule.clone();
        schedule.sort_by_key(|(r, _)| *r);
        let mut net = SimNetwork {
            mixing,
            ledger: CommLedger::default(),
            degrees,
            clock: vec![0.0; m],
            streams,
            straggle,
            round: 0,
            sched_next: 0,
            epoch: 0,
            last_events: Vec::new(),
            queue: EventQueue::new(),
            done: Vec::new(),
            active: None,
            cfg: NetConfig { topology_schedule: schedule, ..cfg },
            graph,
        };
        // A schedule entry at round 0 replaces the initial graph.
        net.advance_schedule();
        Ok(net)
    }

    /// The most recent exchange's final arrival, if the round produced any
    /// events at all.  A round can deliver nothing (every message dropped
    /// under heavy loss, or a topology tick left a node with an empty
    /// neighbour set), so consumers must not index `last_events` blindly —
    /// this is the guarded accessor for "what landed last".
    pub fn last_arrival(&self) -> Option<&Arrival> {
        self.last_events.last()
    }

    /// The most recent exchange's final *delivered* (non-dropped) arrival,
    /// if any message survived the round.
    pub fn last_delivery(&self) -> Option<&Arrival> {
        self.last_events.iter().rev().find(|a| !a.dropped)
    }

    /// Indices of the nodes chosen as stragglers.
    pub fn stragglers(&self) -> Vec<usize> {
        (0..self.m())
            .filter(|&i| self.straggle[i] > 0.0)
            .collect()
    }

    /// Per-node virtual clocks (s).
    pub fn clocks(&self) -> &[f64] {
        &self.clock
    }

    fn m(&self) -> usize {
        self.graph.m
    }

    fn advance_schedule(&mut self) {
        let sched = &self.cfg.topology_schedule;
        let mut switched = None;
        while self.sched_next < sched.len() && sched[self.sched_next].0 <= self.round {
            switched = Some(sched[self.sched_next].1);
            self.sched_next += 1;
        }
        if let Some(topo) = switched {
            let graph = Graph::build(topo, self.m());
            self.mixing = MixingMatrix::metropolis(&graph);
            self.degrees = (0..graph.m).map(|i| graph.degree(i)).collect();
            self.graph = graph;
            self.epoch += 1;
        }
    }

    /// The shared engine behind every exchange flavour: pay the bytes,
    /// schedule every copy, drain arrivals in virtual-time order, advance
    /// clocks, and fill `delivered[i]` with the ascending senders whose
    /// copies reached node i.  Payloads never enter the engine, and all
    /// working storage (event queue, clock scratch, sender lists) is
    /// reused — steady state allocates nothing.
    fn simulate_core(&mut self, bytes: &[usize], delivered: &mut Vec<Vec<usize>>) {
        let m = self.m();
        assert_eq!(bytes.len(), m);
        self.advance_schedule();
        let mask = self.active.clone();
        let is_active = |i: usize| mask.as_ref().map_or(true, |a| a[i]);

        // -- ledger: bytes leave the NIC whether or not they arrive -------
        for (i, (b, deg)) in bytes.iter().zip(&self.degrees).enumerate() {
            if !is_active(i) {
                continue;
            }
            self.ledger.total_bytes += (b * deg) as u64;
            self.ledger.messages += *deg as u64;
        }
        self.ledger.gossip_rounds += 1;

        // -- schedule all copies; draw jitter/drops deterministically -----
        debug_assert!(self.queue.is_empty());
        self.done.clear();
        self.done.resize(m, 0.0); // own-send completion per node
        for i in 0..m {
            if !is_active(i) {
                // Sampled out: no sends, no draws; the node's clock still
                // advances with whatever it receives below.
                self.done[i] = self.clock[i];
                continue;
            }
            let start = self.clock[i] + self.straggle[i];
            let tx = bytes[i] as f64 / self.cfg.bandwidth_bytes_per_s;
            let mut depart = start;
            for &nb in self.graph.neighbors(i) {
                depart += tx;
                let jitter = if self.cfg.jitter_s > 0.0 {
                    self.streams[i].uniform() * self.cfg.jitter_s
                } else {
                    0.0
                };
                let dropped =
                    self.cfg.drop_rate > 0.0 && self.streams[i].bernoulli(self.cfg.drop_rate);
                self.queue.push(
                    depart + self.cfg.latency_s + jitter,
                    Flight { sender: i, receiver: nb, dropped },
                );
            }
            self.done[i] = depart;
        }

        // -- drain arrivals in virtual-time order; `done` becomes each
        //    node's ready time (max of send completion and arrivals) ------
        clear_delivered(delivered, m);
        self.last_events.clear();
        while let Some((t, c)) = self.queue.pop() {
            self.last_events.push(Arrival {
                t_s: t,
                sender: c.sender,
                receiver: c.receiver,
                bytes: bytes[c.sender],
                dropped: c.dropped,
            });
            if c.dropped {
                self.ledger.dropped_messages += 1;
                continue;
            }
            delivered[c.receiver].push(c.sender);
            if t > self.done[c.receiver] {
                self.done[c.receiver] = t;
            }
        }

        // -- local barrier: each node proceeds once ITS inbox is complete -
        std::mem::swap(&mut self.clock, &mut self.done);
        let horizon = self.clock.iter().fold(0.0f64, |a, &b| a.max(b));
        self.ledger.network_time_s = horizon;
        self.round += 1;

        // Canonical order (ascending sender) so downstream float
        // reductions match the synchronous transport bit-for-bit.  At most
        // one copy per edge per round, so senders are unique.
        for ib in delivered.iter_mut() {
            ib.sort_unstable();
        }
    }

    /// Arc-sharing wrapper over [`SimNetwork::simulate_core`] for the
    /// owning exchange flavours.
    fn simulate<T>(&mut self, payloads: Vec<T>, bytes: &[usize]) -> Inbox<T> {
        let mut delivered: Vec<Vec<usize>> = Vec::new();
        self.simulate_core(bytes, &mut delivered);
        let payloads: Vec<Arc<T>> = payloads.into_iter().map(Arc::new).collect();
        delivered
            .iter()
            .map(|ib| ib.iter().map(|&s| (s, payloads[s].clone())).collect())
            .collect()
    }

    /// Topology in force right now (changes under a schedule).
    pub fn current_topology(&self) -> Topology {
        self.graph.topology
    }
}

impl Transport for SimNetwork {
    fn m(&self) -> usize {
        SimNetwork::m(self)
    }

    fn weight(&self, i: usize, j: usize) -> f64 {
        self.mixing.weight(i, j)
    }

    fn ledger(&self) -> &CommLedger {
        &self.ledger
    }

    fn set_active(&mut self, mask: Option<Arc<Vec<bool>>>) {
        if let Some(m) = &mask {
            assert_eq!(m.len(), self.m(), "sampling mask length must equal node count");
        }
        self.active = mask;
    }

    fn active(&self) -> Option<&[bool]> {
        self.active.as_ref().map(|a| a.as_slice())
    }

    fn graph_epoch(&self) -> u64 {
        self.epoch
    }

    fn exchange<S: Scalar>(&mut self, msgs: Vec<Compressed<S>>) -> Inbox<Compressed<S>> {
        let bytes: Vec<usize> = msgs.iter().map(Compressed::wire_bytes).collect();
        self.simulate(msgs, &bytes)
    }

    fn exchange_dense<S: Scalar>(&mut self, vecs: &[Vec<S>]) -> Inbox<Vec<S>> {
        let bytes: Vec<usize> = vecs.iter().map(|v| dense_wire_bytes::<S>(v.len())).collect();
        self.simulate(vecs.to_vec(), &bytes)
    }

    fn exchange_indices(&mut self, bytes: &[usize], delivered: &mut Vec<Vec<usize>>) {
        self.simulate_core(bytes, delivered);
    }

    fn last_events(&self) -> &[Arrival] {
        &self.last_events
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::Network;
    use crate::topology::Topology;

    fn event_cfg() -> NetConfig {
        NetConfig { mode: NetMode::Event, ..NetConfig::default() }
    }

    fn ring(m: usize) -> Graph {
        Graph::build(Topology::Ring, m)
    }

    #[test]
    fn benign_sim_matches_sync_inbox_and_ledger() {
        let rows: Vec<Vec<f32>> = (0..6).map(|i| vec![i as f32; 5]).collect();
        let mut sync = Network::new(ring(6));
        let mut sim = SimNetwork::new(ring(6), event_cfg(), 1).unwrap();
        let a = sync.exchange_dense(&rows);
        let b = Transport::exchange_dense(&mut sim, &rows);
        assert_eq!(a.len(), b.len());
        for (ia, ib) in a.iter().zip(&b) {
            let sa: Vec<_> = ia.iter().map(|(s, v)| (*s, v.as_ref().clone())).collect();
            let sb: Vec<_> = ib.iter().map(|(s, v)| (*s, v.as_ref().clone())).collect();
            assert_eq!(sa, sb);
        }
        assert_eq!(sync.ledger.total_bytes, sim.ledger.total_bytes);
        assert_eq!(sync.ledger.messages, sim.ledger.messages);
        assert_eq!(sync.ledger.gossip_rounds, sim.ledger.gossip_rounds);
        assert_eq!(sim.ledger.dropped_messages, 0);
        // Equal message sizes on a ring: identical round time too.
        assert!((sync.ledger.network_time_s - sim.ledger.network_time_s).abs() < 1e-12);
    }

    #[test]
    fn drops_shrink_inboxes_and_are_counted() {
        let mut cfg = event_cfg();
        cfg.drop_rate = 0.5;
        let mut sim = SimNetwork::new(ring(8), cfg, 7).unwrap();
        let rows: Vec<Vec<f32>> = (0..8).map(|i| vec![i as f32; 4]).collect();
        let mut delivered = 0u64;
        let rounds = 50;
        for _ in 0..rounds {
            let inbox = Transport::exchange_dense(&mut sim, &rows);
            delivered += inbox.iter().map(|ib| ib.len() as u64).sum::<u64>();
        }
        let sent = sim.ledger.messages;
        assert_eq!(sent, rounds * 16); // ring of 8: 16 edges-directions
        assert_eq!(delivered + sim.ledger.dropped_messages, sent);
        // ~50% loss, generously bounded.
        let rate = sim.ledger.dropped_messages as f64 / sent as f64;
        assert!((0.35..0.65).contains(&rate), "drop rate {rate}");
        // Bytes are paid for dropped messages too (they left the NIC).
        let mut sync = Network::new(ring(8));
        for _ in 0..rounds {
            sync.exchange_dense(&rows);
        }
        assert_eq!(sim.ledger.total_bytes, sync.ledger.total_bytes);
    }

    #[test]
    fn identical_seeds_identical_realizations() {
        let mut cfg = event_cfg();
        cfg.drop_rate = 0.3;
        cfg.jitter_s = 5e-4;
        let rows: Vec<Vec<f32>> = (0..6).map(|i| vec![i as f32; 3]).collect();
        let run = |seed| {
            let mut sim = SimNetwork::new(ring(6), cfg.clone(), seed).unwrap();
            let mut log = Vec::new();
            for _ in 0..10 {
                Transport::exchange_dense(&mut sim, &rows);
                log.extend(sim.last_events.iter().copied().map(|a| {
                    (a.sender, a.receiver, a.dropped, a.t_s.to_bits())
                }));
            }
            log
        };
        assert_eq!(run(3), run(3));
        assert_ne!(run(3), run(4));
    }

    #[test]
    fn straggler_delays_propagate_through_clocks() {
        let mut cfg = event_cfg();
        cfg.straggler_frac = 0.2; // 1 of 5
        cfg.straggler_delay_s = 0.5;
        let mut sim = SimNetwork::new(ring(5), cfg, 11).unwrap();
        let lag = sim.stragglers();
        assert_eq!(lag.len(), 1);
        let rows: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32; 2]).collect();
        Transport::exchange_dense(&mut sim, &rows);
        let s = lag[0];
        // The straggler's neighbours waited for it; a node two hops away
        // did not (one-hop-per-round propagation).
        let nb = (s + 1) % 5;
        let far = (s + 3) % 5; // distance ≥ 2 on a 5-ring
        assert!(sim.clocks()[nb] > sim.clocks()[far] + 0.4);
        // Event log arrivals are time-sorted and the straggler's sends
        // come last.
        let times: Vec<f64> = sim.last_events.iter().map(|a| a.t_s).collect();
        assert!(times.windows(2).all(|w| w[0] <= w[1]));
        // Guarded accessor: this lossless round certainly delivered, but
        // `last_arrival` is an Option because a round may deliver nothing.
        let last = sim.last_arrival().expect("lossless round delivers");
        assert_eq!(last.sender, s);
    }

    /// Regression: a round that delivers zero messages (total loss,
    /// `drop_rate = 1.0`) must not panic anywhere — empty inboxes, a
    /// guarded `last_delivery`, and exact dropped accounting.
    #[test]
    fn total_loss_round_has_empty_inboxes_and_no_panics() {
        let mut cfg = event_cfg();
        cfg.drop_rate = 1.0;
        let mut sim = SimNetwork::new(ring(5), cfg, 21).unwrap();
        let rows: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32; 3]).collect();
        for _ in 0..4 {
            let inbox = Transport::exchange_dense(&mut sim, &rows);
            assert!(inbox.iter().all(|ib| ib.is_empty()), "nothing may arrive");
            // Dropped copies are still logged (they left the NIC), but no
            // delivery exists — the old `.last().unwrap()` pattern relied
            // on at least one event and the guarded API returns None here.
            assert!(sim.last_arrival().is_some_and(|a| a.dropped));
            assert_eq!(sim.last_delivery(), None);
        }
        assert_eq!(sim.ledger.dropped_messages, sim.ledger.messages);
        assert!(sim.ledger.messages > 0);
    }

    /// A bad `[network]` config (e.g. from a mistyped CLI flag) must
    /// surface as a clean `Err`, not a panic — and so must constructing
    /// the event transport from a `sync`-mode config.
    #[test]
    fn bad_config_errors_instead_of_panicking() {
        let mut cfg = event_cfg();
        cfg.drop_rate = 1.5;
        assert!(SimNetwork::new(ring(4), cfg, 1).is_err());
        let mut cfg = event_cfg();
        cfg.latency_s = -0.2;
        assert!(SimNetwork::new(ring(4), cfg, 1).is_err());
        let err = SimNetwork::new(ring(4), NetConfig::default(), 1).unwrap_err();
        assert!(err.contains("mode"), "{err}");
    }

    /// The borrowing exchange consumes the same jitter/drop draws, pays
    /// the same ledger and reports the same sender sets as the Arc-based
    /// exchange — including under heavy loss.
    #[test]
    fn exchange_indices_matches_exchange_under_drops() {
        let mut cfg = event_cfg();
        cfg.drop_rate = 0.4;
        cfg.jitter_s = 2e-4;
        let rows: Vec<Vec<f32>> = (0..6).map(|i| vec![i as f32; 8]).collect();
        let bytes: Vec<usize> =
            rows.iter().map(|v| dense_wire_bytes::<f32>(v.len())).collect();
        let mut a = SimNetwork::new(ring(6), cfg.clone(), 17).unwrap();
        let mut b = SimNetwork::new(ring(6), cfg, 17).unwrap();
        let mut delivered = Vec::new();
        for _round in 0..20 {
            let inbox = Transport::exchange_dense(&mut a, &rows);
            b.exchange_indices(&bytes, &mut delivered);
            for i in 0..6 {
                let senders: Vec<usize> = inbox[i].iter().map(|(s, _)| *s).collect();
                assert_eq!(delivered[i], senders);
            }
            assert_eq!(a.last_events.len(), b.last_events.len());
            for (ea, eb) in a.last_events.iter().zip(&b.last_events) {
                assert_eq!(ea, eb);
            }
        }
        assert_eq!(a.ledger.total_bytes, b.ledger.total_bytes);
        assert_eq!(a.ledger.dropped_messages, b.ledger.dropped_messages);
        assert_eq!(a.clocks(), b.clocks());
    }

    /// Pins the engine half of the same-timestamp tie contract (see
    /// `sim::event`): with equal message sizes and zero jitter, the r-th
    /// copies of all senders arrive at the same virtual instant, and the
    /// event log pops them in ascending sender order — because copies are
    /// pushed in ascending (sender, neighbour-rank) order and the queue
    /// breaks time ties by insertion sequence.  The benign-sim ≡ sync
    /// bit-identity rests on this; a queue swap must not change it.
    #[test]
    fn tie_contract_equal_arrivals_pop_in_sender_order() {
        let mut sim = SimNetwork::new(ring(8), event_cfg(), 5).unwrap();
        let rows: Vec<Vec<f32>> = (0..8).map(|i| vec![i as f32; 6]).collect();
        Transport::exchange_dense(&mut sim, &rows);
        assert!(!sim.last_events.is_empty());
        // Times are non-decreasing, and within an equal-time run the
        // sender ids strictly ascend.
        for w in sim.last_events.windows(2) {
            assert!(w[0].t_s <= w[1].t_s, "event log must be time-sorted");
            if w[0].t_s == w[1].t_s {
                assert!(
                    w[0].sender < w[1].sender
                        || (w[0].sender == w[1].sender && w[0].receiver != w[1].receiver),
                    "equal-time events must keep (sender, push) order: {:?} then {:?}",
                    w[0],
                    w[1]
                );
            }
        }
        // Sanity: ties actually occur in this setup (equal byte sizes ⇒
        // the r-th copy of every sender lands at the same instant).
        let mut any_tie = false;
        for w in sim.last_events.windows(2) {
            if w[0].t_s == w[1].t_s && w[0].sender != w[1].sender {
                any_tie = true;
            }
        }
        assert!(any_tie, "test setup should produce cross-sender ties");
    }

    /// Masked benign sim == masked sync: same deliveries, same ledger.
    #[test]
    fn masked_benign_sim_matches_masked_sync() {
        let mask = Arc::new(vec![true, true, false, true, false, true]);
        let rows: Vec<Vec<f32>> = (0..6).map(|i| vec![i as f32; 5]).collect();
        let mut sync = Network::new(ring(6));
        let mut sim = SimNetwork::new(ring(6), event_cfg(), 1).unwrap();
        sync.set_active(Some(mask.clone()));
        sim.set_active(Some(mask.clone()));
        for _ in 0..3 {
            let a = sync.exchange_dense(&rows);
            let b = Transport::exchange_dense(&mut sim, &rows);
            for (ia, ib) in a.iter().zip(&b) {
                let sa: Vec<usize> = ia.iter().map(|(s, _)| *s).collect();
                let sb: Vec<usize> = ib.iter().map(|(s, _)| *s).collect();
                assert_eq!(sa, sb);
                assert!(sa.iter().all(|&s| mask[s]));
            }
        }
        assert_eq!(sync.ledger.total_bytes, sim.ledger.total_bytes);
        assert_eq!(sync.ledger.messages, sim.ledger.messages);
        // Clearing the mask restores full participation.
        sim.set_active(None);
        let full = Transport::exchange_dense(&mut sim, &rows);
        assert!(full.iter().all(|ib| ib.len() == 2));
    }

    #[test]
    fn topology_schedule_switches_graph() {
        let mut cfg = event_cfg();
        cfg.topology_schedule = vec![(2, Topology::Complete)];
        let mut sim = SimNetwork::new(ring(5), cfg, 1).unwrap();
        let rows: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32]).collect();
        Transport::exchange_dense(&mut sim, &rows); // round 0: ring
        Transport::exchange_dense(&mut sim, &rows); // round 1: ring
        assert_eq!(sim.current_topology().name(), "ring");
        let inbox = Transport::exchange_dense(&mut sim, &rows); // round 2: complete
        assert_eq!(sim.current_topology().name(), "complete");
        assert!(inbox.iter().all(|ib| ib.len() == 4));
    }
}
