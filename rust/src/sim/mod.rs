//! Event-driven network simulation: realistic transports for the
//! decentralized algorithms.
//!
//! The paper's evaluation (and the `table1`/`fig*` harnesses) runs on a
//! synchronous in-process gossip loop — every message delivered, every
//! node in lockstep.  C²DFB's compressed inner loop matters most when the
//! network is *not* like that, so this subsystem provides:
//!
//! * [`event::EventQueue`] — a deterministic discrete-event queue keyed by
//!   virtual time;
//! * [`SimNetwork`] — a [`Transport`](crate::collective::Transport) that
//!   simulates per-link latency/bandwidth/jitter, message loss,
//!   stragglers, and time-varying topologies;
//! * [`parallel::NodePool`] — a scoped thread pool running per-node
//!   compute concurrently with node-ordered results and per-node RNG
//!   streams, so runs are bit-reproducible at any thread count;
//! * [`scale::ScaleSim`] — the sparse million-node engine (`c2dfb
//!   scale`): lazy per-node state over generator topologies, calendar-
//!   queue delivery, O(m·degree + active·d) memory (docs/SCALE.md);
//! * [`NetConfig`] — the `[network]` config table behind all of it.
//!
//! With a benign config (no jitter/drops/stragglers) the event engine
//! reproduces the synchronous engine's trajectories exactly; see
//! `docs/SIM.md` and `tests/sim.rs`.

pub mod event;
pub mod net;
pub mod parallel;
pub mod scale;

pub use net::{Arrival, SimNetwork};
pub use parallel::NodePool;
pub use scale::{ScaleOpts, ScaleReport, ScaleSim};

use crate::topology::Topology;

/// Which transport engine to run an experiment on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetMode {
    /// Synchronous in-process gossip (the default; the paper's setting).
    Sync,
    /// Discrete-event simulation ([`SimNetwork`]).
    Event,
}

impl NetMode {
    pub fn parse(s: &str) -> Result<NetMode, String> {
        match s {
            "sync" | "ideal" => Ok(NetMode::Sync),
            "sim" | "event" => Ok(NetMode::Event),
            _ => Err(format!("unknown network mode: {s:?} (want sync|sim)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            NetMode::Sync => "sync",
            NetMode::Event => "sim",
        }
    }
}

/// The `[network]` config table: link model, fault injection, topology
/// schedule, and the per-node compute thread pool width.
///
/// Defaults describe the paper's LAN testbed (1 ms latency, 1 Gbit/s,
/// lossless, no stragglers) on the synchronous engine — so an empty
/// `[network]` table changes nothing.
#[derive(Clone, Debug, PartialEq)]
pub struct NetConfig {
    pub mode: NetMode,
    /// Base one-way per-message latency (s).
    pub latency_s: f64,
    /// Extra per-message latency, uniform in `[0, jitter_s)` (s).
    pub jitter_s: f64,
    /// NIC bandwidth per node (bytes/s); copies to different neighbours
    /// serialize through it.
    pub bandwidth_bytes_per_s: f64,
    /// I.i.d. per-message loss probability.
    pub drop_rate: f64,
    /// Fraction of nodes that straggle (chosen once per run, seed-stable).
    pub straggler_frac: f64,
    /// Extra delay a straggler adds before each round's sends (s).
    pub straggler_delay_s: f64,
    /// `(gossip round, topology)` switch points for time-varying graphs.
    pub topology_schedule: Vec<(u64, Topology)>,
    /// Thread-pool width for per-node compute (0 or 1 = serial).
    pub threads: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            mode: NetMode::Sync,
            latency_s: 1e-3,
            jitter_s: 0.0,
            bandwidth_bytes_per_s: 125e6,
            drop_rate: 0.0,
            straggler_frac: 0.0,
            straggler_delay_s: 0.0,
            topology_schedule: Vec::new(),
            threads: 1,
        }
    }
}

impl NetConfig {
    pub fn is_event(&self) -> bool {
        self.mode == NetMode::Event
    }

    /// The synchronous engine's equivalent cost model.
    pub fn time_model(&self) -> crate::metrics::TimeModel {
        crate::metrics::TimeModel {
            latency_s: self.latency_s,
            bandwidth_bytes_per_s: self.bandwidth_bytes_per_s,
        }
    }

    /// Parse a straggler spec `"frac:delay_s"`, e.g. `"0.2:0.05"` = 20% of
    /// nodes add 50 ms before each round's sends.
    pub fn parse_straggler(&mut self, spec: &str) -> Result<(), String> {
        let (frac, delay) = spec
            .split_once(':')
            .ok_or_else(|| format!("straggler wants frac:delay_s, got {spec:?}"))?;
        self.straggler_frac = frac
            .parse()
            .map_err(|_| format!("bad straggler fraction: {frac:?}"))?;
        self.straggler_delay_s = delay
            .parse()
            .map_err(|_| format!("bad straggler delay: {delay:?}"))?;
        Ok(())
    }

    /// Parse a topology schedule `"round:topo[,round:topo]…"`, e.g.
    /// `"0:ring,50:2hop,100:er:0.4"` (rounds are gossip rounds; topology
    /// specs as in [`Topology::parse`], which may themselves contain `:`).
    pub fn parse_schedule(&mut self, spec: &str, seed: u64) -> Result<(), String> {
        let mut out = Vec::new();
        for entry in spec.split(',').filter(|e| !e.trim().is_empty()) {
            let (round, topo) = entry
                .trim()
                .split_once(':')
                .ok_or_else(|| format!("schedule entry wants round:topology, got {entry:?}"))?;
            let round: u64 = round
                .parse()
                .map_err(|_| format!("bad schedule round: {round:?}"))?;
            out.push((round, Topology::parse(topo, seed)?));
        }
        out.sort_by_key(|(r, _)| *r);
        self.topology_schedule = out;
        Ok(())
    }

    pub fn validate(&self) -> Result<(), String> {
        if !(0.0..=1.0).contains(&self.drop_rate) {
            return Err(format!("drop_rate must be in [0, 1], got {}", self.drop_rate));
        }
        if self.latency_s < 0.0 || self.jitter_s < 0.0 || self.straggler_delay_s < 0.0 {
            return Err("latency/jitter/straggler delay must be non-negative".into());
        }
        if self.bandwidth_bytes_per_s.is_nan() || self.bandwidth_bytes_per_s <= 0.0 {
            return Err("bandwidth must be positive".into());
        }
        if !(0.0..=1.0).contains(&self.straggler_frac) {
            return Err(format!(
                "straggler fraction must be in [0, 1], got {}",
                self.straggler_frac
            ));
        }
        if !self.is_event()
            && (self.drop_rate > 0.0
                || self.jitter_s > 0.0
                || self.straggler_frac > 0.0
                || !self.topology_schedule.is_empty())
        {
            return Err(
                "drops/jitter/stragglers/topology_schedule need the event engine: \
                 set network mode = \"sim\""
                    .into(),
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_benign_sync() {
        let c = NetConfig::default();
        assert!(!c.is_event());
        assert_eq!(c.drop_rate, 0.0);
        assert!(c.validate().is_ok());
        let tm = c.time_model();
        assert_eq!(tm.latency_s, 1e-3);
        assert_eq!(tm.bandwidth_bytes_per_s, 125e6);
    }

    #[test]
    fn straggler_spec_parses() {
        let mut c = NetConfig::default();
        c.parse_straggler("0.25:0.05").unwrap();
        assert_eq!(c.straggler_frac, 0.25);
        assert_eq!(c.straggler_delay_s, 0.05);
        assert!(c.parse_straggler("nope").is_err());
        assert!(c.parse_straggler("0.2:x").is_err());
    }

    #[test]
    fn schedule_spec_parses_and_sorts() {
        let mut c = NetConfig::default();
        c.parse_schedule("100:er:0.4, 0:ring,50:2hop", 9).unwrap();
        let names: Vec<(u64, &str)> = c
            .topology_schedule
            .iter()
            .map(|(r, t)| (*r, t.name()))
            .collect();
        assert_eq!(names, vec![(0, "ring"), (50, "2hop"), (100, "er")]);
        assert!(c.parse_schedule("ring", 9).is_err());
        assert!(c.parse_schedule("x:ring", 9).is_err());
    }

    #[test]
    fn validate_rejects_faults_on_sync_engine() {
        let mut c = NetConfig { drop_rate: 0.1, ..NetConfig::default() };
        assert!(c.validate().is_err());
        c.mode = NetMode::Event;
        assert!(c.validate().is_ok());
        // Total loss is a legal (if hostile) regime; the zero-delivery
        // round is exercised by sim::net's total-loss regression test.
        c.drop_rate = 1.0;
        assert!(c.validate().is_ok());
        c.drop_rate = 1.5;
        assert!(c.validate().is_err());
        let c = NetConfig {
            bandwidth_bytes_per_s: 0.0,
            ..NetConfig::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn mode_parse() {
        assert_eq!(NetMode::parse("sync").unwrap(), NetMode::Sync);
        assert_eq!(NetMode::parse("sim").unwrap(), NetMode::Event);
        assert_eq!(NetMode::parse("event").unwrap(), NetMode::Event);
        assert!(NetMode::parse("tcp").is_err());
    }
}
