//! Sparse million-node simulation engine (`c2dfb scale`).
//!
//! The full experiment stack ([`crate::coordinator`]) holds dense
//! per-node model state — O(m·d) floats plus an O(m·degree) graph — which
//! is the right trade at the paper's m ≤ 100 but rules out topology-scale
//! studies.  `ScaleSim` is the other end of that trade: a single-machine
//! engine for **sampled gossip-descent on a synthetic quadratic** whose
//! peak memory is O(m·degree + active·d):
//!
//! * the topology is a [`GenTopology`] — neighbor sets and
//!   Metropolis–Hastings weights by formula, no adjacency or mixing
//!   matrix ever materialized;
//! * node state is **lazy**: node i's initial point and local target are
//!   pure functions of `(seed, i)`, derived on demand; only nodes that
//!   have ever been *active* (sampled into a round) hold a materialized
//!   override in a hash map;
//! * message delivery runs through the calendar
//!   [`EventQueue`](crate::sim::event::EventQueue) — O(1) per event — and
//!   the ledger/virtual-clock accounting matches the synchronous engine's
//!   [`TimeModel::round_time`] cost model;
//! * consensus and loss are reported through
//!   [`ConsensusEstimator::estimate_sampled`], materializing only the
//!   strided subset.
//!
//! ## Round semantics (pinned by the dense-reference tests below)
//!
//! Each round draws the per-node participation mask with
//! [`crate::algorithms::sampling_mask`] — the *same* pure function the
//! real driver uses, so `rate = 1.0` means every node, and the mask is a
//! pure function of `(seed, round, m, rate)`.  Then:
//!
//! 1. every **active** sender j transmits its state to all neighbors;
//!    copy r serializes through j's NIC and arrives at
//!    `clock + latency + (r+1)·msg_bytes/bandwidth`;
//! 2. deliveries pop in virtual-time order (ties in push order — the
//!    pinned tie contract), and each **active** receiver folds
//!    `γ·w_ij·(x_j − x_i)` into its accumulator; inactive receivers sleep
//!    through the round (the sender still paid the bytes);
//! 3. every active node applies its accumulated mix and one gradient
//!    step `x ← x − η(x − c_i)` on its local quadratic
//!    `f_i(x) = ½‖x − c_i‖²`; inactive nodes are frozen exactly.
//!
//! Because all copies with the same NIC rank r arrive at the same
//! instant, the global pop order is (rank, sender id) — so each
//! receiver folds senders rank-major, ascending id within a rank, a
//! deterministic order a dense reference can replay bit-for-bit.
//! The trajectory is therefore a pure function of [`ScaleOpts`]; see
//! `docs/SCALE.md` for the methodology and `BENCH_scale.json` for the
//! nodes/sec numbers this engine is benchmarked on (`benches/scale.rs`).

use std::collections::BTreeMap;

use crate::algorithms::sampling_mask;
use crate::metrics::{CommLedger, ConsensusEstimator, TimeModel};
use crate::sim::event::EventQueue;
use crate::topology::{GenTopology, Neighborhood, Topology};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Salt for the per-node initial state stream.
const STATE_SALT: u64 = 0x5343_4C45_5354_4154; // "SCLESTAT"
/// Salt for the per-node quadratic-target stream.
const TARGET_SALT: u64 = 0x5343_4C45_5447_5454; // "SCLETGTT"

/// Per-node RNG: seed ⊕ salt, spread by the golden-ratio multiplier so
/// adjacent node ids decorrelate.  A pure function of `(seed, salt, i)` —
/// the basis of the lazy-state contract.
fn node_rng(seed: u64, salt: u64, i: usize) -> Rng {
    Rng::new((seed ^ salt).wrapping_add((i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)))
}

/// Everything a [`ScaleSim`] run is a pure function of.
#[derive(Clone, Copy, Debug)]
pub struct ScaleOpts {
    /// Node count m (2 ≤ m; 10⁶ is the design point).
    pub nodes: usize,
    /// Must have a generator form ([`GenTopology::supports`]).
    pub topology: Topology,
    /// Gossip-descent rounds to run.
    pub rounds: usize,
    /// Per-round node sampling rate in (0, 1]; 1.0 = every node.
    pub rate: f64,
    /// Per-node state dimension d.
    pub dim: usize,
    pub seed: u64,
    /// Local gradient step size.
    pub eta: f64,
    /// Gossip mixing step size.
    pub gamma: f64,
    /// Consensus/loss reporting estimator (`auto` keeps small m exact).
    pub estimator: ConsensusEstimator,
}

impl Default for ScaleOpts {
    fn default() -> Self {
        ScaleOpts {
            nodes: 1000,
            topology: Topology::Ring,
            rounds: 10,
            rate: 1.0,
            dim: 8,
            seed: 42,
            eta: 0.1,
            gamma: 0.5,
            estimator: ConsensusEstimator::default(),
        }
    }
}

impl ScaleOpts {
    pub fn validate(&self) -> Result<(), String> {
        if self.nodes < 2 {
            return Err(format!("scale needs >= 2 nodes, got {}", self.nodes));
        }
        if !GenTopology::supports(self.topology) {
            return Err(format!(
                "topology '{}' has no generator form; scale runs need one \
                 (ring, exp, torus, rreg:k)",
                self.topology.name()
            ));
        }
        if !(self.rate > 0.0 && self.rate <= 1.0) {
            return Err(format!("sampling rate must be in (0, 1], got {}", self.rate));
        }
        if self.dim == 0 {
            return Err("state dimension must be >= 1".into());
        }
        if !(self.eta > 0.0 && self.eta <= 1.0) {
            return Err(format!("eta must be in (0, 1], got {}", self.eta));
        }
        if !(self.gamma > 0.0 && self.gamma <= 1.0) {
            return Err(format!("gamma must be in (0, 1], got {}", self.gamma));
        }
        Ok(())
    }
}

/// The sparse engine.  See the module docs for the memory contract and
/// round semantics.
pub struct ScaleSim {
    topo: GenTopology,
    opts: ScaleOpts,
    /// State overrides for nodes that have ever been active.  Everything
    /// else is still on its `(seed, i)`-derived baseline — this map IS
    /// the O(active·d) term of the memory bound.  BTreeMap, not HashMap:
    /// keyed access only today, but an ordered map keeps any future
    /// iteration deterministic by construction (lint rule R2).
    states: BTreeMap<usize, Vec<f32>>,
    pub ledger: CommLedger,
    pub time_model: TimeModel,
    clock: f64,
    round: usize,
    /// Cumulative active node-rounds (the work unit nodes/sec counts).
    active_node_rounds: u64,
    queue: EventQueue<(u32, u32)>,
    /// Per-receiver mix accumulators, live within one round.
    acc: BTreeMap<usize, Vec<f32>>,
    nbrs: Vec<usize>,
}

impl ScaleSim {
    pub fn new(opts: ScaleOpts) -> Result<ScaleSim, String> {
        opts.validate()?;
        let topo = GenTopology::new(opts.topology, opts.nodes)?;
        Ok(ScaleSim {
            topo,
            opts,
            states: BTreeMap::new(),
            ledger: CommLedger::default(),
            time_model: TimeModel::default(),
            clock: 0.0,
            round: 0,
            active_node_rounds: 0,
            queue: EventQueue::new(),
            acc: BTreeMap::new(),
            nbrs: Vec::new(),
        })
    }

    pub fn opts(&self) -> &ScaleOpts {
        &self.opts
    }

    /// Rounds stepped so far.
    pub fn round(&self) -> usize {
        self.round
    }

    /// Virtual network clock (matches the ledger's `network_time_s`).
    pub fn virtual_time_s(&self) -> f64 {
        self.clock
    }

    /// How many nodes hold a materialized state override — the measured
    /// side of the O(active·d) memory claim.
    pub fn tracked_states(&self) -> usize {
        self.states.len()
    }

    /// Node i's current state: its override if it has ever been active,
    /// otherwise the `(seed, i)`-derived baseline.
    pub fn state_into(&self, i: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.opts.dim);
        match self.states.get(&i) {
            Some(s) => out.copy_from_slice(s),
            None => {
                let mut rng = node_rng(self.opts.seed, STATE_SALT, i);
                for x in out.iter_mut() {
                    *x = rng.normal_f32(0.0, 1.0);
                }
            }
        }
    }

    /// Node i's local quadratic target c_i (always derived; never stored).
    pub fn target_into(&self, i: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.opts.dim);
        let mut rng = node_rng(self.opts.seed, TARGET_SALT, i);
        for x in out.iter_mut() {
            *x = rng.normal_f32(0.0, 1.0);
        }
    }

    /// Allocating conveniences around the `_into` accessors.
    pub fn state(&self, i: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; self.opts.dim];
        self.state_into(i, &mut v);
        v
    }

    pub fn target(&self, i: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; self.opts.dim];
        self.target_into(i, &mut v);
        v
    }

    /// All m states as dense rows — the small-m equivalence bridge for
    /// tests; defeats the point of the engine at large m.
    pub fn materialize_states(&self) -> Vec<Vec<f32>> {
        (0..self.opts.nodes).map(|i| self.state(i)).collect()
    }

    /// Consensus distance Σ_i ‖x_i − x̄‖² through the configured
    /// estimator; materializes only the strided subset.
    pub fn consensus_estimate(&self) -> f64 {
        let est = self.opts.estimator;
        est.estimate_sampled(self.opts.nodes, self.opts.dim, |i, row| self.state_into(i, row))
    }

    /// Global objective estimate: the strided mean of the local losses
    /// ½‖x_i − c_i‖² (same row subset as the consensus estimator).
    pub fn loss_estimate(&self) -> f64 {
        let m = self.opts.nodes;
        let d = self.opts.dim;
        let stride = self.opts.estimator.stride_for(m);
        let mut xi = vec![0.0f32; d];
        let mut ci = vec![0.0f32; d];
        let mut sum = 0.0f64;
        let mut n = 0usize;
        for i in (0..m).step_by(stride) {
            self.state_into(i, &mut xi);
            self.target_into(i, &mut ci);
            sum += 0.5
                * xi.iter()
                    .zip(&ci)
                    .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
                    .sum::<f64>();
            n += 1;
        }
        sum / n as f64
    }

    /// One sampled gossip-descent round (module docs spell out the three
    /// phases and the delivery-order contract).
    pub fn step_round(&mut self) {
        let m = self.opts.nodes;
        let d = self.opts.dim;
        let msg_bytes = d * 4; // f32 payload
        let mask = sampling_mask(self.opts.seed, self.round, m, self.opts.rate);
        let mask = mask.as_deref().map(Vec::as_slice);
        let active: Vec<usize> = match mask {
            None => (0..m).collect(),
            Some(mk) => (0..m).filter(|&i| mk[i]).collect(),
        };
        self.active_node_rounds += active.len() as u64;

        // Phase 1: active senders schedule one delivery per neighbor.
        // Copy r serializes through the sender's NIC, so its arrival is
        // clock + latency + (r+1)·msg/bw; equal-rank copies from
        // different senders tie and pop in push (= ascending sender)
        // order.
        let per_copy_s = msg_bytes as f64 / self.time_model.bandwidth_bytes_per_s;
        let base_t = self.clock + self.time_model.latency_s;
        let mut max_fanout = 0usize;
        for &j in &active {
            self.topo.neighbors_into(j, &mut self.nbrs);
            max_fanout = max_fanout.max(self.nbrs.len());
            for (r, &i) in self.nbrs.iter().enumerate() {
                self.queue.push(base_t + (r + 1) as f64 * per_copy_s, (j as u32, i as u32));
            }
            self.ledger.total_bytes += (self.nbrs.len() * msg_bytes) as u64;
            self.ledger.messages += self.nbrs.len() as u64;
        }
        self.ledger.gossip_rounds += 1;

        // Phase 2: drain deliveries in virtual-time order.  Active
        // receivers fold γ·w_ij·(x_j − x_i) against their ROUND-START
        // state (overrides only mutate in phase 3); inactive receivers
        // sleep through the round.
        let gamma = self.opts.gamma;
        let mut xi = vec![0.0f32; d];
        let mut xj = vec![0.0f32; d];
        while let Some((_t, (j, i))) = self.queue.pop() {
            let (j, i) = (j as usize, i as usize);
            if let Some(mk) = mask {
                if !mk[i] {
                    continue;
                }
            }
            self.state_into(j, &mut xj);
            self.state_into(i, &mut xi);
            let w = (gamma * self.topo.mix_weight(i, j)) as f32;
            let acc = self.acc.entry(i).or_insert_with(|| vec![0.0f32; d]);
            for k in 0..d {
                acc[k] += w * (xj[k] - xi[k]);
            }
        }

        // The round costs what the synchronous cost model charges: the
        // busiest active sender bounds it (TimeModel::round_time).
        self.clock += self.time_model.round_time(max_fanout * msg_bytes);
        self.ledger.network_time_s = self.clock;

        // Phase 3: active nodes apply mix + one local gradient step and
        // become (or update) overrides; everyone else is untouched.
        let eta = self.opts.eta as f32;
        let mut ci = vec![0.0f32; d];
        for &i in &active {
            self.state_into(i, &mut xi);
            if let Some(a) = self.acc.get(&i) {
                for k in 0..d {
                    xi[k] += a[k];
                }
            }
            self.target_into(i, &mut ci);
            for k in 0..d {
                xi[k] -= eta * (xi[k] - ci[k]);
            }
            self.states.insert(i, xi.clone());
        }
        self.acc.clear();
        self.round += 1;
    }

    /// Run the configured number of rounds and report before/after
    /// consensus and loss estimates.  This engine is wall-clock-free
    /// (lint rule R1): `wall_s`/`nodes_per_sec` come back zero and the
    /// CLI layer stamps them via [`ScaleReport::set_wall`] — everything
    /// this method computes is a pure function of [`ScaleOpts`].
    pub fn run(&mut self) -> ScaleReport {
        let consensus_before = self.consensus_estimate();
        let loss_before = self.loss_estimate();
        let start_active = self.active_node_rounds;
        for _ in 0..self.opts.rounds {
            self.step_round();
        }
        let active_node_rounds = self.active_node_rounds - start_active;
        ScaleReport {
            nodes: self.opts.nodes,
            topology: self.opts.topology.name().to_string(),
            rounds: self.opts.rounds,
            rate: self.opts.rate,
            dim: self.opts.dim,
            seed: self.opts.seed,
            estimator: self.opts.estimator.name(),
            active_node_rounds,
            tracked_states: self.tracked_states(),
            total_bytes: self.ledger.total_bytes,
            messages: self.ledger.messages,
            network_time_s: self.ledger.network_time_s,
            consensus_before,
            consensus_after: self.consensus_estimate(),
            loss_before,
            loss_after: self.loss_estimate(),
            wall_s: 0.0,
            nodes_per_sec: 0.0,
        }
    }
}

/// What a `c2dfb scale` run prints and writes (`--out report.json`).
#[derive(Clone, Debug)]
pub struct ScaleReport {
    pub nodes: usize,
    pub topology: String,
    pub rounds: usize,
    pub rate: f64,
    pub dim: usize,
    pub seed: u64,
    pub estimator: String,
    /// Σ over rounds of that round's active node count — the work unit.
    pub active_node_rounds: u64,
    /// Materialized state overrides at the end (≤ distinct-ever-active).
    pub tracked_states: usize,
    pub total_bytes: u64,
    pub messages: u64,
    pub network_time_s: f64,
    pub consensus_before: f64,
    pub consensus_after: f64,
    pub loss_before: f64,
    pub loss_after: f64,
    /// Wall-clock seconds for the rounds (nondeterministic; everything
    /// else in the report is a pure function of the opts).  Zero until
    /// the caller stamps it with [`ScaleReport::set_wall`] — the engine
    /// itself never reads a clock.
    pub wall_s: f64,
    /// active_node_rounds / wall_s; stamped together with `wall_s`.
    pub nodes_per_sec: f64,
}

impl ScaleReport {
    /// Stamp the nondeterministic throughput numbers.  Lives outside the
    /// engine so `run()` stays a pure function of [`ScaleOpts`]; the CLI
    /// (`c2dfb scale`) and the bench harness time the call and stamp the
    /// report afterwards.
    pub fn set_wall(&mut self, wall_s: f64) {
        self.wall_s = wall_s;
        self.nodes_per_sec = if wall_s > 0.0 {
            self.active_node_rounds as f64 / wall_s
        } else {
            0.0
        };
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("nodes", Json::num(self.nodes as f64)),
            ("topology", Json::str(&self.topology)),
            ("rounds", Json::num(self.rounds as f64)),
            ("rate", Json::num(self.rate)),
            ("dim", Json::num(self.dim as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("estimator", Json::str(&self.estimator)),
            ("active_node_rounds", Json::num(self.active_node_rounds as f64)),
            ("tracked_states", Json::num(self.tracked_states as f64)),
            ("total_bytes", Json::num(self.total_bytes as f64)),
            ("messages", Json::num(self.messages as f64)),
            ("network_time_s", Json::num(self.network_time_s)),
            ("consensus_before", Json::num(self.consensus_before)),
            ("consensus_after", Json::num(self.consensus_after)),
            ("loss_before", Json::num(self.loss_before)),
            ("loss_after", Json::num(self.loss_after)),
            ("wall_s", Json::num(self.wall_s)),
            ("nodes_per_sec", Json::num(self.nodes_per_sec)),
        ])
    }

    pub fn render(&self) -> String {
        format!(
            "scale: m={} topology={} rounds={} rate={} dim={} seed={}\n\
               active node-rounds {}  tracked states {}  comm {:.3} MB  \
             net {:.3}s\n\
               consensus {:.4e} -> {:.4e}   loss {:.4e} -> {:.4e}\n\
               wall {:.3}s  ({:.3e} active nodes/sec)",
            self.nodes,
            self.topology,
            self.rounds,
            self.rate,
            self.dim,
            self.seed,
            self.active_node_rounds,
            self.tracked_states,
            self.total_bytes as f64 / 1e6,
            self.network_time_s,
            self.consensus_before,
            self.consensus_after,
            self.loss_before,
            self.loss_after,
            self.wall_s,
            self.nodes_per_sec,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts(nodes: usize, topology: Topology, rounds: usize, rate: f64) -> ScaleOpts {
        ScaleOpts { nodes, topology, rounds, rate, dim: 3, seed: 7, ..ScaleOpts::default() }
    }

    /// A dense in-test reference replaying the pinned round semantics
    /// (rank-major, ascending-sender fold order; frozen inactive nodes)
    /// must match the sparse engine bit-for-bit.
    fn dense_reference(o: &ScaleOpts) -> Vec<Vec<f32>> {
        let topo = GenTopology::new(o.topology, o.nodes).unwrap();
        let probe = ScaleSim::new(*o).unwrap();
        let mut x: Vec<Vec<f32>> = (0..o.nodes).map(|i| probe.state(i)).collect();
        let c: Vec<Vec<f32>> = (0..o.nodes).map(|i| probe.target(i)).collect();
        let (eta, gamma) = (o.eta as f32, o.gamma);
        for round in 0..o.rounds {
            let mask = sampling_mask(o.seed, round, o.nodes, o.rate);
            let is_active =
                |i: usize| mask.as_ref().map_or(true, |mk| mk[i]);
            let active: Vec<usize> = (0..o.nodes).filter(|&i| is_active(i)).collect();
            let max_deg = active.iter().map(|&j| topo.degree(j)).max().unwrap_or(0);
            let mut acc = vec![vec![0.0f32; o.dim]; o.nodes];
            for r in 0..max_deg {
                for &j in &active {
                    let nb = topo.neighbors(j);
                    if r >= nb.len() {
                        continue;
                    }
                    let i = nb[r];
                    if !is_active(i) {
                        continue;
                    }
                    let w = (gamma * topo.mix_weight(i, j)) as f32;
                    for k in 0..o.dim {
                        acc[i][k] += w * (x[j][k] - x[i][k]);
                    }
                }
            }
            for &i in &active {
                let mut xi = x[i].clone();
                for k in 0..o.dim {
                    xi[k] += acc[i][k];
                }
                for k in 0..o.dim {
                    xi[k] -= eta * (xi[k] - c[i][k]);
                }
                x[i] = xi;
            }
        }
        x
    }

    #[test]
    fn sparse_engine_matches_dense_reference_bitwise() {
        for (topology, m) in [
            (Topology::Ring, 6),
            (Topology::Exponential, 9),
            (Topology::Torus, 12),
            (Topology::RandomRegular { k: 4, seed: 5 }, 11),
        ] {
            for rate in [1.0, 0.6] {
                let o = opts(m, topology, 4, rate);
                let mut sim = ScaleSim::new(o).unwrap();
                sim.run();
                let sparse = sim.materialize_states();
                let dense = dense_reference(&o);
                for i in 0..m {
                    let a: Vec<u32> = sparse[i].iter().map(|x| x.to_bits()).collect();
                    let b: Vec<u32> = dense[i].iter().map(|x| x.to_bits()).collect();
                    assert_eq!(a, b, "{topology:?} m={m} rate={rate} node {i}");
                }
            }
        }
    }

    #[test]
    fn runs_are_deterministic() {
        let o = opts(16, Topology::Exponential, 5, 0.5);
        let run = |o: &ScaleOpts| {
            let mut sim = ScaleSim::new(*o).unwrap();
            sim.run();
            (sim.materialize_states(), sim.ledger.total_bytes, sim.ledger.messages)
        };
        assert_eq!(run(&o), run(&o));
    }

    /// Nodes never sampled stay exactly on their derived baseline, and
    /// the override map tracks exactly the ever-active set.
    #[test]
    fn inactive_nodes_stay_on_baseline() {
        let o = opts(20, Topology::Ring, 6, 0.4);
        let baseline = ScaleSim::new(o).unwrap();
        let mut sim = ScaleSim::new(o).unwrap();
        sim.run();
        let mut ever_active = vec![false; o.nodes];
        for round in 0..o.rounds {
            let mask = sampling_mask(o.seed, round, o.nodes, o.rate).unwrap();
            for (i, &a) in mask.iter().enumerate() {
                ever_active[i] |= a;
            }
        }
        assert_eq!(
            sim.tracked_states(),
            ever_active.iter().filter(|&&a| a).count(),
            "override map must hold exactly the ever-active nodes"
        );
        for i in 0..o.nodes {
            if !ever_active[i] {
                assert_eq!(sim.state(i), baseline.state(i), "node {i} moved while inactive");
            }
        }
    }

    /// Bytes, messages, and virtual time follow the synchronous cost
    /// model with only active senders paying.
    #[test]
    fn ledger_counts_only_active_senders() {
        let o = opts(18, Topology::Ring, 5, 0.5);
        let mut sim = ScaleSim::new(o).unwrap();
        let tm = sim.time_model;
        sim.run();
        let msg = o.dim * 4;
        let topo = GenTopology::new(o.topology, o.nodes).unwrap();
        let (mut bytes, mut msgs, mut net_s) = (0u64, 0u64, 0.0f64);
        for round in 0..o.rounds {
            let mask = sampling_mask(o.seed, round, o.nodes, o.rate).unwrap();
            let mut max_fanout = 0usize;
            for i in 0..o.nodes {
                if mask[i] {
                    let deg = topo.degree(i);
                    bytes += (deg * msg) as u64;
                    msgs += deg as u64;
                    max_fanout = max_fanout.max(deg);
                }
            }
            net_s += tm.round_time(max_fanout * msg);
        }
        assert_eq!(sim.ledger.total_bytes, bytes);
        assert_eq!(sim.ledger.messages, msgs);
        assert_eq!(sim.ledger.gossip_rounds, o.rounds as u64);
        assert_eq!(sim.ledger.network_time_s.to_bits(), net_s.to_bits());
    }

    /// Full participation converges on the tiny quadratic: loss and
    /// consensus both drop.
    #[test]
    fn full_participation_descends() {
        let mut sim = ScaleSim::new(opts(12, Topology::Exponential, 40, 1.0)).unwrap();
        let r = sim.run();
        assert!(r.loss_after < r.loss_before, "{} !< {}", r.loss_after, r.loss_before);
        assert!(
            r.consensus_after < r.consensus_before,
            "{} !< {}",
            r.consensus_after,
            r.consensus_before
        );
        assert_eq!(r.active_node_rounds, 12 * 40);
        assert_eq!(r.tracked_states, 12);
    }

    /// The design point: a million-node round completes with the
    /// override map holding only the sampled sliver of the graph.
    #[test]
    fn million_node_round_stays_sparse() {
        let o = ScaleOpts {
            nodes: 1_000_000,
            topology: Topology::Ring,
            rounds: 2,
            rate: 0.001,
            dim: 4,
            seed: 9,
            ..ScaleOpts::default()
        };
        let mut sim = ScaleSim::new(o).unwrap();
        let report = sim.run();
        assert!(report.active_node_rounds > 0);
        // ~2k expected; generous ceiling guards the sparsity claim.
        assert!(
            report.tracked_states < 10_000,
            "override map ballooned: {}",
            report.tracked_states
        );
        assert!(report.consensus_after.is_finite() && report.consensus_after > 0.0);
        assert!(report.loss_after.is_finite());
        assert_eq!(sim.round(), 2);
    }

    #[test]
    fn opts_validate_rejects_bad_knobs() {
        let ok = ScaleOpts::default();
        assert!(ok.validate().is_ok());
        assert!(ScaleOpts { nodes: 1, ..ok }.validate().is_err());
        assert!(ScaleOpts { rate: 0.0, ..ok }.validate().is_err());
        assert!(ScaleOpts { rate: 1.1, ..ok }.validate().is_err());
        assert!(ScaleOpts { dim: 0, ..ok }.validate().is_err());
        assert!(ScaleOpts { eta: 0.0, ..ok }.validate().is_err());
        assert!(ScaleOpts { gamma: 2.0, ..ok }.validate().is_err());
        assert!(ScaleOpts { topology: Topology::Complete, ..ok }.validate().is_err());
        assert!(ScaleSim::new(ScaleOpts { topology: Topology::Star, ..ok }).is_err());
    }

    #[test]
    fn report_json_roundtrips_key_fields() {
        let mut sim = ScaleSim::new(opts(8, Topology::Ring, 3, 1.0)).unwrap();
        let report = sim.run();
        let j = Json::parse(&report.to_json().to_string()).unwrap();
        assert_eq!(j.get("nodes").and_then(Json::as_usize), Some(8));
        assert_eq!(j.get("topology").and_then(Json::as_str), Some("ring"));
        assert_eq!(
            j.get("active_node_rounds").and_then(Json::as_usize),
            Some(8 * 3)
        );
        assert!(report.render().contains("m=8"));
    }
}
