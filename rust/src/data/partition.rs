//! Data partitioners across decentralized nodes.
//!
//! `iid` reproduces the paper's random split; `heterogeneous(h)` its
//! class-skew protocol: an `h` fraction of each class c's rows is pinned to
//! node `c mod m`, the remaining `1−h` spread uniformly over the others
//! (the paper's experiments use h = 0.8).  `dirichlet(α)` is the standard
//! federated-learning label-skew knob (Hsu et al. 2019): each class's rows
//! are divided across nodes by a fresh Dir(α·1_m) draw — α → ∞ recovers
//! IID, α → 0 approaches single-class shards — giving a *continuous*
//! heterogeneity axis where `het:h` only pins one home node per class.

use super::Dataset;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Partition {
    Iid,
    /// `h` ∈ [0, 1): fraction of each class pinned to its designated node.
    Heterogeneous { h: f64 },
    /// Label-skew via per-class Dir(α·1_m) proportions (α > 0).
    Dirichlet { alpha: f64 },
}

impl Partition {
    pub fn name(&self) -> String {
        match self {
            Partition::Iid => "iid".into(),
            Partition::Heterogeneous { h } => format!("het:{h}"),
            Partition::Dirichlet { alpha } => format!("dir:{alpha}"),
        }
    }

    pub fn parse(s: &str) -> Result<Partition, String> {
        if s == "iid" {
            return Ok(Partition::Iid);
        }
        if let Some(h) = s.strip_prefix("het:").or_else(|| s.strip_prefix("het=")) {
            let h: f64 = h.parse().map_err(|_| format!("bad heterogeneity: {s}"))?;
            if !(0.0..=1.0).contains(&h) {
                return Err(format!("heterogeneity out of range: {h}"));
            }
            return Ok(Partition::Heterogeneous { h });
        }
        if let Some(a) = s.strip_prefix("dir:").or_else(|| s.strip_prefix("dir=")) {
            let alpha: f64 = a.parse().map_err(|_| format!("bad dirichlet alpha: {s}"))?;
            if !(alpha > 0.0 && alpha.is_finite()) {
                return Err(format!("dirichlet alpha must be positive, got {alpha}"));
            }
            return Ok(Partition::Dirichlet { alpha });
        }
        Err(format!(
            "unknown partition: {s} (use 'iid', 'het:0.8' or 'dir:0.3')"
        ))
    }

    /// Split `ds` into `m` shards according to the scheme.
    pub fn split(&self, ds: &Dataset, m: usize, rng: &mut Rng) -> Vec<Dataset> {
        assert!(m >= 1);
        let mut assignment: Vec<Vec<usize>> = vec![Vec::new(); m];
        match self {
            Partition::Iid => {
                let mut rows: Vec<usize> = (0..ds.n).collect();
                rng.shuffle(&mut rows);
                for (i, r) in rows.into_iter().enumerate() {
                    assignment[i % m].push(r);
                }
            }
            Partition::Heterogeneous { h } => {
                // Group rows by class.
                let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); ds.classes];
                for i in 0..ds.n {
                    by_class[ds.labels[i]].push(i);
                }
                for (c, mut rows) in by_class.into_iter().enumerate() {
                    rng.shuffle(&mut rows);
                    let pinned = ((rows.len() as f64) * h).round() as usize;
                    let home = c % m;
                    for (i, r) in rows.into_iter().enumerate() {
                        if i < pinned {
                            assignment[home].push(r);
                        } else if m == 1 {
                            assignment[0].push(r);
                        } else {
                            // Spread the tail over the other m−1 nodes.
                            let mut t = rng.below(m - 1);
                            if t >= home {
                                t += 1;
                            }
                            assignment[t].push(r);
                        }
                    }
                }
            }
            Partition::Dirichlet { alpha } => {
                let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); ds.classes];
                for i in 0..ds.n {
                    by_class[ds.labels[i]].push(i);
                }
                for mut rows in by_class.into_iter() {
                    rng.shuffle(&mut rows);
                    let p = rng.dirichlet(*alpha, m);
                    // Largest-remainder allocation: counts sum exactly to
                    // the class size, so no rows are lost or duplicated.
                    let n_c = rows.len();
                    let mut counts: Vec<usize> =
                        p.iter().map(|&q| (q * n_c as f64).floor() as usize).collect();
                    let assigned: usize = counts.iter().sum();
                    let mut rema: Vec<(usize, f64)> = p
                        .iter()
                        .enumerate()
                        .map(|(t, &q)| (t, q * n_c as f64 - (q * n_c as f64).floor()))
                        .collect();
                    rema.sort_by(|a, b| {
                        b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0))
                    });
                    for &(t, _) in rema.iter().take(n_c - assigned) {
                        counts[t] += 1;
                    }
                    let mut it = rows.into_iter();
                    for (t, &cnt) in counts.iter().enumerate() {
                        for _ in 0..cnt {
                            assignment[t].push(it.next().unwrap());
                        }
                    }
                }
                // Tiny α can starve a node entirely; downstream shard
                // resizing samples *from* the shard, so guarantee every
                // node at least one row by stealing from the fullest.
                for t in 0..m {
                    if assignment[t].is_empty() {
                        let donor = (0..m)
                            .max_by_key(|&s| assignment[s].len())
                            .expect("m >= 1");
                        if assignment[donor].len() > 1 {
                            let row = assignment[donor].pop().unwrap();
                            assignment[t].push(row);
                        }
                    }
                }
            }
        }
        assignment.iter().map(|rows| ds.subset(rows)).collect()
    }
}

/// Node-level skew measure: mean over nodes of the total-variation distance
/// between the node's class distribution and the global one.  0 for a
/// perfectly IID split, → 1 as shards become single-class.
pub fn skew(shards: &[Dataset], classes: usize) -> f64 {
    let total: usize = shards.iter().map(|s| s.n).sum();
    let mut global = vec![0.0f64; classes];
    for s in shards {
        for (c, cnt) in s.class_histogram().into_iter().enumerate() {
            global[c] += cnt as f64;
        }
    }
    for g in global.iter_mut() {
        *g /= total as f64;
    }
    let mut acc = 0.0;
    for s in shards {
        if s.n == 0 {
            continue;
        }
        let hist = s.class_histogram();
        let tv: f64 = hist
            .iter()
            .enumerate()
            .map(|(c, &cnt)| (cnt as f64 / s.n as f64 - global[c]).abs())
            .sum::<f64>()
            / 2.0;
        acc += tv;
    }
    acc / shards.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::newsgroups_like;

    #[test]
    fn iid_split_sizes_balanced() {
        let ds = newsgroups_like(103, 16, 4, 0.3, 1);
        let mut rng = Rng::new(2);
        let shards = Partition::Iid.split(&ds, 10, &mut rng);
        assert_eq!(shards.len(), 10);
        let total: usize = shards.iter().map(|s| s.n).sum();
        assert_eq!(total, 103);
        assert!(shards.iter().all(|s| s.n >= 10 && s.n <= 11));
    }

    #[test]
    fn heterogeneous_pins_classes() {
        let ds = newsgroups_like(400, 16, 4, 0.3, 3);
        let mut rng = Rng::new(4);
        let shards = Partition::Heterogeneous { h: 0.8 }.split(&ds, 4, &mut rng);
        for (node, s) in shards.iter().enumerate() {
            let hist = s.class_histogram();
            // Node c holds ~80% of class c: that class dominates its shard.
            let own = hist[node] as f64 / s.n as f64;
            assert!(own > 0.5, "node {node} own-class frac {own}");
        }
    }

    #[test]
    fn heterogeneity_increases_skew() {
        let ds = newsgroups_like(600, 16, 6, 0.3, 5);
        let mut rng = Rng::new(6);
        let iid = skew(&Partition::Iid.split(&ds, 6, &mut rng), 6);
        let het5 = skew(&Partition::Heterogeneous { h: 0.5 }.split(&ds, 6, &mut rng), 6);
        let het9 = skew(&Partition::Heterogeneous { h: 0.9 }.split(&ds, 6, &mut rng), 6);
        assert!(iid < het5, "{iid} !< {het5}");
        assert!(het5 < het9, "{het5} !< {het9}");
    }

    #[test]
    fn more_classes_than_nodes_wraps() {
        let ds = newsgroups_like(300, 8, 10, 0.3, 7);
        let mut rng = Rng::new(8);
        let shards = Partition::Heterogeneous { h: 0.8 }.split(&ds, 3, &mut rng);
        assert_eq!(shards.len(), 3);
        assert_eq!(shards.iter().map(|s| s.n).sum::<usize>(), 300);
    }

    #[test]
    fn parse_specs() {
        assert_eq!(Partition::parse("iid").unwrap(), Partition::Iid);
        assert_eq!(
            Partition::parse("het:0.8").unwrap(),
            Partition::Heterogeneous { h: 0.8 }
        );
        assert_eq!(
            Partition::parse("dir:0.3").unwrap(),
            Partition::Dirichlet { alpha: 0.3 }
        );
        assert!(Partition::parse("x").is_err());
        assert!(Partition::parse("het:2").is_err());
        assert!(Partition::parse("dir:0").is_err());
        assert!(Partition::parse("dir:-1").is_err());
    }

    #[test]
    fn dirichlet_conserves_rows_and_alpha_controls_skew() {
        let ds = newsgroups_like(600, 16, 6, 0.3, 11);
        let mut rng = Rng::new(12);
        let tight = Partition::Dirichlet { alpha: 100.0 }.split(&ds, 6, &mut rng);
        let loose = Partition::Dirichlet { alpha: 0.1 }.split(&ds, 6, &mut rng);
        for shards in [&tight, &loose] {
            assert_eq!(shards.iter().map(|s| s.n).sum::<usize>(), 600);
            assert!(shards.iter().all(|s| s.n >= 1), "empty shard");
        }
        let s_tight = skew(&tight, 6);
        let s_loose = skew(&loose, 6);
        assert!(
            s_tight + 0.1 < s_loose,
            "α=100 skew {s_tight} should be well below α=0.1 skew {s_loose}"
        );
    }

    #[test]
    fn dirichlet_split_is_deterministic_by_seed() {
        let ds = newsgroups_like(200, 8, 4, 0.3, 13);
        let shards = |seed: u64| {
            let mut rng = Rng::new(seed);
            Partition::Dirichlet { alpha: 0.5 }
                .split(&ds, 5, &mut rng)
                .iter()
                .map(|s| (s.n, s.labels.clone()))
                .collect::<Vec<_>>()
        };
        assert_eq!(shards(7), shards(7));
        assert_ne!(shards(7), shards(8));
    }
}
