//! Synthetic datasets and heterogeneous partitioning.
//!
//! The paper evaluates on 20 Newsgroups (tf-idf features, linear model)
//! and MNIST (784-dim images, MLP).  Neither is downloadable in this
//! offline environment, so we generate structurally equivalent synthetic
//! corpora (see DESIGN.md §Substitutions):
//!
//! * [`newsgroups_like`] — sparse-ish multiclass linear data: per-class
//!   sparse mean direction + Gaussian noise, mimicking tf-idf geometry.
//! * [`mnist_like`] — per-class 28×28 template images (random smooth
//!   blobs) + pixel noise, normalized like the paper (mean .1307/std .3081).
//!
//! Partitioners reproduce the paper's protocols: `iid` (random split) and
//! `heterogeneous(h)` where an h-fraction of each class's data is pinned
//! to one designated node (the paper's h = 0.8 setting).

use crate::util::rng::Rng;

pub mod partition;

/// A dense multiclass dataset (row-major features, one-hot labels).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub n: usize,
    pub d: usize,
    pub classes: usize,
    /// n×d row-major.
    pub features: Vec<f32>,
    /// Class index per row.
    pub labels: Vec<usize>,
}

impl Dataset {
    pub fn row(&self, i: usize) -> &[f32] {
        &self.features[i * self.d..(i + 1) * self.d]
    }

    /// One-hot encode labels as an n×c row-major f32 matrix.
    pub fn onehot(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n * self.classes];
        for (i, &l) in self.labels.iter().enumerate() {
            out[i * self.classes + l] = 1.0;
        }
        out
    }

    /// Select rows by index into a new dataset.
    pub fn subset(&self, rows: &[usize]) -> Dataset {
        let mut features = Vec::with_capacity(rows.len() * self.d);
        let mut labels = Vec::with_capacity(rows.len());
        for &r in rows {
            features.extend_from_slice(self.row(r));
            labels.push(self.labels[r]);
        }
        Dataset { n: rows.len(), d: self.d, classes: self.classes, features, labels }
    }

    /// Split into (train, val) with the given train fraction, shuffled.
    pub fn split(&self, train_frac: f64, rng: &mut Rng) -> (Dataset, Dataset) {
        let mut rows: Vec<usize> = (0..self.n).collect();
        rng.shuffle(&mut rows);
        let ntr = ((self.n as f64) * train_frac).round() as usize;
        (self.subset(&rows[..ntr]), self.subset(&rows[ntr..]))
    }

    /// Pad or subsample to exactly `n` rows (artifact shapes are static).
    pub fn resize_to(&self, n: usize, rng: &mut Rng) -> Dataset {
        if n == self.n {
            return self.clone();
        }
        let mut rows: Vec<usize> = Vec::with_capacity(n);
        if n < self.n {
            rows = rng.sample_indices(self.n, n);
        } else {
            rows.extend(0..self.n);
            while rows.len() < n {
                rows.push(rng.below(self.n));
            }
        }
        self.subset(&rows)
    }

    /// Per-class row counts.
    pub fn class_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.classes];
        for &l in &self.labels {
            h[l] += 1;
        }
        h
    }
}

/// Sparse-ish multiclass linear data in the spirit of tf-idf 20-Newsgroups:
/// each class has a sparse mean direction over `d` features (a fraction
/// `support` of coordinates active), rows are `mean[class] + noise`, and a
/// global sparsity mask zeroes most small entries, mimicking term-document
/// sparsity.
pub fn newsgroups_like(
    n: usize,
    d: usize,
    classes: usize,
    noise: f32,
    seed: u64,
) -> Dataset {
    let mut rng = Rng::new(seed);
    let support = (0.05f64.max(20.0 / d as f64)).min(1.0);
    let k = ((d as f64) * support).ceil() as usize;
    // Per-class sparse mean directions (the class signal).
    let mut means = vec![vec![0.0f32; d]; classes];
    for mean in means.iter_mut() {
        for idx in rng.sample_indices(d, k) {
            mean[idx] = rng.normal_f32(0.0, 1.0);
        }
    }
    // Shared class-independent "background topics" — the high-variance
    // common-word subspace of real tf-idf corpora.  They dominate the raw
    // feature variance, so a classifier must *suppress* them before the
    // (small) class signal decides the prediction; this is what makes the
    // learning curve gradual instead of one-step, like the real dataset.
    let n_topics = 8usize.min(d / 4).max(1);
    let bg_scale = 3.0f32;
    let mut topics = vec![vec![0.0f32; d]; n_topics];
    for t in topics.iter_mut() {
        rng.fill_normal(t, 0.0, 1.0);
        let nrm = (t.iter().map(|v| (*v as f64).powi(2)).sum::<f64>()).sqrt() as f32;
        for v in t.iter_mut() {
            *v /= nrm.max(1e-9);
        }
    }
    let mut features = Vec::with_capacity(n * d);
    let mut labels = Vec::with_capacity(n);
    let mut bg_row = vec![0.0f32; d];
    for i in 0..n {
        let c = i % classes; // balanced classes
        labels.push(c);
        let mean = &means[c];
        bg_row.fill(0.0);
        for t in &topics {
            let coef = bg_scale * rng.normal_f32(0.0, 1.0);
            for (b, tv) in bg_row.iter_mut().zip(t) {
                *b += coef * tv;
            }
        }
        let row_start = features.len();
        for j in 0..d {
            let x = mean[j] + bg_row[j] + rng.normal_f32(0.0, noise);
            // Soft-threshold small activations to mimic tf-idf sparsity,
            // then clamp to non-negative like term frequencies.
            let x = if x.abs() < 0.5 * noise { 0.0 } else { x };
            features.push(x.max(0.0));
        }
        // L2-normalize the row like tf-idf vectors: bounds the CE
        // smoothness constant so the paper's O(1) step sizes are stable.
        let row = &mut features[row_start..];
        let norm = (row.iter().map(|v| (*v as f64).powi(2)).sum::<f64>()).sqrt() as f32;
        if norm > 0.0 {
            for v in row.iter_mut() {
                *v /= norm;
            }
        }
    }
    let mut ds = Dataset { n, d, classes, features, labels };
    shuffle_rows(&mut ds, &mut rng);
    ds
}

/// MNIST-shaped data: per-class smooth 2-D templates + noise, normalized
/// with the paper's constants (mean 0.1307, std 0.3081).  `d` is the
/// flattened image size (784 for the full preset); non-square `d` is
/// generated on the smallest enclosing square and truncated.
pub fn mnist_like(n: usize, d: usize, classes: usize, noise: f32, seed: u64) -> Dataset {
    let side = (d as f64).sqrt().ceil() as usize;
    let mut rng = Rng::new(seed);
    // Each class template: a sum of 3 Gaussian blobs at random positions.
    let sq = side * side;
    let mut templates = vec![vec![0.0f32; sq]; classes];
    let lo = side as f32 * 0.2;
    let hi = side as f32 * 0.8;
    for t in templates.iter_mut() {
        for _ in 0..3 {
            let cx = rng.uniform_in(lo, hi);
            let cy = rng.uniform_in(lo, hi);
            let sigma = rng.uniform_in(side as f32 * 0.07, side as f32 * 0.18);
            let amp = rng.uniform_in(0.6, 1.0);
            for y in 0..side {
                for x in 0..side {
                    let dx = x as f32 - cx;
                    let dy = y as f32 - cy;
                    t[y * side + x] += amp * (-(dx * dx + dy * dy) / (2.0 * sigma * sigma)).exp();
                }
            }
        }
    }
    let mut features = Vec::with_capacity(n * d);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % classes;
        labels.push(c);
        for j in 0..d {
            let pix = (templates[c][j] + rng.normal_f32(0.0, noise)).clamp(0.0, 1.0);
            // The paper's Normalize((0.1307,), (0.3081,)).
            features.push((pix - 0.1307) / 0.3081);
        }
    }
    let mut ds = Dataset { n, d, classes, features, labels };
    shuffle_rows(&mut ds, &mut rng);
    ds
}

fn shuffle_rows(ds: &mut Dataset, rng: &mut Rng) {
    let mut order: Vec<usize> = (0..ds.n).collect();
    rng.shuffle(&mut order);
    let shuffled = ds.subset(&order);
    *ds = shuffled;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn newsgroups_shapes_and_balance() {
        let ds = newsgroups_like(200, 64, 4, 0.3, 1);
        assert_eq!(ds.n, 200);
        assert_eq!(ds.d, 64);
        assert_eq!(ds.features.len(), 200 * 64);
        let hist = ds.class_histogram();
        assert_eq!(hist.iter().sum::<usize>(), 200);
        assert!(hist.iter().all(|&c| c == 50));
    }

    #[test]
    fn newsgroups_is_sparse_nonnegative_unit_rows() {
        let ds = newsgroups_like(100, 128, 4, 0.3, 2);
        let zeros = ds.features.iter().filter(|&&x| x == 0.0).count();
        assert!(zeros as f64 / ds.features.len() as f64 > 0.3, "not sparse: {zeros}");
        assert!(ds.features.iter().all(|&x| x >= 0.0));
        for i in 0..ds.n {
            let norm: f64 = ds.row(i).iter().map(|v| (*v as f64).powi(2)).sum();
            assert!((norm - 1.0).abs() < 1e-4, "row {i} norm² {norm}");
        }
    }

    #[test]
    fn newsgroups_is_linearly_separable_ish() {
        // Class means should be farther apart than in-class scatter, so a
        // linear model can learn: check mean inter-class distance exceeds
        // mean intra-class distance.
        let ds = newsgroups_like(120, 100, 3, 0.2, 3);
        let mut means = vec![vec![0.0f64; ds.d]; 3];
        let hist = ds.class_histogram();
        for i in 0..ds.n {
            for j in 0..ds.d {
                means[ds.labels[i]][j] += ds.row(i)[j] as f64;
            }
        }
        for (c, m) in means.iter_mut().enumerate() {
            for v in m.iter_mut() {
                *v /= hist[c] as f64;
            }
        }
        let dist = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f64>().sqrt()
        };
        let inter = dist(&means[0], &means[1]).min(dist(&means[1], &means[2]));
        assert!(inter > 0.5, "class means too close: {inter}");
    }

    #[test]
    fn mnist_like_shapes_and_normalization() {
        let ds = mnist_like(50, 784, 10, 0.1, 4);
        assert_eq!(ds.d, 784);
        // Normalized pixel range: (0−.1307)/.3081 ≈ −0.42, (1−.1307)/.3081 ≈ 2.82.
        for &x in &ds.features {
            assert!((-0.43..=2.83).contains(&x), "{x}");
        }
    }

    #[test]
    fn split_and_resize() {
        let mut rng = Rng::new(5);
        let ds = newsgroups_like(100, 32, 4, 0.3, 6);
        let (tr, va) = ds.split(0.7, &mut rng);
        assert_eq!(tr.n, 70);
        assert_eq!(va.n, 30);
        let up = va.resize_to(50, &mut rng);
        assert_eq!(up.n, 50);
        let down = tr.resize_to(10, &mut rng);
        assert_eq!(down.n, 10);
    }

    #[test]
    fn onehot_rows_sum_to_one() {
        let ds = newsgroups_like(30, 16, 4, 0.3, 7);
        let oh = ds.onehot();
        for i in 0..ds.n {
            let s: f32 = oh[i * 4..(i + 1) * 4].iter().sum();
            assert_eq!(s, 1.0);
            assert_eq!(oh[i * 4 + ds.labels[i]], 1.0);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = newsgroups_like(40, 16, 4, 0.3, 9);
        let b = newsgroups_like(40, 16, 4, 0.3, 9);
        assert_eq!(a.features, b.features);
        let c = newsgroups_like(40, 16, 4, 0.3, 10);
        assert_ne!(a.features, c.features);
    }
}
