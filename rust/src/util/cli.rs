//! Tiny command-line parser (offline build: no clap).
//!
//! Grammar: `prog <subcommand> [--flag] [--key value]... [positional]...`
//! Flags may also be written `--key=value`.  Unknown keys are an error so
//! typos fail loudly.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    known: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (tests) — first token is NOT the
    /// program name.
    pub fn parse_tokens(tokens: &[String]) -> Args {
        let mut a = Args::default();
        let mut it = tokens.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                a.subcommand = Some(it.next().unwrap().clone());
            }
        }
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    a.kv.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    a.kv.insert(stripped.to_string(), it.next().unwrap().clone());
                } else {
                    a.flags.push(stripped.to_string());
                }
            } else {
                a.positional.push(tok.clone());
            }
        }
        a
    }

    pub fn from_env() -> Args {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        Args::parse_tokens(&tokens)
    }

    pub fn flag(&mut self, name: &str) -> bool {
        self.known.push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&mut self, name: &str) -> Option<String> {
        self.known.push(name.to_string());
        self.kv.get(name).cloned()
    }

    pub fn get_or(&mut self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or_else(|| default.to_string())
    }

    pub fn get_parse<T: std::str::FromStr>(&mut self, name: &str, default: T) -> T {
        match self.get(name) {
            Some(s) => s
                .parse()
                .unwrap_or_else(|_| panic!("--{name}: cannot parse {s:?}")),
            None => default,
        }
    }

    /// Call after consuming all known options; errors on leftovers.
    pub fn finish(&self) -> Result<(), String> {
        let unknown: Vec<&String> = self
            .kv
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !self.known.contains(k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!("unknown options: {unknown:?}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn subcommand_kv_flags_positional() {
        let mut a = Args::parse_tokens(&toks("run --rounds 50 --verbose --topo=ring cfg.toml"));
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get_parse("rounds", 0usize), 50);
        assert!(a.flag("verbose"));
        assert_eq!(a.get_or("topo", "x"), "ring");
        assert_eq!(a.positional, vec!["cfg.toml"]);
        assert!(a.finish().is_ok());
    }

    #[test]
    fn unknown_option_errors() {
        let mut a = Args::parse_tokens(&toks("run --oops 1"));
        let _ = a.get("rounds");
        assert!(a.finish().is_err());
    }

    #[test]
    fn defaults_apply() {
        let mut a = Args::parse_tokens(&toks("bench"));
        assert_eq!(a.get_parse("m", 10usize), 10);
        assert_eq!(a.get_or("algo", "c2dfb"), "c2dfb");
    }
}
