//! Deterministic PRNG: xoshiro256++ with splitmix64 seeding.
//!
//! Every stochastic component in the library (data generation,
//! heterogeneous partitioning, rand-k compression, Erdős–Rényi topology)
//! draws from this generator, so whole experiments are reproducible from a
//! single `u64` seed.  No external crates are available offline, and a
//! from-scratch generator also lets the property tests shrink
//! deterministically.

/// xoshiro256++ by Blackman & Vigna (public domain reference algorithm).
#[derive(Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box–Muller.
    spare_normal: Option<f64>,
    /// Reusable membership bitmap for [`Rng::sample_indices_into`].  Pure
    /// scratch — not part of the generator state, never affects draws.
    mask: Vec<u64>,
}

impl Clone for Rng {
    fn clone(&self) -> Rng {
        // Clone the generator state only; the scratch is per-instance.
        Rng { s: self.s, spare_normal: self.spare_normal, mask: Vec::new() }
    }
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any `u64` (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None, mask: Vec::new() }
    }

    /// Derive an independent stream (for per-node generators).
    pub fn split(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in `[0, n)` via Lemire's rejection-free-ish method.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // 128-bit multiply keeps the modulo bias negligible for any n that
        // fits in usize.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (caches the spare deviate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal f32 with the given mean and standard deviation.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a slice with i.i.d. N(mean, std²) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(mean, std);
        }
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang squeeze; `shape < 1` uses the
    /// standard boost Gamma(k) = Gamma(k+1)·U^{1/k}.  Deterministic given
    /// the generator state (drives the Dirichlet partitioner).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0, "gamma shape must be positive");
        if shape < 1.0 {
            let boost = loop {
                let u = self.uniform();
                if u > 0.0 {
                    break u.powf(1.0 / shape);
                }
            };
            return boost * self.gamma(shape + 1.0);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4)
                || (u > 0.0 && u.ln() < 0.5 * x * x + d - d * v + d * v.ln())
            {
                return d * v;
            }
        }
    }

    /// A point on the `n`-simplex ~ Dirichlet(α·1) (symmetric
    /// concentration α): normalized i.i.d. Gamma(α) draws.
    pub fn dirichlet(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        assert!(n >= 1);
        let mut g: Vec<f64> = (0..n).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = g.iter().sum();
        if sum <= 0.0 {
            // Pathologically tiny α can underflow every draw; fall back to
            // a deterministic one-hot on a uniform index.
            let hot = self.below(n);
            return (0..n).map(|i| if i == hot { 1.0 } else { 0.0 }).collect();
        }
        for x in g.iter_mut() {
            *x /= sum;
        }
        g
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)`, sorted ascending
    /// (Floyd's algorithm for small k, shuffle for large k).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(k);
        self.sample_indices_into(n, k, &mut out);
        out
    }

    /// [`sample_indices`](Rng::sample_indices) into a reusable buffer:
    /// identical draw sequence and output, but allocation-free once `out`
    /// (and the internal bitmap) have capacity — the rand-k hot path.
    /// `out` is overwritten.
    pub fn sample_indices_into(&mut self, n: usize, k: usize, out: &mut Vec<usize>) {
        assert!(k <= n);
        out.clear();
        if k * 4 > n {
            out.extend(0..n);
            self.shuffle(out);
            out.truncate(k);
            out.sort_unstable();
            return;
        }
        // Floyd's algorithm with a reusable bitmap as the membership set:
        // same accept/replace decisions (and so the same draws and output)
        // as the hash-set formulation, O(1) queries, no per-call heap
        // churn.  Taken out of `self` so `below` can borrow the generator.
        let mut mask = std::mem::take(&mut self.mask);
        mask.clear();
        mask.resize((n + 63) / 64, 0);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let v = if (mask[t / 64] >> (t % 64)) & 1 == 1 { j } else { t };
            mask[v / 64] |= 1 << (v % 64);
            out.push(v);
        }
        self.mask = mask;
        out.sort_unstable();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_bounds_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn gamma_moments() {
        // Gamma(k, 1): mean k, variance k — check both above and below the
        // Marsaglia–Tsang k = 1 boost boundary.
        for shape in [0.5f64, 2.5] {
            let mut r = Rng::new(17);
            let n = 40_000;
            let (mut s1, mut s2) = (0.0, 0.0);
            for _ in 0..n {
                let g = r.gamma(shape);
                assert!(g > 0.0);
                s1 += g;
                s2 += g * g;
            }
            let mean = s1 / n as f64;
            let var = s2 / n as f64 - mean * mean;
            assert!((mean - shape).abs() < 0.05 * (1.0 + shape), "k={shape} mean={mean}");
            assert!((var - shape).abs() < 0.1 * (1.0 + shape), "k={shape} var={var}");
        }
    }

    #[test]
    fn dirichlet_is_on_the_simplex_and_alpha_controls_spread() {
        let mut r = Rng::new(19);
        let spread = |alpha: f64, r: &mut Rng| -> f64 {
            let mut acc = 0.0;
            for _ in 0..200 {
                let p = r.dirichlet(alpha, 6);
                let sum: f64 = p.iter().sum();
                assert!((sum - 1.0).abs() < 1e-9, "sum={sum}");
                assert!(p.iter().all(|&x| x >= 0.0));
                // Max coordinate: → 1/n for large α, → 1 for tiny α.
                acc += p.iter().cloned().fold(0.0, f64::max);
            }
            acc / 200.0
        };
        let tight = spread(100.0, &mut r);
        let loose = spread(0.1, &mut r);
        assert!(tight < 0.3, "α=100 max-coord {tight}");
        assert!(loose > 0.6, "α=0.1 max-coord {loose}");
    }

    #[test]
    fn gamma_deterministic_by_seed() {
        let mut a = Rng::new(23);
        let mut b = Rng::new(23);
        for _ in 0..50 {
            assert_eq!(a.gamma(0.7).to_bits(), b.gamma(0.7).to_bits());
        }
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(11);
        for (n, k) in [(100, 5), (100, 80), (10, 10), (1, 1)] {
            let idx = r.sample_indices(n, k);
            assert_eq!(idx.len(), k);
            assert!(idx.windows(2).all(|w| w[0] < w[1]));
            assert!(idx.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn sample_indices_into_matches_allocating_version() {
        let mut dirty = vec![7usize; 300];
        for (n, k) in [(100, 5), (100, 80), (10, 10), (1, 1), (70_000, 7)] {
            let mut a = Rng::new(29);
            let mut b = Rng::new(29);
            let fresh = a.sample_indices(n, k);
            b.sample_indices_into(n, k, &mut dirty);
            assert_eq!(fresh, dirty);
            // Both generators advanced identically.
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
