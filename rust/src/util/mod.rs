//! Self-contained infrastructure substrates (the offline build has no
//! external crates beyond `xla` + `anyhow`): PRNG, JSON, CLI parsing,
//! a benchmark harness and a property-testing harness.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
