//! Minimal JSON parser/serializer (no external crates offline).
//!
//! Consumed for two things: reading the AOT `artifacts/manifest.json`
//! written by `python/compile/aot.py`, and writing structured run records
//! (metrics, experiment summaries).  Supports the full JSON value grammar
//! with the usual escapes; numbers are held as `f64`.

// Toolchain-native twin of lint rule R3: this parser sees daemon-client
// bytes, so it must never panic.  docs/LINT.md.
#![warn(clippy::unwrap_used, clippy::expect_used, clippy::panic)]

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]...` traversal; returns None on any miss.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- construction helpers ---------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // -- serialization ----------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Inf literal; emit null.
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

pub(crate) fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    // Named `eat`, not `expect`, so hostile-input call sites stay
    // trivially greppable from Result::expect (lint rule R3).
    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b.get(self.i..).is_some_and(|r| r.starts_with(word.as_bytes())) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(self.b.get(start..self.i).unwrap_or_default())
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let bytes = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or("bad \\u escape")?;
                            let hex = std::str::from_utf8(bytes)
                                .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let rest = std::str::from_utf8(self.b.get(self.i..).unwrap_or_default())
                        .map_err(|_| "invalid utf-8")?;
                    let c = rest.chars().next().ok_or("unterminated string")?;
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used, clippy::panic)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.path(&["c", "d"]).unwrap().as_f64(), Some(-2500.0));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parses_manifest_shape() {
        let src = r#"{"entries": {"coeff.eval": {"file": "coeff/eval.hlo.txt",
            "inputs": [{"shape": [64, 4], "dtype": "float32"}],
            "outputs": [{"shape": [], "dtype": "float32"}]}}}"#;
        let v = Json::parse(src).unwrap();
        let e = v.path(&["entries", "coeff.eval"]).unwrap();
        assert_eq!(e.get("file").unwrap().as_str(), Some("coeff/eval.hlo.txt"));
        let shape = e.path(&["inputs"]).unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        let dims: Vec<usize> = shape.iter().map(|d| d.as_usize().unwrap()).collect();
        assert_eq!(dims, vec![64, 4]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse(r#""λκ ψ — ok""#).unwrap();
        assert_eq!(v.as_str(), Some("λκ ψ — ok"));
    }
}
