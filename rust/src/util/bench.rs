//! Mini-criterion: a benchmark harness for the `harness = false` benches.
//!
//! No external bench framework builds offline, so this provides the core of
//! what the repo needs: warmup, timed iterations until a wall-clock budget,
//! and mean / p50 / p95 / throughput reporting with a stable text format
//! that EXPERIMENTS.md quotes.  Filters like `cargo bench -- <substring>`
//! are honoured.

// Wall-clock reads are this module's whole purpose (lint.toml R1 allow2).
#![allow(clippy::disallowed_methods)]

use std::time::{Duration, Instant};

pub struct Bencher {
    filter: Option<String>,
    /// (name, mean_ns) pairs for the summary table.
    results: Vec<(String, f64)>,
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: u32,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::from_env()
    }
}

impl Bencher {
    pub fn from_env() -> Bencher {
        // `cargo bench -- foo` passes "foo" through; also honour "--bench".
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with("--"));
        Bencher {
            filter,
            results: Vec::new(),
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 10,
        }
    }

    /// Fast profile for CI-ish runs (smaller budget).
    pub fn quick() -> Bencher {
        let mut b = Bencher::from_env();
        b.warmup = Duration::from_millis(50);
        b.budget = Duration::from_millis(400);
        b.min_iters = 5;
        b
    }

    fn selected(&self, name: &str) -> bool {
        match &self.filter {
            Some(f) => name.contains(f.as_str()),
            None => true,
        }
    }

    /// Benchmark a closure; returns the mean duration (or None if filtered
    /// out).  The closure should return something observable to keep the
    /// optimizer honest; its result is black-boxed here.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> Option<Duration> {
        if !self.selected(name) {
            return None;
        }
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // Timed samples.
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.budget || samples_ns.len() < self.min_iters as usize {
            let t0 = Instant::now();
            black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
            if samples_ns.len() > 100_000 {
                break;
            }
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
        let p = |q: f64| samples_ns[((samples_ns.len() - 1) as f64 * q) as usize];
        println!(
            "bench {name:44} {:>12} mean  {:>12} p50  {:>12} p95  ({} iters)",
            fmt_ns(mean),
            fmt_ns(p(0.50)),
            fmt_ns(p(0.95)),
            samples_ns.len()
        );
        self.results.push((name.to_string(), mean));
        Some(Duration::from_nanos(mean as u64))
    }

    /// Benchmark with a units-per-iteration throughput report.
    pub fn bench_throughput<T>(
        &mut self,
        name: &str,
        units: f64,
        unit_label: &str,
        f: impl FnMut() -> T,
    ) {
        if let Some(mean) = self.bench(name, f) {
            let per_sec = units / mean.as_secs_f64();
            println!("      └─ throughput: {per_sec:.3e} {unit_label}/s");
        }
    }

    pub fn finish(&self) {
        println!("\n{} benchmarks run", self.results.len());
    }
}

/// Identity function that defeats constant-folding (stable-rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut b = Bencher {
            filter: None,
            results: Vec::new(),
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(10),
            min_iters: 3,
        };
        let d = b.bench("noop", || 1 + 1).unwrap();
        assert!(d.as_nanos() > 0);
        assert_eq!(b.results.len(), 1);
    }

    #[test]
    fn filter_skips() {
        let mut b = Bencher {
            filter: Some("xyz".into()),
            results: Vec::new(),
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(5),
            min_iters: 1,
        };
        assert!(b.bench("abc", || ()).is_none());
        assert!(b.bench("has_xyz_inside", || ()).is_some());
    }
}
