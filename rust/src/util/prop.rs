//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` random
//! inputs drawn from a deterministic seed sequence.  On failure it reports
//! the failing case's seed so the case can be replayed exactly with
//! `check_seed`.  Generators live on `Gen`, a thin wrapper over
//! [`crate::util::rng::Rng`] with value-space helpers.

use super::rng::Rng;

pub struct Gen {
    pub rng: Rng,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, len: usize, std: f32) -> Vec<f32> {
        (0..len).map(|_| self.rng.normal_f32(0.0, std)).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Run `f` over `cases` generated inputs; panic with a replayable seed on
/// the first failure (failures are signalled by `f` panicking or returning
/// an Err description).
pub fn check(name: &str, cases: u64, f: impl Fn(&mut Gen) -> Result<(), String>) {
    for case in 0..cases {
        let seed = 0xC2DFB ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen { rng: Rng::new(seed) };
        if let Err(msg) = f(&mut g) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case.
pub fn check_seed(name: &str, seed: u64, f: impl Fn(&mut Gen) -> Result<(), String>) {
    let mut g = Gen { rng: Rng::new(seed) };
    if let Err(msg) = f(&mut g) {
        panic!("property '{name}' failed (seed {seed:#x}): {msg}");
    }
}

/// Assertion helpers that return Err instead of panicking, so `check` can
/// attach the seed.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 50, |g| {
            let a = g.f32_in(-10.0, 10.0);
            let b = g.f32_in(-10.0, 10.0);
            ensure((a + b - (b + a)).abs() < 1e-6, "not commutative")
        });
    }

    #[test]
    #[should_panic(expected = "deliberate")]
    fn failing_property_reports() {
        check("fails", 10, |_| Err("deliberate".into()));
    }

    #[test]
    fn generators_in_bounds() {
        check("gen-bounds", 100, |g| {
            let n = g.usize_in(3, 17);
            ensure((3..=17).contains(&n), format!("usize_in out of bounds: {n}"))?;
            let v = g.vec_f32(n, -1.0, 1.0);
            ensure(v.len() == n, "wrong len")?;
            ensure(
                v.iter().all(|x| (-1.0..1.0).contains(x)),
                "f32 out of bounds",
            )
        });
    }
}
