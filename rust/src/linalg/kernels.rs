//! The dense arithmetic kernels behind every hot loop in the stack,
//! centralized here so the planned `std::simd` feature lands in one
//! module instead of ten (ROADMAP: SIMD + f32 + PGO).
//!
//! Every kernel is generic over [`Scalar`] and falls into one of two
//! classes with different bit-identity rules:
//!
//! * **Elementwise** kernels (axpy, scale, the gossip/tracker folds, the
//!   quantize/dequantize passes): each output element depends only on
//!   same-index inputs, so processing in fixed-width chunks cannot
//!   reassociate anything — the chunked form below is bit-identical to
//!   the naive loop while handing the autovectorizer provably
//!   independent lanes.
//! * **Reductions** ([`dot`], [`norm2_sq`], [`dist_sq`]): accumulate in
//!   `f64` in strict left-to-right element order.  These are *not*
//!   chunked — partial sums would reassociate the addition and change
//!   bits, and the golden traces pin the sequential order.
//!
//! The per-element expressions are verbatim transcriptions of the loops
//! they replaced (`linalg`, `compress`, `optim::{inner,refpoint,tracking}`,
//! `collective::mix_paid_into`); tests/hotpath.rs holds the
//! transcription bit-for-bit.

use super::scalar::Scalar;
use crate::util::rng::Rng;

/// Chunk width for the elementwise kernels.  Eight lanes cover a full
/// AVX2 register of f32 and two of f64; the remainder loop handles
/// tails.  Safe for elementwise ops only (no cross-lane dependencies).
const LANES: usize = 8;

/// Apply `f(&mut y[i], x[i])` over equal-length slices in LANES-wide
/// chunks plus a tail.  Bit-identical to the plain zip loop.
#[inline(always)]
fn zip2<S: Scalar>(y: &mut [S], x: &[S], f: impl Fn(&mut S, S)) {
    debug_assert_eq!(x.len(), y.len());
    let mut yc = y.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (ys, xs) in (&mut yc).zip(&mut xc) {
        for (yi, &xi) in ys.iter_mut().zip(xs) {
            f(yi, xi);
        }
    }
    for (yi, &xi) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        f(yi, xi);
    }
}

/// Apply `f(&mut o[i], a[i], b[i])` over equal-length slices in
/// LANES-wide chunks plus a tail.
#[inline(always)]
fn zip3<S: Scalar>(o: &mut [S], a: &[S], b: &[S], f: impl Fn(&mut S, S, S)) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), o.len());
    let mut oc = o.chunks_exact_mut(LANES);
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for ((os, xs), ys) in (&mut oc).zip(&mut ac).zip(&mut bc) {
        for ((oi, &xi), &yi) in os.iter_mut().zip(xs).zip(ys) {
            f(oi, xi, yi);
        }
    }
    for ((oi, &xi), &yi) in oc
        .into_remainder()
        .iter_mut()
        .zip(ac.remainder())
        .zip(bc.remainder())
    {
        f(oi, xi, yi);
    }
}

// ---------------------------------------------------------------------------
// level-1 BLAS (formerly inlined in linalg::mod)
// ---------------------------------------------------------------------------

/// `y += alpha * x`
#[inline]
pub fn axpy<S: Scalar>(alpha: S, x: &[S], y: &mut [S]) {
    zip2(y, x, |yi, xi| *yi += alpha * xi);
}

/// `y = x` (copy)
#[inline]
pub fn copy<S: Scalar>(x: &[S], y: &mut [S]) {
    y.copy_from_slice(x);
}

/// `x *= alpha`
#[inline]
pub fn scale<S: Scalar>(alpha: S, x: &mut [S]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// `out = a - b`
#[inline]
pub fn sub<S: Scalar>(a: &[S], b: &[S], out: &mut [S]) {
    zip3(out, a, b, |o, x, y| *o = x - y);
}

/// `a -= b`
#[inline]
pub fn sub_assign<S: Scalar>(a: &mut [S], b: &[S]) {
    zip2(a, b, |x, y| *x -= y);
}

/// `a += b`
#[inline]
pub fn add_assign<S: Scalar>(a: &mut [S], b: &[S]) {
    zip2(a, b, |x, y| *x += y);
}

/// Dot product with strict left-to-right `f64` accumulation (reduction:
/// never chunked — see the module docs).
#[inline]
pub fn dot<S: Scalar>(x: &[S], y: &[S]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| a.to_f64() * b.to_f64()).sum()
}

/// Squared Euclidean norm with strict left-to-right `f64` accumulation.
#[inline]
pub fn norm2_sq<S: Scalar>(x: &[S]) -> f64 {
    x.iter().map(|a| a.to_f64() * a.to_f64()).sum()
}

/// Euclidean norm.
#[inline]
pub fn norm2<S: Scalar>(x: &[S]) -> f64 {
    norm2_sq(x).sqrt()
}

/// `Σ (a[i] − b[i])²` in strict left-to-right `f64` accumulation — the
/// consensus-distance fold.
#[inline]
pub fn dist_sq<S: Scalar>(a: &[S], b: &[S]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x.to_f64() - y.to_f64()).powi(2))
        .sum()
}

// ---------------------------------------------------------------------------
// gossip / tracker folds (formerly inlined in optim::{inner,refpoint,
// tracking} and collective::Transport::mix_paid_into)
// ---------------------------------------------------------------------------

/// Gradient-descent step `x -= eta * g` (the inner-loop model update).
#[inline]
pub fn descent<S: Scalar>(eta: S, g: &[S], x: &mut [S]) {
    zip2(x, g, |xi, gi| *xi -= eta * gi);
}

/// Paid-mixing fold `out += w * (a − b)` — the gossip kernel: `a` is the
/// neighbour's row, `b` the receiver's snapshot, `w` the (already
/// γ-scaled) mixing weight.
#[inline]
pub fn weighted_diff_add<S: Scalar>(w: S, a: &[S], b: &[S], out: &mut [S]) {
    zip3(out, a, b, |o, x, y| *o += w * (x - y));
}

/// Tracker fold `s += new − old` (gradient-tracking recursion).
#[inline]
pub fn add_diff<S: Scalar>(new: &[S], old: &[S], s: &mut [S]) {
    zip3(s, new, old, |o, n, p| *o += n - p);
}

/// Reference-point mixing term `out += gamma * (hat_w − sw · hat)`
/// ([`crate::optim::RefPoint::add_mix_term`]).
#[inline]
pub fn ref_mix_term<S: Scalar>(gamma: S, sw: S, hat_w: &[S], hat: &[S], out: &mut [S]) {
    zip3(out, hat_w, hat, |o, hw, h| *o += gamma * (hw - sw * h));
}

/// Moving average toward the difference `a − b`:
/// `u ← (1−θ)·u + θ·(a − b)` (MA-DSBO's hypergradient tracker).
#[inline]
pub fn ema_diff<S: Scalar>(theta: S, a: &[S], b: &[S], u: &mut [S]) {
    let omt = S::ONE - theta;
    zip3(u, a, b, |ui, x, y| *ui = omt * *ui + theta * (x - y));
}

// ---------------------------------------------------------------------------
// payload expansion (formerly inlined in compress::message)
// ---------------------------------------------------------------------------

/// Overwrite `out[idx[j]] = val[j]`, silently dropping indices beyond
/// `out.len()` — a decoded index can exceed the receiver's dim on
/// hostile bytes; dropping beats panicking (R3).  `out` is NOT zeroed.
#[inline]
pub fn scatter_write<S: Scalar>(idx: &[u32], val: &[S], out: &mut [S]) {
    for (&i, &x) in idx.iter().zip(val) {
        debug_assert!((i as usize) < out.len(), "sparse index {i} out of range");
        if let Some(o) = out.get_mut(i as usize) {
            *o = x;
        }
    }
}

/// `target[idx[j]] += w * val[j]` with the same hostile-index guard.
#[inline]
pub fn scatter_add_scaled<S: Scalar>(w: S, idx: &[u32], val: &[S], target: &mut [S]) {
    for (&i, &x) in idx.iter().zip(val) {
        debug_assert!((i as usize) < target.len(), "sparse index {i} out of range");
        if let Some(t) = target.get_mut(i as usize) {
            *t += w * x;
        }
    }
}

/// `target += w * v` over the zipped prefix (dense payload fold; a
/// hostile dense payload may claim a different length than the
/// receiver's buffer, so this zips instead of asserting).
#[inline]
pub fn dense_add_scaled<S: Scalar>(w: S, v: &[S], target: &mut [S]) {
    for (t, &x) in target.iter_mut().zip(v) {
        *t += w * x;
    }
}

/// Dequantize `out[i] = codes[i] · scale` over the zipped prefix.
#[inline]
pub fn dequant_write<S: Scalar>(scale: S, codes: &[i16], out: &mut [S]) {
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = S::from_i16(c) * scale;
    }
}

/// Dequantize-accumulate `target[i] += codes[i] · scale`.
#[inline]
pub fn dequant_add<S: Scalar>(scale: S, codes: &[i16], target: &mut [S]) {
    for (t, &c) in target.iter_mut().zip(codes) {
        *t += S::from_i16(c) * scale;
    }
}

// ---------------------------------------------------------------------------
// compression passes (formerly inlined in compress::mod)
// ---------------------------------------------------------------------------

/// QSGD stochastic quantization pass: fills `codes` with signed level
/// codes for `v` and returns the vector norm used as the shared scale.
/// One Bernoulli draw per coordinate, in index order (the RNG draw
/// sequence is part of the golden contract).  `codes` is cleared first.
/// Caller guarantees `norm > 0` (the zero-vector fast path never gets
/// here) and `levels ≤ i16::MAX`.
#[inline]
pub fn qsgd_quantize<S: Scalar>(
    v: &[S],
    norm: S,
    levels: u32,
    codes: &mut Vec<i16>,
    rng: &mut Rng,
) {
    let s = S::from_u32(levels);
    codes.clear();
    for &x in v {
        let u = x.abs() / norm * s; // in [0, s]
        let lo = u.floor();
        let level = lo
            + if rng.bernoulli((u - lo).to_f64()) {
                S::ONE
            } else {
                S::ZERO
            };
        // Signed code in [−s, s]; Qsgd::new bounds s to the i16 range.
        let code = (level * x.signum()).to_f64() as i16;
        codes.push(code);
    }
}

/// k-th largest value (0-based) of `xs` by magnitude-descending order —
/// the top-k threshold pass.  Median-of-three quickselect; comparisons
/// assume finite inputs (the top-k compressor falls back to dense on
/// non-finite vectors before calling this).
pub fn quickselect_desc<S: Scalar>(xs: &mut [S], k: usize) -> S {
    let n = xs.len();
    assert!(k < n);
    let (mut lo, mut hi) = (0usize, n - 1);
    loop {
        if lo == hi {
            return xs[lo];
        }
        // Median-of-three pivot for adversarial orderings.
        let mid = lo + (hi - lo) / 2;
        let (a, b, c) = (xs[lo], xs[mid], xs[hi]);
        let pivot = if (a >= b) == (b >= c) {
            b
        } else if (b >= a) == (a >= c) {
            a
        } else {
            c
        };
        let (mut i, mut j) = (lo, hi);
        while i <= j {
            while xs[i] > pivot {
                i += 1;
            }
            while xs[j] < pivot {
                j -= 1;
            }
            if i <= j {
                xs.swap(i, j);
                i += 1;
                if j == 0 {
                    break;
                }
                j -= 1;
            }
        }
        if k <= j {
            hi = j;
        } else if k >= i {
            lo = i;
        } else {
            return xs[k];
        }
    }
}

/// Top-k selection: quickselect on `|v|` (in the reusable `scratch`) for
/// the threshold, then count strictly-above entries and gather in one
/// ascending pass — everything above the threshold plus the first
/// (k − count) ties in index order, so indices are canonical ascending
/// by construction.  Appends to `idx`/`val` (caller clears).
pub fn topk_select<S: Scalar>(
    v: &[S],
    k: usize,
    scratch: &mut Vec<S>,
    idx: &mut Vec<u32>,
    val: &mut Vec<S>,
) {
    scratch.clear();
    scratch.extend(v.iter().map(|x| x.abs()));
    let thresh = quickselect_desc(scratch, k - 1);
    let n_gt = v.iter().filter(|x| x.abs() > thresh).count();
    let mut ties_left = k - n_gt;
    for (i, &x) in v.iter().enumerate() {
        let a = x.abs();
        if a > thresh {
            idx.push(i as u32);
            val.push(x);
        } else if a == thresh && ties_left > 0 {
            ties_left -= 1;
            idx.push(i as u32);
            val.push(x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The chunked elementwise kernels must be bit-identical to the
    /// naive zip loops at every length straddling the LANES boundary.
    #[test]
    fn chunked_matches_naive_at_all_tail_lengths() {
        for n in 0..=(3 * LANES + 1) {
            let x: Vec<f32> = (0..n).map(|i| (i as f32) * 0.37 - 1.5).collect();
            let y0: Vec<f32> = (0..n).map(|i| (i as f32) * -0.11 + 0.5).collect();

            let mut y = y0.clone();
            axpy(0.625f32, &x, &mut y);
            let naive: Vec<f32> = y0.iter().zip(&x).map(|(yi, xi)| yi + 0.625 * xi).collect();
            assert_eq!(y, naive, "axpy n={n}");

            let mut o = vec![0.0f32; n];
            sub(&x, &y0, &mut o);
            let naive: Vec<f32> = x.iter().zip(&y0).map(|(a, b)| a - b).collect();
            assert_eq!(o, naive, "sub n={n}");

            let mut s = y0.clone();
            add_diff(&x, &o, &mut s);
            let naive: Vec<f32> = y0
                .iter()
                .zip(&x)
                .zip(&o)
                .map(|((si, ni), pi)| si + (ni - pi))
                .collect();
            assert_eq!(s, naive, "add_diff n={n}");
        }
    }

    #[test]
    fn folds_match_their_formulas() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [0.5f32, 1.0, -1.0];
        let mut out = [10.0f32, 20.0, 30.0];
        weighted_diff_add(2.0f32, &a, &b, &mut out);
        assert_eq!(out, [11.0, 22.0, 38.0]);

        let mut x = [1.0f32, 1.0];
        descent(0.5f32, &[2.0, -2.0], &mut x);
        assert_eq!(x, [0.0, 2.0]);

        let mut o = [0.0f32; 2];
        ref_mix_term(0.5f32, 2.0f32, &[4.0, 8.0], &[1.0, 2.0], &mut o);
        // o += 0.5 * (hw − 2h) = 0.5·(4−2), 0.5·(8−4)
        assert_eq!(o, [1.0, 2.0]);
    }

    #[test]
    fn reductions_accumulate_sequentially_in_f64() {
        let x = [1.0f32, 2.0, 3.0];
        assert_eq!(dot(&x, &x), 14.0);
        assert_eq!(norm2_sq(&x), 14.0);
        assert!((norm2(&x) - 14f64.sqrt()).abs() < 1e-12);
        assert_eq!(dist_sq(&x, &[0.0, 0.0, 0.0]), 14.0);
        // f64 path too.
        let y = [1.0f64, 2.0, 3.0];
        assert_eq!(dot(&y, &y), 14.0);
    }

    #[test]
    fn scatter_guards_hostile_indices() {
        let mut out = [0.0f32; 3];
        scatter_write(&[0, 2, 9], &[1.0, 2.0, 3.0], &mut out);
        assert_eq!(out, [1.0, 0.0, 2.0], "index 9 dropped, not panicked");
        let mut t = [1.0f32; 3];
        scatter_add_scaled(2.0, &[1, 7], &[3.0, 9.0], &mut t);
        assert_eq!(t, [1.0, 7.0, 1.0]);
    }

    #[test]
    fn dequant_roundtrip() {
        let mut out = [0.0f32; 3];
        dequant_write(2.0f32, &[4, -2, 0], &mut out);
        assert_eq!(out, [8.0, -4.0, 0.0]);
        dequant_add(1.0f32, &[1, 1, 1], &mut out);
        assert_eq!(out, [9.0, -3.0, 1.0]);
    }

    #[test]
    fn quickselect_generic_matches_sort_f64() {
        let mut rng = Rng::new(11);
        for _ in 0..50 {
            let n = 1 + rng.below(200);
            let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let k = rng.below(n);
            let got = quickselect_desc(&mut v.clone(), k);
            v.sort_by(|a, b| b.partial_cmp(a).unwrap());
            assert_eq!(got, v[k]);
        }
    }

    #[test]
    fn topk_select_canonical_ascending_with_ties() {
        let v = [1.0f32; 10];
        let (mut scratch, mut idx, mut val) = (Vec::new(), Vec::new(), Vec::new());
        topk_select(&v, 3, &mut scratch, &mut idx, &mut val);
        assert_eq!(idx, vec![0, 1, 2]);
        assert_eq!(val, vec![1.0; 3]);
    }
}
