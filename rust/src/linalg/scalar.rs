//! The sealed scalar-type layer: every module that touches payload data
//! (vectors on the wire, compressor buffers, inner-loop state, task
//! oracles) is generic over [`Scalar`], implemented by exactly `f32` and
//! `f64`.
//!
//! `f32` is the repo's historical storage/wire type and stays the
//! default — the goldens, the hotpath transcription test and the sweep
//! byte-identity suite all pin the `f32` path bit-for-bit.  `f64` is the
//! high-precision mode selected with `dtype = "f64"` (CLI `--dtype`): it
//! doubles every payload byte on the wire and every state byte in memory
//! in exchange for ~1e-16 relative rounding instead of ~1e-7.  Type
//! erasure happens exactly once, at the `Runner` boundary
//! ([`crate::coordinator`]), so `sim`, `daemon` and `obs` stay
//! monomorphic.
//!
//! The trait is sealed: downstream code may assume the two-impl closed
//! world (e.g. the wire-tag space in [`crate::compress::message`] or the
//! dtype dispatch in the coordinator) without defensive handling of
//! hypothetical third scalar types.

/// The payload element type of a run, as named in config/CLI/sweep axes.
/// This is the *erased* (runtime) twin of the [`Scalar`] type parameter.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Dtype {
    /// 4 bytes per coordinate on the wire; the default.
    #[default]
    F32,
    /// 8 bytes per coordinate on the wire; high-precision mode.
    F64,
}

impl Dtype {
    pub fn name(&self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::F64 => "f64",
        }
    }

    /// Wire bytes per coordinate.
    pub fn bytes(&self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::F64 => 8,
        }
    }

    pub fn parse(s: &str) -> Result<Dtype, String> {
        match s {
            "f32" | "float" | "single" => Ok(Dtype::F32),
            "f64" | "double" => Ok(Dtype::F64),
            _ => Err(format!("unknown dtype: {s} (expected \"f32\" or \"f64\")")),
        }
    }
}

impl std::fmt::Display for Dtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

mod private {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for f64 {}
}

/// Payload scalar: the element type of everything that crosses the wire
/// or sits in per-node numeric state.  Sealed; implemented by `f32` and
/// `f64` only.
///
/// Contract notes (load-bearing for bit-identity, see docs/DTYPE.md):
///
/// * All conversions (`from_f64`, `from_i16`, …) are single native
///   casts — generic code written as `S::from_f64(x)` produces exactly
///   the same bits the historical `x as f32` sites did.
/// * Math methods (`abs`, `sqrt`, `exp`, …) forward to the native float
///   method of the same name, never to a widened `f64` round-trip, so
///   the `f32` path's last-ulp behaviour is unchanged by the refactor.
/// * Reductions are *not* part of this trait: dot products and norms
///   accumulate in `f64` for both dtypes (see [`crate::linalg::kernels`]).
pub trait Scalar:
    private::Sealed
    + Copy
    + Default
    + PartialEq
    + PartialOrd
    + Send
    + Sync
    + std::fmt::Debug
    + std::fmt::Display
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::Div<Output = Self>
    + std::ops::Neg<Output = Self>
    + std::ops::AddAssign
    + std::ops::SubAssign
    + std::ops::MulAssign
    + std::ops::DivAssign
    + 'static
{
    const ZERO: Self;
    const ONE: Self;
    /// Machine epsilon of the concrete type.
    const EPSILON: Self;
    const NEG_INFINITY: Self;
    /// Wire bytes per coordinate (4 / 8); must agree with [`Dtype::bytes`].
    const BYTES: usize;
    /// The erased runtime tag for this type.
    const DTYPE: Dtype;
    /// Added to the payload-kind byte to form the wire tag
    /// (`0` for f32 → tags 0..=3, `4` for f64 → tags 4..=7); see
    /// [`crate::compress::message`].
    const WIRE_OFFSET: u8;
    /// Human name, matching [`Dtype::name`].
    const NAME: &'static str;
    /// Default relative tolerance when comparing a run in this dtype
    /// against an f64 reference (the docs/DTYPE.md envelope policy).
    const REL_TOL: f64;

    fn from_f64(x: f64) -> Self;
    fn to_f64(self) -> f64;
    fn from_i16(x: i16) -> Self;
    fn from_u32(x: u32) -> Self;
    fn from_usize(x: usize) -> Self;

    fn abs(self) -> Self;
    fn sqrt(self) -> Self;
    fn exp(self) -> Self;
    fn ln(self) -> Self;
    fn powi(self, n: i32) -> Self;
    fn floor(self) -> Self;
    fn signum(self) -> Self;
    fn max(self, other: Self) -> Self;
    fn min(self, other: Self) -> Self;
    fn is_finite(self) -> bool;

    /// Append the little-endian wire encoding (`Self::BYTES` bytes).
    fn write_le(self, out: &mut Vec<u8>);
    /// Decode from exactly `Self::BYTES` little-endian bytes; `None` on a
    /// wrong-length slice (hostile input — never panics).
    fn read_le(bytes: &[u8]) -> Option<Self>;
}

impl Scalar for f32 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const EPSILON: Self = f32::EPSILON;
    const NEG_INFINITY: Self = f32::NEG_INFINITY;
    const BYTES: usize = 4;
    const DTYPE: Dtype = Dtype::F32;
    const WIRE_OFFSET: u8 = 0;
    const NAME: &'static str = "f32";
    const REL_TOL: f64 = 1e-3;

    #[inline(always)]
    fn from_f64(x: f64) -> Self {
        x as f32
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }

    #[inline(always)]
    fn from_i16(x: i16) -> Self {
        x as f32
    }

    #[inline(always)]
    fn from_u32(x: u32) -> Self {
        x as f32
    }

    #[inline(always)]
    fn from_usize(x: usize) -> Self {
        x as f32
    }

    #[inline(always)]
    fn abs(self) -> Self {
        f32::abs(self)
    }

    #[inline(always)]
    fn sqrt(self) -> Self {
        f32::sqrt(self)
    }

    #[inline(always)]
    fn exp(self) -> Self {
        f32::exp(self)
    }

    #[inline(always)]
    fn ln(self) -> Self {
        f32::ln(self)
    }

    #[inline(always)]
    fn powi(self, n: i32) -> Self {
        f32::powi(self, n)
    }

    #[inline(always)]
    fn floor(self) -> Self {
        f32::floor(self)
    }

    #[inline(always)]
    fn signum(self) -> Self {
        f32::signum(self)
    }

    #[inline(always)]
    fn max(self, other: Self) -> Self {
        f32::max(self, other)
    }

    #[inline(always)]
    fn min(self, other: Self) -> Self {
        f32::min(self, other)
    }

    #[inline(always)]
    fn is_finite(self) -> bool {
        f32::is_finite(self)
    }

    #[inline(always)]
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_bits().to_le_bytes());
    }

    #[inline(always)]
    fn read_le(bytes: &[u8]) -> Option<Self> {
        let b: [u8; 4] = bytes.try_into().ok()?;
        Some(f32::from_bits(u32::from_le_bytes(b)))
    }
}

impl Scalar for f64 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const EPSILON: Self = f64::EPSILON;
    const NEG_INFINITY: Self = f64::NEG_INFINITY;
    const BYTES: usize = 8;
    const DTYPE: Dtype = Dtype::F64;
    const WIRE_OFFSET: u8 = 4;
    const NAME: &'static str = "f64";
    const REL_TOL: f64 = 1e-9;

    #[inline(always)]
    fn from_f64(x: f64) -> Self {
        x
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }

    #[inline(always)]
    fn from_i16(x: i16) -> Self {
        x as f64
    }

    #[inline(always)]
    fn from_u32(x: u32) -> Self {
        x as f64
    }

    #[inline(always)]
    fn from_usize(x: usize) -> Self {
        x as f64
    }

    #[inline(always)]
    fn abs(self) -> Self {
        f64::abs(self)
    }

    #[inline(always)]
    fn sqrt(self) -> Self {
        f64::sqrt(self)
    }

    #[inline(always)]
    fn exp(self) -> Self {
        f64::exp(self)
    }

    #[inline(always)]
    fn ln(self) -> Self {
        f64::ln(self)
    }

    #[inline(always)]
    fn powi(self, n: i32) -> Self {
        f64::powi(self, n)
    }

    #[inline(always)]
    fn floor(self) -> Self {
        f64::floor(self)
    }

    #[inline(always)]
    fn signum(self) -> Self {
        f64::signum(self)
    }

    #[inline(always)]
    fn max(self, other: Self) -> Self {
        f64::max(self, other)
    }

    #[inline(always)]
    fn min(self, other: Self) -> Self {
        f64::min(self, other)
    }

    #[inline(always)]
    fn is_finite(self) -> bool {
        f64::is_finite(self)
    }

    #[inline(always)]
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_bits().to_le_bytes());
    }

    #[inline(always)]
    fn read_le(bytes: &[u8]) -> Option<Self> {
        let b: [u8; 8] = bytes.try_into().ok()?;
        Some(f64::from_bits(u64::from_le_bytes(b)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parse_and_names() {
        assert_eq!(Dtype::parse("f32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("f64").unwrap(), Dtype::F64);
        assert_eq!(Dtype::parse("double").unwrap(), Dtype::F64);
        assert!(Dtype::parse("f16").is_err());
        assert_eq!(Dtype::F32.name(), "f32");
        assert_eq!(Dtype::default(), Dtype::F32, "f32 is the bit-identity default");
        assert_eq!(Dtype::F32.bytes(), <f32 as Scalar>::BYTES);
        assert_eq!(Dtype::F64.bytes(), <f64 as Scalar>::BYTES);
    }

    #[test]
    fn casts_match_native() {
        // The whole bit-identity argument rests on these being single
        // native casts.
        assert_eq!(<f32 as Scalar>::from_f64(0.1), 0.1f64 as f32);
        assert_eq!(<f32 as Scalar>::from_i16(-321), -321.0f32);
        assert_eq!(<f32 as Scalar>::from_usize(7), 7.0f32);
        assert_eq!(<f64 as Scalar>::from_f64(0.1), 0.1);
        assert_eq!(1.5f32.to_f64(), 1.5f64);
    }

    #[test]
    fn wire_roundtrip_both_dtypes() {
        fn check<S: Scalar>(vals: &[f64]) {
            for &x in vals {
                let s = S::from_f64(x);
                let mut b = Vec::new();
                s.write_le(&mut b);
                assert_eq!(b.len(), S::BYTES);
                assert_eq!(S::read_le(&b), Some(s));
            }
            assert_eq!(S::read_le(&[0u8; 3]), None, "wrong length must be clean");
        }
        check::<f32>(&[0.0, -1.5, 1e30, 0.1]);
        check::<f64>(&[0.0, -1.5, 1e300, 0.1]);
    }

    #[test]
    fn wire_offsets_partition_the_tag_space() {
        assert_eq!(<f32 as Scalar>::WIRE_OFFSET, 0);
        assert_eq!(<f64 as Scalar>::WIRE_OFFSET, 4);
    }
}
