//! Contiguous per-node matrices for the coordination hot path.
//!
//! The inner loop (Algorithm 2) and the trackers keep one d-vector per
//! node.  Backing those with `Vec<Vec<f32>>` scatters the rows across the
//! heap and forces an allocation every time a batch is rebuilt; a
//! [`NodeBlock`] is one m×d row-major allocation with row views, so
//! per-step rebuilds are `copy_from_slice` into storage that already
//! exists and neighbouring rows share cache lines.
//!
//! The [`Rows`]/[`RowsMut`] traits abstract "m stacked d-vectors" so the
//! paid gossip-mixing kernels
//! ([`Transport::mix_paid_into`](crate::collective::Transport::mix_paid_into))
//! work identically over a `NodeBlock` and over the legacy `[Vec<f32>]`
//! representation the algorithm iterates still use at their API surface.

/// Read access to m stacked rows of dimension d.
pub trait Rows {
    fn nrows(&self) -> usize;
    fn dim(&self) -> usize;
    fn row(&self, i: usize) -> &[f32];
}

/// Mutable access to m stacked rows of dimension d.
pub trait RowsMut: Rows {
    fn row_mut(&mut self, i: usize) -> &mut [f32];
}

impl Rows for [Vec<f32>] {
    fn nrows(&self) -> usize {
        self.len()
    }

    fn dim(&self) -> usize {
        self.first().map_or(0, |r| r.len())
    }

    fn row(&self, i: usize) -> &[f32] {
        &self[i]
    }
}

impl RowsMut for [Vec<f32>] {
    fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self[i]
    }
}

/// One contiguous row-major m×d `f32` matrix holding a per-node vector per
/// row.  All row accessors are allocation-free; the only methods that
/// allocate are the explicit conversions ([`NodeBlock::to_vecs`],
/// [`NodeBlock::mean_row`]).
#[derive(Clone, Debug, PartialEq)]
pub struct NodeBlock {
    m: usize,
    d: usize,
    data: Vec<f32>,
}

impl Default for NodeBlock {
    fn default() -> Self {
        NodeBlock::zeros(0, 0)
    }
}

impl NodeBlock {
    pub fn zeros(m: usize, d: usize) -> NodeBlock {
        NodeBlock { m, d, data: vec![0.0; m * d] }
    }

    pub fn from_rows(rows: &[Vec<f32>]) -> NodeBlock {
        let mut b = NodeBlock::zeros(rows.nrows(), rows.dim());
        b.copy_from_rows(rows);
        b
    }

    pub fn nrows(&self) -> usize {
        self.m
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.d..(i + 1) * self.d]
    }

    /// Iterate all rows in node order.
    pub fn rows(&self) -> impl Iterator<Item = &[f32]> {
        self.data.chunks_exact(self.d.max(1))
    }

    /// Re-shape to m×d, keeping the backing storage (no allocation once
    /// capacity covers the largest shape ever used).  Newly grown storage
    /// is zeroed; existing contents are unspecified — callers overwrite.
    pub fn reset(&mut self, m: usize, d: usize) {
        self.m = m;
        self.d = d;
        self.data.clear();
        self.data.resize(m * d, 0.0);
    }

    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    /// Copy all rows from stacked vectors of matching shape.
    pub fn copy_from_rows(&mut self, rows: &[Vec<f32>]) {
        debug_assert_eq!(rows.nrows(), self.m);
        for (i, r) in rows.iter().enumerate() {
            self.row_mut(i).copy_from_slice(r);
        }
    }

    /// Copy from another block of identical shape.
    pub fn copy_from(&mut self, other: &NodeBlock) {
        debug_assert_eq!((self.m, self.d), (other.m, other.d));
        self.data.copy_from_slice(&other.data);
    }

    /// Node-average row (allocates; evaluation cadence only).
    pub fn mean_row(&self) -> Vec<f32> {
        assert!(self.m > 0);
        let mut out = vec![0.0f32; self.d];
        for r in self.rows() {
            super::add_assign(&mut out, r);
        }
        super::scale(1.0 / self.m as f32, &mut out);
        out
    }

    /// Frobenius-norm² consensus error `‖X − 1·x̄‖²` (allocates the mean;
    /// evaluation cadence only).
    pub fn consensus_err_sq(&self) -> f64 {
        let mean = self.mean_row();
        self.rows()
            .map(|r| {
                r.iter()
                    .zip(&mean)
                    .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
                    .sum::<f64>()
            })
            .sum()
    }

    /// Convert to the legacy stacked-vector representation (allocates).
    pub fn to_vecs(&self) -> Vec<Vec<f32>> {
        self.rows().map(<[f32]>::to_vec).collect()
    }
}

impl Rows for NodeBlock {
    fn nrows(&self) -> usize {
        self.m
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn row(&self, i: usize) -> &[f32] {
        NodeBlock::row(self, i)
    }
}

impl RowsMut for NodeBlock {
    fn row_mut(&mut self, i: usize) -> &mut [f32] {
        NodeBlock::row_mut(self, i)
    }
}

impl std::ops::Index<usize> for NodeBlock {
    type Output = [f32];

    fn index(&self, i: usize) -> &[f32] {
        self.row(i)
    }
}

impl std::ops::IndexMut<usize> for NodeBlock {
    fn index_mut(&mut self, i: usize) -> &mut [f32] {
        self.row_mut(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_and_indexing() {
        let mut b = NodeBlock::zeros(3, 2);
        b.row_mut(1).copy_from_slice(&[1.0, 2.0]);
        assert_eq!(b.row(0), &[0.0, 0.0]);
        assert_eq!(&b[1], &[1.0, 2.0]);
        b[2][0] = 5.0;
        assert_eq!(b.row(2), &[5.0, 0.0]);
        assert_eq!(b.rows().count(), 3);
    }

    #[test]
    fn from_rows_roundtrip() {
        let rows = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        let b = NodeBlock::from_rows(&rows);
        assert_eq!(b.to_vecs(), rows);
        assert_eq!(b.nrows(), 2);
        assert_eq!(b.dim(), 2);
    }

    #[test]
    fn mean_and_consensus_match_vec_versions() {
        let rows = vec![vec![1.0, 0.0], vec![3.0, 4.0]];
        let b = NodeBlock::from_rows(&rows);
        assert_eq!(b.mean_row(), super::super::mean_rows(&rows));
        assert!((b.consensus_err_sq() - super::super::consensus_err_sq(&rows)).abs() < 1e-12);
    }

    #[test]
    fn reset_reshapes_without_shrinking_capacity() {
        let mut b = NodeBlock::zeros(4, 8);
        let cap = b.data.capacity();
        b.reset(2, 3);
        assert_eq!((b.nrows(), b.dim()), (2, 3));
        assert_eq!(b.data.len(), 6);
        assert!(b.data.capacity() >= cap.min(32));
        b.reset(4, 8);
        assert_eq!(b.data.len(), 32);
        assert_eq!(b.data.capacity(), cap, "reset must reuse storage");
    }

    #[test]
    fn rows_trait_on_slices() {
        let rows = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        let s: &[Vec<f32>] = &rows;
        assert_eq!(s.nrows(), 2);
        assert_eq!(s.dim(), 2);
        assert_eq!(s.row(1), &[3.0, 4.0]);
    }
}
