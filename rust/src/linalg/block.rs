//! Contiguous per-node matrices for the coordination hot path.
//!
//! The inner loop (Algorithm 2) and the trackers keep one d-vector per
//! node.  Backing those with `Vec<Vec<S>>` scatters the rows across the
//! heap and forces an allocation every time a batch is rebuilt; a
//! [`NodeBlock`] is one m×d row-major allocation with row views, so
//! per-step rebuilds are `copy_from_slice` into storage that already
//! exists and neighbouring rows share cache lines.
//!
//! The [`Rows`]/[`RowsMut`] traits abstract "m stacked d-vectors" so the
//! paid gossip-mixing kernels
//! ([`Transport::mix_paid_into`](crate::collective::Transport::mix_paid_into))
//! work identically over a `NodeBlock` and over the legacy `[Vec<S>]`
//! representation the algorithm iterates still use at their API surface.
//! Everything here is generic over the payload [`Scalar`] (default
//! `f32`, the wire dtype).

use super::scalar::Scalar;

/// Read access to m stacked rows of dimension d.
pub trait Rows<S: Scalar = f32> {
    fn nrows(&self) -> usize;
    fn dim(&self) -> usize;
    fn row(&self, i: usize) -> &[S];
}

/// Mutable access to m stacked rows of dimension d.
pub trait RowsMut<S: Scalar = f32>: Rows<S> {
    fn row_mut(&mut self, i: usize) -> &mut [S];
}

impl<S: Scalar> Rows<S> for [Vec<S>] {
    fn nrows(&self) -> usize {
        self.len()
    }

    fn dim(&self) -> usize {
        self.first().map_or(0, |r| r.len())
    }

    fn row(&self, i: usize) -> &[S] {
        &self[i]
    }
}

impl<S: Scalar> RowsMut<S> for [Vec<S>] {
    fn row_mut(&mut self, i: usize) -> &mut [S] {
        &mut self[i]
    }
}

/// One contiguous row-major m×d matrix holding a per-node vector per
/// row.  All row accessors are allocation-free; the only methods that
/// allocate are the explicit conversions ([`NodeBlock::to_vecs`],
/// [`NodeBlock::mean_row`]).
#[derive(Clone, Debug, PartialEq)]
pub struct NodeBlock<S: Scalar = f32> {
    m: usize,
    d: usize,
    data: Vec<S>,
}

impl<S: Scalar> Default for NodeBlock<S> {
    fn default() -> Self {
        NodeBlock::zeros(0, 0)
    }
}

impl<S: Scalar> NodeBlock<S> {
    pub fn zeros(m: usize, d: usize) -> NodeBlock<S> {
        NodeBlock { m, d, data: vec![S::ZERO; m * d] }
    }

    pub fn from_rows(rows: &[Vec<S>]) -> NodeBlock<S> {
        let mut b = NodeBlock::zeros(rows.nrows(), rows.dim());
        b.copy_from_rows(rows);
        b
    }

    pub fn nrows(&self) -> usize {
        self.m
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[S] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [S] {
        &mut self.data[i * self.d..(i + 1) * self.d]
    }

    /// Iterate all rows in node order.
    pub fn rows(&self) -> impl Iterator<Item = &[S]> {
        self.data.chunks_exact(self.d.max(1))
    }

    /// Re-shape to m×d, keeping the backing storage (no allocation once
    /// capacity covers the largest shape ever used).  Newly grown storage
    /// is zeroed; existing contents are unspecified — callers overwrite.
    pub fn reset(&mut self, m: usize, d: usize) {
        self.m = m;
        self.d = d;
        self.data.clear();
        self.data.resize(m * d, S::ZERO);
    }

    pub fn fill(&mut self, v: S) {
        self.data.fill(v);
    }

    /// Copy all rows from stacked vectors of matching shape.
    pub fn copy_from_rows(&mut self, rows: &[Vec<S>]) {
        debug_assert_eq!(rows.nrows(), self.m);
        for (i, r) in rows.iter().enumerate() {
            self.row_mut(i).copy_from_slice(r);
        }
    }

    /// Copy from another block of identical shape.
    pub fn copy_from(&mut self, other: &NodeBlock<S>) {
        debug_assert_eq!((self.m, self.d), (other.m, other.d));
        self.data.copy_from_slice(&other.data);
    }

    /// Node-average row (allocates; evaluation cadence only).
    pub fn mean_row(&self) -> Vec<S> {
        assert!(self.m > 0);
        let mut out = vec![S::ZERO; self.d];
        for r in self.rows() {
            super::add_assign(&mut out, r);
        }
        super::scale(S::ONE / S::from_usize(self.m), &mut out);
        out
    }

    /// Frobenius-norm² consensus error `‖X − 1·x̄‖²` (allocates the mean;
    /// evaluation cadence only).
    pub fn consensus_err_sq(&self) -> f64 {
        let mean = self.mean_row();
        self.rows().map(|r| super::kernels::dist_sq(r, &mean)).sum()
    }

    /// Convert to the legacy stacked-vector representation (allocates).
    pub fn to_vecs(&self) -> Vec<Vec<S>> {
        self.rows().map(<[S]>::to_vec).collect()
    }
}

impl<S: Scalar> Rows<S> for NodeBlock<S> {
    fn nrows(&self) -> usize {
        self.m
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn row(&self, i: usize) -> &[S] {
        NodeBlock::row(self, i)
    }
}

impl<S: Scalar> RowsMut<S> for NodeBlock<S> {
    fn row_mut(&mut self, i: usize) -> &mut [S] {
        NodeBlock::row_mut(self, i)
    }
}

impl<S: Scalar> std::ops::Index<usize> for NodeBlock<S> {
    type Output = [S];

    fn index(&self, i: usize) -> &[S] {
        self.row(i)
    }
}

impl<S: Scalar> std::ops::IndexMut<usize> for NodeBlock<S> {
    fn index_mut(&mut self, i: usize) -> &mut [S] {
        self.row_mut(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_and_indexing() {
        let mut b = NodeBlock::<f32>::zeros(3, 2);
        b.row_mut(1).copy_from_slice(&[1.0, 2.0]);
        assert_eq!(b.row(0), &[0.0, 0.0]);
        assert_eq!(&b[1], &[1.0, 2.0]);
        b[2][0] = 5.0;
        assert_eq!(b.row(2), &[5.0, 0.0]);
        assert_eq!(b.rows().count(), 3);
    }

    #[test]
    fn from_rows_roundtrip() {
        let rows = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        let b = NodeBlock::from_rows(&rows);
        assert_eq!(b.to_vecs(), rows);
        assert_eq!(b.nrows(), 2);
        assert_eq!(b.dim(), 2);
    }

    #[test]
    fn f64_block_works_identically() {
        let rows = vec![vec![1.0f64, 2.0], vec![3.0, 4.0]];
        let b = NodeBlock::from_rows(&rows);
        assert_eq!(b.to_vecs(), rows);
        assert_eq!(b.mean_row(), vec![2.0, 3.0]);
    }

    #[test]
    fn mean_and_consensus_match_vec_versions() {
        let rows = vec![vec![1.0f32, 0.0], vec![3.0, 4.0]];
        let b = NodeBlock::from_rows(&rows);
        assert_eq!(b.mean_row(), super::super::mean_rows(&rows));
        assert!((b.consensus_err_sq() - super::super::consensus_err_sq(&rows)).abs() < 1e-12);
    }

    #[test]
    fn reset_reshapes_without_shrinking_capacity() {
        let mut b = NodeBlock::<f32>::zeros(4, 8);
        let cap = b.data.capacity();
        b.reset(2, 3);
        assert_eq!((b.nrows(), b.dim()), (2, 3));
        assert_eq!(b.data.len(), 6);
        assert!(b.data.capacity() >= cap.min(32));
        b.reset(4, 8);
        assert_eq!(b.data.len(), 32);
        assert_eq!(b.data.capacity(), cap, "reset must reuse storage");
    }

    #[test]
    fn rows_trait_on_slices() {
        let rows = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        let s: &[Vec<f32>] = &rows;
        assert_eq!(s.nrows(), 2);
        assert_eq!(s.dim(), 2);
        assert_eq!(s.row(1), &[3.0, 4.0]);
    }
}
