//! Dense linear algebra substrate.
//!
//! The coordinator's per-round math — gossip mixing, gradient tracking,
//! compression residuals — is all level-1 BLAS on [`Scalar`] vectors
//! (`f32` by default, `f64` in high-precision mode; see docs/DTYPE.md)
//! plus a little dense `f64` matrix work for the mixing matrices (doubly
//! stochastic checks, spectral gap via a cyclic Jacobi eigensolver).
//!
//! The actual loops live in [`kernels`]; the free functions here are
//! thin generic re-exports kept for call-site ergonomics.

pub mod block;
pub mod kernels;
pub mod matrix;
pub mod scalar;

pub use block::{NodeBlock, Rows, RowsMut};
pub use matrix::MatF64;
pub use scalar::{Dtype, Scalar};

// ---------------------------------------------------------------------------
// vector kernels (the L3 hot path) — generic fronts over linalg::kernels
// ---------------------------------------------------------------------------

/// `y += alpha * x`
#[inline]
pub fn axpy<S: Scalar>(alpha: S, x: &[S], y: &mut [S]) {
    kernels::axpy(alpha, x, y);
}

/// `y = x` (copy)
#[inline]
pub fn copy<S: Scalar>(x: &[S], y: &mut [S]) {
    kernels::copy(x, y);
}

/// `x *= alpha`
#[inline]
pub fn scale<S: Scalar>(alpha: S, x: &mut [S]) {
    kernels::scale(alpha, x);
}

/// Dot product with f64 accumulation.
#[inline]
pub fn dot<S: Scalar>(x: &[S], y: &[S]) -> f64 {
    kernels::dot(x, y)
}

/// Squared Euclidean norm (f64 accumulation).
#[inline]
pub fn norm2_sq<S: Scalar>(x: &[S]) -> f64 {
    kernels::norm2_sq(x)
}

/// Euclidean norm.
#[inline]
pub fn norm2<S: Scalar>(x: &[S]) -> f64 {
    kernels::norm2(x)
}

/// `out = a - b`
#[inline]
pub fn sub<S: Scalar>(a: &[S], b: &[S], out: &mut [S]) {
    kernels::sub(a, b, out);
}

/// `a -= b`
#[inline]
pub fn sub_assign<S: Scalar>(a: &mut [S], b: &[S]) {
    kernels::sub_assign(a, b);
}

/// `a += b`
#[inline]
pub fn add_assign<S: Scalar>(a: &mut [S], b: &[S]) {
    kernels::add_assign(a, b);
}

/// Mean of m stacked vectors of dimension d (`rows` is row-major m×d).
pub fn mean_rows<S: Scalar>(rows: &[Vec<S>]) -> Vec<S> {
    assert!(!rows.is_empty());
    let d = rows[0].len();
    let mut out = vec![S::ZERO; d];
    for r in rows {
        add_assign(&mut out, r);
    }
    scale(S::ONE / S::from_usize(rows.len()), &mut out);
    out
}

/// Frobenius-norm² of the consensus error `‖X − 1·x̄‖²` of stacked rows.
pub fn consensus_err_sq<S: Scalar>(rows: &[Vec<S>]) -> f64 {
    let mean = mean_rows(rows);
    rows.iter().map(|r| kernels::dist_sq(r, &mean)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_dot_norm() {
        let x = vec![1.0f32, 2.0, 3.0];
        let mut y = vec![10.0f32, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        assert_eq!(dot(&x, &x), 14.0);
        assert!((norm2(&x) - 14f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn axpy_dot_norm_f64() {
        let x = vec![1.0f64, 2.0, 3.0];
        let mut y = vec![10.0f64, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        assert_eq!(dot(&x, &x), 14.0);
    }

    #[test]
    fn mean_and_consensus() {
        let rows = vec![vec![1.0f32, 0.0], vec![3.0, 4.0]];
        assert_eq!(mean_rows(&rows), vec![2.0, 2.0]);
        // ‖(−1,−2)‖² + ‖(1,2)‖² = 5 + 5
        assert!((consensus_err_sq(&rows) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn consensus_zero_when_equal() {
        let rows = vec![vec![5.0f32; 8]; 4];
        assert!(consensus_err_sq(&rows) < 1e-12);
    }

    #[test]
    fn sub_ops() {
        let a = vec![5.0f32, 7.0];
        let b = vec![2.0f32, 3.0];
        let mut out = vec![0.0f32; 2];
        sub(&a, &b, &mut out);
        assert_eq!(out, vec![3.0, 4.0]);
        let mut c = a.clone();
        sub_assign(&mut c, &b);
        assert_eq!(c, out);
        add_assign(&mut c, &b);
        assert_eq!(c, a);
    }
}
