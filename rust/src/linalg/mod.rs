//! Dense linear algebra substrate.
//!
//! The coordinator's per-round math — gossip mixing, gradient tracking,
//! compression residuals — is all level-1 BLAS on `f32` vectors plus a
//! little dense `f64` matrix work for the mixing matrices (doubly
//! stochastic checks, spectral gap via a cyclic Jacobi eigensolver).

pub mod block;
pub mod matrix;

pub use block::{NodeBlock, Rows, RowsMut};
pub use matrix::MatF64;

// ---------------------------------------------------------------------------
// f32 vector kernels (the L3 hot path)
// ---------------------------------------------------------------------------

/// `y += alpha * x`
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `y = x` (copy)
#[inline]
pub fn copy(x: &[f32], y: &mut [f32]) {
    y.copy_from_slice(x);
}

/// `x *= alpha`
#[inline]
pub fn scale(alpha: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Dot product with f64 accumulation.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| *a as f64 * *b as f64).sum()
}

/// Squared Euclidean norm (f64 accumulation).
#[inline]
pub fn norm2_sq(x: &[f32]) -> f64 {
    x.iter().map(|a| *a as f64 * *a as f64).sum()
}

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f32]) -> f64 {
    norm2_sq(x).sqrt()
}

/// `out = a - b`
#[inline]
pub fn sub(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = x - y;
    }
}

/// `a -= b`
#[inline]
pub fn sub_assign(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b) {
        *x -= y;
    }
}

/// `a += b`
#[inline]
pub fn add_assign(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b) {
        *x += y;
    }
}

/// Mean of m stacked vectors of dimension d (`rows` is row-major m×d).
pub fn mean_rows(rows: &[Vec<f32>]) -> Vec<f32> {
    assert!(!rows.is_empty());
    let d = rows[0].len();
    let mut out = vec![0.0f32; d];
    for r in rows {
        add_assign(&mut out, r);
    }
    scale(1.0 / rows.len() as f32, &mut out);
    out
}

/// Frobenius-norm² of the consensus error `‖X − 1·x̄‖²` of stacked rows.
pub fn consensus_err_sq(rows: &[Vec<f32>]) -> f64 {
    let mean = mean_rows(rows);
    rows.iter()
        .map(|r| {
            r.iter()
                .zip(&mean)
                .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
                .sum::<f64>()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_dot_norm() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        assert_eq!(dot(&x, &x), 14.0);
        assert!((norm2(&x) - 14f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn mean_and_consensus() {
        let rows = vec![vec![1.0, 0.0], vec![3.0, 4.0]];
        assert_eq!(mean_rows(&rows), vec![2.0, 2.0]);
        // ‖(−1,−2)‖² + ‖(1,2)‖² = 5 + 5
        assert!((consensus_err_sq(&rows) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn consensus_zero_when_equal() {
        let rows = vec![vec![5.0; 8]; 4];
        assert!(consensus_err_sq(&rows) < 1e-12);
    }

    #[test]
    fn sub_ops() {
        let a = vec![5.0, 7.0];
        let b = vec![2.0, 3.0];
        let mut out = vec![0.0; 2];
        sub(&a, &b, &mut out);
        assert_eq!(out, vec![3.0, 4.0]);
        let mut c = a.clone();
        sub_assign(&mut c, &b);
        assert_eq!(c, out);
        add_assign(&mut c, &b);
        assert_eq!(c, a);
    }
}
