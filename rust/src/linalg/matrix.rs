//! Small dense `f64` matrices: mixing-matrix algebra and a cyclic Jacobi
//! eigensolver (the mixing matrices are symmetric, m ≤ a few hundred, so
//! Jacobi is simple, robust and plenty fast).

#[derive(Clone, Debug, PartialEq)]
pub struct MatF64 {
    pub n: usize,
    /// Row-major n×n storage.
    pub a: Vec<f64>,
}

impl MatF64 {
    pub fn zeros(n: usize) -> MatF64 {
        MatF64 { n, a: vec![0.0; n * n] }
    }

    pub fn identity(n: usize) -> MatF64 {
        let mut m = MatF64::zeros(n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> MatF64 {
        let n = rows.len();
        assert!(rows.iter().all(|r| r.len() == n), "not square");
        MatF64 { n, a: rows.iter().flatten().copied().collect() }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.n + j]
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.a[i * self.n..(i + 1) * self.n]
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        (0..self.n).map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum()).collect()
    }

    pub fn is_symmetric(&self, tol: f64) -> bool {
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                if (self.get(i, j) - self.get(j, i)).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Max |row sum − 1| and |col sum − 1|: 0 for a doubly stochastic matrix.
    pub fn doubly_stochastic_defect(&self) -> f64 {
        let mut worst: f64 = 0.0;
        for i in 0..self.n {
            let rs: f64 = self.row(i).iter().sum();
            let cs: f64 = (0..self.n).map(|j| self.get(j, i)).sum();
            worst = worst.max((rs - 1.0).abs()).max((cs - 1.0).abs());
        }
        worst
    }

    /// Eigenvalues of a symmetric matrix via cyclic Jacobi rotations,
    /// sorted descending.  Panics if not symmetric.
    pub fn symmetric_eigenvalues(&self) -> Vec<f64> {
        assert!(self.is_symmetric(1e-9), "Jacobi requires a symmetric matrix");
        let n = self.n;
        let mut a = self.clone();
        // Up to 30 sweeps; convergence is quadratic so this is generous.
        for _sweep in 0..30 {
            let mut off: f64 = 0.0;
            for i in 0..n {
                for j in (i + 1)..n {
                    off += a.get(i, j).powi(2);
                }
            }
            if off.sqrt() < 1e-12 {
                break;
            }
            for p in 0..n {
                for q in (p + 1)..n {
                    let apq = a.get(p, q);
                    if apq.abs() < 1e-15 {
                        continue;
                    }
                    let app = a.get(p, p);
                    let aqq = a.get(q, q);
                    let theta = (aqq - app) / (2.0 * apq);
                    let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                    let c = 1.0 / (t * t + 1.0).sqrt();
                    let s = t * c;
                    // Apply the rotation G(p,q,θ)ᵀ A G(p,q,θ) in place.
                    for k in 0..n {
                        let akp = a.get(k, p);
                        let akq = a.get(k, q);
                        a[(k, p)] = c * akp - s * akq;
                        a[(k, q)] = s * akp + c * akq;
                    }
                    for k in 0..n {
                        let apk = a.get(p, k);
                        let aqk = a.get(q, k);
                        a[(p, k)] = c * apk - s * aqk;
                        a[(q, k)] = s * apk + c * aqk;
                    }
                }
            }
        }
        let mut eig: Vec<f64> = (0..n).map(|i| a.get(i, i)).collect();
        eig.sort_by(|x, y| y.partial_cmp(x).unwrap());
        eig
    }

    /// Second-largest eigenvalue magnitude δ_ρ = max{|λ₂|, |λ_m|} of a
    /// doubly stochastic symmetric matrix (λ₁ = 1), per Definition 3.
    pub fn second_largest_eig_magnitude(&self) -> f64 {
        let eig = self.symmetric_eigenvalues();
        assert!(eig.len() >= 2, "need m >= 2");
        // λ₁ should be 1 for a mixing matrix; take the rest.
        eig[1].abs().max(eig[eig.len() - 1].abs())
    }

    /// Largest singular value squared of (W − I) — the ρ' constant in the
    /// paper's Lemma 4 — i.e. the largest eigenvalue of (W−I)ᵀ(W−I),
    /// which for symmetric W is max (λᵢ−1)².
    pub fn w_minus_i_norm_sq(&self) -> f64 {
        self.symmetric_eigenvalues()
            .iter()
            .map(|l| (l - 1.0).powi(2))
            .fold(0.0, f64::max)
    }
}

impl std::ops::Index<(usize, usize)> for MatF64 {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.a[i * self.n + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for MatF64 {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.a[i * self.n + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_eigenvalues() {
        let eig = MatF64::identity(5).symmetric_eigenvalues();
        for e in eig {
            assert!((e - 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let m = MatF64::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]);
        let eig = m.symmetric_eigenvalues();
        assert!((eig[0] - 3.0).abs() < 1e-10);
        assert!((eig[1] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn ring_mixing_spectrum() {
        // 4-ring with 1/3 self + 1/3 each neighbor... use W = I/2 + (P+Pᵀ)/4
        // for the 4-cycle: eigenvalues 1/2 + cos(2πk/4)/2 = {1, 1/2, 0, 1/2}.
        let n = 4;
        let mut w = MatF64::zeros(n);
        for i in 0..n {
            w[(i, i)] = 0.5;
            w[(i, (i + 1) % n)] += 0.25;
            w[(i, (i + n - 1) % n)] += 0.25;
        }
        assert!(w.doubly_stochastic_defect() < 1e-12);
        let eig = w.symmetric_eigenvalues();
        assert!((eig[0] - 1.0).abs() < 1e-10);
        assert!((eig[1] - 0.5).abs() < 1e-10);
        assert!(eig[3].abs() < 1e-10);
        assert!((w.second_largest_eig_magnitude() - 0.5).abs() < 1e-10);
    }

    #[test]
    fn matvec_works() {
        let m = MatF64::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
    }

    #[test]
    fn w_minus_i_norm() {
        let m = MatF64::identity(3);
        assert!(m.w_minus_i_norm_sq() < 1e-12);
    }
}
