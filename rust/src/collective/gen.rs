//! Generator-backed synchronous transport: [`GenNetwork`] answers every
//! [`Transport`] query from a [`GenTopology`] — no adjacency lists, no
//! m×m mixing matrix — so the fixed per-run footprint is O(m) (degrees
//! for the ledger) instead of O(m²).
//!
//! Semantics are exactly [`Network`](super::Network)'s: every message
//! from an active sender is delivered within the round, receivers see
//! senders ascending, the ledger and time model are identical, and
//! mixing weights are bitwise-equal Metropolis–Hastings values (the
//! [`GenTopology`] edge contract).  `tests/scale.rs` pins full-trajectory
//! bit-identity against the materialized path at small m.

use std::sync::Arc;

use super::{clear_delivered, dense_wire_bytes, Inbox, Transport};
use crate::compress::Compressed;
use crate::linalg::scalar::Scalar;
use crate::metrics::{CommLedger, TimeModel};
use crate::topology::{GenTopology, Neighborhood, Topology};

/// Synchronous in-process transport over an implicit topology.
pub struct GenNetwork {
    topo: GenTopology,
    m: usize,
    pub ledger: CommLedger,
    pub time_model: TimeModel,
    degrees: Vec<usize>,
    active: Option<Arc<Vec<bool>>>,
    /// Reusable neighbor buffer for delivery fan-out.
    nbrs: Vec<usize>,
}

impl GenNetwork {
    pub fn new(topo: GenTopology) -> GenNetwork {
        let m = topo.node_count();
        let degrees = (0..m).map(|i| topo.degree(i)).collect();
        GenNetwork {
            topo,
            m,
            ledger: CommLedger::default(),
            time_model: TimeModel::default(),
            degrees,
            active: None,
            nbrs: Vec::new(),
        }
    }

    /// Build straight from a [`Topology`] value; errors on variants with
    /// no generator form.
    pub fn build(topology: Topology, m: usize) -> Result<GenNetwork, String> {
        Ok(GenNetwork::new(GenTopology::new(topology, m)?))
    }

    pub fn topology(&self) -> &GenTopology {
        &self.topo
    }

    fn mask(&self) -> Option<&[bool]> {
        self.active.as_ref().map(|a| a.as_slice())
    }

    fn fan_out<T>(&mut self, msgs: Vec<T>) -> Inbox<T> {
        let mut inbox: Inbox<T> = vec![Vec::new(); self.m];
        let mut nbrs = std::mem::take(&mut self.nbrs);
        for (sender, msg) in msgs.into_iter().enumerate() {
            if let Some(mask) = self.mask() {
                if !mask[sender] {
                    continue;
                }
            }
            let msg = Arc::new(msg);
            self.topo.neighbors_into(sender, &mut nbrs);
            for &nb in &nbrs {
                inbox[nb].push((sender, msg.clone()));
            }
        }
        self.nbrs = nbrs;
        inbox
    }
}

impl Transport for GenNetwork {
    fn m(&self) -> usize {
        self.m
    }

    fn weight(&self, i: usize, j: usize) -> f64 {
        self.topo.mix_weight(i, j)
    }

    fn ledger(&self) -> &CommLedger {
        &self.ledger
    }

    fn set_active(&mut self, mask: Option<Arc<Vec<bool>>>) {
        if let Some(m) = &mask {
            assert_eq!(m.len(), self.m, "sampling mask length must equal node count");
        }
        self.active = mask;
    }

    fn active(&self) -> Option<&[bool]> {
        self.mask()
    }

    fn exchange<S: Scalar>(&mut self, msgs: Vec<Compressed<S>>) -> Inbox<Compressed<S>> {
        assert_eq!(msgs.len(), self.m);
        let bytes: Vec<usize> = msgs.iter().map(Compressed::wire_bytes).collect();
        self.ledger
            .record_round_active(&bytes, &self.degrees, self.mask(), &self.time_model);
        self.fan_out(msgs)
    }

    fn exchange_dense<S: Scalar>(&mut self, vecs: &[Vec<S>]) -> Inbox<Vec<S>> {
        assert_eq!(vecs.len(), self.m);
        let bytes: Vec<usize> = vecs.iter().map(|v| dense_wire_bytes::<S>(v.len())).collect();
        self.ledger
            .record_round_active(&bytes, &self.degrees, self.mask(), &self.time_model);
        self.fan_out(vecs.to_vec())
    }

    fn exchange_indices(&mut self, bytes: &[usize], delivered: &mut Vec<Vec<usize>>) {
        assert_eq!(bytes.len(), self.m);
        self.ledger
            .record_round_active(bytes, &self.degrees, self.mask(), &self.time_model);
        clear_delivered(delivered, self.m);
        let mut nbrs = std::mem::take(&mut self.nbrs);
        for sender in 0..self.m {
            if let Some(mask) = self.mask() {
                if !mask[sender] {
                    continue;
                }
            }
            self.topo.neighbors_into(sender, &mut nbrs);
            for &nb in &nbrs {
                delivered[nb].push(sender);
            }
        }
        self.nbrs = nbrs;
    }

    // mix_paid / mix_paid_into: trait defaults.  They fold delivered
    // messages with `weight()`, which is bitwise-equal to the
    // materialized MixingMatrix, and `Network`'s fast paths are pinned
    // equal to the same defaults — so all three agree exactly.
}

#[cfg(test)]
mod tests {
    use super::super::{MixScratch, Network};
    use super::*;
    use crate::topology::Graph;
    use crate::util::rng::Rng;

    fn pair(topology: Topology, m: usize) -> (Network, GenNetwork) {
        (
            Network::new(Graph::build(topology, m)),
            GenNetwork::build(topology, m).unwrap(),
        )
    }

    #[test]
    fn matches_materialized_network_bitwise() {
        for (topology, m) in [
            (Topology::Ring, 6),
            (Topology::Exponential, 9),
            (Topology::Torus, 12),
            (Topology::RandomRegular { k: 4, seed: 5 }, 11),
        ] {
            let (mut mat, mut gen) = pair(topology, m);
            let mut rng = Rng::new(17);
            let rows: Vec<Vec<f32>> = (0..m)
                .map(|_| (0..7).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                .collect();

            let a = mat.mix_paid(0.6, &rows);
            let b = gen.mix_paid(0.6, &rows);
            assert_eq!(a, b, "{topology:?} m={m}");
            assert_eq!(mat.ledger.total_bytes, gen.ledger.total_bytes);
            assert_eq!(mat.ledger.messages, gen.ledger.messages);
            assert_eq!(
                mat.ledger.network_time_s.to_bits(),
                gen.ledger.network_time_s.to_bits()
            );

            let bytes = vec![100usize; m];
            let (mut da, mut db) = (Vec::new(), Vec::new());
            mat.exchange_indices(&bytes, &mut da);
            gen.exchange_indices(&bytes, &mut db);
            assert_eq!(da, db);

            for i in 0..m {
                for j in 0..m {
                    assert_eq!(
                        Transport::weight(&mat, i, j).to_bits(),
                        Transport::weight(&gen, i, j).to_bits(),
                        "{topology:?} w[{i},{j}]"
                    );
                }
            }
        }
    }

    #[test]
    fn masked_paths_match_materialized() {
        let (mut mat, mut gen) = pair(Topology::Exponential, 10);
        let mask = Arc::new((0..10).map(|i| i % 3 != 1).collect::<Vec<bool>>());
        mat.set_active(Some(mask.clone()));
        gen.set_active(Some(mask.clone()));
        let rows: Vec<Vec<f32>> = (0..10).map(|i| vec![i as f32; 5]).collect();
        let a = mat.mix_paid(0.8, &rows);
        let b = gen.mix_paid(0.8, &rows);
        assert_eq!(a, b);
        assert_eq!(mat.ledger.total_bytes, gen.ledger.total_bytes);

        // The in-place masked kernel agrees with the allocating one.
        let mut sc = MixScratch::new();
        let mut inplace = rows.clone();
        gen.mix_paid_into(0.8, inplace.as_mut_slice(), &mut sc);
        assert_eq!(inplace, a);
    }

    #[test]
    fn exchange_fans_out_like_network() {
        let (mut mat, mut gen) = pair(Topology::Ring, 5);
        let rows: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32]).collect();
        let ia = mat.exchange_dense(&rows);
        let ib = gen.exchange_dense(&rows);
        for i in 0..5 {
            let sa: Vec<usize> = ia[i].iter().map(|(s, _)| *s).collect();
            let sb: Vec<usize> = ib[i].iter().map(|(s, _)| *s).collect();
            assert_eq!(sa, sb);
            for ((_, va), (_, vb)) in ia[i].iter().zip(&ib[i]) {
                assert_eq!(va.as_ref(), vb.as_ref());
            }
        }
    }
}
