//! Simulated decentralized network: synchronous gossip exchanges over a
//! topology, with exact per-message byte accounting and a latency/bandwidth
//! time model.
//!
//! The simulator is deterministic and in-process (the paper's testbed is 10
//! processes on one machine; its metrics — communication volume and
//! time-to-accuracy — depend on *what* is sent, which we account exactly,
//! not on real sockets).  One [`Network::exchange`] call = one
//! communication round in the paper's plots.

use crate::compress::Compressed;
use crate::metrics::{CommLedger, TimeModel};
use crate::topology::{Graph, MixingMatrix};

/// Messages delivered to each node: `(sender, payload)` pairs.
pub type Inbox<T> = Vec<Vec<(usize, T)>>;

pub struct Network {
    pub graph: Graph,
    pub mixing: MixingMatrix,
    pub ledger: CommLedger,
    pub time_model: TimeModel,
    degrees: Vec<usize>,
}

impl Network {
    pub fn new(graph: Graph) -> Network {
        let mixing = MixingMatrix::metropolis(&graph);
        let degrees = (0..graph.m).map(|i| graph.degree(i)).collect();
        Network {
            graph,
            mixing,
            ledger: CommLedger::default(),
            time_model: TimeModel::default(),
            degrees,
        }
    }

    pub fn m(&self) -> usize {
        self.graph.m
    }

    /// Gossip-broadcast one compressed message per node to all its
    /// neighbours.  Returns each node's inbox; bytes are recorded.
    pub fn exchange(&mut self, msgs: Vec<Compressed>) -> Inbox<Compressed> {
        assert_eq!(msgs.len(), self.m());
        let bytes: Vec<usize> = msgs.iter().map(Compressed::wire_bytes).collect();
        self.ledger.record_round(&bytes, &self.degrees, &self.time_model);
        let mut inbox: Inbox<Compressed> = vec![Vec::new(); self.m()];
        for (sender, msg) in msgs.into_iter().enumerate() {
            for &nb in self.graph.neighbors(sender) {
                inbox[nb].push((sender, msg.clone()));
            }
        }
        inbox
    }

    /// Gossip-broadcast dense vectors (uncompressed algorithms / the outer
    /// loop).  Returns the inbox of borrowed-by-clone vectors.
    pub fn exchange_dense(&mut self, vecs: &[Vec<f32>]) -> Inbox<Vec<f32>> {
        assert_eq!(vecs.len(), self.m());
        let bytes: Vec<usize> = vecs.iter().map(|v| 8 + 4 * v.len()).collect();
        self.ledger.record_round(&bytes, &self.degrees, &self.time_model);
        let mut inbox: Inbox<Vec<f32>> = vec![Vec::new(); self.m()];
        for (sender, v) in vecs.iter().enumerate() {
            for &nb in self.graph.neighbors(sender) {
                inbox[nb].push((sender, v.clone()));
            }
        }
        inbox
    }

    /// Dense gossip-mix step `rows_i + γ Σ_j w_ij (rows_j − rows_i)` that
    /// *also* pays for the communication (one dense exchange).  This is the
    /// outer-loop mixing of Algorithm 1 and the whole communication story
    /// of the uncompressed baselines.
    pub fn mix_paid(&mut self, gamma: f64, rows: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let inbox = self.exchange_dense(rows);
        let mut out = rows.to_vec();
        for (i, msgs) in inbox.into_iter().enumerate() {
            for (sender, v) in msgs {
                let w = (gamma * self.mixing.weight(i, sender)) as f32;
                for k in 0..v.len() {
                    out[i][k] += w * (v[k] - rows[i][k]);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, Identity, TopK};
    use crate::linalg;
    use crate::topology::Topology;
    use crate::util::rng::Rng;

    fn net(m: usize) -> Network {
        Network::new(Graph::build(Topology::Ring, m))
    }

    #[test]
    fn exchange_delivers_to_neighbors_only() {
        let mut n = net(5);
        let mut rng = Rng::new(1);
        let msgs: Vec<Compressed> = (0..5)
            .map(|i| Identity.compress(&[i as f32], &mut rng))
            .collect();
        let inbox = n.exchange(msgs);
        for i in 0..5 {
            let senders: Vec<usize> = inbox[i].iter().map(|(s, _)| *s).collect();
            let mut expect = vec![(i + 1) % 5, (i + 4) % 5];
            expect.sort_unstable();
            let mut got = senders.clone();
            got.sort_unstable();
            assert_eq!(got, expect);
        }
    }

    #[test]
    fn ledger_counts_compressed_vs_dense() {
        let d = 1000;
        let v = vec![1.0f32; d];
        let mut rng = Rng::new(2);

        let mut n1 = net(4);
        n1.exchange_dense(&vec![v.clone(); 4]);
        let dense_bytes = n1.ledger.total_bytes;

        let mut n2 = net(4);
        let msgs: Vec<Compressed> =
            (0..4).map(|_| TopK::new(0.1).compress(&v, &mut rng)).collect();
        n2.exchange(msgs);
        let sparse_bytes = n2.ledger.total_bytes;

        // top-10% of 1000 coords at 8B vs 4000B dense: ~5× saving.
        assert!(sparse_bytes * 4 < dense_bytes, "{sparse_bytes} vs {dense_bytes}");
        assert_eq!(n1.ledger.gossip_rounds, 1);
        assert_eq!(n1.ledger.messages, 8); // ring of 4: deg 2 each
    }

    #[test]
    fn mix_paid_preserves_mean_and_counts() {
        let mut n = net(6);
        let rows: Vec<Vec<f32>> = (0..6).map(|i| vec![i as f32; 3]).collect();
        let mixed = n.mix_paid(0.5, &rows);
        let m0 = linalg::mean_rows(&rows);
        let m1 = linalg::mean_rows(&mixed);
        for (a, b) in m0.iter().zip(&m1) {
            assert!((a - b).abs() < 1e-5);
        }
        assert!(n.ledger.total_bytes > 0);
        assert!(n.ledger.network_time_s > 0.0);
    }

    #[test]
    fn mix_paid_contracts_consensus() {
        let mut n = net(8);
        let rows: Vec<Vec<f32>> = (0..8).map(|i| vec![(i * i) as f32; 2]).collect();
        let e0 = linalg::consensus_err_sq(&rows);
        let mixed = n.mix_paid(1.0, &rows);
        assert!(linalg::consensus_err_sq(&mixed) < e0);
    }
}
