//! The communication layer: the [`Transport`] abstraction every algorithm
//! gossips through, and [`Network`] — the synchronous in-process transport
//! with exact per-message byte accounting and a latency/bandwidth time
//! model.
//!
//! The synchronous simulator is deterministic and in-process (the paper's
//! testbed is 10 processes on one machine; its metrics — communication
//! volume and time-to-accuracy — depend on *what* is sent, which we
//! account exactly, not on real sockets).  One [`Transport::exchange`]
//! call = one communication round in the paper's plots.
//!
//! [`crate::sim::SimNetwork`] implements the same trait with a
//! discrete-event engine (per-link latency/jitter, drops, stragglers,
//! time-varying topologies); algorithms are generic over [`Transport`] and
//! behave identically on either when the network is benign.
//!
//! Inbox payloads are [`Arc`]-shared: a broadcast message is allocated
//! once per sender and reference-counted per neighbour, so the dense
//! gossip hot path no longer clones every vector per edge.  The
//! compressed inner loop goes one step further through
//! [`Transport::exchange_indices`] — messages stay with the caller and
//! only (reused) sender-index lists cross the trait boundary — and
//! dense mixing has an in-place twin, [`Transport::mix_paid_into`],
//! with caller-owned [`MixScratch`] buffers; both are allocation-free
//! in steady state and bit-identical to their allocating counterparts.
//!
//! Payload-carrying methods are generic over the payload [`Scalar`] `S`
//! (`f32` wire default, `f64` high precision — docs/DTYPE.md); the
//! transport itself is dtype-agnostic, it only sees byte counts.

use crate::compress::Compressed;
use crate::linalg::scalar::Scalar;
use crate::linalg::{NodeBlock, RowsMut};
use crate::metrics::{CommLedger, TimeModel};
use crate::topology::{Graph, MixingMatrix};
use std::sync::Arc;

mod gen;

pub use gen::GenNetwork;

/// Messages delivered to each node: `(sender, payload)` pairs, in
/// ascending sender order.  Payloads are shared, not cloned per edge.
pub type Inbox<T> = Vec<Vec<(usize, Arc<T>)>>;

/// Exact wire size of a dense `S` vector message (8-byte header + data).
#[inline]
pub fn dense_wire_bytes<S: Scalar>(len: usize) -> usize {
    8 + S::BYTES * len
}

/// Fan a message set out to each sender's neighbours (shared payloads).
/// Receivers see senders in ascending order — a canonical order, so
/// downstream float reductions are reproducible across transports.
/// Senders that are inactive under `active` transmit nothing.
pub(crate) fn deliver<T>(graph: &Graph, msgs: Vec<T>, active: Option<&[bool]>) -> Inbox<T> {
    let mut inbox: Inbox<T> = vec![Vec::new(); graph.m];
    for (sender, msg) in msgs.into_iter().enumerate() {
        if let Some(mask) = active {
            if !mask[sender] {
                continue;
            }
        }
        let msg = Arc::new(msg);
        for &nb in graph.neighbors(sender) {
            inbox[nb].push((sender, msg.clone()));
        }
    }
    inbox
}

/// Shape `delivered` into m empty per-node sender lists, reusing the
/// existing allocations (the borrowing-exchange hot path).
pub(crate) fn clear_delivered(delivered: &mut Vec<Vec<usize>>, m: usize) {
    delivered.resize_with(m, Vec::new);
    for ib in delivered.iter_mut() {
        ib.clear();
    }
}

/// Reusable buffers for the in-place paid mixing kernel
/// ([`Transport::mix_paid_into`]): a contiguous snapshot of the pre-mix
/// rows, the per-sender byte sizes, and the delivered-sender lists.  Own
/// one per mixed variable and the steady state allocates nothing.
#[derive(Default)]
pub struct MixScratch<S: Scalar = f32> {
    prev: NodeBlock<S>,
    bytes: Vec<usize>,
    delivered: Vec<Vec<usize>>,
}

impl<S: Scalar> MixScratch<S> {
    pub fn new() -> MixScratch<S> {
        MixScratch::default()
    }
}

/// What an algorithm needs from a network: gossip exchanges that pay
/// communication, the mixing weights, and the cost ledger.
///
/// Implementations must deliver each message to every current neighbour
/// of its sender (minus whatever the transport's loss model eats) and
/// keep inboxes in ascending sender order.
///
/// Mixing weights are exposed as point queries ([`Transport::weight`])
/// rather than a materialized matrix, so generator-backed transports
/// ([`GenNetwork`]) can answer them in O(1) from degrees at million-node
/// scale.  Per-round node sampling plugs in through
/// [`Transport::set_active`]: an inactive node sends nothing and pays
/// nothing that round, while still receiving whatever its active
/// neighbours broadcast (docs/SCALE.md covers the semantics).
pub trait Transport {
    /// Number of nodes.
    fn m(&self) -> usize;
    /// Current gossip mixing weight w_ij (may change under a topology
    /// schedule).  `i == j` yields the self-weight, non-edges exactly 0.
    fn weight(&self, i: usize, j: usize) -> f64;
    /// Cumulative communication costs.
    fn ledger(&self) -> &CommLedger;

    /// Install (`Some`) or clear (`None`) the per-round sampling mask.
    /// While a mask is set, inactive senders transmit nothing and are
    /// charged nothing; delivery to *receivers* is unaffected (an
    /// inactive node still hears its active neighbours — the compressed
    /// inner loop needs this to keep reference points in sync).  The
    /// default ignores the mask: custom transports without sampling
    /// support keep every node active.
    fn set_active(&mut self, mask: Option<Arc<Vec<bool>>>) {
        let _ = mask;
    }

    /// The currently installed sampling mask, if any.
    fn active(&self) -> Option<&[bool]> {
        None
    }

    /// Gossip-broadcast one compressed message per node to all its
    /// neighbours.  Returns each node's inbox; bytes are recorded.
    fn exchange<S: Scalar>(&mut self, msgs: Vec<Compressed<S>>) -> Inbox<Compressed<S>>;

    /// The borrowing gossip round (the inner-loop hot path): pay
    /// `bytes[i]` per neighbour of node i and fill `delivered[i]` with the
    /// ascending sender indices whose messages reached node i.  Payloads
    /// never enter the transport — the caller keeps them and reads
    /// `&msgs[j]` for each delivered `j` — so no per-round `Arc`/`Vec`
    /// churn.  Ledger accounting, loss model and RNG consumption are
    /// identical to [`Transport::exchange`] with the same byte sizes.
    fn exchange_indices(&mut self, bytes: &[usize], delivered: &mut Vec<Vec<usize>>);

    /// In-place [`Transport::mix_paid`]: mixes `rows` (any [`RowsMut`]
    /// representation) against the delivered messages, snapshotting the
    /// pre-mix rows into `sc`.  Bit-identical to `mix_paid` on every
    /// transport (same fold expression, ascending sender order) but
    /// allocation-free in steady state.
    fn mix_paid_into<S: Scalar, R: RowsMut<S> + ?Sized>(
        &mut self,
        gamma: f64,
        rows: &mut R,
        sc: &mut MixScratch<S>,
    ) {
        let m = self.m();
        let d = rows.dim();
        debug_assert_eq!(rows.nrows(), m);
        sc.prev.reset(m, d);
        for i in 0..m {
            sc.prev.row_mut(i).copy_from_slice(rows.row(i));
        }
        sc.bytes.clear();
        sc.bytes.resize(m, dense_wire_bytes::<S>(d));
        self.exchange_indices(&sc.bytes, &mut sc.delivered);
        for i in 0..m {
            // Under a sampling mask only active nodes take the mix step;
            // inactive rows pass through unchanged (senders were already
            // filtered by the transport's exchange).
            if let Some(mask) = self.active() {
                if !mask[i] {
                    continue;
                }
            }
            let oi = rows.row_mut(i);
            let ri = sc.prev.row(i);
            for &j in &sc.delivered[i] {
                let w = S::from_f64(gamma * self.weight(i, j));
                crate::linalg::kernels::weighted_diff_add(w, sc.prev.row(j), ri, oi);
            }
        }
    }

    /// Gossip-broadcast dense vectors (uncompressed algorithms / the outer
    /// loop).
    fn exchange_dense<S: Scalar>(&mut self, vecs: &[Vec<S>]) -> Inbox<Vec<S>>;

    /// Dense gossip-mix step `rows_i + γ Σ_j w_ij (rows_j − rows_i)` that
    /// *also* pays for the communication (one dense exchange).  This is the
    /// outer-loop mixing of Algorithm 1 and the whole communication story
    /// of the uncompressed baselines.  The default implementation mixes
    /// with whatever the transport actually delivered, so message loss
    /// degrades consensus exactly as it would in a real deployment.
    fn mix_paid<S: Scalar>(&mut self, gamma: f64, rows: &[Vec<S>]) -> Vec<Vec<S>> {
        let inbox = self.exchange_dense(rows);
        let mut out = rows.to_vec();
        for (i, msgs) in inbox.into_iter().enumerate() {
            if let Some(mask) = self.active() {
                if !mask[i] {
                    continue;
                }
            }
            let ri = &rows[i];
            let oi = &mut out[i];
            for (sender, v) in msgs {
                let w = S::from_f64(gamma * self.weight(i, sender));
                crate::linalg::kernels::weighted_diff_add(w, &v, ri, oi);
            }
        }
        out
    }

    /// Monotone counter bumped whenever the communication graph (and so
    /// the mixing matrix) changes — time-varying topologies.  Constant on
    /// static transports.  Protocols that cache topology-derived state
    /// (the reference points) watch this to know when to resync.
    fn graph_epoch(&self) -> u64 {
        0
    }

    /// Total virtual (modeled) network time so far, seconds.
    fn virtual_time_s(&self) -> f64 {
        self.ledger().network_time_s
    }

    /// Per-message arrival records of the most recent exchange, for
    /// telemetry ([`Recorder::exchange`](crate::obs::Recorder::exchange)):
    /// per-edge delivered/dropped flags and sim-time arrival stamps.  Only
    /// the event engine has per-edge timing; the synchronous transport
    /// (and any custom transport) reports nothing via this default.
    fn last_events(&self) -> &[crate::sim::Arrival] {
        &[]
    }
}

/// Synchronous in-process transport: every message is delivered within the
/// round, time is modeled per round as latency + max-node-bytes/bandwidth.
pub struct Network {
    pub graph: Graph,
    pub mixing: MixingMatrix,
    pub ledger: CommLedger,
    pub time_model: TimeModel,
    degrees: Vec<usize>,
    active: Option<Arc<Vec<bool>>>,
}

impl Network {
    pub fn new(graph: Graph) -> Network {
        let mixing = MixingMatrix::metropolis(&graph);
        let degrees = (0..graph.m).map(|i| graph.degree(i)).collect();
        Network {
            graph,
            mixing,
            ledger: CommLedger::default(),
            time_model: TimeModel::default(),
            degrees,
            active: None,
        }
    }

    pub fn m(&self) -> usize {
        self.graph.m
    }

    fn mask(&self) -> Option<&[bool]> {
        self.active.as_ref().map(|a| a.as_slice())
    }

    /// See [`Transport::exchange`].
    pub fn exchange<S: Scalar>(&mut self, msgs: Vec<Compressed<S>>) -> Inbox<Compressed<S>> {
        assert_eq!(msgs.len(), self.m());
        let bytes: Vec<usize> = msgs.iter().map(Compressed::wire_bytes).collect();
        self.ledger
            .record_round_active(&bytes, &self.degrees, self.mask(), &self.time_model);
        deliver(&self.graph, msgs, self.mask())
    }

    /// See [`Transport::exchange_dense`].  One clone per sender (into the
    /// shared payload), not one per edge.
    pub fn exchange_dense<S: Scalar>(&mut self, vecs: &[Vec<S>]) -> Inbox<Vec<S>> {
        assert_eq!(vecs.len(), self.m());
        let bytes: Vec<usize> = vecs.iter().map(|v| dense_wire_bytes::<S>(v.len())).collect();
        self.ledger
            .record_round_active(&bytes, &self.degrees, self.mask(), &self.time_model);
        deliver(&self.graph, vecs.to_vec(), self.mask())
    }

    /// See [`Transport::mix_paid`].  The synchronous network delivers
    /// everything, so with no sampling mask it can skip payload
    /// materialization entirely: pay the bytes, then mix straight over
    /// the callers' rows (zero clones beyond the output).  Under a mask
    /// it folds explicitly — active receivers mix contributions from
    /// active neighbours only, inactive rows pass through — which is
    /// bit-identical to the trait default's masked fold.
    pub fn mix_paid<S: Scalar>(&mut self, gamma: f64, rows: &[Vec<S>]) -> Vec<Vec<S>> {
        assert_eq!(rows.len(), self.m());
        let bytes: Vec<usize> = rows.iter().map(|v| dense_wire_bytes::<S>(v.len())).collect();
        self.ledger
            .record_round_active(&bytes, &self.degrees, self.mask(), &self.time_model);
        let Some(mask) = self.active.clone() else {
            return self.mixing.mix(gamma, rows);
        };
        let mut out = rows.to_vec();
        for i in 0..self.m() {
            if !mask[i] {
                continue;
            }
            let oi = &mut out[i];
            for &j in self.graph.neighbors(i) {
                if !mask[j] {
                    continue;
                }
                let w = S::from_f64(gamma * self.mixing.weight(i, j));
                crate::linalg::kernels::weighted_diff_add(w, &rows[j], &rows[i], oi);
            }
        }
        out
    }

    /// See [`Transport::exchange_indices`]: every message from an active
    /// sender is delivered, so the sender lists are just the (ascending)
    /// neighbour relation filtered by the mask; only the ledger is
    /// touched.  Allocation-free once `delivered` is warm.
    pub fn exchange_indices(&mut self, bytes: &[usize], delivered: &mut Vec<Vec<usize>>) {
        assert_eq!(bytes.len(), self.m());
        self.ledger
            .record_round_active(bytes, &self.degrees, self.mask(), &self.time_model);
        clear_delivered(delivered, self.m());
        for sender in 0..self.m() {
            if let Some(mask) = self.mask() {
                if !mask[sender] {
                    continue;
                }
            }
            for &nb in self.graph.neighbors(sender) {
                delivered[nb].push(sender);
            }
        }
    }
}

impl Transport for Network {
    fn m(&self) -> usize {
        Network::m(self)
    }

    fn weight(&self, i: usize, j: usize) -> f64 {
        self.mixing.weight(i, j)
    }

    fn ledger(&self) -> &CommLedger {
        &self.ledger
    }

    fn set_active(&mut self, mask: Option<Arc<Vec<bool>>>) {
        if let Some(m) = &mask {
            assert_eq!(m.len(), self.m(), "sampling mask length must equal node count");
        }
        self.active = mask;
    }

    fn active(&self) -> Option<&[bool]> {
        self.mask()
    }

    fn exchange<S: Scalar>(&mut self, msgs: Vec<Compressed<S>>) -> Inbox<Compressed<S>> {
        Network::exchange(self, msgs)
    }

    fn exchange_dense<S: Scalar>(&mut self, vecs: &[Vec<S>]) -> Inbox<Vec<S>> {
        Network::exchange_dense(self, vecs)
    }

    fn exchange_indices(&mut self, bytes: &[usize], delivered: &mut Vec<Vec<usize>>) {
        Network::exchange_indices(self, bytes, delivered)
    }

    fn mix_paid<S: Scalar>(&mut self, gamma: f64, rows: &[Vec<S>]) -> Vec<Vec<S>> {
        Network::mix_paid(self, gamma, rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, Identity, TopK};
    use crate::linalg;
    use crate::topology::Topology;
    use crate::util::rng::Rng;

    fn net(m: usize) -> Network {
        Network::new(Graph::build(Topology::Ring, m))
    }

    #[test]
    fn exchange_delivers_to_neighbors_only() {
        let mut n = net(5);
        let mut rng = Rng::new(1);
        let msgs: Vec<Compressed<f32>> = (0..5)
            .map(|i| Identity.compress(&[i as f32], &mut rng))
            .collect();
        let inbox = n.exchange(msgs);
        for i in 0..5 {
            let senders: Vec<usize> = inbox[i].iter().map(|(s, _)| *s).collect();
            let mut expect = vec![(i + 1) % 5, (i + 4) % 5];
            expect.sort_unstable();
            // Inboxes arrive in ascending sender order.
            assert_eq!(senders, expect);
        }
    }

    #[test]
    fn inbox_payloads_are_shared_not_cloned() {
        let mut n = net(4);
        let rows: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32; 8]).collect();
        let inbox = n.exchange_dense(&rows);
        // Ring of 4: each message has 2 receivers sharing one allocation.
        let (s0, v0) = &inbox[1][0];
        assert_eq!(*s0, 0);
        assert_eq!(Arc::strong_count(v0), 2);
        assert_eq!(v0.as_ref(), &rows[0]);
    }

    #[test]
    fn ledger_counts_compressed_vs_dense() {
        let d = 1000;
        let v = vec![1.0f32; d];
        let mut rng = Rng::new(2);

        let mut n1 = net(4);
        n1.exchange_dense(&vec![v.clone(); 4]);
        let dense_bytes = n1.ledger.total_bytes;

        let mut n2 = net(4);
        let msgs: Vec<Compressed<f32>> =
            (0..4).map(|_| TopK::new(0.1).compress(&v, &mut rng)).collect();
        n2.exchange(msgs);
        let sparse_bytes = n2.ledger.total_bytes;

        // top-10% of 1000 coords at 8B vs 4000B dense: ~5× saving.
        assert!(sparse_bytes * 4 < dense_bytes, "{sparse_bytes} vs {dense_bytes}");
        assert_eq!(n1.ledger.gossip_rounds, 1);
        assert_eq!(n1.ledger.messages, 8); // ring of 4: deg 2 each
    }

    /// Dense f64 payloads cost exactly twice the value bytes of f32
    /// (same 8-byte header), straight from the dtype-aware wire size.
    #[test]
    fn dense_f64_exchange_doubles_value_bytes() {
        assert_eq!(dense_wire_bytes::<f32>(100), 8 + 400);
        assert_eq!(dense_wire_bytes::<f64>(100), 8 + 800);
        let rows32: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32; 100]).collect();
        let rows64: Vec<Vec<f64>> = (0..4).map(|i| vec![i as f64; 100]).collect();
        let mut n32 = net(4);
        n32.exchange_dense(&rows32);
        let mut n64 = net(4);
        n64.exchange_dense(&rows64);
        assert_eq!(n32.ledger.total_bytes, 8 * (8 + 400) as u64);
        assert_eq!(n64.ledger.total_bytes, 8 * (8 + 800) as u64);
    }

    #[test]
    fn mix_paid_preserves_mean_and_counts() {
        let mut n = net(6);
        let rows: Vec<Vec<f32>> = (0..6).map(|i| vec![i as f32; 3]).collect();
        let mixed = n.mix_paid(0.5, &rows);
        let m0 = linalg::mean_rows(&rows);
        let m1 = linalg::mean_rows(&mixed);
        for (a, b) in m0.iter().zip(&m1) {
            assert!((a - b).abs() < 1e-5);
        }
        assert!(n.ledger.total_bytes > 0);
        assert!(n.ledger.network_time_s > 0.0);
    }

    #[test]
    fn mix_paid_contracts_consensus() {
        let mut n = net(8);
        let rows: Vec<Vec<f32>> = (0..8).map(|i| vec![(i * i) as f32; 2]).collect();
        let e0 = linalg::consensus_err_sq(&rows);
        let mixed = n.mix_paid(1.0, &rows);
        assert!(linalg::consensus_err_sq(&mixed) < e0);
    }

    /// The inherent fast path and the trait's inbox-based default must
    /// agree bit-for-bit on a lossless transport (same neighbour order,
    /// same f32 arithmetic).
    #[test]
    fn mix_paid_fast_path_matches_trait_default() {
        struct DefaultOnly(Network);
        impl Transport for DefaultOnly {
            fn m(&self) -> usize {
                self.0.m()
            }
            fn weight(&self, i: usize, j: usize) -> f64 {
                self.0.mixing.weight(i, j)
            }
            fn ledger(&self) -> &CommLedger {
                &self.0.ledger
            }
            fn set_active(&mut self, mask: Option<Arc<Vec<bool>>>) {
                self.0.set_active(mask)
            }
            fn active(&self) -> Option<&[bool]> {
                Transport::active(&self.0)
            }
            fn exchange<S: Scalar>(&mut self, msgs: Vec<Compressed<S>>) -> Inbox<Compressed<S>> {
                self.0.exchange(msgs)
            }
            fn exchange_dense<S: Scalar>(&mut self, vecs: &[Vec<S>]) -> Inbox<Vec<S>> {
                self.0.exchange_dense(vecs)
            }
            fn exchange_indices(&mut self, bytes: &[usize], delivered: &mut Vec<Vec<usize>>) {
                self.0.exchange_indices(bytes, delivered)
            }
            // mix_paid: trait default (inbox-based).
        }

        let mut rng = Rng::new(3);
        let rows: Vec<Vec<f32>> = (0..7)
            .map(|_| (0..11).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let mut fast = net(7);
        let mut slow = DefaultOnly(net(7));
        let a = fast.mix_paid(0.7, &rows);
        let b = slow.mix_paid(0.7, &rows);
        assert_eq!(a, b);
        assert_eq!(fast.ledger.total_bytes, slow.0.ledger.total_bytes);
    }

    /// The borrowing exchange pays exactly what the Arc-based exchange
    /// pays and reports the same (ascending) sender sets.
    #[test]
    fn exchange_indices_matches_exchange_deliveries_and_ledger() {
        let mut rng = Rng::new(5);
        let msgs: Vec<Compressed<f32>> = (0..5)
            .map(|i| {
                let mut v = vec![0.0f32; 40 + 10 * i];
                rng.fill_normal(&mut v, 0.0, 1.0);
                TopK::new(0.3).compress(&v, &mut rng)
            })
            .collect();
        let bytes: Vec<usize> = msgs.iter().map(Compressed::wire_bytes).collect();

        let mut a = net(5);
        let inbox = a.exchange(msgs.clone());
        let mut b = net(5);
        // Dirty, wrongly-shaped buffer: must be reshaped and cleared.
        let mut delivered = vec![vec![9usize; 3]; 2];
        b.exchange_indices(&bytes, &mut delivered);

        assert_eq!(a.ledger.total_bytes, b.ledger.total_bytes);
        assert_eq!(a.ledger.messages, b.ledger.messages);
        assert_eq!(a.ledger.gossip_rounds, b.ledger.gossip_rounds);
        assert!((a.ledger.network_time_s - b.ledger.network_time_s).abs() < 1e-15);
        for i in 0..5 {
            let senders: Vec<usize> = inbox[i].iter().map(|(s, _)| *s).collect();
            assert_eq!(delivered[i], senders);
        }
    }

    /// In-place paid mixing is bit-identical to `mix_paid` on both a
    /// stacked-vector slice and a contiguous block, with equal ledgers.
    #[test]
    fn mix_paid_into_matches_mix_paid_bitwise() {
        use crate::linalg::NodeBlock;
        let mut rng = Rng::new(9);
        let rows: Vec<Vec<f32>> = (0..6)
            .map(|_| (0..17).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();

        let mut reference = net(6);
        let expect = reference.mix_paid(0.6, &rows);

        let mut sc = MixScratch::new();
        let mut inplace = rows.clone();
        let mut n1 = net(6);
        n1.mix_paid_into(0.6, inplace.as_mut_slice(), &mut sc);
        assert_eq!(inplace, expect);
        assert_eq!(n1.ledger.total_bytes, reference.ledger.total_bytes);

        let mut block = NodeBlock::from_rows(&rows);
        let mut n2 = net(6);
        n2.mix_paid_into(0.6, &mut block, &mut sc);
        assert_eq!(block.to_vecs(), expect);
        assert_eq!(n2.ledger.total_bytes, reference.ledger.total_bytes);
    }

    /// The generic mixing path works at f64 and agrees with a plain f64
    /// reference fold.
    #[test]
    fn mix_paid_f64_matches_reference_fold() {
        let mut rng = Rng::new(11);
        let rows: Vec<Vec<f64>> = (0..5)
            .map(|_| (0..9).map(|_| rng.normal()).collect())
            .collect();
        let mut n = net(5);
        let mixed = n.mix_paid(0.8, &rows);
        let mut expect = rows.clone();
        let mixing = MixingMatrix::metropolis(&Graph::build(Topology::Ring, 5));
        for i in 0..5 {
            for &(j, wij) in mixing.neighbors(i) {
                let c = 0.8 * wij;
                for k in 0..9 {
                    expect[i][k] += c * (rows[j][k] - rows[i][k]);
                }
            }
        }
        for (a, b) in mixed.iter().flatten().zip(expect.iter().flatten()) {
            assert!((a - b).abs() < 1e-15);
        }
    }

    /// Sampling semantics on the synchronous transport: inactive senders
    /// pay nothing and deliver nothing, inactive receivers pass through
    /// unchanged, and the masked fast path agrees with the masked trait
    /// default bit-for-bit.
    #[test]
    fn masked_exchange_and_mix_semantics() {
        let mask = Arc::new(vec![true, false, true, true, false, true]);
        let rows: Vec<Vec<f32>> = (0..6).map(|i| vec![i as f32 + 0.5; 4]).collect();

        // Delivery: only active senders appear in inboxes/delivered.
        let mut n = net(6);
        n.set_active(Some(mask.clone()));
        let inbox = n.exchange_dense(&rows);
        for (i, msgs) in inbox.iter().enumerate() {
            for (s, _) in msgs {
                assert!(mask[*s], "inactive sender {s} delivered to {i}");
            }
        }
        let mut delivered = Vec::new();
        let mut n2 = net(6);
        n2.set_active(Some(mask.clone()));
        n2.exchange_indices(&[dense_wire_bytes::<f32>(4); 6], &mut delivered);
        for senders in &delivered {
            assert!(senders.iter().all(|&s| mask[s]));
            assert!(senders.windows(2).all(|w| w[0] < w[1]));
        }
        // Ledger charges active senders only (4 of 6, degree 2 each).
        assert_eq!(n2.ledger.messages, 8);
        assert_eq!(n2.ledger.total_bytes, 4 * 2 * dense_wire_bytes::<f32>(4) as u64);

        // Masked fast path == masked trait default, inactive rows frozen.
        struct DefaultOnly(Network);
        impl Transport for DefaultOnly {
            fn m(&self) -> usize {
                self.0.m()
            }
            fn weight(&self, i: usize, j: usize) -> f64 {
                self.0.mixing.weight(i, j)
            }
            fn ledger(&self) -> &CommLedger {
                &self.0.ledger
            }
            fn set_active(&mut self, mask: Option<Arc<Vec<bool>>>) {
                self.0.set_active(mask)
            }
            fn active(&self) -> Option<&[bool]> {
                Transport::active(&self.0)
            }
            fn exchange<S: Scalar>(&mut self, msgs: Vec<Compressed<S>>) -> Inbox<Compressed<S>> {
                self.0.exchange(msgs)
            }
            fn exchange_dense<S: Scalar>(&mut self, vecs: &[Vec<S>]) -> Inbox<Vec<S>> {
                self.0.exchange_dense(vecs)
            }
            fn exchange_indices(&mut self, bytes: &[usize], delivered: &mut Vec<Vec<usize>>) {
                self.0.exchange_indices(bytes, delivered)
            }
        }
        let mut fast = net(6);
        fast.set_active(Some(mask.clone()));
        let a = fast.mix_paid(0.7, &rows);
        let mut slow = DefaultOnly(net(6));
        slow.set_active(Some(mask.clone()));
        let b = slow.mix_paid(0.7, &rows);
        assert_eq!(a, b);
        assert_eq!(fast.ledger.total_bytes, slow.0.ledger.total_bytes);
        for i in 0..6 {
            if !mask[i] {
                assert_eq!(a[i], rows[i], "inactive row {i} must not move");
            } else {
                assert_ne!(a[i], rows[i], "active row {i} should mix");
            }
        }
        // mix_paid_into honors the mask identically.
        let mut sc = MixScratch::new();
        let mut inplace = rows.clone();
        let mut n3 = net(6);
        n3.set_active(Some(mask.clone()));
        n3.mix_paid_into(0.7, inplace.as_mut_slice(), &mut sc);
        assert_eq!(inplace, a);

        // Clearing the mask restores the unmasked path exactly.
        let mut cleared = net(6);
        cleared.set_active(Some(mask));
        cleared.set_active(None);
        let mut plain = net(6);
        assert_eq!(cleared.mix_paid(0.7, &rows), plain.mix_paid(0.7, &rows));
    }
}
