//! `obs` — structured tracing, per-phase metrics, and deterministic run
//! telemetry.
//!
//! The paper's claims are resource claims (Õ(ε⁻⁴) first-order oracle
//! calls, compressed inner-loop traffic), but `RunMetrics` only reports
//! end-of-run aggregates.  This module records *where* inside a run the
//! bytes, oracle calls and simulated time go, without perturbing any of
//! the bit-reproducibility contracts:
//!
//! * [`Recorder`] — a cheap clonable handle threaded through
//!   [`RunContext`](crate::algorithms::RunContext) and
//!   [`InnerState`](crate::optim::InnerState).  The no-op recorder
//!   ([`Recorder::noop`], the default) is a `None` behind the handle:
//!   every instrumentation call is a single branch, no allocation — the
//!   zero-allocation steady-state contract of the inner loop is asserted
//!   *with a recorder attached* by `benches/inner_loop.rs`.
//! * A **deterministic JSONL sink** (`--trace out.jsonl`): one JSON object
//!   per line, stamped with counters and simulated time only — never wall
//!   clock.  Tracing consumes no RNG and never touches the
//!   [`CommLedger`](crate::metrics::CommLedger), so traced runs are
//!   bit-identical to untraced runs, and sweep traces are byte-identical
//!   at any `--jobs` width (per-cell buffers, flushed in declaration
//!   order — the docs/SWEEP.md cell-id contract).
//! * A **wall-clock phase profiler** (`--profile`): explicitly
//!   nondeterministic, reported separately ([`Recorder::render_profile`])
//!   and never written into the JSONL sink.
//! * [`Console`] — one place for harness verbosity (`--quiet` /
//!   `--verbose`) instead of scattered `println!`/`eprintln!`.
//! * [`summarize`] / [`validate_line`] — the engine behind `c2dfb trace
//!   <file>`: schema validation (rejecting any wall-clock field) and the
//!   per-phase cost table (bytes / oracle calls / sim-time by phase ×
//!   algorithm, plus per-node byte deciles).
//!
//! The span taxonomy, JSONL schema and determinism contract are
//! documented in `docs/OBS.md`.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::rc::Rc;
use std::time::Instant;

use crate::compress::Compressed;
use crate::compress::PayloadKind;
use crate::metrics::{CommLedger, OracleCounter, RunMetrics, TracePoint};
use crate::sim::Arrival;
use crate::util::json::Json;

/// JSONL trace format version (the `format` key of `run_start` lines).
pub const TRACE_FORMAT: u64 = 1;

/// Histogram width for payload-byte and latency histograms (log₂ buckets).
pub const HIST_BUCKETS: usize = 24;

/// Default JSONL buffer capacity.  Pre-sized so steady-state appends do
/// not reallocate for typical runs (round lines are ~120 bytes).
pub const DEFAULT_TRACE_CAPACITY: usize = 256 * 1024;

// ---------------------------------------------------------------------------
// span taxonomy
// ---------------------------------------------------------------------------

/// Which loop a recorded phase belongs to.  Inner-loop instrumentation
/// points live in `optim::inner`, which is generic over the y/z sequence —
/// the algorithm tags each [`InnerState`](crate::optim::InnerState) with a
/// scoped handle ([`Recorder::scoped`]) so the phases separate.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Scope {
    /// The outer loop (Algorithm 1) and everything not inside an `IN` call.
    #[default]
    Outer,
    /// The y-sequence inner loop (descending h = f + λg).
    InnerY,
    /// The z-sequence inner loop (descending g).
    InnerZ,
}

pub const N_SCOPES: usize = 3;

impl Scope {
    pub fn name(self) -> &'static str {
        match self {
            Scope::Outer => "outer",
            Scope::InnerY => "inner_y",
            Scope::InnerZ => "inner_z",
        }
    }

    fn idx(self) -> usize {
        match self {
            Scope::Outer => 0,
            Scope::InnerY => 1,
            Scope::InnerZ => 2,
        }
    }
}

const ALL_SCOPES: [Scope; N_SCOPES] = [Scope::Outer, Scope::InnerY, Scope::InnerZ];

/// What kind of work a span covers.  C²DFB uses `Init`, `Mix`,
/// `Compress`, `Exchange`, `Grad`, `Tracker`, `Hypergrad` and `Eval`;
/// the second-order baselines additionally attribute their coarse
/// sections to `Lower` (lower-level GD), `Hvp` (MADSBO's quadratic
/// sub-solver) and `Neumann` (MDBO's series).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// `BilevelAlgorithm::init` — state construction before round 0.
    Init,
    /// Gossip-mixing model iterates (outer x-mix, inner model update).
    Mix,
    /// Residual computation + compressor encode.
    Compress,
    /// A paid transport exchange (and the fold of delivered messages).
    Exchange,
    /// Lower-level gradient oracle batches.
    Grad,
    /// Gradient-tracker bookkeeping (s-updates).
    Tracker,
    /// Hypergradient assembly.
    Hypergrad,
    /// Baselines: the lower-level GD section.
    Lower,
    /// MADSBO: the tracked HVP quadratic sub-solver.
    Hvp,
    /// MDBO: the Neumann-series Hessian-inverse approximation.
    Neumann,
    /// Consensus evaluation (loss/accuracy on the averaged model).
    Eval,
}

pub const N_PHASES: usize = 11;

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Init => "init",
            Phase::Mix => "mix",
            Phase::Compress => "compress",
            Phase::Exchange => "exchange",
            Phase::Grad => "grad",
            Phase::Tracker => "tracker",
            Phase::Hypergrad => "hypergrad",
            Phase::Lower => "lower",
            Phase::Hvp => "hvp",
            Phase::Neumann => "neumann",
            Phase::Eval => "eval",
        }
    }

    fn idx(self) -> usize {
        match self {
            Phase::Init => 0,
            Phase::Mix => 1,
            Phase::Compress => 2,
            Phase::Exchange => 3,
            Phase::Grad => 4,
            Phase::Tracker => 5,
            Phase::Hypergrad => 6,
            Phase::Lower => 7,
            Phase::Hvp => 8,
            Phase::Neumann => 9,
            Phase::Eval => 10,
        }
    }
}

const ALL_PHASES: [Phase; N_PHASES] = [
    Phase::Init,
    Phase::Mix,
    Phase::Compress,
    Phase::Exchange,
    Phase::Grad,
    Phase::Tracker,
    Phase::Hypergrad,
    Phase::Lower,
    Phase::Hvp,
    Phase::Neumann,
    Phase::Eval,
];

// ---------------------------------------------------------------------------
// recorder
// ---------------------------------------------------------------------------

/// A copy of the [`CommLedger`] counters before a paid section, so the
/// recorder can attribute the delta.  Plain `Copy` data — taking a
/// snapshot never allocates.
#[derive(Clone, Copy, Debug, Default)]
pub struct LedgerSnap {
    pub bytes: u64,
    pub msgs: u64,
    pub dropped: u64,
    pub gossip: u64,
    pub sim_s: f64,
}

impl LedgerSnap {
    pub fn of(l: &CommLedger) -> LedgerSnap {
        LedgerSnap {
            bytes: l.total_bytes,
            msgs: l.messages,
            dropped: l.dropped_messages,
            gossip: l.gossip_rounds,
            sim_s: l.network_time_s,
        }
    }
}

/// Per-(scope, phase) aggregates.  `wall_ns` is profiler-only data and is
/// never written to the deterministic sink.
#[derive(Clone, Copy, Debug, Default)]
struct PhaseStat {
    count: u64,
    bytes: u64,
    msgs: u64,
    dropped: u64,
    oracles: u64,
    sim_s: f64,
    wall_ns: u64,
}

impl PhaseStat {
    fn is_zero(&self) -> bool {
        self.count == 0
    }
}

/// Per-compressor encode/decode counters + payload-byte histogram.
#[derive(Clone, Debug, Default)]
struct CompressStats {
    encodes: u64,
    decodes: u64,
    dense: u64,
    sparse: u64,
    quantized: u64,
    payload_hist: [u64; HIST_BUCKETS],
}

/// Per-edge delivery counters + sim-time latency histogram (event engine
/// only — the synchronous transport has no per-edge timing).
#[derive(Clone, Debug, Default)]
struct EdgeStats {
    delivered: u64,
    dropped: u64,
    queue_peak: u64,
    latency_hist: [u64; HIST_BUCKETS],
}

struct Inner {
    /// JSONL buffer; `None` when only profiling.
    buf: Option<String>,
    profile: bool,
    cell: Option<String>,
    algo: String,
    phase: [[PhaseStat; N_PHASES]; N_SCOPES],
    enc: CompressStats,
    edges: EdgeStats,
    node_bytes: Vec<u64>,
    resets: u64,
}

impl Inner {
    fn reset_run(&mut self) {
        self.phase = [[PhaseStat::default(); N_PHASES]; N_SCOPES];
        self.enc = CompressStats::default();
        self.edges = EdgeStats::default();
        self.node_bytes.clear();
        self.resets = 0;
    }
}

/// The span/event recorder behind a cheap clonable handle.
///
/// The default ([`Recorder::noop`]) carries no state: every
/// instrumentation call is one `Option` branch and returns immediately —
/// no allocation, no RNG, no ledger access.  An enabled recorder shares
/// one `Rc<RefCell>` across its scoped clones, so the outer loop and both
/// inner-loop states record into the same sinks.
#[derive(Clone, Default)]
pub struct Recorder {
    inner: Option<Rc<RefCell<Inner>>>,
    scope: Scope,
}

impl Recorder {
    /// The no-op recorder: all instrumentation compiles down to a branch.
    pub fn noop() -> Recorder {
        Recorder::default()
    }

    /// A recorder with the requested sinks; noop when both are off.
    pub fn new(trace: bool, profile: bool) -> Recorder {
        Recorder::with_capacity(if trace { DEFAULT_TRACE_CAPACITY } else { 0 }, profile)
    }

    /// A recorder whose JSONL buffer is pre-sized to `trace_capacity`
    /// bytes (0 disables the trace sink).  Steady-state appends within
    /// the capacity never reallocate.
    pub fn with_capacity(trace_capacity: usize, profile: bool) -> Recorder {
        if trace_capacity == 0 && !profile {
            return Recorder::noop();
        }
        Recorder {
            inner: Some(Rc::new(RefCell::new(Inner {
                buf: (trace_capacity > 0).then(|| String::with_capacity(trace_capacity)),
                profile,
                cell: None,
                algo: String::new(),
                phase: [[PhaseStat::default(); N_PHASES]; N_SCOPES],
                enc: CompressStats::default(),
                edges: EdgeStats::default(),
                node_bytes: Vec::new(),
                resets: 0,
            }))),
            scope: Scope::Outer,
        }
    }

    /// A recorder for one sweep cell: `run_start` lines carry the cell id
    /// so a concatenated sweep trace keyed by the cell-id contract stays
    /// self-describing.
    pub fn for_cell(trace: bool, profile: bool, cell: &str) -> Recorder {
        let rec = Recorder::new(trace, profile);
        if let Some(rc) = &rec.inner {
            rc.borrow_mut().cell = Some(cell.to_string());
        }
        rec
    }

    /// Whether any sink is attached (false for the no-op recorder).
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// A clone of this handle tagged with `scope`; records into the same
    /// shared sinks.
    pub fn scoped(&self, scope: Scope) -> Recorder {
        Recorder { inner: self.inner.clone(), scope }
    }

    /// `Some(now)` iff the wall-clock profiler is on.  Pass the result to
    /// the matching `phase`/`phase_comm`/`exchange` call; the deterministic
    /// sink never sees it.  (lint.toml R1 allow1: the profiler is the one
    /// sanctioned clock reader.)
    #[allow(clippy::disallowed_methods)]
    pub fn clock(&self) -> Option<Instant> {
        match &self.inner {
            Some(rc) if rc.borrow().profile => Some(Instant::now()),
            _ => None,
        }
    }

    // -- run lifecycle ----------------------------------------------------

    /// Start-of-run event: resets per-run aggregates and emits the
    /// `run_start` line.  `seed` is written as a string (u64s do not fit
    /// f64 JSON numbers losslessly).
    pub fn run_start(&self, algo: &str, label: &str, m: usize, seed: u64, compressor: &str) {
        let Some(rc) = &self.inner else { return };
        let mut g = rc.borrow_mut();
        g.reset_run();
        g.algo.clear();
        g.algo.push_str(algo);
        g.node_bytes.resize(m, 0);
        let cell = g.cell.take();
        if let Some(b) = g.buf.as_mut() {
            b.push_str("{\"ev\":\"run_start\",\"format\":");
            let _ = write!(b, "{TRACE_FORMAT}");
            b.push_str(",\"algo\":");
            push_json_str(b, algo);
            if let Some(c) = &cell {
                b.push_str(",\"cell\":");
                push_json_str(b, c);
            }
            b.push_str(",\"label\":");
            push_json_str(b, label);
            b.push_str(",\"m\":");
            let _ = write!(b, "{m}");
            b.push_str(",\"seed\":");
            push_json_str(b, &seed.to_string());
            b.push_str(",\"compressor\":");
            push_json_str(b, compressor);
            b.push_str("}\n");
        }
        g.cell = cell;
    }

    /// End-of-round span: cumulative counters after the round's step.
    pub fn round(&self, round: usize, l: &CommLedger, o: &OracleCounter) {
        let Some(rc) = &self.inner else { return };
        let mut g = rc.borrow_mut();
        if let Some(b) = g.buf.as_mut() {
            b.push_str("{\"ev\":\"round\",\"round\":");
            let _ = write!(b, "{round}");
            b.push_str(",\"bytes\":");
            let _ = write!(b, "{}", l.total_bytes);
            b.push_str(",\"msgs\":");
            let _ = write!(b, "{}", l.messages);
            b.push_str(",\"dropped\":");
            let _ = write!(b, "{}", l.dropped_messages);
            b.push_str(",\"gossip\":");
            let _ = write!(b, "{}", l.gossip_rounds);
            b.push_str(",\"first_order\":");
            let _ = write!(b, "{}", o.first_order);
            b.push_str(",\"second_order\":");
            let _ = write!(b, "{}", o.second_order);
            b.push_str(",\"sim_s\":");
            push_num(b, l.network_time_s);
            b.push_str("}\n");
        }
    }

    /// Evaluation span: the trace point minus its wall-clock field.
    pub fn eval(&self, p: &TracePoint) {
        let Some(rc) = &self.inner else { return };
        let mut g = rc.borrow_mut();
        if let Some(b) = g.buf.as_mut() {
            b.push_str("{\"ev\":\"eval\",\"round\":");
            let _ = write!(b, "{}", p.round);
            b.push_str(",\"loss\":");
            push_num(b, p.loss);
            b.push_str(",\"accuracy\":");
            push_num(b, p.accuracy);
            b.push_str(",\"grad_norm\":");
            push_num(b, p.grad_norm);
            b.push_str(",\"consensus\":");
            push_num(b, p.consensus_err);
            b.push_str(",\"comm_mb\":");
            push_num(b, p.comm_mb);
            b.push_str(",\"dropped\":");
            let _ = write!(b, "{}", p.dropped_msgs);
            b.push_str(",\"sim_s\":");
            push_num(b, p.sim_time_s);
            b.push_str("}\n");
        }
    }

    /// End-of-run: per-phase aggregate lines, compressor/edge/node
    /// summaries, then the `run_end` line.
    pub fn run_end(&self, m: &RunMetrics) {
        let Some(rc) = &self.inner else { return };
        let mut g = rc.borrow_mut();
        let g = &mut *g;
        let Some(b) = g.buf.as_mut() else { return };
        for scope in ALL_SCOPES {
            for phase in ALL_PHASES {
                let st = g.phase[scope.idx()][phase.idx()];
                if st.is_zero() {
                    continue;
                }
                b.push_str("{\"ev\":\"phase\",\"scope\":");
                push_json_str(b, scope.name());
                b.push_str(",\"phase\":");
                push_json_str(b, phase.name());
                b.push_str(",\"count\":");
                let _ = write!(b, "{}", st.count);
                b.push_str(",\"bytes\":");
                let _ = write!(b, "{}", st.bytes);
                b.push_str(",\"msgs\":");
                let _ = write!(b, "{}", st.msgs);
                b.push_str(",\"dropped\":");
                let _ = write!(b, "{}", st.dropped);
                b.push_str(",\"oracles\":");
                let _ = write!(b, "{}", st.oracles);
                b.push_str(",\"sim_s\":");
                push_num(b, st.sim_s);
                b.push_str("}\n");
            }
        }
        if g.enc.encodes > 0 {
            b.push_str("{\"ev\":\"compress\",\"encodes\":");
            let _ = write!(b, "{}", g.enc.encodes);
            b.push_str(",\"decodes\":");
            let _ = write!(b, "{}", g.enc.decodes);
            b.push_str(",\"dense\":");
            let _ = write!(b, "{}", g.enc.dense);
            b.push_str(",\"sparse\":");
            let _ = write!(b, "{}", g.enc.sparse);
            b.push_str(",\"quantized\":");
            let _ = write!(b, "{}", g.enc.quantized);
            b.push_str(",\"payload_hist\":");
            push_hist(b, &g.enc.payload_hist);
            b.push_str("}\n");
        }
        if g.edges.delivered + g.edges.dropped > 0 {
            b.push_str("{\"ev\":\"edges\",\"delivered\":");
            let _ = write!(b, "{}", g.edges.delivered);
            b.push_str(",\"dropped\":");
            let _ = write!(b, "{}", g.edges.dropped);
            b.push_str(",\"queue_peak\":");
            let _ = write!(b, "{}", g.edges.queue_peak);
            b.push_str(",\"latency_hist\":");
            push_hist(b, &g.edges.latency_hist);
            b.push_str("}\n");
        }
        if g.node_bytes.iter().any(|&v| v > 0) {
            b.push_str("{\"ev\":\"node_bytes\",\"bytes\":");
            push_hist(b, &g.node_bytes);
            b.push_str("}\n");
        }
        b.push_str("{\"ev\":\"run_end\",\"algo\":");
        push_json_str(b, &m.algo);
        b.push_str(",\"stop\":");
        push_json_str(b, m.stop_reason.map_or("none", |r| r.name()));
        b.push_str(",\"rounds\":");
        let _ = write!(b, "{}", m.trace.last().map_or(0, |p| p.round));
        b.push_str(",\"bytes\":");
        let _ = write!(b, "{}", m.ledger.total_bytes);
        b.push_str(",\"msgs\":");
        let _ = write!(b, "{}", m.ledger.messages);
        b.push_str(",\"dropped\":");
        let _ = write!(b, "{}", m.ledger.dropped_messages);
        b.push_str(",\"gossip\":");
        let _ = write!(b, "{}", m.ledger.gossip_rounds);
        b.push_str(",\"first_order\":");
        let _ = write!(b, "{}", m.oracles.first_order);
        b.push_str(",\"second_order\":");
        let _ = write!(b, "{}", m.oracles.second_order);
        b.push_str(",\"evals\":");
        let _ = write!(b, "{}", m.oracles.evals);
        b.push_str(",\"resets\":");
        let _ = write!(b, "{}", g.resets);
        b.push_str(",\"sim_s\":");
        push_num(b, m.ledger.network_time_s);
        b.push_str("}\n");
    }

    // -- hot-path instrumentation ----------------------------------------

    /// Record a compute-only phase event (`oracles` oracle calls, no
    /// communication).  `t` comes from [`Recorder::clock`].
    pub fn phase(&self, phase: Phase, oracles: u64, t: Option<Instant>) {
        let Some(rc) = &self.inner else { return };
        let mut g = rc.borrow_mut();
        let st = &mut g.phase[self.scope.idx()][phase.idx()];
        st.count += 1;
        st.oracles += oracles;
        if let Some(t0) = t {
            st.wall_ns += t0.elapsed().as_nanos() as u64;
        }
    }

    /// Record a phase event that also paid communication: attributes the
    /// ledger delta since `before`.
    pub fn phase_comm(
        &self,
        phase: Phase,
        oracles: u64,
        before: LedgerSnap,
        after: &CommLedger,
        t: Option<Instant>,
    ) {
        let Some(rc) = &self.inner else { return };
        let mut g = rc.borrow_mut();
        let st = &mut g.phase[self.scope.idx()][phase.idx()];
        st.count += 1;
        st.oracles += oracles;
        st.bytes += after.total_bytes - before.bytes;
        st.msgs += after.messages - before.msgs;
        st.dropped += after.dropped_messages - before.dropped;
        st.sim_s += after.network_time_s - before.sim_s;
        if let Some(t0) = t {
            st.wall_ns += t0.elapsed().as_nanos() as u64;
        }
    }

    /// Record one paid transport exchange: the ledger delta, per-node sent
    /// bytes (`sent[i]` = wire bytes node i sent to EACH neighbour), and —
    /// on the event engine — per-edge arrivals (delivered/dropped counts,
    /// queue depth, sim-time latency histogram).
    pub fn exchange(
        &self,
        phase: Phase,
        before: LedgerSnap,
        after: &CommLedger,
        sent: &[usize],
        events: &[Arrival],
        t: Option<Instant>,
    ) {
        let Some(rc) = &self.inner else { return };
        let mut g = rc.borrow_mut();
        {
            let st = &mut g.phase[self.scope.idx()][phase.idx()];
            st.count += 1;
            st.bytes += after.total_bytes - before.bytes;
            st.msgs += after.messages - before.msgs;
            st.dropped += after.dropped_messages - before.dropped;
            st.sim_s += after.network_time_s - before.sim_s;
            if let Some(t0) = t {
                st.wall_ns += t0.elapsed().as_nanos() as u64;
            }
        }
        if g.node_bytes.len() < sent.len() {
            g.node_bytes.resize(sent.len(), 0);
        }
        for (nb, &s) in g.node_bytes.iter_mut().zip(sent) {
            *nb += s as u64;
        }
        if !events.is_empty() {
            g.edges.queue_peak = g.edges.queue_peak.max(events.len() as u64);
            for e in events {
                if e.dropped {
                    g.edges.dropped += 1;
                } else {
                    g.edges.delivered += 1;
                }
                let lat_us = ((e.t_s - before.sim_s).max(0.0) * 1e6) as u64;
                g.edges.latency_hist[log_bucket(lat_us)] += 1;
            }
        }
    }

    /// Count compressor encodes: one per message, with the payload kind
    /// and a log₂ wire-byte histogram.
    pub fn encoded<S: crate::linalg::Scalar>(&self, msgs: &[Compressed<S>]) {
        let Some(rc) = &self.inner else { return };
        let mut g = rc.borrow_mut();
        for msg in msgs {
            g.enc.encodes += 1;
            g.enc.payload_hist[log_bucket(msg.wire_bytes() as u64)] += 1;
            match msg.payload_kind() {
                PayloadKind::Dense => g.enc.dense += 1,
                PayloadKind::Sparse => g.enc.sparse += 1,
                PayloadKind::Quantized => g.enc.quantized += 1,
            }
        }
    }

    /// Count `n` compressor decodes (neighbour folds of delivered
    /// messages).
    pub fn decoded(&self, n: u64) {
        let Some(rc) = &self.inner else { return };
        rc.borrow_mut().enc.decodes += n;
    }

    /// A reference-point resync event (topology epoch change or a node
    /// that fell behind): counter-stamped, scope from the handle.
    pub fn reset(&self, step: u64, epoch: u64) {
        let Some(rc) = &self.inner else { return };
        let mut g = rc.borrow_mut();
        g.resets += 1;
        let scope = self.scope;
        if let Some(b) = g.buf.as_mut() {
            b.push_str("{\"ev\":\"reset\",\"scope\":");
            push_json_str(b, scope.name());
            b.push_str(",\"step\":");
            let _ = write!(b, "{step}");
            b.push_str(",\"epoch\":");
            let _ = write!(b, "{epoch}");
            b.push_str("}\n");
        }
    }

    // -- sink extraction --------------------------------------------------

    /// Take the JSONL buffer (None for noop/profile-only recorders, or if
    /// already taken).
    pub fn take_trace(&self) -> Option<String> {
        self.inner.as_ref()?.borrow_mut().buf.take()
    }

    /// Render the wall-clock phase profile (None unless profiling).  The
    /// output is explicitly nondeterministic and is kept out of the
    /// deterministic JSONL sink by construction.
    pub fn render_profile(&self) -> Option<String> {
        let rc = self.inner.as_ref()?;
        let g = rc.borrow();
        if !g.profile {
            return None;
        }
        let mut out = String::new();
        let _ = writeln!(
            out,
            "# wall-clock phase profile ({}; nondeterministic, never in --trace)",
            if g.algo.is_empty() { "run" } else { &g.algo }
        );
        let _ = writeln!(out, "{:<22} {:>10} {:>12} {:>12}", "scope/phase", "count", "wall_ms", "ms/event");
        for scope in ALL_SCOPES {
            for phase in ALL_PHASES {
                let st = g.phase[scope.idx()][phase.idx()];
                if st.is_zero() {
                    continue;
                }
                let ms = st.wall_ns as f64 / 1e6;
                let _ = writeln!(
                    out,
                    "{:<22} {:>10} {:>12.3} {:>12.6}",
                    format!("{}/{}", scope.name(), phase.name()),
                    st.count,
                    ms,
                    ms / st.count as f64,
                );
            }
        }
        Some(out)
    }
}

// ---------------------------------------------------------------------------
// console verbosity
// ---------------------------------------------------------------------------

/// Harness output level: `--quiet` < normal < `--verbose`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Verbosity {
    Quiet,
    #[default]
    Normal,
    Verbose,
}

/// The one place harness progress output goes through, so `--quiet` /
/// `--verbose` control every sweep/goldens/budget progress line.
/// Warnings always print (stderr).
#[derive(Clone, Copy, Debug, Default)]
pub struct Console {
    pub level: Verbosity,
}

impl Console {
    pub fn new(quiet: bool, verbose: bool) -> Console {
        let level = if quiet {
            Verbosity::Quiet
        } else if verbose {
            Verbosity::Verbose
        } else {
            Verbosity::Normal
        };
        Console { level }
    }

    pub fn quiet() -> Console {
        Console { level: Verbosity::Quiet }
    }

    pub fn from_verbose(verbose: bool) -> Console {
        Console::new(false, verbose)
    }

    pub fn is_verbose(&self) -> bool {
        self.level >= Verbosity::Verbose
    }

    pub fn is_quiet(&self) -> bool {
        self.level == Verbosity::Quiet
    }

    /// Per-trace-point progress lines (`--verbose` only).
    pub fn progress(&self, msg: std::fmt::Arguments<'_>) {
        if self.level >= Verbosity::Verbose {
            println!("{msg}");
        }
    }

    /// Normal result/summary lines (suppressed by `--quiet`).
    pub fn info(&self, msg: std::fmt::Arguments<'_>) {
        if self.level >= Verbosity::Normal {
            println!("{msg}");
        }
    }

    /// Diagnostics that must not be silenced (stderr).
    pub fn warn(&self, msg: std::fmt::Arguments<'_>) {
        eprintln!("{msg}");
    }
}

// ---------------------------------------------------------------------------
// JSONL helpers
// ---------------------------------------------------------------------------

/// Log₂ histogram bucket of `v` (bucket 0 holds 0, bucket k holds
/// [2^(k-1), 2^k)), clamped to [`HIST_BUCKETS`].
fn log_bucket(v: u64) -> usize {
    ((64 - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
}

/// JSON number with [`Json`]'s exact semantics (non-finite → null,
/// integral < 1e15 → integer form) so traces parse back identically.
fn push_num(b: &mut String, v: f64) {
    if !v.is_finite() {
        b.push_str("null");
    } else if v.fract() == 0.0 && v.abs() < 1e15 {
        let _ = write!(b, "{}", v as i64);
    } else {
        let _ = write!(b, "{v}");
    }
}

/// JSON string with [`Json`]'s escaping.
fn push_json_str(b: &mut String, s: &str) {
    crate::util::json::write_escaped(s, b);
}

fn push_hist(b: &mut String, h: &[u64]) {
    b.push('[');
    for (i, v) in h.iter().enumerate() {
        if i > 0 {
            b.push(',');
        }
        let _ = write!(b, "{v}");
    }
    b.push(']');
}

// ---------------------------------------------------------------------------
// trace validation + summary (`c2dfb trace <file>`)
// ---------------------------------------------------------------------------

/// Required keys per event type; unknown event types are an error.
fn required_keys(ev: &str) -> Option<&'static [&'static str]> {
    Some(match ev {
        "run_start" => &["format", "algo", "label", "m", "seed", "compressor"],
        "round" => &["round", "bytes", "msgs", "dropped", "gossip", "first_order", "sim_s"],
        "eval" => &["round", "loss", "accuracy", "grad_norm", "consensus", "comm_mb", "sim_s"],
        "reset" => &["scope", "step", "epoch"],
        "phase" => &["scope", "phase", "count", "bytes", "msgs", "dropped", "oracles", "sim_s"],
        "compress" => &["encodes", "decodes", "dense", "sparse", "quantized", "payload_hist"],
        "edges" => &["delivered", "dropped", "queue_peak", "latency_hist"],
        "node_bytes" => &["bytes"],
        "run_end" => &[
            "algo",
            "stop",
            "rounds",
            "bytes",
            "msgs",
            "gossip",
            "first_order",
            "second_order",
            "evals",
            "sim_s",
        ],
        _ => return None,
    })
}

/// Validate one JSONL trace line: must parse as a JSON object with a
/// known `ev`, all required keys present, and **no wall-clock field** —
/// the deterministic sink's contract.
pub fn validate_line(line: &str) -> Result<Json, String> {
    let v = Json::parse(line).map_err(|e| format!("bad JSON: {e}"))?;
    let obj = v.as_obj().ok_or("not a JSON object")?;
    for k in obj.keys() {
        if k.contains("wall") {
            return Err(format!("wall-clock field {k:?} in deterministic trace"));
        }
    }
    let ev = v
        .get("ev")
        .and_then(Json::as_str)
        .ok_or("missing \"ev\" key")?;
    let req = required_keys(ev).ok_or_else(|| format!("unknown event type {ev:?}"))?;
    for k in req {
        if obj.get(*k).is_none() {
            return Err(format!("{ev}: missing required key {k:?}"));
        }
    }
    Ok(v)
}

#[derive(Clone, Copy, Debug, Default)]
struct PhaseRow {
    count: u64,
    bytes: u64,
    msgs: u64,
    dropped: u64,
    oracles: u64,
    sim_s: f64,
}

/// Aggregated view of a JSONL trace: the per-phase cost table behind
/// `c2dfb trace <file>`.
#[derive(Default)]
pub struct TraceSummary {
    pub lines: usize,
    pub runs: usize,
    pub evals: usize,
    pub resets: usize,
    /// (algo, scope, phase) → aggregates, across all runs in the file.
    rows: BTreeMap<(String, String, String), PhaseRow>,
    /// algo → per-node cumulative sent bytes, pooled across that algo's
    /// runs (the node-decile distribution).
    node_bytes: BTreeMap<String, Vec<u64>>,
}

/// Parse, validate and aggregate a JSONL trace.  Errors carry the
/// 1-based line number.
pub fn summarize(text: &str) -> Result<TraceSummary, String> {
    let mut s = TraceSummary::default();
    let mut algo = String::from("?");
    for (ln, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = validate_line(line).map_err(|e| format!("line {}: {e}", ln + 1))?;
        s.lines += 1;
        let ev = v.get("ev").and_then(Json::as_str).unwrap_or("");
        match ev {
            "run_start" => {
                algo = v.get("algo").and_then(Json::as_str).unwrap_or("?").to_string();
            }
            "run_end" => s.runs += 1,
            "eval" => s.evals += 1,
            "reset" => s.resets += 1,
            "phase" => {
                let key = (
                    algo.clone(),
                    v.get("scope").and_then(Json::as_str).unwrap_or("?").to_string(),
                    v.get("phase").and_then(Json::as_str).unwrap_or("?").to_string(),
                );
                let num = |k: &str| v.get(k).and_then(Json::as_f64).unwrap_or(0.0);
                let row = s.rows.entry(key).or_default();
                row.count += num("count") as u64;
                row.bytes += num("bytes") as u64;
                row.msgs += num("msgs") as u64;
                row.dropped += num("dropped") as u64;
                row.oracles += num("oracles") as u64;
                row.sim_s += num("sim_s");
            }
            "node_bytes" => {
                let pool = s.node_bytes.entry(algo.clone()).or_default();
                if let Some(arr) = v.get("bytes").and_then(Json::as_arr) {
                    pool.extend(arr.iter().map(|x| x.as_f64().unwrap_or(0.0) as u64));
                }
            }
            _ => {}
        }
    }
    Ok(s)
}

impl TraceSummary {
    /// All (algo, scope, phase) triples present in the trace.
    pub fn phase_pairs(&self) -> Vec<(String, String, String)> {
        self.rows.keys().cloned().collect()
    }

    /// Render the per-phase cost table (+ node-decile sent-byte
    /// distribution when recorded).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "trace: {} lines, {} runs, {} evals, {} resets",
            self.lines, self.runs, self.evals, self.resets
        );
        let _ = writeln!(
            out,
            "\n| {:<10} | {:<8} | {:<9} | {:>8} | {:>14} | {:>8} | {:>8} | {:>10} | {:>12} |",
            "algo", "scope", "phase", "count", "bytes", "msgs", "dropped", "oracles", "sim_s"
        );
        let _ = writeln!(
            out,
            "|{:-<12}|{:-<10}|{:-<11}|{:-<10}|{:-<16}|{:-<10}|{:-<10}|{:-<12}|{:-<14}|",
            "", "", "", "", "", "", "", "", ""
        );
        for ((algo, scope, phase), r) in &self.rows {
            let _ = writeln!(
                out,
                "| {:<10} | {:<8} | {:<9} | {:>8} | {:>14} | {:>8} | {:>8} | {:>10} | {:>12.6} |",
                algo, scope, phase, r.count, r.bytes, r.msgs, r.dropped, r.oracles, r.sim_s
            );
        }
        if !self.node_bytes.is_empty() {
            let _ = writeln!(
                out,
                "\nper-node sent bytes (deciles p10..p100 of the node distribution):"
            );
            for (algo, pool) in &self.node_bytes {
                let mut sorted = pool.clone();
                sorted.sort_unstable();
                let decs: Vec<String> = (1..=10)
                    .map(|q| {
                        let idx = (q * sorted.len()).div_ceil(10).saturating_sub(1);
                        format!("{}", sorted.get(idx).copied().unwrap_or(0))
                    })
                    .collect();
                let _ = writeln!(out, "  {:<10} [{}]", algo, decs.join(", "));
            }
        }
        out
    }
}

/// Validate a whole trace file; returns the number of (non-empty) lines.
pub fn validate_trace(text: &str) -> Result<usize, String> {
    let mut n = 0;
    for (ln, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        validate_line(line).map_err(|e| format!("line {}: {e}", ln + 1))?;
        n += 1;
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::StopReason;

    fn ledger(bytes: u64, msgs: u64, sim_s: f64) -> CommLedger {
        CommLedger {
            total_bytes: bytes,
            gossip_rounds: 1,
            network_time_s: sim_s,
            messages: msgs,
            dropped_messages: 0,
        }
    }

    #[test]
    fn noop_recorder_is_inert() {
        let r = Recorder::noop();
        assert!(!r.enabled());
        assert!(r.clock().is_none());
        r.phase(Phase::Grad, 10, None);
        r.round(0, &ledger(1, 1, 0.0), &OracleCounter::default());
        assert!(r.take_trace().is_none());
        assert!(r.render_profile().is_none());
    }

    #[test]
    fn new_with_no_sinks_is_noop() {
        assert!(!Recorder::new(false, false).enabled());
        assert!(Recorder::new(true, false).enabled());
        assert!(Recorder::new(false, true).enabled());
    }

    #[test]
    fn trace_lines_validate_and_summarize() {
        let r = Recorder::new(true, false);
        r.run_start("c2dfb", "lab", 4, 42, "topk:0.5");
        let before = LedgerSnap::of(&ledger(0, 0, 0.0));
        r.scoped(Scope::InnerY)
            .exchange(Phase::Exchange, before, &ledger(800, 8, 0.001), &[100; 4], &[], None);
        r.scoped(Scope::InnerY).phase(Phase::Grad, 4, None);
        r.round(0, &ledger(800, 8, 0.001), &OracleCounter { first_order: 4, ..Default::default() });
        let mut m = RunMetrics::new("c2dfb", "lab");
        m.ledger = ledger(800, 8, 0.001);
        m.record_eval(0, 1.0, 0.5, 0.1, 0.0);
        r.eval(m.trace.last().unwrap());
        m.stop_reason = Some(StopReason::Rounds);
        r.run_end(&m);
        let text = r.take_trace().unwrap();
        let s = summarize(&text).unwrap();
        assert_eq!(s.runs, 1);
        assert_eq!(s.evals, 1);
        let pairs = s.phase_pairs();
        assert!(pairs.contains(&("c2dfb".into(), "inner_y".into(), "exchange".into())));
        assert!(pairs.contains(&("c2dfb".into(), "inner_y".into(), "grad".into())));
        let rendered = s.render();
        assert!(rendered.contains("inner_y"));
        assert!(rendered.contains("exchange"));
        // Deterministic-sink contract: nothing wall-clock anywhere.
        assert!(!text.contains("wall"));
    }

    #[test]
    fn validator_rejects_wall_clock_fields() {
        let err = validate_line(r#"{"ev":"round","round":0,"wall_time_s":1.0}"#).unwrap_err();
        assert!(err.contains("wall"));
    }

    #[test]
    fn validator_rejects_unknown_events_and_missing_keys() {
        assert!(validate_line(r#"{"ev":"bogus"}"#).is_err());
        assert!(validate_line(r#"{"round":0}"#).is_err());
        assert!(validate_line(r#"{"ev":"reset","scope":"inner_y"}"#).is_err());
        assert!(validate_line("not json").is_err());
        assert!(validate_line(
            r#"{"ev":"reset","scope":"inner_y","step":3,"epoch":1}"#
        )
        .is_ok());
    }

    #[test]
    fn scoped_handles_share_one_sink() {
        let r = Recorder::new(true, false);
        r.run_start("c2dfb", "l", 2, 1, "none");
        let y = r.scoped(Scope::InnerY);
        let z = y.scoped(Scope::InnerZ);
        y.phase(Phase::Mix, 0, None);
        z.phase(Phase::Mix, 0, None);
        let m = RunMetrics::new("c2dfb", "l");
        r.run_end(&m);
        let text = r.take_trace().unwrap();
        assert!(text.contains(r#""scope":"inner_y","phase":"mix""#));
        assert!(text.contains(r#""scope":"inner_z","phase":"mix""#));
        // y's sink is the same buffer — already taken.
        assert!(y.take_trace().is_none());
    }

    #[test]
    fn reset_events_are_counter_stamped() {
        let r = Recorder::new(true, false);
        r.run_start("c2dfb", "l", 2, 1, "none");
        r.scoped(Scope::InnerZ).reset(17, 3);
        let m = RunMetrics::new("c2dfb", "l");
        r.run_end(&m);
        let text = r.take_trace().unwrap();
        assert!(text.contains(r#"{"ev":"reset","scope":"inner_z","step":17,"epoch":3}"#));
        assert!(text.contains(r#""resets":1"#));
        assert_eq!(summarize(&text).unwrap().resets, 1);
    }

    #[test]
    fn profile_renders_separately_from_trace() {
        let r = Recorder::new(true, true);
        r.run_start("c2dfb", "l", 2, 1, "none");
        let t = r.clock();
        assert!(t.is_some());
        r.phase(Phase::Grad, 2, t);
        let m = RunMetrics::new("c2dfb", "l");
        r.run_end(&m);
        let prof = r.render_profile().unwrap();
        assert!(prof.contains("outer/grad"));
        assert!(prof.contains("nondeterministic"));
        let text = r.take_trace().unwrap();
        assert!(!text.contains("wall"), "profiler data leaked into the trace");
        assert!(validate_trace(&text).unwrap() > 0);
    }

    #[test]
    fn log_bucket_is_monotone_and_clamped() {
        assert_eq!(log_bucket(0), 0);
        assert_eq!(log_bucket(1), 1);
        assert_eq!(log_bucket(2), 2);
        assert_eq!(log_bucket(3), 2);
        assert_eq!(log_bucket(4), 3);
        assert_eq!(log_bucket(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn node_decile_render_pools_per_algo() {
        let r = Recorder::new(true, false);
        r.run_start("c2dfb", "l", 4, 1, "none");
        let before = LedgerSnap::default();
        r.exchange(Phase::Exchange, before, &ledger(40, 4, 0.0), &[10, 20, 30, 40], &[], None);
        let m = RunMetrics::new("c2dfb", "l");
        r.run_end(&m);
        let text = r.take_trace().unwrap();
        assert!(text.contains(r#"{"ev":"node_bytes","bytes":[10,20,30,40]}"#));
        let rendered = summarize(&text).unwrap().render();
        assert!(rendered.contains("per-node sent bytes"));
    }

    #[test]
    fn console_levels() {
        assert!(Console::new(false, true).is_verbose());
        assert!(!Console::new(false, false).is_verbose());
        assert!(Console::new(true, true).is_quiet(), "quiet wins over verbose");
        assert!(Console::quiet().is_quiet());
        assert_eq!(Console::default().level, Verbosity::Normal);
    }

    #[test]
    fn edge_events_feed_latency_histogram() {
        let r = Recorder::new(true, false);
        r.run_start("c2dfb", "l", 2, 1, "none");
        let before = LedgerSnap::default();
        let events = [
            Arrival { t_s: 0.001, sender: 0, receiver: 1, bytes: 50, dropped: false },
            Arrival { t_s: 0.002, sender: 1, receiver: 0, bytes: 50, dropped: true },
        ];
        r.exchange(Phase::Exchange, before, &ledger(100, 2, 0.002), &[50, 50], &events, None);
        let m = RunMetrics::new("c2dfb", "l");
        r.run_end(&m);
        let text = r.take_trace().unwrap();
        assert!(text.contains(r#""delivered":1"#));
        assert!(text.contains(r#""queue_peak":2"#));
        assert!(validate_trace(&text).is_ok());
    }
}
