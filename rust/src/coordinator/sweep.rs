//! Declarative scenario-grid orchestrator: the substrate every harness
//! runs on.
//!
//! A sweep is a cartesian grid over seven axes — algorithm × task ×
//! topology × compressor × partition × engine × stop condition — declared
//! either programmatically (a `Vec<Cell>`, how the `table1`/`fig*`/
//! `netsweep`/`budget` harnesses are now written), from a `[sweep]` TOML
//! table, or from `c2dfb sweep` CLI flags.  [`run_cells`] executes the
//! cells on a work-stealing pool ([`NodePool`]'s shared cursor *is* the
//! stealing) and returns per-cell outcomes **in declaration order**:
//!
//! * Every cell is self-contained — its config carries a deterministic
//!   seed (see [`derive_seed`]) and cells share no mutable state — so
//!   N-way-parallel execution is **bit-identical** to serial execution
//!   (proven by [`diff_outcomes`], enforced by `c2dfb sweep --tiny`, CI
//!   and `tests/sweep.rs`).
//! * A cell that fails (bad config, diverged run, missing artifacts) is
//!   reported in its [`CellOutcome`] without aborting sibling cells.
//! * Cells whose task is [`TaskRef::Shared`] run concurrently; cells that
//!   build their task from the artifact registry ([`TaskRef::Registry`])
//!   run on the caller's thread, because the PJRT state is thread-local
//!   (`Rc` oracle handles) — same engine, serial lane.
//!
//! [`report_csv`]/[`report_json`] aggregate the outcomes into one
//! cross-cell document (per-cell deterministic metrics plus a grouped
//! summary with communication/virtual-time ratios); wall-clock fields are
//! deliberately excluded so the report bytes are identical at any
//! parallelism.  See `docs/SWEEP.md` for the grid syntax, the
//! seed-derivation contract and the report schema.

use crate::algorithms::RunObserver;
use crate::config::toml::{self, TomlValue};
use crate::config::{Algorithm, ExperimentConfig};
use crate::coordinator::{experiments, Runner};
use crate::data::partition::Partition;
use crate::linalg::Dtype;
use crate::metrics::{RunMetrics, TracePoint};
use crate::obs::{Console, Recorder};
use crate::runtime::ArtifactRegistry;
use crate::sim::{NetMode, NodePool};
use crate::tasks::BilevelTask;
use crate::topology::Topology;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// Harness observer: streams a progress line per trace point at
/// [`Verbosity::Verbose`](crate::obs::Verbosity) and aborts any run whose
/// loss goes non-finite (divergence guard) — the runner then records
/// `stop_reason = observer_abort` instead of burning the remaining
/// round/communication budget on NaNs.  All console output routes through
/// [`Console`], so one `--quiet`/`--verbose` flag governs every harness.
#[derive(Default)]
pub struct HarnessObserver {
    /// Output routing: per-point progress at Verbose, warnings always.
    pub console: Console,
}

impl HarnessObserver {
    /// Compatibility constructor for the old `{ verbose: bool }` shape.
    pub fn verbose(verbose: bool) -> HarnessObserver {
        HarnessObserver { console: Console::from_verbose(verbose) }
    }
}

impl RunObserver for HarnessObserver {
    fn on_trace(&mut self, algo: &str, p: &TracePoint) -> bool {
        self.console.progress(format_args!(
            "    [{algo:8}] round {:5}  comm {:9.3} MB  loss {:.5}  acc {:.3}",
            p.round, p.comm_mb, p.loss, p.accuracy
        ));
        if !p.loss.is_finite() {
            self.console.warn(format_args!(
                "    [{algo}] aborting run: non-finite loss at round {}",
                p.round
            ));
            return false;
        }
        true
    }
}

/// Where a cell's task comes from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskRef {
    /// Index into the sweep's shared task table — parallel lane.
    Shared(usize),
    /// Build a PJRT task from the artifact registry inside the cell —
    /// serial lane (oracle handles are thread-local).
    Registry,
}

/// A shared-lane task reference at its payload width.  The sweep's
/// `dtype` axis decides which width each cell binds to; type erasure
/// still happens once, at the [`Runner`] boundary — a slot is just the
/// pre-erased reference plus its width tag.
#[derive(Clone, Copy)]
pub enum TaskSlot<'a> {
    F32(&'a (dyn BilevelTask + Sync)),
    F64(&'a (dyn BilevelTask<f64> + Sync)),
}

/// An owned shared task at either payload width — the expansion's task
/// table entry ([`Grid::tasks`]).
pub enum NativeTask {
    F32(Box<dyn BilevelTask + Sync>),
    F64(Box<dyn BilevelTask<f64> + Sync>),
}

impl NativeTask {
    /// Borrow as the width-tagged reference the execution layer takes.
    pub fn slot(&self) -> TaskSlot<'_> {
        match self {
            NativeTask::F32(t) => TaskSlot::F32(t.as_ref()),
            NativeTask::F64(t) => TaskSlot::F64(t.as_ref()),
        }
    }

    /// The task's display name, width-independent.
    pub fn name(&self) -> String {
        match self {
            NativeTask::F32(t) => t.name(),
            NativeTask::F64(t) => t.name(),
        }
    }
}

/// One fully-resolved cell of a sweep grid.
#[derive(Clone)]
pub struct Cell {
    /// Unique id within the sweep; also the seed-derivation input.
    pub id: String,
    pub cfg: ExperimentConfig,
    pub task: TaskRef,
}

/// The per-cell result: the run's metrics, or the error that felled this
/// cell (sibling cells always run to completion either way).
#[derive(Clone)]
pub struct CellOutcome {
    pub id: String,
    pub result: Result<RunMetrics, String>,
    /// The cell's deterministic JSONL trace chunk ([`crate::obs`]), when
    /// tracing was requested.  Buffered per cell so the sweep-level file
    /// is byte-identical at any `--jobs`.
    pub trace: Option<String>,
    /// The cell's wall-clock phase profile (explicitly nondeterministic;
    /// never mixed into the trace), when profiling was requested.
    pub profile: Option<String>,
}

impl CellOutcome {
    /// An outcome with no telemetry attached (tests, error paths).
    pub fn bare(id: String, result: Result<RunMetrics, String>) -> CellOutcome {
        CellOutcome { id, result, trace: None, profile: None }
    }

    pub fn metrics(&self) -> Option<&RunMetrics> {
        self.result.as_ref().ok()
    }
}

/// Resolve `jobs = 0` to the machine's available parallelism.
pub fn effective_jobs(jobs: usize) -> usize {
    if jobs == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        jobs
    }
}

/// Per-cell lifecycle callbacks layered onto cell execution by a caller
/// that multiplexes many grids through one pool — the `c2dfb serve`
/// daemon streams these into per-job SSE event logs.  Every method has a
/// no-op default, so implementors override only what they observe.
///
/// Hooks run on pool worker threads (hence the `Sync` supertrait) and
/// must not block: they are called inside the cell's run loop.
pub trait CellHooks: Sync {
    /// Called once before a cell starts executing.
    fn on_cell_start(&self, _id: &str) {}
    /// Called at every evaluation point of a cell's run (the same cadence
    /// as [`RunObserver::on_trace`]).  Returning `false` aborts the run —
    /// the runner records `stop_reason = observer_abort` — which is how
    /// the daemon implements mid-job cancellation: the abort engages at
    /// the cell's next evaluation point (`eval_every` cadence), never
    /// mid-step.
    fn on_point(&self, _id: &str, _algo: &str, _p: &TracePoint) -> bool {
        true
    }
    /// Called once after a cell finishes (ok or error).
    fn on_cell_done(&self, _id: &str, _ok: bool) {}
    /// Checked before a cell starts; `true` skips execution entirely and
    /// yields an `Err("skipped: …")` outcome (a cancelled job's pending
    /// cells never pay init costs).
    fn skip(&self, _id: &str) -> bool {
        false
    }
}

/// Execution knobs for [`run_cells_with`]: parallelism, console routing
/// and which telemetry sinks ([`crate::obs`]) each cell gets.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecOpts {
    /// Cell-level parallelism (0 = all cores).
    pub jobs: usize,
    /// Console verbosity for progress streaming and warnings.
    pub console: Console,
    /// Attach a deterministic JSONL trace sink to every cell.
    pub trace: bool,
    /// Attach the wall-clock phase profiler to every cell.
    pub profile: bool,
}

/// Execute every cell and return outcomes in declaration order.
/// Compatibility wrapper over [`run_cells_with`] for the pre-telemetry
/// `(jobs, verbose)` signature.
pub fn run_cells(
    cells: &[Cell],
    tasks: &[&(dyn BilevelTask + Sync)],
    reg: Option<&ArtifactRegistry>,
    jobs: usize,
    verbose: bool,
) -> Vec<CellOutcome> {
    let opts = ExecOpts {
        jobs,
        console: Console::from_verbose(verbose),
        ..ExecOpts::default()
    };
    run_cells_with(cells, tasks, reg, &opts)
}

/// Execute every cell and return outcomes in declaration order.
///
/// Shared-task cells fan out over a [`NodePool`] of `opts.jobs` workers
/// (`jobs = 0` = all cores); registry cells run serially on this thread.
/// Verbose trace streaming only engages at `jobs <= 1` — interleaved
/// progress lines from concurrent cells would scramble the log — but the
/// divergence guard is armed in both lanes.  A failing cell never aborts
/// its siblings.
///
/// With `opts.trace` each cell gets its own [`Recorder`] whose JSONL
/// chunk lands in [`CellOutcome::trace`]; chunks carry only counters and
/// sim-time, and concatenating them in declaration order
/// ([`concat_traces`]) yields bytes independent of `jobs`.
pub fn run_cells_with(
    cells: &[Cell],
    tasks: &[&(dyn BilevelTask + Sync)],
    reg: Option<&ArtifactRegistry>,
    opts: &ExecOpts,
) -> Vec<CellOutcome> {
    let slots: Vec<TaskSlot> = tasks.iter().map(|t| TaskSlot::F32(*t)).collect();
    run_cells_observed(cells, &slots, reg, opts, None)
}

/// [`run_cells_with`] over a width-tagged task table — what dtype-axis
/// sweeps use ([`Grid::slots`]); the f32-only entry points wrap into
/// [`TaskSlot::F32`] and land here.
pub fn run_cells_slots(
    cells: &[Cell],
    tasks: &[TaskSlot],
    reg: Option<&ArtifactRegistry>,
    opts: &ExecOpts,
) -> Vec<CellOutcome> {
    run_cells_observed(cells, tasks, reg, opts, None)
}

/// [`run_cells_slots`] plus per-cell lifecycle [`CellHooks`].  The hooks
/// see every cell start/point/done on whatever pool thread runs the cell;
/// `hooks = None` is exactly `run_cells_slots`.
pub fn run_cells_observed(
    cells: &[Cell],
    tasks: &[TaskSlot],
    reg: Option<&ArtifactRegistry>,
    opts: &ExecOpts,
    hooks: Option<&dyn CellHooks>,
) -> Vec<CellOutcome> {
    let jobs = effective_jobs(opts.jobs);
    let stream = if jobs <= 1 {
        opts.console
    } else {
        Console { level: opts.console.level.min(crate::obs::Verbosity::Normal) }
    };
    let shared_lane: Vec<usize> = cells
        .iter()
        .enumerate()
        .filter(|(_, c)| matches!(c.task, TaskRef::Shared(_)))
        .map(|(i, _)| i)
        .collect();

    let mut outcomes: Vec<Option<CellOutcome>> = cells.iter().map(|_| None).collect();
    let pool = NodePool::new(jobs);
    let lane_results = pool.map(shared_lane.len(), |k| {
        run_shared_cell(&cells[shared_lane[k]], tasks, stream, opts, hooks)
    });
    for (&i, out) in shared_lane.iter().zip(lane_results) {
        outcomes[i] = Some(out);
    }
    for (i, cell) in cells.iter().enumerate() {
        if cell.task == TaskRef::Registry {
            outcomes[i] = Some(run_registry_cell(cell, reg, opts, hooks));
        }
    }
    outcomes
        .into_iter()
        .map(|o| o.expect("every cell ran on exactly one lane"))
        .collect()
}

/// The observer attached to every hooked cell: the divergence guard
/// first (its verdict always counts), then the caller's hooks.
struct GuardedObserver<'a> {
    guard: HarnessObserver,
    id: &'a str,
    hooks: Option<&'a dyn CellHooks>,
}

impl RunObserver for GuardedObserver<'_> {
    fn on_trace(&mut self, algo: &str, p: &TracePoint) -> bool {
        let ok = self.guard.on_trace(algo, p);
        let cont = match self.hooks {
            Some(h) => h.on_point(self.id, algo, p),
            None => true,
        };
        ok && cont
    }
}

/// Wrap a cell run with its per-cell telemetry recorder and harvest the
/// sinks into the outcome.
fn finish_cell(
    cell: &Cell,
    rec: Recorder,
    result: Result<RunMetrics, String>,
) -> CellOutcome {
    CellOutcome {
        id: cell.id.clone(),
        result,
        trace: rec.take_trace(),
        profile: rec.render_profile(),
    }
}

fn run_shared_cell(
    cell: &Cell,
    tasks: &[TaskSlot],
    stream: Console,
    opts: &ExecOpts,
    hooks: Option<&dyn CellHooks>,
) -> CellOutcome {
    if hooks.is_some_and(|h| h.skip(&cell.id)) {
        return CellOutcome::bare(cell.id.clone(), Err("skipped: job cancelled".into()));
    }
    if let Some(h) = hooks {
        h.on_cell_start(&cell.id);
    }
    let rec = Recorder::for_cell(opts.trace, opts.profile, &cell.id);
    let result = match cell.task {
        TaskRef::Shared(t) => match tasks.get(t) {
            Some(slot) => {
                let mut guard = GuardedObserver {
                    guard: HarnessObserver { console: stream },
                    id: &cell.id,
                    hooks,
                };
                let runner = match *slot {
                    TaskSlot::F32(task) => Runner::new(&cell.cfg).shared_task(task),
                    TaskSlot::F64(task) => Runner::new(&cell.cfg).shared_task_f64(task),
                };
                runner
                    .observer(&mut guard)
                    .recorder(&rec)
                    .run()
                    .map_err(|e| format!("{e:#}"))
            }
            None => Err(format!(
                "task index {t} out of range ({} shared tasks declared)",
                tasks.len()
            )),
        },
        TaskRef::Registry => unreachable!("registry cells run on the serial lane"),
    };
    if let Some(h) = hooks {
        h.on_cell_done(&cell.id, result.is_ok());
    }
    finish_cell(cell, rec, result)
}

fn run_registry_cell(
    cell: &Cell,
    reg: Option<&ArtifactRegistry>,
    opts: &ExecOpts,
    hooks: Option<&dyn CellHooks>,
) -> CellOutcome {
    if hooks.is_some_and(|h| h.skip(&cell.id)) {
        return CellOutcome::bare(cell.id.clone(), Err("skipped: job cancelled".into()));
    }
    if let Some(h) = hooks {
        h.on_cell_start(&cell.id);
    }
    let rec = Recorder::for_cell(opts.trace, opts.profile, &cell.id);
    let result = match reg {
        Some(reg) => {
            let mut guard = GuardedObserver {
                guard: HarnessObserver { console: opts.console },
                id: &cell.id,
                hooks,
            };
            Runner::new(&cell.cfg)
                .registry(reg)
                .observer(&mut guard)
                .recorder(&rec)
                .run()
                .map_err(|e| format!("{e:#}"))
        }
        None => Err("cell needs the artifact registry, but none was supplied".into()),
    };
    if let Some(h) = hooks {
        h.on_cell_done(&cell.id, result.is_ok());
    }
    finish_cell(cell, rec, result)
}

/// Concatenate per-cell trace chunks in declaration order.  Because every
/// chunk is buffered privately and stamped only with counters and
/// sim-time, the result is byte-identical at any `--jobs`.
pub fn concat_traces(outcomes: &[CellOutcome]) -> String {
    let mut out = String::new();
    for o in outcomes {
        if let Some(t) = &o.trace {
            out.push_str(t);
        }
    }
    out
}

/// The per-cell seed-derivation contract (see docs/SWEEP.md): FNV-1a 64
/// over the cell id, mixed with the sweep's base seed through one
/// splitmix64 finalizer.  The derived seed depends only on
/// `(base_seed, cell_id)` — never on grid shape, cell order or
/// parallelism — so editing one axis leaves every other cell's run
/// untouched, and parallel execution is trivially bit-identical to
/// serial.
pub fn derive_seed(base: u64, cell_id: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in cell_id.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    let mut z = (base ^ h).wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A declarative sweep: axis value lists over a base config.  Built from
/// `[sweep]` TOML (`SweepSpec::from_toml_str`) or CLI flags (`c2dfb
/// sweep`); `expand` turns it into cells + a shared task table.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// Non-axis knobs: nodes, rounds, eval cadence, seed, out_dir, the
    /// `[network]` link model and the `[stop]` budget table.
    pub base: ExperimentConfig,
    pub algos: Vec<Algorithm>,
    /// Native task specs: `quadratic`, `logreg`, `hyperrep`.
    pub tasks: Vec<String>,
    /// Topology specs as in [`Topology::parse`] (realized with the base
    /// seed, shared by every cell of the same axis value).
    pub topologies: Vec<String>,
    /// Compressor specs; `"default"` keeps the per-cell calibrated choice.
    pub compressors: Vec<String>,
    /// Partition specs (`iid`, `het:0.8`, `dir:0.5`); part of the task
    /// table key — data is generated once per (task, partition).
    pub partitions: Vec<String>,
    pub engines: Vec<NetMode>,
    /// Stop-axis specs: `rounds:N`, `comm_mb:X`, `oracles:N`, `acc:X`,
    /// `sim_secs:X`; `"rounds"` keeps the base round cap.  (`wall_secs`
    /// is rejected: a wall-clock stop is scheduler-dependent and would
    /// break the parallel ≡ serial bit-identity contract.)
    pub stops: Vec<String>,
    /// Payload-width axis: `"default"` (the base config's dtype, normally
    /// f32), `"f32"` or `"f64"`.  Non-default values are stamped into the
    /// cell id, so adding the axis never reshuffles existing cells' seeds.
    pub dtypes: Vec<String>,
    /// Node-sampling-rate axis: `"default"` keeps the base `[sampling]`
    /// table; a number (e.g. `"0.5"`) overrides `sampling.rate` for the
    /// cell.  Rates below 1 are c2dfb/c2dfb_nc-only (config validation).
    pub sampling_rates: Vec<String>,
    /// Generator-transport axis: `"default"` keeps the base `[scale]`
    /// table; `"on"`/`"off"` override `scale.generator` for the cell.
    pub generators: Vec<String>,
    /// Cell-level parallelism (0 = all cores).
    pub jobs: usize,
    /// Small task instances (the `--tiny` sizes).
    pub tiny: bool,
    /// Start each cell from the task library's calibrated per-(algorithm,
    /// task) step sizes (default); `false` takes the base config's
    /// optimizer knobs verbatim.
    pub calibrate: bool,
}

impl Default for SweepSpec {
    fn default() -> Self {
        let base = ExperimentConfig {
            name: "sweep".into(),
            nodes: 8,
            rounds: 30,
            eval_every: 5,
            ..ExperimentConfig::default()
        };
        SweepSpec {
            base,
            algos: vec![Algorithm::C2dfb, Algorithm::Madsbo, Algorithm::Mdbo],
            tasks: vec!["quadratic".into()],
            topologies: vec!["ring".into()],
            compressors: vec!["default".into()],
            partitions: vec!["dir:0.5".into()],
            engines: vec![NetMode::Sync],
            stops: vec!["rounds".into()],
            dtypes: vec!["default".into()],
            sampling_rates: vec!["default".into()],
            generators: vec!["default".into()],
            jobs: 0,
            tiny: false,
            calibrate: true,
        }
    }
}

impl SweepSpec {
    /// The `--tiny` grid: a real multi-axis sweep (2 algos × 2 tasks ×
    /// 2 topologies × 2 engines = 16 cells) sized to finish in seconds.
    pub fn tiny() -> SweepSpec {
        let mut s = SweepSpec {
            algos: vec![Algorithm::C2dfb, Algorithm::Madsbo],
            tasks: vec!["quadratic".into(), "logreg".into()],
            topologies: vec!["ring".into(), "exp".into()],
            engines: vec![NetMode::Sync, NetMode::Event],
            tiny: true,
            ..SweepSpec::default()
        };
        s.base.nodes = 4;
        s.base.rounds = 3;
        s.base.eval_every = 1;
        s
    }

    /// Parse a config file whose non-`[sweep]` keys feed the base config
    /// and whose `[sweep]` table declares the axes.
    pub fn from_toml_file(path: &Path) -> Result<SweepSpec, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        SweepSpec::from_toml_str(&text)
    }

    pub fn from_toml_str(text: &str) -> Result<SweepSpec, String> {
        SweepSpec::from_flat_map(&toml::parse(text)?)
    }

    /// Build a spec from a flattened `table.key → value` map — the common
    /// substrate behind TOML files ([`from_toml_str`](Self::from_toml_str))
    /// and the daemon's JSON job bodies, so both surfaces resolve a body
    /// to the *same* spec (and hence the same grid, seeds and report
    /// bytes).  `sweep.tiny = true` starts from [`SweepSpec::tiny`] — the
    /// built-in tiny grid, exactly what `c2dfb sweep --tiny` runs — and
    /// the map's other keys then override it.
    pub fn from_flat_map(map: &BTreeMap<String, TomlValue>) -> Result<SweepSpec, String> {
        let tiny = matches!(map.get("sweep.tiny"), Some(TomlValue::Bool(true)));
        let mut spec = if tiny { SweepSpec::tiny() } else { SweepSpec::default() };
        let base_map: BTreeMap<String, TomlValue> = map
            .iter()
            .filter(|(k, _)| !k.starts_with("sweep."))
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        spec.base.apply_map(&base_map)?;
        for (k, v) in map.iter().filter(|(k, _)| k.starts_with("sweep.")) {
            spec.apply_one(k.strip_prefix("sweep.").unwrap(), v)?;
        }
        Ok(spec)
    }

    /// Apply one `[sweep]` key (TOML `sweep.*` or a CLI `--key value`).
    /// Axis lists accept a comma-separated string or a TOML string array.
    pub fn apply_one(&mut self, k: &str, v: &TomlValue) -> Result<(), String> {
        match k {
            "algos" | "algorithms" => {
                self.algos = parse_list(v)?
                    .iter()
                    .map(|s| Algorithm::parse(s))
                    .collect::<Result<_, _>>()?
            }
            "tasks" => self.tasks = parse_list(v)?,
            "topologies" => self.topologies = parse_list(v)?,
            "compressors" => self.compressors = parse_list(v)?,
            "partitions" => self.partitions = parse_list(v)?,
            "engines" => {
                self.engines = parse_list(v)?
                    .iter()
                    .map(|s| NetMode::parse(s))
                    .collect::<Result<_, _>>()?
            }
            "stops" => self.stops = parse_list(v)?,
            "dtypes" | "dtype" => self.dtypes = parse_list(v)?,
            "sampling_rates" | "sampling_rate" => self.sampling_rates = parse_list(v)?,
            "generators" | "generator" => self.generators = parse_list(v)?,
            "jobs" | "parallelism" => {
                self.jobs = v
                    .as_i64()
                    .filter(|i| *i >= 0)
                    .ok_or(format!("sweep.{k}: expected non-negative integer"))?
                    as usize
            }
            "tiny" => {
                self.tiny = v.as_bool().ok_or(format!("sweep.{k}: expected bool"))?
            }
            "calibrate" => {
                self.calibrate = v.as_bool().ok_or(format!("sweep.{k}: expected bool"))?
            }
            _ => return Err(format!("unknown [sweep] key: {k}")),
        }
        Ok(())
    }
}

fn parse_list(v: &TomlValue) -> Result<Vec<String>, String> {
    match v {
        TomlValue::Str(s) => Ok(s
            .split(',')
            .map(|p| p.trim().to_string())
            .filter(|p| !p.is_empty())
            .collect()),
        TomlValue::Arr(a) => a
            .iter()
            .map(|e| {
                e.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| "sweep axis lists must contain strings".to_string())
            })
            .collect(),
        _ => Err("expected a comma-separated string or an array of strings".into()),
    }
}

/// Apply one stop-axis spec to a cell config.  `"rounds"` (bare) and
/// `"default"` keep the base round cap unchanged.
pub fn apply_stop(cfg: &mut ExperimentConfig, spec: &str) -> Result<(), String> {
    let spec = spec.trim();
    if spec.is_empty() || spec == "rounds" || spec == "default" {
        return Ok(());
    }
    let (k, v) = spec
        .split_once(':')
        .ok_or_else(|| format!("stop axis wants kind:value, got {spec:?}"))?;
    let float = || v.parse::<f64>().map_err(|_| format!("bad stop value in {spec:?}"));
    match k {
        "rounds" => {
            cfg.rounds = v.parse().map_err(|_| format!("bad stop value in {spec:?}"))?
        }
        "comm_mb" => cfg.stop.comm_mb = Some(float()?),
        "oracles" | "first_order" => {
            cfg.stop.first_order =
                Some(v.parse().map_err(|_| format!("bad stop value in {spec:?}"))?)
        }
        "acc" | "target_accuracy" => cfg.target_accuracy = Some(float()?),
        "sim_secs" => cfg.stop.sim_secs = Some(float()?),
        "wall_secs" => {
            // A wall-clock budget stops at a scheduler-dependent round, so
            // it cannot honor the sweep's parallel ≡ serial bit-identity
            // contract (diff_outcomes / --verify would flag spurious
            // divergence).  Virtual time is the deterministic equivalent.
            return Err(
                "stop axis wall_secs is wall-clock-nondeterministic under a parallel sweep; \
                 use sim_secs (virtual network time) instead"
                    .into(),
            );
        }
        _ => {
            return Err(format!(
                "unknown stop axis kind {k:?} (rounds|comm_mb|oracles|acc|sim_secs)"
            ))
        }
    }
    Ok(())
}

/// An expanded sweep: cells in deterministic grid order plus the shared
/// task table their [`TaskRef::Shared`] indices point into.  The table
/// holds one entry per (task, partition, dtype) — a dtype axis gets its
/// own widened instance of the *same* problem (identical f32 generation
/// streams, exact widening; see docs/DTYPE.md).
pub struct Grid {
    pub cells: Vec<Cell>,
    pub tasks: Vec<NativeTask>,
}

impl Grid {
    /// Borrow the task table as the width-tagged slice
    /// [`run_cells_slots`] / [`run_cells_observed`] take.
    pub fn slots(&self) -> Vec<TaskSlot<'_>> {
        self.tasks.iter().map(|t| t.slot()).collect()
    }
}

/// Resolve one dtype-axis value against the base config's width.
fn resolve_dtype(spec: &str, base: Dtype) -> Result<Dtype> {
    match spec {
        "default" | "" => Ok(base),
        s => Dtype::parse(s).map_err(anyhow::Error::msg),
    }
}

/// Expand a spec into its cell grid.  Axis order (outer→inner): task,
/// partition, topology, compressor, engine, stop, dtype, sampling rate,
/// generator, algorithm — so the rows to compare (same scenario,
/// different algorithm) sit adjacent.  Task data is generated once per
/// (task, partition, dtype) from the **base** seed: every cell of a
/// comparison group trains on identical shards no matter which other
/// cells exist.
///
/// Cell-id compatibility: the three scale/width axes only contribute an
/// id segment for **non-default** values (`+f64`, `+sr:0.5`, `+gen:on`),
/// so a grid that leaves them at `"default"` expands to exactly the
/// pre-axis ids — and hence the same derived seeds and cached results.
pub fn expand(spec: &SweepSpec) -> Result<Grid> {
    for (axis, len) in [
        ("algos", spec.algos.len()),
        ("tasks", spec.tasks.len()),
        ("topologies", spec.topologies.len()),
        ("compressors", spec.compressors.len()),
        ("partitions", spec.partitions.len()),
        ("engines", spec.engines.len()),
        ("stops", spec.stops.len()),
        ("dtypes", spec.dtypes.len()),
        ("sampling_rates", spec.sampling_rates.len()),
        ("generators", spec.generators.len()),
    ] {
        if len == 0 {
            anyhow::bail!("sweep axis {axis:?} is empty");
        }
    }
    // Pre-resolve the scale/width axes so bad values fail before any task
    // generation, and so the task table below knows which widths it needs.
    let mut dtypes: Vec<(&str, Dtype)> = Vec::new();
    for d in &spec.dtypes {
        dtypes.push((d.as_str(), resolve_dtype(d, spec.base.dtype)?));
    }
    let mut rates: Vec<(&str, Option<f64>)> = Vec::new();
    for r in &spec.sampling_rates {
        let v = match r.as_str() {
            "default" | "" => None,
            s => Some(s.parse::<f64>().map_err(|_| {
                anyhow::anyhow!("sampling_rates axis wants a number or \"default\", got {s:?}")
            })?),
        };
        rates.push((r.as_str(), v));
    }
    let mut gens: Vec<(&str, Option<bool>)> = Vec::new();
    for g in &spec.generators {
        let v = match g.as_str() {
            "default" | "" => None,
            "on" | "true" => Some(true),
            "off" | "false" => Some(false),
            s => anyhow::bail!("generators axis wants on|off|default, got {s:?}"),
        };
        gens.push((g.as_str(), v));
    }

    let mut tasks: Vec<NativeTask> = Vec::new();
    let mut task_idx: BTreeMap<(String, String, &'static str), usize> = BTreeMap::new();
    let mut cells = Vec::new();
    for task_spec in &spec.tasks {
        for part_spec in &spec.partitions {
            let part = Partition::parse(part_spec).map_err(anyhow::Error::msg)?;
            // One shared instance per width this grid's dtype axis uses.
            for &(_, dtype) in &dtypes {
                let key = (task_spec.clone(), part_spec.clone(), dtype.name());
                if let std::collections::btree_map::Entry::Vacant(e) = task_idx.entry(key) {
                    let t = match dtype {
                        Dtype::F32 => experiments::native_task_with(
                            task_spec,
                            spec.base.nodes,
                            spec.tiny,
                            spec.base.seed,
                            part,
                        )
                        .map(NativeTask::F32),
                        Dtype::F64 => experiments::native_task_f64(
                            task_spec,
                            spec.base.nodes,
                            spec.tiny,
                            spec.base.seed,
                            part,
                        )
                        .map(NativeTask::F64),
                    }
                    .with_context(|| format!("building task for axis value {task_spec:?}"))?;
                    tasks.push(t);
                    e.insert(tasks.len() - 1);
                }
            }
            for topo_spec in &spec.topologies {
                let topology =
                    Topology::parse(topo_spec, spec.base.seed).map_err(anyhow::Error::msg)?;
                for comp in &spec.compressors {
                    for engine in &spec.engines {
                        for stop in &spec.stops {
                            for &(dspec, dtype) in &dtypes {
                                for &(rspec, rate) in &rates {
                                    for &(gspec, genv) in &gens {
                                        for &algo in &spec.algos {
                                            let mut id = format!(
                                                "{task_spec}+{part_spec}+{topo_spec}+{comp}+{}+{stop}",
                                                engine.name(),
                                            );
                                            if dspec != "default" && !dspec.is_empty() {
                                                let _ = write!(id, "+{}", dtype.name());
                                            }
                                            if rspec != "default" && !rspec.is_empty() {
                                                let _ = write!(id, "+sr:{rspec}");
                                            }
                                            if gspec != "default" && !gspec.is_empty() {
                                                let _ = write!(id, "+gen:{gspec}");
                                            }
                                            let _ = write!(id, "+{}", algo.name());
                                            let mut cfg = if spec.calibrate {
                                                experiments::calibrated_cfg(
                                                    algo,
                                                    task_spec,
                                                    spec.base.rounds,
                                                    spec.base.nodes,
                                                )
                                            } else {
                                                let mut c = spec.base.clone();
                                                c.algorithm = algo;
                                                c
                                            };
                                            cfg.name = spec.base.name.clone();
                                            cfg.preset = task_spec.clone();
                                            cfg.nodes = spec.base.nodes;
                                            cfg.rounds = spec.base.rounds;
                                            cfg.eval_every = spec.base.eval_every;
                                            cfg.out_dir = spec.base.out_dir.clone();
                                            cfg.network = spec.base.network.clone();
                                            cfg.stop = spec.base.stop.clone();
                                            // Scale machinery rides along even
                                            // when the optimizer knobs come from
                                            // the calibration table: generator
                                            // transport, consensus estimator,
                                            // and per-round sampling are
                                            // base-config properties of the
                                            // whole grid, then overridden by
                                            // their axes.
                                            cfg.sampling = spec.base.sampling.clone();
                                            cfg.scale = spec.base.scale.clone();
                                            cfg.target_accuracy = spec.base.target_accuracy;
                                            cfg.topology = topology;
                                            cfg.partition = part;
                                            if comp != "default" && !comp.is_empty() {
                                                cfg.compressor = comp.clone();
                                            }
                                            cfg.network.mode = *engine;
                                            apply_stop(&mut cfg, stop)
                                                .map_err(anyhow::Error::msg)?;
                                            cfg.dtype = dtype;
                                            if let Some(r) = rate {
                                                cfg.sampling.rate = r;
                                            }
                                            if let Some(g) = genv {
                                                cfg.scale.generator = g;
                                            }
                                            cfg.seed = derive_seed(spec.base.seed, &id);
                                            let ti = task_idx[&(
                                                task_spec.clone(),
                                                part_spec.clone(),
                                                dtype.name(),
                                            )];
                                            cells.push(Cell {
                                                id,
                                                cfg,
                                                task: TaskRef::Shared(ti),
                                            });
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(Grid { cells, tasks })
}

/// Expand and execute a spec; outcomes come back in grid order.
pub fn run(spec: &SweepSpec, verbose: bool) -> Result<(Grid, Vec<CellOutcome>)> {
    let opts = ExecOpts {
        jobs: spec.jobs,
        console: Console::from_verbose(verbose),
        ..ExecOpts::default()
    };
    run_with(spec, &opts)
}

/// [`run`] with explicit execution options (telemetry sinks, console
/// routing).  `opts.jobs` overrides the spec's own parallelism knob.
pub fn run_with(spec: &SweepSpec, opts: &ExecOpts) -> Result<(Grid, Vec<CellOutcome>)> {
    let grid = expand(spec)?;
    let outcomes = run_cells_slots(&grid.cells, &grid.slots(), None, opts);
    Ok((grid, outcomes))
}

/// The report's `stop` column: every active stop condition, `|`-joined,
/// round cap always last — so two cells differing in ANY stop knob (a
/// varying `rounds:N` axis under a base `[stop]` budget included) get
/// distinct descriptions.
fn stop_desc(cfg: &ExperimentConfig) -> String {
    let mut parts = Vec::new();
    if let Some(a) = cfg.target_accuracy {
        parts.push(format!("acc:{a}"));
    }
    if let Some(mb) = cfg.stop.comm_mb {
        parts.push(format!("comm_mb:{mb}"));
    }
    if let Some(n) = cfg.stop.first_order {
        parts.push(format!("oracles:{n}"));
    }
    if let Some(s) = cfg.stop.sim_secs {
        parts.push(format!("sim_secs:{s}"));
    }
    if let Some(s) = cfg.stop.wall_secs {
        parts.push(format!("wall_secs:{s}"));
    }
    parts.push(format!("rounds:{}", cfg.rounds));
    parts.join("|")
}

/// A cell's comparison-group key: its id with the trailing
/// `+<algorithm>` stripped (the expansion and every harness put the
/// algorithm last in the id).  Ids without the suffix — single-algorithm
/// grids like fig5/ablation — group as themselves.
fn group_key(cell: &Cell) -> String {
    let suffix = format!("+{}", cell.cfg.algorithm.name());
    match cell.id.strip_suffix(&suffix) {
        Some(prefix) => prefix.to_string(),
        None => cell.id.clone(),
    }
}

fn sanitize_csv(s: &str) -> String {
    s.replace([',', '\n', '\r'], ";")
}

/// The aggregated per-cell CSV report.  Every field is a pure function of
/// (code, config, seed) — wall-clock columns are deliberately absent — so
/// the bytes are identical at any parallelism.
pub fn report_csv(cells: &[Cell], outcomes: &[CellOutcome]) -> String {
    assert_eq!(cells.len(), outcomes.len());
    let mut out = String::from(
        "cell,algo,task,topology,partition,compressor,engine,stop,seed,status,\
         rounds,gossip_rounds,comm_mb,total_bytes,messages,dropped,network_time_s,\
         first_order,second_order,evals,final_loss,final_accuracy,stop_reason,error\n",
    );
    for (c, o) in cells.iter().zip(outcomes) {
        let cfg = &c.cfg;
        let _ = write!(
            out,
            "{},{},{},{},{},{},{},{},{},",
            sanitize_csv(&c.id),
            cfg.algorithm.name(),
            sanitize_csv(&cfg.preset),
            cfg.topology.name(),
            cfg.partition.name(),
            sanitize_csv(&cfg.compressor),
            cfg.network.mode.name(),
            sanitize_csv(&stop_desc(cfg)),
            cfg.seed,
        );
        match &o.result {
            Ok(m) => {
                let last = m.final_point();
                let _ = writeln!(
                    out,
                    "ok,{},{},{:.6},{},{},{},{:.9},{},{},{},{:.9e},{:.6},{},",
                    last.map_or(0, |p| p.round),
                    m.ledger.gossip_rounds,
                    m.ledger.total_mb(),
                    m.ledger.total_bytes,
                    m.ledger.messages,
                    m.ledger.dropped_messages,
                    m.ledger.network_time_s,
                    m.oracles.first_order,
                    m.oracles.second_order,
                    m.oracles.evals,
                    last.map_or(f64::NAN, |p| p.loss),
                    last.map_or(f64::NAN, |p| p.accuracy),
                    m.stop_reason.map_or("none", |r| r.name()),
                );
            }
            Err(e) => {
                let _ = writeln!(out, "error,,,,,,,,,,,,,,{}", sanitize_csv(e));
            }
        }
    }
    out
}

/// The aggregated JSON report: per-cell deterministic metrics plus a
/// cross-cell `summary` grouping cells by everything-but-algorithm and
/// annotating each row with its communication / virtual-time ratio
/// against the group's best (min).  Wall-clock fields are excluded, so
/// the document is byte-identical at any parallelism.
pub fn report_json(cells: &[Cell], outcomes: &[CellOutcome]) -> Json {
    assert_eq!(cells.len(), outcomes.len());
    let cell_docs: Vec<Json> = cells
        .iter()
        .zip(outcomes)
        .map(|(c, o)| {
            let cfg = &c.cfg;
            let mut pairs = vec![
                ("cell", Json::str(&c.id)),
                ("algo", Json::str(cfg.algorithm.name())),
                ("task", Json::str(&cfg.preset)),
                ("topology", Json::str(cfg.topology.name())),
                ("partition", Json::str(&cfg.partition.name())),
                ("compressor", Json::str(&cfg.compressor)),
                ("engine", Json::str(cfg.network.mode.name())),
                ("stop", Json::str(&stop_desc(cfg))),
                // u64 seeds exceed f64's exact-integer range: keep as text.
                ("seed", Json::str(&cfg.seed.to_string())),
            ];
            match &o.result {
                Ok(m) => {
                    let last = m.final_point();
                    pairs.push(("status", Json::str("ok")));
                    pairs.push(("rounds", Json::num(last.map_or(0, |p| p.round) as f64)));
                    pairs.push((
                        "gossip_rounds",
                        Json::num(m.ledger.gossip_rounds as f64),
                    ));
                    pairs.push(("comm_mb", Json::num(m.ledger.total_mb())));
                    pairs.push(("total_bytes", Json::num(m.ledger.total_bytes as f64)));
                    pairs.push(("messages", Json::num(m.ledger.messages as f64)));
                    pairs.push((
                        "dropped_messages",
                        Json::num(m.ledger.dropped_messages as f64),
                    ));
                    pairs.push(("network_time_s", Json::num(m.ledger.network_time_s)));
                    pairs.push(("first_order", Json::num(m.oracles.first_order as f64)));
                    pairs.push(("second_order", Json::num(m.oracles.second_order as f64)));
                    pairs.push(("evals", Json::num(m.oracles.evals as f64)));
                    pairs.push((
                        "final_loss",
                        Json::num(last.map_or(f64::NAN, |p| p.loss)),
                    ));
                    pairs.push((
                        "final_accuracy",
                        Json::num(last.map_or(f64::NAN, |p| p.accuracy)),
                    ));
                    pairs.push((
                        "stop_reason",
                        Json::str(m.stop_reason.map_or("none", |r| r.name())),
                    ));
                }
                Err(e) => {
                    pairs.push(("status", Json::str("error")));
                    pairs.push(("error", Json::str(e)));
                }
            }
            Json::obj(pairs)
        })
        .collect();

    // Cross-cell summary: group by everything-but-algorithm; ratio each
    // row's comm volume and virtual time against the group minimum.  The
    // group key is the cell id minus its algorithm suffix — NOT a
    // reconstruction from config fields, because per-algorithm calibration
    // legitimately varies fields like the compressor within a comparison
    // group (C²DFB's calibrated top-k vs the baselines' default), and the
    // id is the one string that carries exactly the declared axis values.
    let mut groups: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    for (i, c) in cells.iter().enumerate() {
        groups.entry(group_key(c)).or_default().push(i);
    }
    let mut summary = Vec::new();
    for (key, members) in &groups {
        let ok: Vec<(&Cell, &RunMetrics)> = members
            .iter()
            .filter_map(|&i| outcomes[i].metrics().map(|m| (&cells[i], m)))
            .collect();
        if ok.is_empty() {
            continue;
        }
        let min_mb = ok
            .iter()
            .map(|(_, m)| m.ledger.total_mb())
            .fold(f64::INFINITY, f64::min);
        let min_t = ok
            .iter()
            .map(|(_, m)| m.ledger.network_time_s)
            .fold(f64::INFINITY, f64::min);
        let rows: Vec<Json> = ok
            .iter()
            .map(|(c, m)| {
                let last = m.final_point();
                Json::obj(vec![
                    ("algo", Json::str(c.cfg.algorithm.name())),
                    ("comm_mb", Json::num(m.ledger.total_mb())),
                    (
                        "comm_x_best",
                        Json::num(if min_mb > 0.0 {
                            m.ledger.total_mb() / min_mb
                        } else {
                            f64::NAN
                        }),
                    ),
                    ("network_time_s", Json::num(m.ledger.network_time_s)),
                    (
                        "time_x_best",
                        Json::num(if min_t > 0.0 {
                            m.ledger.network_time_s / min_t
                        } else {
                            f64::NAN
                        }),
                    ),
                    ("first_order", Json::num(m.oracles.first_order as f64)),
                    ("second_order", Json::num(m.oracles.second_order as f64)),
                    (
                        "final_loss",
                        Json::num(last.map_or(f64::NAN, |p| p.loss)),
                    ),
                    (
                        "final_accuracy",
                        Json::num(last.map_or(f64::NAN, |p| p.accuracy)),
                    ),
                ])
            })
            .collect();
        summary.push(Json::obj(vec![
            ("group", Json::str(key)),
            ("algos", Json::Arr(rows)),
        ]));
    }

    Json::obj(vec![
        ("format", Json::num(1.0)),
        ("cells", Json::Arr(cell_docs)),
        ("summary", Json::Arr(summary)),
    ])
}

/// Write `report.csv` + `report.json` under `dir` (created if needed).
pub fn write_report(
    dir: &Path,
    cells: &[Cell],
    outcomes: &[CellOutcome],
) -> Result<(PathBuf, PathBuf)> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating report dir {}", dir.display()))?;
    let csv = dir.join("report.csv");
    std::fs::write(&csv, report_csv(cells, outcomes))
        .with_context(|| format!("writing {}", csv.display()))?;
    let json = dir.join("report.json");
    std::fs::write(&json, report_json(cells, outcomes).to_string() + "\n")
        .with_context(|| format!("writing {}", json.display()))?;
    Ok((csv, json))
}

/// Compare two outcome sets on every deterministic field — bit-level for
/// floats, exact for counters and stop reasons; wall-clock fields exempt.
/// Returns the first difference, or `None` when the sets are
/// bit-identical (the `parallel ≡ serial` proof obligation).
pub fn diff_outcomes(a: &[CellOutcome], b: &[CellOutcome]) -> Option<String> {
    if a.len() != b.len() {
        return Some(format!("cell count differs: {} vs {}", a.len(), b.len()));
    }
    for (x, y) in a.iter().zip(b) {
        if x.id != y.id {
            return Some(format!("cell order differs: {:?} vs {:?}", x.id, y.id));
        }
        match (&x.result, &y.result) {
            (Err(e1), Err(e2)) => {
                if e1 != e2 {
                    return Some(format!("{}: errors differ: {e1:?} vs {e2:?}", x.id));
                }
            }
            (Ok(_), Err(e)) | (Err(e), Ok(_)) => {
                return Some(format!("{}: ok on one side, error on the other: {e}", x.id));
            }
            (Ok(m1), Ok(m2)) => {
                if let Some(d) = diff_metrics(&x.id, m1, m2) {
                    return Some(d);
                }
            }
        }
        // Telemetry is part of the determinism contract: when both sides
        // traced, the JSONL chunks must match byte for byte.
        if x.trace != y.trace {
            return Some(format!("{}: JSONL trace chunks differ", x.id));
        }
    }
    None
}

fn diff_metrics(id: &str, a: &RunMetrics, b: &RunMetrics) -> Option<String> {
    let exact = [
        ("total_bytes", a.ledger.total_bytes, b.ledger.total_bytes),
        ("messages", a.ledger.messages, b.ledger.messages),
        ("gossip_rounds", a.ledger.gossip_rounds, b.ledger.gossip_rounds),
        ("dropped", a.ledger.dropped_messages, b.ledger.dropped_messages),
        (
            "network_time_bits",
            a.ledger.network_time_s.to_bits(),
            b.ledger.network_time_s.to_bits(),
        ),
        ("first_order", a.oracles.first_order, b.oracles.first_order),
        ("second_order", a.oracles.second_order, b.oracles.second_order),
        ("evals", a.oracles.evals, b.oracles.evals),
    ];
    for (k, va, vb) in exact {
        if va != vb {
            return Some(format!("{id}: {k} {va} vs {vb}"));
        }
    }
    let (ra, rb) = (
        a.stop_reason.map(|r| r.name()),
        b.stop_reason.map(|r| r.name()),
    );
    if ra != rb {
        return Some(format!("{id}: stop reason {ra:?} vs {rb:?}"));
    }
    if a.trace.len() != b.trace.len() {
        return Some(format!(
            "{id}: trace length {} vs {}",
            a.trace.len(),
            b.trace.len()
        ));
    }
    for (i, (p, q)) in a.trace.iter().zip(&b.trace).enumerate() {
        let fields = [
            ("round", p.round as u64, q.round as u64),
            ("comm_mb", p.comm_mb.to_bits(), q.comm_mb.to_bits()),
            ("sim_time", p.sim_time_s.to_bits(), q.sim_time_s.to_bits()),
            ("loss", p.loss.to_bits(), q.loss.to_bits()),
            ("accuracy", p.accuracy.to_bits(), q.accuracy.to_bits()),
            ("grad_norm", p.grad_norm.to_bits(), q.grad_norm.to_bits()),
            (
                "consensus",
                p.consensus_err.to_bits(),
                q.consensus_err.to_bits(),
            ),
            ("dropped", p.dropped_msgs, q.dropped_msgs),
        ];
        for (k, va, vb) in fields {
            if va != vb {
                return Some(format!("{id}[{i}]: {k} differs ({va} vs {vb})"));
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derive_seed_is_stable_and_id_sensitive() {
        // The docs/SWEEP.md contract: a pure function of (base, id),
        // sensitive to both (changing the hash is a fixture-breaking
        // change and must be deliberate).
        assert_eq!(derive_seed(42, "a+b+c"), derive_seed(42, "a+b+c"));
        assert_ne!(derive_seed(42, "a+b+c"), derive_seed(43, "a+b+c"));
        assert_ne!(derive_seed(42, "a+b+c"), derive_seed(42, "a+b+d"));
        // Independent of any global state: pure function of its inputs.
        let first = derive_seed(7, "cell");
        for _ in 0..3 {
            assert_eq!(derive_seed(7, "cell"), first);
        }
    }

    #[test]
    fn parse_list_accepts_strings_and_arrays() {
        let v = TomlValue::Str("a, b,c".into());
        assert_eq!(parse_list(&v).unwrap(), vec!["a", "b", "c"]);
        let v = TomlValue::Arr(vec![
            TomlValue::Str("x".into()),
            TomlValue::Str("y".into()),
        ]);
        assert_eq!(parse_list(&v).unwrap(), vec!["x", "y"]);
        assert!(parse_list(&TomlValue::Int(3)).is_err());
    }

    #[test]
    fn apply_stop_covers_every_kind() {
        let mut cfg = ExperimentConfig::default();
        apply_stop(&mut cfg, "rounds:7").unwrap();
        assert_eq!(cfg.rounds, 7);
        apply_stop(&mut cfg, "comm_mb:1.5").unwrap();
        assert_eq!(cfg.stop.comm_mb, Some(1.5));
        apply_stop(&mut cfg, "oracles:5000").unwrap();
        assert_eq!(cfg.stop.first_order, Some(5000));
        apply_stop(&mut cfg, "acc:0.7").unwrap();
        assert_eq!(cfg.target_accuracy, Some(0.7));
        apply_stop(&mut cfg, "sim_secs:2.5").unwrap();
        assert_eq!(cfg.stop.sim_secs, Some(2.5));
        apply_stop(&mut cfg, "rounds").unwrap(); // no-op
        assert!(apply_stop(&mut cfg, "bogus:1").is_err());
        assert!(apply_stop(&mut cfg, "comm_mb:x").is_err());
        // Wall-clock stops are scheduler-dependent: rejected with a hint.
        let err = apply_stop(&mut cfg, "wall_secs:3").unwrap_err();
        assert!(err.contains("sim_secs"), "{err}");
        assert_eq!(cfg.stop.wall_secs, None);
    }

    #[test]
    fn tiny_grid_expands_with_unique_ids_and_derived_seeds() {
        let spec = SweepSpec::tiny();
        let grid = expand(&spec).unwrap();
        assert_eq!(grid.cells.len(), 2 * 2 * 2 * 2, "2 algos×2 tasks×2 topos×2 engines");
        assert_eq!(grid.tasks.len(), 2, "one task instance per (task, partition)");
        let mut ids: Vec<&str> = grid.cells.iter().map(|c| c.id.as_str()).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), grid.cells.len(), "cell ids must be unique");
        for c in &grid.cells {
            assert_eq!(c.cfg.seed, derive_seed(spec.base.seed, &c.id));
            c.cfg.validate().unwrap_or_else(|e| panic!("{}: {e}", c.id));
        }
    }

    #[test]
    fn scale_axes_route_into_cells_and_keep_default_ids() {
        // Default axis values add no id segment: the grid expands to the
        // exact pre-axis ids (and hence the same derived seeds).
        let grid = expand(&SweepSpec::tiny()).unwrap();
        assert!(grid.cells.iter().all(|c| {
            !c.id.contains("+f32") && !c.id.contains("+sr:") && !c.id.contains("+gen:")
        }));

        let mut spec = SweepSpec::tiny();
        spec.algos = vec![Algorithm::C2dfb];
        spec.tasks = vec!["quadratic".into()];
        spec.topologies = vec!["ring".into()];
        spec.engines = vec![NetMode::Sync];
        spec.dtypes = vec!["default".into(), "f64".into()];
        spec.sampling_rates = vec!["default".into(), "0.5".into()];
        spec.generators = vec!["default".into(), "on".into()];
        let grid = expand(&spec).unwrap();
        assert_eq!(grid.cells.len(), 2 * 2 * 2, "dtype × rate × generator");
        assert_eq!(grid.tasks.len(), 2, "one shared instance per width");

        let mut ids: Vec<&str> = grid.cells.iter().map(|c| c.id.as_str()).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), grid.cells.len(), "axis segments keep ids unique");

        for c in &grid.cells {
            assert_eq!(c.id.contains("+f64"), c.cfg.dtype == Dtype::F64);
            assert_eq!(c.id.contains("+sr:0.5"), c.cfg.sampling.rate == 0.5);
            assert_eq!(c.id.contains("+gen:on"), c.cfg.scale.generator);
            assert_eq!(c.cfg.seed, derive_seed(spec.base.seed, &c.id));
            c.cfg.validate().unwrap_or_else(|e| panic!("{}: {e}", c.id));
            // Each cell binds to the task entry of its own width.
            let TaskRef::Shared(ti) = c.task else {
                panic!("native sweeps never use the registry lane")
            };
            match (&grid.tasks[ti], c.cfg.dtype) {
                (NativeTask::F32(_), Dtype::F32) | (NativeTask::F64(_), Dtype::F64) => {}
                _ => panic!("{}: cell width disagrees with its task slot", c.id),
            }
        }

        // Bad axis values fail expansion with a pointed message.
        spec.dtypes = vec!["f16".into()];
        assert!(expand(&spec).is_err());
        spec.dtypes = vec!["default".into()];
        spec.sampling_rates = vec!["fast".into()];
        assert!(expand(&spec).is_err());
        spec.sampling_rates = vec!["default".into()];
        spec.generators = vec!["maybe".into()];
        assert!(expand(&spec).is_err());
    }

    #[test]
    fn sweep_toml_roundtrip() {
        let spec = SweepSpec::from_toml_str(
            r#"
[experiment]
nodes = 6
rounds = 12
seed = 9

[sweep]
algos = "c2dfb,mdbo"
tasks = "quadratic"
topologies = "ring,2hop"
engines = "sync,sim"
stops = "rounds,comm_mb:2.5"
jobs = 3
calibrate = false
"#,
        )
        .unwrap();
        assert_eq!(spec.base.nodes, 6);
        assert_eq!(spec.base.rounds, 12);
        assert_eq!(spec.base.seed, 9);
        assert_eq!(spec.algos, vec![Algorithm::C2dfb, Algorithm::Mdbo]);
        assert_eq!(spec.topologies, vec!["ring", "2hop"]);
        assert_eq!(spec.engines, vec![NetMode::Sync, NetMode::Event]);
        assert_eq!(spec.stops, vec!["rounds", "comm_mb:2.5"]);
        assert_eq!(spec.jobs, 3);
        assert!(!spec.calibrate);
        assert!(SweepSpec::from_toml_str("[sweep]\nbogus = 1\n").is_err());
    }

    #[test]
    fn report_csv_handles_errors_without_commas() {
        let cell = Cell {
            id: "x".into(),
            cfg: ExperimentConfig::default(),
            task: TaskRef::Shared(0),
        };
        let out = CellOutcome::bare(
            "x".into(),
            Err("boom, with commas\nand newlines".into()),
        );
        let csv = report_csv(&[cell], &[out]);
        let row = csv.lines().nth(1).unwrap();
        assert!(row.contains("error"));
        assert!(row.contains("boom; with commas;and newlines"));
        assert_eq!(
            row.split(',').count(),
            csv.lines().next().unwrap().split(',').count()
        );
    }
}
