//! Per-table / per-figure harnesses reproducing the paper's evaluation.
//!
//! Each harness builds the exact workload grid from §6 / Appendix C, runs
//! every (algorithm × topology × heterogeneity) cell, prints the rows the
//! paper reports, and writes the full traces as CSV under `runs/<id>/`.
//! Absolute numbers differ from the paper (synthetic data, simulated
//! network — see DESIGN.md §Substitutions); the comparisons (who wins, by
//! what order of magnitude) are the reproduction target.

use crate::algorithms::RunObserver;
use crate::config::{Algorithm, ExperimentConfig};
use crate::coordinator::{summarize, write_runs, Runner};
use crate::data::partition::Partition;
use crate::metrics::{RunMetrics, TracePoint};
use crate::runtime::ArtifactRegistry;
use crate::sim::{NetConfig, NetMode};
use crate::tasks::{BilevelTask, HyperRepTask, LogRegTask, QuadraticTask};
use crate::topology::Topology;
use anyhow::Result;

/// Harness observer: optionally prints a progress line per trace point and
/// aborts any run whose loss goes non-finite (divergence guard) — the
/// runner then records `stop_reason = observer_abort` instead of burning
/// the remaining round/communication budget on NaNs.
#[derive(Default)]
pub struct HarnessObserver {
    /// Print one line per recorded trace point.
    pub verbose: bool,
}

impl RunObserver for HarnessObserver {
    fn on_trace(&mut self, algo: &str, p: &TracePoint) -> bool {
        if self.verbose {
            println!(
                "    [{algo:8}] round {:5}  comm {:9.3} MB  loss {:.5}  acc {:.3}",
                p.round, p.comm_mb, p.loss, p.accuracy
            );
        }
        if !p.loss.is_finite() {
            eprintln!("    [{algo}] aborting run: non-finite loss at round {}", p.round);
            return false;
        }
        true
    }
}

/// Run one harness cell against the artifact registry with the divergence
/// guard attached.
fn run_cell(reg: &ArtifactRegistry, cfg: &ExperimentConfig, o: &HarnessOpts) -> Result<RunMetrics> {
    let mut guard = HarnessObserver { verbose: o.verbose };
    Runner::new(cfg).registry(reg).observer(&mut guard).run()
}

/// Scaling knobs shared by all harnesses (CLI: --rounds, --verbose,
/// --preset-suffix).
#[derive(Clone, Debug)]
pub struct HarnessOpts {
    /// Outer rounds per run (paper: ~1000 coeff / ~100 hyperrep; default
    /// here is sized for minutes-scale runs with the same ordering).
    pub rounds: usize,
    /// Preset override, e.g. "coeff_tiny" for smoke runs.
    pub coeff_preset: String,
    pub hyperrep_preset: String,
    pub out_dir: String,
    pub seed: u64,
    /// Stream one progress line per recorded trace point (CLI: --verbose).
    pub verbose: bool,
}

impl Default for HarnessOpts {
    fn default() -> Self {
        HarnessOpts {
            rounds: 120,
            coeff_preset: "coeff".into(),
            hyperrep_preset: "hyperrep".into(),
            out_dir: "runs".into(),
            seed: 42,
            verbose: false,
        }
    }
}

fn coeff_cfg(o: &HarnessOpts) -> ExperimentConfig {
    ExperimentConfig {
        preset: o.coeff_preset.clone(),
        rounds: o.rounds,
        seed: o.seed,
        out_dir: o.out_dir.clone(),
        eval_every: (o.rounds / 40).max(1),
        // Paper Appendix C.1 for C²DFB on coefficient tuning; the step
        // sizes are rescaled for the synthetic corpus (lr 1 with λ=10 sits
        // past the compressed-tracking stability edge on it; the baselines
        // get the same treatment — see EXPERIMENTS.md §Calibration).
        eta_out: 0.5,
        eta_in: 0.2,
        gamma_out: 0.5,
        gamma_in: 0.5,
        lambda: 10.0,
        inner_steps: 15,
        compressor: "topk:0.2".into(),
        // Noise calibrated so the optimal linear classifier sits near 85%
        // and the 70% target separates the methods — see EXPERIMENTS.md
        // §Calibration.
        data_noise: 1.2,
        ..ExperimentConfig::default()
    }
}

fn hyperrep_cfg(o: &HarnessOpts) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::hyperrep_defaults();
    cfg.preset = o.hyperrep_preset.clone();
    cfg.rounds = o.rounds;
    cfg.seed = o.seed;
    cfg.out_dir = o.out_dir.clone();
    cfg.eval_every = (o.rounds / 60).max(1);
    // Calibrated for the synthetic MNIST-like corpus (He-init backbone
    // features give the head problem λ·L ≈ 160; the paper's lr 1 diverges).
    cfg.eta_out = 0.02;
    cfg.eta_in = 0.05;
    cfg.data_noise = 0.45;
    cfg
}

/// Baselines need smaller upper steps (no tracking-normalized scale) —
/// tuned so each baseline converges on the synthetic corpus.
fn tune_for(algo: Algorithm, cfg: &mut ExperimentConfig) {
    cfg.algorithm = algo;
    match algo {
        Algorithm::C2dfb | Algorithm::C2dfbNc => {}
        Algorithm::Madsbo => {
            cfg.eta_out *= 2.0; // moving average damps the step
            cfg.eta_in *= 0.5;
        }
        Algorithm::Mdbo => {
            // MDBO's untracked gossip SGD has an O(η·heterogeneity/ρ) bias
            // neighbourhood: it needs a much smaller lower-level step to
            // make progress under h = 0.8 (and is correspondingly slow —
            // the paper's Table 1 shows the same 1-2 order gap).
            cfg.eta_in *= 0.25;
        }
    }
}

/// **Table 1** — comm volume (MB) + training time (s) to reach the target
/// test accuracy on the coefficient-tuning task, ring topology,
/// heterogeneous (h = 0.8).
pub fn table1(reg: &ArtifactRegistry, o: &HarnessOpts, target_acc: f64) -> Result<Vec<RunMetrics>> {
    println!("== Table 1: comm volume & time to {:.0}% test accuracy (ring, het 0.8) ==", target_acc * 100.0);
    let mut runs = Vec::new();
    for algo in [Algorithm::C2dfb, Algorithm::Madsbo, Algorithm::Mdbo] {
        let mut cfg = coeff_cfg(o);
        tune_for(algo, &mut cfg);
        cfg.name = "table1".into();
        cfg.topology = Topology::Ring;
        cfg.partition = Partition::Heterogeneous { h: 0.8 };
        cfg.target_accuracy = Some(target_acc);
        let m = run_cell(reg, &cfg, o)?;
        println!("  {}", summarize(&m));
        runs.push(m);
    }
    println!("\n| Algo   | Comm. Vol. (MB) | Sim. Time (s) | Wall Time (s) | reached |");
    println!("|--------|-----------------|---------------|---------------|---------|");
    for m in &runs {
        let hit = m.time_to_accuracy(target_acc);
        let (mb, st, wt, reached) = match hit {
            Some(p) => (p.comm_mb, p.sim_time_s + p.wall_time_s, p.wall_time_s, "yes"),
            None => {
                let p = m.final_point().unwrap();
                (p.comm_mb, p.sim_time_s + p.wall_time_s, p.wall_time_s, "no")
            }
        };
        println!("| {:6} | {:15.2} | {:13.2} | {:13.2} | {:7} |", m.algo, mb, st, wt, reached);
    }
    write_runs(&o.out_dir, "table1", &runs)?;
    Ok(runs)
}

/// **Figures 2 & 4** — coefficient tuning: accuracy/loss vs comm volume,
/// time, and rounds across {ring, 2hop, ER(0.4)} × {iid, het 0.8} for
/// C²DFB vs MADSBO vs MDBO.  (Fig. 4 is the same traces plotted against
/// rounds; the CSVs contain all three x-axes.)
pub fn fig2(reg: &ArtifactRegistry, o: &HarnessOpts) -> Result<Vec<RunMetrics>> {
    println!("== Fig 2/4: coefficient tuning across topologies & heterogeneity ==");
    grid(
        reg,
        o,
        "fig2",
        coeff_cfg(o),
        &[Algorithm::C2dfb, Algorithm::Madsbo, Algorithm::Mdbo],
    )
}

/// **Figures 3 & 6** — hyper-representation: loss vs comm volume / rounds
/// across topologies × heterogeneity for C²DFB vs MADSBO vs C²DFB(nc).
pub fn fig3(reg: &ArtifactRegistry, o: &HarnessOpts) -> Result<Vec<RunMetrics>> {
    println!("== Fig 3/6: hyper-representation across topologies & heterogeneity ==");
    grid(
        reg,
        o,
        "fig3",
        hyperrep_cfg(o),
        &[Algorithm::C2dfb, Algorithm::Madsbo, Algorithm::C2dfbNc],
    )
}

fn grid(
    reg: &ArtifactRegistry,
    o: &HarnessOpts,
    id: &str,
    base: ExperimentConfig,
    algos: &[Algorithm],
) -> Result<Vec<RunMetrics>> {
    let topologies = [
        Topology::Ring,
        Topology::TwoHopRing,
        Topology::ErdosRenyi { p_milli: 400, seed: o.seed },
    ];
    let partitions = [Partition::Iid, Partition::Heterogeneous { h: 0.8 }];
    let mut runs = Vec::new();
    for topo in topologies {
        for part in partitions {
            for &algo in algos {
                let mut cfg = base.clone();
                tune_for(algo, &mut cfg);
                cfg.name = id.into();
                cfg.topology = topo;
                cfg.partition = part;
                let m = run_cell(reg, &cfg, o)?;
                println!("  {}", summarize(&m));
                runs.push(m);
            }
        }
    }
    write_runs(&o.out_dir, id, &runs)?;
    Ok(runs)
}

/// **Figure 5** — sensitivity of C²DFB on coefficient tuning: (a) inner
/// loops K, (b) compression ratio, (c) multiplier λ (σ).
pub fn fig5(reg: &ArtifactRegistry, o: &HarnessOpts) -> Result<Vec<RunMetrics>> {
    println!("== Fig 5: C²DFB sensitivity (K, compression ratio, λ) ==");
    let mut runs = Vec::new();

    for k in [1usize, 5, 15, 30] {
        let mut cfg = coeff_cfg(o);
        cfg.name = format!("fig5_K{k}");
        cfg.inner_steps = k;
        let m = run_cell(reg, &cfg, o)?;
        println!("  K={k:3}  {}", summarize(&m));
        runs.push(m);
    }
    for ratio in ["0.05", "0.1", "0.2", "0.5", "1.0"] {
        let mut cfg = coeff_cfg(o);
        cfg.name = format!("fig5_ratio{ratio}");
        cfg.compressor = format!("topk:{ratio}");
        let m = run_cell(reg, &cfg, o)?;
        println!("  ratio={ratio:5}  {}", summarize(&m));
        runs.push(m);
    }
    for lam in [1.0, 10.0, 50.0, 100.0] {
        let mut cfg = coeff_cfg(o);
        cfg.name = format!("fig5_lam{lam}");
        cfg.lambda = lam;
        let m = run_cell(reg, &cfg, o)?;
        println!("  λ={lam:5}  {}", summarize(&m));
        runs.push(m);
    }
    // Label runs uniquely before writing (RunMetrics label comes from cfg
    // label; augment with name).
    write_runs(&o.out_dir, "fig5", &runs)?;
    Ok(runs)
}

/// Per-algorithm settings that converge on the analytic quadratic task
/// (mirrors the algorithm test suites; no artifacts needed).
fn quad_cfg_for(algo: Algorithm, rounds: usize, nodes: usize, o: &HarnessOpts) -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        algorithm: algo,
        nodes,
        rounds,
        seed: o.seed,
        out_dir: o.out_dir.clone(),
        eval_every: (rounds / 10).max(1),
        gamma_out: 0.8,
        ..ExperimentConfig::default()
    };
    match algo {
        Algorithm::C2dfb | Algorithm::C2dfbNc => {
            cfg.inner_steps = 15;
            cfg.eta_out = 0.3;
            cfg.eta_in = 0.4;
            cfg.gamma_in = 0.6;
            cfg.lambda = 50.0;
            cfg.compressor = "topk:0.5".into();
        }
        Algorithm::Madsbo => {
            cfg.inner_steps = 10;
            cfg.eta_out = 0.8;
            cfg.eta_in = 0.3;
        }
        Algorithm::Mdbo => {
            cfg.inner_steps = 10;
            cfg.eta_out = 0.4;
            cfg.eta_in = 0.3;
        }
    }
    cfg
}

/// **netsweep** — C²DFB vs the baselines across network regimes on the
/// analytic quadratic task (runs without artifacts): ideal LAN, WAN
/// latency/bandwidth, message loss, stragglers, and a time-varying
/// topology.  This is the comparison axis the communication-complexity
/// line of work (Zhang et al.; Chen et al.) argues about — how much of
/// C²DFB's compressed-residual advantage survives a hostile network.
///
/// Also doubles as the sim engine's acceptance check: the `sync` and
/// `ideal-sim` rows must agree exactly (bytes, rounds, final loss).
pub fn netsweep(o: &HarnessOpts, tiny: bool) -> Result<Vec<RunMetrics>> {
    let (nodes, dim) = if tiny { (6, 8) } else { (8, 32) };
    let rounds = o.rounds;
    println!(
        "== netsweep: network regimes on the quadratic task (m={nodes}, d={dim}, {rounds} rounds) =="
    );
    let task = QuadraticTask::generate(nodes, dim, 0.8, o.seed);

    let event = NetConfig { mode: NetMode::Event, ..NetConfig::default() };
    let dynamic = {
        let mut n = event.clone();
        n.parse_schedule("100:2hop,300:er:0.4", o.seed)
            .map_err(anyhow::Error::msg)?;
        n
    };
    let regimes: Vec<(&str, NetConfig)> = vec![
        ("sync", NetConfig::default()),
        ("ideal-sim", event.clone()),
        (
            "wan",
            NetConfig {
                latency_s: 0.04,
                jitter_s: 0.01,
                bandwidth_bytes_per_s: 12.5e6,
                ..event.clone()
            },
        ),
        ("lossy", NetConfig { drop_rate: 0.1, ..event.clone() }),
        (
            "straggler",
            NetConfig {
                straggler_frac: 0.25,
                straggler_delay_s: 0.05,
                ..event.clone()
            },
        ),
        ("dynamic", dynamic),
    ];
    let algos = [Algorithm::C2dfb, Algorithm::Madsbo, Algorithm::Mdbo];

    let mut runs = Vec::new();
    println!(
        "\n| regime    | algo   | comm (MB) | gossip rounds | virtual time (s) | dropped | final loss |"
    );
    println!(
        "|-----------|--------|-----------|---------------|------------------|---------|------------|"
    );
    for (regime, netcfg) in &regimes {
        for algo in algos {
            let mut cfg = quad_cfg_for(algo, rounds, nodes, o);
            cfg.name = format!("netsweep_{regime}");
            cfg.network = netcfg.clone();
            let mut guard = HarnessObserver { verbose: o.verbose };
            let m = Runner::new(&cfg)
                .shared_task(&task)
                .observer(&mut guard)
                .run()?;
            let last = m.final_point().expect("run produced no trace");
            println!(
                "| {:9} | {:6} | {:9.3} | {:13} | {:16.4} | {:7} | {:10.5} |",
                regime,
                m.algo,
                m.ledger.total_mb(),
                m.ledger.gossip_rounds,
                m.ledger.network_time_s,
                m.ledger.dropped_messages,
                last.loss
            );
            runs.push(m);
        }
    }

    // Benign-network equivalence: event engine ≡ synchronous engine.
    let mut all_ok = true;
    for i in 0..algos.len() {
        let (s, e) = (&runs[i], &runs[algos.len() + i]);
        let ok = s.ledger.total_bytes == e.ledger.total_bytes
            && s.ledger.gossip_rounds == e.ledger.gossip_rounds
            && s.final_point().map(|p| p.loss.to_bits())
                == e.final_point().map(|p| p.loss.to_bits());
        all_ok &= ok;
        println!(
            "{} sync ≡ ideal-sim ({}): bytes/rounds/loss {}",
            if ok { "OK " } else { "ERR" },
            s.algo,
            if ok { "identical" } else { "DIFFER" }
        );
    }
    if !all_ok {
        anyhow::bail!("event engine diverged from the synchronous engine on a benign network");
    }
    write_runs(&o.out_dir, "netsweep", &runs)?;
    Ok(runs)
}

/// Build a native (artifact-free) task by name for the no-artifact
/// harnesses: `"quadratic"` (the analytic default), `"logreg"`
/// (hyperparameter tuning, `dir:0.5` Dirichlet label skew) or
/// `"hyperrep"` (linear hyper-representation).  Sizes scale with `tiny`.
pub fn native_task(
    spec: &str,
    nodes: usize,
    tiny: bool,
    seed: u64,
) -> Result<Box<dyn BilevelTask + Sync>> {
    let part = crate::data::partition::Partition::Dirichlet { alpha: 0.5 };
    Ok(match spec {
        "quadratic" | "quad" => {
            let dim = if tiny { 8 } else { 32 };
            Box::new(QuadraticTask::generate(nodes, dim, 0.8, seed))
        }
        "logreg" => {
            let (d, n_tr, n_val) = if tiny { (12, 24, 12) } else { (48, 80, 40) };
            Box::new(LogRegTask::generate(nodes, d, 4, n_tr, n_val, part, 0.4, seed))
        }
        "hyperrep" => {
            let (p, k, n_tr, n_val) = if tiny { (12, 4, 20, 10) } else { (36, 8, 64, 32) };
            Box::new(HyperRepTask::generate(
                nodes, p, k, 4, n_tr, n_val, part, 0.3, seed,
            ))
        }
        other => anyhow::bail!("unknown native task {other:?} (quadratic|logreg|hyperrep)"),
    })
}

/// Per-algorithm settings for the native data tasks (smaller steps than
/// the quadratic: CE/ridge curvature, λ = 10 like the paper).
fn native_cfg_for(
    algo: Algorithm,
    spec: &str,
    rounds: usize,
    nodes: usize,
    o: &HarnessOpts,
) -> ExperimentConfig {
    if matches!(spec, "quadratic" | "quad") {
        return quad_cfg_for(algo, rounds, nodes, o);
    }
    let mut cfg = ExperimentConfig {
        algorithm: algo,
        nodes,
        rounds,
        seed: o.seed,
        out_dir: o.out_dir.clone(),
        eval_every: (rounds / 10).max(1),
        gamma_out: 0.8,
        gamma_in: 0.6,
        inner_steps: 5,
        lambda: 10.0,
        compressor: "topk:0.5".into(),
        ..ExperimentConfig::default()
    };
    match spec {
        "logreg" => {
            cfg.eta_out = 0.2;
            cfg.eta_in = 0.3;
        }
        _ => {
            // hyperrep: the embedded-feature Gram matrix has the largest
            // curvature; keep both levels conservative.
            cfg.eta_out = 0.05;
            cfg.eta_in = 0.05;
        }
    }
    if matches!(algo, Algorithm::Mdbo) {
        cfg.eta_in *= 0.5; // untracked gossip SGD needs smaller LL steps
    }
    cfg
}

/// **budget** — the equal-communication comparison behind the paper's
/// efficiency claim: run all four algorithms on a native task until each
/// has spent the same communication budget (MB), then compare where they
/// got.  This makes the Table-1 / Fig-2 "who wins at equal communication"
/// reading a first-class run instead of post-hoc trace slicing (cf. Zhang
/// et al. 2023's framing of decentralized bilevel baselines by
/// communication complexity).  Needs no artifacts; `task_spec` selects
/// quadratic (default), logreg or hyperrep via [`native_task`].
///
/// Every run carries a [`crate::metrics::StopCondition::CommBudgetMb`]
/// plus a generous round cap as a non-progress guard; the printed `stop`
/// column should read `comm_budget` for every row.
pub fn budget(o: &HarnessOpts, budget_mb: f64, tiny: bool) -> Result<Vec<RunMetrics>> {
    budget_on(o, budget_mb, tiny, "quadratic")
}

/// [`budget`] on an explicit native task.
pub fn budget_on(
    o: &HarnessOpts,
    budget_mb: f64,
    tiny: bool,
    task_spec: &str,
) -> Result<Vec<RunMetrics>> {
    let nodes = if tiny { 6 } else { 8 };
    let task = native_task(task_spec, nodes, tiny, o.seed)?;
    println!(
        "== budget: all algorithms to {budget_mb} MB of communication \
         ({}, m={nodes}, round cap {}) ==",
        task.name(),
        o.rounds
    );
    let algos = [
        Algorithm::C2dfb,
        Algorithm::C2dfbNc,
        Algorithm::Madsbo,
        Algorithm::Mdbo,
    ];

    let mut runs = Vec::new();
    for algo in algos {
        let mut cfg = native_cfg_for(algo, task_spec, o.rounds, nodes, o);
        cfg.name = format!("budget_{task_spec}");
        cfg.stop.comm_mb = Some(budget_mb);
        // Check the budget every round so each run lands within one outer
        // round of the budget (the stop contract is one eval interval).
        cfg.eval_every = 1;
        let mut guard = HarnessObserver { verbose: o.verbose };
        let m = Runner::new(&cfg)
            .shared_task(task.as_ref())
            .observer(&mut guard)
            .run()?;
        println!("  {}", summarize(&m));
        runs.push(m);
    }

    println!("\n| algo     | comm (MB) | rounds | oracles 1st | oracles 2nd | final loss | stop        |");
    println!("|----------|-----------|--------|-------------|-------------|------------|-------------|");
    for m in &runs {
        let last = m.final_point().expect("run produced no trace");
        println!(
            "| {:8} | {:9.3} | {:6} | {:11} | {:11} | {:10.5} | {:11} |",
            m.algo,
            m.ledger.total_mb(),
            last.round,
            m.oracles.first_order,
            m.oracles.second_order,
            last.loss,
            m.stop_reason.map_or("-", |s| s.name()),
        );
    }
    write_runs(&o.out_dir, "budget", &runs)?;
    Ok(runs)
}

/// Compressor ablation beyond the paper: top-k vs rand-k vs qsgd vs dense
/// at matched settings (DESIGN.md "extension" item).
pub fn compressor_ablation(reg: &ArtifactRegistry, o: &HarnessOpts) -> Result<Vec<RunMetrics>> {
    println!("== Ablation: compressor family (C²DFB, coeff, ring, het) ==");
    let mut runs = Vec::new();
    for comp in ["topk:0.2", "randk:0.2", "qsgd:16", "none"] {
        let mut cfg = coeff_cfg(o);
        cfg.name = format!("ablate_{}", comp.replace(':', ""));
        cfg.partition = Partition::Heterogeneous { h: 0.8 };
        cfg.compressor = comp.into();
        let m = run_cell(reg, &cfg, o)?;
        println!("  {comp:10}  {}", summarize(&m));
        runs.push(m);
    }
    write_runs(&o.out_dir, "ablation_compressor", &runs)?;
    Ok(runs)
}
