//! Per-table / per-figure harnesses reproducing the paper's evaluation.
//!
//! Each harness is now a **thin grid declaration** over the
//! [`sweep`](super::sweep) orchestrator: it builds the exact workload grid
//! from §6 / Appendix C as a `Vec<Cell>`, hands the cells to
//! [`sweep::run_cells`] (which executes them — concurrently when the
//! tasks are thread-shareable and `--jobs > 1`), then prints the rows the
//! paper reports and writes the full traces as CSV plus an aggregated
//! `report.{csv,json}` under `runs/<id>/`.  Output semantics are
//! unchanged from the pre-sweep serial loops: cells are summarized in
//! declaration order and the first failing cell still fails the harness.
//! Absolute numbers differ from the paper (synthetic data, simulated
//! network — see DESIGN.md §Substitutions); the comparisons (who wins, by
//! what order of magnitude) are the reproduction target.

use super::sweep::{self, Cell, CellOutcome, TaskRef};
use crate::config::{Algorithm, ExperimentConfig};
use crate::coordinator::{summarize, write_runs};
use crate::data::partition::Partition;
use crate::linalg::{Dtype, Scalar};
use crate::metrics::RunMetrics;
use crate::obs::Console;
use crate::runtime::ArtifactRegistry;
use crate::sim::{NetConfig, NetMode};
use crate::tasks::{BilevelTask, HyperRepTask, LogRegTask, QuadraticTask};
use crate::topology::Topology;
use anyhow::Result;

pub use super::sweep::HarnessObserver;

/// Scaling knobs shared by all harnesses (CLI: --rounds, --verbose,
/// --jobs, --preset-suffix).
#[derive(Clone, Debug)]
pub struct HarnessOpts {
    /// Outer rounds per run (paper: ~1000 coeff / ~100 hyperrep; default
    /// here is sized for minutes-scale runs with the same ordering).
    pub rounds: usize,
    /// Preset override, e.g. "coeff_tiny" for smoke runs.
    pub coeff_preset: String,
    pub hyperrep_preset: String,
    pub out_dir: String,
    pub seed: u64,
    /// Stream one progress line per recorded trace point (CLI: --verbose).
    pub verbose: bool,
    /// Cell-level parallelism for thread-shareable grids (CLI: --jobs;
    /// 0 = all cores).  Artifact-registry grids always run serially
    /// (thread-local PJRT state); 1 preserves the classic serial order.
    pub jobs: usize,
    /// Suppress per-harness summary output (CLI: --quiet); warnings and
    /// the final tables' data still land in `runs/` either way.
    pub quiet: bool,
    /// Write the deterministic JSONL telemetry trace ([`crate::obs`]) of
    /// every cell, concatenated in declaration order, to this path
    /// (CLI: --trace FILE).
    pub trace: Option<String>,
    /// Print each cell's wall-clock phase profile after the grid runs
    /// (CLI: --profile; explicitly nondeterministic, never in the trace).
    pub profile: bool,
    /// Payload precision for the native (artifact-free) harnesses —
    /// netsweep and budget (CLI: --dtype).  The registry-backed harnesses
    /// stay f32: PJRT artifacts are f32-only (docs/DTYPE.md).
    pub dtype: Dtype,
}

impl Default for HarnessOpts {
    fn default() -> Self {
        HarnessOpts {
            rounds: 120,
            coeff_preset: "coeff".into(),
            hyperrep_preset: "hyperrep".into(),
            out_dir: "runs".into(),
            seed: 42,
            verbose: false,
            jobs: 1,
            quiet: false,
            trace: None,
            profile: false,
            dtype: Dtype::F32,
        }
    }
}

impl HarnessOpts {
    /// Console routing derived from `--quiet`/`--verbose` — the single
    /// knob every harness's progress and summary output goes through.
    pub fn console(&self) -> Console {
        Console::new(self.quiet, self.verbose)
    }
}

/// Run a declared grid and unwrap the outcomes with classic harness
/// semantics: the first failing cell (in declaration order) fails the
/// harness, otherwise every cell's metrics come back in order.  Also
/// writes the aggregated cross-cell report next to the per-run traces.
fn run_grid(
    id: &str,
    cells: Vec<Cell>,
    tasks: &[sweep::TaskSlot],
    reg: Option<&ArtifactRegistry>,
    o: &HarnessOpts,
) -> Result<Vec<RunMetrics>> {
    let opts = sweep::ExecOpts {
        jobs: o.jobs,
        console: o.console(),
        trace: o.trace.is_some(),
        profile: o.profile,
    };
    let outcomes = sweep::run_cells_slots(&cells, tasks, reg, &opts);
    if let Some(path) = &o.trace {
        std::fs::write(path, sweep::concat_traces(&outcomes))
            .map_err(|e| anyhow::anyhow!("writing trace {path}: {e}"))?;
        o.console()
            .info(format_args!("wrote JSONL trace to {path}"));
    }
    if o.profile {
        for oc in &outcomes {
            if let Some(p) = &oc.profile {
                println!("-- profile: {} --\n{p}", oc.id);
            }
        }
    }
    let dir = std::path::Path::new(&o.out_dir).join(id);
    sweep::write_report(&dir, &cells, &outcomes)?;
    let mut runs = Vec::with_capacity(outcomes.len());
    for CellOutcome { id: cell_id, result, .. } in outcomes {
        match result {
            Ok(m) => runs.push(m),
            Err(e) => anyhow::bail!("cell {cell_id}: {e}"),
        }
    }
    write_runs(&o.out_dir, id, &runs)?;
    Ok(runs)
}

fn coeff_cfg(o: &HarnessOpts) -> ExperimentConfig {
    ExperimentConfig {
        preset: o.coeff_preset.clone(),
        rounds: o.rounds,
        seed: o.seed,
        out_dir: o.out_dir.clone(),
        eval_every: (o.rounds / 40).max(1),
        // Paper Appendix C.1 for C²DFB on coefficient tuning; the step
        // sizes are rescaled for the synthetic corpus (lr 1 with λ=10 sits
        // past the compressed-tracking stability edge on it; the baselines
        // get the same treatment — see EXPERIMENTS.md §Calibration).
        eta_out: 0.5,
        eta_in: 0.2,
        gamma_out: 0.5,
        gamma_in: 0.5,
        lambda: 10.0,
        inner_steps: 15,
        compressor: "topk:0.2".into(),
        // Noise calibrated so the optimal linear classifier sits near 85%
        // and the 70% target separates the methods — see EXPERIMENTS.md
        // §Calibration.
        data_noise: 1.2,
        ..ExperimentConfig::default()
    }
}

fn hyperrep_cfg(o: &HarnessOpts) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::hyperrep_defaults();
    cfg.preset = o.hyperrep_preset.clone();
    cfg.rounds = o.rounds;
    cfg.seed = o.seed;
    cfg.out_dir = o.out_dir.clone();
    cfg.eval_every = (o.rounds / 60).max(1);
    // Calibrated for the synthetic MNIST-like corpus (He-init backbone
    // features give the head problem λ·L ≈ 160; the paper's lr 1 diverges).
    cfg.eta_out = 0.02;
    cfg.eta_in = 0.05;
    cfg.data_noise = 0.45;
    cfg
}

/// Baselines need smaller upper steps (no tracking-normalized scale) —
/// tuned so each baseline converges on the synthetic corpus.
fn tune_for(algo: Algorithm, cfg: &mut ExperimentConfig) {
    cfg.algorithm = algo;
    match algo {
        Algorithm::C2dfb | Algorithm::C2dfbNc => {}
        Algorithm::Madsbo => {
            cfg.eta_out *= 2.0; // moving average damps the step
            cfg.eta_in *= 0.5;
        }
        Algorithm::Mdbo => {
            // MDBO's untracked gossip SGD has an O(η·heterogeneity/ρ) bias
            // neighbourhood: it needs a much smaller lower-level step to
            // make progress under h = 0.8 (and is correspondingly slow —
            // the paper's Table 1 shows the same 1-2 order gap).
            cfg.eta_in *= 0.25;
        }
    }
}

/// **Table 1** — comm volume (MB) + training time (s) to reach the target
/// test accuracy on the coefficient-tuning task, ring topology,
/// heterogeneous (h = 0.8).
pub fn table1(reg: &ArtifactRegistry, o: &HarnessOpts, target_acc: f64) -> Result<Vec<RunMetrics>> {
    let con = o.console();
    con.info(format_args!(
        "== Table 1: comm volume & time to {:.0}% test accuracy (ring, het 0.8) ==",
        target_acc * 100.0
    ));
    let mut cells = Vec::new();
    for algo in [Algorithm::C2dfb, Algorithm::Madsbo, Algorithm::Mdbo] {
        let mut cfg = coeff_cfg(o);
        tune_for(algo, &mut cfg);
        cfg.name = "table1".into();
        cfg.topology = Topology::Ring;
        cfg.partition = Partition::Heterogeneous { h: 0.8 };
        cfg.target_accuracy = Some(target_acc);
        cells.push(Cell {
            id: format!("table1+{}", algo.name()),
            cfg,
            task: TaskRef::Registry,
        });
    }
    let runs = run_grid("table1", cells, &[], Some(reg), o)?;
    for m in &runs {
        con.info(format_args!("  {}", summarize(m)));
    }
    con.info(format_args!(
        "\n| Algo   | Comm. Vol. (MB) | Sim. Time (s) | Wall Time (s) | reached |"
    ));
    con.info(format_args!(
        "|--------|-----------------|---------------|---------------|---------|"
    ));
    for m in &runs {
        let hit = m.time_to_accuracy(target_acc);
        let (mb, st, wt, reached) = match hit {
            Some(p) => (p.comm_mb, p.sim_time_s + p.wall_time_s, p.wall_time_s, "yes"),
            None => {
                let p = m.final_point().unwrap();
                (p.comm_mb, p.sim_time_s + p.wall_time_s, p.wall_time_s, "no")
            }
        };
        con.info(format_args!(
            "| {:6} | {:15.2} | {:13.2} | {:13.2} | {:7} |",
            m.algo, mb, st, wt, reached
        ));
    }
    Ok(runs)
}

/// **Figures 2 & 4** — coefficient tuning: accuracy/loss vs comm volume,
/// time, and rounds across {ring, 2hop, ER(0.4)} × {iid, het 0.8} for
/// C²DFB vs MADSBO vs MDBO.  (Fig. 4 is the same traces plotted against
/// rounds; the CSVs contain all three x-axes.)
pub fn fig2(reg: &ArtifactRegistry, o: &HarnessOpts) -> Result<Vec<RunMetrics>> {
    o.console()
        .info(format_args!("== Fig 2/4: coefficient tuning across topologies & heterogeneity =="));
    grid(
        reg,
        o,
        "fig2",
        coeff_cfg(o),
        &[Algorithm::C2dfb, Algorithm::Madsbo, Algorithm::Mdbo],
    )
}

/// **Figures 3 & 6** — hyper-representation: loss vs comm volume / rounds
/// across topologies × heterogeneity for C²DFB vs MADSBO vs C²DFB(nc).
pub fn fig3(reg: &ArtifactRegistry, o: &HarnessOpts) -> Result<Vec<RunMetrics>> {
    o.console()
        .info(format_args!("== Fig 3/6: hyper-representation across topologies & heterogeneity =="));
    grid(
        reg,
        o,
        "fig3",
        hyperrep_cfg(o),
        &[Algorithm::C2dfb, Algorithm::Madsbo, Algorithm::C2dfbNc],
    )
}

/// The figs' 3-topology × 2-partition × N-algorithm grid, declared as
/// sweep cells over the artifact registry.
fn grid(
    reg: &ArtifactRegistry,
    o: &HarnessOpts,
    id: &str,
    base: ExperimentConfig,
    algos: &[Algorithm],
) -> Result<Vec<RunMetrics>> {
    let topologies = [
        Topology::Ring,
        Topology::TwoHopRing,
        Topology::ErdosRenyi { p_milli: 400, seed: o.seed },
    ];
    let partitions = [Partition::Iid, Partition::Heterogeneous { h: 0.8 }];
    let mut cells = Vec::new();
    for topo in topologies {
        for part in partitions {
            for &algo in algos {
                let mut cfg = base.clone();
                tune_for(algo, &mut cfg);
                cfg.name = id.into();
                cfg.topology = topo;
                cfg.partition = part;
                cells.push(Cell {
                    id: format!("{id}+{}+{}+{}", topo.name(), part.name(), algo.name()),
                    cfg,
                    task: TaskRef::Registry,
                });
            }
        }
    }
    let runs = run_grid(id, cells, &[], Some(reg), o)?;
    for m in &runs {
        o.console().info(format_args!("  {}", summarize(m)));
    }
    Ok(runs)
}

/// **Figure 5** — sensitivity of C²DFB on coefficient tuning: (a) inner
/// loops K, (b) compression ratio, (c) multiplier λ (σ).
pub fn fig5(reg: &ArtifactRegistry, o: &HarnessOpts) -> Result<Vec<RunMetrics>> {
    o.console()
        .info(format_args!("== Fig 5: C²DFB sensitivity (K, compression ratio, λ) =="));
    let mut cells = Vec::new();
    let mut prefixes = Vec::new();

    for k in [1usize, 5, 15, 30] {
        let mut cfg = coeff_cfg(o);
        cfg.name = format!("fig5_K{k}");
        cfg.inner_steps = k;
        prefixes.push(format!("K={k:3}"));
        cells.push(Cell { id: format!("fig5+K{k}"), cfg, task: TaskRef::Registry });
    }
    for ratio in ["0.05", "0.1", "0.2", "0.5", "1.0"] {
        let mut cfg = coeff_cfg(o);
        cfg.name = format!("fig5_ratio{ratio}");
        cfg.compressor = format!("topk:{ratio}");
        prefixes.push(format!("ratio={ratio:5}"));
        cells.push(Cell { id: format!("fig5+ratio{ratio}"), cfg, task: TaskRef::Registry });
    }
    for lam in [1.0, 10.0, 50.0, 100.0] {
        let mut cfg = coeff_cfg(o);
        cfg.name = format!("fig5_lam{lam}");
        cfg.lambda = lam;
        prefixes.push(format!("λ={lam:5}"));
        cells.push(Cell { id: format!("fig5+lam{lam}"), cfg, task: TaskRef::Registry });
    }
    let runs = run_grid("fig5", cells, &[], Some(reg), o)?;
    for (prefix, m) in prefixes.iter().zip(&runs) {
        o.console().info(format_args!("  {prefix}  {}", summarize(m)));
    }
    Ok(runs)
}

/// Per-algorithm settings that converge on the analytic quadratic task
/// (mirrors the algorithm test suites; no artifacts needed).
fn quad_cfg_for(algo: Algorithm, rounds: usize, nodes: usize, o: &HarnessOpts) -> ExperimentConfig {
    let mut cfg = calibrated_cfg(algo, "quadratic", rounds, nodes);
    cfg.seed = o.seed;
    cfg.out_dir = o.out_dir.clone();
    cfg
}

/// **netsweep** — C²DFB vs the baselines across network regimes on the
/// analytic quadratic task (runs without artifacts): ideal LAN, WAN
/// latency/bandwidth, message loss, stragglers, and a time-varying
/// topology.  This is the comparison axis the communication-complexity
/// line of work (Zhang et al.; Chen et al.) argues about — how much of
/// C²DFB's compressed-residual advantage survives a hostile network.
///
/// Also doubles as the sim engine's acceptance check: the `sync` and
/// `ideal-sim` rows must agree exactly (bytes, rounds, final loss).
pub fn netsweep(o: &HarnessOpts, tiny: bool) -> Result<Vec<RunMetrics>> {
    let (nodes, dim) = if tiny { (6, 8) } else { (8, 32) };
    let rounds = o.rounds;
    let con = o.console();
    con.info(format_args!(
        "== netsweep: network regimes on the quadratic task (m={nodes}, d={dim}, {rounds} rounds, dtype={}) ==",
        o.dtype
    ));
    // Same seed → identical f32 generation streams at either width; the
    // f64 instance is the exact widening of the f32 one (docs/DTYPE.md).
    let task = match o.dtype {
        Dtype::F32 => sweep::NativeTask::F32(Box::new(QuadraticTask::<f32>::generate(
            nodes, dim, 0.8, o.seed,
        ))),
        Dtype::F64 => sweep::NativeTask::F64(Box::new(QuadraticTask::<f64>::generate(
            nodes, dim, 0.8, o.seed,
        ))),
    };

    let event = NetConfig { mode: NetMode::Event, ..NetConfig::default() };
    let dynamic = {
        let mut n = event.clone();
        n.parse_schedule("100:2hop,300:er:0.4", o.seed)
            .map_err(anyhow::Error::msg)?;
        n
    };
    let regimes: Vec<(&str, NetConfig)> = vec![
        ("sync", NetConfig::default()),
        ("ideal-sim", event.clone()),
        (
            "wan",
            NetConfig {
                latency_s: 0.04,
                jitter_s: 0.01,
                bandwidth_bytes_per_s: 12.5e6,
                ..event.clone()
            },
        ),
        ("lossy", NetConfig { drop_rate: 0.1, ..event.clone() }),
        (
            "straggler",
            NetConfig {
                straggler_frac: 0.25,
                straggler_delay_s: 0.05,
                ..event.clone()
            },
        ),
        ("dynamic", dynamic),
    ];
    let algos = [Algorithm::C2dfb, Algorithm::Madsbo, Algorithm::Mdbo];

    let mut cells = Vec::new();
    let mut regime_of = Vec::new();
    for (regime, netcfg) in &regimes {
        for algo in algos {
            let mut cfg = quad_cfg_for(algo, rounds, nodes, o);
            cfg.name = format!("netsweep_{regime}");
            cfg.network = netcfg.clone();
            cfg.dtype = o.dtype;
            regime_of.push(*regime);
            cells.push(Cell {
                id: format!("netsweep+{regime}+{}", algo.name()),
                cfg,
                task: TaskRef::Shared(0),
            });
        }
    }
    let runs = run_grid("netsweep", cells, &[task.slot()], None, o)?;

    con.info(format_args!(
        "\n| regime    | algo   | comm (MB) | gossip rounds | virtual time (s) | dropped | final loss |"
    ));
    con.info(format_args!(
        "|-----------|--------|-----------|---------------|------------------|---------|------------|"
    ));
    for (regime, m) in regime_of.iter().zip(&runs) {
        let last = m.final_point().expect("run produced no trace");
        con.info(format_args!(
            "| {:9} | {:6} | {:9.3} | {:13} | {:16.4} | {:7} | {:10.5} |",
            regime,
            m.algo,
            m.ledger.total_mb(),
            m.ledger.gossip_rounds,
            m.ledger.network_time_s,
            m.ledger.dropped_messages,
            last.loss
        ));
    }

    // Benign-network equivalence: event engine ≡ synchronous engine.
    let mut all_ok = true;
    for i in 0..algos.len() {
        let (s, e) = (&runs[i], &runs[algos.len() + i]);
        let ok = s.ledger.total_bytes == e.ledger.total_bytes
            && s.ledger.gossip_rounds == e.ledger.gossip_rounds
            && s.final_point().map(|p| p.loss.to_bits())
                == e.final_point().map(|p| p.loss.to_bits());
        all_ok &= ok;
        con.info(format_args!(
            "{} sync ≡ ideal-sim ({}): bytes/rounds/loss {}",
            if ok { "OK " } else { "ERR" },
            s.algo,
            if ok { "identical" } else { "DIFFER" }
        ));
    }
    if !all_ok {
        anyhow::bail!("event engine diverged from the synchronous engine on a benign network");
    }
    Ok(runs)
}

/// Build a native (artifact-free) task by name for the no-artifact
/// harnesses: `"quadratic"` (the analytic default), `"logreg"`
/// (hyperparameter tuning) or `"hyperrep"` (linear hyper-representation),
/// partitioned with the default `dir:0.5` Dirichlet label skew.  Sizes
/// scale with `tiny`.
pub fn native_task(
    spec: &str,
    nodes: usize,
    tiny: bool,
    seed: u64,
) -> Result<Box<dyn BilevelTask + Sync>> {
    native_task_with(spec, nodes, tiny, seed, Partition::Dirichlet { alpha: 0.5 })
}

/// [`native_task`] with an explicit partition (the sweep's partition
/// axis).  The quadratic task has no label distribution to skew, so the
/// partition maps onto its heterogeneity knob: `iid` → h = 0, `het:h` →
/// h, and `dir:α` → the historical default h = 0.8.
pub fn native_task_with(
    spec: &str,
    nodes: usize,
    tiny: bool,
    seed: u64,
    part: Partition,
) -> Result<Box<dyn BilevelTask + Sync>> {
    native_task_generic::<f32>(spec, nodes, tiny, seed, part)
}

/// [`native_task_with`] at f64 — what the sweep's `dtype` axis builds its
/// high-precision table entries from.  Data generation draws the identical
/// f32 streams and widens exactly, so this is the *same* problem instance
/// at higher arithmetic precision (docs/DTYPE.md).
pub fn native_task_f64(
    spec: &str,
    nodes: usize,
    tiny: bool,
    seed: u64,
    part: Partition,
) -> Result<Box<dyn BilevelTask<f64> + Sync>> {
    native_task_generic::<f64>(spec, nodes, tiny, seed, part)
}

fn native_task_generic<S: Scalar>(
    spec: &str,
    nodes: usize,
    tiny: bool,
    seed: u64,
    part: Partition,
) -> Result<Box<dyn BilevelTask<S> + Sync>> {
    Ok(match spec {
        "quadratic" | "quad" => {
            let dim = if tiny { 8 } else { 32 };
            let h = match part {
                Partition::Iid => 0.0,
                Partition::Heterogeneous { h } => h,
                Partition::Dirichlet { .. } => 0.8,
            };
            Box::new(QuadraticTask::<S>::generate(nodes, dim, h, seed))
        }
        "logreg" => {
            let (d, n_tr, n_val) = if tiny { (12, 24, 12) } else { (48, 80, 40) };
            Box::new(LogRegTask::<S>::generate(nodes, d, 4, n_tr, n_val, part, 0.4, seed))
        }
        "hyperrep" => {
            let (p, k, n_tr, n_val) = if tiny { (12, 4, 20, 10) } else { (36, 8, 64, 32) };
            Box::new(HyperRepTask::<S>::generate(
                nodes, p, k, 4, n_tr, n_val, part, 0.3, seed,
            ))
        }
        other => anyhow::bail!("unknown native task {other:?} (quadratic|logreg|hyperrep)"),
    })
}

/// Calibrated per-(algorithm, task) settings for the native tasks — the
/// step sizes known to converge on each task's curvature (quadratic from
/// the algorithm test suites; CE/ridge tasks with λ = 10 like the paper).
/// Seed and out_dir are left at their defaults for the caller to set.
pub fn calibrated_cfg(
    algo: Algorithm,
    spec: &str,
    rounds: usize,
    nodes: usize,
) -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        algorithm: algo,
        nodes,
        rounds,
        eval_every: (rounds / 10).max(1),
        gamma_out: 0.8,
        ..ExperimentConfig::default()
    };
    if matches!(spec, "quadratic" | "quad") {
        match algo {
            Algorithm::C2dfb | Algorithm::C2dfbNc => {
                cfg.inner_steps = 15;
                cfg.eta_out = 0.3;
                cfg.eta_in = 0.4;
                cfg.gamma_in = 0.6;
                cfg.lambda = 50.0;
                cfg.compressor = "topk:0.5".into();
            }
            Algorithm::Madsbo => {
                cfg.inner_steps = 10;
                cfg.eta_out = 0.8;
                cfg.eta_in = 0.3;
            }
            Algorithm::Mdbo => {
                cfg.inner_steps = 10;
                cfg.eta_out = 0.4;
                cfg.eta_in = 0.3;
            }
        }
        return cfg;
    }
    cfg.gamma_in = 0.6;
    cfg.inner_steps = 5;
    cfg.lambda = 10.0;
    cfg.compressor = "topk:0.5".into();
    match spec {
        "logreg" => {
            cfg.eta_out = 0.2;
            cfg.eta_in = 0.3;
        }
        _ => {
            // hyperrep: the embedded-feature Gram matrix has the largest
            // curvature; keep both levels conservative.
            cfg.eta_out = 0.05;
            cfg.eta_in = 0.05;
        }
    }
    if matches!(algo, Algorithm::Mdbo) {
        cfg.eta_in *= 0.5; // untracked gossip SGD needs smaller LL steps
    }
    cfg
}

/// Per-algorithm settings for the native data tasks, with the harness's
/// seed/out_dir applied.
fn native_cfg_for(
    algo: Algorithm,
    spec: &str,
    rounds: usize,
    nodes: usize,
    o: &HarnessOpts,
) -> ExperimentConfig {
    let mut cfg = calibrated_cfg(algo, spec, rounds, nodes);
    cfg.seed = o.seed;
    cfg.out_dir = o.out_dir.clone();
    cfg
}

/// **budget** — the equal-communication comparison behind the paper's
/// efficiency claim: run all four algorithms on a native task until each
/// has spent the same communication budget (MB), then compare where they
/// got.  This makes the Table-1 / Fig-2 "who wins at equal communication"
/// reading a first-class run instead of post-hoc trace slicing (cf. Zhang
/// et al. 2023's framing of decentralized bilevel baselines by
/// communication complexity).  Needs no artifacts; `task_spec` selects
/// quadratic (default), logreg or hyperrep via [`native_task`].
///
/// Every run carries a [`crate::metrics::StopCondition::CommBudgetMb`]
/// plus a generous round cap as a non-progress guard; the printed `stop`
/// column should read `comm_budget` for every row.
pub fn budget(o: &HarnessOpts, budget_mb: f64, tiny: bool) -> Result<Vec<RunMetrics>> {
    budget_on(o, budget_mb, tiny, "quadratic")
}

/// [`budget`] on an explicit native task.
pub fn budget_on(
    o: &HarnessOpts,
    budget_mb: f64,
    tiny: bool,
    task_spec: &str,
) -> Result<Vec<RunMetrics>> {
    let nodes = if tiny { 6 } else { 8 };
    let part = Partition::Dirichlet { alpha: 0.5 };
    let task = match o.dtype {
        Dtype::F32 => {
            sweep::NativeTask::F32(native_task_with(task_spec, nodes, tiny, o.seed, part)?)
        }
        Dtype::F64 => {
            sweep::NativeTask::F64(native_task_f64(task_spec, nodes, tiny, o.seed, part)?)
        }
    };
    let con = o.console();
    con.info(format_args!(
        "== budget: all algorithms to {budget_mb} MB of communication \
         ({}, m={nodes}, dtype={}, round cap {}) ==",
        task.name(),
        o.dtype,
        o.rounds
    ));
    let algos = [
        Algorithm::C2dfb,
        Algorithm::C2dfbNc,
        Algorithm::Madsbo,
        Algorithm::Mdbo,
    ];

    let mut cells = Vec::new();
    for algo in algos {
        let mut cfg = native_cfg_for(algo, task_spec, o.rounds, nodes, o);
        cfg.name = format!("budget_{task_spec}");
        cfg.stop.comm_mb = Some(budget_mb);
        cfg.dtype = o.dtype;
        // Check the budget every round so each run lands within one outer
        // round of the budget (the stop contract is one eval interval).
        cfg.eval_every = 1;
        cells.push(Cell {
            id: format!("budget+{task_spec}+{}", algo.name()),
            cfg,
            task: TaskRef::Shared(0),
        });
    }
    let runs = run_grid("budget", cells, &[task.slot()], None, o)?;
    for m in &runs {
        con.info(format_args!("  {}", summarize(m)));
    }

    con.info(format_args!(
        "\n| algo     | comm (MB) | rounds | oracles 1st | oracles 2nd | final loss | stop        |"
    ));
    con.info(format_args!(
        "|----------|-----------|--------|-------------|-------------|------------|-------------|"
    ));
    for m in &runs {
        let last = m.final_point().expect("run produced no trace");
        con.info(format_args!(
            "| {:8} | {:9.3} | {:6} | {:11} | {:11} | {:10.5} | {:11} |",
            m.algo,
            m.ledger.total_mb(),
            last.round,
            m.oracles.first_order,
            m.oracles.second_order,
            last.loss,
            m.stop_reason.map_or("-", |s| s.name()),
        ));
    }
    Ok(runs)
}

/// Compressor ablation beyond the paper: top-k vs rand-k vs qsgd vs dense
/// at matched settings (DESIGN.md "extension" item).
pub fn compressor_ablation(reg: &ArtifactRegistry, o: &HarnessOpts) -> Result<Vec<RunMetrics>> {
    o.console()
        .info(format_args!("== Ablation: compressor family (C²DFB, coeff, ring, het) =="));
    let comps = ["topk:0.2", "randk:0.2", "qsgd:16", "none"];
    let mut cells = Vec::new();
    for comp in comps {
        let mut cfg = coeff_cfg(o);
        cfg.name = format!("ablate_{}", comp.replace(':', ""));
        cfg.partition = Partition::Heterogeneous { h: 0.8 };
        cfg.compressor = comp.into();
        cells.push(Cell {
            id: format!("ablation+{comp}"),
            cfg,
            task: TaskRef::Registry,
        });
    }
    let runs = run_grid("ablation_compressor", cells, &[], Some(reg), o)?;
    for (comp, m) in comps.iter().zip(&runs) {
        o.console().info(format_args!("  {comp:10}  {}", summarize(m)));
    }
    Ok(runs)
}
