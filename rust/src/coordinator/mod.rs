//! Experiment coordinator: builds the world (topology → network, artifacts
//! → task, config → algorithm) and drives runs through the fluent
//! [`Runner`]; [`experiments`] hosts the per-table/figure harnesses from
//! the paper's evaluation.
//!
//! ```no_run
//! # use c2dfb::config::ExperimentConfig;
//! # use c2dfb::coordinator::Runner;
//! # use c2dfb::tasks::QuadraticTask;
//! # fn main() -> anyhow::Result<()> {
//! let cfg = ExperimentConfig::default();
//! let task: QuadraticTask = QuadraticTask::generate(10, 16, 0.8, 42);
//! let metrics = Runner::new(&cfg).shared_task(&task).run()?;
//! println!("stopped: {:?}", metrics.stop_reason);
//! # Ok(())
//! # }
//! ```
//!
//! The runner picks the transport engine (`[network] mode`), the execution
//! mode (serial vs [`crate::sim::NodePool`] for shared tasks), and stops on
//! the first [`StopCondition`](crate::metrics::StopCondition) from the
//! `[stop]` table to fire — see `docs/API.md` for the surface and the
//! migration table from the old `run_with_*` functions.

pub mod experiments;
pub mod sweep;

use crate::algorithms::{self, NoObserver, RunObserver};
use crate::collective::{GenNetwork, Network, Transport};
use crate::config::ExperimentConfig;
use crate::linalg::{Dtype, Scalar};
use crate::metrics::RunMetrics;
use crate::obs::Recorder;
use crate::runtime::ArtifactRegistry;
use crate::sim::SimNetwork;
use crate::tasks::{BilevelTask, PjrtTask};
use crate::topology::Graph;
use anyhow::Result;
use std::path::Path;

/// Build the synchronous gossip network for a config (the default
/// engine), with the `[network]` link parameters as its cost model.
pub fn build_network(cfg: &ExperimentConfig) -> Network {
    let mut net = Network::new(Graph::build(cfg.topology, cfg.nodes));
    net.time_model = cfg.network.time_model();
    net
}

/// Build the generator-backed synchronous transport
/// (`scale.generator = true`): O(m·degree) memory instead of the
/// materialized graph + m×m mixing matrix, bitwise-identical semantics.
/// Errors cleanly on topologies without a generator form.
pub fn build_gen_network(cfg: &ExperimentConfig) -> Result<GenNetwork> {
    let mut net = GenNetwork::build(cfg.topology, cfg.nodes)
        .map_err(|e| anyhow::anyhow!("building generator network: {e}"))?;
    net.time_model = cfg.network.time_model();
    Ok(net)
}

/// Build the event-driven network for a config (`network.mode = "sim"`).
/// Errors cleanly on an invalid `[network]` table (e.g. a bad CLI flag)
/// instead of panicking inside the transport constructor.
pub fn build_sim_network(cfg: &ExperimentConfig) -> Result<SimNetwork> {
    SimNetwork::new(
        Graph::build(cfg.topology, cfg.nodes),
        cfg.network.clone(),
        cfg.seed ^ 0x6E65_7477, // independent of the algorithms' stream
    )
    .map_err(|e| anyhow::anyhow!("building event network: {e}"))
}

/// Build the PJRT-backed task for a config (artifacts must exist).
pub fn build_task(reg: &ArtifactRegistry, cfg: &ExperimentConfig) -> Result<PjrtTask> {
    PjrtTask::build(
        reg,
        &cfg.preset,
        cfg.nodes,
        cfg.partition,
        cfg.data_noise as f32,
        cfg.seed,
    )
}

/// Fluent run entry point: pick a task source, optionally attach a
/// [`RunObserver`], and `.run()`.  Replaces the pre-Runner
/// `run_with_task` / `run_with_task_shared` / `run_with_registry` trio
/// (removed after their one-release deprecation window; see the
/// migration table in `docs/API.md`): the runner owns transport selection
/// (sync vs event), execution mode (serial vs [`crate::sim::NodePool`])
/// and budgeted stopping, so every entry path behaves identically.
pub struct Runner<'a> {
    cfg: &'a ExperimentConfig,
    source: Source<'a>,
    observer: Option<&'a mut dyn RunObserver>,
    recorder: Recorder,
}

/// The task source, with the payload dtype erased here and nowhere else:
/// `run()` matches the source width against `cfg.dtype` and dispatches
/// into the monomorphic [`launch`]`::<S>` — everything downstream
/// (transports, sim engine, daemon, obs) only ever sees one `S`.
enum Source<'a> {
    Unset,
    Task(&'a dyn BilevelTask),
    Shared(&'a (dyn BilevelTask + Sync)),
    TaskF64(&'a dyn BilevelTask<f64>),
    SharedF64(&'a (dyn BilevelTask<f64> + Sync)),
    Registry(&'a ArtifactRegistry),
}

impl Source<'_> {
    /// The payload width this source can run at (None = follows config;
    /// only `Unset` has no inherent width).
    fn dtype(&self) -> Option<Dtype> {
        match self {
            Source::Unset => None,
            Source::Task(_) | Source::Shared(_) | Source::Registry(_) => Some(Dtype::F32),
            Source::TaskF64(_) | Source::SharedF64(_) => Some(Dtype::F64),
        }
    }
}

impl<'a> Runner<'a> {
    pub fn new(cfg: &'a ExperimentConfig) -> Runner<'a> {
        Runner {
            cfg,
            source: Source::Unset,
            observer: None,
            recorder: Recorder::noop(),
        }
    }

    /// Run against a caller-provided task (analytic tasks, tests).
    pub fn task(mut self, task: &'a dyn BilevelTask) -> Self {
        self.source = Source::Task(task);
        self
    }

    /// Like [`Runner::task`] for thread-shareable tasks:
    /// `network.threads > 1` then fans per-node compute out over the
    /// [`crate::sim::NodePool`] (bit-identical to serial).
    pub fn shared_task(mut self, task: &'a (dyn BilevelTask + Sync)) -> Self {
        self.source = Source::Shared(task);
        self
    }

    /// Run against an f64 task (`dtype = "f64"`; native tasks only).
    pub fn task_f64(mut self, task: &'a dyn BilevelTask<f64>) -> Self {
        self.source = Source::TaskF64(task);
        self
    }

    /// Like [`Runner::task_f64`] for thread-shareable tasks.
    pub fn shared_task_f64(mut self, task: &'a (dyn BilevelTask<f64> + Sync)) -> Self {
        self.source = Source::SharedF64(task);
        self
    }

    /// Build the task from AOT artifacts (the real stack).
    pub fn registry(mut self, reg: &'a ArtifactRegistry) -> Self {
        self.source = Source::Registry(reg);
        self
    }

    /// Attach an observer: called on every trace point; may abort the run.
    pub fn observer(mut self, obs: &'a mut dyn RunObserver) -> Self {
        self.observer = Some(obs);
        self
    }

    /// Attach a telemetry recorder ([`crate::obs`]): span/phase counters,
    /// the deterministic JSONL trace sink and/or the wall-clock profiler.
    /// Cloning shares the sink — take the trace from the caller's handle
    /// after `.run()`.
    pub fn recorder(mut self, rec: &Recorder) -> Self {
        self.recorder = rec.clone();
        self
    }

    /// Validate the config, build the world and drive the run to its stop
    /// condition.  The stop reason lands in
    /// [`RunMetrics::stop_reason`](crate::metrics::RunMetrics).
    pub fn run(self) -> Result<RunMetrics> {
        self.cfg.validate()?;
        let Runner { cfg, source, observer, recorder } = self;
        if let Some(width) = source.dtype() {
            if width != cfg.dtype {
                anyhow::bail!(
                    "dtype mismatch: config says {} but the task source is {} \
                     (artifact tasks and .task()/.shared_task() run at f32; \
                     use .task_f64()/.shared_task_f64() with dtype = \"f64\")",
                    cfg.dtype.name(),
                    width.name()
                );
            }
        }
        let mut fallback = NoObserver;
        let obs: &mut dyn RunObserver = match observer {
            Some(o) => o,
            None => &mut fallback,
        };
        match source {
            Source::Unset => anyhow::bail!(
                "Runner has no task source: call .task(), .shared_task() or .registry() before .run()"
            ),
            Source::Task(task) => launch(task, None, cfg, obs, recorder),
            Source::Shared(task) => launch(task, Some(task), cfg, obs, recorder),
            Source::TaskF64(task) => launch(task, None, cfg, obs, recorder),
            Source::SharedF64(task) => launch(task, Some(task), cfg, obs, recorder),
            Source::Registry(reg) => {
                let task = build_task(reg, cfg)?;
                launch(&task, None, cfg, obs, recorder)
            }
        }
    }
}

/// Transport selection: one place decides sync vs event for every entry
/// path (previously duplicated across the four `run_*` functions).
fn launch<S: Scalar>(
    task: &dyn BilevelTask<S>,
    shared: Option<&(dyn BilevelTask<S> + Sync)>,
    cfg: &ExperimentConfig,
    obs: &mut dyn RunObserver,
    rec: Recorder,
) -> Result<RunMetrics> {
    if cfg.network.is_event() {
        drive_on(task, shared, build_sim_network(cfg)?, cfg, obs, rec)
    } else if cfg.scale.generator {
        drive_on(task, shared, build_gen_network(cfg)?, cfg, obs, rec)
    } else {
        drive_on(task, shared, build_network(cfg), cfg, obs, rec)
    }
}

fn drive_on<T: Transport, S: Scalar>(
    task: &dyn BilevelTask<S>,
    shared: Option<&(dyn BilevelTask<S> + Sync)>,
    net: T,
    cfg: &ExperimentConfig,
    obs: &mut dyn RunObserver,
    rec: Recorder,
) -> Result<RunMetrics> {
    let mut ctx = match shared {
        Some(t) => algorithms::RunContext::new_shared(t, net, cfg.clone()),
        None => algorithms::RunContext::new(task, net, cfg.clone()),
    };
    ctx.obs = rec;
    let mut algo = algorithms::make_algorithm(ctx.cfg.algorithm);
    algorithms::drive(&mut ctx, algo.as_mut(), obs)?;
    Ok(ctx.metrics)
}

/// Persist a batch of run metrics under `out_dir/name/`.
pub fn write_runs(out_dir: &str, name: &str, runs: &[RunMetrics]) -> Result<()> {
    let dir = Path::new(out_dir).join(name);
    for r in runs {
        r.write_to(&dir)?;
    }
    Ok(())
}

/// One-line human summary of a run (used by the CLI and EXPERIMENTS.md).
pub fn summarize(r: &RunMetrics) -> String {
    let last = r.final_point();
    format!(
        "{:10} {:32} comm={:9.2} MB  rounds={:5}  oracles(1st/2nd)={}/{}  loss={:.4}  acc={:.3}  wall={:.1}s  stop={}",
        r.algo,
        r.label,
        r.ledger.total_mb(),
        r.ledger.gossip_rounds,
        r.oracles.first_order,
        r.oracles.second_order,
        last.map(|p| p.loss).unwrap_or(f64::NAN),
        last.map(|p| p.accuracy).unwrap_or(f64::NAN),
        r.wall_time_s(),
        r.stop_reason.map_or("-", |s| s.name()),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algorithm;
    use crate::metrics::StopReason;
    use crate::tasks::QuadraticTask;

    #[test]
    fn runner_all_algorithms() {
        let task: QuadraticTask = QuadraticTask::generate(4, 6, 0.5, 77);
        for algo in [
            Algorithm::C2dfb,
            Algorithm::C2dfbNc,
            Algorithm::Madsbo,
            Algorithm::Mdbo,
        ] {
            let cfg = ExperimentConfig {
                algorithm: algo,
                nodes: 4,
                rounds: 5,
                inner_steps: 5,
                eta_out: 0.1,
                eta_in: 0.2,
                eval_every: 5,
                ..ExperimentConfig::default()
            };
            let m = Runner::new(&cfg).task(&task).run().expect(algo.name());
            assert!(!m.trace.is_empty(), "{}", algo.name());
            assert!(m.ledger.total_bytes > 0, "{}", algo.name());
            assert_eq!(m.stop_reason, Some(StopReason::Rounds), "{}", algo.name());
        }
    }

    #[test]
    fn runner_event_engine_all_algorithms() {
        use crate::sim::NetMode;
        let task: QuadraticTask = QuadraticTask::generate(4, 6, 0.5, 79);
        for algo in [
            Algorithm::C2dfb,
            Algorithm::C2dfbNc,
            Algorithm::Madsbo,
            Algorithm::Mdbo,
        ] {
            let mut cfg = ExperimentConfig {
                algorithm: algo,
                nodes: 4,
                rounds: 5,
                inner_steps: 5,
                eta_out: 0.1,
                eta_in: 0.2,
                eval_every: 5,
                ..ExperimentConfig::default()
            };
            cfg.network.mode = NetMode::Event;
            cfg.network.drop_rate = 0.1;
            let m = Runner::new(&cfg).task(&task).run().expect(algo.name());
            assert!(!m.trace.is_empty(), "{}", algo.name());
            assert!(m.ledger.dropped_messages > 0, "{}", algo.name());
        }
    }

    #[test]
    fn shared_runner_matches_serial_runner() {
        let task: QuadraticTask = QuadraticTask::generate(4, 6, 0.5, 80);
        let mut cfg = ExperimentConfig {
            nodes: 4,
            rounds: 4,
            inner_steps: 4,
            eta_out: 0.1,
            eta_in: 0.2,
            eval_every: 2,
            ..ExperimentConfig::default()
        };
        let serial = Runner::new(&cfg).task(&task).run().unwrap();
        cfg.network.threads = 3;
        let parallel = Runner::new(&cfg).shared_task(&task).run().unwrap();
        let a: Vec<u64> = serial.trace.iter().map(|p| p.loss.to_bits()).collect();
        let b: Vec<u64> = parallel.trace.iter().map(|p| p.loss.to_bits()).collect();
        assert_eq!(a, b);
        assert_eq!(serial.ledger.total_bytes, parallel.ledger.total_bytes);
    }

    #[test]
    fn bad_network_config_is_a_clean_error_not_a_panic() {
        use crate::sim::NetMode;
        // Simulates `c2dfb run --network sim --drop_rate 1.5`: the flag
        // parses, the config is invalid, and every path must return Err.
        let mut cfg = ExperimentConfig::default();
        cfg.network.mode = NetMode::Event;
        cfg.network.drop_rate = 1.5;
        let err = build_sim_network(&cfg).unwrap_err();
        assert!(err.to_string().contains("drop_rate"), "{err}");
        let task: QuadraticTask = QuadraticTask::generate(4, 6, 0.5, 81);
        let err = Runner::new(&cfg).task(&task).run().unwrap_err();
        assert!(err.to_string().contains("drop_rate"), "{err}");
        // A sync-mode config handed to the event constructor: Err too.
        cfg.network.drop_rate = 0.0;
        cfg.network.mode = NetMode::Sync;
        assert!(build_sim_network(&cfg).is_err());
    }

    #[test]
    fn generator_transport_matches_materialized_run_bitwise() {
        use crate::topology::Topology;
        let task: QuadraticTask = QuadraticTask::generate(8, 6, 0.5, 83);
        for topology in [
            Topology::Ring,
            Topology::Exponential,
            Topology::Torus,
            Topology::RandomRegular { k: 4, seed: 42 },
        ] {
            let mut cfg = ExperimentConfig {
                nodes: 8,
                topology,
                rounds: 5,
                inner_steps: 4,
                eta_out: 0.1,
                eta_in: 0.2,
                eval_every: 1,
                ..ExperimentConfig::default()
            };
            let base = Runner::new(&cfg).task(&task).run().unwrap();
            cfg.scale.generator = true;
            let gen = Runner::new(&cfg).task(&task).run().unwrap();
            let a: Vec<u64> = base.trace.iter().map(|p| p.loss.to_bits()).collect();
            let b: Vec<u64> = gen.trace.iter().map(|p| p.loss.to_bits()).collect();
            assert_eq!(a, b, "{topology:?}: generator trace diverged");
            assert_eq!(base.ledger.total_bytes, gen.ledger.total_bytes);
            assert_eq!(
                base.ledger.network_time_s.to_bits(),
                gen.ledger.network_time_s.to_bits()
            );
        }
    }

    #[test]
    fn runner_without_source_errors() {
        let cfg = ExperimentConfig::default();
        let err = Runner::new(&cfg).run().unwrap_err();
        assert!(err.to_string().contains("no task source"), "{err}");
    }

    /// The f64 path: `dtype = "f64"` + `.task_f64()` runs end to end, and
    /// the dtype/source width must agree — mismatches are clean errors at
    /// the erasure boundary, not type confusion downstream.
    #[test]
    fn runner_dtype_dispatch_and_mismatch() {
        use crate::linalg::Dtype;
        let t32: QuadraticTask = QuadraticTask::generate(4, 6, 0.5, 88);
        let t64: QuadraticTask<f64> = QuadraticTask::generate(4, 6, 0.5, 88);
        let mut cfg = ExperimentConfig {
            nodes: 4,
            rounds: 4,
            inner_steps: 4,
            eta_out: 0.1,
            eta_in: 0.2,
            eval_every: 2,
            ..ExperimentConfig::default()
        };

        cfg.dtype = Dtype::F64;
        let m64 = Runner::new(&cfg).task_f64(&t64).run().unwrap();
        assert!(!m64.trace.is_empty());
        assert!(m64.label.ends_with("_f64"));
        let err = Runner::new(&cfg).task(&t32).run().unwrap_err();
        assert!(err.to_string().contains("dtype mismatch"), "{err}");

        cfg.dtype = Dtype::F32;
        let m32 = Runner::new(&cfg).task(&t32).run().unwrap();
        let err = Runner::new(&cfg).task_f64(&t64).run().unwrap_err();
        assert!(err.to_string().contains("dtype mismatch"), "{err}");

        // Same instance, same schedule: the f64 run moves about twice the
        // bytes of the f32 run and lands on a nearby trajectory.
        let ratio = m64.ledger.total_bytes as f64 / m32.ledger.total_bytes as f64;
        assert!(ratio > 1.6 && ratio <= 2.0, "byte ratio {ratio}");
        let (a, b) = (
            m32.trace.last().unwrap().loss,
            m64.trace.last().unwrap().loss,
        );
        assert!((a - b).abs() < 1e-3 * (1.0 + a.abs()), "{a} vs {b}");
    }

    #[test]
    fn write_runs_creates_files() {
        let task: QuadraticTask = QuadraticTask::generate(4, 6, 0.5, 78);
        let cfg = ExperimentConfig {
            nodes: 4,
            rounds: 3,
            inner_steps: 3,
            eta_out: 0.1,
            eta_in: 0.2,
            ..ExperimentConfig::default()
        };
        let m = Runner::new(&cfg).task(&task).run().unwrap();
        let dir = std::env::temp_dir().join("c2dfb_write_runs");
        let _ = std::fs::remove_dir_all(&dir);
        write_runs(dir.to_str().unwrap(), "t", &[m]).unwrap();
        let files: Vec<_> = std::fs::read_dir(dir.join("t")).unwrap().collect();
        assert_eq!(files.len(), 2); // csv + json
    }
}
