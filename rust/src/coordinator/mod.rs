//! Experiment coordinator: builds the world (topology → network, artifacts
//! → task, config → algorithm) and drives runs; [`experiments`] hosts the
//! per-table/figure harnesses from the paper's evaluation.

pub mod experiments;

use crate::algorithms;
use crate::collective::Network;
use crate::config::ExperimentConfig;
use crate::metrics::RunMetrics;
use crate::runtime::ArtifactRegistry;
use crate::sim::SimNetwork;
use crate::tasks::{BilevelTask, PjrtTask};
use crate::topology::Graph;
use anyhow::Result;
use std::path::Path;

/// Build the synchronous gossip network for a config (the default
/// engine), with the `[network]` link parameters as its cost model.
pub fn build_network(cfg: &ExperimentConfig) -> Network {
    let mut net = Network::new(Graph::build(cfg.topology, cfg.nodes));
    net.time_model = cfg.network.time_model();
    net
}

/// Build the event-driven network for a config (`network.mode = "sim"`).
pub fn build_sim_network(cfg: &ExperimentConfig) -> SimNetwork {
    SimNetwork::new(
        Graph::build(cfg.topology, cfg.nodes),
        cfg.network.clone(),
        cfg.seed ^ 0x6E65_7477, // independent of the algorithms' stream
    )
}

/// Build the PJRT-backed task for a config (artifacts must exist).
pub fn build_task(reg: &ArtifactRegistry, cfg: &ExperimentConfig) -> Result<PjrtTask> {
    PjrtTask::build(
        reg,
        &cfg.preset,
        cfg.nodes,
        cfg.partition,
        cfg.data_noise as f32,
        cfg.seed,
    )
}

/// Run one experiment end-to-end against the real artifacts.
pub fn run_with_registry(reg: &ArtifactRegistry, cfg: &ExperimentConfig) -> Result<RunMetrics> {
    cfg.validate().map_err(anyhow::Error::msg)?;
    let task = build_task(reg, cfg)?;
    if cfg.network.is_event() {
        algorithms::run(&task, build_sim_network(cfg), cfg.clone())
    } else {
        algorithms::run(&task, build_network(cfg), cfg.clone())
    }
}

/// Run against a caller-provided task (analytic tasks, tests).
pub fn run_with_task(task: &dyn BilevelTask, cfg: &ExperimentConfig) -> Result<RunMetrics> {
    cfg.validate().map_err(anyhow::Error::msg)?;
    if cfg.network.is_event() {
        algorithms::run(task, build_sim_network(cfg), cfg.clone())
    } else {
        algorithms::run(task, build_network(cfg), cfg.clone())
    }
}

/// [`run_with_task`] for thread-shareable tasks: `network.threads > 1`
/// fans per-node compute out over the [`crate::sim::NodePool`].
pub fn run_with_task_shared(
    task: &(dyn BilevelTask + Sync),
    cfg: &ExperimentConfig,
) -> Result<RunMetrics> {
    cfg.validate().map_err(anyhow::Error::msg)?;
    if cfg.network.is_event() {
        algorithms::run_shared(task, build_sim_network(cfg), cfg.clone())
    } else {
        algorithms::run_shared(task, build_network(cfg), cfg.clone())
    }
}

/// Persist a batch of run metrics under `out_dir/name/`.
pub fn write_runs(out_dir: &str, name: &str, runs: &[RunMetrics]) -> Result<()> {
    let dir = Path::new(out_dir).join(name);
    for r in runs {
        r.write_to(&dir)?;
    }
    Ok(())
}

/// One-line human summary of a run (used by the CLI and EXPERIMENTS.md).
pub fn summarize(r: &RunMetrics) -> String {
    let last = r.final_point();
    format!(
        "{:10} {:32} comm={:9.2} MB  rounds={:5}  oracles(1st/2nd)={}/{}  loss={:.4}  acc={:.3}  wall={:.1}s",
        r.algo,
        r.label,
        r.ledger.total_mb(),
        r.ledger.gossip_rounds,
        r.oracles.first_order,
        r.oracles.second_order,
        last.map(|p| p.loss).unwrap_or(f64::NAN),
        last.map(|p| p.accuracy).unwrap_or(f64::NAN),
        r.wall_time_s(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algorithm;
    use crate::tasks::QuadraticTask;

    #[test]
    fn run_with_task_all_algorithms() {
        let task = QuadraticTask::generate(4, 6, 0.5, 77);
        for algo in [
            Algorithm::C2dfb,
            Algorithm::C2dfbNc,
            Algorithm::Madsbo,
            Algorithm::Mdbo,
        ] {
            let cfg = ExperimentConfig {
                algorithm: algo,
                nodes: 4,
                rounds: 5,
                inner_steps: 5,
                eta_out: 0.1,
                eta_in: 0.2,
                eval_every: 5,
                ..ExperimentConfig::default()
            };
            let m = run_with_task(&task, &cfg).expect(algo.name());
            assert!(!m.trace.is_empty(), "{}", algo.name());
            assert!(m.ledger.total_bytes > 0, "{}", algo.name());
        }
    }

    #[test]
    fn run_with_task_event_engine_all_algorithms() {
        use crate::sim::NetMode;
        let task = QuadraticTask::generate(4, 6, 0.5, 79);
        for algo in [
            Algorithm::C2dfb,
            Algorithm::C2dfbNc,
            Algorithm::Madsbo,
            Algorithm::Mdbo,
        ] {
            let mut cfg = ExperimentConfig {
                algorithm: algo,
                nodes: 4,
                rounds: 5,
                inner_steps: 5,
                eta_out: 0.1,
                eta_in: 0.2,
                eval_every: 5,
                ..ExperimentConfig::default()
            };
            cfg.network.mode = NetMode::Event;
            cfg.network.drop_rate = 0.1;
            let m = run_with_task(&task, &cfg).expect(algo.name());
            assert!(!m.trace.is_empty(), "{}", algo.name());
            assert!(m.ledger.dropped_messages > 0, "{}", algo.name());
        }
    }

    #[test]
    fn shared_runner_matches_serial_runner() {
        let task = QuadraticTask::generate(4, 6, 0.5, 80);
        let mut cfg = ExperimentConfig {
            nodes: 4,
            rounds: 4,
            inner_steps: 4,
            eta_out: 0.1,
            eta_in: 0.2,
            eval_every: 2,
            ..ExperimentConfig::default()
        };
        let serial = run_with_task(&task, &cfg).unwrap();
        cfg.network.threads = 3;
        let parallel = run_with_task_shared(&task, &cfg).unwrap();
        let a: Vec<u64> = serial.trace.iter().map(|p| p.loss.to_bits()).collect();
        let b: Vec<u64> = parallel.trace.iter().map(|p| p.loss.to_bits()).collect();
        assert_eq!(a, b);
        assert_eq!(serial.ledger.total_bytes, parallel.ledger.total_bytes);
    }

    #[test]
    fn write_runs_creates_files() {
        let task = QuadraticTask::generate(4, 6, 0.5, 78);
        let cfg = ExperimentConfig {
            nodes: 4,
            rounds: 3,
            inner_steps: 3,
            eta_out: 0.1,
            eta_in: 0.2,
            ..ExperimentConfig::default()
        };
        let m = run_with_task(&task, &cfg).unwrap();
        let dir = std::env::temp_dir().join("c2dfb_write_runs");
        let _ = std::fs::remove_dir_all(&dir);
        write_runs(dir.to_str().unwrap(), "t", &[m]).unwrap();
        let files: Vec<_> = std::fs::read_dir(dir.join("t")).unwrap().collect();
        assert_eq!(files.len(), 2); // csv + json
    }
}
