//! Decentralized network topologies and gossip mixing matrices.
//!
//! Covers every topology the paper evaluates (ring, 2-hop ring,
//! Erdős–Rényi(p)) plus the standard extras a user of the library will
//! want (complete, star, path, 2-D torus).  Mixing weights are
//! Metropolis–Hastings (symmetric, doubly stochastic by construction) and
//! the spectral quantities of Assumption 1 / Definition 3 are computed
//! exactly via the Jacobi eigensolver.

mod graph;
mod mixing;

pub use graph::{Graph, Topology};
pub use mixing::MixingMatrix;
