//! Decentralized network topologies and gossip mixing matrices.
//!
//! Covers every topology the paper evaluates (ring, 2-hop ring,
//! Erdős–Rényi(p)) plus the standard extras a user of the library will
//! want (complete, star, path, 2-D torus, seed-derived random-regular
//! circulants).  Mixing weights are Metropolis–Hastings (symmetric,
//! doubly stochastic by construction) and the spectral quantities of
//! Assumption 1 / Definition 3 are computed exactly via the Jacobi
//! eigensolver.
//!
//! Two representations answer the same queries (see docs/SCALE.md):
//!
//! * materialized — [`Graph`] adjacency + dense [`MixingMatrix`], the
//!   default below a few thousand nodes;
//! * generated — [`GenTopology`] computes neighbor sets and mixing
//!   weights on the fly in O(degree) memory, bit-identical to the
//!   materialized path for every supported topology (pinned by
//!   `tests/scale.rs`).

mod gen;
mod graph;
mod mixing;

pub use gen::{circulant_offsets, GenTopology, Neighborhood};
pub use graph::{torus_dims, Graph, Topology};
pub use mixing::MixingMatrix;
