//! Gossip mixing matrices (Assumption 1) with Metropolis–Hastings weights.

use super::Graph;
use crate::linalg::{kernels, MatF64, Scalar};

/// Symmetric doubly stochastic mixing matrix over a graph, with the
/// spectral quantities used throughout the convergence analysis cached.
#[derive(Clone, Debug)]
pub struct MixingMatrix {
    pub m: usize,
    w: MatF64,
    /// δ_ρ = max{|λ₂|, |λ_m|} (Definition 3).
    pub second_eig_magnitude: f64,
    /// Spectral gap ρ = 1 − δ_ρ.
    pub spectral_gap: f64,
    /// ρ' = ‖W − I‖² (largest squared singular value), paper Lemma 4.
    pub w_minus_i_norm_sq: f64,
    /// Per-node list of (neighbor, weight), excluding self.
    neighbor_weights: Vec<Vec<(usize, f64)>>,
}

impl MixingMatrix {
    /// Metropolis–Hastings: w_ij = 1 / (1 + max(deg_i, deg_j)) for edges,
    /// w_ii = 1 − Σ_j w_ij.  Symmetric and doubly stochastic by
    /// construction; positive diagonal ⇒ λ_m > −1 on any connected graph.
    pub fn metropolis(graph: &Graph) -> MixingMatrix {
        let m = graph.m;
        let mut w = MatF64::zeros(m);
        for i in 0..m {
            for &j in graph.neighbors(i) {
                w[(i, j)] = 1.0 / (1.0 + graph.degree(i).max(graph.degree(j)) as f64);
            }
        }
        for i in 0..m {
            let off: f64 = (0..m).filter(|&j| j != i).map(|j| w.get(i, j)).sum();
            w[(i, i)] = 1.0 - off;
        }
        Self::from_matrix(w)
    }

    /// Build from an explicit matrix (validated).
    pub fn from_matrix(w: MatF64) -> MixingMatrix {
        assert!(w.is_symmetric(1e-9), "mixing matrix must be symmetric");
        assert!(
            w.doubly_stochastic_defect() < 1e-9,
            "mixing matrix must be doubly stochastic (defect {})",
            w.doubly_stochastic_defect()
        );
        let m = w.n;
        let second = w.second_largest_eig_magnitude();
        let w_minus_i = w.w_minus_i_norm_sq();
        let mut neighbor_weights = vec![Vec::new(); m];
        for i in 0..m {
            for j in 0..m {
                if i != j && w.get(i, j) != 0.0 {
                    neighbor_weights[i].push((j, w.get(i, j)));
                }
            }
        }
        MixingMatrix {
            m,
            second_eig_magnitude: second,
            spectral_gap: 1.0 - second,
            w_minus_i_norm_sq: w_minus_i,
            w,
            neighbor_weights,
        }
    }

    #[inline]
    pub fn weight(&self, i: usize, j: usize) -> f64 {
        self.w.get(i, j)
    }

    /// Off-diagonal neighbour weights of node i.
    pub fn neighbors(&self, i: usize) -> &[(usize, f64)] {
        &self.neighbor_weights[i]
    }

    pub fn matrix(&self) -> &MatF64 {
        &self.w
    }

    /// The mixing step of Algorithms 1–2 applied to stacked rows:
    /// `out_i = rows_i + γ Σ_j w_ij (rows_j − rows_i)`, i.e. X ← (I + γ(W−I))X.
    /// Proposition 5: this keeps a spectral gap of at least γρ.
    pub fn mix<S: Scalar>(&self, gamma: f64, rows: &[Vec<S>]) -> Vec<Vec<S>> {
        assert_eq!(rows.len(), self.m);
        let mut out = rows.to_vec();
        for i in 0..self.m {
            let oi = &mut out[i];
            for &(j, wij) in &self.neighbor_weights[i] {
                let c = S::from_f64(gamma * wij);
                kernels::weighted_diff_add(c, &rows[j], &rows[i], oi);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg;
    use crate::topology::Topology;

    fn mm(t: Topology, m: usize) -> MixingMatrix {
        MixingMatrix::metropolis(&Graph::build(t, m))
    }

    #[test]
    fn metropolis_is_valid_for_all_topologies() {
        for t in [
            Topology::Ring,
            Topology::TwoHopRing,
            Topology::Complete,
            Topology::Star,
            Topology::Path,
            Topology::Torus,
            Topology::ErdosRenyi { p_milli: 400, seed: 3 },
        ] {
            let w = mm(t, 10);
            assert!(w.matrix().doubly_stochastic_defect() < 1e-9, "{t:?}");
            assert!(w.spectral_gap > 0.0, "{t:?} gap {}", w.spectral_gap);
            assert!(w.second_eig_magnitude < 1.0, "{t:?}");
        }
    }

    #[test]
    fn better_connectivity_larger_gap() {
        let ring = mm(Topology::Ring, 10).spectral_gap;
        let twohop = mm(Topology::TwoHopRing, 10).spectral_gap;
        let complete = mm(Topology::Complete, 10).spectral_gap;
        assert!(ring < twohop, "ring {ring} vs 2hop {twohop}");
        assert!(twohop < complete + 1e-12, "2hop {twohop} vs complete {complete}");
    }

    #[test]
    fn mix_preserves_mean_exactly_in_expectation() {
        // Eq. 7 of the paper: the average over nodes is invariant under the
        // (uncompressed) mixing step because 1ᵀ(W−I) = 0.
        let w = mm(Topology::Ring, 6);
        let rows: Vec<Vec<f32>> =
            (0..6).map(|i| vec![i as f32, (i * i) as f32, -(i as f32)]).collect();
        let before = linalg::mean_rows(&rows);
        let after = linalg::mean_rows(&w.mix(0.7, &rows));
        for (a, b) in before.iter().zip(&after) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn mix_contracts_consensus_error() {
        let w = mm(Topology::Ring, 8);
        let rows: Vec<Vec<f32>> = (0..8).map(|i| vec![i as f32; 4]).collect();
        let e0 = linalg::consensus_err_sq(&rows);
        let mixed = w.mix(1.0, &rows);
        let e1 = linalg::consensus_err_sq(&mixed);
        assert!(e1 < e0, "{e1} !< {e0}");
    }

    #[test]
    fn mix_fixed_point_consensus() {
        let w = mm(Topology::TwoHopRing, 5);
        let rows = vec![vec![3.0f32, -1.0]; 5];
        let mixed = w.mix(0.5, &rows);
        for r in mixed {
            assert_eq!(r, vec![3.0, -1.0]);
        }
    }

    #[test]
    fn gamma_scales_gap_proposition5() {
        // W̃ = I + γ(W−I) has gap γρ (Proposition 5): verify spectrally.
        let w = mm(Topology::Ring, 8);
        let gamma = 0.5;
        let mut wt = MatF64::zeros(8);
        for i in 0..8 {
            for j in 0..8 {
                let id = if i == j { 1.0 } else { 0.0 };
                wt[(i, j)] = id + gamma * (w.matrix().get(i, j) - id);
            }
        }
        let eig = wt.symmetric_eigenvalues();
        let gap = 1.0 - eig[1];
        assert!((gap - gamma * (1.0 - w.matrix().symmetric_eigenvalues()[1])).abs() < 1e-9);
    }
}
