//! Generator-backed implicit topologies: neighbor sets and mixing
//! weights computed on the fly in O(degree) per node, no materialized
//! adjacency or m×m mixing matrix.
//!
//! This is the memory half of the million-node scale story (see
//! docs/SCALE.md): a [`Graph`] + `MixingMatrix` pair costs O(m·degree)
//! for adjacency plus O(m²) for the dense mixing matrix, which caps
//! experiments at a few thousand nodes.  A [`GenTopology`] answers the
//! same queries from closed-form edge rules in O(degree) memory total.
//!
//! ## Edge contract
//!
//! For every supported [`Topology`] variant the generator reproduces the
//! materialized [`Graph::build`] adjacency **exactly** (same neighbor
//! sets, ascending order) and [`Neighborhood::mix_weight`] reproduces
//! `MixingMatrix::metropolis` **bitwise**:
//!
//! * edge weights are the identical expression
//!   `1.0 / (1.0 + max(deg_i, deg_j) as f64)`, and
//! * the self-weight sums neighbor weights in ascending-j order, which is
//!   bit-identical to the materialized row sum because non-neighbor
//!   entries are exactly `0.0` and `x + 0.0 == x` for the non-negative
//!   finite weights involved.
//!
//! Random-regular graphs are seed-derived circulants: the offset list is
//! a pure function of `(m, k, seed)` shared with
//! `Topology::RandomRegular` via [`circulant_offsets`], so the generator
//! and materialized paths agree by construction.  The equivalence suite
//! (`tests/scale.rs`) pins all of this at small m.

use super::graph::{torus_dims, Graph, Topology};
use crate::util::rng::Rng;

/// Uniform query interface over materialized and generated topologies.
///
/// Everything the gossip hot path needs: node count, degrees, ascending
/// neighbor lists, and Metropolis–Hastings mixing weights (including the
/// `i == j` self-weight).  [`Graph`] implements it by lookup; a
/// [`GenTopology`] implements it by formula.
pub trait Neighborhood {
    /// Number of nodes.
    fn node_count(&self) -> usize;

    /// Degree of node `i`.
    fn degree(&self, i: usize) -> usize;

    /// Replace `out` with `i`'s neighbors in ascending order.
    fn neighbors_into(&self, i: usize, out: &mut Vec<usize>);

    /// Metropolis–Hastings mixing weight w_ij; `i == j` yields the
    /// self-weight `1 − Σ_j w_ij`, non-edges yield exactly `0.0`.
    fn mix_weight(&self, i: usize, j: usize) -> f64;
}

impl Neighborhood for Graph {
    fn node_count(&self) -> usize {
        self.m
    }

    fn degree(&self, i: usize) -> usize {
        Graph::degree(self, i)
    }

    fn neighbors_into(&self, i: usize, out: &mut Vec<usize>) {
        out.clear();
        out.extend_from_slice(self.neighbors(i));
    }

    fn mix_weight(&self, i: usize, j: usize) -> f64 {
        metropolis_weight(self, i, j)
    }
}

/// Metropolis–Hastings weight computed from degrees alone — the shared
/// implementation behind every [`Neighborhood`].  Bitwise-identical to
/// `MixingMatrix::metropolis` (see the module docs for why the
/// neighbor-only self-weight sum is exact).
fn metropolis_weight<N: Neighborhood + ?Sized>(n: &N, i: usize, j: usize) -> f64 {
    if i != j {
        let mut nbrs = Vec::with_capacity(n.degree(i));
        n.neighbors_into(i, &mut nbrs);
        if nbrs.binary_search(&j).is_ok() {
            1.0 / (1.0 + n.degree(i).max(n.degree(j)) as f64)
        } else {
            0.0
        }
    } else {
        let mut nbrs = Vec::with_capacity(n.degree(i));
        n.neighbors_into(i, &mut nbrs);
        let di = n.degree(i);
        let off: f64 = nbrs
            .iter()
            .map(|&j| 1.0 / (1.0 + di.max(n.degree(j)) as f64))
            .sum();
        1.0 - off
    }
}

/// Seed-derived circulant offsets for a k-regular graph on m nodes: the
/// pure function of `(m, k, seed)` shared by [`GenTopology`] and the
/// materialized `Topology::RandomRegular` build, so both paths produce
/// the same edge set.
///
/// Offset 1 is always included (guarantees connectivity — the graph
/// contains the m-cycle); the remaining k/2 − 1 offsets are distinct
/// draws from [2, (m−1)/2].  Every offset o satisfies 0 < o < m/2, so
/// the ±o neighbors of a node are 2·|offsets| distinct nodes and the
/// graph is exactly k-regular.
pub fn circulant_offsets(m: usize, k: usize, seed: u64) -> Result<Vec<usize>, String> {
    if k < 2 || k % 2 != 0 {
        return Err(format!("random-regular degree must be even and >= 2, got {k}"));
    }
    if m < 3 {
        return Err(format!("random-regular needs m >= 3, got {m}"));
    }
    let extra = k / 2 - 1;
    let hi = (m - 1) / 2; // largest usable offset
    let avail = hi.saturating_sub(1); // offsets in [2, hi]
    if extra > avail {
        return Err(format!(
            "random-regular degree {k} infeasible for m={m} (needs {extra} offsets in [2, {hi}])"
        ));
    }
    let mut offsets = vec![1usize];
    if extra > 0 {
        // Distinct ascending draws from [2, hi], salted so the offset
        // stream is independent of every other seed consumer.
        let mut rng = Rng::new(seed ^ 0x5252_4547); // "RREG"
        offsets.extend(rng.sample_indices(avail, extra).into_iter().map(|x| x + 2));
    }
    Ok(offsets)
}

/// The closed-form edge rule behind a [`GenTopology`].
#[derive(Clone, Debug, PartialEq, Eq)]
enum GenKind {
    Ring,
    Exponential,
    Torus { rows: usize, cols: usize },
    /// Circulant: i ↔ (i ± o) mod m for each offset o.
    Circulant { offsets: Vec<usize> },
}

/// An implicit topology over `m` nodes: O(degree) memory, every query
/// answered by formula.  Construct with [`GenTopology::new`] from the
/// same [`Topology`] value the materialized path uses.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GenTopology {
    m: usize,
    topology: Topology,
    kind: GenKind,
    /// Per-node degree for the uniform-degree kinds (circulants); the
    /// torus computes per-node degree from its position.
    uniform_degree: usize,
}

impl GenTopology {
    /// Wrap `topology` as a generator.  Errors on variants whose edge
    /// sets are not closed-form (ER needs global resampling; complete /
    /// star / path / 2-hop simply have no scale story and stay
    /// materialized-only).
    pub fn new(topology: Topology, m: usize) -> Result<GenTopology, String> {
        assert!(m >= 2, "need at least 2 nodes");
        let (kind, uniform_degree) = match topology {
            Topology::Ring => (GenKind::Ring, if m == 2 { 1 } else { 2 }),
            Topology::Exponential => {
                // Degree is uniform (circulant): count distinct ±2^j mod m.
                let mut nbrs = Vec::new();
                exp_neighbors_into(m, 0, &mut nbrs);
                (GenKind::Exponential, nbrs.len())
            }
            Topology::Torus => {
                let (rows, cols) = torus_dims(m);
                (GenKind::Torus { rows, cols }, 0)
            }
            Topology::RandomRegular { k, seed } => {
                let offsets = circulant_offsets(m, k as usize, seed)?;
                let deg = 2 * offsets.len();
                (GenKind::Circulant { offsets }, deg)
            }
            other => {
                return Err(format!(
                    "topology '{}' has no generator form (use the materialized path)",
                    other.name()
                ))
            }
        };
        Ok(GenTopology { m, topology, kind, uniform_degree })
    }

    /// The [`Topology`] this generator mirrors.
    pub fn topology(&self) -> Topology {
        self.topology
    }

    /// Whether `topology` has a generator form.
    pub fn supports(topology: Topology) -> bool {
        matches!(
            topology,
            Topology::Ring | Topology::Exponential | Topology::Torus | Topology::RandomRegular { .. }
        )
    }

    /// Allocating convenience around [`Neighborhood::neighbors_into`].
    pub fn neighbors(&self, i: usize) -> Vec<usize> {
        let mut out = Vec::new();
        self.neighbors_into(i, &mut out);
        out
    }

    /// Materialize this generator as a [`Graph`] (test/equivalence
    /// bridge; O(m·degree) memory — small m only).
    pub fn materialize(&self) -> Graph {
        Graph::build(self.topology, self.m)
    }

    /// O(degree) allocation-free adjacency test — the hot edge-weight
    /// path at scale.
    pub fn has_edge(&self, i: usize, j: usize) -> bool {
        if i == j || i >= self.m || j >= self.m {
            return false;
        }
        let m = self.m;
        let diff = (j + m - i) % m; // forward circular distance i → j
        match &self.kind {
            GenKind::Ring => diff == 1 || diff == m - 1,
            GenKind::Exponential => {
                let mut hop = 1usize;
                while hop < m {
                    if diff == hop || diff == m - hop {
                        return true;
                    }
                    hop *= 2;
                }
                false
            }
            GenKind::Torus { rows, cols } => {
                let (rows, cols) = (*rows, *cols);
                let (ri, ci) = (i / cols, i % cols);
                let (rj, cj) = (j / cols, j % cols);
                let col_adj = cols > 1
                    && ri == rj
                    && ((ci + 1) % cols == cj || (cj + 1) % cols == ci);
                let row_adj = rows > 1
                    && ci == cj
                    && ((ri + 1) % rows == rj || (rj + 1) % rows == ri);
                col_adj || row_adj
            }
            GenKind::Circulant { offsets } => {
                offsets.iter().any(|&o| diff == o || diff == m - o)
            }
        }
    }
}

/// Ascending distinct ±2^j (mod m) neighbors of `i` — the exponential
/// graph rule, shared with the uniform-degree probe in `new`.
fn exp_neighbors_into(m: usize, i: usize, out: &mut Vec<usize>) {
    out.clear();
    let mut hop = 1usize;
    while hop < m {
        out.push((i + hop) % m);
        out.push((i + m - hop) % m);
        hop *= 2;
    }
    out.sort_unstable();
    out.dedup();
}

impl Neighborhood for GenTopology {
    fn node_count(&self) -> usize {
        self.m
    }

    fn degree(&self, i: usize) -> usize {
        match &self.kind {
            GenKind::Torus { rows, cols } => {
                let _ = i; // torus degree is position-independent too
                let row_deg = match *rows {
                    1 => 0,
                    2 => 1,
                    _ => 2,
                };
                let col_deg = match *cols {
                    1 => 0,
                    2 => 1,
                    _ => 2,
                };
                row_deg + col_deg
            }
            _ => self.uniform_degree,
        }
    }

    fn neighbors_into(&self, i: usize, out: &mut Vec<usize>) {
        let m = self.m;
        debug_assert!(i < m);
        match &self.kind {
            GenKind::Ring => {
                out.clear();
                out.push((i + 1) % m);
                out.push((i + m - 1) % m);
                out.sort_unstable();
                out.dedup();
            }
            GenKind::Exponential => exp_neighbors_into(m, i, out),
            GenKind::Torus { rows, cols } => {
                out.clear();
                let (rows, cols) = (*rows, *cols);
                let (r, c) = (i / cols, i % cols);
                let id = |r: usize, c: usize| r * cols + c;
                if cols > 1 {
                    out.push(id(r, (c + 1) % cols));
                    out.push(id(r, (c + cols - 1) % cols));
                }
                if rows > 1 {
                    out.push(id((r + 1) % rows, c));
                    out.push(id((r + rows - 1) % rows, c));
                }
                out.sort_unstable();
                out.dedup();
            }
            GenKind::Circulant { offsets } => {
                out.clear();
                for &o in offsets {
                    out.push((i + o) % m);
                    out.push((i + m - o) % m);
                }
                out.sort_unstable();
                out.dedup();
            }
        }
    }

    fn mix_weight(&self, i: usize, j: usize) -> f64 {
        if i != j {
            // Allocation-free edge path (the per-message hot path); the
            // expression is the exact MixingMatrix::metropolis one.
            if self.has_edge(i, j) {
                1.0 / (1.0 + self.degree(i).max(self.degree(j)) as f64)
            } else {
                0.0
            }
        } else {
            metropolis_weight(self, i, i)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::MixingMatrix;

    fn assert_matches_materialized(topology: Topology, m: usize) {
        let gen = GenTopology::new(topology, m).unwrap();
        let graph = Graph::build(topology, m);
        let mixing = MixingMatrix::metropolis(&graph);
        let mut nbrs = Vec::new();
        for i in 0..m {
            gen.neighbors_into(i, &mut nbrs);
            assert_eq!(nbrs.as_slice(), graph.neighbors(i), "{topology:?} m={m} node {i}");
            assert_eq!(gen.degree(i), graph.degree(i), "{topology:?} m={m} node {i}");
            for j in 0..m {
                let a = gen.mix_weight(i, j);
                let b = mixing.weight(i, j);
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{topology:?} m={m} w[{i},{j}] gen={a} mat={b}"
                );
            }
        }
    }

    #[test]
    fn ring_matches_materialized() {
        for m in [2, 3, 4, 7, 16] {
            assert_matches_materialized(Topology::Ring, m);
        }
    }

    #[test]
    fn exponential_matches_materialized() {
        for m in [2, 3, 8, 10, 17] {
            assert_matches_materialized(Topology::Exponential, m);
        }
    }

    #[test]
    fn torus_matches_materialized() {
        for m in [4, 6, 12, 16, 15] {
            assert_matches_materialized(Topology::Torus, m);
        }
    }

    #[test]
    fn random_regular_matches_materialized() {
        for (m, k) in [(8usize, 4u32), (16, 4), (16, 6), (33, 8)] {
            assert_matches_materialized(Topology::RandomRegular { k, seed: 7 }, m);
        }
    }

    #[test]
    fn random_regular_is_exactly_k_regular_and_seeded() {
        let t = Topology::RandomRegular { k: 6, seed: 11 };
        let g = GenTopology::new(t, 40).unwrap();
        for i in 0..40 {
            assert_eq!(g.degree(i), 6);
            assert_eq!(g.neighbors(i).len(), 6);
        }
        // Same (m, k, seed) → same offsets; different seed → (almost
        // surely) different edges but still k-regular.
        assert_eq!(
            circulant_offsets(40, 6, 11).unwrap(),
            circulant_offsets(40, 6, 11).unwrap()
        );
        let other = GenTopology::new(Topology::RandomRegular { k: 6, seed: 12 }, 40).unwrap();
        assert_eq!(other.degree(0), 6);
    }

    #[test]
    fn circulant_offsets_rejects_infeasible() {
        assert!(circulant_offsets(8, 3, 0).is_err()); // odd degree
        assert!(circulant_offsets(8, 0, 0).is_err());
        assert!(circulant_offsets(2, 2, 0).is_err()); // m too small
        assert!(circulant_offsets(7, 6, 0).is_err()); // not enough offsets
        assert_eq!(circulant_offsets(7, 4, 3).unwrap().len(), 2);
    }

    #[test]
    fn unsupported_topologies_error_cleanly() {
        for t in [Topology::Complete, Topology::Star, Topology::Path, Topology::TwoHopRing] {
            let err = GenTopology::new(t, 8).unwrap_err();
            assert!(err.contains("generator"), "{err}");
        }
        assert!(GenTopology::new(Topology::ErdosRenyi { p_milli: 400, seed: 1 }, 8).is_err());
    }

    #[test]
    fn million_node_queries_are_cheap() {
        // The point of the module: neighbor queries at m = 1M without
        // materializing anything.  Just exercise a handful of nodes.
        let m = 1_000_000;
        for t in [Topology::Ring, Topology::Exponential, Topology::Torus] {
            let g = GenTopology::new(t, m).unwrap();
            let mut nbrs = Vec::new();
            for &i in &[0usize, 1, m / 2, m - 1] {
                g.neighbors_into(i, &mut nbrs);
                assert_eq!(nbrs.len(), g.degree(i));
                assert!(nbrs.windows(2).all(|w| w[0] < w[1]));
                assert!(nbrs.iter().all(|&j| j < m && j != i));
                // Symmetry spot-check.
                let mut back = Vec::new();
                for &j in &nbrs {
                    g.neighbors_into(j, &mut back);
                    assert!(back.binary_search(&i).is_ok(), "{t:?}: {j} missing back-edge to {i}");
                }
                let w_self = g.mix_weight(i, i);
                assert!(w_self > 0.0 && w_self < 1.0);
            }
        }
        let g = GenTopology::new(Topology::RandomRegular { k: 8, seed: 3 }, m).unwrap();
        assert_eq!(g.degree(123_456), 8);
    }
}
