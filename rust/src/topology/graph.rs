//! Undirected connected graphs over `m` nodes.

use crate::util::rng::Rng;
use std::collections::BTreeSet;

/// The topologies used in the paper's evaluation plus common extras.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Each node linked to its two immediate neighbours (paper Fig. 2).
    Ring,
    /// Ring plus links to neighbours' neighbours (paper's "2-hop").
    TwoHopRing,
    /// Static exponential graph: node i links to i ± 2^j (mod m) for every
    /// j with 2^j < m — O(log m) degree with an O(1/log m) spectral gap,
    /// the standard high-connectivity topology in decentralized training.
    Exponential,
    /// Erdős–Rényi with edge probability p (paper uses p = 0.4);
    /// resampled until connected.
    ErdosRenyi { p_milli: u32, seed: u64 },
    /// All-to-all.
    Complete,
    /// Node 0 is the hub.
    Star,
    /// A line (worst-case spectral gap for fixed m).
    Path,
    /// 2-D torus grid; m must be rows*cols with |rows-cols| minimal.
    Torus,
    /// Seed-derived k-regular circulant: offset 1 (an m-cycle, so always
    /// connected) plus k/2 − 1 distinct offsets drawn from [2, (m−1)/2].
    /// Pure function of (m, k, seed) — shared with the generator path via
    /// [`circulant_offsets`](crate::topology::circulant_offsets).
    RandomRegular { k: u32, seed: u64 },
}

/// Torus factorization used by both the materialized and generator
/// paths: the smallest divisor r of m minimizing |m/r − r| (rows), with
/// cols = m/r.  E.g. m = 12 → 3 × 4.
pub fn torus_dims(m: usize) -> (usize, usize) {
    let rows = (1..=m)
        .filter(|r| m % r == 0)
        .min_by_key(|r| (m / r).abs_diff(*r))
        .unwrap();
    (rows, m / rows)
}

impl Topology {
    pub fn name(&self) -> &'static str {
        match self {
            Topology::Ring => "ring",
            Topology::TwoHopRing => "2hop",
            Topology::Exponential => "exp",
            Topology::ErdosRenyi { .. } => "er",
            Topology::Complete => "complete",
            Topology::Star => "star",
            Topology::Path => "path",
            Topology::Torus => "torus",
            Topology::RandomRegular { .. } => "rreg",
        }
    }

    /// Parse "ring" | "2hop" | "exp" | "er:0.4" | "complete" | "star" |
    /// "path" | "torus" | "rreg:k" (ER takes p, random-regular takes the
    /// even degree k, after a colon).
    pub fn parse(s: &str, seed: u64) -> Result<Topology, String> {
        let s = s.trim();
        if let Some(p) = s.strip_prefix("er:").or_else(|| s.strip_prefix("er=")) {
            let p: f64 = p.parse().map_err(|_| format!("bad ER probability: {s}"))?;
            if !(0.0..=1.0).contains(&p) {
                return Err(format!("ER probability out of range: {p}"));
            }
            return Ok(Topology::ErdosRenyi { p_milli: (p * 1000.0).round() as u32, seed });
        }
        if let Some(k) = s.strip_prefix("rreg:").or_else(|| s.strip_prefix("rreg=")) {
            let k: u32 = k.parse().map_err(|_| format!("bad random-regular degree: {s}"))?;
            if k < 2 || k % 2 != 0 {
                return Err(format!("random-regular degree must be even and >= 2, got {k}"));
            }
            return Ok(Topology::RandomRegular { k, seed });
        }
        match s {
            "ring" => Ok(Topology::Ring),
            "2hop" | "two-hop" | "twohop" => Ok(Topology::TwoHopRing),
            "exp" | "exponential" => Ok(Topology::Exponential),
            "er" => Ok(Topology::ErdosRenyi { p_milli: 400, seed }),
            "complete" | "full" => Ok(Topology::Complete),
            "star" => Ok(Topology::Star),
            "path" | "line" => Ok(Topology::Path),
            "torus" | "grid" => Ok(Topology::Torus),
            _ => Err(format!("unknown topology: {s}")),
        }
    }
}

/// Undirected graph with adjacency lists; invariant: connected, no
/// self-loops, neighbour lists sorted.
#[derive(Clone, Debug)]
pub struct Graph {
    pub m: usize,
    pub topology: Topology,
    adj: Vec<Vec<usize>>,
}

impl Graph {
    pub fn build(topology: Topology, m: usize) -> Graph {
        assert!(m >= 2, "need at least 2 nodes");
        let mut edges: BTreeSet<(usize, usize)> = BTreeSet::new();
        let add = |edges: &mut BTreeSet<(usize, usize)>, i: usize, j: usize| {
            if i != j {
                edges.insert((i.min(j), i.max(j)));
            }
        };
        match topology {
            Topology::Ring => {
                for i in 0..m {
                    add(&mut edges, i, (i + 1) % m);
                }
            }
            Topology::TwoHopRing => {
                for i in 0..m {
                    add(&mut edges, i, (i + 1) % m);
                    add(&mut edges, i, (i + 2) % m);
                }
            }
            Topology::Exponential => {
                for i in 0..m {
                    let mut hop = 1usize;
                    while hop < m {
                        add(&mut edges, i, (i + hop) % m);
                        hop *= 2;
                    }
                }
            }
            Topology::ErdosRenyi { p_milli, seed } => {
                let p = p_milli as f64 / 1000.0;
                let mut rng = Rng::new(seed);
                // Resample until connected (guaranteed to terminate for
                // p > 0 since we fall back to adding a ring after enough
                // failures).
                let mut attempts = 0;
                loop {
                    edges.clear();
                    for i in 0..m {
                        for j in (i + 1)..m {
                            if rng.bernoulli(p) {
                                edges.insert((i, j));
                            }
                        }
                    }
                    attempts += 1;
                    if Self::connected(m, &edges) {
                        break;
                    }
                    if attempts > 1000 {
                        // Degenerate p: superimpose a ring to restore
                        // connectivity (documented fallback).
                        for i in 0..m {
                            add(&mut edges, i, (i + 1) % m);
                        }
                        break;
                    }
                }
            }
            Topology::Complete => {
                for i in 0..m {
                    for j in (i + 1)..m {
                        edges.insert((i, j));
                    }
                }
            }
            Topology::Star => {
                for i in 1..m {
                    edges.insert((0, i));
                }
            }
            Topology::Path => {
                for i in 0..m - 1 {
                    edges.insert((i, i + 1));
                }
            }
            Topology::Torus => {
                let (rows, cols) = torus_dims(m);
                let id = |r: usize, c: usize| r * cols + c;
                for r in 0..rows {
                    for c in 0..cols {
                        if cols > 1 {
                            add(&mut edges, id(r, c), id(r, (c + 1) % cols));
                        }
                        if rows > 1 {
                            add(&mut edges, id(r, c), id((r + 1) % rows, c));
                        }
                    }
                }
            }
            Topology::RandomRegular { k, seed } => {
                let offsets = super::gen::circulant_offsets(m, k as usize, seed)
                    .unwrap_or_else(|e| panic!("{e}"));
                for i in 0..m {
                    for &o in &offsets {
                        add(&mut edges, i, (i + o) % m);
                    }
                }
            }
        }
        let mut adj = vec![Vec::new(); m];
        for &(i, j) in &edges {
            adj[i].push(j);
            adj[j].push(i);
        }
        for a in adj.iter_mut() {
            a.sort_unstable();
            a.dedup();
        }
        let g = Graph { m, topology, adj };
        assert!(g.is_connected(), "built graph must be connected");
        g
    }

    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj[i]
    }

    pub fn degree(&self, i: usize) -> usize {
        self.adj[i].len()
    }

    pub fn edge_count(&self) -> usize {
        self.adj.iter().map(Vec::len).sum::<usize>() / 2
    }

    pub fn edges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.edge_count());
        for i in 0..self.m {
            for &j in &self.adj[i] {
                if i < j {
                    out.push((i, j));
                }
            }
        }
        out
    }

    pub fn has_edge(&self, i: usize, j: usize) -> bool {
        self.adj[i].binary_search(&j).is_ok()
    }

    pub fn is_connected(&self) -> bool {
        let mut seen = vec![false; self.m];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(v) = stack.pop() {
            for &w in &self.adj[v] {
                if !seen[w] {
                    seen[w] = true;
                    count += 1;
                    stack.push(w);
                }
            }
        }
        count == self.m
    }

    fn connected(m: usize, edges: &BTreeSet<(usize, usize)>) -> bool {
        let mut adj = vec![Vec::new(); m];
        for &(i, j) in edges {
            adj[i].push(j);
            adj[j].push(i);
        }
        let mut seen = vec![false; m];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(v) = stack.pop() {
            for &w in &adj[v] {
                if !seen[w] {
                    seen[w] = true;
                    count += 1;
                    stack.push(w);
                }
            }
        }
        count == m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_degrees() {
        let g = Graph::build(Topology::Ring, 10);
        assert!(g.is_connected());
        for i in 0..10 {
            assert_eq!(g.degree(i), 2);
        }
        assert_eq!(g.edge_count(), 10);
    }

    #[test]
    fn two_hop_degrees() {
        let g = Graph::build(Topology::TwoHopRing, 10);
        for i in 0..10 {
            assert_eq!(g.degree(i), 4);
        }
        assert!(g.has_edge(0, 2) && g.has_edge(0, 1));
    }

    #[test]
    fn er_connected_and_deterministic() {
        let t = Topology::ErdosRenyi { p_milli: 400, seed: 7 };
        let g1 = Graph::build(t, 10);
        let g2 = Graph::build(t, 10);
        assert!(g1.is_connected());
        assert_eq!(g1.edges(), g2.edges());
    }

    #[test]
    fn complete_star_path_torus() {
        let g = Graph::build(Topology::Complete, 6);
        assert_eq!(g.edge_count(), 15);
        let g = Graph::build(Topology::Star, 6);
        assert_eq!(g.degree(0), 5);
        assert_eq!(g.degree(3), 1);
        let g = Graph::build(Topology::Path, 6);
        assert_eq!(g.edge_count(), 5);
        let g = Graph::build(Topology::Torus, 12); // 3×4 torus
        assert!(g.is_connected());
        for i in 0..12 {
            assert!(g.degree(i) >= 3, "torus degree {}", g.degree(i));
        }
    }

    #[test]
    fn exponential_degrees_and_edges() {
        // m = 8: hops {1, 2, 4}; hop 4 pairs antipodes, so degree is
        // 2·|hops| − 1 = 5 for every node.
        let g = Graph::build(Topology::Exponential, 8);
        assert!(g.is_connected());
        for i in 0..8 {
            assert_eq!(g.degree(i), 5, "node {i}");
        }
        assert!(g.has_edge(0, 1) && g.has_edge(0, 2) && g.has_edge(0, 4));
        assert!(!g.has_edge(0, 3));
        // Non-power-of-two m still connects and keeps O(log m) degree.
        let g = Graph::build(Topology::Exponential, 10);
        assert!(g.is_connected());
        for i in 0..10 {
            assert!(g.degree(i) <= 8, "degree {}", g.degree(i));
        }
        // Tiny m degenerates gracefully (hop 1 only).
        let g = Graph::build(Topology::Exponential, 2);
        assert_eq!(g.edge_count(), 1);
    }

    #[test]
    fn small_rings() {
        // m=2 and m=3 are edge cases for the modular neighbour formulas.
        let g = Graph::build(Topology::Ring, 2);
        assert_eq!(g.edge_count(), 1);
        let g = Graph::build(Topology::TwoHopRing, 3);
        assert!(g.is_connected());
        assert!(g.edge_count() <= 3);
    }

    #[test]
    fn parse_names() {
        assert_eq!(Topology::parse("ring", 0).unwrap(), Topology::Ring);
        assert_eq!(
            Topology::parse("er:0.4", 5).unwrap(),
            Topology::ErdosRenyi { p_milli: 400, seed: 5 }
        );
        assert_eq!(
            Topology::parse("rreg:6", 9).unwrap(),
            Topology::RandomRegular { k: 6, seed: 9 }
        );
        assert!(Topology::parse("nope", 0).is_err());
        assert!(Topology::parse("er:1.5", 0).is_err());
        assert!(Topology::parse("rreg:5", 0).is_err());
        assert!(Topology::parse("rreg:x", 0).is_err());
    }

    #[test]
    fn random_regular_builds_k_regular_connected() {
        let g = Graph::build(Topology::RandomRegular { k: 4, seed: 21 }, 20);
        assert!(g.is_connected());
        for i in 0..20 {
            assert_eq!(g.degree(i), 4, "node {i}");
        }
        // Deterministic by (m, k, seed).
        let g2 = Graph::build(Topology::RandomRegular { k: 4, seed: 21 }, 20);
        assert_eq!(g.edges(), g2.edges());
    }

    #[test]
    fn torus_dims_balanced() {
        assert_eq!(torus_dims(12), (3, 4));
        assert_eq!(torus_dims(16), (4, 4));
        assert_eq!(torus_dims(7), (1, 7));
        assert_eq!(torus_dims(2), (1, 2));
    }
}
