//! c2dfb — leader entrypoint / CLI.
//!
//! ```text
//! c2dfb run [--config cfg.toml] [--algo c2dfb] [--topology ring]
//!           [--network sim --drop_rate 0.1 --straggler 0.25:0.05 ...]
//!           [--stop_comm_mb MB --stop_first_order N --stop_wall_secs S ...]
//! c2dfb sweep [--tiny] [--config cfg.toml] [--algos L] [--tasks L] ...
//!           # declarative multi-axis scenario grid on the parallel pool
//! c2dfb table1 [--rounds N] [--target 0.7] [--tiny]
//! c2dfb fig2 | fig3 | fig4 | fig5 | fig6 | ablation [--rounds N] [--tiny]
//! c2dfb all [--rounds N]          # every table+figure harness
//! c2dfb netsweep [--rounds N] [--tiny]   # network-regime sweep (no artifacts)
//! c2dfb scale [--nodes M] [--rate P] ...  # sparse million-node engine
//! c2dfb budget [--budget_mb MB] [--tiny]  # equal-comm-budget comparison
//! c2dfb goldens [--bless] [--dir D] [--jobs N]  # golden-trace fixtures
//! c2dfb trace out.jsonl            # summarize a recorded JSONL trace
//! c2dfb artifacts                  # list AOT artifacts + shapes
//! c2dfb serve [--http A] [--tcp A] # long-running sweep daemon
//! c2dfb client <action> [...]      # talk to a running daemon
//! ```

use anyhow::{anyhow, Result};
use c2dfb::config::toml::TomlValue;
use c2dfb::config::ExperimentConfig;
use c2dfb::coordinator::{experiments, summarize, sweep, Runner};
use c2dfb::runtime::ArtifactRegistry;
use c2dfb::util::cli::Args;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "usage: c2dfb <run|sweep|scale|table1|fig2|fig3|fig4|fig5|fig6|ablation|netsweep|budget|goldens|trace|lint|all|artifacts|serve|client> [options]
  telemetry (run, sweep, and every harness; see docs/OBS.md):
            --trace FILE.jsonl (deterministic JSONL span trace, sim-time /
            counter stamped, byte-identical at any --jobs width)
            --profile (wall-clock per-phase profile, nondeterministic,
            printed separately)  --quiet (errors only)  --verbose
  run options: --config <file.toml> plus any config key as --key value
               (e.g. --algo mdbo --topology er:0.4 --partition het:0.8
                --rounds 100 --compressor topk:0.2 --lambda 10
                --dtype f32|f64, payload precision; docs/DTYPE.md)
               network keys: --network sync|sim  --latency S  --jitter S
                --bandwidth B/s  --drop_rate P  --straggler FRAC:DELAY
                --topology_schedule R:TOPO,...  --threads N
               stop keys (budgeted stopping, first to fire wins):
                --stop_comm_mb MB  --stop_first_order N  --stop_wall_secs S
                --stop_sim_secs S  --stop_target_accuracy A  --stop_rounds N
               scale keys (docs/SCALE.md): --generator true|false
                --sample_rate P  --consensus_estimator exact|strided:K|auto
  sweep options (declarative scenario grid, executed concurrently; see
            docs/SWEEP.md): --config <file.toml> with a [sweep] table, or
            axis lists --algos --tasks --topologies --compressors
            --partitions --engines --stops --dtypes --sampling_rates
            --generators (comma-separated), base knobs
            --nodes --rounds --seed --eval_every --out, --jobs N (cell
            parallelism, 0 = all cores), --calibrate true|false,
            --verify (prove N-way-parallel ≡ serial bit-identity; implied
            by --tiny); writes runs/sweep/report.{csv,json}
  harness options: --rounds N  --target 0.7  --tiny  --out DIR  --seed S
                   --jobs N (cell parallelism for artifact-free grids)
                   --verbose (stream one progress line per eval point)
  scale:    sparse gossip-descent at up to millions of nodes (docs/SCALE.md):
            generator topologies, lazy node state, calendar-queue delivery.
            --nodes M (default 100000)  --topology ring|exp|torus|rreg:k
            --rounds N  --rate P (per-round node sampling, (0,1])
            --dim D  --seed S  --eta X  --gamma X
            --consensus auto|auto:N|exact|strided:K  --out report.json
  netsweep: C²DFB vs baselines across network regimes (no artifacts needed);
            --dtype f32|f64 selects the payload precision
  budget:   all four algorithms to one communication budget (--budget_mb MB,
            --task quadratic|logreg|hyperrep, --dtype f32|f64, no artifacts
            needed); prints comm/oracles/loss + stop reason
  goldens:  replay the 4 algo x 3 task x 2 topology x 2 engine golden-trace
            matrix against rust/goldens/*.json (drift fails; missing files
            are bootstrapped); --bless regenerates the fixtures, --dir D
            overrides the fixture directory
  trace:    summarize a recorded JSONL trace into a per-phase cost table
            (c2dfb trace out.jsonl, or --file out.jsonl); validates every
            line against the schema in docs/OBS.md
  lint:     static determinism & hostile-input checks over the Rust tree
            (rules R1-R6, docs/LINT.md); policy from rust/lint.toml.
            c2dfb lint [paths...] [--config lint.toml] [--format text|json]
            [--fix-safety-stubs] — exits non-zero on any finding
  serve:    long-running sweep daemon (docs/SERVE.md): bounded priority
            job queue, deterministic completed-cell result cache, SSE
            progress streaming, Prometheus /metrics, graceful shutdown.
            --http ADDR (default 127.0.0.1:8642, 'off' disables)
            --tcp ADDR (default 127.0.0.1:8643, 'off' disables)
            --jobs N (cell parallelism)  --queue_cap N (default 64)
            --cache_cap N (default 4096)  --out DIR (default runs/daemon,
            'off' keeps artifacts in memory only)
  client:   talk to a running daemon over the TCP line protocol:
            c2dfb client [--addr HOST:PORT] <action>
              submit [--config f.toml | --tiny] [--priority P] [--trace]
                     [--wait [--timeout SECS]]
              status <id> | list | wait <id> [--timeout SECS]
              report <id> [--format csv|json|trace] [--out FILE]
              cancel <id> | metrics | ping | shutdown [--now]";

fn real_main() -> Result<()> {
    let args = Args::from_env();
    let sub = args
        .subcommand
        .clone()
        .ok_or_else(|| anyhow!("{USAGE}"))?;

    match sub.as_str() {
        "artifacts" => {
            args.finish().map_err(anyhow::Error::msg)?;
            let reg = ArtifactRegistry::open_default()?;
            println!("artifacts root: {}", reg.root.display());
            for (key, e) in &reg.manifest.entries {
                let ins: Vec<String> =
                    e.inputs.iter().map(|s| format!("{:?}", s.shape)).collect();
                println!(
                    "  {key:28} kernels={:6} inputs={} outputs={:?}",
                    e.kernels,
                    ins.join(","),
                    e.outputs.iter().map(|s| s.shape.clone()).collect::<Vec<_>>()
                );
            }
            Ok(())
        }
        "run" => cmd_run(args),
        "sweep" => cmd_sweep(args),
        "scale" => cmd_scale(args),
        "netsweep" => cmd_netsweep(args),
        "budget" => cmd_budget(args),
        "goldens" => cmd_goldens(args),
        "trace" => cmd_trace(args),
        "lint" => cmd_lint(args),
        "serve" => cmd_serve(args),
        "client" => cmd_client(args),
        "table1" | "fig2" | "fig3" | "fig4" | "fig5" | "fig6" | "ablation" | "all" => {
            cmd_harness(&sub, args)
        }
        other => Err(anyhow!("unknown subcommand {other:?}\n{USAGE}")),
    }
}

fn cmd_run(mut args: Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_toml_file(std::path::Path::new(&path))
            .map_err(anyhow::Error::msg)?,
        None => ExperimentConfig::default(),
    };
    // Any remaining --key value pairs are config overrides.
    for key in [
        "name", "preset", "algo", "algorithm", "nodes", "m", "topology", "partition",
        "compressor", "rounds", "inner_steps", "K", "eta_out", "eta_in", "gamma_out",
        "gamma_in", "gamma", "lambda", "sigma", "seed", "eval_every",
        "target_accuracy", "data_noise", "out_dir", "network", "latency", "jitter",
        "bandwidth", "drop_rate", "straggler", "topology_schedule", "threads",
        "stop_comm_mb", "stop_first_order", "stop_wall_secs", "stop_sim_secs",
        "stop_target_accuracy", "stop_rounds", "trace", "sample_rate", "generator",
        "consensus_estimator", "dtype",
    ] {
        if let Some(v) = args.get(key) {
            // Ints/floats/strings: try int, then float, then string.
            // `generator` alone takes a bool; parsing true/false for every
            // key would break string values that happen to spell a bool.
            let tv = if key == "generator" {
                match v.parse::<bool>() {
                    Ok(b) => TomlValue::Bool(b),
                    Err(_) => TomlValue::Str(v),
                }
            } else if let Ok(i) = v.parse::<i64>() {
                TomlValue::Int(i)
            } else if let Ok(f) = v.parse::<f64>() {
                TomlValue::Float(f)
            } else {
                TomlValue::Str(v)
            };
            cfg.apply_one(key, &tv).map_err(anyhow::Error::msg)?;
        }
    }
    if args.flag("profile") {
        cfg.obs.profile = true;
    }
    let con = c2dfb::obs::Console::new(args.flag("quiet"), args.flag("verbose"));
    args.finish().map_err(anyhow::Error::msg)?;
    cfg.validate()?;

    let reg = ArtifactRegistry::open_default()?;
    con.info(format_args!(
        "running {} on {} (topology={}, partition={}, compressor={}, rounds={})",
        cfg.algorithm.name(),
        cfg.preset,
        cfg.topology.name(),
        cfg.partition.name(),
        cfg.compressor,
        cfg.rounds
    ));
    let rec = c2dfb::obs::Recorder::new(cfg.obs.trace.is_some(), cfg.obs.profile);
    let metrics = Runner::new(&cfg).registry(&reg).recorder(&rec).run()?;
    con.info(format_args!("{}", summarize(&metrics)));
    let dir = std::path::Path::new(&cfg.out_dir).join(&cfg.name);
    metrics.write_to(&dir)?;
    con.info(format_args!("traces written to {}", dir.display()));
    if let Some(path) = &cfg.obs.trace {
        let text = rec.take_trace().unwrap_or_default();
        std::fs::write(path, text).map_err(|e| anyhow!("writing trace {path}: {e}"))?;
        con.info(format_args!("wrote JSONL trace to {path}"));
    }
    if let Some(p) = rec.render_profile() {
        println!("-- profile (wall-clock, nondeterministic) --\n{p}");
    }
    Ok(())
}

/// `c2dfb sweep`: expand the declared grid, execute it on the
/// work-stealing pool, write the aggregated report, and (with --verify,
/// implied by --tiny) prove the parallel run bit-identical to a serial
/// re-run of the same grid.
// CLI layer: wall-clock progress reporting only (lint.toml R1 allow5).
#[allow(clippy::disallowed_methods)]
fn cmd_sweep(mut args: Args) -> Result<()> {
    let tiny = args.flag("tiny");
    let mut spec = match args.get("config") {
        Some(path) => {
            let mut s = sweep::SweepSpec::from_toml_file(std::path::Path::new(&path))
                .map_err(anyhow::Error::msg)?;
            s.tiny |= tiny;
            s
        }
        None if tiny => sweep::SweepSpec::tiny(),
        None => sweep::SweepSpec::default(),
    };
    // Base-config knobs, then axis lists — all optional CLI overrides.
    for key in ["nodes", "rounds", "seed", "eval_every"] {
        if let Some(v) = args.get(key) {
            let tv = if let Ok(i) = v.parse::<i64>() {
                TomlValue::Int(i)
            } else {
                TomlValue::Str(v)
            };
            spec.base.apply_one(key, &tv).map_err(anyhow::Error::msg)?;
        }
    }
    if let Some(out) = args.get("out") {
        spec.base.out_dir = out;
    }
    for key in [
        "algos", "tasks", "topologies", "compressors", "partitions", "engines", "stops",
        "jobs", "calibrate",
    ] {
        if let Some(v) = args.get(key) {
            let tv = if let Ok(i) = v.parse::<i64>() {
                TomlValue::Int(i)
            } else if let Ok(b) = v.parse::<bool>() {
                TomlValue::Bool(b)
            } else {
                TomlValue::Str(v)
            };
            spec.apply_one(key, &tv).map_err(anyhow::Error::msg)?;
        }
    }
    // Scale/width axes take value lists verbatim ("0.5,1" would otherwise
    // be misparsed as a number by the loop above).
    for key in ["dtypes", "dtype", "sampling_rates", "sampling_rate", "generators", "generator"] {
        if let Some(v) = args.get(key) {
            spec.apply_one(key, &TomlValue::Str(v)).map_err(anyhow::Error::msg)?;
        }
    }
    let verify = args.flag("verify") || tiny;
    let verbose = args.flag("verbose");
    let trace_path = args.get("trace");
    let eopts = sweep::ExecOpts {
        jobs: spec.jobs,
        console: c2dfb::obs::Console::new(args.flag("quiet"), verbose),
        trace: trace_path.is_some(),
        profile: args.flag("profile"),
    };
    let con = eopts.console;
    args.finish().map_err(anyhow::Error::msg)?;

    let jobs = sweep::effective_jobs(spec.jobs);
    let started = std::time::Instant::now();
    let (grid, outcomes) = sweep::run_with(&spec, &eopts)?;
    con.info(format_args!(
        "== sweep: {} cells ({} tasks × {} partitions × {} topologies × {} compressors × {} engines × {} stops × {} algos) on {jobs} workers ==",
        grid.cells.len(),
        spec.tasks.len(),
        spec.partitions.len(),
        spec.topologies.len(),
        spec.compressors.len(),
        spec.engines.len(),
        spec.stops.len(),
        spec.algos.len(),
    ));
    let mut n_err = 0usize;
    for (cell, o) in grid.cells.iter().zip(&outcomes) {
        match &o.result {
            Ok(m) => con.info(format_args!("  {:48} {}", cell.id, summarize(m))),
            Err(e) => {
                n_err += 1;
                con.info(format_args!("  {:48} ERROR: {e}", cell.id));
            }
        }
    }
    con.info(format_args!(
        "ran {} cells in {:.1}s wall ({n_err} errors)",
        grid.cells.len(),
        started.elapsed().as_secs_f64()
    ));
    let dir = std::path::Path::new(&spec.base.out_dir).join(&spec.base.name);
    let (csv, json) = sweep::write_report(&dir, &grid.cells, &outcomes)?;
    con.info(format_args!(
        "aggregated report: {} + {}",
        csv.display(),
        json.display()
    ));
    if let Some(path) = &trace_path {
        std::fs::write(path, sweep::concat_traces(&outcomes))
            .map_err(|e| anyhow!("writing trace {path}: {e}"))?;
        con.info(format_args!("wrote JSONL trace to {path}"));
    }
    if eopts.profile {
        for oc in &outcomes {
            if let Some(p) = &oc.profile {
                println!("-- profile (wall-clock, nondeterministic): {} --\n{p}", oc.id);
            }
        }
    }

    if verify {
        con.info(format_args!(
            "verify: re-running the cells serially to prove bit-identity ..."
        ));
        // Re-run the already-expanded cells at jobs = 1 — same cells,
        // same task instances, same telemetry sinks, no duplicate grid
        // expansion or dataset generation; only the execution width
        // changes.  diff_outcomes also compares the per-cell JSONL
        // trace chunks, so a --trace run proves the trace bytes are
        // width-independent too.
        let sopts = sweep::ExecOpts {
            jobs: 1,
            console: c2dfb::obs::Console::quiet(),
            ..eopts
        };
        let soutcomes = sweep::run_cells_slots(&grid.cells, &grid.slots(), None, &sopts);
        if let Some(d) = sweep::diff_outcomes(&outcomes, &soutcomes) {
            anyhow::bail!("parallel execution diverged from serial: {d}");
        }
        let par_csv = sweep::report_csv(&grid.cells, &outcomes);
        let ser_csv = sweep::report_csv(&grid.cells, &soutcomes);
        let par_json = sweep::report_json(&grid.cells, &outcomes).to_string();
        let ser_json = sweep::report_json(&grid.cells, &soutcomes).to_string();
        anyhow::ensure!(
            par_csv == ser_csv && par_json == ser_json,
            "aggregate report bytes differ between parallel and serial execution"
        );
        con.info(format_args!(
            "OK {jobs}-way-parallel ≡ serial: all {} per-cell results bit-identical, report bytes identical.",
            outcomes.len()
        ));
    }
    if n_err > 0 {
        anyhow::bail!(
            "{n_err} of {} cells failed — per-cell errors are in the report at {}",
            grid.cells.len(),
            csv.display()
        );
    }
    Ok(())
}

/// `c2dfb serve`: the long-running sweep daemon (docs/SERVE.md).
fn cmd_serve(mut args: Args) -> Result<()> {
    let http = args.get_or("http", "127.0.0.1:8642");
    let tcp = args.get_or("tcp", "127.0.0.1:8643");
    let jobs = args.get_parse::<usize>("jobs", 0);
    let queue_cap = args.get_parse::<usize>("queue_cap", 64);
    let cache_cap = args.get_parse::<usize>("cache_cap", 4096);
    let out = args.get_or("out", "runs/daemon");
    let con = c2dfb::obs::Console::new(args.flag("quiet"), args.flag("verbose"));
    args.finish().map_err(anyhow::Error::msg)?;
    let opts = c2dfb::daemon::ServeOpts {
        http: (http != "off").then_some(http),
        tcp: (tcp != "off").then_some(tcp),
        jobs,
        queue_cap,
        cache_cap,
        out_dir: (out != "off").then_some(out),
        console: con,
        ..c2dfb::daemon::ServeOpts::default()
    };
    c2dfb::daemon::serve(opts)
}

/// `c2dfb client`: drive a running daemon over the TCP line protocol.
fn cmd_client(mut args: Args) -> Result<()> {
    use c2dfb::util::json::Json;
    let addr = args.get_or("addr", "127.0.0.1:8643");
    let con = c2dfb::obs::Console::new(args.flag("quiet"), args.flag("verbose"));
    let client = c2dfb::daemon::Client::new(&addr);
    let action = args
        .positional
        .first()
        .cloned()
        .ok_or_else(|| anyhow!("client wants an action\n{USAGE}"))?;
    let pos_id: Option<u64> = args.positional.get(1).and_then(|s| s.parse().ok());
    let need_id = || pos_id.ok_or_else(|| anyhow!("client {action} wants a job id"));
    let final_state = |status: &Json| -> Result<()> {
        match status.get("state").and_then(Json::as_str) {
            Some("done") => Ok(()),
            other => anyhow::bail!("job ended {}", other.unwrap_or("in an unknown state")),
        }
    };
    match action.as_str() {
        "ping" => {
            args.finish().map_err(anyhow::Error::msg)?;
            client.ping().map_err(anyhow::Error::msg)?;
            con.info(format_args!("pong from {addr}"));
            Ok(())
        }
        "submit" => {
            let tiny = args.flag("tiny");
            let config = args.get("config");
            let body = match (&config, tiny) {
                // The daemon resolves sweep.tiny=true to the exact grid
                // batch `c2dfb sweep --tiny` runs.
                (None, true) => r#"{"sweep": {"tiny": true}}"#.to_string(),
                (Some(path), _) => std::fs::read_to_string(path)
                    .map_err(|e| anyhow!("reading {path}: {e}"))?,
                (None, false) => anyhow::bail!("client submit wants --config FILE or --tiny"),
            };
            let priority = args.get_parse::<i64>("priority", 0);
            let trace = args.flag("trace");
            let wait = args.flag("wait");
            let timeout = args.get_parse::<u64>("timeout", 3600);
            args.finish().map_err(anyhow::Error::msg)?;
            let status = client.submit(&body, priority, trace).map_err(anyhow::Error::msg)?;
            println!("{}", status.to_string());
            if wait {
                let id = status
                    .get("id")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("daemon returned a status without an id"))?;
                let done = client
                    .wait(id as u64, std::time::Duration::from_secs(timeout), &con)
                    .map_err(anyhow::Error::msg)?;
                println!("{}", done.to_string());
                final_state(&done)?;
            }
            Ok(())
        }
        "status" => {
            let id = need_id()?;
            args.finish().map_err(anyhow::Error::msg)?;
            println!("{}", client.status(id).map_err(anyhow::Error::msg)?.to_string());
            Ok(())
        }
        "list" => {
            args.finish().map_err(anyhow::Error::msg)?;
            println!("{}", client.list().map_err(anyhow::Error::msg)?.to_string());
            Ok(())
        }
        "wait" => {
            let id = need_id()?;
            let timeout = args.get_parse::<u64>("timeout", 3600);
            args.finish().map_err(anyhow::Error::msg)?;
            let done = client
                .wait(id, std::time::Duration::from_secs(timeout), &con)
                .map_err(anyhow::Error::msg)?;
            println!("{}", done.to_string());
            final_state(&done)
        }
        "report" => {
            let id = need_id()?;
            let fmt = args.get_or("format", "csv");
            let out = args.get("out");
            args.finish().map_err(anyhow::Error::msg)?;
            let bytes = client.report(id, &fmt).map_err(anyhow::Error::msg)?;
            match out {
                Some(path) => {
                    std::fs::write(&path, &bytes).map_err(|e| anyhow!("writing {path}: {e}"))?;
                    con.info(format_args!("wrote {} bytes to {path}", bytes.len()));
                }
                None => {
                    use std::io::Write as _;
                    std::io::stdout().write_all(&bytes)?;
                }
            }
            Ok(())
        }
        "cancel" => {
            let id = need_id()?;
            args.finish().map_err(anyhow::Error::msg)?;
            println!("{}", client.cancel(id).map_err(anyhow::Error::msg)?.to_string());
            Ok(())
        }
        "metrics" => {
            args.finish().map_err(anyhow::Error::msg)?;
            print!("{}", client.metrics().map_err(anyhow::Error::msg)?);
            Ok(())
        }
        "shutdown" => {
            let now = args.flag("now");
            args.finish().map_err(anyhow::Error::msg)?;
            client.shutdown(now).map_err(anyhow::Error::msg)?;
            con.info(format_args!("daemon at {addr} is shutting down"));
            Ok(())
        }
        other => Err(anyhow!("unknown client action {other:?}\n{USAGE}")),
    }
}

/// `c2dfb scale`: the sparse million-node engine (`sim::scale`,
/// docs/SCALE.md).  No artifacts, no dense state — prints active
/// nodes/sec plus before/after consensus and loss estimates.
// CLI layer: times the engine call and stamps the report afterwards
// (lint.toml R1 allow5).
#[allow(clippy::disallowed_methods)]
fn cmd_scale(mut args: Args) -> Result<()> {
    use c2dfb::metrics::ConsensusEstimator;
    use c2dfb::sim::{ScaleOpts, ScaleSim};
    let seed: u64 = args.get_parse("seed", 42u64);
    let topo_spec = args.get_or("topology", "ring");
    let opts = ScaleOpts {
        nodes: args.get_parse("nodes", 100_000usize),
        topology: c2dfb::topology::Topology::parse(&topo_spec, seed)
            .map_err(anyhow::Error::msg)?,
        rounds: args.get_parse("rounds", 10usize),
        rate: args.get_parse("rate", 1.0f64),
        dim: args.get_parse("dim", 8usize),
        seed,
        eta: args.get_parse("eta", 0.1f64),
        gamma: args.get_parse("gamma", 0.5f64),
        estimator: ConsensusEstimator::parse(&args.get_or("consensus", "auto"))
            .map_err(anyhow::Error::msg)?,
    };
    let out = args.get("out");
    let con = c2dfb::obs::Console::new(args.flag("quiet"), args.flag("verbose"));
    args.finish().map_err(anyhow::Error::msg)?;
    let mut sim = ScaleSim::new(opts).map_err(anyhow::Error::msg)?;
    // The engine is wall-clock-free (lint R1); the CLI times the call and
    // stamps the nondeterministic throughput numbers onto the report.
    let t0 = std::time::Instant::now();
    let mut report = sim.run();
    report.set_wall(t0.elapsed().as_secs_f64());
    println!("{}", report.render());
    if let Some(path) = out {
        std::fs::write(&path, report.to_json().to_string())
            .map_err(|e| anyhow!("writing {path}: {e}"))?;
        con.info(format_args!("wrote scale report to {path}"));
    }
    Ok(())
}

fn cmd_netsweep(mut args: Args) -> Result<()> {
    let tiny = args.flag("tiny");
    let dtype = c2dfb::linalg::Dtype::parse(&args.get_or("dtype", "f32"))
        .map_err(anyhow::Error::msg)?;
    let opts = experiments::HarnessOpts {
        rounds: args.get_parse("rounds", if tiny { 12 } else { 60 }),
        out_dir: args.get_or("out", "runs"),
        seed: args.get_parse("seed", 42u64),
        verbose: args.flag("verbose"),
        quiet: args.flag("quiet"),
        trace: args.get("trace"),
        profile: args.flag("profile"),
        jobs: args.get_parse("jobs", 1usize),
        dtype,
        ..Default::default()
    };
    args.finish().map_err(anyhow::Error::msg)?;
    // Analytic task — no artifact registry needed.
    experiments::netsweep(&opts, tiny)?;
    opts.console().info(format_args!(
        "\ntraces under {}/netsweep/ — compare comm_mb / sim_time_s / dropped across regimes.",
        opts.out_dir
    ));
    Ok(())
}

fn cmd_budget(mut args: Args) -> Result<()> {
    let tiny = args.flag("tiny");
    let budget_mb: f64 = args.get_parse("budget_mb", if tiny { 0.75 } else { 8.0 });
    let task_spec = args.get_or("task", "quadratic");
    let dtype = c2dfb::linalg::Dtype::parse(&args.get_or("dtype", "f32"))
        .map_err(anyhow::Error::msg)?;
    let opts = experiments::HarnessOpts {
        // A generous non-progress guard; the comm budget should fire first.
        rounds: args.get_parse("rounds", if tiny { 200 } else { 600 }),
        out_dir: args.get_or("out", "runs"),
        seed: args.get_parse("seed", 42u64),
        verbose: args.flag("verbose"),
        quiet: args.flag("quiet"),
        trace: args.get("trace"),
        profile: args.flag("profile"),
        jobs: args.get_parse("jobs", 1usize),
        dtype,
        ..Default::default()
    };
    args.finish().map_err(anyhow::Error::msg)?;
    // Native tasks — no artifact registry needed.
    experiments::budget_on(&opts, budget_mb, tiny, &task_spec)?;
    opts.console().info(format_args!(
        "\ntraces under {}/budget/ — equal-communication comparison; the stop column records why each run ended.",
        opts.out_dir
    ));
    Ok(())
}

fn cmd_goldens(mut args: Args) -> Result<()> {
    let bless = args.flag("bless");
    let dir = match args.get("dir") {
        Some(d) => std::path::PathBuf::from(d),
        None => c2dfb::goldens::default_dir(),
    };
    // Scenario re-runs go through the sweep pool; bit-identical at any
    // width (0 = all cores).
    let jobs = args.get_parse("jobs", 1usize);
    args.finish().map_err(anyhow::Error::msg)?;
    if bless {
        let written = c2dfb::goldens::bless(&dir, jobs)?;
        for p in &written {
            println!("blessed {}", p.display());
        }
        println!(
            "{} fixture files regenerated; commit them so replay pins this behavior.",
            written.len()
        );
        return Ok(());
    }
    let report = c2dfb::goldens::replay(&dir, jobs)?;
    for p in &report.bootstrapped {
        println!("bootstrapped {} (no fixture on disk; commit it)", p.display());
    }
    println!(
        "replayed {} golden scenarios against {}",
        report.checked,
        dir.display()
    );
    if !report.ok() {
        for m in &report.mismatches {
            eprintln!("  DRIFT {m}");
        }
        anyhow::bail!(
            "{} golden-trace mismatches — if the change is intentional, \
             re-bless with `c2dfb goldens --bless` and commit the diff",
            report.mismatches.len()
        );
    }
    println!("all golden traces match.");
    Ok(())
}

fn cmd_harness(which: &str, mut args: Args) -> Result<()> {
    let tiny = args.flag("tiny");
    let mut opts = experiments::HarnessOpts {
        rounds: args.get_parse("rounds", if tiny { 20 } else { 120 }),
        out_dir: args.get_or("out", "runs"),
        seed: args.get_parse("seed", 42u64),
        verbose: args.flag("verbose"),
        quiet: args.flag("quiet"),
        trace: args.get("trace"),
        profile: args.flag("profile"),
        jobs: args.get_parse("jobs", 1usize),
        ..Default::default()
    };
    if tiny {
        opts.coeff_preset = "coeff_tiny".into();
        opts.hyperrep_preset = "hyperrep_tiny".into();
    }
    let target: f64 = args.get_parse("target", 0.7);
    args.finish().map_err(anyhow::Error::msg)?;

    let reg = ArtifactRegistry::open_default()?;
    match which {
        "table1" => {
            experiments::table1(&reg, &opts, target)?;
        }
        // Fig 4 is Fig 2's traces plotted against rounds; Fig 6 is Fig 3's.
        "fig2" | "fig4" => {
            experiments::fig2(&reg, &opts)?;
        }
        "fig3" | "fig6" => {
            experiments::fig3(&reg, &opts)?;
        }
        "fig5" => {
            experiments::fig5(&reg, &opts)?;
        }
        "ablation" => {
            experiments::compressor_ablation(&reg, &opts)?;
        }
        "all" => {
            experiments::table1(&reg, &opts, target)?;
            experiments::fig2(&reg, &opts)?;
            experiments::fig3(&reg, &opts)?;
            experiments::fig5(&reg, &opts)?;
            experiments::compressor_ablation(&reg, &opts)?;
        }
        _ => unreachable!(),
    }
    opts.console().info(format_args!("\ntraces under {}/ — plot loss/accuracy against comm_mb (Figs 2,3), wall/sim time (Fig 2 right, Table 1), or round (Figs 4,6).", opts.out_dir));
    Ok(())
}

/// `c2dfb trace <file.jsonl>`: validate every line of a recorded trace
/// against the JSONL schema and render the per-phase cost table
/// (bytes / oracles / sim-time by phase × algorithm × node decile).
fn cmd_trace(mut args: Args) -> Result<()> {
    let file = match args.get("file") {
        Some(f) => f,
        None => args
            .positional
            .first()
            .cloned()
            .ok_or_else(|| anyhow!("trace: expected a JSONL file, e.g. `c2dfb trace out.jsonl`"))?,
    };
    args.finish().map_err(anyhow::Error::msg)?;
    let text =
        std::fs::read_to_string(&file).map_err(|e| anyhow!("reading {file}: {e}"))?;
    let summary = c2dfb::obs::summarize(&text).map_err(anyhow::Error::msg)?;
    println!("{}", summary.render());
    Ok(())
}

/// `c2dfb lint`: the static determinism & hostile-input pass
/// (docs/LINT.md).  Exits non-zero on any finding, which is what makes
/// it a CI gate.
fn cmd_lint(mut args: Args) -> Result<()> {
    use c2dfb::analysis::{self, LintConfig};
    let format = args.get_or("format", "text");
    let mut paths: Vec<String> = args.positional.clone();
    let mut fix = args.flag("fix-safety-stubs");
    // The CLI grammar binds `--fix-safety-stubs PATH` as a key/value
    // pair; accept that spelling too and recover the path.
    if let Some(v) = args.get("fix-safety-stubs") {
        fix = true;
        paths.insert(0, v);
    }
    let cfg = match args.get("config") {
        Some(p) => LintConfig::load(std::path::Path::new(&p)).map_err(anyhow::Error::msg)?,
        None => {
            // Works from the repo root and from rust/ (where cargo test
            // and CI run); falls back to the built-in scopes.
            match ["lint.toml", "rust/lint.toml"]
                .iter()
                .find(|p| std::path::Path::new(p).is_file())
            {
                Some(p) => LintConfig::load(std::path::Path::new(p))
                    .map_err(anyhow::Error::msg)?,
                None => LintConfig::default_config(),
            }
        }
    };
    args.finish().map_err(anyhow::Error::msg)?;
    if paths.is_empty() {
        let root = ["src", "rust/src"]
            .iter()
            .find(|p| std::path::Path::new(p).is_dir())
            .ok_or_else(|| anyhow!("lint: no src/ or rust/src/ here; pass paths explicitly"))?;
        paths.push(root.to_string());
    }
    let report = analysis::lint_tree(&paths, &cfg).map_err(anyhow::Error::msg)?;
    if fix {
        let n = analysis::fix_safety_stubs(&report).map_err(anyhow::Error::msg)?;
        eprintln!(
            "lint: wrote {n} // SAFETY: FIXME stub(s); replace each with a real argument"
        );
    }
    match format.as_str() {
        "json" => println!("{}", report.to_json().to_string()),
        "text" => print!("{}", report.render_text()),
        other => return Err(anyhow!("lint: unknown --format {other:?} (text|json)")),
    }
    if report.findings.is_empty() {
        Ok(())
    } else {
        Err(anyhow!("lint: {} finding(s)", report.findings.len()))
    }
}
