//! The decentralized bilevel algorithms.
//!
//! * [`c2dfb`] — the paper's method (Algorithm 1 over Algorithm 2), and its
//!   naive-compression ablation C²DFB(nc).
//! * [`madsbo`] — MA-DSBO-style second-order baseline (Chen et al. 2023):
//!   decentralized lower-level GD, an HVP quadratic sub-solver for
//!   v ≈ (∇²_yy g)⁻¹ ∇_y f, and a moving-average hypergradient tracker.
//! * [`mdbo`] — gossip bilevel with Neumann-series Hessian-inverse
//!   approximation (Yang, Zhang & Wang 2022).
//!
//! All algorithms consume the same [`crate::tasks::BilevelTask`] oracle
//! bundle and pay communication through the same
//! [`Transport`](crate::collective::Transport), so comm-volume and
//! oracle-count comparisons are apples to apples (this is how the Table 1
//! / Fig. 2–4 harnesses work) — and each runs unmodified on either the
//! synchronous [`Network`](crate::collective::Network) or the
//! event-driven [`SimNetwork`](crate::sim::SimNetwork).
//!
//! Each method implements [`BilevelAlgorithm`] — `init` builds the iterate
//! state, `step` executes one outer round — and the [`drive`] loop owns
//! everything around the steps: evaluation cadence, the communication
//! ledger mirror, [`StopCondition`](crate::metrics::StopCondition)
//! checks, and [`RunObserver`] callbacks.  Budgeted runs are therefore
//! bit-identical prefixes of fixed-round runs.  Use
//! [`Runner`](crate::coordinator::Runner) unless you are composing the
//! pieces yourself; see `docs/API.md`.
//!
//! Per-node oracle batches go through [`RunContext::par_nodes`]: when the
//! task is `Sync` (the analytic tasks) and `network.threads > 1`, nodes
//! evaluate concurrently on a [`NodePool`] with node-ordered results, so
//! trajectories are bit-identical to the serial path.

pub mod c2dfb;
pub mod madsbo;
pub mod mdbo;

pub use self::c2dfb::C2dfb;
pub use self::madsbo::Madsbo;
pub use self::mdbo::Mdbo;

use crate::collective::Transport;
use crate::config::{Algorithm, ExperimentConfig};
use crate::linalg::Scalar;
use crate::metrics::{ConsensusEstimator, RunMetrics, StopReason, TracePoint};
use crate::obs::{LedgerSnap, Phase, Recorder};
use crate::sim::NodePool;
use crate::tasks::BilevelTask;
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::Arc;

/// Shared driver state handed to each algorithm.  Generic over the
/// payload [`Scalar`] `S` (iterates, oracles, and wire payloads all run at
/// `S`); the type parameter defaults to `f32` so existing
/// `RunContext<'_, T>` spellings keep meaning the historical path.
pub struct RunContext<'a, T: Transport, S: Scalar = f32> {
    pub task: &'a dyn BilevelTask<S>,
    /// Set when the task may be shared across threads (analytic tasks);
    /// enables the parallel per-node executor.
    task_sync: Option<&'a (dyn BilevelTask<S> + Sync)>,
    pub net: T,
    pub cfg: ExperimentConfig,
    pub rng: Rng,
    pub metrics: RunMetrics,
    pub pool: NodePool,
    /// Telemetry recorder (defaults to the no-op recorder — a single
    /// branch per instrumentation point, no allocation, no RNG).  Set via
    /// [`Runner::recorder`](crate::coordinator::Runner::recorder) or
    /// directly before [`drive`].
    pub obs: Recorder,
}

impl<'a, T: Transport, S: Scalar> RunContext<'a, T, S> {
    pub fn new(task: &'a dyn BilevelTask<S>, net: T, cfg: ExperimentConfig) -> Self {
        let label = format!("{}_{}", cfg.name, cfg.label());
        let metrics = RunMetrics::new(cfg.algorithm.name(), &label);
        let rng = Rng::new(cfg.seed ^ 0xA1607);
        let pool = NodePool::new(cfg.network.threads);
        RunContext {
            task,
            task_sync: None,
            net,
            cfg,
            rng,
            metrics,
            pool,
            obs: Recorder::noop(),
        }
    }

    /// Like [`RunContext::new`] for thread-shareable tasks: per-node
    /// oracle batches may then run on the pool.
    pub fn new_shared(
        task: &'a (dyn BilevelTask<S> + Sync),
        net: T,
        cfg: ExperimentConfig,
    ) -> Self {
        let mut ctx = RunContext::new(task, net, cfg);
        ctx.task_sync = Some(task);
        ctx
    }

    /// The `Sync` view of the task, when available.
    pub fn task_shared(&self) -> Option<&'a (dyn BilevelTask<S> + Sync)> {
        self.task_sync
    }

    /// Evaluate a pure per-node oracle batch `f(task, i)` for every node —
    /// on the thread pool when the task is shareable and the pool is
    /// wider than one thread, serially otherwise.  Results come back in
    /// node order either way, so downstream reductions are identical.
    pub fn par_nodes<R, F>(&self, f: F) -> Result<Vec<R>>
    where
        R: Send,
        F: Fn(&dyn BilevelTask<S>, usize) -> Result<R> + Sync,
    {
        let m = self.task.nodes();
        match self.task_sync {
            // NB: `ts` (the `+ Sync` view) must be what the closure
            // captures — coercing to `&dyn BilevelTask` before the closure
            // would make the capture non-Sync.
            Some(ts) if self.pool.threads() > 1 => {
                self.pool.map(m, |i| f(ts, i)).into_iter().collect()
            }
            _ => (0..m).map(|i| f(self.task, i)).collect(),
        }
    }

    /// Evaluate mean loss/acc over nodes and record a trace point.  The
    /// communication-ledger mirror is synced by [`drive`] (its single
    /// owner) before each call, so the point sees current byte totals.
    pub fn record(
        &mut self,
        round: usize,
        xs: &[Vec<S>],
        ys: &[Vec<S>],
        grad_norm: f64,
    ) -> Result<()> {
        // Consensus-model evaluation (paper protocol): test the averaged
        // (x̄, ȳ) on every node's validation shard.
        let (loss, acc) = crate::tasks::eval_consensus(self.task, xs, ys)?;
        self.metrics.oracles.evals += self.task.nodes() as u64;
        // The estimator spec is validated up front; "auto" is the exact
        // path (bitwise) below its node-count threshold, so existing
        // configs keep byte-stable traces.
        let est = ConsensusEstimator::parse(&self.cfg.scale.consensus)
            .map_err(anyhow::Error::msg)?;
        let consensus = est.estimate(xs);
        self.metrics.record_eval(round, loss, acc, grad_norm, consensus);
        Ok(())
    }
}

/// The active-node mask for outer round `round` — a pure function of
/// (seed, round, m, rate), so any round's mask can be recomputed from the
/// config alone (sweep replays, crash recovery, the adversarial tests).
///
/// `rate ≥ 1` returns `None` and consumes no RNG: the unsampled path is
/// bit-identical to a build without sampling at all.  Each node is active
/// with probability `rate`; an all-inactive draw activates node
/// `round % m` so every round makes progress.
pub fn sampling_mask(seed: u64, round: usize, m: usize, rate: f64) -> Option<Arc<Vec<bool>>> {
    if rate >= 1.0 {
        return None;
    }
    let salt = (seed ^ 0x5A4D_5053_414D_504C)
        .wrapping_add((round as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let mut rng = Rng::new(salt);
    let mut mask: Vec<bool> = (0..m).map(|_| rng.bernoulli(rate)).collect();
    if !mask.iter().any(|&a| a) {
        mask[round % m] = true;
    }
    Some(Arc::new(mask))
}

/// What one outer round reports back to the driver.
#[derive(Clone, Copy, Debug)]
pub struct StepOutcome {
    /// ‖mean hypergradient estimate‖ after the round (`NaN` when the
    /// algorithm has no estimate yet, e.g. the baselines at round 0).
    pub grad_norm: f64,
}

/// A decentralized bilevel method, driven one outer round at a time.
///
/// Implementations own their iterate state (models, trackers, inner-loop
/// caches); the [`drive`] loop owns everything around the steps —
/// evaluation cadence, stop conditions, observers, and the ledger mirror.
/// Constructed by [`make_algorithm`] or directly (e.g.
/// [`C2dfb::new`]`(naive)` for the compression ablation).
pub trait BilevelAlgorithm<T: Transport, S: Scalar = f32> {
    /// Algorithm identifier (matches [`Algorithm::name`]).
    fn name(&self) -> &'static str;
    /// Build all run state from the context; returns the round-0 outcome.
    fn init(&mut self, ctx: &mut RunContext<'_, T, S>) -> Result<StepOutcome>;
    /// Execute outer round `round` (0-based).
    fn step(&mut self, ctx: &mut RunContext<'_, T, S>, round: usize) -> Result<StepOutcome>;
    /// Per-node upper iterates (consensus evaluation reads these).
    fn xs(&self) -> &[Vec<S>];
    /// Per-node lower iterates.
    fn ys(&self) -> &[Vec<S>];
}

/// Construct the configured algorithm.  C²DFB(nc) is the same
/// implementation as C²DFB with `naive = true`.
pub fn make_algorithm<T: Transport, S: Scalar>(algo: Algorithm) -> Box<dyn BilevelAlgorithm<T, S>> {
    match algo {
        Algorithm::C2dfb => Box::new(C2dfb::new(false)),
        Algorithm::C2dfbNc => Box::new(C2dfb::new(true)),
        Algorithm::Madsbo => Box::new(Madsbo::new()),
        Algorithm::Mdbo => Box::new(Mdbo::new()),
    }
}

/// Callback surface of the [`drive`] loop: receives every recorded
/// [`TracePoint`] (progress lines, streaming consumers).  Returning
/// `false` aborts the run, recorded as [`StopReason::Observer`].
pub trait RunObserver {
    fn on_trace(&mut self, algo: &str, point: &TracePoint) -> bool;
}

/// The do-nothing observer.
pub struct NoObserver;

impl RunObserver for NoObserver {
    fn on_trace(&mut self, _algo: &str, _point: &TracePoint) -> bool {
        true
    }
}

/// The outer loop, owned by the coordinator: `init`, then `step` until a
/// [`StopCondition`](crate::metrics::StopCondition) fires.  Evaluation
/// (consensus loss/accuracy → trace point → observer → stop checks) runs
/// every `cfg.eval_every` rounds plus rounds 0 and `cfg.rounds`, so any
/// budget triggers within one eval interval of being exceeded and a
/// budget-stopped run is a bit-identical prefix of the fixed-round trace.
/// The stop reason lands in [`RunMetrics::stop_reason`].
pub fn drive<T: Transport, S: Scalar>(
    ctx: &mut RunContext<'_, T, S>,
    algo: &mut dyn BilevelAlgorithm<T, S>,
    observer: &mut dyn RunObserver,
) -> Result<()> {
    let stops = ctx.cfg.stop_conditions();
    let every = ctx.cfg.eval_every.max(1);
    ctx.obs.run_start(
        ctx.cfg.algorithm.name(),
        &ctx.metrics.label,
        ctx.net.m(),
        ctx.cfg.seed,
        &ctx.cfg.compressor,
    );
    let init_snap = LedgerSnap::of(ctx.net.ledger());
    let (f0, s0) = (ctx.metrics.oracles.first_order, ctx.metrics.oracles.second_order);
    let t = ctx.obs.clock();
    let mut out = algo.init(ctx)?;
    ctx.obs.phase_comm(
        Phase::Init,
        (ctx.metrics.oracles.first_order - f0) + (ctx.metrics.oracles.second_order - s0),
        init_snap,
        ctx.net.ledger(),
        t,
    );
    let mut round = 0usize;
    let reason = loop {
        // The transport owns the live byte counters; this is the single
        // place they are mirrored into the run metrics (trace points,
        // stop conditions and summaries all read the mirror).
        ctx.metrics.ledger = ctx.net.ledger().clone();
        if round % every == 0 || round == ctx.cfg.rounds {
            let t = ctx.obs.clock();
            ctx.record(round, algo.xs(), algo.ys(), out.grad_norm)?;
            ctx.obs.phase(Phase::Eval, ctx.net.m() as u64, t);
            let point = ctx.metrics.trace.last().expect("record pushed a point");
            ctx.obs.eval(point);
            if !observer.on_trace(algo.name(), point) {
                break StopReason::Observer;
            }
            if let Some(c) = stops.iter().find(|c| c.triggered(round, &ctx.metrics)) {
                break c.reason();
            }
        }
        // Refresh the round's sampling mask (None at rate 1.0 — the
        // default — which leaves every transport on its unmasked path).
        ctx.net.set_active(sampling_mask(
            ctx.cfg.seed,
            round,
            ctx.net.m(),
            ctx.cfg.sampling.rate,
        ));
        out = algo.step(ctx, round)?;
        ctx.obs.round(round, ctx.net.ledger(), &ctx.metrics.oracles);
        round += 1;
    };
    ctx.metrics.stop_reason = Some(reason);
    ctx.net.set_active(None);
    ctx.obs.run_end(&ctx.metrics);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_mask_is_pure_and_never_empty() {
        for round in 0..50 {
            let a = sampling_mask(7, round, 16, 0.2).unwrap();
            let b = sampling_mask(7, round, 16, 0.2).unwrap();
            assert_eq!(a, b, "mask must be a pure function of (seed, round)");
            assert!(a.iter().any(|&x| x), "round {round}: empty mask");
        }
        // Different rounds/seeds decorrelate.
        let r0 = sampling_mask(7, 0, 64, 0.5).unwrap();
        let r1 = sampling_mask(7, 1, 64, 0.5).unwrap();
        let s1 = sampling_mask(8, 0, 64, 0.5).unwrap();
        assert_ne!(r0, r1);
        assert_ne!(r0, s1);
    }

    #[test]
    fn sampling_mask_rate_one_is_none() {
        assert!(sampling_mask(1, 0, 10, 1.0).is_none());
        assert!(sampling_mask(1, 3, 10, 1.5).is_none());
    }

    #[test]
    fn sampling_mask_tiny_rate_forces_progress() {
        for round in 0..20 {
            let m = 5;
            let mask = sampling_mask(3, round, m, 1e-12).unwrap();
            let n = mask.iter().filter(|&&x| x).count();
            assert!(n >= 1, "round {round}: no active node");
        }
    }

    #[test]
    fn sampling_mask_rate_tracks_expectation() {
        let m = 4000;
        let mask = sampling_mask(11, 2, m, 0.3).unwrap();
        let frac = mask.iter().filter(|&&x| x).count() as f64 / m as f64;
        assert!((frac - 0.3).abs() < 0.05, "active fraction {frac} far from 0.3");
    }
}

