//! The decentralized bilevel algorithms.
//!
//! * [`c2dfb`] — the paper's method (Algorithm 1 over Algorithm 2), and its
//!   naive-compression ablation C²DFB(nc).
//! * [`madsbo`] — MA-DSBO-style second-order baseline (Chen et al. 2023):
//!   decentralized lower-level GD, an HVP quadratic sub-solver for
//!   v ≈ (∇²_yy g)⁻¹ ∇_y f, and a moving-average hypergradient tracker.
//! * [`mdbo`] — gossip bilevel with Neumann-series Hessian-inverse
//!   approximation (Yang, Zhang & Wang 2022).
//!
//! All algorithms consume the same [`crate::tasks::BilevelTask`] oracle
//! bundle and pay communication through the same
//! [`Transport`](crate::collective::Transport), so comm-volume and
//! oracle-count comparisons are apples to apples (this is how the Table 1
//! / Fig. 2–4 harnesses work) — and each runs unmodified on either the
//! synchronous [`Network`](crate::collective::Network) or the
//! event-driven [`SimNetwork`](crate::sim::SimNetwork).
//!
//! Per-node oracle batches go through [`RunContext::par_nodes`]: when the
//! task is `Sync` (the analytic tasks) and `network.threads > 1`, nodes
//! evaluate concurrently on a [`NodePool`] with node-ordered results, so
//! trajectories are bit-identical to the serial path.

pub mod c2dfb;
pub mod madsbo;
pub mod mdbo;

use crate::collective::Transport;
use crate::config::{Algorithm, ExperimentConfig};
use crate::metrics::RunMetrics;
use crate::sim::NodePool;
use crate::tasks::BilevelTask;
use crate::util::rng::Rng;
use anyhow::Result;

/// Shared driver state handed to each algorithm.
pub struct RunContext<'a, T: Transport> {
    pub task: &'a dyn BilevelTask,
    /// Set when the task may be shared across threads (analytic tasks);
    /// enables the parallel per-node executor.
    task_sync: Option<&'a (dyn BilevelTask + Sync)>,
    pub net: T,
    pub cfg: ExperimentConfig,
    pub rng: Rng,
    pub metrics: RunMetrics,
    pub pool: NodePool,
}

impl<'a, T: Transport> RunContext<'a, T> {
    pub fn new(task: &'a dyn BilevelTask, net: T, cfg: ExperimentConfig) -> Self {
        let label = format!("{}_{}", cfg.name, cfg.label());
        let metrics = RunMetrics::new(cfg.algorithm.name(), &label);
        let rng = Rng::new(cfg.seed ^ 0xA1607);
        let pool = NodePool::new(cfg.network.threads);
        RunContext { task, task_sync: None, net, cfg, rng, metrics, pool }
    }

    /// Like [`RunContext::new`] for thread-shareable tasks: per-node
    /// oracle batches may then run on the pool.
    pub fn new_shared(task: &'a (dyn BilevelTask + Sync), net: T, cfg: ExperimentConfig) -> Self {
        let mut ctx = RunContext::new(task, net, cfg);
        ctx.task_sync = Some(task);
        ctx
    }

    /// The `Sync` view of the task, when available.
    pub fn task_shared(&self) -> Option<&'a (dyn BilevelTask + Sync)> {
        self.task_sync
    }

    /// Evaluate a pure per-node oracle batch `f(task, i)` for every node —
    /// on the thread pool when the task is shareable and the pool is
    /// wider than one thread, serially otherwise.  Results come back in
    /// node order either way, so downstream reductions are identical.
    pub fn par_nodes<R, F>(&self, f: F) -> Result<Vec<R>>
    where
        R: Send,
        F: Fn(&dyn BilevelTask, usize) -> Result<R> + Sync,
    {
        let m = self.task.nodes();
        match self.task_sync {
            // NB: `ts` (the `+ Sync` view) must be what the closure
            // captures — coercing to `&dyn BilevelTask` before the closure
            // would make the capture non-Sync.
            Some(ts) if self.pool.threads() > 1 => {
                self.pool.map(m, |i| f(ts, i)).into_iter().collect()
            }
            _ => (0..m).map(|i| f(self.task, i)).collect(),
        }
    }

    /// Evaluate mean loss/acc over nodes and record a trace point.  Returns
    /// true if the target accuracy (if any) has been reached.
    pub fn record(
        &mut self,
        round: usize,
        xs: &[Vec<f32>],
        ys: &[Vec<f32>],
        grad_norm: f64,
    ) -> Result<bool> {
        // The network owns the live byte counters; mirror them into the
        // run metrics so trace points and summaries see current totals.
        self.metrics.ledger = self.net.ledger().clone();
        // Consensus-model evaluation (paper protocol): test the averaged
        // (x̄, ȳ) on every node's validation shard.
        let (loss, acc) = crate::tasks::eval_consensus(self.task, xs, ys)?;
        self.metrics.oracles.evals += self.task.nodes() as u64;
        let consensus = crate::linalg::consensus_err_sq(xs);
        self.metrics.record_eval(round, loss, acc, grad_norm, consensus);
        Ok(self
            .cfg
            .target_accuracy
            .map(|t| acc >= t)
            .unwrap_or(false))
    }
}

fn dispatch<T: Transport>(mut ctx: RunContext<T>) -> Result<RunMetrics> {
    match ctx.cfg.algorithm {
        Algorithm::C2dfb => c2dfb::run(&mut ctx, false)?,
        Algorithm::C2dfbNc => c2dfb::run(&mut ctx, true)?,
        Algorithm::Madsbo => madsbo::run(&mut ctx)?,
        Algorithm::Mdbo => mdbo::run(&mut ctx)?,
    }
    ctx.metrics.ledger = ctx.net.ledger().clone();
    Ok(ctx.metrics)
}

/// Entry point: dispatch on the configured algorithm and run to completion.
pub fn run<T: Transport>(
    task: &dyn BilevelTask,
    net: T,
    cfg: ExperimentConfig,
) -> Result<RunMetrics> {
    dispatch(RunContext::new(task, net, cfg))
}

/// [`run`] for thread-shareable tasks: honours `network.threads`.
pub fn run_shared<T: Transport>(
    task: &(dyn BilevelTask + Sync),
    net: T,
    cfg: ExperimentConfig,
) -> Result<RunMetrics> {
    dispatch(RunContext::new_shared(task, net, cfg))
}
