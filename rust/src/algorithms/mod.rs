//! The decentralized bilevel algorithms.
//!
//! * [`c2dfb`] — the paper's method (Algorithm 1 over Algorithm 2), and its
//!   naive-compression ablation C²DFB(nc).
//! * [`madsbo`] — MA-DSBO-style second-order baseline (Chen et al. 2023):
//!   decentralized lower-level GD, an HVP quadratic sub-solver for
//!   v ≈ (∇²_yy g)⁻¹ ∇_y f, and a moving-average hypergradient tracker.
//! * [`mdbo`] — gossip bilevel with Neumann-series Hessian-inverse
//!   approximation (Yang, Zhang & Wang 2022).
//!
//! All algorithms consume the same [`crate::tasks::BilevelTask`] oracle
//! bundle and pay communication through the same [`crate::collective`]
//! network, so comm-volume and oracle-count comparisons are apples to
//! apples (this is how the Table 1 / Fig. 2–4 harnesses work).

pub mod c2dfb;
pub mod madsbo;
pub mod mdbo;

use crate::collective::Network;
use crate::config::{Algorithm, ExperimentConfig};
use crate::metrics::RunMetrics;
use crate::tasks::BilevelTask;
use crate::util::rng::Rng;
use anyhow::Result;

/// Shared driver state handed to each algorithm.
pub struct RunContext<'a> {
    pub task: &'a dyn BilevelTask,
    pub net: Network,
    pub cfg: ExperimentConfig,
    pub rng: Rng,
    pub metrics: RunMetrics,
}

impl<'a> RunContext<'a> {
    pub fn new(task: &'a dyn BilevelTask, net: Network, cfg: ExperimentConfig) -> Self {
        let label = format!("{}_{}", cfg.name, cfg.label());
        let metrics = RunMetrics::new(cfg.algorithm.name(), &label);
        let rng = Rng::new(cfg.seed ^ 0xA1607);
        RunContext { task, net, cfg, rng, metrics }
    }

    /// Evaluate mean loss/acc over nodes and record a trace point.  Returns
    /// true if the target accuracy (if any) has been reached.
    pub fn record(
        &mut self,
        round: usize,
        xs: &[Vec<f32>],
        ys: &[Vec<f32>],
        grad_norm: f64,
    ) -> Result<bool> {
        // The network owns the live byte counters; mirror them into the
        // run metrics so trace points and summaries see current totals.
        self.metrics.ledger = self.net.ledger.clone();
        // Consensus-model evaluation (paper protocol): test the averaged
        // (x̄, ȳ) on every node's validation shard.
        let (loss, acc) = crate::tasks::eval_consensus(self.task, xs, ys)?;
        self.metrics.oracles.evals += self.task.nodes() as u64;
        let consensus = crate::linalg::consensus_err_sq(xs);
        self.metrics.record_eval(round, loss, acc, grad_norm, consensus);
        Ok(self
            .cfg
            .target_accuracy
            .map(|t| acc >= t)
            .unwrap_or(false))
    }
}

/// Entry point: dispatch on the configured algorithm and run to completion.
pub fn run(task: &dyn BilevelTask, net: Network, cfg: ExperimentConfig) -> Result<RunMetrics> {
    let mut ctx = RunContext::new(task, net, cfg);
    match ctx.cfg.algorithm {
        Algorithm::C2dfb => c2dfb::run(&mut ctx, false)?,
        Algorithm::C2dfbNc => c2dfb::run(&mut ctx, true)?,
        Algorithm::Madsbo => madsbo::run(&mut ctx)?,
        Algorithm::Mdbo => mdbo::run(&mut ctx)?,
    }
    ctx.metrics.ledger = ctx.net.ledger.clone();
    Ok(ctx.metrics)
}
