//! MADSBO — the MA-DSBO-style second-order baseline (Chen et al. 2023,
//! "Decentralized Stochastic Bilevel Optimization with Improved
//! per-Iteration Complexity"), re-implemented at the oracle/message level:
//!
//! per outer round:
//! 1. K steps of gradient-TRACKED decentralized GD on the lower-level y
//!    (MA-DSBO tracks the LL gradient; two dense exchanges — y and its
//!    tracker — per step);
//! 2. an HVP quadratic sub-solver: N tracked decentralized GD steps on
//!    ½vᵀ(∇²_yy g)v − vᵀ∇_y f  to get v ≈ (∇²_yy ḡ)⁻¹ ∇_y f̄
//!    (two dense exchanges + one HVP oracle per step);
//! 3. hypergradient  h_i = ∇_x f_i − (∇²_xy g_i)·v  (one JVP oracle);
//! 4. moving average  u_i ← (1−θ) u_i + θ h_i, gossip-mixed, and the
//!    upper step x_i ← mix(x)_i − η_out u_i (dense x exchange).
//!
//! Everything it sends is dense and it pays HVP/JVP (second-order) oracle
//! calls — the cost profile the paper's Table 1 contrasts C²DFB against.
//! (MDBO, by contrast, keeps the published *untracked* gossip SGD and
//! therefore suffers the full heterogeneity bias — see `mdbo.rs`.)
//!
//! Generic over the payload [`Scalar`] `S` like every algorithm here;
//! `f32` (the default) is byte-identical to the historical path.

use super::{BilevelAlgorithm, RunContext, StepOutcome};
use crate::collective::{MixScratch, Transport};
use crate::linalg::{kernels, Scalar};
use crate::obs::{LedgerSnap, Phase};
use crate::optim::DenseTracker;
use anyhow::Result;

/// Moving-average constant (paper Appendix C.1 uses 0.3).
const THETA: f64 = 0.3;
/// Quadratic sub-solver iterations per round.
pub(crate) const SUBSOLVER_STEPS: usize = 10;

/// MA-DSBO-style second-order baseline as a step-driven
/// [`BilevelAlgorithm`].
pub struct Madsbo<S: Scalar = f32> {
    st: Option<St<S>>,
}

/// Iterate state built by `init` and advanced by `step`.
struct St<S: Scalar> {
    eta_in: S,
    eta_out: S,
    gamma: f64,
    xs: Vec<Vec<S>>,
    ys: Vec<Vec<S>>,
    vs: Vec<Vec<S>>,
    us: Vec<Vec<S>>,
    /// Lower-level gradient tracker (persists across rounds; MA-DSBO
    /// warm-starts both y and its tracker).
    y_tracker: DenseTracker<S>,
    /// Reused buffers for every in-place dense mix (y/v/u/x exchanges).
    mix: MixScratch<S>,
}

impl<S: Scalar> Madsbo<S> {
    pub fn new() -> Madsbo<S> {
        Madsbo::default()
    }
}

impl<S: Scalar> Default for Madsbo<S> {
    fn default() -> Self {
        Madsbo { st: None }
    }
}

/// Per-row `h − g` into a fresh matrix (the sub-solver's tracked field).
fn rows_sub<S: Scalar>(hv: &[Vec<S>], gyf: &[Vec<S>]) -> Vec<Vec<S>> {
    hv.iter()
        .zip(gyf)
        .map(|(h, g)| {
            let mut out = vec![S::ZERO; h.len()];
            kernels::sub(h, g, &mut out);
            out
        })
        .collect()
}

impl<T: Transport, S: Scalar> BilevelAlgorithm<T, S> for Madsbo<S> {
    fn name(&self) -> &'static str {
        "madsbo"
    }

    fn init(&mut self, ctx: &mut RunContext<'_, T, S>) -> Result<StepOutcome> {
        let m = ctx.task.nodes();
        let dy = ctx.task.dy();
        let x0 = ctx.task.init_x(&mut ctx.rng);
        let y0 = ctx.task.init_y(&mut ctx.rng);
        let xs: Vec<Vec<S>> = vec![x0; m];
        let ys: Vec<Vec<S>> = vec![y0; m];
        let vs: Vec<Vec<S>> = vec![vec![S::ZERO; dy]; m];
        let us: Vec<Vec<S>> = vec![vec![S::ZERO; ctx.task.dx()]; m];

        let g0: Vec<Vec<S>> = ctx.par_nodes(|task, i| task.inner_z_grad(i, &xs[i], &ys[i]))?;
        ctx.metrics.oracles.first_order += m as u64;
        self.st = Some(St {
            eta_in: S::from_f64(ctx.cfg.eta_in),
            eta_out: S::from_f64(ctx.cfg.eta_out),
            gamma: ctx.cfg.gamma_out,
            xs,
            ys,
            vs,
            us,
            y_tracker: DenseTracker::new(g0),
            mix: MixScratch::new(),
        });
        // No hypergradient estimate before the first round.
        Ok(StepOutcome { grad_norm: f64::NAN })
    }

    fn step(&mut self, ctx: &mut RunContext<'_, T, S>, _round: usize) -> Result<StepOutcome> {
        let st = self.st.as_mut().expect("init() must run before step()");
        let m = ctx.task.nodes();
        let (eta_in, eta_out, gamma) = (st.eta_in, st.eta_out, st.gamma);
        let theta = S::from_f64(THETA);

        // -- 1. tracked lower-level loop (in-place dense mixes) -----------
        let snap = LedgerSnap::of(ctx.net.ledger());
        let t = ctx.obs.clock();
        for _k in 0..ctx.cfg.inner_steps {
            ctx.net.mix_paid_into(gamma, st.ys.as_mut_slice(), &mut st.mix);
            for (i, yi) in st.ys.iter_mut().enumerate() {
                kernels::descent(eta_in, st.y_tracker.s.row(i), yi);
            }
            let g: Vec<Vec<S>> =
                ctx.par_nodes(|task, i| task.inner_z_grad(i, &st.xs[i], &st.ys[i]))?;
            ctx.metrics.oracles.first_order += m as u64;
            st.y_tracker.update(&mut ctx.net, gamma, &g);
        }
        let lower_oracles = (ctx.cfg.inner_steps * m) as u64;
        ctx.obs
            .phase_comm(Phase::Lower, lower_oracles, snap, ctx.net.ledger(), t);

        // -- 2. tracked quadratic sub-solver for v ≈ H⁻¹ ∇_y f -------------
        let snap = LedgerSnap::of(ctx.net.ledger());
        let t = ctx.obs.clock();
        let gyf: Vec<Vec<S>> =
            ctx.par_nodes(|task, i| task.grad_y_f(i, &st.xs[i], &st.ys[i]))?;
        ctx.metrics.oracles.first_order += m as u64;
        let alpha = eta_in;
        let q0: Vec<Vec<S>> = {
            let hv: Vec<Vec<S>> =
                ctx.par_nodes(|task, i| task.hvp_yy_g(i, &st.xs[i], &st.ys[i], &st.vs[i]))?;
            ctx.metrics.oracles.second_order += m as u64;
            rows_sub(&hv, &gyf)
        };
        let mut v_tracker = DenseTracker::new(q0);
        for _n in 0..SUBSOLVER_STEPS {
            ctx.net.mix_paid_into(gamma, st.vs.as_mut_slice(), &mut st.mix);
            for (i, vi) in st.vs.iter_mut().enumerate() {
                kernels::descent(alpha, v_tracker.s.row(i), vi);
            }
            let q: Vec<Vec<S>> = {
                let hv: Vec<Vec<S>> =
                    ctx.par_nodes(|task, i| task.hvp_yy_g(i, &st.xs[i], &st.ys[i], &st.vs[i]))?;
                ctx.metrics.oracles.second_order += m as u64;
                rows_sub(&hv, &gyf)
            };
            v_tracker.update(&mut ctx.net, gamma, &q);
        }
        let hvp_oracles = (m + (1 + SUBSOLVER_STEPS) * m) as u64;
        ctx.obs
            .phase_comm(Phase::Hvp, hvp_oracles, snap, ctx.net.ledger(), t);

        // -- 3. hypergradient + moving average ----------------------------
        let t = ctx.obs.clock();
        let hyper: Vec<(Vec<S>, Vec<S>)> = ctx.par_nodes(|task, i| {
            let gxf = task.grad_x_f(i, &st.xs[i], &st.ys[i])?;
            let jv = task.jvp_xy_g(i, &st.xs[i], &st.ys[i], &st.vs[i])?;
            Ok((gxf, jv))
        })?;
        ctx.metrics.oracles.first_order += m as u64;
        ctx.metrics.oracles.second_order += m as u64;
        for (i, (gxf, jv)) in hyper.into_iter().enumerate() {
            kernels::ema_diff(theta, &gxf, &jv, &mut st.us[i]);
        }
        ctx.obs.phase(Phase::Hypergrad, 2 * m as u64, t);

        // Mix the hypergradient estimates (dense exchange).
        let snap = LedgerSnap::of(ctx.net.ledger());
        let t = ctx.obs.clock();
        ctx.net.mix_paid_into(gamma, st.us.as_mut_slice(), &mut st.mix);

        // -- 4. upper step -------------------------------------------------
        ctx.net.mix_paid_into(gamma, st.xs.as_mut_slice(), &mut st.mix);
        for (xi, ui) in st.xs.iter_mut().zip(&st.us) {
            kernels::descent(eta_out, ui, xi);
        }
        ctx.obs.phase_comm(Phase::Mix, 0, snap, ctx.net.ledger(), t);

        let grad_norm = crate::linalg::norm2(&crate::linalg::mean_rows(&st.us));
        Ok(StepOutcome { grad_norm })
    }

    fn xs(&self) -> &[Vec<S>] {
        &self.st.as_ref().expect("init() must run first").xs
    }

    fn ys(&self) -> &[Vec<S>] {
        &self.st.as_ref().expect("init() must run first").ys
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::Network;
    use crate::config::{Algorithm, ExperimentConfig};
    use crate::tasks::QuadraticTask;
    use crate::topology::{Graph, Topology};

    fn cfg(rounds: usize) -> ExperimentConfig {
        ExperimentConfig {
            algorithm: Algorithm::Madsbo,
            nodes: 6,
            rounds,
            inner_steps: 10,
            eta_out: 0.8,
            eta_in: 0.3,
            gamma_out: 0.8,
            eval_every: 10,
            ..ExperimentConfig::default()
        }
    }

    #[test]
    fn madsbo_converges_on_quadratic() {
        use crate::tasks::BilevelTask;
        let task: QuadraticTask = QuadraticTask::generate(6, 8, 0.8, 31);
        // ψ* > 0: measure excess loss over the analytic hyper-minimum.
        let mut xstar = task.init_x(&mut crate::util::rng::Rng::new(5));
        for _ in 0..5000 {
            let g = task.hypergrad_analytic(&xstar);
            for k in 0..xstar.len() {
                xstar[k] -= 0.2 * g[k];
            }
        }
        let psi_min = task.psi(&xstar);

        let net = Network::new(Graph::build(Topology::Ring, 6));
        let mut ctx = super::super::RunContext::new(&task, net, cfg(400));
        let mut algo = Madsbo::new();
        super::super::drive(&mut ctx, &mut algo, &mut super::super::NoObserver).unwrap();
        let first = ctx.metrics.trace.first().unwrap().loss;
        let last = ctx.metrics.trace.last().unwrap().loss;
        assert!(last.is_finite(), "diverged");
        let (e0, e1) = (first - psi_min, last - psi_min);
        assert!(
            e1 < e0 * 0.5,
            "excess loss {e0:.4} -> {e1:.4} (psi_min {psi_min:.4})"
        );
    }

    #[test]
    fn madsbo_pays_second_order_oracles_and_dense_bytes() {
        let task: QuadraticTask = QuadraticTask::generate(6, 8, 0.8, 32);
        let net = Network::new(Graph::build(Topology::Ring, 6));
        let mut ctx = super::super::RunContext::new(&task, net, cfg(5));
        let mut algo = Madsbo::new();
        super::super::drive(&mut ctx, &mut algo, &mut super::super::NoObserver).unwrap();
        assert!(ctx.metrics.oracles.second_order > 0);
        // Per round: 2K (tracked y) + 2N (tracked v) + 2 (u, x) dense
        // exchanges; plus one tracker bootstrap exchange... the ledger
        // counts every mix_paid/update call:
        let per_round = 2 * 10 + 2 * SUBSOLVER_STEPS + 2;
        let expected = 5 * per_round + 1; // +1 y-tracker bootstrap? none: new() doesn't mix
        // Allow exact check with the actual schedule:
        assert_eq!(
            ctx.metrics.ledger.gossip_rounds as usize,
            5 * per_round,
            "unexpected message schedule (expected ~{expected})"
        );
    }
}
