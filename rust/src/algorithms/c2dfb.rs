//! C²DFB — the paper's Algorithm 1 (outer loop) over Algorithm 2 (inner).
//!
//! Per outer round t, on every node i:
//!
//! 1. **Outer mixing + step** (communicate x, dense):
//!    `x_i ← x_i + γ_out Σ_j w_ij (x_j − x_i) − η_out (s_i)_x`
//! 2. **Inner loops** (communicate compressed residuals only):
//!    `y_i ← IN(h(x_i, ·))` on h = f + λg, warm-started;
//!    `z_i ← IN(g(x_i, ·))`.
//! 3. **Hypergradient** (local, fully first-order):
//!    `u_i = ∇_x f_i(x,y) + λ(∇_x g_i(x,y) − ∇_x g_i(x,z))`
//! 4. **Tracker update** (communicate s_x, dense):
//!    `(s_i)_x ← (s_i)_x + γ_out Σ_j w_ij ((s_j)_x − (s_i)_x) + u_i^{t+1} − u_i^t`
//!
//! With `naive = true` ([`C2dfb::new`]) the inner loops use the
//! error-feedback naive-compression protocol instead of reference points —
//! the paper's C²DFB(nc) ablation (same message sizes, worse error
//! dynamics).
//!
//! Generic over the payload [`Scalar`] `S`: iterates, oracle calls and
//! every wire payload run at `S` (docs/DTYPE.md), with `f32` the default
//! and byte-identical to the historical path.
//!
//! All communication goes through the generic [`Transport`], and the
//! per-node oracle batches run through [`GradFn`]/[`RunContext::par_nodes`]
//! so they can fan out over the thread pool for `Sync` tasks.  The outer
//! loop itself lives in [`super::drive`]; this module only implements
//! [`BilevelAlgorithm::init`]/[`BilevelAlgorithm::step`].

use super::{BilevelAlgorithm, RunContext, StepOutcome};
use crate::collective::{MixScratch, Transport};
use crate::compress::{self, Compressor};
use crate::linalg::{kernels, Scalar};
use crate::obs::{LedgerSnap, Phase, Scope};
use crate::optim::{
    run_inner_naive_with, run_inner_with, DenseTracker, GradFn, InnerConfig, InnerState,
};
use crate::sim::NodePool;
use crate::tasks::BilevelTask;
use crate::util::rng::Rng;
use anyhow::Result;

/// Which lower-level oracle an `IN` call descends on.
#[derive(Clone, Copy)]
enum InnerOracle<S: Scalar> {
    /// ∇_y h with h = f + λg (the y-sequence).
    Y { lambda: S },
    /// ∇_y g (the z-sequence).
    Z,
}

impl<S: Scalar> InnerOracle<S> {
    /// Evaluate into the inner loop's reusable gradient row.  (The task
    /// oracles themselves return fresh vectors — that allocation belongs
    /// to the task API, not the coordination hot path.)
    fn eval_into(
        &self,
        task: &dyn BilevelTask<S>,
        i: usize,
        xs: &[Vec<S>],
        d: &[S],
        out: &mut [S],
    ) {
        let g = match self {
            InnerOracle::Y { lambda } => task
                .inner_y_grad(i, &xs[i], d, *lambda)
                .expect("inner_y oracle failed"),
            InnerOracle::Z => task
                .inner_z_grad(i, &xs[i], d)
                .expect("inner_z oracle failed"),
        };
        out.copy_from_slice(&g);
    }
}

/// One warm-started `IN` call (Algorithm 2): pick the protocol (reference
/// points vs naive error feedback) and the oracle execution mode (serial,
/// or fanned out over the pool when a `Sync` task view exists).  Returns
/// oracle calls made.
#[allow(clippy::too_many_arguments)]
fn inner_pass<S: Scalar, T: Transport>(
    naive: bool,
    cfg: &InnerConfig,
    net: &mut T,
    compressor: &dyn Compressor<S>,
    rng: &mut Rng,
    state: &mut InnerState<S>,
    d: &mut [Vec<S>],
    xs: &[Vec<S>],
    oracle: InnerOracle<S>,
    task: &dyn BilevelTask<S>,
    shared: Option<&(dyn BilevelTask<S> + Sync)>,
    pool: &NodePool,
) -> u64 {
    match shared {
        Some(ts) => {
            let g = |i: usize, di: &[S], out: &mut [S]| oracle.eval_into(ts, i, xs, di, out);
            let grad = GradFn::Parallel(&g, pool);
            if naive {
                run_inner_naive_with(cfg, net, compressor, rng, state, d, grad)
            } else {
                run_inner_with(cfg, net, compressor, rng, state, d, grad)
            }
        }
        None => {
            let mut g =
                |i: usize, di: &[S], out: &mut [S]| oracle.eval_into(task, i, xs, di, out);
            let grad = GradFn::Serial(&mut g);
            if naive {
                run_inner_naive_with(cfg, net, compressor, rng, state, d, grad)
            } else {
                run_inner_with(cfg, net, compressor, rng, state, d, grad)
            }
        }
    }
}

/// C²DFB (Algorithm 1 over Algorithm 2) as a step-driven
/// [`BilevelAlgorithm`]; `naive = true` is the C²DFB(nc) ablation.
pub struct C2dfb<S: Scalar = f32> {
    naive: bool,
    st: Option<St<S>>,
}

/// Iterate state built by `init` and advanced by `step`.
struct St<S: Scalar> {
    lambda: S,
    compressor: Box<dyn Compressor<S>>,
    inner_cfg_y: InnerConfig,
    inner_cfg_z: InnerConfig,
    xs: Vec<Vec<S>>,
    ys: Vec<Vec<S>>,
    zs: Vec<Vec<S>>,
    y_state: InnerState<S>,
    z_state: InnerState<S>,
    tracker: DenseTracker<S>,
    /// Reused buffers for the outer in-place x mixing.
    mix: MixScratch<S>,
}

impl<S: Scalar> C2dfb<S> {
    /// `naive` selects the error-feedback naive-compression inner protocol
    /// (the paper's C²DFB(nc)) instead of reference points.
    pub fn new(naive: bool) -> C2dfb<S> {
        C2dfb { naive, st: None }
    }
}

impl<T: Transport, S: Scalar> BilevelAlgorithm<T, S> for C2dfb<S> {
    fn name(&self) -> &'static str {
        if self.naive {
            "c2dfb_nc"
        } else {
            "c2dfb"
        }
    }

    fn init(&mut self, ctx: &mut RunContext<'_, T, S>) -> Result<StepOutcome> {
        let m = ctx.task.nodes();
        let lambda = S::from_f64(ctx.cfg.lambda);
        let compressor = compress::parse(&ctx.cfg.compressor).map_err(anyhow::Error::msg)?;
        let inner_cfg_y = InnerConfig {
            eta: ctx.cfg.eta_in / (1.0 + ctx.cfg.lambda), // h = f + λg is (λL)-smooth
            gamma: ctx.cfg.gamma_in,
            k_steps: ctx.cfg.inner_steps,
        };
        let inner_cfg_z = InnerConfig {
            eta: ctx.cfg.eta_in,
            gamma: ctx.cfg.gamma_in,
            k_steps: ctx.cfg.inner_steps,
        };

        // Identical models on every node (paper setup).
        let x0 = ctx.task.init_x(&mut ctx.rng);
        let y0 = ctx.task.init_y(&mut ctx.rng);
        let xs: Vec<Vec<S>> = vec![x0; m];
        let ys: Vec<Vec<S>> = vec![y0.clone(); m];
        let zs: Vec<Vec<S>> = vec![y0; m];
        let mut y_state = InnerState::new(&ctx.net, ctx.task.dy());
        let mut z_state = InnerState::new(&ctx.net, ctx.task.dy());
        y_state.obs = ctx.obs.scoped(Scope::InnerY);
        z_state.obs = ctx.obs.scoped(Scope::InnerZ);

        // s_x⁰ = u_i⁰ with the initial (y, z).
        let u: Vec<Vec<S>> =
            ctx.par_nodes(|task, i| task.hypergrad(i, &xs[i], &ys[i], &zs[i], lambda))?;
        ctx.metrics.oracles.first_order += m as u64;
        let grad_norm = crate::linalg::norm2(&crate::linalg::mean_rows(&u));
        self.st = Some(St {
            lambda,
            compressor,
            inner_cfg_y,
            inner_cfg_z,
            xs,
            ys,
            zs,
            y_state,
            z_state,
            tracker: DenseTracker::new(u),
            mix: MixScratch::new(),
        });
        Ok(StepOutcome { grad_norm })
    }

    fn step(&mut self, ctx: &mut RunContext<'_, T, S>, _round: usize) -> Result<StepOutcome> {
        let st = self.st.as_mut().expect("init() must run before step()");
        let m = ctx.task.nodes();
        let pool = ctx.pool;
        let lambda = st.lambda;
        let eta_out = S::from_f64(ctx.cfg.eta_out);
        // Snapshot the round's sampling mask (set on the transport by the
        // driver).  Inactive nodes sit the whole round out: their x/y/z
        // rows freeze, they pay no oracle calls and transmit no bytes —
        // the masked transports and inner loops enforce the wire side.
        let active: Option<Vec<bool>> = ctx.net.active().map(|a| a.to_vec());

        // -- 1. outer mixing + descent (pays one dense x exchange) -------
        let snap = LedgerSnap::of(ctx.net.ledger());
        let t = ctx.obs.clock();
        ctx.net
            .mix_paid_into(ctx.cfg.gamma_out, st.xs.as_mut_slice(), &mut st.mix);
        for (i, xi) in st.xs.iter_mut().enumerate() {
            if let Some(mask) = &active {
                if !mask[i] {
                    continue;
                }
            }
            kernels::descent(eta_out, st.tracker.s.row(i), xi);
        }
        ctx.obs.phase_comm(Phase::Mix, 0, snap, ctx.net.ledger(), t);

        // -- 2. inner loops (compressed) ----------------------------------
        let shared = ctx.task_shared().filter(|_| pool.threads() > 1);
        for (cfg, state, d, oracle) in [
            (
                &st.inner_cfg_y,
                &mut st.y_state,
                &mut st.ys,
                InnerOracle::Y { lambda },
            ),
            (&st.inner_cfg_z, &mut st.z_state, &mut st.zs, InnerOracle::Z),
        ] {
            let calls = inner_pass(
                self.naive,
                cfg,
                &mut ctx.net,
                st.compressor.as_ref(),
                &mut ctx.rng,
                state,
                d,
                &st.xs,
                oracle,
                ctx.task,
                shared,
                &pool,
            );
            ctx.metrics.oracles.first_order += calls;
        }

        // -- 3. local hypergradients --------------------------------------
        //       Under sampling only active nodes evaluate; inactive nodes
        //       report their last hypergradient, so the tracker folds a
        //       zero difference for them and the mean-gradient readout
        //       stays defined at every node.
        let t = ctx.obs.clock();
        let (u_new, hyper_evals): (Vec<Vec<S>>, u64) = match &active {
            None => (
                ctx.par_nodes(|task, i| {
                    task.hypergrad(i, &st.xs[i], &st.ys[i], &st.zs[i], lambda)
                })?,
                m as u64,
            ),
            Some(mask) => {
                let mut u = Vec::with_capacity(m);
                let mut evals = 0u64;
                for i in 0..m {
                    if mask[i] {
                        u.push(ctx.task.hypergrad(i, &st.xs[i], &st.ys[i], &st.zs[i], lambda)?);
                        evals += 1;
                    } else {
                        u.push(st.tracker.last_u(i).to_vec());
                    }
                }
                (u, evals)
            }
        };
        ctx.metrics.oracles.first_order += hyper_evals;
        ctx.obs.phase(Phase::Hypergrad, hyper_evals, t);

        // -- 4. gradient tracking on s_x (pays one dense s exchange) -----
        let snap = LedgerSnap::of(ctx.net.ledger());
        let t = ctx.obs.clock();
        st.tracker.update(&mut ctx.net, ctx.cfg.gamma_out, &u_new);
        ctx.obs.phase_comm(Phase::Tracker, 0, snap, ctx.net.ledger(), t);
        let grad_norm = crate::linalg::norm2(&crate::linalg::mean_rows(&u_new));
        Ok(StepOutcome { grad_norm })
    }

    fn xs(&self) -> &[Vec<S>] {
        &self.st.as_ref().expect("init() must run first").xs
    }

    fn ys(&self) -> &[Vec<S>] {
        &self.st.as_ref().expect("init() must run first").ys
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::Network;
    use crate::config::{Algorithm, ExperimentConfig};
    use crate::tasks::QuadraticTask;
    use crate::topology::{Graph, Topology};

    fn quad_cfg(rounds: usize) -> ExperimentConfig {
        ExperimentConfig {
            algorithm: Algorithm::C2dfb,
            nodes: 6,
            rounds,
            inner_steps: 20,
            eta_out: 0.3,
            eta_in: 0.4,
            gamma_out: 0.8,
            gamma_in: 0.6,
            lambda: 50.0,
            compressor: "topk:0.5".into(),
            eval_every: 10,
            ..ExperimentConfig::default()
        }
    }

    fn run_quad(rounds: usize, naive: bool) -> (f64, crate::metrics::RunMetrics) {
        let task: QuadraticTask = QuadraticTask::generate(6, 8, 1.0, 21);
        let net = Network::new(Graph::build(Topology::Ring, 6));
        let mut ctx = RunContext::new(&task, net, quad_cfg(rounds));
        let mut algo = C2dfb::new(naive);
        crate::algorithms::drive(&mut ctx, &mut algo, &mut crate::algorithms::NoObserver).unwrap();
        // Hyper-stationarity of the mean upper model.
        let xbar = {
            // re-derive final xs is not exposed; use grad_norm from trace.
            ctx.metrics.trace.last().unwrap().grad_norm
        };
        (xbar, ctx.metrics)
    }

    #[test]
    fn c2dfb_drives_hypergradient_down_on_quadratic() {
        let (g_end, metrics) = run_quad(150, false);
        let g_start = metrics.trace.first().unwrap().grad_norm;
        assert!(
            g_end < g_start * 0.05,
            "hypergrad norm {g_start} -> {g_end} (insufficient decrease)"
        );
        assert!(metrics.trace.last().unwrap().loss < metrics.trace[0].loss);
    }

    #[test]
    fn c2dfb_reaches_consensus() {
        let (_, metrics) = run_quad(150, false);
        let c_end = metrics.trace.last().unwrap().consensus_err;
        assert!(c_end < 1e-3, "consensus err {c_end}");
    }

    #[test]
    fn naive_variant_also_runs_but_tracks_more_error() {
        let (g_ref, m_ref) = run_quad(80, false);
        let (g_nc, m_nc) = run_quad(80, true);
        assert!(g_ref.is_finite() && g_nc.is_finite());
        // Identical message schedule ⇒ identical byte counts.
        assert_eq!(m_ref.ledger.total_bytes, m_nc.ledger.total_bytes);
    }

    #[test]
    fn oracle_counts_are_first_order_only() {
        let (_, metrics) = run_quad(10, false);
        assert!(metrics.oracles.first_order > 0);
        assert_eq!(metrics.oracles.second_order, 0);
    }

    #[test]
    fn target_accuracy_stops_early() {
        let task: QuadraticTask = QuadraticTask::generate(6, 8, 0.5, 22);
        let net = Network::new(Graph::build(Topology::Ring, 6));
        let mut cfg = quad_cfg(500);
        cfg.target_accuracy = Some(0.0); // any accuracy qualifies
        cfg.eval_every = 1;
        let mut ctx = RunContext::new(&task, net, cfg);
        let mut algo = C2dfb::new(false);
        crate::algorithms::drive(&mut ctx, &mut algo, &mut crate::algorithms::NoObserver).unwrap();
        // The driver checks the target at round 0 already.
        assert_eq!(ctx.metrics.trace.len(), 1);
        assert_eq!(
            ctx.metrics.stop_reason,
            Some(crate::metrics::StopReason::TargetAccuracy)
        );
    }

    /// The shared-task parallel path is bit-identical to the serial path
    /// and counts the same oracle calls.
    #[test]
    fn parallel_pool_matches_serial_run() {
        let task: QuadraticTask = QuadraticTask::generate(6, 8, 1.0, 23);
        let run_with_threads = |threads: usize| {
            let mut cfg = quad_cfg(30);
            cfg.network.threads = threads;
            let net = Network::new(Graph::build(Topology::Ring, 6));
            let mut ctx = RunContext::new_shared(&task, net, cfg);
            let mut algo = C2dfb::new(false);
            crate::algorithms::drive(&mut ctx, &mut algo, &mut crate::algorithms::NoObserver)
                .unwrap();
            ctx.metrics
        };
        let serial = run_with_threads(1);
        let par = run_with_threads(4);
        assert_eq!(serial.oracles.first_order, par.oracles.first_order);
        assert_eq!(serial.ledger.total_bytes, par.ledger.total_bytes);
        let a: Vec<u64> = serial.trace.iter().map(|p| p.loss.to_bits()).collect();
        let b: Vec<u64> = par.trace.iter().map(|p| p.loss.to_bits()).collect();
        assert_eq!(a, b, "loss trace must not depend on thread count");
    }

    /// Node sampling at rate 0.5: strictly fewer oracle calls and bytes
    /// than the full run, deterministic trace, finite everywhere — and
    /// still making progress on the hypergradient.
    #[test]
    fn sampled_run_is_deterministic_and_cheaper() {
        let task: QuadraticTask = QuadraticTask::generate(6, 8, 1.0, 21);
        let run = |rate: f64| {
            let mut cfg = quad_cfg(60);
            cfg.sampling.rate = rate;
            cfg.validate().unwrap();
            let net = Network::new(Graph::build(Topology::Ring, 6));
            let mut ctx = RunContext::new(&task, net, cfg);
            let mut algo = C2dfb::new(false);
            crate::algorithms::drive(&mut ctx, &mut algo, &mut crate::algorithms::NoObserver)
                .unwrap();
            ctx.metrics
        };
        let full = run(1.0);
        let half = run(0.5);
        assert!(
            half.oracles.first_order < full.oracles.first_order,
            "sampled {} !< full {}",
            half.oracles.first_order,
            full.oracles.first_order
        );
        assert!(half.ledger.total_bytes < full.ledger.total_bytes);
        assert!(half
            .trace
            .iter()
            .all(|p| p.loss.is_finite() && p.consensus_err.is_finite()));
        let g0 = half.trace.first().unwrap().grad_norm;
        let g1 = half.trace.last().unwrap().grad_norm;
        assert!(g1 < g0, "sampled run made no progress: {g0} -> {g1}");
        let again = run(0.5);
        let a: Vec<u64> = half.trace.iter().map(|p| p.loss.to_bits()).collect();
        let b: Vec<u64> = again.trace.iter().map(|p| p.loss.to_bits()).collect();
        assert_eq!(a, b, "sampled runs must be deterministic");
    }

    /// An f64 C²DFB run converges on the widened quadratic instance and
    /// moves roughly double the payload bytes of the f32 run with the
    /// identical schedule (dtype is the only wire difference).
    #[test]
    fn f64_run_converges_and_doubles_payload() {
        let run_at = |f64_mode: bool| -> (f64, f64, u64) {
            let cfg = quad_cfg(80);
            let net = Network::new(Graph::build(Topology::Ring, 6));
            if f64_mode {
                let task: QuadraticTask<f64> = QuadraticTask::generate(6, 8, 1.0, 21);
                let mut ctx = RunContext::new(&task, net, cfg);
                let mut algo = C2dfb::<f64>::new(false);
                crate::algorithms::drive(&mut ctx, &mut algo, &mut crate::algorithms::NoObserver)
                    .unwrap();
                let t = &ctx.metrics.trace;
                (
                    t.first().unwrap().grad_norm,
                    t.last().unwrap().grad_norm,
                    ctx.metrics.ledger.total_bytes,
                )
            } else {
                let task: QuadraticTask = QuadraticTask::generate(6, 8, 1.0, 21);
                let mut ctx = RunContext::new(&task, net, cfg);
                let mut algo = C2dfb::new(false);
                crate::algorithms::drive(&mut ctx, &mut algo, &mut crate::algorithms::NoObserver)
                    .unwrap();
                let t = &ctx.metrics.trace;
                (
                    t.first().unwrap().grad_norm,
                    t.last().unwrap().grad_norm,
                    ctx.metrics.ledger.total_bytes,
                )
            }
        };
        let (g0_32, g1_32, bytes_32) = run_at(false);
        let (g0_64, g1_64, bytes_64) = run_at(true);
        assert!(g1_64 < g0_64 * 0.1, "f64 run stalled: {g0_64} -> {g1_64}");
        assert!(g1_32 < g0_32 * 0.1);
        // Same message schedule, double-width payloads; headers/index maps
        // keep the ratio just under 2.
        let ratio = bytes_64 as f64 / bytes_32 as f64;
        assert!(
            ratio > 1.6 && ratio <= 2.0,
            "byte ratio {ratio} (f64 {bytes_64} vs f32 {bytes_32})"
        );
    }
}
