//! C²DFB — the paper's Algorithm 1 (outer loop) over Algorithm 2 (inner).
//!
//! Per outer round t, on every node i:
//!
//! 1. **Outer mixing + step** (communicate x, dense):
//!    `x_i ← x_i + γ_out Σ_j w_ij (x_j − x_i) − η_out (s_i)_x`
//! 2. **Inner loops** (communicate compressed residuals only):
//!    `y_i ← IN(h(x_i, ·))` on h = f + λg, warm-started;
//!    `z_i ← IN(g(x_i, ·))`.
//! 3. **Hypergradient** (local, fully first-order):
//!    `u_i = ∇_x f_i(x,y) + λ(∇_x g_i(x,y) − ∇_x g_i(x,z))`
//! 4. **Tracker update** (communicate s_x, dense):
//!    `(s_i)_x ← (s_i)_x + γ_out Σ_j w_ij ((s_j)_x − (s_i)_x) + u_i^{t+1} − u_i^t`
//!
//! With `naive = true` the inner loops use the error-feedback
//! naive-compression protocol instead of reference points — the paper's
//! C²DFB(nc) ablation (same message sizes, worse error dynamics).

use super::RunContext;
use crate::compress;
use crate::optim::{run_inner, run_inner_naive, DenseTracker, InnerConfig, InnerState};
use anyhow::Result;

pub fn run(ctx: &mut RunContext, naive: bool) -> Result<()> {
    let m = ctx.task.nodes();
    let lambda = ctx.cfg.lambda as f32;
    let compressor = compress::parse(&ctx.cfg.compressor)
        .map_err(anyhow::Error::msg)?;
    let inner_cfg = InnerConfig {
        eta: ctx.cfg.eta_in / (1.0 + ctx.cfg.lambda), // h = f + λg is (λL)-smooth
        gamma: ctx.cfg.gamma_in,
        k_steps: ctx.cfg.inner_steps,
    };
    let inner_cfg_z = InnerConfig {
        eta: ctx.cfg.eta_in,
        gamma: ctx.cfg.gamma_in,
        k_steps: ctx.cfg.inner_steps,
    };

    // --- init: identical models on every node (paper setup) -------------
    let x0 = ctx.task.init_x(&mut ctx.rng);
    let y0 = ctx.task.init_y(&mut ctx.rng);
    let mut xs: Vec<Vec<f32>> = vec![x0; m];
    let mut ys: Vec<Vec<f32>> = vec![y0.clone(); m];
    let mut zs: Vec<Vec<f32>> = vec![y0; m];
    let mut y_state = InnerState::new(&ctx.net, ctx.task.dy());
    let mut z_state = InnerState::new(&ctx.net, ctx.task.dy());

    // s_x⁰ = u_i⁰ with the initial (y, z).
    let mut u: Vec<Vec<f32>> = (0..m)
        .map(|i| ctx.task.hypergrad(i, &xs[i], &ys[i], &zs[i], lambda))
        .collect::<Result<_>>()?;
    ctx.metrics.oracles.first_order += m as u64;
    let mut tracker = DenseTracker::new(u.clone());

    let grad_norm0 = crate::linalg::norm2(&crate::linalg::mean_rows(&u));
    ctx.record(0, &xs, &ys, grad_norm0)?;

    for t in 0..ctx.cfg.rounds {
        // -- 1. outer mixing + descent (pays one dense x exchange) -------
        let mixed = ctx.net.mix_paid(ctx.cfg.gamma_out, &xs);
        for i in 0..m {
            xs[i] = mixed[i].clone();
            for (xk, sk) in xs[i].iter_mut().zip(&tracker.s[i]) {
                *xk -= ctx.cfg.eta_out as f32 * sk;
            }
        }

        // -- 2. inner loops (compressed) ----------------------------------
        {
            let task = ctx.task;
            let metrics = &mut ctx.metrics;
            let xs_ref = &xs;
            let grad_y = |i: usize, yi: &[f32]| {
                metrics.oracles.first_order += 1;
                task.inner_y_grad(i, &xs_ref[i], yi, lambda)
                    .expect("inner_y oracle failed")
            };
            if naive {
                run_inner_naive(
                    &inner_cfg,
                    &mut ctx.net,
                    compressor.as_ref(),
                    &mut ctx.rng,
                    &mut y_state,
                    &mut ys,
                    grad_y,
                );
            } else {
                run_inner(
                    &inner_cfg,
                    &mut ctx.net,
                    compressor.as_ref(),
                    &mut ctx.rng,
                    &mut y_state,
                    &mut ys,
                    grad_y,
                );
            }
        }
        {
            let task = ctx.task;
            let metrics = &mut ctx.metrics;
            let xs_ref = &xs;
            let grad_z = |i: usize, zi: &[f32]| {
                metrics.oracles.first_order += 1;
                task.inner_z_grad(i, &xs_ref[i], zi)
                    .expect("inner_z oracle failed")
            };
            if naive {
                run_inner_naive(
                    &inner_cfg_z,
                    &mut ctx.net,
                    compressor.as_ref(),
                    &mut ctx.rng,
                    &mut z_state,
                    &mut zs,
                    grad_z,
                );
            } else {
                run_inner(
                    &inner_cfg_z,
                    &mut ctx.net,
                    compressor.as_ref(),
                    &mut ctx.rng,
                    &mut z_state,
                    &mut zs,
                    grad_z,
                );
            }
        }

        // -- 3. local hypergradients --------------------------------------
        let u_new: Vec<Vec<f32>> = (0..m)
            .map(|i| ctx.task.hypergrad(i, &xs[i], &ys[i], &zs[i], lambda))
            .collect::<Result<_>>()?;
        ctx.metrics.oracles.first_order += m as u64;

        // -- 4. gradient tracking on s_x (pays one dense s exchange) -----
        tracker.update(&mut ctx.net, ctx.cfg.gamma_out, &u_new);
        u = u_new;

        // -- eval ---------------------------------------------------------
        if (t + 1) % ctx.cfg.eval_every == 0 || t + 1 == ctx.cfg.rounds {
            let grad_norm = crate::linalg::norm2(&crate::linalg::mean_rows(&u));
            if ctx.record(t + 1, &xs, &ys, grad_norm)? {
                break; // target accuracy reached
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::Network;
    use crate::config::{Algorithm, ExperimentConfig};
    use crate::tasks::QuadraticTask;
    use crate::topology::{Graph, Topology};

    fn quad_cfg(rounds: usize) -> ExperimentConfig {
        ExperimentConfig {
            algorithm: Algorithm::C2dfb,
            nodes: 6,
            rounds,
            inner_steps: 20,
            eta_out: 0.3,
            eta_in: 0.4,
            gamma_out: 0.8,
            gamma_in: 0.6,
            lambda: 50.0,
            compressor: "topk:0.5".into(),
            eval_every: 10,
            ..ExperimentConfig::default()
        }
    }

    fn run_quad(rounds: usize, naive: bool) -> (f64, crate::metrics::RunMetrics) {
        let task = QuadraticTask::generate(6, 8, 1.0, 21);
        let net = Network::new(Graph::build(Topology::Ring, 6));
        let mut ctx = RunContext::new(&task, net, quad_cfg(rounds));
        run(&mut ctx, naive).unwrap();
        // Hyper-stationarity of the mean upper model.
        let xbar = {
            // re-derive final xs is not exposed; use grad_norm from trace.
            ctx.metrics.trace.last().unwrap().grad_norm
        };
        (xbar, ctx.metrics)
    }

    #[test]
    fn c2dfb_drives_hypergradient_down_on_quadratic() {
        let (g_end, metrics) = run_quad(150, false);
        let g_start = metrics.trace.first().unwrap().grad_norm;
        assert!(
            g_end < g_start * 0.05,
            "hypergrad norm {g_start} -> {g_end} (insufficient decrease)"
        );
        assert!(metrics.trace.last().unwrap().loss < metrics.trace[0].loss);
    }

    #[test]
    fn c2dfb_reaches_consensus() {
        let (_, metrics) = run_quad(150, false);
        let c_end = metrics.trace.last().unwrap().consensus_err;
        assert!(c_end < 1e-3, "consensus err {c_end}");
    }

    #[test]
    fn naive_variant_also_runs_but_tracks_more_error() {
        let (g_ref, m_ref) = run_quad(80, false);
        let (g_nc, m_nc) = run_quad(80, true);
        assert!(g_ref.is_finite() && g_nc.is_finite());
        // Identical message schedule ⇒ identical byte counts.
        assert_eq!(m_ref.ledger.total_bytes, m_nc.ledger.total_bytes);
    }

    #[test]
    fn oracle_counts_are_first_order_only() {
        let (_, metrics) = run_quad(10, false);
        assert!(metrics.oracles.first_order > 0);
        assert_eq!(metrics.oracles.second_order, 0);
    }

    #[test]
    fn target_accuracy_stops_early() {
        let task = QuadraticTask::generate(6, 8, 0.5, 22);
        let net = Network::new(Graph::build(Topology::Ring, 6));
        let mut cfg = quad_cfg(500);
        cfg.target_accuracy = Some(0.0); // any accuracy qualifies
        cfg.eval_every = 1;
        let mut ctx = RunContext::new(&task, net, cfg);
        run(&mut ctx, false).unwrap();
        assert!(ctx.metrics.trace.len() <= 3);
    }
}
