//! MDBO — gossip-based decentralized bilevel optimization with a
//! Neumann-series Hessian-inverse approximation (Yang, Zhang & Wang 2022),
//! re-implemented at the oracle/message level:
//!
//! per outer round:
//! 1. K steps of decentralized GD with gossip on the lower level (dense y
//!    exchange + one ∇_y g per step);
//! 2. Neumann series for v ≈ (∇²_yy ḡ)⁻¹ ∇_y f̄:
//!        p⁰ = ∇_y f_i,  p^{q+1} = p^q − η (∇²_yy g_i) p^q,
//!        v = η Σ_{q<Q} p^q,
//!    gossip-averaging p every term (dense exchange + one HVP per term —
//!    this is where MDBO's communication volume explodes);
//! 3. hypergradient h_i = ∇_x f_i − (∇²_xy g_i)·v (one JVP);
//! 4. upper gossip step x_i ← mix(x)_i − η_out h_i (dense x exchange).
//!
//! Generic over the payload [`Scalar`] `S` like every algorithm here;
//! `f32` (the default) is byte-identical to the historical path.

use super::{BilevelAlgorithm, RunContext, StepOutcome};
use crate::collective::{MixScratch, Transport};
use crate::linalg::{kernels, Scalar};
use crate::obs::{LedgerSnap, Phase};
use anyhow::Result;

/// Neumann-series length (Q).  The published algorithm takes Q ≈ κ log(·);
/// 15 matches the paper's experimental scale.
const NEUMANN_TERMS: usize = 15;

/// MDBO (gossip bilevel + Neumann-series hypergradient) as a step-driven
/// [`BilevelAlgorithm`].
pub struct Mdbo<S: Scalar = f32> {
    st: Option<St<S>>,
}

/// Iterate state built by `init` and advanced by `step`.
struct St<S: Scalar> {
    eta_in: S,
    eta_out: S,
    gamma: f64,
    xs: Vec<Vec<S>>,
    ys: Vec<Vec<S>>,
    /// Reused buffers for every in-place dense mix (y/p/x exchanges).
    mix: MixScratch<S>,
}

impl<S: Scalar> Mdbo<S> {
    pub fn new() -> Mdbo<S> {
        Mdbo::default()
    }
}

impl<S: Scalar> Default for Mdbo<S> {
    fn default() -> Self {
        Mdbo { st: None }
    }
}

impl<T: Transport, S: Scalar> BilevelAlgorithm<T, S> for Mdbo<S> {
    fn name(&self) -> &'static str {
        "mdbo"
    }

    fn init(&mut self, ctx: &mut RunContext<'_, T, S>) -> Result<StepOutcome> {
        let m = ctx.task.nodes();
        let x0 = ctx.task.init_x(&mut ctx.rng);
        let y0 = ctx.task.init_y(&mut ctx.rng);
        self.st = Some(St {
            eta_in: S::from_f64(ctx.cfg.eta_in),
            eta_out: S::from_f64(ctx.cfg.eta_out),
            gamma: ctx.cfg.gamma_out,
            xs: vec![x0; m],
            ys: vec![y0; m],
            mix: MixScratch::new(),
        });
        // No hypergradient estimate before the first round.
        Ok(StepOutcome { grad_norm: f64::NAN })
    }

    fn step(&mut self, ctx: &mut RunContext<'_, T, S>, _round: usize) -> Result<StepOutcome> {
        let st = self.st.as_mut().expect("init() must run before step()");
        let m = ctx.task.nodes();
        let (eta_in, eta_out, gamma) = (st.eta_in, st.eta_out, st.gamma);

        // -- 1. lower-level gossip GD (in-place dense mixes) ---------------
        let snap = LedgerSnap::of(ctx.net.ledger());
        let t = ctx.obs.clock();
        for _k in 0..ctx.cfg.inner_steps {
            ctx.net.mix_paid_into(gamma, st.ys.as_mut_slice(), &mut st.mix);
            let g: Vec<Vec<S>> =
                ctx.par_nodes(|task, i| task.inner_z_grad(i, &st.xs[i], &st.ys[i]))?;
            ctx.metrics.oracles.first_order += m as u64;
            for (yi, gi) in st.ys.iter_mut().zip(&g) {
                kernels::descent(eta_in, gi, yi);
            }
        }
        let lower_oracles = (ctx.cfg.inner_steps * m) as u64;
        ctx.obs
            .phase_comm(Phase::Lower, lower_oracles, snap, ctx.net.ledger(), t);

        // -- 2. Neumann series with per-term gossip ------------------------
        let snap = LedgerSnap::of(ctx.net.ledger());
        let t = ctx.obs.clock();
        let mut ps: Vec<Vec<S>> =
            ctx.par_nodes(|task, i| task.grad_y_f(i, &st.xs[i], &st.ys[i]))?;
        ctx.metrics.oracles.first_order += m as u64;
        let mut vs: Vec<Vec<S>> = ps
            .iter()
            .map(|p| {
                let mut v = p.clone();
                kernels::scale(eta_in, &mut v);
                v
            })
            .collect();
        for _q in 0..NEUMANN_TERMS {
            ctx.net.mix_paid_into(gamma, ps.as_mut_slice(), &mut st.mix);
            let hp: Vec<Vec<S>> =
                ctx.par_nodes(|task, i| task.hvp_yy_g(i, &st.xs[i], &st.ys[i], &ps[i]))?;
            ctx.metrics.oracles.second_order += m as u64;
            for i in 0..m {
                kernels::descent(eta_in, &hp[i], &mut ps[i]);
                kernels::axpy(eta_in, &ps[i], &mut vs[i]);
            }
        }
        let neumann_oracles = (m + NEUMANN_TERMS * m) as u64;
        ctx.obs
            .phase_comm(Phase::Neumann, neumann_oracles, snap, ctx.net.ledger(), t);

        // -- 3. hypergradient ----------------------------------------------
        let t = ctx.obs.clock();
        let hs: Vec<Vec<S>> = ctx.par_nodes(|task, i| {
            let gxf = task.grad_x_f(i, &st.xs[i], &st.ys[i])?;
            let jv = task.jvp_xy_g(i, &st.xs[i], &st.ys[i], &vs[i])?;
            let mut h = vec![S::ZERO; gxf.len()];
            kernels::sub(&gxf, &jv, &mut h);
            Ok(h)
        })?;
        ctx.metrics.oracles.first_order += m as u64;
        ctx.metrics.oracles.second_order += m as u64;
        ctx.obs.phase(Phase::Hypergrad, 2 * m as u64, t);

        // -- 4. upper gossip step ------------------------------------------
        let snap = LedgerSnap::of(ctx.net.ledger());
        let t = ctx.obs.clock();
        ctx.net.mix_paid_into(gamma, st.xs.as_mut_slice(), &mut st.mix);
        for (xi, hi) in st.xs.iter_mut().zip(&hs) {
            kernels::descent(eta_out, hi, xi);
        }
        ctx.obs.phase_comm(Phase::Mix, 0, snap, ctx.net.ledger(), t);

        let grad_norm = crate::linalg::norm2(&crate::linalg::mean_rows(&hs));
        Ok(StepOutcome { grad_norm })
    }

    fn xs(&self) -> &[Vec<S>] {
        &self.st.as_ref().expect("init() must run first").xs
    }

    fn ys(&self) -> &[Vec<S>] {
        &self.st.as_ref().expect("init() must run first").ys
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::Network;
    use crate::config::{Algorithm, ExperimentConfig};
    use crate::tasks::{BilevelTask, QuadraticTask};
    use crate::topology::{Graph, Topology};

    fn cfg(rounds: usize) -> ExperimentConfig {
        ExperimentConfig {
            algorithm: Algorithm::Mdbo,
            nodes: 6,
            rounds,
            inner_steps: 10,
            eta_out: 0.4,
            eta_in: 0.3,
            gamma_out: 0.8,
            eval_every: 10,
            ..ExperimentConfig::default()
        }
    }

    #[test]
    fn mdbo_converges_on_quadratic() {
        let task: QuadraticTask = QuadraticTask::generate(6, 8, 0.8, 41);
        // ψ* > 0 for this task: measure excess loss over the analytic
        // minimum, found by GD on the closed-form hypergradient.
        let mut xstar = task.init_x(&mut crate::util::rng::Rng::new(5));
        for _ in 0..5000 {
            let g = task.hypergrad_analytic(&xstar);
            for k in 0..xstar.len() {
                xstar[k] -= 0.2 * g[k];
            }
        }
        let psi_min = task.psi(&xstar);

        let net = Network::new(Graph::build(Topology::Ring, 6));
        let mut ctx = super::super::RunContext::new(&task, net, cfg(300));
        let mut algo = Mdbo::new();
        super::super::drive(&mut ctx, &mut algo, &mut super::super::NoObserver).unwrap();
        let first = ctx.metrics.trace.first().unwrap().loss;
        let last = ctx.metrics.trace.last().unwrap().loss;
        assert!(last.is_finite(), "diverged");
        let (e0, e1) = (first - psi_min, last - psi_min);
        assert!(
            e1 < e0 * 0.5,
            "excess loss {e0:.4} -> {e1:.4} (psi_min {psi_min:.4})"
        );
    }

    #[test]
    fn mdbo_communicates_more_than_c2dfb_for_same_rounds() {
        // The structural claim behind Table 1: per outer round MDBO pays
        // (K + Q + 1) dense exchanges vs C²DFB's 2 dense + 4K compressed.
        let task: QuadraticTask = QuadraticTask::generate(6, 64, 0.8, 42);

        let net = Network::new(Graph::build(Topology::Ring, 6));
        let mut ctx = super::super::RunContext::new(&task, net, cfg(10));
        let mut algo = Mdbo::new();
        super::super::drive(&mut ctx, &mut algo, &mut super::super::NoObserver).unwrap();
        let mdbo_bytes = ctx.metrics.ledger.total_bytes;

        let net = Network::new(Graph::build(Topology::Ring, 6));
        let mut c_cfg = cfg(10);
        c_cfg.algorithm = Algorithm::C2dfb;
        c_cfg.compressor = "topk:0.2".into();
        c_cfg.lambda = 50.0;
        let mut ctx2 = super::super::RunContext::new(&task, net, c_cfg);
        let mut c2dfb = super::super::C2dfb::new(false);
        super::super::drive(&mut ctx2, &mut c2dfb, &mut super::super::NoObserver).unwrap();
        let c2dfb_bytes = ctx2.metrics.ledger.total_bytes;

        // At EQUAL round counts the structural gap is modest (both move
        // O(K·d) per round); the order-of-magnitude gap in Table 1 comes
        // from rounds-to-target, measured by the table1 harness.
        assert!(
            mdbo_bytes > c2dfb_bytes,
            "mdbo {mdbo_bytes} vs c2dfb {c2dfb_bytes}"
        );
        assert!(ctx.metrics.oracles.second_order > 0);
        assert_eq!(ctx2.metrics.oracles.second_order, 0);
    }
}
