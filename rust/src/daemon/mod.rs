//! `c2dfb serve` — the long-running sweep daemon.
//!
//! The batch entry points (run, sweep, the paper harnesses) are one
//! process / one grid / exit.  This module turns the same sweep substrate
//! into a multi-client service (the ROADMAP's "serve heavy traffic"
//! step): a std-only server (`std::net::TcpListener` + threads, no new
//! crates) that owns one execution pool and multiplexes many submitted
//! grids through [`coordinator::sweep::run_cells_observed`].
//!
//! Architecture (see docs/SERVE.md for the protocol reference):
//!
//! * **Job queue** — submissions land in a bounded priority queue
//!   ([`ServeOpts::queue_cap`]); a full queue refuses new work (HTTP 429
//!   / TCP `ERR queue-full`) instead of growing without bound.  One
//!   executor thread drains it (highest priority first, FIFO within a
//!   priority); each job then fans its cells out over the work-stealing
//!   [`NodePool`](crate::sim::NodePool) inside `run_cells_observed`, so
//!   cell-level parallelism is the daemon-wide [`ServeOpts::jobs`] knob.
//! * **Result cache** — completed cells are cached under the
//!   deterministic key of [`cache::cache_key`]; resubmitted or
//!   overlapping grids are served byte-identically without re-running
//!   (docs/SWEEP.md seed contract).
//! * **Progress streaming** — every job carries an [`EventLog`] of
//!   JSON event lines fed by [`CellHooks`]; HTTP clients stream it as
//!   SSE (`GET /jobs/:id/events`), TCP clients poll it with a cursor.
//! * **Error isolation** — a failing cell is confined to its row in the
//!   job's report (PR 5's per-cell error model); a panicking job is
//!   confined to that job, which ends `failed`.
//! * **Graceful shutdown** — SIGINT/SIGTERM flip the daemon into drain
//!   mode: listeners stop accepting, the queue drains, artifacts flush.
//!   A second signal (or `mode=now`) cancels the running job at its next
//!   evaluation point and checkpoints still-queued job bodies to disk.
//!
//! Everything here is std-only and deterministic where it matters: the
//! report bytes a job produces are identical to what a batch `c2dfb
//! sweep` of the same body would write.

mod cache;
mod client;
mod http;
mod prom;
mod tcp;

pub use cache::{cache_key, CacheEntry, CellCache};
pub use client::Client;
pub use prom::{render_process, validate_exposition, ProcSnapshot};

use crate::config::toml::{self, TomlValue};
use crate::coordinator::sweep::{self, CellHooks, CellOutcome, ExecOpts, SweepSpec};
use crate::data::partition::Partition;
use crate::metrics::{RunMetrics, TracePoint};
use crate::obs::Console;
use crate::topology::Topology;
use crate::util::json::Json;
use anyhow::Result;
use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// Recover a lock even if a holder panicked — the daemon's per-job panic
/// isolation must not poison shared state.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

// ---------------------------------------------------------------------------
// Options

/// Daemon configuration (CLI: `c2dfb serve`).
#[derive(Clone)]
pub struct ServeOpts {
    /// HTTP listen address, or `None` to disable the HTTP surface.
    pub http: Option<String>,
    /// Line-protocol TCP listen address (the `c2dfb client` transport),
    /// or `None` to disable it.
    pub tcp: Option<String>,
    /// Cell-level parallelism per job (0 = all cores).
    pub jobs: usize,
    /// Maximum queued (not yet running) jobs before submissions are
    /// refused with explicit backpressure.
    pub queue_cap: usize,
    /// Maximum completed cells kept in the result cache (0 disables).
    pub cache_cap: usize,
    /// Per-job progress-event cap; past it events are counted + dropped.
    pub event_cap: usize,
    /// Artifact directory: finished jobs flush `job-<id>/report.{csv,json}`
    /// (+ `trace.jsonl`) here, and a hard shutdown checkpoints still-queued
    /// job bodies under `checkpoint/`.  `None` keeps artifacts in memory
    /// only.
    pub out_dir: Option<String>,
    pub console: Console,
    /// Start with the executor paused (tests: lets a queue fill up
    /// deterministically).  Unpause with [`Daemon::pause`].
    pub start_paused: bool,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            http: None,
            tcp: None,
            jobs: 0,
            queue_cap: 64,
            cache_cap: 4096,
            event_cap: 10_000,
            out_dir: None,
            console: Console::quiet(),
            start_paused: false,
        }
    }
}

// ---------------------------------------------------------------------------
// Job state

/// Job lifecycle: `queued → running → done | failed | cancelled`
/// (queued jobs may also jump straight to `cancelled`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobState {
    pub fn name(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    pub fn terminal(self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }
}

/// Mutable per-job progress + artifacts, behind the job's mutex.
pub struct JobProgress {
    pub state: JobState,
    pub cells_total: usize,
    pub cells_done: usize,
    pub cells_cached: usize,
    pub cells_failed: usize,
    pub error: Option<String>,
    pub report_csv: Option<String>,
    pub report_json: Option<String>,
    pub trace_jsonl: Option<String>,
}

/// One submitted sweep.
pub struct Job {
    pub id: u64,
    /// Submission order — the FIFO tiebreak within a priority class.
    pub seq: u64,
    /// Higher runs earlier.
    pub priority: i64,
    pub name: String,
    /// Whether the job records per-cell JSONL traces.
    pub trace: bool,
    /// The original submitted body (TOML or JSON) — checkpointed verbatim
    /// on hard shutdown so queued work survives a restart.
    pub body: String,
    pub spec: SweepSpec,
    /// Cooperative cancel flag: checked before each pending cell and at
    /// every evaluation point of running cells.
    pub cancel: AtomicBool,
    pub events: EventLog,
    st: Mutex<JobProgress>,
}

impl Job {
    pub fn state(&self) -> JobState {
        lock(&self.st).state
    }

    /// Read the progress snapshot under the job lock.
    pub fn with_progress<R>(&self, f: impl FnOnce(&JobProgress) -> R) -> R {
        f(&lock(&self.st))
    }

    /// The status document served by `GET /jobs/:id` and `STATUS`.
    pub fn status_json(&self) -> Json {
        let st = lock(&self.st);
        let mut pairs = vec![
            ("id", Json::num(self.id as f64)),
            ("name", Json::str(&self.name)),
            ("state", Json::str(st.state.name())),
            ("priority", Json::num(self.priority as f64)),
            ("trace", Json::Bool(self.trace)),
            ("cells", Json::num(st.cells_total as f64)),
            ("cells_done", Json::num(st.cells_done as f64)),
            ("cells_cached", Json::num(st.cells_cached as f64)),
            ("cells_failed", Json::num(st.cells_failed as f64)),
        ];
        if let Some(e) = &st.error {
            pairs.push(("error", Json::str(e)));
        }
        Json::obj(pairs)
    }
}

// ---------------------------------------------------------------------------
// Event log

/// Bounded, closable, waitable log of JSON event lines — one per job.
/// Readers keep a cursor (line index) and either poll (`snapshot_from`)
/// or block (`wait_from`, the SSE path).  Past the cap a single
/// `events_truncated` marker is appended and further events are counted
/// but dropped, so a runaway job cannot exhaust daemon memory.
pub struct EventLog {
    cap: usize,
    inner: Mutex<EventBuf>,
    cv: Condvar,
}

struct EventBuf {
    lines: Vec<String>,
    closed: bool,
    dropped: u64,
}

impl EventLog {
    fn new(cap: usize) -> EventLog {
        EventLog {
            cap: cap.max(2),
            inner: Mutex::new(EventBuf { lines: Vec::new(), closed: false, dropped: 0 }),
            cv: Condvar::new(),
        }
    }

    fn push(&self, line: String) {
        let mut g = lock(&self.inner);
        if g.closed {
            return;
        }
        if g.lines.len() >= self.cap {
            if g.dropped == 0 {
                g.lines.push(Json::obj(vec![("ev", Json::str("events_truncated"))]).to_string());
            }
            g.dropped += 1;
        } else {
            g.lines.push(line);
        }
        self.cv.notify_all();
    }

    fn close(&self) {
        lock(&self.inner).closed = true;
        self.cv.notify_all();
    }

    pub fn dropped(&self) -> u64 {
        lock(&self.inner).dropped
    }

    /// Non-blocking read from `cursor`: `(new lines, next cursor, closed)`.
    pub fn snapshot_from(&self, cursor: usize) -> (Vec<String>, usize, bool) {
        let g = lock(&self.inner);
        let start = cursor.min(g.lines.len());
        (g.lines[start..].to_vec(), g.lines.len(), g.closed)
    }

    /// Like [`snapshot_from`](Self::snapshot_from) but blocks up to
    /// `timeout` when nothing new is available yet.
    pub fn wait_from(&self, cursor: usize, timeout: Duration) -> (Vec<String>, usize, bool) {
        let mut g = lock(&self.inner);
        if g.lines.len() <= cursor && !g.closed {
            g = self
                .cv
                .wait_timeout(g, timeout)
                .unwrap_or_else(std::sync::PoisonError::into_inner)
                .0;
        }
        let start = cursor.min(g.lines.len());
        (g.lines[start..].to_vec(), g.lines.len(), g.closed)
    }
}

// ---------------------------------------------------------------------------
// Process counters

/// Monotonic process-level counters surfaced at `GET /metrics`.
#[derive(Default)]
pub struct ProcCounters {
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub cancelled: AtomicU64,
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
    pub cells_run: AtomicU64,
}

fn bump(c: &AtomicU64) {
    c.fetch_add(1, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Daemon

/// Why a submission was refused.
#[derive(Debug)]
pub enum SubmitError {
    /// Queue at capacity — explicit backpressure, try again later.
    QueueFull,
    /// Daemon is draining; no new work is accepted.
    ShuttingDown,
    /// The job body did not parse/validate.
    Bad(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "queue full"),
            SubmitError::ShuttingDown => write!(f, "daemon is shutting down"),
            SubmitError::Bad(e) => write!(f, "bad job body: {e}"),
        }
    }
}

const PHASE_RUN: u8 = 0;
const PHASE_DRAIN: u8 = 1;
const PHASE_STOPPED: u8 = 2;

/// Shared daemon state: job table, queue signalling, cell cache and the
/// aggregate metrics ledger.  All surfaces (HTTP, TCP, in-process tests)
/// operate on an `Arc<Daemon>`.
pub struct Daemon {
    pub opts: ServeOpts,
    jobs: Mutex<BTreeMap<u64, Arc<Job>>>,
    queue_cv: Condvar,
    next_id: AtomicU64,
    cache: Mutex<CellCache>,
    pub counters: ProcCounters,
    /// Cross-job aggregate of executed (non-cached) cells; its single
    /// `render_prometheus` block is concatenated into `GET /metrics`.
    agg: Mutex<RunMetrics>,
    phase: AtomicU8,
    paused: AtomicBool,
}

impl Daemon {
    pub fn new(opts: ServeOpts) -> Arc<Daemon> {
        let cache_cap = opts.cache_cap;
        let paused = opts.start_paused;
        Arc::new(Daemon {
            opts,
            jobs: Mutex::new(BTreeMap::new()),
            queue_cv: Condvar::new(),
            next_id: AtomicU64::new(1),
            cache: Mutex::new(CellCache::new(cache_cap)),
            counters: ProcCounters::default(),
            agg: Mutex::new(RunMetrics::new("all", "daemon")),
            phase: AtomicU8::new(PHASE_RUN),
            paused: AtomicBool::new(paused),
        })
    }

    fn phase(&self) -> u8 {
        self.phase.load(Ordering::SeqCst)
    }

    /// `false` once shutdown has begun (submissions are refused).
    pub fn accepting(&self) -> bool {
        self.phase() == PHASE_RUN
    }

    /// `true` once the executor has exited and listeners are stopping.
    pub fn stopped(&self) -> bool {
        self.phase() == PHASE_STOPPED
    }

    /// Pause/resume the executor (jobs keep queueing while paused).
    pub fn pause(&self, paused: bool) {
        self.paused.store(paused, Ordering::SeqCst);
        self.queue_cv.notify_all();
    }

    pub fn job(&self, id: u64) -> Option<Arc<Job>> {
        lock(&self.jobs).get(&id).cloned()
    }

    pub fn jobs_snapshot(&self) -> Vec<Arc<Job>> {
        lock(&self.jobs).values().cloned().collect()
    }

    pub fn queue_depth(&self) -> usize {
        lock(&self.jobs)
            .values()
            .filter(|j| j.state() == JobState::Queued)
            .count()
    }

    /// Parse, validate and enqueue a job body.  Backpressure and
    /// drain-mode refusal happen here — before any task data is built.
    pub fn submit(&self, body: &str, priority: i64, trace: bool) -> Result<Arc<Job>, SubmitError> {
        if !self.accepting() {
            bump(&self.counters.rejected);
            return Err(SubmitError::ShuttingDown);
        }
        let spec = parse_spec(body).map_err(|e| {
            bump(&self.counters.rejected);
            SubmitError::Bad(e)
        })?;
        let mut jobs = lock(&self.jobs);
        let queued = jobs
            .values()
            .filter(|j| j.state() == JobState::Queued)
            .count();
        if queued >= self.opts.queue_cap {
            bump(&self.counters.rejected);
            return Err(SubmitError::QueueFull);
        }
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let job = Arc::new(Job {
            id,
            seq: id,
            priority,
            name: spec.base.name.clone(),
            trace,
            body: body.to_string(),
            spec,
            cancel: AtomicBool::new(false),
            events: EventLog::new(self.opts.event_cap),
            st: Mutex::new(JobProgress {
                state: JobState::Queued,
                cells_total: 0,
                cells_done: 0,
                cells_cached: 0,
                cells_failed: 0,
                error: None,
                report_csv: None,
                report_json: None,
                trace_jsonl: None,
            }),
        });
        job.events.push(
            Json::obj(vec![
                ("ev", Json::str("queued")),
                ("job", Json::num(id as f64)),
                ("priority", Json::num(priority as f64)),
            ])
            .to_string(),
        );
        jobs.insert(id, job.clone());
        bump(&self.counters.submitted);
        self.queue_cv.notify_all();
        Ok(job)
    }

    /// Request cancellation.  A queued job flips to `cancelled`
    /// immediately; a running job aborts at its next evaluation point
    /// (`eval_every` cadence — never mid-step).  Terminal jobs are
    /// untouched.  Returns the job, or `None` if the id is unknown.
    pub fn cancel(&self, id: u64) -> Option<Arc<Job>> {
        let job = self.job(id)?;
        job.cancel.store(true, Ordering::SeqCst);
        let became_cancelled = {
            let mut st = lock(&job.st);
            if st.state == JobState::Queued {
                st.state = JobState::Cancelled;
                st.error = Some("cancelled before start".into());
                true
            } else {
                false
            }
        };
        if became_cancelled {
            bump(&self.counters.cancelled);
            job.events.push(
                Json::obj(vec![
                    ("ev", Json::str("job_done")),
                    ("job", Json::num(job.id as f64)),
                    ("state", Json::str("cancelled")),
                ])
                .to_string(),
            );
            job.events.close();
        }
        self.queue_cv.notify_all();
        Some(job)
    }

    /// Begin shutdown.  Drain mode stops accepting and lets the queue
    /// finish; `now` additionally cancels queued + running jobs and
    /// checkpoints the queued bodies under `out_dir/checkpoint/`.
    pub fn begin_shutdown(&self, now: bool) {
        let _ = self.phase.compare_exchange(
            PHASE_RUN,
            PHASE_DRAIN,
            Ordering::SeqCst,
            Ordering::SeqCst,
        );
        if now {
            let queued_ids: Vec<u64> = {
                let jobs = lock(&self.jobs);
                for j in jobs.values() {
                    j.cancel.store(true, Ordering::SeqCst);
                }
                jobs.values()
                    .filter(|j| j.state() == JobState::Queued)
                    .map(|j| j.id)
                    .collect()
            };
            for id in queued_ids {
                if let Some(job) = self.job(id) {
                    self.checkpoint_job(&job);
                    let mut st = lock(&job.st);
                    if st.state == JobState::Queued {
                        st.state = JobState::Cancelled;
                        st.error = Some("daemon shutdown".into());
                        drop(st);
                        bump(&self.counters.cancelled);
                        job.events.close();
                    }
                }
            }
        }
        self.queue_cv.notify_all();
    }

    /// Persist a queued job's original body so a restart can resubmit it.
    fn checkpoint_job(&self, job: &Job) {
        let Some(dir) = &self.opts.out_dir else { return };
        let dir = Path::new(dir).join("checkpoint");
        if let Err(e) = std::fs::create_dir_all(&dir) {
            self.opts
                .console
                .warn(format_args!("checkpoint dir {}: {e}", dir.display()));
            return;
        }
        let path = dir.join(format!("job-{}.body", job.id));
        if let Err(e) = std::fs::write(&path, &job.body) {
            self.opts
                .console
                .warn(format_args!("checkpointing {}: {e}", path.display()));
        }
    }

    /// The `GET /metrics` document: process families + exactly one
    /// aggregate [`RunMetrics::render_prometheus`] block.
    pub fn render_metrics(&self) -> String {
        let mut by_state = [0u64; 5];
        let mut events_dropped = 0u64;
        for j in self.jobs_snapshot() {
            let i = match j.state() {
                JobState::Queued => 0,
                JobState::Running => 1,
                JobState::Done => 2,
                JobState::Failed => 3,
                JobState::Cancelled => 4,
            };
            by_state[i] += 1;
            events_dropped += j.events.dropped();
        }
        let c = &self.counters;
        let snap = ProcSnapshot {
            queue_depth: by_state[0],
            jobs_by_state: by_state,
            submitted: c.submitted.load(Ordering::Relaxed),
            rejected: c.rejected.load(Ordering::Relaxed),
            completed: c.completed.load(Ordering::Relaxed),
            failed: c.failed.load(Ordering::Relaxed),
            cancelled: c.cancelled.load(Ordering::Relaxed),
            cache_hits: c.cache_hits.load(Ordering::Relaxed),
            cache_misses: c.cache_misses.load(Ordering::Relaxed),
            cache_entries: lock(&self.cache).len() as u64,
            cells_run: c.cells_run.load(Ordering::Relaxed),
            events_dropped,
        };
        format!("{}{}", render_process(&snap), lock(&self.agg).render_prometheus())
    }

    // -- executor ---------------------------------------------------------

    /// The single job-executor loop: pick the best queued job, run it,
    /// repeat; exit once shutdown has begun and the queue is empty.
    fn executor_loop(&self) {
        loop {
            let next = {
                let mut g = lock(&self.jobs);
                loop {
                    if self.paused.load(Ordering::SeqCst) && self.phase() == PHASE_RUN {
                        g = self
                            .queue_cv
                            .wait_timeout(g, Duration::from_millis(100))
                            .unwrap_or_else(std::sync::PoisonError::into_inner)
                            .0;
                        continue;
                    }
                    let pick = g
                        .values()
                        .filter(|j| j.state() == JobState::Queued)
                        .max_by_key(|j| (j.priority, std::cmp::Reverse(j.seq)))
                        .cloned();
                    match pick {
                        Some(j) => {
                            lock(&j.st).state = JobState::Running;
                            break Some(j);
                        }
                        None => {
                            if self.phase() >= PHASE_DRAIN {
                                break None;
                            }
                            g = self
                                .queue_cv
                                .wait_timeout(g, Duration::from_millis(200))
                                .unwrap_or_else(std::sync::PoisonError::into_inner)
                                .0;
                        }
                    }
                }
            };
            let Some(job) = next else { break };
            // Per-job panic isolation: a job that panics ends `failed`
            // without taking the daemon down.
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                self.run_job(&job);
            }));
            if run.is_err() {
                let mut st = lock(&job.st);
                if !st.state.terminal() {
                    st.state = JobState::Failed;
                    st.error = Some("job panicked while executing".into());
                    drop(st);
                    bump(&self.counters.failed);
                }
                job.events.close();
            }
        }
        self.phase.store(PHASE_STOPPED, Ordering::SeqCst);
    }

    fn fail_job(&self, job: &Job, err: String) {
        {
            let mut st = lock(&job.st);
            st.state = JobState::Failed;
            st.error = Some(err.clone());
        }
        bump(&self.counters.failed);
        job.events.push(
            Json::obj(vec![
                ("ev", Json::str("job_done")),
                ("job", Json::num(job.id as f64)),
                ("state", Json::str("failed")),
                ("error", Json::str(&err)),
            ])
            .to_string(),
        );
        job.events.close();
    }

    /// Execute one job: expand the grid, partition cells into cache hits
    /// and misses, run the misses through the pool with progress hooks,
    /// merge in declaration order, cache fresh successes, and render the
    /// aggregate reports.
    fn run_job(&self, job: &Arc<Job>) {
        let grid = match sweep::expand(&job.spec) {
            Ok(g) => g,
            Err(e) => return self.fail_job(job, format!("{e:#}")),
        };
        // Partition against the cache.
        let mut merged: Vec<Option<CellOutcome>> = grid.cells.iter().map(|_| None).collect();
        let mut miss: Vec<usize> = Vec::new();
        {
            let cache = lock(&self.cache);
            for (i, cell) in grid.cells.iter().enumerate() {
                match cache.get(&cache_key(&job.spec, job.trace, cell)) {
                    Some(e) => {
                        merged[i] = Some(CellOutcome {
                            id: cell.id.clone(),
                            result: Ok(e.metrics.clone()),
                            trace: e.trace.clone(),
                            profile: None,
                        });
                        bump(&self.counters.cache_hits);
                    }
                    None => {
                        miss.push(i);
                        bump(&self.counters.cache_misses);
                    }
                }
            }
        }
        let cached = grid.cells.len() - miss.len();
        {
            let mut st = lock(&job.st);
            st.cells_total = grid.cells.len();
            st.cells_cached = cached;
            st.cells_done = cached;
        }
        job.events.push(
            Json::obj(vec![
                ("ev", Json::str("job_start")),
                ("job", Json::num(job.id as f64)),
                ("cells", Json::num(grid.cells.len() as f64)),
                ("cached", Json::num(cached as f64)),
            ])
            .to_string(),
        );

        // Run the misses (skipped entirely on a full cache hit — zero new
        // oracle calls, the acceptance criterion).
        if !miss.is_empty() {
            let miss_cells: Vec<sweep::Cell> =
                miss.iter().map(|&i| grid.cells[i].clone()).collect();
            let tasks = grid.slots();
            let hooks = JobHooks { daemon: self, job };
            let eopts = ExecOpts {
                jobs: self.opts.jobs,
                console: Console::quiet(),
                trace: job.trace,
                profile: false,
            };
            let fresh = sweep::run_cells_observed(&miss_cells, &tasks, None, &eopts, Some(&hooks));
            for (k, outcome) in fresh.into_iter().enumerate() {
                merged[miss[k]] = Some(outcome);
            }
        }
        let outcomes: Vec<CellOutcome> = merged
            .into_iter()
            .map(|o| o.expect("every cell is either cached or ran"))
            .collect();

        let cancelled = job.cancel.load(Ordering::SeqCst);
        // Cache fresh successes — but never from a cancelled job, whose
        // aborted cells stopped at a client-timing-dependent point.
        if !cancelled {
            let mut cache = lock(&self.cache);
            for &i in &miss {
                if let Ok(m) = &outcomes[i].result {
                    cache.insert(
                        cache_key(&job.spec, job.trace, &grid.cells[i]),
                        CacheEntry { metrics: m.clone(), trace: outcomes[i].trace.clone() },
                    );
                }
            }
        }
        // Fold executed cells into the daemon-wide aggregate ledger
        // (cache hits deliberately excluded: they cost nothing).
        {
            let mut agg = lock(&self.agg);
            for &i in &miss {
                if let Ok(m) = &outcomes[i].result {
                    agg.ledger.total_bytes += m.ledger.total_bytes;
                    agg.ledger.messages += m.ledger.messages;
                    agg.ledger.dropped_messages += m.ledger.dropped_messages;
                    agg.ledger.gossip_rounds += m.ledger.gossip_rounds;
                    agg.ledger.network_time_s += m.ledger.network_time_s;
                    agg.oracles.first_order += m.oracles.first_order;
                    agg.oracles.second_order += m.oracles.second_order;
                    agg.oracles.evals += m.oracles.evals;
                }
            }
        }

        if cancelled {
            {
                let mut st = lock(&job.st);
                st.state = JobState::Cancelled;
                st.error = Some("cancelled while running".into());
            }
            bump(&self.counters.cancelled);
            job.events.push(
                Json::obj(vec![
                    ("ev", Json::str("job_done")),
                    ("job", Json::num(job.id as f64)),
                    ("state", Json::str("cancelled")),
                ])
                .to_string(),
            );
            job.events.close();
            return;
        }

        // Aggregate reports over the FULL grid (cached + fresh), exactly
        // the bytes a batch sweep of this body would write.
        let csv = sweep::report_csv(&grid.cells, &outcomes);
        let json = sweep::report_json(&grid.cells, &outcomes).to_string() + "\n";
        let trace = job.trace.then(|| sweep::concat_traces(&outcomes));
        let failed = outcomes.iter().filter(|o| o.result.is_err()).count();
        if let Some(dir) = &self.opts.out_dir {
            let d = Path::new(dir).join(format!("job-{}", job.id));
            let write_all = || -> std::io::Result<()> {
                std::fs::create_dir_all(&d)?;
                std::fs::write(d.join("report.csv"), &csv)?;
                std::fs::write(d.join("report.json"), &json)?;
                if let Some(t) = &trace {
                    std::fs::write(d.join("trace.jsonl"), t)?;
                }
                Ok(())
            };
            if let Err(e) = write_all() {
                self.opts
                    .console
                    .warn(format_args!("flushing artifacts to {}: {e}", d.display()));
            }
        }
        {
            let mut st = lock(&job.st);
            st.state = JobState::Done;
            st.cells_done = st.cells_total;
            st.cells_failed = failed;
            st.report_csv = Some(csv);
            st.report_json = Some(json);
            st.trace_jsonl = trace;
        }
        bump(&self.counters.completed);
        job.events.push(
            Json::obj(vec![
                ("ev", Json::str("job_done")),
                ("job", Json::num(job.id as f64)),
                ("state", Json::str("done")),
                ("cells_failed", Json::num(failed as f64)),
            ])
            .to_string(),
        );
        job.events.close();
    }
}

/// The per-job [`CellHooks`] bridge: cell lifecycle → event log +
/// counters, cancel flag → skip/abort.
struct JobHooks<'a> {
    daemon: &'a Daemon,
    job: &'a Arc<Job>,
}

impl CellHooks for JobHooks<'_> {
    fn on_cell_start(&self, id: &str) {
        self.job.events.push(
            Json::obj(vec![("ev", Json::str("cell_start")), ("cell", Json::str(id))]).to_string(),
        );
    }

    fn on_point(&self, id: &str, algo: &str, p: &TracePoint) -> bool {
        self.job.events.push(
            Json::obj(vec![
                ("ev", Json::str("point")),
                ("cell", Json::str(id)),
                ("algo", Json::str(algo)),
                ("round", Json::num(p.round as f64)),
                ("loss", Json::num(p.loss)),
                ("comm_mb", Json::num(p.comm_mb)),
            ])
            .to_string(),
        );
        !self.job.cancel.load(Ordering::Relaxed)
    }

    fn on_cell_done(&self, id: &str, ok: bool) {
        bump(&self.daemon.counters.cells_run);
        let (done, total) = {
            let mut st = lock(&self.job.st);
            st.cells_done += 1;
            if !ok {
                st.cells_failed += 1;
            }
            (st.cells_done, st.cells_total)
        };
        self.job.events.push(
            Json::obj(vec![
                ("ev", Json::str("cell_done")),
                ("cell", Json::str(id)),
                ("ok", Json::Bool(ok)),
                ("done", Json::num(done as f64)),
                ("total", Json::num(total as f64)),
            ])
            .to_string(),
        );
    }

    fn skip(&self, _id: &str) -> bool {
        self.job.cancel.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// Job-body parsing

/// Parse a job body into a sweep spec.  Sniffs the format: a leading `{`
/// means JSON (flattened to the same `table.key` map TOML produces), else
/// TOML.  Both resolve through [`SweepSpec::from_flat_map`], so a body
/// yields the same grid, seeds and report bytes as a batch `c2dfb sweep
/// --config` of the equivalent file.
pub fn parse_spec(body: &str) -> Result<SweepSpec, String> {
    let trimmed = body.trim_start();
    if trimmed.is_empty() {
        return Err("empty job body".into());
    }
    let map = if trimmed.starts_with('{') {
        json_flat_map(body)?
    } else {
        toml::parse(body)?
    };
    let spec = SweepSpec::from_flat_map(&map)?;
    validate_spec(&spec)?;
    Ok(spec)
}

/// Cheap submit-time validation: parse every axis value that has a
/// parser, so malformed grids are refused with 400 at submission instead
/// of failing later inside the queue.  (Task names are validated at
/// expansion — building task data here would be submit-time work.)
fn validate_spec(spec: &SweepSpec) -> Result<(), String> {
    for p in &spec.partitions {
        Partition::parse(p)?;
    }
    for t in &spec.topologies {
        Topology::parse(t, spec.base.seed)?;
    }
    for c in &spec.compressors {
        if c != "default" && !c.is_empty() {
            crate::compress::parse(c)?;
        }
    }
    let mut scratch = spec.base.clone();
    for s in &spec.stops {
        sweep::apply_stop(&mut scratch, s)?;
    }
    Ok(())
}

/// Flatten a JSON job body to the `table.key → TomlValue` map the TOML
/// parser produces: top-level scalars keep their key, one level of
/// nesting becomes `section.key`, arrays of strings map to TOML string
/// arrays (axis lists).  Deeper nesting is rejected.
fn json_flat_map(body: &str) -> Result<BTreeMap<String, TomlValue>, String> {
    let doc = Json::parse(body)?;
    let top = doc.as_obj().ok_or("job body must be a JSON object")?;
    let mut map = BTreeMap::new();
    for (k, v) in top {
        match v {
            Json::Obj(inner) => {
                for (k2, v2) in inner {
                    map.insert(format!("{k}.{k2}"), json_scalar(&format!("{k}.{k2}"), v2)?);
                }
            }
            other => {
                map.insert(k.clone(), json_scalar(k, other)?);
            }
        }
    }
    Ok(map)
}

fn json_scalar(key: &str, v: &Json) -> Result<TomlValue, String> {
    match v {
        Json::Bool(b) => Ok(TomlValue::Bool(*b)),
        Json::Str(s) => Ok(TomlValue::Str(s.clone())),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                Ok(TomlValue::Int(*n as i64))
            } else {
                Ok(TomlValue::Float(*n))
            }
        }
        Json::Arr(a) => a
            .iter()
            .map(|e| {
                e.as_str()
                    .map(|s| TomlValue::Str(s.to_string()))
                    .ok_or(format!("{key}: axis arrays must contain strings"))
            })
            .collect::<Result<Vec<_>, _>>()
            .map(TomlValue::Arr),
        Json::Null => Err(format!("{key}: null is not a valid value")),
        Json::Obj(_) => Err(format!("{key}: nesting deeper than one table is not supported")),
    }
}

// ---------------------------------------------------------------------------
// Serving

/// A spawned daemon: shared state, bound addresses, listener threads.
pub struct DaemonHandle {
    pub daemon: Arc<Daemon>,
    pub http_addr: Option<SocketAddr>,
    pub tcp_addr: Option<SocketAddr>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl DaemonHandle {
    /// Block until every daemon thread has exited (after
    /// [`Daemon::begin_shutdown`] has let the executor drain).
    pub fn join(self) {
        for t in self.threads {
            let _ = t.join();
        }
    }

    /// Convenience for tests: begin shutdown and wait for full stop.
    pub fn shutdown_join(self, now: bool) {
        self.daemon.begin_shutdown(now);
        self.join();
    }
}

/// Bind the requested listeners and start the executor; returns
/// immediately.  Tests bind `127.0.0.1:0` and read the actual port from
/// the handle.
pub fn spawn(opts: ServeOpts) -> Result<DaemonHandle> {
    let daemon = Daemon::new(opts);
    let mut threads = Vec::new();
    let http_addr = match &daemon.opts.http {
        Some(addr) => {
            let listener = std::net::TcpListener::bind(addr)
                .map_err(|e| anyhow::anyhow!("binding http {addr}: {e}"))?;
            let local = listener.local_addr()?;
            let d = daemon.clone();
            threads.push(std::thread::spawn(move || http::listen(&d, listener)));
            Some(local)
        }
        None => None,
    };
    let tcp_addr = match &daemon.opts.tcp {
        Some(addr) => {
            let listener = std::net::TcpListener::bind(addr)
                .map_err(|e| anyhow::anyhow!("binding tcp {addr}: {e}"))?;
            let local = listener.local_addr()?;
            let d = daemon.clone();
            threads.push(std::thread::spawn(move || tcp::listen(&d, listener)));
            Some(local)
        }
        None => None,
    };
    {
        let d = daemon.clone();
        threads.push(std::thread::spawn(move || d.executor_loop()));
    }
    Ok(DaemonHandle { daemon, http_addr, tcp_addr, threads })
}

/// Foreground entry point for `c2dfb serve`: spawn, then supervise until
/// a signal (or a protocol `SHUTDOWN`) stops the daemon.  First
/// SIGINT/SIGTERM drains; a second one hard-stops (cancel + checkpoint).
pub fn serve(opts: ServeOpts) -> Result<()> {
    install_signal_handlers();
    let con = opts.console;
    let handle = spawn(opts)?;
    if let Some(a) = handle.http_addr {
        con.info(format_args!("c2dfb serve: http on {a}"));
    }
    if let Some(a) = handle.tcp_addr {
        con.info(format_args!("c2dfb serve: tcp on {a}"));
    }
    if handle.http_addr.is_none() && handle.tcp_addr.is_none() {
        anyhow::bail!("both surfaces disabled: pass --http ADDR and/or --tcp ADDR");
    }
    let mut announced = 0usize;
    while !handle.daemon.stopped() {
        let signals = SIGNALS_SEEN.load(Ordering::SeqCst);
        if signals >= 2 {
            if announced < 2 {
                con.info(format_args!("second signal: cancelling + checkpointing the queue"));
                announced = 2;
            }
            handle.daemon.begin_shutdown(true);
        } else if signals == 1 {
            if announced < 1 {
                con.info(format_args!(
                    "signal received: draining the queue (signal again to hard-stop)"
                ));
                announced = 1;
            }
            handle.daemon.begin_shutdown(false);
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    handle.join();
    con.info(format_args!("c2dfb serve: stopped"));
    Ok(())
}

static SIGNALS_SEEN: AtomicUsize = AtomicUsize::new(0);

#[cfg(unix)]
fn install_signal_handlers() {
    extern "C" fn on_signal(_sig: i32) {
        SIGNALS_SEEN.fetch_add(1, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    // SAFETY: registering `on_signal` for SIGINT (2) and SIGTERM (15) via
    // the libc `signal` FFI is sound because (a) the handler is
    // async-signal-safe: its only effect is `AtomicUsize::fetch_add` on a
    // static — a single lock-free instruction with no allocation, no
    // locks, no panics, and no other library calls; (b) the function
    // pointer has the exact `extern "C" fn(i32)` ABI the kernel will
    // invoke it with, and a `'static` lifetime (a plain fn item); (c) the
    // FFI declaration matches libc's `signal` signature (handler passed
    // as a pointer-sized integer); and (d) replacing the previous
    // disposition is the intent — the supervise loop polls SIGNALS_SEEN
    // to run graceful shutdown instead of the default immediate kill.
    unsafe {
        signal(2, on_signal as extern "C" fn(i32) as usize);
        signal(15, on_signal as extern "C" fn(i32) as usize);
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spec_sniffs_toml_and_json_to_the_same_grid() {
        let toml_spec = parse_spec(
            "[sweep]\ntiny = true\n",
        )
        .unwrap();
        let json_spec = parse_spec(r#"{"sweep": {"tiny": true}}"#).unwrap();
        let a = sweep::expand(&toml_spec).unwrap();
        let b = sweep::expand(&json_spec).unwrap();
        assert_eq!(a.cells.len(), b.cells.len());
        for (x, y) in a.cells.iter().zip(&b.cells) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.cfg.seed, y.cfg.seed);
        }
        // And both match the batch --tiny grid.
        let tiny = sweep::expand(&SweepSpec::tiny()).unwrap();
        assert_eq!(a.cells.len(), tiny.cells.len());
        for (x, y) in a.cells.iter().zip(&tiny.cells) {
            assert_eq!(x.id, y.id);
        }
    }

    #[test]
    fn parse_spec_rejects_garbage_early() {
        assert!(parse_spec("").is_err());
        assert!(parse_spec("   ").is_err());
        assert!(parse_spec("{not json").is_err());
        assert!(parse_spec("[sweep]\nbogus = 1\n").is_err());
        assert!(parse_spec(r#"{"sweep": {"stops": "wall_secs:3"}}"#).is_err());
        assert!(parse_spec(r#"{"sweep": {"topologies": "hypercube9000"}}"#).is_err());
        assert!(parse_spec(r#"{"a": {"b": {"c": 1}}}"#).is_err(), "deep nesting");
        assert!(parse_spec(r#"{"sweep": {"algos": [1, 2]}}"#).is_err(), "non-string axis");
    }

    #[test]
    fn event_log_caps_waits_and_closes() {
        let log = EventLog::new(3);
        log.push("a".into());
        log.push("b".into());
        log.push("c".into());
        log.push("d".into());
        log.push("e".into());
        let (lines, next, closed) = log.snapshot_from(0);
        assert_eq!(lines.len(), 4, "3 lines + one truncation marker");
        assert!(lines[3].contains("events_truncated"));
        assert_eq!(log.dropped(), 2);
        assert!(!closed);
        let (rest, _, _) = log.snapshot_from(next);
        assert!(rest.is_empty());
        log.close();
        let (_, _, closed) = log.wait_from(next, Duration::from_millis(10));
        assert!(closed);
    }

    #[test]
    fn submit_backpressure_and_priority_order() {
        let opts = ServeOpts { queue_cap: 2, start_paused: true, ..ServeOpts::default() };
        let d = Daemon::new(opts);
        let body = r#"{"sweep": {"tiny": true}}"#;
        let a = d.submit(body, 0, false).unwrap();
        let b = d.submit(body, 5, false).unwrap();
        assert!(matches!(d.submit(body, 0, false), Err(SubmitError::QueueFull)));
        assert_eq!(d.queue_depth(), 2);
        assert_eq!(d.counters.rejected.load(Ordering::Relaxed), 1);
        // Cancel one queued job: it flips to cancelled immediately and
        // frees queue capacity.
        d.cancel(a.id).unwrap();
        assert_eq!(a.state(), JobState::Cancelled);
        assert_eq!(d.queue_depth(), 1);
        assert!(d.submit(body, 0, false).is_ok());
        // Drain mode refuses new work.
        d.begin_shutdown(false);
        assert!(matches!(d.submit(body, 0, false), Err(SubmitError::ShuttingDown)));
        assert_eq!(b.state(), JobState::Queued, "drain keeps queued jobs");
    }
}
