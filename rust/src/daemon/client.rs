//! `c2dfb client` — the daemon's command-line companion, speaking the
//! line-delimited TCP protocol of [`super::tcp`].  One connection per
//! call: write a command, read one `OK <n>`/`ERR <msg>` frame, done.
//! Also usable programmatically (the daemon tests drive it in-process).

use crate::obs::Console;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

pub struct Client {
    pub addr: String,
    pub timeout: Duration,
}

impl Client {
    pub fn new(addr: &str) -> Client {
        Client { addr: addr.to_string(), timeout: Duration::from_secs(10) }
    }

    /// One protocol round-trip: send `header` (+ optional raw body for
    /// `SUBMITB`), return the `OK` payload or the `ERR` message.
    fn call(&self, header: &str, body: Option<&[u8]>) -> Result<Vec<u8>, String> {
        let mut stream = TcpStream::connect(&self.addr)
            .map_err(|e| format!("connecting to {}: {e}", self.addr))?;
        let _ = stream.set_read_timeout(Some(self.timeout));
        let _ = stream.set_write_timeout(Some(self.timeout));
        stream
            .write_all(header.as_bytes())
            .and_then(|_| stream.write_all(b"\n"))
            .and_then(|_| match body {
                Some(b) => stream.write_all(b),
                None => Ok(()),
            })
            .and_then(|_| stream.flush())
            .map_err(|e| format!("sending command: {e}"))?;
        let mut reader = BufReader::new(stream);
        let mut status = String::new();
        reader
            .read_line(&mut status)
            .map_err(|e| format!("reading response: {e}"))?;
        let status = status.trim_end();
        if let Some(rest) = status.strip_prefix("OK ") {
            let n: usize = rest
                .parse()
                .map_err(|_| format!("malformed response frame {status:?}"))?;
            let mut payload = vec![0u8; n];
            reader
                .read_exact(&mut payload)
                .map_err(|e| format!("reading {n}-byte payload: {e}"))?;
            Ok(payload)
        } else if let Some(msg) = status.strip_prefix("ERR ") {
            Err(msg.to_string())
        } else {
            Err(format!("malformed response frame {status:?}"))
        }
    }

    fn call_json(&self, header: &str, body: Option<&[u8]>) -> Result<Json, String> {
        let payload = self.call(header, body)?;
        let text = String::from_utf8(payload).map_err(|_| "non-UTF-8 response")?;
        Json::parse(&text)
    }

    pub fn ping(&self) -> Result<(), String> {
        self.call("PING", None).map(|_| ())
    }

    /// Submit a TOML/JSON sweep body (`SUBMITB`: length-framed, so the
    /// body may span lines).  Returns the job's status document.
    pub fn submit(&self, body: &str, priority: i64, trace: bool) -> Result<Json, String> {
        let header = format!(
            "SUBMITB {} {priority} {}",
            body.len(),
            if trace { 1 } else { 0 }
        );
        self.call_json(&header, Some(body.as_bytes()))
    }

    pub fn status(&self, id: u64) -> Result<Json, String> {
        self.call_json(&format!("STATUS {id}"), None)
    }

    pub fn list(&self) -> Result<Json, String> {
        self.call_json("LIST", None)
    }

    pub fn report(&self, id: u64, fmt: &str) -> Result<Vec<u8>, String> {
        self.call(&format!("REPORT {id} {fmt}"), None)
    }

    /// Poll the event log once from `cursor`:
    /// `(new lines, next cursor, closed)`.
    pub fn events(&self, id: u64, cursor: usize) -> Result<(Vec<String>, usize, bool), String> {
        let doc = self.call_json(&format!("EVENTS {id} {cursor}"), None)?;
        let next = doc
            .get("next")
            .and_then(Json::as_usize)
            .ok_or("malformed EVENTS response")?;
        let closed = matches!(doc.get("closed"), Some(Json::Bool(true)));
        let lines = doc
            .get("lines")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .filter_map(|l| l.as_str().map(str::to_string))
                    .collect()
            })
            .unwrap_or_default();
        Ok((lines, next, closed))
    }

    pub fn cancel(&self, id: u64) -> Result<Json, String> {
        self.call_json(&format!("CANCEL {id}"), None)
    }

    pub fn metrics(&self) -> Result<String, String> {
        let payload = self.call("METRICS", None)?;
        String::from_utf8(payload).map_err(|_| "non-UTF-8 metrics".into())
    }

    pub fn shutdown(&self, now: bool) -> Result<(), String> {
        self.call(if now { "SHUTDOWN now" } else { "SHUTDOWN drain" }, None)
            .map(|_| ())
    }

    /// Follow a job to a terminal state, streaming its progress events to
    /// `con` (event lines at verbose, one line per cell completion at
    /// normal).  Returns the final status document.
    // Operator-facing deadline against a remote daemon (lint.toml R1
    // allow4).
    #[allow(clippy::disallowed_methods)]
    pub fn wait(&self, id: u64, timeout: Duration, con: &Console) -> Result<Json, String> {
        let started = Instant::now();
        let mut cursor = 0usize;
        loop {
            let (lines, next, closed) = self.events(id, cursor)?;
            cursor = next;
            for line in &lines {
                con.progress(format_args!("  {line}"));
                if !con.is_verbose() {
                    if let Ok(ev) = Json::parse(line) {
                        if ev.get("ev").and_then(Json::as_str) == Some("cell_done") {
                            con.info(format_args!(
                                "  cell {}/{} {}",
                                ev.get("done").and_then(Json::as_usize).unwrap_or(0),
                                ev.get("total").and_then(Json::as_usize).unwrap_or(0),
                                ev.get("cell").and_then(Json::as_str).unwrap_or("?"),
                            ));
                        }
                    }
                }
            }
            if closed && lines.is_empty() {
                let status = self.status(id)?;
                let state = status.get("state").and_then(Json::as_str).unwrap_or("");
                if matches!(state, "done" | "failed" | "cancelled") {
                    return Ok(status);
                }
                // Events closed but the state write is racing us: fall
                // through to the timeout check and poll again.
            }
            if started.elapsed() > timeout {
                return Err(format!("timed out after {:.0?} waiting for job {id}", timeout));
            }
            if lines.is_empty() {
                std::thread::sleep(Duration::from_millis(150));
            }
        }
    }
}
