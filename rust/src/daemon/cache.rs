//! Completed-cell result cache.
//!
//! The sweep layer's determinism contract (docs/SWEEP.md) makes caching
//! sound: a cell's metrics and trace chunk are a pure function of its
//! fully-resolved config plus the seed derived from `(base_seed,
//! cell_id)` by [`derive_seed`](crate::coordinator::sweep::derive_seed).
//! Re-running an identical cell is a bit-identical replay, so the daemon
//! serves resubmitted or overlapping grids straight from this cache —
//! byte-identical `report.{csv,json}` with zero new oracle calls.
//!
//! The key ([`cache_key`]) therefore captures *everything* a cell run
//! reads: the cell id (seed input), the sweep-level knobs that shape the
//! task data (`tiny`, base seed) and whether a trace sink was attached,
//! plus the full resolved `ExperimentConfig` via its `Debug` rendering
//! (topology realization, partition, compressor, stop budgets, optimizer
//! knobs — all of it).  Execution-only knobs (`jobs`, console verbosity)
//! are deliberately absent: they cannot change result bytes.
//!
//! Eviction is FIFO with a bounded entry count — the daemon's memory
//! stays bounded no matter how many distinct grids clients submit, and
//! FIFO keeps the policy deterministic (no clock reads).

use crate::coordinator::sweep::{Cell, SweepSpec};
use crate::metrics::RunMetrics;
use std::collections::{BTreeMap, VecDeque};

/// A cached cell result: the deterministic metrics plus the cell's JSONL
/// trace chunk when the job that produced it traced.
#[derive(Clone)]
pub struct CacheEntry {
    pub metrics: RunMetrics,
    pub trace: Option<String>,
}

/// The deterministic cache key for one cell of one submission.  `v1|` is
/// a schema version prefix so a future key-shape change cannot alias old
/// entries.
pub fn cache_key(spec: &SweepSpec, trace: bool, cell: &Cell) -> String {
    format!(
        "v1|tiny={}|base_seed={}|trace={}|{}|{:?}",
        spec.tiny, spec.base.seed, trace, cell.id, cell.cfg
    )
}

/// Bounded FIFO map from [`cache_key`] to [`CacheEntry`].
pub struct CellCache {
    cap: usize,
    map: BTreeMap<String, CacheEntry>,
    order: VecDeque<String>,
}

impl CellCache {
    /// `cap = 0` disables caching entirely (every lookup misses).
    pub fn new(cap: usize) -> CellCache {
        CellCache { cap, map: BTreeMap::new(), order: VecDeque::new() }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn get(&self, key: &str) -> Option<&CacheEntry> {
        self.map.get(key)
    }

    /// Insert one completed cell, evicting oldest-first past the cap.
    /// Re-inserting an existing key is a no-op (first result wins; both
    /// are bit-identical by the determinism contract anyway).
    pub fn insert(&mut self, key: String, entry: CacheEntry) {
        if self.cap == 0 || self.map.contains_key(&key) {
            return;
        }
        while self.map.len() >= self.cap {
            match self.order.pop_front() {
                Some(old) => {
                    self.map.remove(&old);
                }
                None => break,
            }
        }
        self.order.push_back(key.clone());
        self.map.insert(key, entry);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sweep::{self, SweepSpec};

    fn entry() -> CacheEntry {
        CacheEntry { metrics: RunMetrics::new("c2dfb", "t"), trace: None }
    }

    #[test]
    fn fifo_eviction_bounds_entry_count() {
        let mut c = CellCache::new(2);
        c.insert("a".into(), entry());
        c.insert("b".into(), entry());
        c.insert("c".into(), entry());
        assert_eq!(c.len(), 2);
        assert!(c.get("a").is_none(), "oldest entry evicted first");
        assert!(c.get("b").is_some());
        assert!(c.get("c").is_some());
    }

    #[test]
    fn zero_cap_disables_caching() {
        let mut c = CellCache::new(0);
        c.insert("a".into(), entry());
        assert!(c.is_empty());
        assert!(c.get("a").is_none());
    }

    #[test]
    fn key_separates_seed_trace_and_cell() {
        let spec = SweepSpec::tiny();
        let grid = sweep::expand(&spec).unwrap();
        let a = cache_key(&spec, false, &grid.cells[0]);
        let b = cache_key(&spec, false, &grid.cells[1]);
        assert_ne!(a, b, "distinct cells key differently");
        assert_ne!(
            a,
            cache_key(&spec, true, &grid.cells[0]),
            "trace flag is part of the key"
        );
        let mut seeded = SweepSpec::tiny();
        seeded.base.seed = 999;
        let reseeded = sweep::expand(&seeded).unwrap();
        assert_ne!(
            a,
            cache_key(&seeded, false, &reseeded.cells[0]),
            "base seed is part of the key"
        );
        assert_eq!(
            a,
            cache_key(&spec, false, &sweep::expand(&spec).unwrap().cells[0]),
            "identical submissions share the key"
        );
    }
}
