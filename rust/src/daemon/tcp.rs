//! Line-delimited TCP protocol — the `c2dfb client` transport.
//!
//! One command per connection.  The client sends a single command line
//! (LF-terminated; `SUBMITB` is followed by a raw body), the server
//! answers with exactly one framed response and closes:
//!
//! ```text
//! OK <nbytes>\n<nbytes of payload>     success
//! ERR <message>\n                      failure (message is one line)
//! ```
//!
//! Commands:
//!
//! ```text
//! PING
//! SUBMIT <priority> <trace:0|1> <inline-json-body>
//! SUBMITB <nbytes> <priority> <trace:0|1>    (raw TOML/JSON body follows)
//! STATUS <id>
//! LIST
//! REPORT <id> csv|json|trace
//! EVENTS <id> <cursor>
//! CANCEL <id>
//! METRICS
//! SHUTDOWN [drain|now]
//! ```
//!
//! Same hardening budget as HTTP: 1 MiB command line, 4 MiB body,
//! 10 s I/O timeouts.

// Toolchain-native twin of lint rule R3 (panic-free request parsing);
// `c2dfb lint` enforces the same contract lexically.  docs/LINT.md.
#![warn(clippy::unwrap_used, clippy::expect_used, clippy::panic)]

use super::{Daemon, SubmitError};
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

const MAX_LINE_BYTES: usize = 1024 * 1024;
const MAX_BODY_BYTES: usize = 4 * 1024 * 1024;
const IO_TIMEOUT: Duration = Duration::from_secs(10);

/// Accept loop (mirrors the HTTP one): non-blocking accept polling the
/// shutdown phase, one thread per connection.
pub fn listen(d: &Arc<Daemon>, listener: TcpListener) {
    if listener.set_nonblocking(true).is_err() {
        d.opts.console.warn(format_args!("tcp listener: cannot set non-blocking"));
        return;
    }
    loop {
        if d.stopped() {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let d = d.clone();
                std::thread::spawn(move || handle(&d, stream));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(50)),
        }
    }
}

fn handle(d: &Arc<Daemon>, stream: TcpStream) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let result = read_command(&mut reader).and_then(|line| dispatch(d, &line, &mut reader));
    match result {
        Ok(payload) => {
            let _ = writer.write_all(format!("OK {}\n", payload.len()).as_bytes());
            let _ = writer.write_all(&payload);
        }
        Err(msg) => {
            // The error frame is one line by construction.
            let one_line = msg.replace(['\n', '\r'], " ");
            let _ = writer.write_all(format!("ERR {one_line}\n").as_bytes());
        }
    }
    let _ = writer.flush();
}

/// Read one LF-terminated command line with an explicit cap (BufRead's
/// `read_line` is unbounded — a hostile peer could stream gigabytes).
fn read_command(reader: &mut BufReader<TcpStream>) -> Result<String, String> {
    let mut raw = Vec::new();
    let n = reader
        .by_ref()
        .take(MAX_LINE_BYTES as u64 + 1)
        .read_until(b'\n', &mut raw)
        .map_err(|e| format!("reading command: {e}"))?;
    if n == 0 {
        return Err("empty command".into());
    }
    if raw.last() != Some(&b'\n') {
        return Err(format!("command line exceeds {MAX_LINE_BYTES} bytes or is unterminated"));
    }
    raw.pop();
    if raw.last() == Some(&b'\r') {
        raw.pop();
    }
    String::from_utf8(raw).map_err(|_| "command must be UTF-8".into())
}

fn parse_id(tok: Option<&str>) -> Result<u64, String> {
    tok.ok_or("missing job id")?
        .parse()
        .map_err(|_| "bad job id".into())
}

fn submit(d: &Daemon, body: &str, priority: i64, trace: bool) -> Result<Vec<u8>, String> {
    match d.submit(body, priority, trace) {
        Ok(job) => Ok((job.status_json().to_string() + "\n").into_bytes()),
        Err(SubmitError::QueueFull) => Err("queue-full".into()),
        Err(SubmitError::ShuttingDown) => Err("shutting-down".into()),
        Err(SubmitError::Bad(e)) => Err(format!("bad-request: {e}")),
    }
}

fn dispatch(
    d: &Arc<Daemon>,
    line: &str,
    reader: &mut BufReader<TcpStream>,
) -> Result<Vec<u8>, String> {
    let mut head = line.splitn(4, ' ');
    let cmd = head.next().unwrap_or_default();
    match cmd {
        "PING" => Ok(b"pong\n".to_vec()),
        "SUBMIT" => {
            let priority: i64 = head
                .next()
                .ok_or("SUBMIT wants: SUBMIT <priority> <trace:0|1> <json>")?
                .parse()
                .map_err(|_| "bad priority")?;
            let trace = parse_trace_flag(head.next())?;
            let body = head.next().ok_or("SUBMIT: missing inline body")?;
            submit(d, body, priority, trace)
        }
        "SUBMITB" => {
            let nbytes: usize = head
                .next()
                .ok_or("SUBMITB wants: SUBMITB <nbytes> <priority> <trace:0|1>")?
                .parse()
                .map_err(|_| "bad byte count")?;
            if nbytes > MAX_BODY_BYTES {
                return Err(format!("body larger than {MAX_BODY_BYTES} bytes"));
            }
            let priority: i64 = head
                .next()
                .ok_or("SUBMITB: missing priority")?
                .parse()
                .map_err(|_| "bad priority")?;
            let trace = parse_trace_flag(head.next())?;
            let mut body = vec![0u8; nbytes];
            reader
                .read_exact(&mut body)
                .map_err(|e| format!("reading {nbytes}-byte body: {e}"))?;
            let body = String::from_utf8(body).map_err(|_| "body must be UTF-8")?;
            submit(d, &body, priority, trace)
        }
        "STATUS" => {
            let id = parse_id(head.next())?;
            let job = d.job(id).ok_or(format!("no job {id}"))?;
            Ok((job.status_json().to_string() + "\n").into_bytes())
        }
        "LIST" => {
            let docs: Vec<Json> = d.jobs_snapshot().iter().map(|j| j.status_json()).collect();
            let doc = Json::obj(vec![("jobs", Json::Arr(docs))]);
            Ok((doc.to_string() + "\n").into_bytes())
        }
        "REPORT" => {
            let id = parse_id(head.next())?;
            let fmt = head.next().ok_or("REPORT wants: REPORT <id> csv|json|trace")?;
            let job = d.job(id).ok_or(format!("no job {id}"))?;
            job.with_progress(|st| {
                if st.state != super::JobState::Done {
                    return Err(format!(
                        "job is {} — artifacts exist once it is done",
                        st.state.name()
                    ));
                }
                let body = match fmt {
                    "csv" => st.report_csv.clone(),
                    "json" => st.report_json.clone(),
                    "trace" => st.trace_jsonl.clone(),
                    other => return Err(format!("unknown report format {other:?}")),
                };
                body.map(String::into_bytes)
                    .ok_or("no such artifact (trace requires submitting with trace=1)".into())
            })
        }
        "EVENTS" => {
            let id = parse_id(head.next())?;
            let cursor: usize = head
                .next()
                .unwrap_or("0")
                .parse()
                .map_err(|_| "bad cursor")?;
            let job = d.job(id).ok_or(format!("no job {id}"))?;
            let (lines, next, closed) = job.events.snapshot_from(cursor);
            let doc = Json::obj(vec![
                ("next", Json::num(next as f64)),
                ("closed", Json::Bool(closed)),
                (
                    "lines",
                    Json::Arr(lines.iter().map(|l| Json::str(l)).collect()),
                ),
            ]);
            Ok((doc.to_string() + "\n").into_bytes())
        }
        "CANCEL" => {
            let id = parse_id(head.next())?;
            let job = d.cancel(id).ok_or(format!("no job {id}"))?;
            Ok((job.status_json().to_string() + "\n").into_bytes())
        }
        "METRICS" => Ok(d.render_metrics().into_bytes()),
        "SHUTDOWN" => {
            let now = matches!(head.next(), Some("now"));
            d.begin_shutdown(now);
            Ok(b"shutting down\n".to_vec())
        }
        other => Err(format!("unknown command {other:?}")),
    }
}

fn parse_trace_flag(tok: Option<&str>) -> Result<bool, String> {
    match tok {
        Some("0") => Ok(false),
        Some("1") => Ok(true),
        _ => Err("trace flag must be 0 or 1".into()),
    }
}
