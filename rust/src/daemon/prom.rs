//! Prometheus text-exposition helpers for the daemon's GET /metrics.
//!
//! Two halves:
//!
//! * [`render_process`] — the daemon's process-level families (queue
//!   depth, jobs by state, submission/cache counters), rendered from a
//!   plain snapshot struct so the daemon's internals stay private.  The
//!   endpoint concatenates this with exactly **one**
//!   [`RunMetrics::render_prometheus`](crate::metrics::RunMetrics::render_prometheus)
//!   rendering of the daemon's aggregate run ledger — never one per job,
//!   because repeated `# TYPE` lines for the same family are invalid
//!   exposition.
//! * [`validate_exposition`] — a strict parser for the text exposition
//!   format (the format `# TYPE` discipline, metric/label name grammar,
//!   float sample values, optional timestamps).  It is the unit-test
//!   oracle that keeps /metrics scrapable.

use std::collections::BTreeSet;
use std::fmt::Write as _;

/// Point-in-time view of the daemon's process-level metrics.
#[derive(Clone, Copy, Debug, Default)]
pub struct ProcSnapshot {
    pub queue_depth: u64,
    /// Jobs currently in each lifecycle state, in fixed order:
    /// queued, running, done, failed, cancelled.
    pub jobs_by_state: [u64; 5],
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub cancelled: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_entries: u64,
    pub cells_run: u64,
    pub events_dropped: u64,
}

const STATE_NAMES: [&str; 5] = ["queued", "running", "done", "failed", "cancelled"];

fn family(out: &mut String, name: &str, help: &str, kind: &str, samples: &[(String, u64)]) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
    for (labels, v) in samples {
        let _ = writeln!(out, "{name}{labels} {v}");
    }
}

/// Render the daemon's process-level families as text exposition.
pub fn render_process(s: &ProcSnapshot) -> String {
    let mut out = String::new();
    let plain = |v: u64| vec![(String::new(), v)];
    family(
        &mut out,
        "c2dfb_daemon_queue_depth",
        "Jobs waiting in the priority queue.",
        "gauge",
        &plain(s.queue_depth),
    );
    family(
        &mut out,
        "c2dfb_daemon_jobs",
        "Jobs currently tracked, by lifecycle state.",
        "gauge",
        &STATE_NAMES
            .iter()
            .zip(s.jobs_by_state)
            .map(|(name, v)| (format!("{{state=\"{name}\"}}"), v))
            .collect::<Vec<_>>(),
    );
    family(
        &mut out,
        "c2dfb_daemon_jobs_submitted_total",
        "Jobs accepted into the queue since start.",
        "counter",
        &plain(s.submitted),
    );
    family(
        &mut out,
        "c2dfb_daemon_jobs_rejected_total",
        "Submissions refused by queue backpressure.",
        "counter",
        &plain(s.rejected),
    );
    family(
        &mut out,
        "c2dfb_daemon_jobs_completed_total",
        "Jobs that finished successfully.",
        "counter",
        &plain(s.completed),
    );
    family(
        &mut out,
        "c2dfb_daemon_jobs_failed_total",
        "Jobs that failed (bad spec, panic, or expansion error).",
        "counter",
        &plain(s.failed),
    );
    family(
        &mut out,
        "c2dfb_daemon_jobs_cancelled_total",
        "Jobs cancelled by clients or shutdown.",
        "counter",
        &plain(s.cancelled),
    );
    family(
        &mut out,
        "c2dfb_daemon_cell_cache_hits_total",
        "Cells served from the completed-cell result cache.",
        "counter",
        &plain(s.cache_hits),
    );
    family(
        &mut out,
        "c2dfb_daemon_cell_cache_misses_total",
        "Cells that had to execute.",
        "counter",
        &plain(s.cache_misses),
    );
    family(
        &mut out,
        "c2dfb_daemon_cell_cache_entries",
        "Completed cells currently cached.",
        "gauge",
        &plain(s.cache_entries),
    );
    family(
        &mut out,
        "c2dfb_daemon_cells_run_total",
        "Cells executed (cache misses that ran to completion or error).",
        "counter",
        &plain(s.cells_run),
    );
    family(
        &mut out,
        "c2dfb_daemon_events_dropped_total",
        "Per-job progress events discarded past the event-log cap.",
        "counter",
        &plain(s.events_dropped),
    );
    out
}

fn valid_metric_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn valid_label_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Parse one `{name="value",...}` label block; returns the byte length
/// consumed (including both braces).
fn parse_labels(s: &str) -> Result<usize, String> {
    let bytes = s.as_bytes();
    debug_assert_eq!(bytes.first(), Some(&b'{'));
    let mut i = 1;
    loop {
        // Allow `{}` and a trailing comma before the closing brace.
        if bytes.get(i) == Some(&b'}') {
            return Ok(i + 1);
        }
        let name_start = i;
        while i < bytes.len() && bytes[i] != b'=' && bytes[i] != b'}' && bytes[i] != b',' {
            i += 1;
        }
        let name = &s[name_start..i];
        if !valid_label_name(name) {
            return Err(format!("bad label name {name:?}"));
        }
        if bytes.get(i) != Some(&b'=') {
            return Err(format!("label {name:?} missing '='"));
        }
        i += 1;
        if bytes.get(i) != Some(&b'"') {
            return Err(format!("label {name:?} value must be quoted"));
        }
        i += 1;
        // Scan the quoted value; backslash escapes the next byte.
        loop {
            match bytes.get(i) {
                None => return Err(format!("unterminated label value for {name:?}")),
                Some(b'\\') => i += 2,
                Some(b'"') => {
                    i += 1;
                    break;
                }
                Some(_) => i += 1,
            }
        }
        match bytes.get(i) {
            Some(b',') => i += 1,
            Some(b'}') => return Ok(i + 1),
            _ => return Err("expected ',' or '}' after label value".into()),
        }
    }
}

/// Validate Prometheus text exposition (format version 0.0.4).  Checks
/// the grammar of every line, the metric/label name character sets, that
/// each family's `# TYPE` appears at most once and before any of its
/// samples, and that every sample value parses as a float.  Returns the
/// number of sample lines.
pub fn validate_exposition(text: &str) -> Result<usize, String> {
    let mut typed: BTreeSet<String> = BTreeSet::new();
    let mut sampled: BTreeSet<String> = BTreeSet::new();
    let mut samples = 0usize;
    for (ln, line) in text.lines().enumerate() {
        let ln = ln + 1;
        if line.trim().is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().ok_or(format!("line {ln}: TYPE without name"))?;
            let kind = it.next().ok_or(format!("line {ln}: TYPE without kind"))?;
            if it.next().is_some() {
                return Err(format!("line {ln}: trailing tokens after TYPE"));
            }
            if !valid_metric_name(name) {
                return Err(format!("line {ln}: bad metric name {name:?}"));
            }
            if !matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                return Err(format!("line {ln}: unknown metric type {kind:?}"));
            }
            if !typed.insert(name.to_string()) {
                return Err(format!("line {ln}: duplicate TYPE for {name:?}"));
            }
            if sampled.contains(name) {
                return Err(format!("line {ln}: TYPE for {name:?} after its samples"));
            }
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest
                .split_whitespace()
                .next()
                .ok_or(format!("line {ln}: HELP without name"))?;
            if !valid_metric_name(name) {
                return Err(format!("line {ln}: bad metric name {name:?}"));
            }
            continue;
        }
        if line.starts_with('#') {
            continue; // free-form comment
        }
        // Sample: name[{labels}] value [timestamp]
        let name_end = line
            .find(|c: char| c == '{' || c == ' ' || c == '\t')
            .ok_or(format!("line {ln}: sample without value"))?;
        let name = &line[..name_end];
        if !valid_metric_name(name) {
            return Err(format!("line {ln}: bad metric name {name:?}"));
        }
        let mut rest = &line[name_end..];
        if rest.starts_with('{') {
            let consumed =
                parse_labels(rest).map_err(|e| format!("line {ln}: {e}"))?;
            rest = &rest[consumed..];
        }
        let mut it = rest.split_whitespace();
        let value = it.next().ok_or(format!("line {ln}: sample without value"))?;
        value
            .parse::<f64>()
            .map_err(|_| format!("line {ln}: bad sample value {value:?}"))?;
        if let Some(ts) = it.next() {
            ts.parse::<i64>()
                .map_err(|_| format!("line {ln}: bad timestamp {ts:?}"))?;
        }
        if it.next().is_some() {
            return Err(format!("line {ln}: trailing tokens after sample"));
        }
        // The family base name: histogram/summary series suffixes
        // (_bucket/_sum/_count) still belong to the declared family.
        let base = name
            .strip_suffix("_bucket")
            .or_else(|| name.strip_suffix("_sum"))
            .or_else(|| name.strip_suffix("_count"))
            .filter(|b| typed.contains(*b))
            .unwrap_or(name);
        sampled.insert(base.to_string());
        samples += 1;
    }
    Ok(samples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{RunMetrics, TracePoint};

    #[test]
    fn process_families_are_valid_exposition() {
        let snap = ProcSnapshot {
            queue_depth: 3,
            jobs_by_state: [3, 1, 7, 1, 2],
            submitted: 14,
            rejected: 2,
            completed: 7,
            cache_hits: 32,
            cache_misses: 16,
            cache_entries: 16,
            ..ProcSnapshot::default()
        };
        let text = render_process(&snap);
        let n = validate_exposition(&text).expect("process families must validate");
        // 11 single-sample families + 5 per-state job gauges.
        assert_eq!(n, 16);
        assert!(text.contains("c2dfb_daemon_jobs{state=\"queued\"} 3"));
        assert!(text.contains("c2dfb_daemon_cell_cache_hits_total 32"));
    }

    #[test]
    fn run_metrics_render_validates_and_concatenates_once() {
        let mut m = RunMetrics::new("c2dfb", "daemon");
        m.ledger.total_bytes = 123_456;
        m.ledger.messages = 78;
        m.oracles.first_order = 900;
        m.trace.push(TracePoint {
            round: 3,
            comm_mb: 0.1,
            sim_time_s: 0.0,
            wall_time_s: 0.0,
            loss: 0.25,
            accuracy: 0.5,
            grad_norm: 1.0,
            consensus_err: 0.0,
            dropped_msgs: 0,
        });
        validate_exposition(&m.render_prometheus()).expect("run families must validate");
        // The /metrics endpoint shape: process families + ONE run render.
        let combined = format!("{}{}", render_process(&ProcSnapshot::default()), m.render_prometheus());
        validate_exposition(&combined).expect("combined endpoint output must validate");
        // Two run renders would repeat every # TYPE line — exactly what
        // the validator (and real scrapers) reject.
        let doubled = format!("{}{}", m.render_prometheus(), m.render_prometheus());
        assert!(validate_exposition(&doubled).is_err());
    }

    #[test]
    fn validator_rejects_malformed_lines() {
        assert!(validate_exposition("ok_metric 1\n").is_ok());
        assert!(validate_exposition("ok{a=\"b\",c=\"d\"} 2.5 1234\n").is_ok());
        assert!(validate_exposition("ok{a=\"say \\\"hi\\\"\"} NaN\n").is_ok());
        assert!(validate_exposition("1bad 1\n").is_err(), "name must not start with digit");
        assert!(validate_exposition("m{1x=\"v\"} 1\n").is_err(), "bad label name");
        assert!(validate_exposition("m{a=\"v} 1\n").is_err(), "unterminated label");
        assert!(validate_exposition("m notanumber\n").is_err(), "bad value");
        assert!(validate_exposition("m 1 2 3\n").is_err(), "trailing tokens");
        assert!(validate_exposition("# TYPE m flavor\nm 1\n").is_err(), "unknown type");
        assert!(
            validate_exposition("m 1\n# TYPE m counter\n").is_err(),
            "TYPE after samples"
        );
    }
}
