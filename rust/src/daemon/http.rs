//! Minimal HTTP/1.1 surface over [`Daemon`] — std-only, one request per
//! connection (`Connection: close`), each connection on its own thread.
//!
//! Routes (docs/SERVE.md):
//!
//! | method | path                          | purpose                        |
//! |--------|-------------------------------|--------------------------------|
//! | POST   | /jobs?priority=N&trace=1      | submit a TOML/JSON sweep body  |
//! | GET    | /jobs                         | list job statuses              |
//! | GET    | /jobs/:id                     | one job's status               |
//! | DELETE | /jobs/:id                     | cancel                         |
//! | GET    | /jobs/:id/report.csv          | finished job's CSV report      |
//! | GET    | /jobs/:id/report.json         | finished job's JSON report     |
//! | GET    | /jobs/:id/trace.jsonl         | finished job's JSONL trace     |
//! | GET    | /jobs/:id/events?cursor=N     | SSE progress stream            |
//! | GET    | /metrics                      | Prometheus text exposition     |
//! | GET    | /healthz                      | liveness probe                 |
//! | POST   | /shutdown?mode=drain\|now     | begin shutdown                 |
//!
//! Input hardening: 16 KiB header cap, 4 MiB body cap, read/write
//! timeouts, no chunked encoding (411 without a Content-Length body).

// Toolchain-native twin of lint rule R3 (panic-free request parsing);
// `c2dfb lint` enforces the same contract lexically.  docs/LINT.md.
#![warn(clippy::unwrap_used, clippy::expect_used, clippy::panic)]

use super::{Daemon, Job, JobState, SubmitError};
use crate::util::json::Json;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

const MAX_HEADER_BYTES: usize = 16 * 1024;
const MAX_BODY_BYTES: usize = 4 * 1024 * 1024;
const IO_TIMEOUT: Duration = Duration::from_secs(10);

/// Accept loop: non-blocking so it can poll the daemon's shutdown phase;
/// exits once the daemon has stopped.
pub fn listen(d: &Arc<Daemon>, listener: TcpListener) {
    if listener.set_nonblocking(true).is_err() {
        d.opts.console.warn(format_args!("http listener: cannot set non-blocking"));
        return;
    }
    loop {
        if d.stopped() {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let d = d.clone();
                std::thread::spawn(move || handle(&d, stream));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(50)),
        }
    }
}

struct Request {
    method: String,
    /// Path with the query string stripped.
    path: String,
    query: Vec<(String, String)>,
    body: Vec<u8>,
}

impl Request {
    fn query_get(&self, key: &str) -> Option<&str> {
        self.query
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }
}

fn handle(d: &Arc<Daemon>, mut stream: TcpStream) {
    // Listeners accept in non-blocking mode; handler I/O is blocking with
    // timeouts.
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let req = match read_request(&mut stream) {
        Ok(r) => r,
        Err((code, msg)) => {
            respond_json(&mut stream, code, &err_doc(&msg));
            return;
        }
    };
    route(d, &mut stream, &req);
}

fn read_request(stream: &mut TcpStream) -> Result<Request, (u16, String)> {
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 1024];
    let header_end = loop {
        if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > MAX_HEADER_BYTES {
            return Err((431, "request header too large".into()));
        }
        let n = stream
            .read(&mut chunk)
            .map_err(|e| (408u16, format!("reading request: {e}")))?;
        if n == 0 {
            return Err((400, "connection closed mid-request".into()));
        }
        buf.extend_from_slice(chunk.get(..n).unwrap_or_default());
    };
    let head = String::from_utf8_lossy(buf.get(..header_end).unwrap_or_default()).to_string();
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or_default().to_string();
    let target = parts.next().unwrap_or_default().to_string();
    if method.is_empty() || !target.starts_with('/') {
        return Err((400, format!("malformed request line {request_line:?}")));
    }
    let mut content_length = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            let k = k.trim().to_ascii_lowercase();
            let v = v.trim();
            if k == "content-length" {
                content_length = v
                    .parse()
                    .map_err(|_| (400u16, format!("bad content-length {v:?}")))?;
            } else if k == "transfer-encoding" && !v.eq_ignore_ascii_case("identity") {
                return Err((411, "chunked bodies unsupported; send Content-Length".into()));
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err((413, format!("body larger than {MAX_BODY_BYTES} bytes")));
    }
    // header_end + 4 ≤ buf.len() by find_subslice's contract; get keeps
    // the parser panic-free even if that invariant ever shifts (R3).
    let mut body = buf.get(header_end + 4..).unwrap_or_default().to_vec();
    while body.len() < content_length {
        let n = stream
            .read(&mut chunk)
            .map_err(|e| (408u16, format!("reading body: {e}")))?;
        if n == 0 {
            return Err((400, "connection closed mid-body".into()));
        }
        body.extend_from_slice(chunk.get(..n).unwrap_or_default());
    }
    body.truncate(content_length);
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), parse_query(q)),
        None => (target, Vec::new()),
    };
    Ok(Request { method, path, query, body })
}

fn parse_query(q: &str) -> Vec<(String, String)> {
    q.split('&')
        .filter(|kv| !kv.is_empty())
        .map(|kv| match kv.split_once('=') {
            Some((k, v)) => (k.to_string(), v.to_string()),
            None => (kv.to_string(), String::new()),
        })
        .collect()
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack
        .windows(needle.len())
        .position(|w| w == needle)
}

fn reason(code: u16) -> &'static str {
    match code {
        200 => "OK",
        201 => "Created",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    }
}

fn respond(stream: &mut TcpStream, code: u16, ctype: &str, body: &[u8]) {
    let head = format!(
        "HTTP/1.1 {code} {}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        reason(code),
        body.len()
    );
    let _ = stream.write_all(head.as_bytes());
    let _ = stream.write_all(body);
    let _ = stream.flush();
}

fn respond_json(stream: &mut TcpStream, code: u16, doc: &Json) {
    respond(stream, code, "application/json", (doc.to_string() + "\n").as_bytes());
}

fn err_doc(msg: &str) -> Json {
    Json::obj(vec![("error", Json::str(msg))])
}

fn route(d: &Arc<Daemon>, stream: &mut TcpStream, req: &Request) {
    let segments: Vec<&str> = req
        .path
        .trim_matches('/')
        .split('/')
        .filter(|s| !s.is_empty())
        .collect();
    match (req.method.as_str(), segments.as_slice()) {
        ("GET", ["healthz"]) => respond(stream, 200, "text/plain", b"ok\n"),
        ("GET", ["metrics"]) => respond(
            stream,
            200,
            "text/plain; version=0.0.4",
            d.render_metrics().as_bytes(),
        ),
        ("POST", ["jobs"]) => post_job(d, stream, req),
        ("GET", ["jobs"]) => {
            let docs: Vec<Json> = d.jobs_snapshot().iter().map(|j| j.status_json()).collect();
            respond_json(stream, 200, &Json::obj(vec![("jobs", Json::Arr(docs))]));
        }
        ("GET", ["jobs", id]) => match lookup(d, *id) {
            Ok(job) => respond_json(stream, 200, &job.status_json()),
            Err(doc) => respond_json(stream, 404, &doc),
        },
        ("DELETE", ["jobs", id]) => match lookup(d, *id) {
            Ok(job) => {
                d.cancel(job.id);
                respond_json(stream, 200, &job.status_json());
            }
            Err(doc) => respond_json(stream, 404, &doc),
        },
        ("GET", ["jobs", id, artifact @ ("report.csv" | "report.json" | "trace.jsonl")]) => {
            match lookup(d, *id) {
                Ok(job) => serve_artifact(stream, &job, *artifact),
                Err(doc) => respond_json(stream, 404, &doc),
            }
        }
        ("GET", ["jobs", id, "events"]) => match lookup(d, *id) {
            Ok(job) => {
                let cursor = req
                    .query_get("cursor")
                    .and_then(|c| c.parse().ok())
                    .unwrap_or(0usize);
                stream_events(d, stream, &job, cursor);
            }
            Err(doc) => respond_json(stream, 404, &doc),
        },
        ("POST", ["shutdown"]) => {
            let now = req.query_get("mode").is_some_and(|m| m == "now");
            d.begin_shutdown(now);
            respond_json(
                stream,
                202,
                &Json::obj(vec![(
                    "state",
                    Json::str(if now { "stopping" } else { "draining" }),
                )]),
            );
        }
        (_, ["jobs", ..]) | (_, ["metrics"]) | (_, ["healthz"]) | (_, ["shutdown"]) => {
            respond_json(stream, 405, &err_doc("method not allowed"))
        }
        _ => respond_json(stream, 404, &err_doc("no such route")),
    }
}

fn lookup(d: &Daemon, id: &str) -> Result<Arc<Job>, Json> {
    let id: u64 = id
        .parse()
        .map_err(|_| err_doc(&format!("bad job id {id:?}")))?;
    d.job(id).ok_or_else(|| err_doc(&format!("no job {id}")))
}

fn post_job(d: &Arc<Daemon>, stream: &mut TcpStream, req: &Request) {
    let priority: i64 = match req.query_get("priority").map(str::parse).transpose() {
        Ok(p) => p.unwrap_or(0),
        Err(_) => return respond_json(stream, 400, &err_doc("bad priority")),
    };
    let trace = req
        .query_get("trace")
        .is_some_and(|t| t == "1" || t == "true");
    let body = match std::str::from_utf8(&req.body) {
        Ok(s) => s,
        Err(_) => return respond_json(stream, 400, &err_doc("body must be UTF-8 TOML or JSON")),
    };
    match d.submit(body, priority, trace) {
        Ok(job) => respond_json(stream, 201, &job.status_json()),
        Err(SubmitError::QueueFull) => respond_json(stream, 429, &err_doc("queue full")),
        Err(SubmitError::ShuttingDown) => {
            respond_json(stream, 503, &err_doc("daemon is shutting down"))
        }
        Err(SubmitError::Bad(e)) => respond_json(stream, 400, &err_doc(&e)),
    }
}

fn serve_artifact(stream: &mut TcpStream, job: &Job, artifact: &str) {
    enum Out {
        Body(String, &'static str),
        Error(u16, String),
    }
    let out = job.with_progress(|st| match st.state {
        JobState::Queued | JobState::Running => Out::Error(
            409,
            format!("job is {} — artifacts exist once it is done", st.state.name()),
        ),
        JobState::Failed | JobState::Cancelled => Out::Error(
            409,
            format!(
                "job {}: {}",
                st.state.name(),
                st.error.as_deref().unwrap_or("no artifacts")
            ),
        ),
        JobState::Done => {
            let picked = match artifact {
                "report.csv" => (st.report_csv.clone(), "text/csv"),
                "report.json" => (st.report_json.clone(), "application/json"),
                _ => (st.trace_jsonl.clone(), "application/jsonl"),
            };
            match picked {
                (Some(body), ctype) => Out::Body(body, ctype),
                (None, _) => Out::Error(
                    404,
                    "no such artifact (trace.jsonl requires submitting with trace=1)".into(),
                ),
            }
        }
    });
    match out {
        Out::Body(body, ctype) => respond(stream, 200, ctype, body.as_bytes()),
        Out::Error(code, msg) => respond_json(stream, code, &err_doc(&msg)),
    }
}

/// Server-sent events: replay the job's event log from `cursor`, then
/// follow it live (1 s keep-alive comments) until the log closes, the
/// client hangs up, or the daemon stops.
fn stream_events(d: &Arc<Daemon>, stream: &mut TcpStream, job: &Arc<Job>, mut cursor: usize) {
    let head = "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n";
    if stream.write_all(head.as_bytes()).is_err() {
        return;
    }
    loop {
        let (lines, next, closed) = job.events.wait_from(cursor, Duration::from_secs(1));
        cursor = next;
        for line in &lines {
            if stream
                .write_all(format!("data: {line}\n\n").as_bytes())
                .is_err()
            {
                return;
            }
        }
        if closed {
            return;
        }
        if lines.is_empty() {
            if d.stopped() {
                return;
            }
            if stream.write_all(b": keep-alive\n\n").is_err() {
                return;
            }
        }
        let _ = stream.flush();
    }
}
