//! `lint.toml` — the checked-in policy file for `c2dfb lint`.
//!
//! The format rides on the repo's own TOML subset parser
//! ([`crate::config::toml`]): one `[R*]` section per rule, with two key
//! families (numbered so every entry is one greppable line):
//!
//! * `pathN = "…"` — scope the rule to the listed files/directories
//!   (used by the path-scoped rules R3 and R6; a rule with no `pathN`
//!   keys applies to every scanned file).
//! * `allowN = "<path> -- <reason>"` — suppress the rule in one file.
//!   The reason is MANDATORY and lives here, in review-able history,
//!   which is the point: every exemption is a written claim that the
//!   contract holds for a documented reason (docs/LINT.md).
//!
//! A directory scope/allow ends with `/`.  Unknown rule ids and
//! reason-less allows are hard errors — a typo must not silently turn a
//! rule off.

use crate::config::toml;
use std::collections::BTreeMap;

/// One `allowN` entry: `rule` is the section it appeared under.
#[derive(Clone, Debug, PartialEq)]
pub struct AllowEntry {
    pub rule: String,
    pub path: String,
    pub reason: String,
}

/// Parsed lint policy.
#[derive(Clone, Debug, Default)]
pub struct LintConfig {
    /// Rule id → explicit scope paths (empty = rule applies everywhere).
    pub scopes: BTreeMap<String, Vec<String>>,
    pub allows: Vec<AllowEntry>,
}

/// The rules that may appear as `[R*]` sections.
pub const RULE_IDS: [&str; 6] = ["R1", "R2", "R3", "R4", "R5", "R6"];

/// Built-in scopes used when no `lint.toml` is present: R3 covers the
/// hostile-byte parsers, R6 the trace emitter; everything else is
/// tree-wide.  The shipped `rust/lint.toml` mirrors these.
pub fn default_scopes() -> BTreeMap<String, Vec<String>> {
    let mut m = BTreeMap::new();
    m.insert(
        "R3".to_string(),
        [
            "src/compress/message.rs",
            "src/daemon/http.rs",
            "src/daemon/tcp.rs",
            "src/util/json.rs",
            "src/config/toml.rs",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect(),
    );
    m.insert("R6".to_string(), vec!["src/obs/mod.rs".to_string()]);
    m
}

impl LintConfig {
    /// Policy with the built-in scopes and no allows (tests, and `c2dfb
    /// lint` when no `lint.toml` is found).
    pub fn default_config() -> LintConfig {
        LintConfig { scopes: default_scopes(), allows: Vec::new() }
    }

    pub fn from_toml_str(text: &str) -> Result<LintConfig, String> {
        let map = toml::parse(text)?;
        let mut cfg = LintConfig { scopes: default_scopes(), allows: Vec::new() };
        // First pass: any rule section that declares pathN keys replaces
        // that rule's default scope entirely.
        let mut declared_paths: BTreeMap<String, Vec<(String, String)>> = BTreeMap::new();
        for (key, val) in &map {
            let (rule, field) = key
                .split_once('.')
                .ok_or_else(|| format!("lint.toml: top-level key {key:?}; entries live in [R*] sections"))?;
            if !RULE_IDS.contains(&rule) {
                return Err(format!("lint.toml: unknown rule section [{rule}]"));
            }
            let sval = val
                .as_str()
                .ok_or_else(|| format!("lint.toml: {key} must be a string"))?;
            if field.starts_with("path") {
                declared_paths
                    .entry(rule.to_string())
                    .or_default()
                    .push((field.to_string(), sval.to_string()));
            } else if field.starts_with("allow") {
                let (path, reason) = sval.split_once(" -- ").ok_or_else(|| {
                    format!(
                        "lint.toml: {key}: missing \" -- reason\"; every allow entry \
                         must carry a written justification"
                    )
                })?;
                let (path, reason) = (path.trim(), reason.trim());
                if path.is_empty() || reason.is_empty() {
                    return Err(format!("lint.toml: {key}: empty path or reason"));
                }
                cfg.allows.push(AllowEntry {
                    rule: rule.to_string(),
                    path: path.to_string(),
                    reason: reason.to_string(),
                });
            } else {
                return Err(format!(
                    "lint.toml: {key}: unknown field {field:?} (expected pathN or allowN)"
                ));
            }
        }
        for (rule, mut entries) in declared_paths {
            entries.sort(); // key order (path1, path2, …), deterministic
            cfg.scopes
                .insert(rule, entries.into_iter().map(|(_, p)| p).collect());
        }
        Ok(cfg)
    }

    pub fn load(path: &std::path::Path) -> Result<LintConfig, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        LintConfig::from_toml_str(&text)
    }

    /// Does `rule` apply to `file`?  (True when the rule has no scope or
    /// any scope entry matches.)
    pub fn rule_applies(&self, rule: &str, file: &str) -> bool {
        match self.scopes.get(rule) {
            None => true,
            Some(paths) if paths.is_empty() => true,
            Some(paths) => paths.iter().any(|p| path_matches(p, file)),
        }
    }

    /// Index of the allow entry suppressing `rule` in `file`, if any.
    pub fn allow_for(&self, rule: &str, file: &str) -> Option<usize> {
        self.allows
            .iter()
            .position(|a| a.rule == rule && path_matches(&a.path, file))
    }
}

/// Path matching: an entry ending in `/` is a directory prefix; anything
/// else must match the file path exactly or as a `/`-anchored suffix
/// (so `src/obs/mod.rs` matches `rust/src/obs/mod.rs` when the linter is
/// invoked from the repo root).
pub fn path_matches(entry: &str, file: &str) -> bool {
    let f = file.replace('\\', "/");
    if let Some(dir) = entry.strip_suffix('/') {
        f == dir || f.starts_with(&format!("{dir}/")) || f.contains(&format!("/{dir}/"))
    } else {
        f == entry || f.ends_with(&format!("/{entry}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scopes_and_allows() {
        let cfg = LintConfig::from_toml_str(
            "[R1]\nallow1 = \"src/obs/mod.rs -- profiler is wall-clock by design\"\n\
             [R3]\npath1 = \"src/compress/message.rs\"\n",
        )
        .unwrap();
        assert_eq!(cfg.allows.len(), 1);
        assert_eq!(cfg.allows[0].rule, "R1");
        assert!(cfg.allows[0].reason.contains("profiler"));
        assert_eq!(cfg.scopes["R3"], vec!["src/compress/message.rs".to_string()]);
        // R6 keeps its built-in scope when the file does not override it.
        assert!(cfg.rule_applies("R6", "src/obs/mod.rs"));
        assert!(!cfg.rule_applies("R6", "src/main.rs"));
        assert!(cfg.rule_applies("R1", "src/anything.rs"));
        assert_eq!(cfg.allow_for("R1", "rust/src/obs/mod.rs"), Some(0));
        assert_eq!(cfg.allow_for("R1", "src/main.rs"), None);
    }

    #[test]
    fn reasonless_allow_is_an_error() {
        let e = LintConfig::from_toml_str("[R1]\nallow1 = \"src/obs/mod.rs\"\n")
            .unwrap_err();
        assert!(e.contains("reason"), "{e}");
    }

    #[test]
    fn unknown_rule_is_an_error() {
        assert!(LintConfig::from_toml_str("[R9]\npath1 = \"x\"\n").is_err());
        assert!(LintConfig::from_toml_str("[R1]\nwhatever = \"x\"\n").is_err());
    }

    #[test]
    fn dir_entries_match_prefixes() {
        assert!(path_matches("tests/lint_fixtures/", "tests/lint_fixtures/r1.rs"));
        assert!(path_matches("tests/lint_fixtures/", "rust/tests/lint_fixtures/r1.rs"));
        assert!(!path_matches("tests/lint_fixtures/", "src/lib.rs"));
        assert!(path_matches("src/obs/mod.rs", "src/obs/mod.rs"));
        assert!(!path_matches("src/obs/mod.rs", "xsrc/obs/mod.rs"));
    }
}
